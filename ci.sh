#!/usr/bin/env bash
# Repo gate: format, lints, tests, and a bench smoke that proves the
# machine-readable perf record is well-formed. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy (all targets, -D warnings)"
cargo clippy --all-targets -- -D warnings

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> static analyzer over the model zoo (zero diagnostics gate)"
cargo run --quiet --release -- lint

echo "==> fig3 bench smoke (FYRO_BENCH_SMOKE=1)"
BENCH_OUT="$PWD/BENCH_fig3.json"
FYRO_BENCH_SMOKE=1 FYRO_BENCH_OUT="$BENCH_OUT" cargo bench --bench fig3_vae_overhead

echo "==> validating $BENCH_OUT"
python3 - "$BENCH_OUT" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    rec = json.load(f)

for key in ["bench", "unit", "config", "baseline", "optimized", "speedup",
            "compiled", "multi_particle", "parallel_matches_serial", "plate",
            "elbo", "telemetry", "analysis"]:
    assert key in rec, f"missing key: {key}"
for side in ["baseline", "optimized"]:
    for key in ["ns_per_step", "allocs_per_step", "particles", "threads"]:
        assert key in rec[side], f"missing {side}.{key}"
    assert rec[side]["ns_per_step"] > 0, f"{side}.ns_per_step not positive"
assert rec["parallel_matches_serial"] is True, "parallel ELBO diverged from serial"
assert isinstance(rec["multi_particle"], list) and rec["multi_particle"]

plate = rec["plate"]
assert plate["n"] == 1024, f"plate bench must run at N=1024, got {plate['n']}"
vec, seq = plate["vectorized"], plate["sequential"]
for side, d in [("vectorized", vec), ("sequential", seq)]:
    for key in ["sites", "ns_per_step", "allocs_per_step"]:
        assert key in d, f"missing plate.{side}.{key}"
assert vec["sites"] == 2, f"vectorized plate must record 1 site (+1 latent), got {vec['sites']}"
assert seq["sites"] == plate["n"] + 1, f"sequential plate sites {seq['sites']}"
assert plate["elbo_matches"] is True, "vectorized vs sequential plate ELBO diverged"
assert vec["allocs_per_step"] < seq["allocs_per_step"], (
    f"vectorized plate must allocate strictly less at N=1024: "
    f"{vec['allocs_per_step']} vs {seq['allocs_per_step']}")
print(f"plate N=1024: vectorized {vec['ns_per_step']:.0f} ns/step "
      f"({vec['allocs_per_step']:.0f} allocs) vs sequential "
      f"{seq['ns_per_step']:.0f} ns/step ({seq['allocs_per_step']:.0f} allocs)")

elbo = rec["elbo"]
for est in ["trace", "tracegraph", "renyi_iwae"]:
    for key in ["grad_var", "ns_per_step", "particles"]:
        assert key in elbo[est], f"missing elbo.{est}.{key}"
    assert elbo[est]["grad_var"] >= 0, f"elbo.{est}.grad_var negative"
    assert elbo[est]["ns_per_step"] > 0, f"elbo.{est}.ns_per_step not positive"
assert elbo["tracegraph_le_trace"] is True, \
    "TraceGraph gradient variance exceeded plain Trace on the gmm"
assert elbo["tracegraph"]["grad_var"] <= elbo["trace"]["grad_var"], (
    f"Rao-Blackwellized TraceGraph must cut (or match) score-gradient "
    f"variance: {elbo['tracegraph']['grad_var']} vs {elbo['trace']['grad_var']}")
print(f"elbo gmm n={elbo['n']}: grad var Trace {elbo['trace']['grad_var']:.4f} "
      f"-> TraceGraph {elbo['tracegraph']['grad_var']:.4f} "
      f"(ratio {elbo['tracegraph']['grad_var'] / max(elbo['trace']['grad_var'], 1e-300):.3f}), "
      f"Renyi/IWAE-{elbo['renyi_iwae']['particles']} var {elbo['renyi_iwae']['grad_var']:.4f}")
compiled = rec["compiled"]
for key in ["ns_per_step", "allocs_per_step", "speedup_vs_dynamic",
            "matches_dynamic_1e12", "parallel_matches_serial"]:
    assert key in compiled, f"missing compiled.{key}"
assert compiled["ns_per_step"] > 0, "compiled.ns_per_step not positive"
assert compiled["allocs_per_step"] == 0, (
    f"compiled graph-mode step must be allocation-free in steady state, "
    f"got {compiled['allocs_per_step']}")
assert compiled["matches_dynamic_1e12"] is True, \
    "compiled trajectory diverged from the dynamic interpreter (1e-12)"
assert compiled["parallel_matches_serial"] is True, \
    "compiled parallel ELBO diverged from compiled serial"

tel = rec["telemetry"]
for key in ["ns_per_step_compiled_off", "ns_per_step_compiled_on",
            "overhead_pct", "allocs_per_step_compiled_on", "bitwise_match",
            "snapshot"]:
    assert key in tel, f"missing telemetry.{key}"
assert tel["allocs_per_step_compiled_on"] == 0, (
    f"telemetry-enabled compiled step allocated: "
    f"{tel['allocs_per_step_compiled_on']}")
assert tel["bitwise_match"] is True, \
    "telemetry perturbed the loss trajectory (bitwise parity broken)"
snap = tel["snapshot"]
for key in ["counters", "gauges", "hists", "sites"]:
    assert key in snap, f"missing telemetry.snapshot.{key}"
assert snap["counters"]["steps"] > 0, "embedded snapshot recorded no steps"
assert snap["hists"]["step_ns"]["count"] > 0, "step_ns histogram empty"

ana = rec["analysis"]
for key in ["fw_total", "bw_total", "fw_eliminated", "bw_eliminated",
            "dce_bitwise_match", "verifier_ran", "zoo_pairs",
            "zoo_diagnostics", "vae_pair_clean"]:
    assert key in ana, f"missing analysis.{key}"
assert ana["dce_bitwise_match"] is True, \
    "liveness DCE changed the training trajectory (bitwise pin broken)"
assert ana["verifier_ran"] is True, "graph-IR verifier did not run"
assert ana["bw_eliminated"] >= 1, (
    f"DCE found no dead adjoint work on the VAE (observation data leaves "
    f"should be pruned): {ana['bw_eliminated']}")
assert ana["bw_eliminated"] < ana["bw_total"], "DCE pruned the whole backward pass"
assert ana["fw_eliminated"] == 0, \
    "forward plans are loss-pruned at record time; DCE must not touch them"
assert ana["zoo_diagnostics"] == 0 and ana["zoo_pairs"] > 0, \
    f"linter flagged the known-good zoo: {ana['zoo_diagnostics']} diagnostic(s)"
assert ana["vae_pair_clean"] is True, "linter flagged the VAE pair"
print(f"analysis OK: {ana['zoo_pairs']} zoo pairs clean, DCE eliminated "
      f"{ana['bw_eliminated']}/{ana['bw_total']} backward instruction(s) "
      f"bitwise-safely")

if rec["config"].get("smoke"):
    # smoke dims are too small for stable ratios; full runs must hit 3x
    # and the 2% telemetry budget
    print(f"(smoke run: speedup {rec['speedup']:.2f}x / compiled "
          f"{compiled['speedup_vs_dynamic']:.2f}x / telemetry overhead "
          f"{tel['overhead_pct']:+.2f}%, ratios not asserted)")
else:
    assert rec["speedup"] >= 3.0, (
        f"hot-path speedup {rec['speedup']:.2f}x below the 3x acceptance bar")
    assert compiled["speedup_vs_dynamic"] >= 5.0, (
        f"graph-mode speedup {compiled['speedup_vs_dynamic']:.2f}x below the "
        f"5x acceptance bar")
    assert tel["overhead_pct"] <= 2.0, (
        f"telemetry-on overhead {tel['overhead_pct']:.2f}% exceeds the 2% "
        f"budget")
print(f"BENCH_fig3.json OK: speedup {rec['speedup']:.2f}x "
      f"(baseline {rec['baseline']['ns_per_step']:.0f} ns/step, "
      f"optimized {rec['optimized']['ns_per_step']:.0f} ns/step, "
      f"compiled {compiled['ns_per_step']:.0f} ns/step = "
      f"{compiled['speedup_vs_dynamic']:.2f}x dynamic)")
EOF

echo "==> fig2 bench (design-principle record)"
BENCH2_OUT="$PWD/BENCH_fig2.json"
FYRO_BENCH_OUT="$BENCH2_OUT" cargo bench --bench fig2_expressiveness

echo "==> validating $BENCH2_OUT"
python3 - "$BENCH2_OUT" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    rec = json.load(f)
assert rec["bench"] == "fig2_expressiveness"
for p in ["expressivity", "scalability", "flexibility", "minimality"]:
    assert rec["principles"][p] is True, f"design principle failed: {p}"
assert rec["all_pass"] is True
print("BENCH_fig2.json OK: all four design principles hold")
EOF

echo "==> fig4 bench smoke (data-parallel DMM, FYRO_BENCH_SMOKE=1)"
BENCH4_OUT="$PWD/BENCH_fig4.json"
FYRO_BENCH_SMOKE=1 FYRO_BENCH_OUT="$BENCH4_OUT" cargo bench --bench fig4_dmm_elbo

echo "==> validating $BENCH4_OUT"
python3 - "$BENCH4_OUT" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    rec = json.load(f)

for key in ["bench", "unit", "config", "data_loop_allocs", "sweep",
            "thread_speedup_w2", "sync_bitwise", "graph",
            "stream_matches_mem", "async"]:
    assert key in rec, f"missing key: {key}"
assert rec["bench"] == "fig4_dmm_dataparallel"

assert rec["data_loop_allocs"] == 0, (
    f"steady-state epoch data loop allocated: {rec['data_loop_allocs']}")

sweep = rec["sweep"]
assert isinstance(sweep, list) and sweep, "sweep must be a non-empty list"
workers = [row["workers"] for row in sweep]
smoke = rec["config"].get("smoke")
expected = [1, 2] if smoke else [1, 2, 4, 8]
assert workers == expected, f"sweep workers {workers}, expected {expected}"
for row in sweep:
    for key in ["workers", "ns_per_step_serial", "ns_per_step_threaded",
                "thread_speedup", "rows_per_sec"]:
        assert key in row, f"missing sweep.{key}"
    assert row["ns_per_step_serial"] > 0 and row["ns_per_step_threaded"] > 0
    assert row["rows_per_sec"] > 0

assert rec["sync_bitwise"] is True, (
    "threaded data-parallel SVI diverged bitwise from serial at fixed shards")

graph = rec["graph"]
for key in ["active", "matches_dynamic_1e12", "thread_invariant",
            "speedup_vs_dynamic"]:
    assert key in graph, f"missing graph.{key}"
assert graph["active"] is True, "graph mode failed to engage on the DMM"
assert graph["matches_dynamic_1e12"] is True, \
    "compiled shard trajectory diverged from the dynamic interpreter"
assert graph["thread_invariant"] is True, \
    "compiled shard trajectory is thread-dependent"

assert rec["stream_matches_mem"] is True, \
    "on-disk StreamLoader changed the training trajectory vs MemLoader"

asy = rec["async"]
for key in ["workers", "max_staleness", "applied", "rejected",
            "rows_per_sec", "tail_loss"]:
    assert key in asy, f"missing async.{key}"
assert asy["applied"] > 0, "async run applied no pushes"
assert asy["rows_per_sec"] > 0

if smoke:
    # tiny dims + loaded CI machines make the ratio unstable; full runs gate
    print(f"(smoke run: W=2 thread speedup {rec['thread_speedup_w2']:.2f}x, "
          f"not asserted)")
else:
    assert rec["thread_speedup_w2"] >= 1.6, (
        f"W=2 thread speedup {rec['thread_speedup_w2']:.2f}x below the 1.6x "
        f"acceptance bar")
print(f"BENCH_fig4.json OK: sweep W={workers}, "
      f"W=2 speedup {rec['thread_speedup_w2']:.2f}x, "
      f"async {asy['applied']} applied / {asy['rejected']} rejected")
EOF

echo "==> serve bench smoke (serving-layer load sweep, FYRO_BENCH_SMOKE=1)"
BENCHS_OUT="$PWD/BENCH_serve.json"
FYRO_BENCH_SMOKE=1 FYRO_BENCH_OUT="$BENCHS_OUT" cargo bench --bench serve_load

echo "==> validating $BENCHS_OUT"
python3 - "$BENCHS_OUT" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    rec = json.load(f)

for key in ["bench", "unit", "config", "sweep", "worker_speedup",
            "batched", "unbatched", "batched_speedup",
            "solo_matches_batched", "compiled_matches_dynamic_1e12",
            "overload"]:
    assert key in rec, f"missing key: {key}"
assert rec["bench"] == "serve_load"

sweep = rec["sweep"]
assert isinstance(sweep, list) and sweep, "sweep must be a non-empty list"
workers = [row["workers"] for row in sweep]
smoke = rec["config"].get("smoke", rec.get("smoke"))
expected = [1, 2] if smoke else [1, 2, 4]
assert workers == expected, f"sweep workers {workers}, expected {expected}"
for row in sweep:
    for key in ["workers", "requests_per_sec", "p50_ms", "p95_ms", "p99_ms",
                "completed", "retries", "served", "batches_dispatched",
                "mean_batch_fill"]:
        assert key in row, f"missing sweep.{key}"
    assert row["requests_per_sec"] > 0
    assert row["completed"] > 0, "closed-loop clients completed no requests"
    assert row["served"] >= row["completed"], (
        f"served counter {row['served']} below completed {row['completed']}")
    assert row["batches_dispatched"] > 0

# determinism + correctness flags hold on every run, smoke or full
assert rec["solo_matches_batched"] is True, (
    "batched serving changed a response bitwise vs the solo evaluation")
assert rec["compiled_matches_dynamic_1e12"] is True, (
    "compiled Score path diverged from the dynamic interpreter (1e-12)")
ov = rec["overload"]
for key in ["rejected", "accepted_all_served", "rejected_counter"]:
    assert key in ov, f"missing overload.{key}"
assert ov["rejected"] > 0, "overload exercise never tripped backpressure"
assert ov["accepted_all_served"] is True, (
    "an accepted request was dropped under overload")
assert ov["rejected_counter"] == ov["rejected"], (
    f"requests_rejected counter {ov['rejected_counter']} != "
    f"observed rejections {ov['rejected']}")

if smoke:
    # small fleets on loaded CI machines make throughput ratios unstable
    print(f"(smoke run: worker speedup {rec['worker_speedup']:.2f}x, "
          f"batched speedup {rec['batched_speedup']:.2f}x, not asserted)")
else:
    assert rec["worker_speedup"] >= 2.0, (
        f"1->4 worker speedup {rec['worker_speedup']:.2f}x below the 2x "
        f"acceptance bar")
    assert rec["batched_speedup"] >= 1.5, (
        f"batched dispatch speedup {rec['batched_speedup']:.2f}x below the "
        f"1.5x acceptance bar")
best = sweep[-1]
print(f"BENCH_serve.json OK: {best['requests_per_sec']:.0f} req/s at "
      f"W={best['workers']} (p50 {best['p50_ms']:.2f} ms, "
      f"p99 {best['p99_ms']:.2f} ms), overload rejected {ov['rejected']}, "
      f"all accepted served")
EOF

echo "==> python kernel property tests (if jax + hypothesis present)"
if python3 -c "import jax, hypothesis" 2>/dev/null; then
    python3 -m pytest -q python/tests/test_kernels.py
else
    echo "(skipped: jax/hypothesis not importable in this environment)"
fi

echo "==> ci.sh PASS"
