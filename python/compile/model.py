"""Layer-2 JAX models: the paper's evaluation workloads.

Defines, as pure JAX functions calling the Layer-1 Pallas kernels:

  - **VAE** (paper §5, Fig 3): 2-hidden-layer MLP encoder/decoder,
    Bernoulli likelihood, configurable latent size #z and hidden size #h.
  - **DMM** (paper §5, Fig 4): Deep Markov Model (Krishnan et al. 2017)
    with gated transitions, Bernoulli 88-key emissions and a backward-GRU
    inference network, optionally extended with 0/1/2 IAF flows on the
    approximate posterior (Kingma et al. 2016).

Everything the Rust coordinator calls is exposed as three functions per
model variant, each over a single FLAT f32 parameter vector (so the FFI
surface is model-independent):

  init()                              -> params [P]
  train_step(params, m, v, t, x, eps) -> (params', m', v', loss)
  eval_step(params, x, eps)           -> loss
(loss = mean negative ELBO per datum; DMM reports per-timestep.)

Adam runs *inside* the compiled step, exactly like the paper's fused
PyTorch optimizer step. Python never executes at training time: aot.py
lowers each variant to HLO text once.
"""

import numpy as np

import jax
import jax.numpy as jnp

from compile.kernels.bern_ll import bernoulli_ll
from compile.kernels.gauss_elbo import gauss_reparam_kl
from compile.kernels.masked_linear import made_masks, masked_linear

# ---------------------------------------------------------------- helpers


class ParamSpec:
    """Named shapes over one flat parameter vector."""

    def __init__(self, shapes):
        self.shapes = list(shapes)  # [(name, shape)]
        self.offsets = []
        off = 0
        for _, s in self.shapes:
            n = int(np.prod(s)) if s else 1
            self.offsets.append((off, n))
            off += n
        self.total = off

    def unflatten(self, flat):
        out = {}
        for (name, shape), (off, n) in zip(self.shapes, self.offsets):
            out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        return out

    def init_flat(self, key, inits):
        """inits: name -> concrete array; missing names get Xavier."""
        parts = []
        for name, shape in self.shapes:
            if name in inits:
                parts.append(jnp.asarray(inits[name], jnp.float32).reshape(-1))
            elif len(shape) == 2:
                key, sub = jax.random.split(key)
                bound = np.sqrt(6.0 / (shape[0] + shape[1]))
                parts.append(
                    jax.random.uniform(
                        sub, (shape[0] * shape[1],), jnp.float32, -bound, bound
                    )
                )
            else:
                parts.append(jnp.zeros((int(np.prod(shape)) if shape else 1,), jnp.float32))
        return jnp.concatenate(parts)


def adam_update(params, m, v, t, grads, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = t + 1.0
    m = b1 * m + (1.0 - b1) * grads
    v = b2 * v + (1.0 - b2) * grads * grads
    mhat = m / (1.0 - b1**t)
    vhat = v / (1.0 - b2**t)
    return params - lr * mhat / (jnp.sqrt(vhat) + eps), m, v, t


# -------------------------------------------------------------------- VAE


class VAE:
    """Fig-3 workload. x [B, 784] binarized; eps [B, z] standard normal."""

    X_DIM = 784

    def __init__(self, z_dim, h_dim, batch, lr=1e-3):
        self.z, self.h, self.batch, self.lr = z_dim, h_dim, batch, lr
        d, h, z = self.X_DIM, h_dim, z_dim
        self.spec = ParamSpec(
            [
                ("enc_w1", (d, h)), ("enc_b1", (h,)),
                ("enc_w2", (h, h)), ("enc_b2", (h,)),
                ("enc_wloc", (h, z)), ("enc_bloc", (z,)),
                ("enc_wls", (h, z)), ("enc_bls", (z,)),
                ("dec_w1", (z, h)), ("dec_b1", (h,)),
                ("dec_w2", (h, h)), ("dec_b2", (h,)),
                ("dec_w3", (h, d)), ("dec_b3", (d,)),
            ]
        )

    @property
    def name(self):
        return f"vae_z{self.z}_h{self.h}"

    def init(self):
        key = jax.random.PRNGKey(0)
        return self.spec.init_flat(key, {})

    def neg_elbo(self, flat, x, eps):
        p = self.spec.unflatten(flat)
        h1 = jnp.tanh(x @ p["enc_w1"] + p["enc_b1"])
        h2 = jnp.tanh(h1 @ p["enc_w2"] + p["enc_b2"])
        loc = h2 @ p["enc_wloc"] + p["enc_bloc"]
        # bound log-scale for stability (softplus-free clip)
        ls = jnp.clip(h2 @ p["enc_wls"] + p["enc_bls"], -5.0, 3.0)
        z, kl = gauss_reparam_kl(loc, ls, eps)  # L1 kernel
        d1 = jnp.tanh(z @ p["dec_w1"] + p["dec_b1"])
        d2 = jnp.tanh(d1 @ p["dec_w2"] + p["dec_b2"])
        logits = d2 @ p["dec_w3"] + p["dec_b3"]
        ll = bernoulli_ll(logits, x)  # L1 kernel
        return jnp.mean(kl - ll)

    def train_step(self, params, m, v, t, x, eps):
        loss, grads = jax.value_and_grad(self.neg_elbo)(params, x, eps)
        params, m, v, t = adam_update(params, m, v, t[0], grads, self.lr)
        return params, m, v, jnp.stack([t]), jnp.stack([loss])

    def eval_step(self, params, x, eps):
        return jnp.stack([self.neg_elbo(params, x, eps)])

    def example_args(self):
        P = self.spec.total
        f32 = jnp.float32
        return {
            "init": [],
            "train": [
                jax.ShapeDtypeStruct((P,), f32),
                jax.ShapeDtypeStruct((P,), f32),
                jax.ShapeDtypeStruct((P,), f32),
                jax.ShapeDtypeStruct((1,), f32),
                jax.ShapeDtypeStruct((self.batch, self.X_DIM), f32),
                jax.ShapeDtypeStruct((self.batch, self.z), f32),
            ],
            "eval": [
                jax.ShapeDtypeStruct((P,), f32),
                jax.ShapeDtypeStruct((self.batch, self.X_DIM), f32),
                jax.ShapeDtypeStruct((self.batch, self.z), f32),
            ],
        }

    def manifest(self):
        return {
            "kind": "vae",
            "P": self.spec.total,
            "batch": self.batch,
            "x_dims": [self.batch, self.X_DIM],
            "eps_dims": [self.batch, self.z],
            "z": self.z,
            "h": self.h,
            "lr": self.lr,
        }


# -------------------------------------------------------------------- DMM


class DMM:
    """Fig-4 workload: Deep Markov Model over 88-key piano rolls.

    x [B, T, 88]; eps [B, T, z]. `num_iafs` IAF flows refine q(z_t).
    Sizes are scaled from the paper's JSB configuration to CPU budget
    (z 100->32, rnn 600->64, T<=129 -> 32) — DESIGN.md documents the
    substitution; the 0/1/2-IAF *comparison shape* is what Fig 4 tests.
    """

    X_DIM = 88

    def __init__(self, z_dim=32, trans_h=48, emit_h=48, rnn_h=64, iaf_h=64,
                 T=32, batch=16, num_iafs=0, lr=3e-4):
        self.z, self.T, self.batch = z_dim, T, batch
        self.trans_h, self.emit_h, self.rnn_h, self.iaf_h = trans_h, emit_h, rnn_h, iaf_h
        self.num_iafs, self.lr = num_iafs, lr
        z, th, eh, rh, d = z_dim, trans_h, emit_h, rnn_h, self.X_DIM
        shapes = [
            # gated transition p(z_t | z_{t-1})
            ("tr_gw1", (z, th)), ("tr_gb1", (th,)), ("tr_gw2", (th, z)), ("tr_gb2", (z,)),
            ("tr_pw1", (z, th)), ("tr_pb1", (th,)), ("tr_pw2", (th, z)), ("tr_pb2", (z,)),
            ("tr_wloc", (z, z)), ("tr_bloc", (z,)),
            ("tr_wls", (z, z)), ("tr_bls", (z,)),
            # emitter p(x_t | z_t)
            ("em_w1", (z, eh)), ("em_b1", (eh,)),
            ("em_w2", (eh, eh)), ("em_b2", (eh,)),
            ("em_w3", (eh, d)), ("em_b3", (d,)),
            # backward GRU inference net
            ("rnn_wih", (d, 3 * rh)), ("rnn_whh", (rh, 3 * rh)),
            ("rnn_bih", (3 * rh,)), ("rnn_bhh", (3 * rh,)),
            # combiner q(z_t | z_{t-1}, h_t)
            ("co_wz", (z, rh)), ("co_bz", (rh,)),
            ("co_wloc", (rh, z)), ("co_bloc", (z,)),
            ("co_wls", (rh, z)), ("co_bls", (z,)),
            # learned z_0 and h_0
            ("z0", (z,)), ("h0", (rh,)),
        ]
        for k in range(num_iafs):
            shapes += [
                (f"iaf{k}_w1", (z, iaf_h)), (f"iaf{k}_b1", (iaf_h,)),
                (f"iaf{k}_w2", (iaf_h, 2 * z)), (f"iaf{k}_b2", (2 * z,)),
            ]
        self.spec = ParamSpec(shapes)
        self.mask_in, self.mask_out = made_masks(z_dim, iaf_h)

    @property
    def name(self):
        return f"dmm_iaf{self.num_iafs}"

    def init(self):
        return self.spec.init_flat(jax.random.PRNGKey(1), {})

    # --- pieces -----------------------------------------------------

    def _gru_step(self, p, h, x_t):
        gi = x_t @ p["rnn_wih"] + p["rnn_bih"]
        gh = h @ p["rnn_whh"] + p["rnn_bhh"]
        rh = self.rnn_h
        r = jax.nn.sigmoid(gi[:, :rh] + gh[:, :rh])
        zg = jax.nn.sigmoid(gi[:, rh : 2 * rh] + gh[:, rh : 2 * rh])
        n = jnp.tanh(gi[:, 2 * rh :] + r * gh[:, 2 * rh :])
        return (1.0 - zg) * n + zg * h

    def _transition(self, p, z_prev):
        g = jax.nn.sigmoid(
            jnp.tanh(z_prev @ p["tr_gw1"] + p["tr_gb1"]) @ p["tr_gw2"] + p["tr_gb2"]
        )
        prop = jnp.tanh(z_prev @ p["tr_pw1"] + p["tr_pb1"]) @ p["tr_pw2"] + p["tr_pb2"]
        loc = (1.0 - g) * (z_prev @ p["tr_wloc"] + p["tr_bloc"]) + g * prop
        ls = jnp.clip(jax.nn.relu(prop) @ p["tr_wls"] + p["tr_bls"], -5.0, 3.0)
        return loc, ls

    def _emit(self, p, z_t):
        h1 = jnp.tanh(z_t @ p["em_w1"] + p["em_b1"])
        h2 = jnp.tanh(h1 @ p["em_w2"] + p["em_b2"])
        return h2 @ p["em_w3"] + p["em_b3"]

    def _combiner(self, p, z_prev, h_t):
        hc = 0.5 * (jnp.tanh(z_prev @ p["co_wz"] + p["co_bz"]) + h_t)
        loc = hc @ p["co_wloc"] + p["co_bloc"]
        ls = jnp.clip(hc @ p["co_wls"] + p["co_bls"], -5.0, 3.0)
        return loc, ls

    def _iaf(self, p, k, z):
        """One IAF flow: z' = s*z + (1-s)*m with (m, s) autoregressive.
        Returns (z', log|det|) with log|det| = sum log s."""
        h = jax.nn.relu(
            masked_linear(z, p[f"iaf{k}_w1"], self.mask_in, p[f"iaf{k}_b1"])
        )
        ms = masked_linear(h, p[f"iaf{k}_w2"], self.mask_out, p[f"iaf{k}_b2"])
        m, s_raw = ms[:, : self.z], ms[:, self.z :]
        s = jax.nn.sigmoid(s_raw + 1.0)  # forget-gate bias init trick
        z_new = s * z + (1.0 - s) * m
        ld = jnp.sum(jnp.log(s + 1e-8), axis=-1)
        return z_new, ld

    # --- ELBO --------------------------------------------------------

    def neg_elbo(self, flat, x, eps):
        """x [B,T,88], eps [B,T,z] -> scalar mean -ELBO per timestep."""
        p = self.spec.unflatten(flat)
        B = x.shape[0]

        # backward GRU over reversed x
        h0 = jnp.broadcast_to(p["h0"], (B, self.rnn_h))
        xs_rev = jnp.flip(x, axis=1).transpose(1, 0, 2)  # [T,B,88]

        def gru_scan(h, x_t):
            hn = self._gru_step(p, h, x_t)
            return hn, hn

        _, hs_rev = jax.lax.scan(gru_scan, h0, xs_rev)
        hs = jnp.flip(hs_rev, axis=0)  # h_t aligned with x_t, [T,B,rh]

        z0 = jnp.broadcast_to(p["z0"], (B, self.z))

        def step(z_prev, inp):
            h_t, x_t, eps_t = inp
            q_loc, q_ls = self._combiner(p, z_prev, h_t)
            z_t, _ = gauss_reparam_kl(q_loc, q_ls, eps_t)  # L1 kernel (KL unused here)
            log_q = jnp.sum(
                -0.5 * ((z_t - q_loc) / jnp.exp(q_ls)) ** 2
                - q_ls
                - 0.5 * jnp.log(2.0 * jnp.pi),
                axis=-1,
            )
            for k in range(self.num_iafs):
                z_t, ld = self._iaf(p, k, z_t)
                log_q = log_q - ld
            p_loc, p_ls = self._transition(p, z_prev)
            log_p = jnp.sum(
                -0.5 * ((z_t - p_loc) / jnp.exp(p_ls)) ** 2
                - p_ls
                - 0.5 * jnp.log(2.0 * jnp.pi),
                axis=-1,
            )
            ll = bernoulli_ll(self._emit(p, z_t), x_t)  # L1 kernel
            elbo_t = ll + log_p - log_q
            return z_t, elbo_t

        inps = (hs, x.transpose(1, 0, 2), eps.transpose(1, 0, 2))
        _, elbos = jax.lax.scan(step, z0, inps)
        return -jnp.mean(jnp.sum(elbos, axis=0)) / self.T

    def train_step(self, params, m, v, t, x, eps):
        loss, grads = jax.value_and_grad(self.neg_elbo)(params, x, eps)
        # gradient clipping (the DMM configuration uses ClippedAdam)
        grads = jnp.clip(grads, -10.0, 10.0)
        params, m, v, t = adam_update(params, m, v, t[0], grads, self.lr)
        return params, m, v, jnp.stack([t]), jnp.stack([loss])

    def eval_step(self, params, x, eps):
        return jnp.stack([self.neg_elbo(params, x, eps)])

    def example_args(self):
        P = self.spec.total
        f32 = jnp.float32
        x = jax.ShapeDtypeStruct((self.batch, self.T, self.X_DIM), f32)
        e = jax.ShapeDtypeStruct((self.batch, self.T, self.z), f32)
        pv = jax.ShapeDtypeStruct((P,), f32)
        one = jax.ShapeDtypeStruct((1,), f32)
        return {"init": [], "train": [pv, pv, pv, one, x, e], "eval": [pv, x, e]}

    def manifest(self):
        return {
            "kind": "dmm",
            "P": self.spec.total,
            "batch": self.batch,
            "x_dims": [self.batch, self.T, self.X_DIM],
            "eps_dims": [self.batch, self.T, self.z],
            "z": self.z,
            "T": self.T,
            "num_iafs": self.num_iafs,
            "lr": self.lr,
        }


# ------------------------------------------------------------- registry

def fig3_vaes(batch=128):
    """The four (#z, #h) configurations of paper Figure 3."""
    return [VAE(z, h, batch) for z in (10, 30) for h in (400, 2000)]


def e2e_vae():
    """Small config for the end-to-end training example."""
    return VAE(10, 400, 128)


def fig4_dmms():
    """The 0/1/2-IAF DMM variants of paper Figure 4."""
    return [DMM(num_iafs=k) for k in (0, 1, 2)]
