"""Layer-1 Pallas kernel: fused Gaussian reparameterization + analytic KL.

The paper's VAE hot loop evaluates, per mini-batch row:
    z  = mu + sigma * eps           (reparameterized sample)
    kl = KL(N(mu, sigma) || N(0,I)) (closed form, row-summed)
In the CUDA/PyTorch original this is 5-8 separate elementwise kernel
launches bouncing activations through HBM. On TPU we express it as ONE
Pallas kernel per direction: each (row-block, latent) tile is staged into
VMEM once, both outputs are produced in-register, and only z and the
per-row KL partial leave the core. The backward pass is a second fused
kernel wired in via `jax.custom_vjp` (interpret-mode pallas_call does not
support reverse-mode AD, and a hand-fused VJP is what we'd want on real
hardware anyway).

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  - grid over batch tiles of 128 rows (one MXU-feed block);
  - the latent axis stays whole per block (z = 10/30 in the paper's
    configs), so the KL row-reduction is a single in-VMEM reduce;
  - interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
    custom-calls; structure (not interpreter wallclock) is what carries
    to real hardware.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _fwd_kernel(loc_ref, ls_ref, eps_ref, z_ref, kl_ref):
    loc = loc_ref[...]
    ls = ls_ref[...]
    eps = eps_ref[...]
    z_ref[...] = loc + jnp.exp(ls) * eps
    kl_ref[...] = 0.5 * jnp.sum(
        jnp.exp(2.0 * ls) + loc * loc - 1.0 - 2.0 * ls, axis=-1
    )


def _bwd_kernel(loc_ref, ls_ref, eps_ref, gz_ref, gkl_ref, dloc_ref, dls_ref):
    loc = loc_ref[...]
    ls = ls_ref[...]
    eps = eps_ref[...]
    gz = gz_ref[...]
    gkl = gkl_ref[...][:, None]
    # dz/dloc = 1, dkl/dloc = loc
    dloc_ref[...] = gz + gkl * loc
    # dz/dls = eps*e^ls, dkl/dls = e^{2ls} - 1
    dls_ref[...] = gz * eps * jnp.exp(ls) + gkl * (jnp.exp(2.0 * ls) - 1.0)


def _specs(block_b, zdim):
    mat = pl.BlockSpec((block_b, zdim), lambda i: (i, 0))
    vec = pl.BlockSpec((block_b,), lambda i: (i,))
    return mat, vec


@jax.custom_vjp
def gauss_reparam_kl(loc, log_scale, eps):
    """(loc [B,Z], log_scale [B,Z], eps [B,Z]) -> (z [B,Z], kl [B])."""
    return _fwd(loc, log_scale, eps)


def _fwd(loc, log_scale, eps):
    b, zdim = loc.shape
    block_b = min(BLOCK_B, b)
    assert b % block_b == 0, f"batch {b} not divisible by block {block_b}"
    mat, vec = _specs(block_b, zdim)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(b // block_b,),
        in_specs=[mat, mat, mat],
        out_specs=[mat, vec],
        out_shape=[
            jax.ShapeDtypeStruct((b, zdim), loc.dtype),
            jax.ShapeDtypeStruct((b,), loc.dtype),
        ],
        interpret=True,
    )(loc, log_scale, eps)


def _vjp_fwd(loc, log_scale, eps):
    out = _fwd(loc, log_scale, eps)
    return out, (loc, log_scale, eps)


def _vjp_bwd(res, cot):
    loc, log_scale, eps = res
    gz, gkl = cot
    b, zdim = loc.shape
    block_b = min(BLOCK_B, b)
    mat, vec = _specs(block_b, zdim)
    dloc, dls = pl.pallas_call(
        _bwd_kernel,
        grid=(b // block_b,),
        in_specs=[mat, mat, mat, mat, vec],
        out_specs=[mat, mat],
        out_shape=[
            jax.ShapeDtypeStruct((b, zdim), loc.dtype),
            jax.ShapeDtypeStruct((b, zdim), loc.dtype),
        ],
        interpret=True,
    )(loc, log_scale, eps, gz, gkl)
    # eps is noise: no gradient needed, return zeros for shape agreement
    return dloc, dls, jnp.zeros_like(eps)


gauss_reparam_kl.defvjp(_vjp_fwd, _vjp_bwd)
