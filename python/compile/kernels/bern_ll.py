"""Layer-1 Pallas kernel: fused Bernoulli-logits log-likelihood reduction.

The VAE/DMM decoder ends in `sum_d log Bernoulli(x_d | logits_d)` per
row — on GPU this is a sigmoid-BCE kernel plus a reduction kernel; here
both happen in one VMEM-resident pass using the stable form
    x*l - softplus(l) = x*l - max(l,0) - log1p(exp(-|l|)).
A fused backward kernel (gll ⊙ (x - σ(l))) is attached via custom_vjp.

Tiling: batch rows are blocked at 128; the feature axis (784 for
synthetic-MNIST, 88 for chorales) stays whole per block, so the row
reduction never leaves VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _fwd_kernel(logits_ref, x_ref, ll_ref):
    l = logits_ref[...]
    x = x_ref[...]
    sp = jnp.maximum(l, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(l)))
    ll_ref[...] = jnp.sum(x * l - sp, axis=-1)


def _bwd_kernel(logits_ref, x_ref, gll_ref, dlogits_ref):
    l = logits_ref[...]
    x = x_ref[...]
    g = gll_ref[...][:, None]
    dlogits_ref[...] = g * (x - jax.nn.sigmoid(l))


def _specs(block_b, d):
    mat = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    vec = pl.BlockSpec((block_b,), lambda i: (i,))
    return mat, vec


@jax.custom_vjp
def bernoulli_ll(logits, x):
    """(logits [B,D], x [B,D]) -> ll [B]."""
    return _fwd(logits, x)


def _fwd(logits, x):
    b, d = logits.shape
    block_b = min(BLOCK_B, b)
    assert b % block_b == 0
    mat, vec = _specs(block_b, d)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(b // block_b,),
        in_specs=[mat, mat],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((b,), logits.dtype),
        interpret=True,
    )(logits, x)


def _vjp_fwd(logits, x):
    return _fwd(logits, x), (logits, x)


def _vjp_bwd(res, gll):
    logits, x = res
    b, d = logits.shape
    block_b = min(BLOCK_B, b)
    mat, vec = _specs(block_b, d)
    dlogits = pl.pallas_call(
        _bwd_kernel,
        grid=(b // block_b,),
        in_specs=[mat, mat, vec],
        out_specs=mat,
        out_shape=jax.ShapeDtypeStruct((b, d), logits.dtype),
        interpret=True,
    )(logits, x, gll)
    # x is data: no gradient
    return dlogits, jnp.zeros_like(x)


bernoulli_ll.defvjp(_vjp_fwd, _vjp_bwd)
