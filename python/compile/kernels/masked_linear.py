"""Layer-1 Pallas kernel: MADE masked affine layer (the IAF building block).

Inverse autoregressive flows (Kingma et al. 2016 — the paper's Fig-4
extension) are built from MADE layers: y = x @ (w ⊙ mask) + b where the
binary mask enforces the autoregressive degree ordering. On GPU the mask
is baked into the weights per step; the TPU rendering stages the mask
into VMEM once per tile and fuses the elementwise product into the MXU
feed. Backward uses the same masked products (dw is re-masked, so
gradient never leaks through forbidden connections).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _fwd_kernel(x_ref, w_ref, mask_ref, b_ref, y_ref):
    x = x_ref[...]
    wm = w_ref[...] * mask_ref[...]
    y_ref[...] = x @ wm + b_ref[...]


@jax.custom_vjp
def masked_linear(x, w, mask, b):
    """(x [B,I], w [I,O], mask [I,O], b [O]) -> y [B,O]."""
    return _fwd(x, w, mask, b)


def _fwd(x, w, mask, b):
    bsz, i = x.shape
    o = w.shape[1]
    block_b = min(BLOCK_B, bsz)
    assert bsz % block_b == 0
    return pl.pallas_call(
        _fwd_kernel,
        grid=(bsz // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, i), lambda g: (g, 0)),
            pl.BlockSpec((i, o), lambda g: (0, 0)),
            pl.BlockSpec((i, o), lambda g: (0, 0)),
            pl.BlockSpec((o,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, o), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, o), x.dtype),
        interpret=True,
    )(x, w, mask, b)


def _vjp_fwd(x, w, mask, b):
    return _fwd(x, w, mask, b), (x, w, mask)


def _vjp_bwd(res, gy):
    x, w, mask = res
    wm = w * mask
    dx = gy @ wm.T
    dw = (x.T @ gy) * mask
    db = jnp.sum(gy, axis=0)
    return dx, dw, jnp.zeros_like(mask), db


masked_linear.defvjp(_vjp_fwd, _vjp_bwd)


def made_masks(dim, hidden):
    """Degree-ordered MADE masks for one hidden layer: returns
    (mask_in [dim,hidden], mask_out [hidden,2*dim]) where the output
    produces (m, s) pairs each autoregressive in the input ordering."""
    import numpy as np

    deg_in = np.arange(dim) % dim
    deg_hidden = np.arange(hidden) % max(1, dim - 1)
    mask_in = (deg_hidden[None, :] >= deg_in[:, None]).astype(np.float32)
    deg_out = np.concatenate([np.arange(dim), np.arange(dim)]) % dim
    mask_out = (deg_out[None, :] > deg_hidden[:, None]).astype(np.float32)
    return jnp.asarray(mask_in), jnp.asarray(mask_out)
