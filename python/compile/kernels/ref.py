"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against (pytest +
hypothesis in python/tests/test_kernels.py), and the reference
implementations the L2 model can fall back to (`FYRO_NO_PALLAS=1`).
"""

import jax.numpy as jnp


def gauss_reparam_kl_ref(loc, log_scale, eps):
    """Fused Gaussian reparameterization + analytic KL to N(0, I).

    z = loc + exp(log_scale) * eps
    kl[b] = 0.5 * sum_d(exp(2*ls) + loc^2 - 1 - 2*ls)

    Returns (z [B, Z], kl [B]).
    """
    scale = jnp.exp(log_scale)
    z = loc + scale * eps
    kl = 0.5 * jnp.sum(
        jnp.exp(2.0 * log_scale) + loc * loc - 1.0 - 2.0 * log_scale, axis=-1
    )
    return z, kl


def bernoulli_ll_ref(logits, x):
    """Row-summed Bernoulli log-likelihood from logits.

    ll[b] = sum_d x*l - softplus(l)   (stable in both tails)
    """
    sp = jnp.maximum(logits, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return jnp.sum(x * logits - sp, axis=-1)


def masked_linear_ref(x, w, mask, b):
    """MADE masked affine layer: y = x @ (w * mask) + b."""
    return x @ (w * mask) + b
