"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Python's ONLY role at build time. Each model variant is lowered to three
programs (init / train_step / eval_step) as HLO **text** — the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit
instruction ids), while the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
Writes {name}_{init,train,eval}.hlo.txt plus manifest.json describing
shapes and parameter counts for the Rust side.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import DMM, VAE, e2e_vae, fig3_vaes, fig4_dmms


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(model, out_dir, manifest):
    args = model.example_args()
    jobs = [
        ("init", model.init, args["init"]),
        ("train", model.train_step, args["train"]),
        ("eval", model.eval_step, args["eval"]),
    ]
    for stage, fn, a in jobs:
        path = os.path.join(out_dir, f"{model.name}_{stage}.hlo.txt")
        text = to_hlo_text(fn, a)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {model.name}_{stage}: {len(text) / 1e6:.2f} MB")
    manifest[model.name] = model.manifest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated model names (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    models = fig3_vaes() + fig4_dmms()
    # the e2e example config coincides with vae_z10_h400 (already in fig3)
    assert e2e_vae().name in [m.name for m in models]
    if args.only:
        keep = set(args.only.split(","))
        models = [m for m in models if m.name in keep]

    manifest = {}
    mpath = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)
    for m in models:
        print(f"lowering {m.name} ...")
        lower_model(m, args.out_dir, manifest)
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest)} models)")


if __name__ == "__main__":
    main()
