"""Kernel-vs-reference correctness: the core L1 signal.

Each Pallas kernel is checked against its pure-jnp oracle over a
hypothesis sweep of shapes, magnitudes and dtypes, forward and backward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bern_ll import bernoulli_ll
from compile.kernels.gauss_elbo import gauss_reparam_kl
from compile.kernels.masked_linear import made_masks, masked_linear

# batch sizes must divide the 128-row block or be smaller than it
BATCHES = st.sampled_from([1, 2, 4, 16, 32, 128, 256])
DIMS = st.integers(min_value=1, max_value=64)
SCALES = st.floats(min_value=0.1, max_value=10.0)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ------------------------------------------------------------ gauss_elbo


@settings(max_examples=25, deadline=None)
@given(b=BATCHES, z=DIMS, scale=SCALES)
def test_gauss_fwd_matches_ref(b, z, scale):
    loc = rand(0, (b, z), scale)
    ls = rand(1, (b, z), 0.5)
    eps = rand(2, (b, z))
    z_k, kl_k = gauss_reparam_kl(loc, ls, eps)
    z_r, kl_r = ref.gauss_reparam_kl_ref(loc, ls, eps)
    np.testing.assert_allclose(z_k, z_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(kl_k, kl_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([4, 128]), z=st.integers(2, 32))
def test_gauss_grad_matches_ref(b, z):
    loc = rand(3, (b, z))
    ls = rand(4, (b, z), 0.3)
    eps = rand(5, (b, z))

    def k(loc, ls):
        zz, kl = gauss_reparam_kl(loc, ls, eps)
        return jnp.sum(jnp.tanh(zz)) + jnp.sum(kl)

    def r(loc, ls):
        zz, kl = ref.gauss_reparam_kl_ref(loc, ls, eps)
        return jnp.sum(jnp.tanh(zz)) + jnp.sum(kl)

    gk = jax.grad(k, argnums=(0, 1))(loc, ls)
    gr = jax.grad(r, argnums=(0, 1))(loc, ls)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-4)


def test_gauss_kl_zero_at_standard_normal():
    loc = jnp.zeros((4, 8))
    ls = jnp.zeros((4, 8))
    eps = rand(6, (4, 8))
    _, kl = gauss_reparam_kl(loc, ls, eps)
    np.testing.assert_allclose(kl, jnp.zeros(4), atol=1e-6)


def test_gauss_kl_nonnegative_property():
    for seed in range(20):
        loc = rand(seed, (16, 8), 3.0)
        ls = rand(seed + 100, (16, 8), 1.0)
        _, kl = gauss_reparam_kl(loc, ls, rand(seed + 200, (16, 8)))
        assert (np.asarray(kl) >= -1e-5).all()


# -------------------------------------------------------------- bern_ll


@settings(max_examples=25, deadline=None)
@given(b=BATCHES, d=DIMS, scale=SCALES)
def test_bern_fwd_matches_ref(b, d, scale):
    logits = rand(7, (b, d), scale)
    x = (jax.random.uniform(jax.random.PRNGKey(8), (b, d)) < 0.3).astype(jnp.float32)
    np.testing.assert_allclose(
        bernoulli_ll(logits, x), ref.bernoulli_ll_ref(logits, x), rtol=1e-4, atol=1e-4
    )


def test_bern_extreme_logits_stable():
    logits = jnp.array([[1000.0, -1000.0, 0.0, 50.0]])
    x = jnp.array([[1.0, 0.0, 1.0, 0.0]])
    out = np.asarray(bernoulli_ll(logits, x))
    assert np.isfinite(out).all()
    # ll = 0 + 0 + ln(1/2) - 50
    np.testing.assert_allclose(out[0], np.log(0.5) - 50.0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([2, 128]), d=st.integers(2, 64))
def test_bern_grad_matches_ref(b, d):
    logits = rand(9, (b, d), 2.0)
    x = (jax.random.uniform(jax.random.PRNGKey(10), (b, d)) < 0.5).astype(jnp.float32)
    gk = jax.grad(lambda l: jnp.sum(bernoulli_ll(l, x) ** 2))(logits)
    gr = jax.grad(lambda l: jnp.sum(ref.bernoulli_ll_ref(l, x) ** 2))(logits)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


def test_bern_matches_logpmf():
    # cross-check against explicit bernoulli pmf on probabilities
    p = 0.73
    logits = jnp.full((1, 1), np.log(p / (1 - p)), jnp.float32)
    for x, want in [(1.0, np.log(p)), (0.0, np.log(1 - p))]:
        out = bernoulli_ll(logits, jnp.full((1, 1), x, jnp.float32))
        np.testing.assert_allclose(np.asarray(out)[0], want, rtol=1e-5)


# -------------------------------------------------------- masked_linear


@settings(max_examples=20, deadline=None)
@given(b=st.sampled_from([1, 4, 128]), i=DIMS, o=DIMS)
def test_masked_linear_matches_ref(b, i, o):
    x = rand(11, (b, i))
    w = rand(12, (i, o), 0.3)
    mask = (jax.random.uniform(jax.random.PRNGKey(13), (i, o)) < 0.5).astype(jnp.float32)
    bias = rand(14, (o,))
    np.testing.assert_allclose(
        masked_linear(x, w, mask, bias),
        ref.masked_linear_ref(x, w, mask, bias),
        rtol=1e-4,
        atol=1e-4,
    )


def test_masked_linear_grad_respects_mask():
    # gradient w.r.t. w must be exactly zero where mask is zero
    i, o = 6, 10
    x = rand(15, (4, i))
    w = rand(16, (i, o), 0.3)
    mask = (jax.random.uniform(jax.random.PRNGKey(17), (i, o)) < 0.5).astype(jnp.float32)
    bias = jnp.zeros(o)
    g = jax.grad(lambda w: jnp.sum(masked_linear(x, w, mask, bias) ** 2))(w)
    assert (np.asarray(g)[np.asarray(mask) == 0.0] == 0.0).all()


def test_made_masks_autoregressive_property():
    """Composed MADE masks must make output d depend only on inputs < d."""
    dim, hidden = 8, 32
    mi, mo = made_masks(dim, hidden)
    # connectivity: (mi @ mo) > 0 means input i reaches output j
    conn = np.asarray(mi) @ np.asarray(mo)  # [dim, 2*dim]
    for j in range(2 * dim):
        d = j % dim
        for i in range(dim):
            if i >= d:
                assert conn[i, j] == 0.0, f"input {i} leaks into output deg {d}"


def test_iaf_flow_is_invertible_triangular():
    """The Jacobian dz'/dz of one IAF step must be lower-triangular with
    the gate on the diagonal (so logdet = sum log s)."""
    dim, hidden = 5, 16
    mi, mo = made_masks(dim, hidden)
    w1 = rand(18, (dim, hidden), 0.5)
    b1 = jnp.zeros(hidden)
    w2 = rand(19, (hidden, 2 * dim), 0.5)
    b2 = jnp.zeros(2 * dim)

    def flow(z):
        h = jax.nn.relu(masked_linear(z[None, :], w1, mi, b1))
        ms = masked_linear(h, w2, mo, b2)[0]
        m, s_raw = ms[:dim], ms[dim:]
        s = jax.nn.sigmoid(s_raw + 1.0)
        return s * z + (1.0 - s) * m

    z = rand(20, (dim,))
    J = np.asarray(jax.jacrev(flow)(z))
    assert np.allclose(np.triu(J, 1), 0.0, atol=1e-6), "Jacobian not triangular"
    assert (np.diag(J) > 0).all(), "non-positive diagonal"
