//! Neural-network modules on the autodiff substrate — Fyro's `torch.nn`.
//!
//! Modules are lightweight descriptors; their parameters live in the
//! global [`ParamStore`](crate::params::ParamStore) under
//! `"{module}.{field}"` names (mirroring `pyro.module`, which registers
//! every parameter of a `torch.nn.Module` with `pyro.param`). Forward
//! passes take the [`Ctx`] so parameter leaves join the current tape.
//!
//! Initialization is deterministic per parameter name (seeded from a
//! name hash), so runs are reproducible without threading an RNG into
//! init closures.

use crate::autodiff::Var;
use crate::poutine::Ctx;
use crate::tensor::{Pcg64, Tensor};

/// Deterministic per-name seed for reproducible initialization.
fn name_seed(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Xavier/Glorot-uniform init.
fn xavier(dims: &[usize], fan_in: usize, fan_out: usize, name: &str) -> Tensor {
    let mut rng = Pcg64::new(name_seed(name));
    let bound = (6.0 / (fan_in + fan_out) as f64).sqrt();
    Tensor::rand(dims.to_vec(), &mut rng)
        .mul_scalar(2.0 * bound)
        .add_scalar(-bound)
}

/// Activation functions for [`Mlp`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Tanh,
    Sigmoid,
    Softplus,
    Identity,
}

impl Activation {
    pub fn apply(&self, x: &Var) -> Var {
        match self {
            Activation::Relu => x.relu(),
            Activation::Tanh => x.tanh(),
            Activation::Sigmoid => x.sigmoid(),
            Activation::Softplus => x.softplus(),
            Activation::Identity => x.clone(),
        }
    }
}

/// Affine layer: y = x W + b, with x [n, in] (or [in]).
#[derive(Clone, Debug)]
pub struct Linear {
    pub name: String,
    pub in_dim: usize,
    pub out_dim: usize,
}

impl Linear {
    pub fn new(name: impl Into<String>, in_dim: usize, out_dim: usize) -> Self {
        Linear { name: name.into(), in_dim, out_dim }
    }

    pub fn forward(&self, ctx: &mut Ctx, x: &Var) -> Var {
        let (i, o) = (self.in_dim, self.out_dim);
        let wname = format!("{}.w", self.name);
        let w = ctx.param(&wname, || xavier(&[i, o], i, o, &wname));
        let b = ctx.param(&format!("{}.b", self.name), || Tensor::zeros(vec![o]));
        let x2 = if x.dims().len() == 1 { x.reshape(vec![1, i]) } else { x.clone() };
        let y = x2.matmul(&w).add(&b);
        if x.dims().len() == 1 {
            y.reshape(vec![o])
        } else {
            y
        }
    }

    pub fn n_params(&self) -> usize {
        self.in_dim * self.out_dim + self.out_dim
    }
}

/// Multi-layer perceptron with a shared hidden activation and a final
/// (optionally different) output activation.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Linear>,
    pub hidden_act: Activation,
    pub out_act: Activation,
}

impl Mlp {
    /// `dims` = [in, h1, ..., out].
    pub fn new(name: &str, dims: &[usize], hidden_act: Activation, out_act: Activation) -> Self {
        assert!(dims.len() >= 2);
        let layers = (0..dims.len() - 1)
            .map(|i| Linear::new(format!("{name}.l{i}"), dims[i], dims[i + 1]))
            .collect();
        Mlp { layers, hidden_act, out_act }
    }

    pub fn forward(&self, ctx: &mut Ctx, x: &Var) -> Var {
        let mut h = x.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            h = layer.forward(ctx, &h);
            h = if i + 1 == self.layers.len() {
                self.out_act.apply(&h)
            } else {
                self.hidden_act.apply(&h)
            };
        }
        h
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(Linear::n_params).sum()
    }
}

/// Gated recurrent unit cell (Cho et al. 2014), the recurrence used by
/// the DMM's inference network.
#[derive(Clone, Debug)]
pub struct GruCell {
    pub name: String,
    pub in_dim: usize,
    pub hidden: usize,
}

impl GruCell {
    pub fn new(name: impl Into<String>, in_dim: usize, hidden: usize) -> Self {
        GruCell { name: name.into(), in_dim, hidden }
    }

    /// One step: (x [n, in], h [n, hidden]) -> h' [n, hidden].
    pub fn forward(&self, ctx: &mut Ctx, x: &Var, h: &Var) -> Var {
        let (i, hd) = (self.in_dim, self.hidden);
        let wi_name = format!("{}.w_ih", self.name);
        let wh_name = format!("{}.w_hh", self.name);
        let w_ih = ctx.param(&wi_name, || xavier(&[i, 3 * hd], i, hd, &wi_name));
        let w_hh = ctx.param(&wh_name, || xavier(&[hd, 3 * hd], hd, hd, &wh_name));
        let b_ih = ctx.param(&format!("{}.b_ih", self.name), || Tensor::zeros(vec![3 * hd]));
        let b_hh = ctx.param(&format!("{}.b_hh", self.name), || Tensor::zeros(vec![3 * hd]));

        let gi = x.matmul(&w_ih).add(&b_ih);
        let gh = h.matmul(&w_hh).add(&b_hh);
        let (i_r, i_z, i_n) =
            (gi.narrow_last(0, hd), gi.narrow_last(hd, hd), gi.narrow_last(2 * hd, hd));
        let (h_r, h_z, h_n) =
            (gh.narrow_last(0, hd), gh.narrow_last(hd, hd), gh.narrow_last(2 * hd, hd));

        let r = i_r.add(&h_r).sigmoid();
        let z = i_z.add(&h_z).sigmoid();
        let n = i_n.add(&r.mul(&h_n)).tanh();
        // h' = (1 - z) * n + z * h
        z.neg().add_scalar(1.0).mul(&n).add(&z.mul(h))
    }

    pub fn n_params(&self) -> usize {
        3 * self.hidden * (self.in_dim + self.hidden + 2)
    }
}

/// Embedding table: index rows of a [vocab, dim] matrix.
#[derive(Clone, Debug)]
pub struct Embedding {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
}

impl Embedding {
    pub fn new(name: impl Into<String>, vocab: usize, dim: usize) -> Self {
        Embedding { name: name.into(), vocab, dim }
    }

    pub fn forward(&self, ctx: &mut Ctx, idx: &[usize]) -> Var {
        let (v, d) = (self.vocab, self.dim);
        let tname = format!("{}.table", self.name);
        let table = ctx.param(&tname, || xavier(&[v, d], v, d, &tname));
        // one-hot matmul keeps gradients simple and exact
        let mut oh = Tensor::zeros(vec![idx.len(), v]);
        {
            let data = oh.data_mut();
            for (r, &i) in idx.iter().enumerate() {
                assert!(i < v, "embedding index {i} out of range {v}");
                data[r * v + i] = 1.0;
            }
        }
        table.tape().constant(oh).matmul(&table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;

    #[test]
    fn linear_shapes_and_registration() {
        let mut rng = Pcg64::new(1);
        let mut store = ParamStore::new();
        let mut ctx = Ctx::with_store(&mut rng, &mut store);
        let lin = Linear::new("enc", 4, 3);
        let x = ctx.c(Tensor::ones(vec![2, 4]));
        let y = lin.forward(&mut ctx, &x);
        assert_eq!(y.dims(), &[2, 3]);
        drop(ctx);
        assert!(store.contains("enc.w"));
        assert!(store.contains("enc.b"));
        assert_eq!(store.numel(), lin.n_params());
    }

    #[test]
    fn linear_vector_input() {
        let mut rng = Pcg64::new(2);
        let mut store = ParamStore::new();
        let mut ctx = Ctx::with_store(&mut rng, &mut store);
        let lin = Linear::new("v", 4, 3);
        let x = ctx.c(Tensor::ones(vec![4]));
        let y = lin.forward(&mut ctx, &x);
        assert_eq!(y.dims(), &[3]);
    }

    #[test]
    fn init_is_deterministic() {
        let a = xavier(&[3, 4], 3, 4, "m.w");
        let b = xavier(&[3, 4], 3, 4, "m.w");
        assert!(a.allclose(&b, 0.0));
        let c = xavier(&[3, 4], 3, 4, "other.w");
        assert!(!a.allclose(&c, 1e-6));
    }

    #[test]
    fn mlp_forward_and_grads() {
        let mut rng = Pcg64::new(3);
        let mut store = ParamStore::new();
        let mut ctx = Ctx::with_store(&mut rng, &mut store);
        let mlp = Mlp::new("net", &[5, 8, 2], Activation::Tanh, Activation::Identity);
        let x = ctx.c(Tensor::ones(vec![3, 5]));
        let y = mlp.forward(&mut ctx, &x);
        assert_eq!(y.dims(), &[3, 2]);
        let loss = y.square().sum();
        let trace = ctx.into_trace();
        let leaves: Vec<_> = trace.param_leaves.values().collect();
        let grads = loss.tape().grad(&loss, &leaves);
        // all parameter gradients exist and at least one is nonzero
        assert_eq!(grads.len(), 4);
        assert!(grads.iter().any(|g| g.abs().sum() > 0.0));
    }

    #[test]
    fn gru_cell_step_shapes_and_bounds() {
        let mut rng = Pcg64::new(4);
        let mut store = ParamStore::new();
        let mut ctx = Ctx::with_store(&mut rng, &mut store);
        let gru = GruCell::new("rnn", 6, 4);
        let x = ctx.c(Tensor::ones(vec![2, 6]));
        let h = ctx.c(Tensor::zeros(vec![2, 4]));
        let h1 = gru.forward(&mut ctx, &x, &h);
        assert_eq!(h1.dims(), &[2, 4]);
        // GRU output bounded by tanh range
        for &v in h1.value().data() {
            assert!(v.abs() <= 1.0 + 1e-9);
        }
        drop(ctx);
        assert_eq!(store.numel(), gru.n_params());
    }

    #[test]
    fn gru_gradient_flows_through_time() {
        let mut rng = Pcg64::new(5);
        let mut store = ParamStore::new();
        let mut ctx = Ctx::with_store(&mut rng, &mut store);
        let gru = GruCell::new("rnn", 3, 4);
        let x = ctx.c(Tensor::ones(vec![1, 3]));
        let mut h = ctx.c(Tensor::zeros(vec![1, 4]));
        for _ in 0..5 {
            h = gru.forward(&mut ctx, &x, &h);
        }
        let loss = h.square().sum();
        let trace = ctx.into_trace();
        let leaf = &trace.param_leaves["rnn.w_ih"];
        let g = loss.tape().grad(&loss, &[leaf]).remove(0);
        assert!(g.abs().sum() > 0.0);
    }

    #[test]
    fn embedding_rows() {
        let mut rng = Pcg64::new(6);
        let mut store = ParamStore::new();
        let mut ctx = Ctx::with_store(&mut rng, &mut store);
        let emb = Embedding::new("emb", 10, 3);
        let e = emb.forward(&mut ctx, &[2, 2, 7]);
        assert_eq!(e.dims(), &[3, 3]);
        // same index -> same row
        let d = e.value();
        for j in 0..3 {
            assert_eq!(d.at(&[0, j]), d.at(&[1, j]));
        }
    }
}
