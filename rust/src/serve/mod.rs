//! Multi-tenant inference serving over frozen model snapshots.
//!
//! The Edward2 observation (PAPERS.md): once trained, a probabilistic
//! program is just a tensor function — it can be versioned, replicated,
//! and batched like any other model artifact. This module is that
//! serving layer for fyro, built from the same zero-dependency parts as
//! the rest of the crate (std threads + bounded mpsc, exactly like
//! [`crate::coordinator::train_async`]):
//!
//! - [`FrozenModel`] — an immutable (model, guide, [`ParamStore`])
//!   snapshot. Serving never mutates parameters, *enforced by type*:
//!   every serve-path evaluation runs on
//!   [`Ctx::with_frozen_store`], where a `ctx.param` miss panics with
//!   `[FY016]` instead of silently initializing. Each frozen model
//!   lazily compiles and caches one [`CompiledProgram`] for its ELBO
//!   score (reused across every request), fingerprint-guarded with a
//!   loud dynamic fallback exactly like `Svi` graph mode.
//! - [`Registry`] — version-keyed, hot-swappable model catalog.
//!   Registering `v+1` never disturbs in-flight requests: admission
//!   pins the `Arc<FrozenModel>` it resolved, so old requests finish on
//!   the version they were admitted against.
//! - [`Server`] — bounded admission queue → batching dispatcher →
//!   worker pool. The dispatcher coalesces up to
//!   [`ServeConfig::max_batch`] requests (waiting at most
//!   [`ServeConfig::max_wait_us`]) and groups them by (model, version)
//!   so a worker serves a whole same-version batch with warm
//!   compiled-program arenas and one dispatch/lock round per batch
//!   instead of per request. A full queue rejects with
//!   [`ServeError::Overloaded`] — backpressure, never unbounded growth.
//!
//! # Determinism contract
//!
//! Every request carries its own seed, and every evaluation runs on a
//! private `Pcg64::new(seed)` stream. Batching therefore changes *when*
//! a request runs, never *what* it computes: a request's response is
//! bitwise identical whether it was served solo, inside any batch, by
//! any worker, at any pool size (the PR 1/7 merge discipline applied to
//! serving). Cross-request tensor fusion is deliberately **not**
//! attempted — it would thread one RNG stream through all coalesced
//! requests and break this contract; within a request, vectorized
//! plates ([`Ctx::plate_idx`]) already carry the tensorization.
//!
//! Telemetry: `requests_served` / `requests_rejected` /
//! `batches_dispatched` counters and `request_ns` / `batch_fill` /
//! `queue_wait_ns` histograms via [`crate::telemetry`], plus structured
//! `serve_graph_fallback` / `serve_overloaded` warn events.

pub mod loadgen;

use crate::coordinator;
use crate::error::{Error, Result};
use crate::infer::compile::{Arena, CompiledProgram, Recorded};
use crate::infer::elbo::{Elbo, ParticleCtx, TraceElbo};
use crate::infer::Predictive;
use crate::params::ParamStore;
use crate::poutine::{handlers, Ctx};
use crate::telemetry::{self, Counter, Hist, WarnKind};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Model/guide closures a frozen model owns. `Send + Sync` because one
/// frozen model is shared by every worker thread.
pub type ServeModelFn = dyn Fn(&mut Ctx) + Send + Sync;

// ------------------------------------------------------------ FrozenModel

/// Compiled-program cache state for one frozen model.
enum GraphSlot {
    /// Not attempted yet (compiled lazily on the first Score request).
    Pending,
    Ready(Arc<CompiledProgram>),
    /// Compilation failed for a structural reason; pinned dynamic.
    Disabled,
}

/// An immutable, version-keyed (model, guide, params) snapshot.
///
/// The store is read-only for the lifetime of the value — every
/// evaluation path goes through [`Ctx::with_frozen_store`] or
/// [`CompiledProgram::run_step`], both of which take `&ParamStore`.
pub struct FrozenModel {
    name: String,
    version: u64,
    model: Box<ServeModelFn>,
    guide: Box<ServeModelFn>,
    store: ParamStore,
    fingerprint: u64,
    graph: Mutex<GraphSlot>,
}

impl FrozenModel {
    /// Freeze a trained (model, guide, store) triple.
    ///
    /// Runs one probe (guide → replayed model) against a *clone* of the
    /// store and fails if the probe changed the store's structural
    /// fingerprint — i.e. if the pair touches any parameter the
    /// snapshot does not carry. Missing params therefore fail loudly at
    /// registration, not mid-request with `[FY016]`.
    pub fn freeze(
        name: &str,
        version: u64,
        model: Box<ServeModelFn>,
        guide: Box<ServeModelFn>,
        store: ParamStore,
    ) -> Result<Arc<FrozenModel>> {
        let fingerprint = store.fingerprint();
        {
            let mut probe = store.clone();
            let mut rng = Pcg64::new(0x5EED_F00D);
            let mut gctx = Ctx::with_store(&mut rng, &mut probe);
            guide(&mut gctx);
            let tape = gctx.tape.clone();
            let guide_trace = gctx.into_trace();
            let replayed = handlers::replay(&*model, guide_trace);
            let mut mctx = Ctx::with_store_on_tape(tape, &mut rng, &mut probe);
            replayed(&mut mctx);
            let _ = mctx.into_trace();
            if probe.fingerprint() != fingerprint {
                return Err(Error::msg(format!(
                    "cannot freeze '{name}' v{version}: the model/guide pair \
                     initialized params missing from the snapshot — train and \
                     re-snapshot before freezing"
                )));
            }
        }
        Ok(Arc::new(FrozenModel {
            name: name.to_string(),
            version,
            model,
            guide,
            store,
            fingerprint,
            graph: Mutex::new(GraphSlot::Pending),
        }))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn store(&self) -> &ParamStore {
        &self.store
    }

    /// Structural fingerprint of the frozen store (see
    /// [`ParamStore::fingerprint`]).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Posterior-predictive draw for `sites`, stacked with a leading
    /// `[num_samples]` dim. Runs on a private `Pcg64::new(seed)` — the
    /// solo-request reference the batched path must match bitwise.
    pub fn predict(
        &self,
        seed: u64,
        num_samples: usize,
        sites: &[&str],
    ) -> HashMap<String, Tensor> {
        let mut rng = Pcg64::new(seed);
        Predictive::new(num_samples).run_stacked(
            &*self.model,
            &*self.guide,
            &self.store,
            &mut rng,
            sites,
        )
    }

    /// [`FrozenModel::predict`] into caller-owned slabs (see
    /// [`Predictive::run_stacked_into`]).
    pub fn predict_into(
        &self,
        seed: u64,
        num_samples: usize,
        sites: &[&str],
        out: &mut HashMap<String, Tensor>,
    ) {
        let mut rng = Pcg64::new(seed);
        Predictive::new(num_samples).run_stacked_into(
            &*self.model,
            &*self.guide,
            &self.store,
            &mut rng,
            sites,
            out,
        );
    }

    /// One-particle ELBO loss (−ELBO, the `Svi::evaluate_loss`
    /// convention) on the dynamic interpreter — the semantics oracle
    /// the compiled path is held to.
    pub fn score_dynamic(&self, seed: u64) -> f64 {
        let elbo = TraceElbo::default();
        let snapshot = elbo.snapshot();
        let mut rng = Pcg64::new(seed);
        let mut gctx = Ctx::with_frozen_store(&mut rng, &self.store);
        (self.guide)(&mut gctx);
        let tape = gctx.tape.clone();
        let guide_trace = gctx.into_trace();
        let replayed = handlers::replay(&*self.model, guide_trace.clone());
        let mut mctx = Ctx::with_frozen_store_on_tape(tape, &mut rng, &self.store);
        replayed(&mut mctx);
        let model_trace = mctx.into_trace();
        let mut pctx = ParticleCtx::new(&snapshot);
        let (_loss, value) = elbo
            .differentiable_loss(&model_trace, &guide_trace, &mut pctx)
            .expect("frozen model produced an empty trace");
        -value
    }

    /// ELBO loss via the compiled program when available, dynamic
    /// otherwise; returns `(loss, used_compiled_path)`. Both paths
    /// consume a fresh `Pcg64::new(seed)` identically (pinned by
    /// [`CompiledProgram::verify`] at compile time), so they agree to
    /// float round-off.
    pub fn score_with(&self, seed: u64, cache: &mut ArenaCache) -> (f64, bool) {
        if let Some(prog) = self.compiled() {
            if prog.store_fp == self.store.fingerprint() {
                let arena = cache.arena(&self.name, self.version, &prog);
                let mut rng = Pcg64::new(seed);
                let value = prog.run_step(arena, &self.store, &mut rng);
                return (-value, true);
            }
            // Unreachable on an immutable store, but keep the guard as
            // loud as Svi graph mode rather than trusting immutability.
            telemetry::count(Counter::GraphFallbacks);
            telemetry::warn(
                WarnKind::ServeGraphFallback,
                &format!(
                    "'{}' v{}: store fingerprint drifted under a frozen model; \
                     serving dynamically",
                    self.name, self.version
                ),
            );
        }
        (self.score_dynamic(seed), false)
    }

    /// The cached compiled program, compiling on first use. `None` once
    /// compilation is pinned off (inherently dynamic model, verify
    /// mismatch) — callers then stay on [`FrozenModel::score_dynamic`].
    fn compiled(&self) -> Option<Arc<CompiledProgram>> {
        let mut slot = self.graph.lock().unwrap();
        match &*slot {
            GraphSlot::Ready(p) => Some(p.clone()),
            GraphSlot::Disabled => None,
            GraphSlot::Pending => match self.try_compile() {
                Ok(prog) => {
                    telemetry::count(Counter::GraphCompiles);
                    let p = Arc::new(prog);
                    *slot = GraphSlot::Ready(p.clone());
                    Some(p)
                }
                Err(e) => {
                    telemetry::count(Counter::GraphDisables);
                    telemetry::warn(
                        WarnKind::ServeGraphFallback,
                        &format!(
                            "'{}' v{}: pinned on the dynamic path: {e}",
                            self.name, self.version
                        ),
                    );
                    *slot = GraphSlot::Disabled;
                    None
                }
            },
        }
    }

    fn try_compile(&self) -> Result<CompiledProgram> {
        let elbo = TraceElbo::default();
        let snapshot = elbo.snapshot();
        // record_particle needs a mutable store; the recording store is
        // a clone, and freeze() guarantees it gains no entries, so the
        // recorded store_fp equals the frozen fingerprint.
        let mut probe = self.store.clone();
        let seed = 0x5EED_0001 ^ self.version;
        let (rec, _dynamic_out) = crate::infer::compile::record_particle(
            seed,
            &mut probe,
            &*self.model,
            &*self.guide,
            &elbo,
            &snapshot,
        )?;
        let rec = match rec {
            Recorded::Ready(r) => r,
            Recorded::Inherent(why) => return Err(Error::msg(why)),
        };
        let prog = CompiledProgram::compile(&rec)?;
        prog.verify(&self.store, &rec, seed)?;
        Ok(prog)
    }
}

/// Per-worker cache of compiled-program arenas, keyed by (model,
/// version). Arenas are the mutable scratch of a compiled run; caching
/// one per served version keeps repeat Score requests off the
/// allocator entirely.
#[derive(Default)]
pub struct ArenaCache {
    entries: Vec<((String, u64), Arena)>,
}

impl ArenaCache {
    pub fn new() -> Self {
        ArenaCache::default()
    }

    fn arena(&mut self, name: &str, version: u64, prog: &CompiledProgram) -> &mut Arena {
        if let Some(pos) =
            self.entries.iter().position(|((n, v), _)| n == name && *v == version)
        {
            return &mut self.entries[pos].1;
        }
        self.entries.push(((name.to_string(), version), Arena::new(prog)));
        &mut self.entries.last_mut().expect("just pushed").1
    }
}

// --------------------------------------------------------------- Registry

/// Version-keyed catalog of frozen models, hot-swappable while a
/// [`Server`] is running: `register` of a newer version atomically
/// becomes the default for new requests, while requests admitted
/// earlier keep the `Arc` they resolved.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<HashMap<String, Vec<Arc<FrozenModel>>>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Add a frozen model. Duplicate (name, version) pairs are an error
    /// — versions are immutable once registered.
    pub fn register(&self, fm: Arc<FrozenModel>) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let versions = inner.entry(fm.name().to_string()).or_default();
        if versions.iter().any(|m| m.version() == fm.version()) {
            return Err(Error::msg(format!(
                "model '{}' v{} is already registered (versions are immutable; \
                 bump the version to hot-swap)",
                fm.name(),
                fm.version()
            )));
        }
        versions.push(fm);
        versions.sort_by_key(|m| m.version());
        Ok(())
    }

    /// Resolve a model: a specific version, or the latest when `None`.
    pub fn get(&self, name: &str, version: Option<u64>) -> Option<Arc<FrozenModel>> {
        let inner = self.inner.lock().unwrap();
        let versions = inner.get(name)?;
        match version {
            Some(v) => versions.iter().find(|m| m.version() == v).cloned(),
            None => versions.last().cloned(),
        }
    }

    /// Registered versions of `name`, ascending.
    pub fn versions(&self, name: &str) -> Vec<u64> {
        let inner = self.inner.lock().unwrap();
        inner
            .get(name)
            .map(|v| v.iter().map(|m| m.version()).collect())
            .unwrap_or_default()
    }

    /// Load a `FYSNAP01` snapshot from disk
    /// ([`coordinator::load_snapshot`] — fingerprint-validated), freeze
    /// it against the given closures, and register it.
    pub fn load_frozen(
        &self,
        path: &str,
        model: Box<ServeModelFn>,
        guide: Box<ServeModelFn>,
    ) -> Result<Arc<FrozenModel>> {
        let snap = coordinator::load_snapshot(path)?;
        let fm = FrozenModel::freeze(&snap.name, snap.version, model, guide, snap.store)?;
        self.register(fm.clone())?;
        Ok(fm)
    }
}

// ------------------------------------------------------- request/response

/// What a request asks of a frozen model.
#[derive(Clone, Debug)]
pub enum Query {
    /// Posterior-predictive draw: `num_samples` stacked samples of each
    /// named site (see [`FrozenModel::predict`]).
    Predictive { num_samples: usize, sites: Vec<String> },
    /// One-particle ELBO loss (compiled when possible).
    Score,
}

/// A posterior query against a registered model.
#[derive(Clone, Debug)]
pub struct Request {
    pub model: String,
    /// Specific version, or `None` for the latest at admission time.
    pub version: Option<u64>,
    /// Per-request RNG seed — the whole determinism contract hangs off
    /// this being private to the request.
    pub seed: u64,
    pub query: Query,
}

#[derive(Clone, Debug)]
pub enum Response {
    Predictive(HashMap<String, Tensor>),
    Score { loss: f64, compiled: bool },
}

/// Serving failures. `Overloaded` is the backpressure signal: the
/// admission queue is full and the request was NOT accepted — retry or
/// shed load. Accepted work is never dropped.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    Overloaded,
    UnknownModel(String),
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "serve queue full (backpressure)"),
            ServeError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

// ----------------------------------------------------------------- Server

/// Worker-pool shape and batching knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Evaluation threads (≥ 1).
    pub num_workers: usize,
    /// Most requests one dispatched batch may coalesce (≥ 1).
    pub max_batch: usize,
    /// How long the dispatcher waits to fill a batch once it holds at
    /// least one request. 0 disables coalescing (every request is its
    /// own batch).
    pub max_wait_us: u64,
    /// Bound on the admission queue; a full queue rejects with
    /// [`ServeError::Overloaded`].
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { num_workers: 2, max_batch: 16, max_wait_us: 200, queue_depth: 256 }
    }
}

type ReplyResult = std::result::Result<Response, ServeError>;

/// One admitted request in flight: the pinned model, the query, and the
/// oneshot-style reply channel (capacity 1, so the worker's send never
/// blocks; an abandoned `Pending` just drops the receiver).
struct Envelope {
    fm: Arc<FrozenModel>,
    seed: u64,
    query: Query,
    enqueued: Instant,
    reply: SyncSender<ReplyResult>,
}

/// Handle to an admitted request. [`Pending::wait`] blocks for the
/// response; dropping it abandons the result (the work still runs).
pub struct Pending {
    rx: Receiver<ReplyResult>,
}

impl Pending {
    pub fn wait(self) -> ReplyResult {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }
}

/// Batching, backpressured serving front-end over a [`Registry`].
///
/// Thread layout: N clients → bounded admission queue →
/// `fyro-serve-dispatch` (coalesces + groups by version) → bounded
/// batch queue → `fyro-serve-{i}` workers. Shutdown drops the admission
/// sender and joins everything; mpsc guarantees already-buffered
/// envelopes drain first, so accepted work is never dropped.
pub struct Server {
    req_tx: Option<SyncSender<Envelope>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    registry: Arc<Registry>,
    overload_warned: AtomicBool,
}

impl Server {
    pub fn start(registry: Arc<Registry>, config: ServeConfig) -> Server {
        let (req_tx, req_rx) = mpsc::sync_channel::<Envelope>(config.queue_depth.max(1));
        let num_workers = config.num_workers.max(1);
        let (batch_tx, batch_rx) = mpsc::sync_channel::<Vec<Envelope>>(num_workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let max_batch = config.max_batch.max(1);
        let max_wait = Duration::from_micros(config.max_wait_us);
        let dispatcher = std::thread::Builder::new()
            .name("fyro-serve-dispatch".to_string())
            .spawn(move || dispatch_loop(req_rx, batch_tx, max_batch, max_wait))
            .expect("spawn serve dispatcher");
        let mut workers = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let rx = batch_rx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fyro-serve-{w}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn serve worker"),
            );
        }
        Server {
            req_tx: Some(req_tx),
            dispatcher: Some(dispatcher),
            workers,
            registry,
            overload_warned: AtomicBool::new(false),
        }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Try to admit a request. Non-blocking: a full queue returns
    /// [`ServeError::Overloaded`] immediately (counted, and warned once
    /// per server via `serve_overloaded`). The model version is pinned
    /// here, at admission — a hot-swap after this point does not move
    /// the request.
    pub fn submit(&self, req: Request) -> std::result::Result<Pending, ServeError> {
        let fm = self.registry.get(&req.model, req.version).ok_or_else(|| {
            ServeError::UnknownModel(match req.version {
                Some(v) => format!("{} v{v}", req.model),
                None => req.model.clone(),
            })
        })?;
        let tx = self.req_tx.as_ref().ok_or(ServeError::ShuttingDown)?;
        let (reply_tx, reply_rx) = mpsc::sync_channel::<ReplyResult>(1);
        let env = Envelope {
            fm,
            seed: req.seed,
            query: req.query,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        match tx.try_send(env) {
            Ok(()) => Ok(Pending { rx: reply_rx }),
            Err(TrySendError::Full(_)) => {
                telemetry::count(Counter::RequestsRejected);
                if !self.overload_warned.swap(true, Ordering::Relaxed) {
                    telemetry::warn(
                        WarnKind::ServeOverloaded,
                        "admission queue full; rejecting with Overloaded (counted \
                         per request, warned once per server)",
                    );
                }
                Err(ServeError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::ShuttingDown),
        }
    }

    /// Admit and wait: the closed-loop client call.
    pub fn serve(&self, req: Request) -> ReplyResult {
        self.submit(req)?.wait()
    }

    /// Graceful shutdown: stop admitting, then drain — every already
    /// admitted request is served before the threads exit. Dropping the
    /// server does the same.
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        // Closing the admission sender lets the dispatcher consume the
        // buffered envelopes and then see Disconnected; it closes the
        // batch channel in turn, and the workers finish what's queued.
        drop(self.req_tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Coalesce admitted requests into batches: block for the first, then
/// keep draining until `max_batch` or the `max_wait` deadline, then
/// split the drain into same-(model, version) groups (order-preserving)
/// so each worker serves one version with warm caches.
fn dispatch_loop(
    req_rx: Receiver<Envelope>,
    batch_tx: SyncSender<Vec<Envelope>>,
    max_batch: usize,
    max_wait: Duration,
) {
    loop {
        let first = match req_rx.recv() {
            Ok(e) => e,
            // Admission sender dropped and the buffer is fully drained:
            // shutdown complete on this side.
            Err(_) => return,
        };
        let deadline = Instant::now() + max_wait;
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        while batch.len() < max_batch {
            let now = Instant::now();
            let got = if now >= deadline {
                req_rx.try_recv().ok()
            } else {
                req_rx.recv_timeout(deadline - now).ok()
            };
            match got {
                Some(e) => batch.push(e),
                None => break,
            }
        }
        while !batch.is_empty() {
            let key =
                (batch[0].fm.name().to_string(), batch[0].fm.version());
            let (group, rest): (Vec<Envelope>, Vec<Envelope>) = batch
                .into_iter()
                .partition(|e| e.fm.name() == key.0 && e.fm.version() == key.1);
            batch = rest;
            telemetry::count(Counter::BatchesDispatched);
            telemetry::record(Hist::BatchFill, group.len() as u64);
            if batch_tx.send(group).is_err() {
                return;
            }
        }
    }
}

/// Serve dispatched batches. Each worker keeps a private
/// [`ArenaCache`], so repeat Score requests for a version reuse the
/// compiled program's scratch without any cross-thread coordination.
fn worker_loop(rx: Arc<Mutex<Receiver<Vec<Envelope>>>>) {
    let mut arenas = ArenaCache::new();
    loop {
        // Hold the lock only for the recv itself, not the evaluation.
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let batch = match batch {
            Ok(b) => b,
            Err(_) => return,
        };
        for env in batch {
            telemetry::record(
                Hist::QueueWaitNs,
                env.enqueued.elapsed().as_nanos() as u64,
            );
            let _span = telemetry::span(Hist::RequestNs);
            let resp = match &env.query {
                Query::Predictive { num_samples, sites } => {
                    let refs: Vec<&str> = sites.iter().map(|s| s.as_str()).collect();
                    Response::Predictive(env.fm.predict(env.seed, *num_samples, &refs))
                }
                Query::Score => {
                    let (loss, compiled) = env.fm.score_with(env.seed, &mut arenas);
                    Response::Score { loss, compiled }
                }
            };
            telemetry::count(Counter::RequestsServed);
            // A dropped Pending makes this an Err; the work is simply
            // abandoned, which is the caller's prerogative.
            let _ = env.reply.send(Ok(resp));
        }
    }
}
