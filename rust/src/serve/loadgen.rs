//! Synthetic heavy-traffic serving load: a small trained model zoo
//! (vae / gmm / eight_schools snapshots), closed-loop client fleets,
//! parity checks, and the `BENCH_serve.json` record builder shared by
//! `benches/serve_load.rs` and the `fyro serve-bench` CLI subcommand.
//!
//! The zoo deliberately mixes serving profiles: `vae` and
//! `eight_schools` are fully reparameterized (compiled Score path),
//! while `gmm` carries a discrete per-point assignment site and is
//! inherently dynamic — every run exercises the `serve_graph_fallback`
//! warn path alongside the compiled one. The gmm is registered at two
//! versions (different training lengths) so batches split by version,
//! and `eight_schools` uses the non-centered parameterization so its
//! guide stays all-Normal.

use super::{
    ArenaCache, Query, Registry, Request, Response, ServeConfig, ServeError, ServeModelFn,
    Server,
};
use crate::benchkit::{json::JsonObj, percentile};
use crate::coordinator;
use crate::dist::{Categorical, Constraint, MvNormalDiag, Normal};
use crate::infer::elbo::{TraceElbo, TraceGraphElbo};
use crate::infer::Svi;
use crate::optim::Adam;
use crate::params::ParamStore;
use crate::poutine::Ctx;
use crate::telemetry;
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

// ----------------------------------------------------------------- zoo

/// A trained (model, guide, store) triple ready to snapshot and freeze.
pub struct ZooModel {
    pub name: &'static str,
    pub version: u64,
    pub model: Box<ServeModelFn>,
    pub guide: Box<ServeModelFn>,
    pub store: ParamStore,
}

/// Linear-decoder micro-VAE: scalar latent, 32-pixel observation
/// through a learned per-pixel affine decoder inside a [`Ctx::plate_idx`]
/// (static trace → compiled Score path).
pub fn vae_mini(train_steps: usize) -> ZooModel {
    const N: usize = 32;
    let mut drng = Pcg64::new(11);
    let data: Vec<f64> = (0..N).map(|_| 1.5 + 0.4 * drng.normal()).collect();
    let data_t = Tensor::from_vec(data);
    let idx: Vec<usize> = (0..N).collect();

    let dm = data_t.clone();
    let model: Box<ServeModelFn> = Box::new(move |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.plate_idx("pix", N, &idx, |ctx, _plate| {
            let w = ctx.param("dec.w", || Tensor::zeros(vec![N]));
            let b = ctx.param("dec.b", || Tensor::zeros(vec![N]));
            let loc = w.mul(&z).add(&b);
            ctx.observe("x", Normal::new(loc, ctx.cs(0.4)), dm.clone());
        });
    });
    let guide: Box<ServeModelFn> = Box::new(move |ctx: &mut Ctx| {
        let loc = ctx.param("enc.loc", || Tensor::scalar(0.0));
        let scale =
            ctx.param_constrained("enc.scale", || Tensor::scalar(0.5), Constraint::Positive);
        ctx.sample("z", Normal::new(loc, scale));
    });

    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(1);
    let mut svi = Svi::new(Adam::new(0.05), TraceElbo::default());
    for _ in 0..train_steps {
        svi.step(&mut store, &mut rng, &*model, &*guide);
    }
    ZooModel { name: "vae", version: 1, model, guide, store }
}

/// Two-component mixture with a per-point discrete assignment — the
/// inherently-dynamic zoo member (score-function site → compilation is
/// pinned off, every Score request takes the dynamic path and the
/// first one emits `serve_graph_fallback`).
pub fn gmm_mini(version: u64, train_steps: usize) -> ZooModel {
    const N: usize = 16;
    let mut drng = Pcg64::new(9);
    let mut data = Vec::new();
    for _ in 0..N / 2 {
        data.push(-2.0 + 0.5 * drng.normal());
        data.push(3.0 + 0.5 * drng.normal());
    }
    let data_t = Tensor::from_vec(data);

    let dm = data_t.clone();
    let model: Box<ServeModelFn> = Box::new(move |ctx: &mut Ctx| {
        let mu0 = ctx.sample("mu0", Normal::std(0.0, 10.0));
        let mu1 = ctx.sample("mu1", Normal::std(0.0, 10.0));
        ctx.plate("data", N, None, |ctx, _plate| {
            let prior = ctx.c(Tensor::zeros(vec![N, 2]));
            let k = ctx.sample("assign", Categorical::new(prior));
            let one_minus = k.neg().add_scalar(1.0);
            let mu = mu0.mul(&one_minus).add(&mu1.mul(&k));
            ctx.observe("x", Normal::new(mu, ctx.cs(0.5)), dm.clone());
        });
    });
    let guide: Box<ServeModelFn> = Box::new(move |ctx: &mut Ctx| {
        for m in ["mu0", "mu1"] {
            let init = if m == "mu0" { -1.0 } else { 1.0 };
            let loc = ctx.param(&format!("{m}.loc"), move || Tensor::scalar(init));
            let scale = ctx.param_constrained(
                &format!("{m}.scale"),
                || Tensor::scalar(0.1),
                Constraint::Positive,
            );
            ctx.sample(m, Normal::new(loc, scale));
        }
        ctx.plate("data", N, None, |ctx, _plate| {
            let logits = ctx.param("assign.logits", || Tensor::zeros(vec![N, 2]));
            ctx.sample("assign", Categorical::new(logits));
        });
    });

    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(2);
    let mut svi = Svi::new(Adam::new(0.05), TraceGraphElbo::default());
    for _ in 0..train_steps {
        svi.step(&mut store, &mut rng, &*model, &*guide);
    }
    ZooModel { name: "gmm", version, model, guide, store }
}

/// Eight schools, non-centered: `theta = mu + exp(log_tau) * eta` with
/// an all-Normal guide, so the whole pair is reparameterized and the
/// Score path compiles.
pub fn eight_schools_svi(train_steps: usize) -> ZooModel {
    let y = Tensor::from_vec(vec![28.0, 8.0, -3.0, 7.0, -1.0, 1.0, 18.0, 12.0]);
    let sigma = Tensor::from_vec(vec![15.0, 10.0, 16.0, 11.0, 9.0, 11.0, 10.0, 18.0]);

    let ym = y.clone();
    let sm = sigma.clone();
    let model: Box<ServeModelFn> = Box::new(move |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 5.0));
        let log_tau = ctx.sample("log_tau", Normal::std(0.0, 1.0));
        let eta = ctx.sample(
            "eta",
            MvNormalDiag::new(
                ctx.c(Tensor::zeros(vec![8])),
                ctx.c(Tensor::from_vec(vec![1.0; 8])),
            ),
        );
        let theta = mu.add(&eta.mul(&log_tau.exp()));
        ctx.observe("y", Normal::new(theta, ctx.c(sm.clone())), ym.clone());
    });
    let guide: Box<ServeModelFn> = Box::new(move |ctx: &mut Ctx| {
        let mu_loc = ctx.param("mu.loc", || Tensor::scalar(0.0));
        let mu_scale =
            ctx.param_constrained("mu.scale", || Tensor::scalar(1.0), Constraint::Positive);
        ctx.sample("mu", Normal::new(mu_loc, mu_scale));
        let lt_loc = ctx.param("lt.loc", || Tensor::scalar(0.0));
        let lt_scale =
            ctx.param_constrained("lt.scale", || Tensor::scalar(0.5), Constraint::Positive);
        ctx.sample("log_tau", Normal::new(lt_loc, lt_scale));
        let e_loc = ctx.param("eta.loc", || Tensor::zeros(vec![8]));
        let e_scale = ctx.param_constrained(
            "eta.scale",
            || Tensor::from_vec(vec![0.5; 8]),
            Constraint::Positive,
        );
        ctx.sample("eta", MvNormalDiag::new(e_loc, e_scale));
    });

    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(3);
    let mut svi = Svi::new(Adam::new(0.05), TraceElbo::default());
    for _ in 0..train_steps {
        svi.step(&mut store, &mut rng, &*model, &*guide);
    }
    ZooModel { name: "eight_schools", version: 1, model, guide, store }
}

/// Train the zoo, round-trip every member through the on-disk
/// `FYSNAP01` snapshot format, freeze, and register. The gmm lands at
/// two versions so mixed-version batching has something to split.
pub fn build_zoo(registry: &Registry, train_steps: usize, dir: &str) -> crate::error::Result<()> {
    let zoo = vec![
        vae_mini(train_steps),
        gmm_mini(1, train_steps),
        gmm_mini(2, train_steps + train_steps / 2),
        eight_schools_svi(train_steps),
    ];
    for zm in zoo {
        let path = format!("{dir}/fyro_zoo_{}_v{}.snap", zm.name, zm.version);
        coordinator::save_snapshot(&path, zm.name, zm.version, &zm.store)?;
        // load_frozen re-validates the fingerprint and probes the pair
        registry.load_frozen(&path, zm.model, zm.guide)?;
        std::fs::remove_file(&path).ok();
    }
    Ok(())
}

/// The mixed request stream every client walks: model, pinned version,
/// and the predictive site for that model.
const MIX: [(&str, Option<u64>, &str); 4] = [
    ("vae", None, "x"),
    ("gmm", Some(1), "x"),
    ("gmm", Some(2), "x"),
    ("eight_schools", None, "y"),
];

fn mixed_request(client: usize, step: usize) -> Request {
    let (model, version, site) = MIX[(client + step) % MIX.len()];
    let seed = ((client as u64) << 20) | step as u64;
    let query = if (client + step) % 3 == 0 {
        Query::Predictive { num_samples: 4, sites: vec![site.to_string()] }
    } else {
        Query::Score
    };
    Request { model: model.to_string(), version, seed, query }
}

// ------------------------------------------------------------ load gen

pub struct LoadOpts {
    pub clients: usize,
    pub requests_per_client: usize,
    pub config: ServeConfig,
}

pub struct LoadResult {
    pub requests_per_sec: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Completed requests (every client request completes; overload
    /// rejections are retried).
    pub completed: u64,
    /// `Overloaded` rejections absorbed by client retry loops.
    pub retries: u64,
}

/// Closed-loop load: `clients` threads each issue
/// `requests_per_client` mixed requests back-to-back, retrying on
/// `Overloaded` (with a yield) so no intended request is lost. Returns
/// wall-clock throughput and client-observed latency percentiles.
pub fn run_load(registry: &Arc<Registry>, opts: &LoadOpts) -> LoadResult {
    let server = Server::start(registry.clone(), opts.config.clone());
    let t0 = Instant::now();
    let per_client: Vec<(Vec<f64>, u64)> = std::thread::scope(|scope| {
        let server = &server;
        let handles: Vec<_> = (0..opts.clients)
            .map(|c| {
                scope.spawn(move || {
                    let mut lat_ms = Vec::with_capacity(opts.requests_per_client);
                    let mut retries = 0u64;
                    for r in 0..opts.requests_per_client {
                        let t = Instant::now();
                        loop {
                            match server.serve(mixed_request(c, r)) {
                                Ok(_) => break,
                                Err(ServeError::Overloaded) => {
                                    retries += 1;
                                    std::thread::yield_now();
                                }
                                Err(e) => panic!("client {c}: {e}"),
                            }
                        }
                        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    (lat_ms, retries)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    server.shutdown();

    let mut all: Vec<f64> = Vec::new();
    let mut retries = 0u64;
    for (lat, r) in per_client {
        all.extend(lat);
        retries += r;
    }
    all.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    LoadResult {
        requests_per_sec: all.len() as f64 / secs,
        p50_ms: percentile(&all, 0.50),
        p95_ms: percentile(&all, 0.95),
        p99_ms: percentile(&all, 0.99),
        completed: all.len() as u64,
        retries,
    }
}

// ------------------------------------------------------- parity checks

fn maps_bitwise_eq(a: &HashMap<String, Tensor>, b: &HashMap<String, Tensor>) -> bool {
    a.len() == b.len()
        && a.iter().all(|(k, av)| {
            b.get(k).is_some_and(|bv| {
                av.dims() == bv.dims()
                    && av
                        .data()
                        .iter()
                        .zip(bv.data().iter())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            })
        })
}

/// Solo-vs-batched bitwise parity: a predictive request served inside a
/// mixed concurrent batch must equal [`super::FrozenModel::predict`]
/// run directly with the same seed.
pub fn check_solo_vs_batched(registry: &Arc<Registry>) -> bool {
    let fm = registry.get("vae", None).expect("vae registered");
    let solo = fm.predict(1234, 4, &["x", "z"]);

    let server = Server::start(
        registry.clone(),
        ServeConfig { num_workers: 2, max_batch: 8, max_wait_us: 2000, queue_depth: 64 },
    );
    let mut filler = Vec::new();
    for i in 0..6 {
        filler.push(
            server
                .submit(Request {
                    model: "gmm".to_string(),
                    version: Some(1 + i % 2),
                    seed: 70 + i,
                    query: Query::Score,
                })
                .expect("filler admitted"),
        );
    }
    let target = server
        .submit(Request {
            model: "vae".to_string(),
            version: None,
            seed: 1234,
            query: Query::Predictive {
                num_samples: 4,
                sites: vec!["x".to_string(), "z".to_string()],
            },
        })
        .expect("target admitted");
    let batched = match target.wait().expect("target served") {
        Response::Predictive(m) => m,
        other => panic!("predictive request answered with {other:?}"),
    };
    for p in filler {
        p.wait().expect("filler served");
    }
    server.shutdown();
    maps_bitwise_eq(&solo, &batched)
}

/// Compiled-vs-dynamic Score parity at 1e-12 (relative) on the
/// compilable zoo members, plus the gmm staying honestly dynamic.
pub fn check_compiled_vs_dynamic(registry: &Arc<Registry>) -> bool {
    let mut cache = ArenaCache::new();
    let mut ok = true;
    for (name, expect_compiled) in [("vae", true), ("eight_schools", true), ("gmm", false)] {
        let fm = registry.get(name, None).expect("zoo model registered");
        for seed in [99u64, 100, 101] {
            let (loss, compiled) = fm.score_with(seed, &mut cache);
            let dynamic = fm.score_dynamic(seed);
            let tol = 1e-12 * dynamic.abs().max(1.0);
            if compiled != expect_compiled || (loss - dynamic).abs() > tol {
                ok = false;
            }
        }
    }
    ok
}

/// Overload behavior: a tiny queue rejects with `Overloaded` while
/// every *accepted* request still completes.
pub fn check_overload(registry: &Arc<Registry>) -> (u64, bool) {
    let server = Server::start(
        registry.clone(),
        ServeConfig { num_workers: 1, max_batch: 1, max_wait_us: 0, queue_depth: 2 },
    );
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..64u64 {
        match server.submit(Request {
            model: "eight_schools".to_string(),
            version: None,
            seed: i,
            query: Query::Score,
        }) {
            Ok(p) => accepted.push(p),
            Err(ServeError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let all_served = accepted.into_iter().all(|p| p.wait().is_ok());
    server.shutdown();
    (rejected, all_served)
}

// ---------------------------------------------------------- bench record

fn sweep_entry(workers: usize, res: &LoadResult) -> JsonObj {
    let snap = telemetry::snapshot();
    let mean_fill = snap.hist("batch_fill").map(|h| h.mean()).unwrap_or(0.0);
    JsonObj::new()
        .int("workers", workers)
        .num("requests_per_sec", res.requests_per_sec)
        .num("p50_ms", res.p50_ms)
        .num("p95_ms", res.p95_ms)
        .num("p99_ms", res.p99_ms)
        .int("completed", res.completed as usize)
        .int("retries", res.retries as usize)
        .int("served", snap.counter("requests_served") as usize)
        .int("rejected_submits", snap.counter("requests_rejected") as usize)
        .int("batches_dispatched", snap.counter("batches_dispatched") as usize)
        .num("mean_batch_fill", mean_fill)
}

/// The full `BENCH_serve.json` run: build the zoo, sweep the worker
/// pool, compare batched vs unbatched dispatch, and pin the parity /
/// backpressure flags. `smoke` shrinks the fleet for CI.
pub fn run_bench(smoke: bool) -> JsonObj {
    telemetry::set_enabled(true);
    telemetry::reset();

    let registry = Arc::new(Registry::new());
    let dir = std::env::temp_dir().to_string_lossy().to_string();
    let train_steps = if smoke { 60 } else { 300 };
    build_zoo(&registry, train_steps, &dir).expect("zoo build");

    let clients = if smoke { 32 } else { 1024 };
    let requests_per_client = if smoke { 4 } else { 20 };
    let worker_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    // deep enough that the sweep measures service, not admission retry
    let queue_depth = clients;

    let mut sweep = Vec::new();
    let mut rps = Vec::new();
    for &workers in worker_counts {
        telemetry::reset();
        let res = run_load(
            &registry,
            &LoadOpts {
                clients,
                requests_per_client,
                config: ServeConfig {
                    num_workers: workers,
                    max_batch: 32,
                    max_wait_us: 200,
                    queue_depth,
                },
            },
        );
        rps.push(res.requests_per_sec);
        sweep.push(sweep_entry(workers, &res));
    }
    let worker_speedup = rps.last().copied().unwrap_or(0.0) / rps[0].max(1e-9);

    // batched vs unbatched at a fixed pool size
    let pool = if smoke { 2 } else { 4 };
    telemetry::reset();
    let batched = run_load(
        &registry,
        &LoadOpts {
            clients,
            requests_per_client,
            config: ServeConfig {
                num_workers: pool,
                max_batch: 32,
                max_wait_us: 200,
                queue_depth,
            },
        },
    );
    let batched_entry = sweep_entry(pool, &batched);
    telemetry::reset();
    let unbatched = run_load(
        &registry,
        &LoadOpts {
            clients,
            requests_per_client,
            config: ServeConfig {
                num_workers: pool,
                max_batch: 1,
                max_wait_us: 0,
                queue_depth,
            },
        },
    );
    let unbatched_entry = sweep_entry(pool, &unbatched);
    let batched_speedup = batched.requests_per_sec / unbatched.requests_per_sec.max(1e-9);

    // parity + backpressure flags (always checked, smoke or not)
    telemetry::reset();
    let solo_matches_batched = check_solo_vs_batched(&registry);
    let compiled_matches_dynamic = check_compiled_vs_dynamic(&registry);
    let (overload_rejected, overload_all_served) = check_overload(&registry);
    let flags_snap = telemetry::snapshot();

    JsonObj::new()
        .str("bench", "serve_load")
        .str("unit", "requests_per_sec")
        .bool("smoke", smoke)
        .obj(
            "config",
            JsonObj::new()
                .int("clients", clients)
                .int("requests_per_client", requests_per_client)
                .int("queue_depth", queue_depth)
                .int("max_batch", 32)
                .int("max_wait_us", 200)
                .int("train_steps", train_steps)
                .str("models", "vae v1, gmm v1+v2, eight_schools v1"),
        )
        .arr("sweep", sweep)
        .num("worker_speedup", worker_speedup)
        .obj("batched", batched_entry)
        .obj("unbatched", unbatched_entry)
        .num("batched_speedup", batched_speedup)
        .bool("solo_matches_batched", solo_matches_batched)
        .bool("compiled_matches_dynamic_1e12", compiled_matches_dynamic)
        .obj(
            "overload",
            JsonObj::new()
                .int("rejected", overload_rejected as usize)
                .bool("accepted_all_served", overload_all_served)
                .int(
                    "rejected_counter",
                    flags_snap.counter("requests_rejected") as usize,
                ),
        )
}
