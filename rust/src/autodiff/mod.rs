//! Tape-based reverse-mode automatic differentiation.
//!
//! This is Fyro's replacement for `torch.autograd` on the dynamic path:
//! a dynamically-built computation graph ("define-by-run", like PyTorch)
//! over [`Tensor`] values. Every op appends a node with a backward
//! closure; [`Tape::grad`] walks the tape in reverse creation order
//! (which is a valid topological order) accumulating adjoints.
//!
//! Broadcasting ops reduce their output adjoint back to each parent's
//! shape with [`reduce_grad_to`], matching NumPy broadcast semantics.

use crate::tensor::{Shape, Tensor};
use std::cell::RefCell;
use std::rc::Rc;

/// Structural identity of a tape node — what the op *is*, independent of
/// its backward closure. The dynamic path never consults this; the
/// graph-mode compiler ([`crate::infer::compile`]) replays a recorded
/// tape as a straight-line program and needs to know each node's op and
/// static payload (indices, scalars) to re-execute it without closures.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Leaf / constant — no forward computation.
    Leaf,
    Add,
    Sub,
    Mul,
    Div,
    MatMul,
    Neg,
    Exp,
    Ln,
    Sqrt,
    Square,
    Tanh,
    Sigmoid,
    Relu,
    Softplus,
    Lgamma,
    Abs,
    GatherLast(Vec<usize>),
    AddScalar(f64),
    MulScalar(f64),
    NarrowLast(usize, usize),
    Reshape,
    Sum,
    SumLast,
    Sum0,
}

/// Which elementary RNG stream filled a leaf — recorded so the compiled
/// step can refill the same buffer from the same stream each iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrawKind {
    /// One Box–Muller normal per element ([`Tensor::randn`] order).
    StdNormal,
    /// One U[0,1) per element ([`Tensor::rand`] order).
    Uniform,
    /// One U(0,1) per element (inverse-CDF exponential order).
    UniformOpen,
}

/// One entry in the recorded per-step input schedule: everything a
/// dynamic execution consumed besides pure tensor arithmetic, in RNG
/// consumption order.
#[derive(Clone, Debug)]
pub enum TapeEvent {
    /// Leaf `id` was filled from the RNG stream `kind`.
    Draw { id: usize, kind: DrawKind },
    /// A plate drew a subsample permutation of `size` indices, using the
    /// first `take`. `vectorized` is false for sequential plates (which
    /// graph mode rejects — their site *names* change with the draw).
    Permutation { size: usize, take: usize, vectorized: bool },
    /// `plate.select` gathered rows of `source` with permutation ordinal
    /// `perm`; the output's storage pointer is `ptr` (matched against
    /// leaf values at compile time to find where the minibatch enters
    /// the tape).
    Select { ptr: usize, source: Tensor, perm: usize },
}

/// Read-only snapshot of one tape node, exported for compilation.
#[derive(Clone, Debug)]
pub struct TapeNode {
    pub op: Op,
    pub parents: Vec<usize>,
    pub value: Tensor,
}

#[derive(Default)]
struct RecState {
    events: Vec<TapeEvent>,
    perms: usize,
}

/// Sum an adjoint over the dimensions that were broadcast so it matches
/// the parent's shape.
pub fn reduce_grad_to(grad: &Tensor, target: &Shape) -> Tensor {
    if grad.shape() == target {
        return grad.clone();
    }
    let mut g = grad.clone();
    // Collapse leading extra dims.
    while g.rank() > target.rank() {
        g = g.sum0();
    }
    // Sum along dims where target has size 1.
    for i in 0..target.rank() {
        if target.dims()[i] == 1 && g.dims()[i] != 1 {
            // sum along axis i, keepdim
            g = sum_axis_keepdim(&g, i);
        }
    }
    g.reshape(target.dims().to_vec())
}

pub(crate) fn sum_axis_keepdim(t: &Tensor, axis: usize) -> Tensor {
    let dims = t.dims().to_vec();
    let outer: usize = dims[..axis].iter().product();
    let mid = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out = vec![0.0; outer * inner];
    let data = t.data();
    for o in 0..outer {
        for m in 0..mid {
            let base = (o * mid + m) * inner;
            for i in 0..inner {
                out[o * inner + i] += data[base + i];
            }
        }
    }
    let mut new_dims = dims.clone();
    new_dims[axis] = 1;
    Tensor::new(out, new_dims)
}

type BackwardFn = Box<dyn Fn(&Tensor, &[Tensor]) -> Vec<Tensor>>;

struct Node {
    value: Tensor,
    parents: Vec<usize>,
    op: Op,
    /// (output adjoint, parent values) -> parent adjoints.
    backward: Option<BackwardFn>,
}

/// The gradient tape. Create one per differentiable computation (e.g. one
/// per SVI step); drop it to free the graph.
#[derive(Clone)]
pub struct Tape {
    nodes: Rc<RefCell<Vec<Node>>>,
    rec: Rc<RefCell<Option<RecState>>>,
}

impl Default for Tape {
    fn default() -> Self {
        Self::new()
    }
}

/// A differentiable value: an index into a [`Tape`] plus a cached value.
#[derive(Clone)]
pub struct Var {
    pub id: usize,
    value: Tensor,
    tape: Tape,
}

impl std::fmt::Debug for Var {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Var#{} {:?}", self.id, self.value)
    }
}

impl Tape {
    pub fn new() -> Self {
        Tape {
            nodes: Rc::new(RefCell::new(Vec::new())),
            rec: Rc::new(RefCell::new(None)),
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Create a leaf variable (inputs, parameters).
    pub fn leaf(&self, value: Tensor) -> Var {
        let id = self.push(Node { value: value.clone(), parents: vec![], op: Op::Leaf, backward: None });
        Var { id, value, tape: self.clone() }
    }

    /// Create a constant — also a leaf; the distinction is by usage.
    pub fn constant(&self, value: Tensor) -> Var {
        self.leaf(value)
    }

    pub fn scalar(&self, v: f64) -> Var {
        self.leaf(Tensor::scalar(v))
    }

    fn push(&self, node: Node) -> usize {
        let mut nodes = self.nodes.borrow_mut();
        nodes.push(node);
        nodes.len() - 1
    }

    fn unary(&self, a: &Var, value: Tensor, op: Op, backward: BackwardFn) -> Var {
        let id = self.push(Node {
            value: value.clone(),
            parents: vec![a.id],
            op,
            backward: Some(backward),
        });
        Var { id, value, tape: self.clone() }
    }

    fn binary(&self, a: &Var, b: &Var, value: Tensor, op: Op, backward: BackwardFn) -> Var {
        let id = self.push(Node {
            value: value.clone(),
            parents: vec![a.id, b.id],
            op,
            backward: Some(backward),
        });
        Var { id, value, tape: self.clone() }
    }

    // ---------- graph-mode recording ----------

    /// Begin recording per-step input events (RNG draws, plate
    /// permutations, minibatch selects). The recorded tape of one
    /// instrumented execution *is* the straight-line program the
    /// graph-mode compiler replays.
    pub fn start_recording(&self) {
        *self.rec.borrow_mut() = Some(RecState::default());
    }

    /// Whether recording is active.
    pub fn recording(&self) -> bool {
        self.rec.borrow().is_some()
    }

    /// Stop recording and return the event log (None if not recording).
    pub fn take_recording(&self) -> Option<Vec<TapeEvent>> {
        self.rec.borrow_mut().take().map(|r| r.events)
    }

    /// Record that leaf `id` was filled from stream `kind`.
    pub fn note_draw(&self, id: usize, kind: DrawKind) {
        if let Some(rec) = self.rec.borrow_mut().as_mut() {
            rec.events.push(TapeEvent::Draw { id, kind });
        }
    }

    /// Record a plate subsample permutation draw; returns its ordinal
    /// among recorded permutations (for later `Select` references), or
    /// None when not recording.
    pub fn note_permutation(&self, size: usize, take: usize, vectorized: bool) -> Option<usize> {
        let mut rec = self.rec.borrow_mut();
        let rec = rec.as_mut()?;
        let ord = rec.perms;
        rec.perms += 1;
        rec.events.push(TapeEvent::Permutation { size, take, vectorized });
        Some(ord)
    }

    /// Record a `plate.select` row gather (output storage `ptr`, full
    /// data `source`, permutation ordinal `perm`).
    pub fn note_select(&self, ptr: usize, source: Tensor, perm: usize) {
        if let Some(rec) = self.rec.borrow_mut().as_mut() {
            rec.events.push(TapeEvent::Select { ptr, source, perm });
        }
    }

    /// Export a structural snapshot of every node (op, parents, value at
    /// record time) for the graph-mode compiler.
    pub fn snapshot_nodes(&self) -> Vec<TapeNode> {
        self.nodes
            .borrow()
            .iter()
            .map(|n| TapeNode {
                op: n.op.clone(),
                parents: n.parents.clone(),
                value: n.value.clone(),
            })
            .collect()
    }

    /// Reverse pass: adjoints of `loss` (must be scalar) w.r.t. `wrt`.
    pub fn grad(&self, loss: &Var, wrt: &[&Var]) -> Vec<Tensor> {
        assert_eq!(loss.value.numel(), 1, "grad: loss must be scalar");
        let nodes = self.nodes.borrow();
        let mut adjoints: Vec<Option<Tensor>> = vec![None; nodes.len()];
        adjoints[loss.id] = Some(Tensor::scalar(1.0));
        for id in (0..=loss.id).rev() {
            let Some(adj) = adjoints[id].take() else { continue };
            let node = &nodes[id];
            if let Some(backward) = &node.backward {
                let parent_vals: Vec<Tensor> =
                    node.parents.iter().map(|&p| nodes[p].value.clone()).collect();
                let parent_grads = backward(&adj, &parent_vals);
                assert_eq!(parent_grads.len(), node.parents.len());
                for (&p, g) in node.parents.iter().zip(parent_grads) {
                    // Accumulate in place: the adjoint buffer is almost
                    // always uniquely held, so this is allocation-free
                    // (copy-on-write otherwise). The reference toggle
                    // restores the old clone-and-add for A/B benching.
                    match adjoints[p].take() {
                        Some(mut acc) => {
                            if crate::tensor::reference_kernels() {
                                acc = acc.add(&g);
                            } else {
                                acc.add_assign(&g);
                            }
                            adjoints[p] = Some(acc);
                        }
                        None => adjoints[p] = Some(g),
                    }
                }
            }
            adjoints[id] = Some(adj);
        }
        wrt.iter()
            .map(|v| {
                adjoints[v.id]
                    .clone()
                    .unwrap_or_else(|| Tensor::zeros(v.value.dims().to_vec()))
            })
            .collect()
    }
}

impl Var {
    pub fn value(&self) -> &Tensor {
        &self.value
    }

    pub fn tape(&self) -> &Tape {
        &self.tape
    }

    pub fn item(&self) -> f64 {
        self.value.item()
    }

    pub fn dims(&self) -> &[usize] {
        self.value.dims()
    }

    // ---------- binary ops ----------

    pub fn add(&self, o: &Var) -> Var {
        let (sa, sb) = (self.value.shape().clone(), o.value.shape().clone());
        self.tape.binary(
            self,
            o,
            self.value.add(&o.value),
            Op::Add,
            Box::new(move |g, _| vec![reduce_grad_to(g, &sa), reduce_grad_to(g, &sb)]),
        )
    }

    pub fn sub(&self, o: &Var) -> Var {
        let (sa, sb) = (self.value.shape().clone(), o.value.shape().clone());
        self.tape.binary(
            self,
            o,
            self.value.sub(&o.value),
            Op::Sub,
            Box::new(move |g, _| vec![reduce_grad_to(g, &sa), reduce_grad_to(&g.neg(), &sb)]),
        )
    }

    pub fn mul(&self, o: &Var) -> Var {
        let (sa, sb) = (self.value.shape().clone(), o.value.shape().clone());
        self.tape.binary(
            self,
            o,
            self.value.mul(&o.value),
            Op::Mul,
            Box::new(move |g, p| {
                vec![
                    reduce_grad_to(&g.mul(&p[1]), &sa),
                    reduce_grad_to(&g.mul(&p[0]), &sb),
                ]
            }),
        )
    }

    pub fn div(&self, o: &Var) -> Var {
        let (sa, sb) = (self.value.shape().clone(), o.value.shape().clone());
        self.tape.binary(
            self,
            o,
            self.value.div(&o.value),
            Op::Div,
            Box::new(move |g, p| {
                let ga = g.div(&p[1]);
                let gb = g.mul(&p[0]).div(&p[1].mul(&p[1])).neg();
                vec![reduce_grad_to(&ga, &sa), reduce_grad_to(&gb, &sb)]
            }),
        )
    }

    /// Matrix multiply (rank-2 x rank-2, or the vec variants Tensor
    /// supports with both operands rank >= 1).
    pub fn matmul(&self, o: &Var) -> Var {
        assert_eq!(self.value.rank(), 2, "Var::matmul expects rank-2 lhs");
        assert_eq!(o.value.rank(), 2, "Var::matmul expects rank-2 rhs");
        self.tape.binary(
            self,
            o,
            self.value.matmul(&o.value),
            Op::MatMul,
            Box::new(move |g, p| vec![g.matmul(&p[1].t()), p[0].t().matmul(g)]),
        )
    }

    // ---------- unary ops ----------

    pub fn neg(&self) -> Var {
        self.tape
            .unary(self, self.value.neg(), Op::Neg, Box::new(|g, _| vec![g.neg()]))
    }

    pub fn exp(&self) -> Var {
        let out = self.value.exp();
        let out_c = out.clone();
        self.tape
            .unary(self, out, Op::Exp, Box::new(move |g, _| vec![g.mul(&out_c)]))
    }

    pub fn ln(&self) -> Var {
        self.tape
            .unary(self, self.value.ln(), Op::Ln, Box::new(|g, p| vec![g.div(&p[0])]))
    }

    pub fn sqrt(&self) -> Var {
        let out = self.value.sqrt();
        let out_c = out.clone();
        self.tape.unary(
            self,
            out,
            Op::Sqrt,
            Box::new(move |g, _| vec![g.div(&out_c.mul_scalar(2.0))]),
        )
    }

    pub fn square(&self) -> Var {
        self.tape.unary(
            self,
            self.value.mul(&self.value),
            Op::Square,
            Box::new(|g, p| vec![g.mul(&p[0]).mul_scalar(2.0)]),
        )
    }

    pub fn tanh(&self) -> Var {
        let out = self.value.tanh();
        let out_c = out.clone();
        self.tape.unary(
            self,
            out,
            Op::Tanh,
            Box::new(move |g, _| {
                let one_minus = out_c.mul(&out_c).neg().add_scalar(1.0);
                vec![g.mul(&one_minus)]
            }),
        )
    }

    pub fn sigmoid(&self) -> Var {
        let out = self.value.sigmoid();
        let out_c = out.clone();
        self.tape.unary(
            self,
            out,
            Op::Sigmoid,
            Box::new(move |g, _| {
                let d = out_c.mul(&out_c.neg().add_scalar(1.0));
                vec![g.mul(&d)]
            }),
        )
    }

    pub fn relu(&self) -> Var {
        self.tape.unary(
            self,
            self.value.relu(),
            Op::Relu,
            Box::new(|g, p| vec![g.mul(&p[0].gt(&Tensor::scalar(0.0)))]),
        )
    }

    pub fn softplus(&self) -> Var {
        self.tape.unary(
            self,
            self.value.softplus(),
            Op::Softplus,
            Box::new(|g, p| vec![g.mul(&p[0].sigmoid())]),
        )
    }

    pub fn lgamma(&self) -> Var {
        self.tape.unary(
            self,
            self.value.lgamma(),
            Op::Lgamma,
            Box::new(|g, p| vec![g.mul(&p[0].digamma())]),
        )
    }

    pub fn abs(&self) -> Var {
        self.tape.unary(
            self,
            self.value.abs(),
            Op::Abs,
            Box::new(|g, p| vec![g.mul(&p[0].sign())]),
        )
    }

    /// Gather one element per row along the last axis (indices are data,
    /// not differentiable); backward scatters the adjoint.
    pub fn gather_last(&self, idx: &[usize]) -> Var {
        let idx_v = idx.to_vec();
        let dims = self.value.dims().to_vec();
        self.tape.unary(
            self,
            self.value.gather_last(idx),
            Op::GatherLast(idx.to_vec()),
            Box::new(move |g, _| {
                let last = *dims.last().unwrap();
                let mut grad = Tensor::zeros(dims.clone());
                {
                    let gd = grad.data_mut();
                    for (i, &j) in idx_v.iter().enumerate() {
                        gd[i * last + j] = g.data()[i];
                    }
                }
                vec![grad]
            }),
        )
    }

    pub fn add_scalar(&self, s: f64) -> Var {
        self.tape
            .unary(self, self.value.add_scalar(s), Op::AddScalar(s), Box::new(|g, _| vec![g.clone()]))
    }

    /// Contiguous slice along the last axis; backward scatters into the
    /// sliced range.
    pub fn narrow_last(&self, offset: usize, len: usize) -> Var {
        let dims = self.value.dims().to_vec();
        self.tape.unary(
            self,
            self.value.narrow_last(offset, len),
            Op::NarrowLast(offset, len),
            Box::new(move |g, _| {
                let last = *dims.last().unwrap();
                let outer: usize = dims.iter().product::<usize>() / last;
                let mut grad = Tensor::zeros(dims.clone());
                {
                    let gd = grad.data_mut();
                    for i in 0..outer {
                        for j in 0..len {
                            gd[i * last + offset + j] = g.data()[i * len + j];
                        }
                    }
                }
                vec![grad]
            }),
        )
    }

    pub fn mul_scalar(&self, s: f64) -> Var {
        self.tape.unary(
            self,
            self.value.mul_scalar(s),
            Op::MulScalar(s),
            Box::new(move |g, _| vec![g.mul_scalar(s)]),
        )
    }

    pub fn reshape(&self, dims: Vec<usize>) -> Var {
        let old = self.value.dims().to_vec();
        self.tape.unary(
            self,
            self.value.reshape(dims.clone()),
            Op::Reshape,
            Box::new(move |g, _| vec![g.reshape(old.clone())]),
        )
    }

    // ---------- reductions ----------

    /// Sum all elements to a scalar.
    pub fn sum(&self) -> Var {
        let shape = self.value.shape().clone();
        self.tape.unary(
            self,
            Tensor::scalar(self.value.sum()),
            Op::Sum,
            Box::new(move |g, _| vec![Tensor::full(shape.dims().to_vec(), g.item())]),
        )
    }

    pub fn mean(&self) -> Var {
        self.sum().mul_scalar(1.0 / self.value.numel() as f64)
    }

    /// Sum over the last axis.
    pub fn sum_last(&self) -> Var {
        let dims = self.value.dims().to_vec();
        self.tape.unary(
            self,
            self.value.sum_last(),
            Op::SumLast,
            Box::new(move |g, _| {
                // broadcast the adjoint back over the last axis
                let mut gdims = g.dims().to_vec();
                gdims.push(1);
                vec![g.reshape(gdims).broadcast_to(dims.clone())]
            }),
        )
    }

    /// Sum over axis 0.
    pub fn sum0(&self) -> Var {
        let dims = self.value.dims().to_vec();
        self.tape.unary(
            self,
            self.value.sum0(),
            Op::Sum0,
            Box::new(move |g, _| vec![g.broadcast_to(dims.clone())]),
        )
    }

    pub fn dot(&self, o: &Var) -> Var {
        self.mul(o).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    /// Central finite-difference check of an arbitrary scalar function.
    fn check_grad(f: impl Fn(&Tape, &Var) -> Var, x0: Tensor, tol: f64) {
        let tape = Tape::new();
        let x = tape.leaf(x0.clone());
        let y = f(&tape, &x);
        let g = tape.grad(&y, &[&x]).remove(0);
        let eps = 1e-6;
        for i in 0..x0.numel() {
            let mut plus = x0.to_vec();
            plus[i] += eps;
            let mut minus = x0.to_vec();
            minus[i] -= eps;
            let tp = Tape::new();
            let yp = f(&tp, &tp.leaf(Tensor::new(plus, x0.dims().to_vec()))).item();
            let tm = Tape::new();
            let ym = f(&tm, &tm.leaf(Tensor::new(minus, x0.dims().to_vec()))).item();
            let fd = (yp - ym) / (2.0 * eps);
            let ad = g.data()[i];
            assert!(
                (fd - ad).abs() < tol * (1.0 + fd.abs()),
                "elem {i}: fd {fd} vs ad {ad}"
            );
        }
    }

    #[test]
    fn grad_simple_chain() {
        // y = sum((x * 2 + 1)^2)
        check_grad(
            |_, x| x.mul_scalar(2.0).add_scalar(1.0).square().sum(),
            Tensor::from_vec(vec![0.5, -1.0, 2.0]),
            1e-5,
        );
    }

    #[test]
    fn grad_exp_ln() {
        check_grad(
            |_, x| x.exp().ln().mul(x).sum(),
            Tensor::from_vec(vec![0.5, 1.5]),
            1e-5,
        );
    }

    #[test]
    fn grad_through_broadcast_add() {
        // bias broadcast over rows
        let tape = Tape::new();
        let w = tape.leaf(Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]));
        let b = tape.leaf(Tensor::from_vec(vec![0.1, 0.2, 0.3]));
        let y = w.add(&b).sum();
        let grads = tape.grad(&y, &[&w, &b]);
        assert_eq!(grads[0].to_vec(), vec![1.0; 6]);
        // bias adjoint accumulates over the broadcast (2 rows)
        assert_eq!(grads[1].to_vec(), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn grad_matmul() {
        let mut rng = Pcg64::new(5);
        let a0 = Tensor::randn(vec![3, 4], &mut rng);
        let b0 = Tensor::randn(vec![4, 2], &mut rng);
        let tape = Tape::new();
        let a = tape.leaf(a0.clone());
        let b = tape.leaf(b0.clone());
        let y = a.matmul(&b).square().sum();
        let grads = tape.grad(&y, &[&a, &b]);
        // finite differences on a
        let eps = 1e-6;
        for i in 0..a0.numel() {
            let mut plus = a0.to_vec();
            plus[i] += eps;
            let mut minus = a0.to_vec();
            minus[i] -= eps;
            let f = |a: Tensor| a.matmul(&b0).mul(&a.matmul(&b0)).sum();
            let fd = (f(Tensor::new(plus, vec![3, 4])) - f(Tensor::new(minus, vec![3, 4])))
                / (2.0 * eps);
            assert!((fd - grads[0].data()[i]).abs() < 1e-4, "{fd} vs {}", grads[0].data()[i]);
        }
    }

    #[test]
    fn grad_nonlinearities() {
        for f in [
            (|_: &Tape, x: &Var| x.tanh().sum()) as fn(&Tape, &Var) -> Var,
            |_, x| x.sigmoid().sum(),
            |_, x| x.softplus().sum(),
            |_, x| x.sqrt().sum(),
        ] {
            check_grad(f, Tensor::from_vec(vec![0.3, 1.2, 2.7]), 1e-4);
        }
    }

    #[test]
    fn grad_relu_masks() {
        let tape = Tape::new();
        let x = tape.leaf(Tensor::from_vec(vec![-1.0, 2.0, -3.0, 4.0]));
        let y = x.relu().sum();
        let g = tape.grad(&y, &[&x]).remove(0);
        assert_eq!(g.to_vec(), vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn grad_sum_last_and_sum0() {
        check_grad(
            |_, x| x.reshape(vec![2, 3]).sum_last().square().sum(),
            Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            1e-5,
        );
        check_grad(
            |_, x| x.reshape(vec![2, 3]).sum0().square().sum(),
            Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            1e-5,
        );
    }

    #[test]
    fn grad_reused_variable_accumulates() {
        // y = x*x + x  => dy/dx = 2x + 1
        let tape = Tape::new();
        let x = tape.leaf(Tensor::scalar(3.0));
        let y = x.mul(&x).add(&x);
        let g = tape.grad(&y, &[&x]).remove(0);
        assert!((g.item() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn unused_var_gets_zero_grad() {
        let tape = Tape::new();
        let x = tape.leaf(Tensor::scalar(1.0));
        let z = tape.leaf(Tensor::scalar(5.0));
        let y = x.square().sum();
        let g = tape.grad(&y, &[&z]).remove(0);
        assert_eq!(g.item(), 0.0);
    }

    #[test]
    fn gaussian_logprob_grad() {
        // d/dmu of log N(x|mu, sigma) = (x - mu)/sigma^2
        let (x, mu0, sigma) = (1.7, 0.4, 0.8);
        let tape = Tape::new();
        let mu = tape.leaf(Tensor::scalar(mu0));
        let diff = tape.scalar(x).sub(&mu);
        let lp = diff
            .square()
            .mul_scalar(-0.5 / (sigma * sigma))
            .add_scalar(-(sigma * (2.0 * std::f64::consts::PI).sqrt()).ln());
        let g = tape.grad(&lp.sum(), &[&mu]).remove(0);
        assert!((g.item() - (x - mu0) / (sigma * sigma)).abs() < 1e-10);
    }
}
