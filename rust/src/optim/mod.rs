//! Optimizers over the parameter store — `pyro.optim`.
//!
//! All optimizers act on *unconstrained* parameter values, keyed by name
//! with per-parameter state, and include `ClippedAdam` — the optimizer
//! Pyro itself ships (gradient clipping + multiplicative lr decay) and
//! the one the DMM paper configuration uses.

use crate::params::ParamStore;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// A first-order optimizer with per-parameter state.
pub trait Optimizer {
    /// New value for `param` given its gradient.
    fn step(&mut self, name: &str, param: &Tensor, grad: &Tensor) -> Tensor;

    /// End-of-step hook (lr schedules).
    fn finish_step(&mut self) {}
}

/// Apply one optimization step to every (name, grad) pair.
pub fn apply_grads(
    opt: &mut dyn Optimizer,
    store: &mut ParamStore,
    grads: &HashMap<String, Tensor>,
) {
    let mut names: Vec<&String> = grads.keys().collect();
    names.sort(); // deterministic update order
    for name in names {
        let p = store
            .get_unconstrained(name)
            .unwrap_or_else(|| panic!("grad for unknown param '{name}'"));
        let updated = opt.step(name, &p, &grads[name]);
        store.set_unconstrained(name, updated);
    }
    opt.finish_step();
}

// -------------------------------------------------------------------- SGD

#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    velocity: HashMap<String, Tensor>,
}

impl Sgd {
    pub fn new(lr: f64) -> Self {
        Sgd { lr, momentum: 0.0, velocity: HashMap::new() }
    }

    pub fn with_momentum(lr: f64, momentum: f64) -> Self {
        Sgd { lr, momentum, velocity: HashMap::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, name: &str, param: &Tensor, grad: &Tensor) -> Tensor {
        if self.momentum == 0.0 {
            return param.sub(&grad.mul_scalar(self.lr));
        }
        let v = self
            .velocity
            .entry(name.to_string())
            .or_insert_with(|| Tensor::zeros(param.dims().to_vec()));
        *v = v.mul_scalar(self.momentum).add(grad);
        param.sub(&v.mul_scalar(self.lr))
    }
}

// ------------------------------------------------------------------- Adam

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    state: HashMap<String, (Tensor, Tensor, u64)>, // (m, v, t)
}

impl Adam {
    pub fn new(lr: f64) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, state: HashMap::new() }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, name: &str, param: &Tensor, grad: &Tensor) -> Tensor {
        let (m, v, t) = self.state.entry(name.to_string()).or_insert_with(|| {
            (
                Tensor::zeros(param.dims().to_vec()),
                Tensor::zeros(param.dims().to_vec()),
                0,
            )
        });
        *t += 1;
        *m = m.mul_scalar(self.beta1).add(&grad.mul_scalar(1.0 - self.beta1));
        *v = v.mul_scalar(self.beta2).add(&grad.square().mul_scalar(1.0 - self.beta2));
        let bc1 = 1.0 - self.beta1.powi(*t as i32);
        let bc2 = 1.0 - self.beta2.powi(*t as i32);
        let m_hat = m.mul_scalar(1.0 / bc1);
        let v_hat = v.mul_scalar(1.0 / bc2);
        let denom = v_hat.sqrt().add_scalar(self.eps);
        param.sub(&m_hat.div(&denom).mul_scalar(self.lr))
    }
}

// ------------------------------------------------------------ ClippedAdam

/// Pyro's `ClippedAdam`: Adam with elementwise gradient clipping and a
/// multiplicative learning-rate decay `lrd` per step.
#[derive(Clone, Debug)]
pub struct ClippedAdam {
    pub base: Adam,
    pub clip_norm: f64,
    pub lrd: f64,
    lr0: f64,
    steps: u64,
}

impl ClippedAdam {
    pub fn new(lr: f64, clip_norm: f64, lrd: f64) -> Self {
        ClippedAdam { base: Adam::new(lr), clip_norm, lrd, lr0: lr, steps: 0 }
    }
}

impl Optimizer for ClippedAdam {
    fn step(&mut self, name: &str, param: &Tensor, grad: &Tensor) -> Tensor {
        let c = self.clip_norm;
        let clipped = grad.map(|g| g.clamp(-c, c));
        self.base.step(name, param, &clipped)
    }

    fn finish_step(&mut self) {
        self.steps += 1;
        self.base.lr = self.lr0 * self.lrd.powi(self.steps as i32);
    }
}

// ----------------------------------------------------------- lr schedules

/// Exponential decay helper for manual schedules.
pub fn exponential_decay(lr0: f64, gamma: f64, step: u64) -> f64 {
    lr0 * gamma.powi(step as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Tape;
    use crate::dist::Constraint;

    /// Minimize f(x) = (x - 3)^2 with each optimizer.
    fn minimize(opt: &mut dyn Optimizer, iters: usize) -> f64 {
        let mut store = ParamStore::new();
        store.get_or_init("x", || Tensor::scalar(0.0), Constraint::Real);
        for _ in 0..iters {
            let tape = Tape::new();
            let x = tape.leaf(store.get_unconstrained("x").unwrap());
            let loss = x.add_scalar(-3.0).square().sum();
            let g = tape.grad(&loss, &[&x]).remove(0);
            let mut grads = HashMap::new();
            grads.insert("x".to_string(), g);
            apply_grads(opt, &mut store, &grads);
        }
        store.get_unconstrained("x").unwrap().item()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut opt = Sgd::new(0.1);
        let x = minimize(&mut opt, 200);
        assert!((x - 3.0).abs() < 1e-6, "{x}");
    }

    #[test]
    fn sgd_momentum_converges() {
        let mut opt = Sgd::with_momentum(0.05, 0.9);
        let x = minimize(&mut opt, 300);
        assert!((x - 3.0).abs() < 1e-4, "{x}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut opt = Adam::new(0.1);
        let x = minimize(&mut opt, 500);
        assert!((x - 3.0).abs() < 1e-3, "{x}");
    }

    #[test]
    fn clipped_adam_clips_and_decays() {
        let mut opt = ClippedAdam::new(0.1, 1.0, 0.99);
        // huge gradient is clipped to 1.0 elementwise
        let p = Tensor::scalar(0.0);
        let g = Tensor::scalar(1e9);
        let p1 = opt.step("w", &p, &g);
        // first Adam step with any positive grad is exactly -lr
        assert!((p1.item() + 0.1).abs() < 1e-9, "{}", p1.item());
        opt.finish_step();
        assert!((opt.base.lr - 0.1 * 0.99).abs() < 1e-12);
        let x = {
            let mut o = ClippedAdam::new(0.2, 10.0, 0.999);
            minimize(&mut o, 500)
        };
        assert!((x - 3.0).abs() < 0.01, "{x}");
    }

    #[test]
    fn per_param_state_is_independent() {
        let mut opt = Adam::new(0.1);
        let p = Tensor::scalar(0.0);
        let g = Tensor::scalar(1.0);
        let a1 = opt.step("a", &p, &g);
        let b1 = opt.step("b", &p, &g);
        // both get the same first step despite sequential calls
        assert_eq!(a1.item(), b1.item());
    }

    #[test]
    #[should_panic(expected = "unknown param")]
    fn grads_for_unknown_param_panic() {
        let mut store = ParamStore::new();
        let mut opt = Sgd::new(0.1);
        let mut grads = HashMap::new();
        grads.insert("ghost".to_string(), Tensor::scalar(1.0));
        apply_grads(&mut opt, &mut store, &grads);
    }
}
