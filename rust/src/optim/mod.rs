//! Optimizers over the parameter store — `pyro.optim`.
//!
//! All optimizers act on *unconstrained* parameter values, keyed by name
//! with per-parameter state, and include `ClippedAdam` — the optimizer
//! Pyro itself ships (gradient clipping + multiplicative lr decay) and
//! the one the DMM paper configuration uses.
//!
//! The hot path is [`Optimizer::step_inplace`]: a single fused loop per
//! parameter that updates the moment buffers and the parameter storage
//! in place — no intermediate `m_hat`/`v_hat`/`denom` tensors, and zero
//! allocations once state exists. [`reference`] keeps the original
//! allocating implementation as the benchable baseline and the semantic
//! oracle for the fused kernels.

use crate::params::ParamStore;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// A first-order optimizer with per-parameter state.
pub trait Optimizer {
    /// Update `param` in place given its gradient (the hot path).
    fn step_inplace(&mut self, name: &str, param: &mut Tensor, grad: &Tensor);

    /// Allocating convenience wrapper around [`Optimizer::step_inplace`].
    fn step(&mut self, name: &str, param: &Tensor, grad: &Tensor) -> Tensor {
        let mut p = param.clone();
        self.step_inplace(name, &mut p, grad);
        p
    }

    /// End-of-step hook (lr schedules).
    fn finish_step(&mut self) {}
}

/// Apply one optimization step to every (name, grad) pair, mutating the
/// store's parameter buffers directly (no get/set round-trip clones).
pub fn apply_grads(
    opt: &mut dyn Optimizer,
    store: &mut ParamStore,
    grads: &HashMap<String, Tensor>,
) {
    let mut names: Vec<&String> = grads.keys().collect();
    names.sort(); // deterministic update order
    for name in names {
        assert!(store.contains(name), "grad for unknown param '{name}'");
        store.update_unconstrained(name, |p| opt.step_inplace(name, p, &grads[name]));
    }
    opt.finish_step();
}

// -------------------------------------------------------------------- SGD

#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    velocity: HashMap<String, Tensor>,
}

impl Sgd {
    pub fn new(lr: f64) -> Self {
        Sgd { lr, momentum: 0.0, velocity: HashMap::new() }
    }

    pub fn with_momentum(lr: f64, momentum: f64) -> Self {
        Sgd { lr, momentum, velocity: HashMap::new() }
    }
}

impl Optimizer for Sgd {
    fn step_inplace(&mut self, name: &str, param: &mut Tensor, grad: &Tensor) {
        assert_eq!(
            param.dims(),
            grad.dims(),
            "param/grad shape mismatch for '{name}'"
        );
        let (lr, mom) = (self.lr, self.momentum);
        if mom == 0.0 {
            param.axpy(-lr, grad);
            return;
        }
        // get_mut-first keeps the steady state allocation-free: `entry`
        // would clone the name into an owned key on every step.
        if !self.velocity.contains_key(name) {
            self.velocity
                .insert(name.to_string(), Tensor::zeros(param.dims().to_vec()));
        }
        let v = self.velocity.get_mut(name).unwrap();
        let vd = v.data_mut();
        let gd = grad.data();
        for (vi, &gi) in vd.iter_mut().zip(gd) {
            *vi = *vi * mom + gi;
        }
        param.axpy(-lr, v);
    }
}

// ------------------------------------------------------------------- Adam

#[derive(Clone, Debug)]
struct AdamState {
    m: Tensor,
    v: Tensor,
    t: u64,
}

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    state: HashMap<String, AdamState>,
}

impl Adam {
    pub fn new(lr: f64) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, state: HashMap::new() }
    }

    /// One fused pass: moments and parameter updated element-by-element
    /// with optional elementwise gradient clipping folded in. The
    /// floating-point operation order matches [`reference::AdamRef`]
    /// exactly, so the two are bitwise-identical.
    fn fused_step(&mut self, name: &str, param: &mut Tensor, grad: &Tensor, clip: Option<f64>) {
        assert_eq!(
            param.dims(),
            grad.dims(),
            "param/grad shape mismatch for '{name}'"
        );
        // get_mut-first keeps the steady state allocation-free: `entry`
        // would clone the name into an owned key on every step.
        if !self.state.contains_key(name) {
            self.state.insert(
                name.to_string(),
                AdamState {
                    m: Tensor::zeros(param.dims().to_vec()),
                    v: Tensor::zeros(param.dims().to_vec()),
                    t: 0,
                },
            );
        }
        let s = self.state.get_mut(name).unwrap();
        s.t += 1;
        let (b1, b2) = (self.beta1, self.beta2);
        let inv_bc1 = 1.0 / (1.0 - b1.powi(s.t as i32));
        let inv_bc2 = 1.0 / (1.0 - b2.powi(s.t as i32));
        let (lr, eps) = (self.lr, self.eps);
        let md = s.m.data_mut();
        let vd = s.v.data_mut();
        let gd = grad.data();
        let pd = param.data_mut();
        for i in 0..pd.len() {
            let mut g = gd[i];
            if let Some(c) = clip {
                g = g.clamp(-c, c);
            }
            let m = md[i] * b1 + g * (1.0 - b1);
            let v = vd[i] * b2 + (g * g) * (1.0 - b2);
            md[i] = m;
            vd[i] = v;
            pd[i] -= (m * inv_bc1) / ((v * inv_bc2).sqrt() + eps) * lr;
        }
    }
}

impl Optimizer for Adam {
    fn step_inplace(&mut self, name: &str, param: &mut Tensor, grad: &Tensor) {
        self.fused_step(name, param, grad, None);
    }
}

// ------------------------------------------------------------ ClippedAdam

/// Pyro's `ClippedAdam`: Adam with elementwise gradient clipping and a
/// multiplicative learning-rate decay `lrd` per step. The clip is fused
/// into the Adam update loop — no clipped-gradient temporary.
#[derive(Clone, Debug)]
pub struct ClippedAdam {
    pub base: Adam,
    pub clip_norm: f64,
    pub lrd: f64,
    lr0: f64,
    steps: u64,
}

impl ClippedAdam {
    pub fn new(lr: f64, clip_norm: f64, lrd: f64) -> Self {
        ClippedAdam { base: Adam::new(lr), clip_norm, lrd, lr0: lr, steps: 0 }
    }
}

impl Optimizer for ClippedAdam {
    fn step_inplace(&mut self, name: &str, param: &mut Tensor, grad: &Tensor) {
        let c = self.clip_norm;
        self.base.fused_step(name, param, grad, Some(c));
    }

    fn finish_step(&mut self) {
        self.steps += 1;
        self.base.lr = self.lr0 * self.lrd.powi(self.steps as i32);
    }
}

// ----------------------------------------------------------- lr schedules

/// Exponential decay helper for manual schedules.
pub fn exponential_decay(lr0: f64, gamma: f64, step: u64) -> f64 {
    lr0 * gamma.powi(step as i32)
}

// -------------------------------------------------------------- reference

pub mod reference {
    //! The pre-optimization optimizer implementations: ~8 fresh tensor
    //! allocations per parameter per step. Retained so the fig3 bench
    //! can measure the before/after gap inside one binary and so tests
    //! can pin the fused kernels to the original semantics.

    use super::Optimizer;
    use crate::tensor::Tensor;
    use std::collections::HashMap;

    /// The original allocating Adam.
    #[derive(Clone, Debug)]
    pub struct AdamRef {
        pub lr: f64,
        pub beta1: f64,
        pub beta2: f64,
        pub eps: f64,
        state: HashMap<String, (Tensor, Tensor, u64)>,
    }

    impl AdamRef {
        pub fn new(lr: f64) -> Self {
            AdamRef { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, state: HashMap::new() }
        }
    }

    impl Optimizer for AdamRef {
        fn step_inplace(&mut self, name: &str, param: &mut Tensor, grad: &Tensor) {
            let (m, v, t) = self.state.entry(name.to_string()).or_insert_with(|| {
                (
                    Tensor::zeros(param.dims().to_vec()),
                    Tensor::zeros(param.dims().to_vec()),
                    0,
                )
            });
            *t += 1;
            *m = m.mul_scalar(self.beta1).add(&grad.mul_scalar(1.0 - self.beta1));
            *v = v.mul_scalar(self.beta2).add(&grad.square().mul_scalar(1.0 - self.beta2));
            let bc1 = 1.0 - self.beta1.powi(*t as i32);
            let bc2 = 1.0 - self.beta2.powi(*t as i32);
            let m_hat = m.mul_scalar(1.0 / bc1);
            let v_hat = v.mul_scalar(1.0 / bc2);
            let denom = v_hat.sqrt().add_scalar(self.eps);
            *param = param.sub(&m_hat.div(&denom).mul_scalar(self.lr));
        }
    }

    /// The original allocating ClippedAdam (clip materializes a tensor).
    #[derive(Clone, Debug)]
    pub struct ClippedAdamRef {
        pub base: AdamRef,
        pub clip_norm: f64,
        pub lrd: f64,
        lr0: f64,
        steps: u64,
    }

    impl ClippedAdamRef {
        pub fn new(lr: f64, clip_norm: f64, lrd: f64) -> Self {
            ClippedAdamRef { base: AdamRef::new(lr), clip_norm, lrd, lr0: lr, steps: 0 }
        }
    }

    impl Optimizer for ClippedAdamRef {
        fn step_inplace(&mut self, name: &str, param: &mut Tensor, grad: &Tensor) {
            let c = self.clip_norm;
            let clipped = grad.map(|g| g.clamp(-c, c));
            self.base.step_inplace(name, param, &clipped);
        }

        fn finish_step(&mut self) {
            self.steps += 1;
            self.base.lr = self.lr0 * self.lrd.powi(self.steps as i32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Tape;
    use crate::dist::Constraint;
    use crate::tensor::Pcg64;

    /// Minimize f(x) = (x - 3)^2 with each optimizer.
    fn minimize(opt: &mut dyn Optimizer, iters: usize) -> f64 {
        let mut store = ParamStore::new();
        store.get_or_init("x", || Tensor::scalar(0.0), Constraint::Real);
        for _ in 0..iters {
            let tape = Tape::new();
            let x = tape.leaf(store.get_unconstrained("x").unwrap());
            let loss = x.add_scalar(-3.0).square().sum();
            let g = tape.grad(&loss, &[&x]).remove(0);
            let mut grads = HashMap::new();
            grads.insert("x".to_string(), g);
            apply_grads(opt, &mut store, &grads);
        }
        store.get_unconstrained("x").unwrap().item()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut opt = Sgd::new(0.1);
        let x = minimize(&mut opt, 200);
        assert!((x - 3.0).abs() < 1e-6, "{x}");
    }

    #[test]
    fn sgd_momentum_converges() {
        let mut opt = Sgd::with_momentum(0.05, 0.9);
        let x = minimize(&mut opt, 300);
        assert!((x - 3.0).abs() < 1e-4, "{x}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut opt = Adam::new(0.1);
        let x = minimize(&mut opt, 500);
        assert!((x - 3.0).abs() < 1e-3, "{x}");
    }

    #[test]
    fn clipped_adam_clips_and_decays() {
        let mut opt = ClippedAdam::new(0.1, 1.0, 0.99);
        // huge gradient is clipped to 1.0 elementwise
        let p = Tensor::scalar(0.0);
        let g = Tensor::scalar(1e9);
        let p1 = opt.step("w", &p, &g);
        // first Adam step with any positive grad is exactly -lr
        assert!((p1.item() + 0.1).abs() < 1e-9, "{}", p1.item());
        opt.finish_step();
        assert!((opt.base.lr - 0.1 * 0.99).abs() < 1e-12);
        let x = {
            let mut o = ClippedAdam::new(0.2, 10.0, 0.999);
            minimize(&mut o, 500)
        };
        assert!((x - 3.0).abs() < 0.01, "{x}");
    }

    #[test]
    fn per_param_state_is_independent() {
        let mut opt = Adam::new(0.1);
        let p = Tensor::scalar(0.0);
        let g = Tensor::scalar(1.0);
        let a1 = opt.step("a", &p, &g);
        let b1 = opt.step("b", &p, &g);
        // both get the same first step despite sequential calls
        assert_eq!(a1.item(), b1.item());
    }

    #[test]
    #[should_panic(expected = "unknown param")]
    fn grads_for_unknown_param_panic() {
        let mut store = ParamStore::new();
        let mut opt = Sgd::new(0.1);
        let mut grads = HashMap::new();
        grads.insert("ghost".to_string(), Tensor::scalar(1.0));
        apply_grads(&mut opt, &mut store, &grads);
    }

    #[test]
    fn fused_adam_matches_reference_bitwise() {
        let mut fast = Adam::new(0.05);
        let mut slow = reference::AdamRef::new(0.05);
        let mut rng = Pcg64::new(0xFAD);
        let mut p_fast = Tensor::randn(vec![17], &mut rng);
        let mut p_slow = p_fast.clone();
        for _ in 0..25 {
            let g = Tensor::randn(vec![17], &mut rng).mul_scalar(3.0);
            fast.step_inplace("w", &mut p_fast, &g);
            slow.step_inplace("w", &mut p_slow, &g);
        }
        assert_eq!(p_fast.to_vec(), p_slow.to_vec());
    }

    #[test]
    fn fused_clipped_adam_matches_reference_bitwise() {
        let mut fast = ClippedAdam::new(0.03, 0.5, 0.999);
        let mut slow = reference::ClippedAdamRef::new(0.03, 0.5, 0.999);
        let mut rng = Pcg64::new(0xC11);
        let mut p_fast = Tensor::randn(vec![9], &mut rng);
        let mut p_slow = p_fast.clone();
        for _ in 0..20 {
            let g = Tensor::randn(vec![9], &mut rng).mul_scalar(4.0);
            fast.step_inplace("w", &mut p_fast, &g);
            slow.step_inplace("w", &mut p_slow, &g);
            fast.finish_step();
            slow.finish_step();
        }
        assert_eq!(p_fast.to_vec(), p_slow.to_vec());
    }

    #[test]
    fn step_inplace_avoids_reallocating_unique_storage() {
        // pointer-level check that the fused path reuses the buffer
        let mut opt = Adam::new(0.1);
        let mut p = Tensor::from_vec(vec![1.0, 2.0, 3.0]);
        let g = Tensor::from_vec(vec![0.1, 0.2, 0.3]);
        opt.step_inplace("w", &mut p, &g); // state created here
        let before = p.data().as_ptr();
        opt.step_inplace("w", &mut p, &g);
        assert_eq!(before, p.data().as_ptr(), "fused step reallocated the param");
    }
}
