//! Synthetic datasets standing in for the paper's MNIST and JSB chorales
//! (no network access in this environment — see DESIGN.md §2).
//!
//! - [`SyntheticMnist`]: procedurally-drawn 28×28 binarized digit glyphs
//!   with stroke jitter and pixel noise. Preserves what the VAE needs:
//!   a multi-modal binary image distribution with low-dimensional class
//!   structure.
//! - [`SyntheticChorales`]: 4-voice harmonic progressions on an 88-key
//!   piano roll driven by a Markov chain over chord degrees. Preserves
//!   what the DMM needs: binary 88-dim frames with strong temporal
//!   correlation and polyphonic structure.
//!
//! On top of the datasets sits the data-parallel loading layer:
//! [`ShardedLoader`] abstracts "gather these rows into a flat f32
//! block" over in-memory ([`MemLoader`]) and on-disk streaming
//! ([`StreamLoader`]) storage, and [`ShardCursor`] walks one worker's
//! shard epoch by epoch with seeded shuffles that are reproducible
//! across process restarts (the shuffle for epoch `e` depends only on
//! the cursor seed and `e`, never on history).

use crate::error::{Error, Result};
use crate::tensor::Pcg64;
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::Mutex;

/// f32 design matrix [n, 784] plus labels, split into train/test.
pub struct SyntheticMnist {
    pub train: Vec<Vec<f32>>,
    pub test: Vec<Vec<f32>>,
    pub train_labels: Vec<usize>,
    pub test_labels: Vec<usize>,
}

/// 7-segment-style digit strokes on a 28x28 canvas.
/// Segments: 0 top, 1 top-left, 2 top-right, 3 middle, 4 bottom-left,
/// 5 bottom-right, 6 bottom.
const DIGIT_SEGMENTS: [&[usize]; 10] = [
    &[0, 1, 2, 4, 5, 6],    // 0
    &[2, 5],                // 1
    &[0, 2, 3, 4, 6],       // 2
    &[0, 2, 3, 5, 6],       // 3
    &[1, 2, 3, 5],          // 4
    &[0, 1, 3, 5, 6],       // 5
    &[0, 1, 3, 4, 5, 6],    // 6
    &[0, 2, 5],             // 7
    &[0, 1, 2, 3, 4, 5, 6], // 8
    &[0, 1, 2, 3, 5, 6],    // 9
];

fn draw_digit(digit: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut img = vec![0.0f32; 28 * 28];
    // jittered bounding box
    let x0 = 6 + rng.below(4) as i32;
    let y0 = 4 + rng.below(4) as i32;
    let w = 12 + rng.below(5) as i32;
    let h = 16 + rng.below(5) as i32;
    let thick = 2 + rng.below(2) as i32;
    let line = |xa: i32, ya: i32, xb: i32, yb: i32, img: &mut Vec<f32>| {
        let steps = (xb - xa).abs().max((yb - ya).abs()).max(1);
        for s in 0..=steps {
            let x = xa + (xb - xa) * s / steps;
            let y = ya + (yb - ya) * s / steps;
            for dx in 0..thick {
                for dy in 0..thick {
                    let (px, py) = (x + dx, y + dy);
                    if (0..28).contains(&px) && (0..28).contains(&py) {
                        img[(py * 28 + px) as usize] = 1.0;
                    }
                }
            }
        }
    };
    let mid = y0 + h / 2;
    for &seg in DIGIT_SEGMENTS[digit] {
        match seg {
            0 => line(x0, y0, x0 + w, y0, &mut img),
            1 => line(x0, y0, x0, mid, &mut img),
            2 => line(x0 + w, y0, x0 + w, mid, &mut img),
            3 => line(x0, mid, x0 + w, mid, &mut img),
            4 => line(x0, mid, x0, y0 + h, &mut img),
            5 => line(x0 + w, mid, x0 + w, y0 + h, &mut img),
            6 => line(x0, y0 + h, x0 + w, y0 + h, &mut img),
            _ => unreachable!(),
        }
    }
    // salt-and-pepper noise: flip ~1.5% of pixels
    for p in img.iter_mut() {
        if rng.uniform() < 0.015 {
            *p = 1.0 - *p;
        }
    }
    img
}

impl SyntheticMnist {
    pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let mut gen = |n: usize| {
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for _ in 0..n {
                let d = rng.below(10);
                xs.push(draw_digit(d, &mut rng));
                ys.push(d);
            }
            (xs, ys)
        };
        let (train, train_labels) = gen(n_train);
        let (test, test_labels) = gen(n_test);
        SyntheticMnist { train, test, train_labels, test_labels }
    }
}

/// [n][T][88] binary piano rolls.
pub struct SyntheticChorales {
    pub train: Vec<Vec<Vec<f32>>>,
    pub test: Vec<Vec<Vec<f32>>>,
}

/// Diatonic scale degrees (semitone offsets) of a major key.
const SCALE: [usize; 7] = [0, 2, 4, 5, 7, 9, 11];
/// Chord-degree transition weights (I ii iii IV V vi vii°): classic
/// functional-harmony tendencies.
const CHORD_TRANS: [[f64; 7]; 7] = [
    [0.10, 0.10, 0.05, 0.30, 0.30, 0.10, 0.05], // I ->
    [0.05, 0.05, 0.05, 0.10, 0.55, 0.10, 0.10], // ii ->
    [0.10, 0.10, 0.05, 0.25, 0.15, 0.30, 0.05], // iii ->
    [0.30, 0.10, 0.05, 0.05, 0.35, 0.05, 0.10], // IV ->
    [0.55, 0.05, 0.05, 0.10, 0.05, 0.15, 0.05], // V ->
    [0.10, 0.25, 0.10, 0.25, 0.15, 0.05, 0.10], // vi ->
    [0.60, 0.05, 0.05, 0.05, 0.15, 0.05, 0.05], // vii ->
];

fn chorale(t_len: usize, rng: &mut Pcg64) -> Vec<Vec<f32>> {
    let key = 21 + rng.below(12); // tonic in MIDI, mapped to key 0..87
    let mut degree = 0usize; // start on I
    let mut roll = Vec::with_capacity(t_len);
    for step in 0..t_len {
        if step % 2 == 0 && step > 0 {
            degree = rng.categorical(&CHORD_TRANS[degree]);
        }
        let mut frame = vec![0.0f32; 88];
        // 4 voices: root, third, fifth (+ octave root), soprano jitter
        let triad = [0usize, 2, 4];
        for (v, &off) in triad.iter().enumerate() {
            let scale_deg = (degree + off) % 7;
            let octave = 12 * (v + 2);
            let pitch = key + SCALE[scale_deg] + octave - 21;
            if pitch < 88 {
                frame[pitch] = 1.0;
            }
        }
        // bass: root two octaves down
        let bass = key + SCALE[degree % 7];
        if bass >= 21 {
            let p = bass - 21;
            if p < 88 {
                frame[p] = 1.0;
            }
        }
        // passing-tone noise
        if rng.uniform() < 0.2 {
            let p = rng.below(88);
            frame[p] = 1.0;
        }
        roll.push(frame);
    }
    roll
}

impl SyntheticChorales {
    pub fn generate(n_train: usize, n_test: usize, t_len: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let train = (0..n_train).map(|_| chorale(t_len, &mut rng)).collect();
        let test = (0..n_test).map(|_| chorale(t_len, &mut rng)).collect();
        SyntheticChorales { train, test }
    }
}

/// Shuffled mini-batch index iterator (one epoch).
pub struct BatchIter {
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, rng: &mut Pcg64) -> Self {
        BatchIter { order: rng.permutation(n), batch, pos: 0 }
    }

    /// Reshuffle in place for a new epoch without reallocating the
    /// index buffer. Consumes the same RNG stream as [`BatchIter::new`],
    /// so `new` + N×`reset` matches N+1 fresh iterators bitwise.
    pub fn reset(&mut self, rng: &mut Pcg64) {
        let n = self.order.len();
        rng.permutation_into(n, &mut self.order);
        self.pos = 0;
    }

    /// Allocation-free [`Iterator::next`]: writes the next batch's
    /// indices into `out` (cleared first) and returns `false` at the
    /// epoch boundary (same drop-last semantics as the iterator).
    pub fn next_into(&mut self, out: &mut Vec<usize>) -> bool {
        if self.pos + self.batch > self.order.len() {
            return false;
        }
        out.clear();
        out.extend_from_slice(&self.order[self.pos..self.pos + self.batch]);
        self.pos += self.batch;
        true
    }
}

impl Iterator for BatchIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        // drop the ragged tail (standard drop_last=True semantics)
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let out = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        Some(out)
    }
}

/// Gather a [batch, 784] f32 matrix from row indices.
pub fn gather_images(data: &[Vec<f32>], idx: &[usize]) -> Vec<f32> {
    let d = data[0].len();
    let mut out = Vec::with_capacity(idx.len() * d);
    for &i in idx {
        out.extend_from_slice(&data[i]);
    }
    out
}

/// Gather a [batch, T, 88] f32 block from sequence indices.
pub fn gather_rolls(data: &[Vec<Vec<f32>>], idx: &[usize]) -> Vec<f32> {
    let t = data[0].len();
    let d = data[0][0].len();
    let mut out = Vec::with_capacity(idx.len() * t * d);
    for &i in idx {
        for frame in &data[i] {
            out.extend_from_slice(frame);
        }
    }
    let _ = (t, d);
    out
}

/// [`gather_images`] into a caller-owned buffer: allocation-free in
/// steady state once `out` has grown to batch capacity.
pub fn gather_images_into(data: &[Vec<f32>], idx: &[usize], out: &mut Vec<f32>) {
    out.clear();
    for &i in idx {
        out.extend_from_slice(&data[i]);
    }
}

/// [`gather_rolls`] into a caller-owned buffer: allocation-free in
/// steady state once `out` has grown to batch capacity.
pub fn gather_rolls_into(data: &[Vec<Vec<f32>>], idx: &[usize], out: &mut Vec<f32>) {
    out.clear();
    for &i in idx {
        for frame in &data[i] {
            out.extend_from_slice(frame);
        }
    }
}

// ---------------------------------------------------- sharded loading

/// A dataset that serves arbitrary rows as flat f32 blocks, without the
/// caller knowing whether rows live in RAM or stream from disk. `Sync`
/// so data-parallel workers can gather their shards concurrently from
/// one shared loader.
pub trait ShardedLoader: Sync {
    /// Total rows in the dataset.
    fn len(&self) -> usize;

    /// Per-row dims (e.g. `[784]` for images, `[T, 88]` for rolls).
    fn row_dims(&self) -> &[usize];

    /// Gather rows `idx` (dataset-global indices) into `out` as a
    /// row-major `[idx.len(), row_numel]` block. `out` is cleared
    /// first; implementations must not allocate in steady state once
    /// `out` (and any internal scratch) has reached batch capacity.
    fn gather_into(&self, idx: &[usize], out: &mut Vec<f32>) -> Result<()>;

    /// Elements per row.
    fn row_numel(&self) -> usize {
        self.row_dims().iter().product()
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// In-memory [`ShardedLoader`]: rows flattened into one contiguous
/// block (cache-friendly gathers, and the exact layout [`StreamLoader`]
/// writes to disk).
pub struct MemLoader {
    flat: Vec<f32>,
    dims: Vec<usize>,
    n: usize,
}

impl MemLoader {
    /// Build from per-row slices; every row must have `dims` numel.
    pub fn from_rows<'a>(rows: impl IntoIterator<Item = &'a [f32]>, dims: Vec<usize>) -> MemLoader {
        let numel: usize = dims.iter().product();
        let mut flat = Vec::new();
        let mut n = 0usize;
        for row in rows {
            assert_eq!(row.len(), numel, "row {n} has {} elements, dims want {numel}", row.len());
            flat.extend_from_slice(row);
            n += 1;
        }
        MemLoader { flat, dims, n }
    }

    /// [n, 784]-style image rows (one `Vec<f32>` per row).
    pub fn from_images(rows: &[Vec<f32>]) -> MemLoader {
        let d = rows.first().map(|r| r.len()).unwrap_or(0);
        MemLoader::from_rows(rows.iter().map(|r| r.as_slice()), vec![d])
    }

    /// [n][T][88] piano rolls, flattened to one `[T, 88]` row each.
    pub fn from_rolls(rolls: &[Vec<Vec<f32>>]) -> MemLoader {
        let t = rolls.first().map(|r| r.len()).unwrap_or(0);
        let d = rolls.first().and_then(|r| r.first()).map(|f| f.len()).unwrap_or(0);
        let numel = t * d;
        let mut flat = Vec::with_capacity(rolls.len() * numel);
        for roll in rolls {
            assert_eq!(roll.len(), t, "ragged roll lengths");
            for frame in roll {
                flat.extend_from_slice(frame);
            }
        }
        MemLoader { flat, dims: vec![t, d], n: rolls.len() }
    }
}

impl ShardedLoader for MemLoader {
    fn len(&self) -> usize {
        self.n
    }

    fn row_dims(&self) -> &[usize] {
        &self.dims
    }

    fn gather_into(&self, idx: &[usize], out: &mut Vec<f32>) -> Result<()> {
        let numel = self.row_numel();
        out.clear();
        for &i in idx {
            if i >= self.n {
                return Err(Error::msg(format!("row {i} out of range (n = {})", self.n)));
            }
            out.extend_from_slice(&self.flat[i * numel..(i + 1) * numel]);
        }
        Ok(())
    }
}

const STREAM_MAGIC: &[u8; 8] = b"FYRODS01";

struct StreamInner {
    file: std::fs::File,
    /// Per-row byte scratch, retained so epoch-steady gathers touch the
    /// allocator zero times.
    buf: Vec<u8>,
}

/// On-disk streaming [`ShardedLoader`]: rows are read per batch from a
/// little-endian f32 file written by [`StreamLoader::create`], so an
/// epoch never materializes the dataset in memory. The file handle is
/// behind a mutex (seek + read must be atomic per row); workers gather
/// whole batches under one lock, and the OS page cache keeps repeat
/// epochs cheap.
pub struct StreamLoader {
    inner: Mutex<StreamInner>,
    n: usize,
    dims: Vec<usize>,
    data_off: u64,
}

impl StreamLoader {
    /// Write a dataset file from row slices; returns rows written.
    /// Layout: 8-byte magic, u64 row count, u32 rank, rank×u64 dims,
    /// then `n × numel` little-endian f32s.
    pub fn create<'a>(
        path: &str,
        dims: &[usize],
        rows: impl IntoIterator<Item = &'a [f32]>,
    ) -> Result<usize> {
        let numel: usize = dims.iter().product();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(STREAM_MAGIC)?;
        f.write_all(&0u64.to_le_bytes())?; // row count backpatched below
        f.write_all(&(dims.len() as u32).to_le_bytes())?;
        for &d in dims {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let mut n = 0usize;
        for row in rows {
            if row.len() != numel {
                return Err(Error::msg(format!(
                    "row {n} has {} elements, dims want {numel}",
                    row.len()
                )));
            }
            for &v in row {
                f.write_all(&v.to_le_bytes())?;
            }
            n += 1;
        }
        let mut f = f.into_inner().map_err(|e| Error::msg(format!("flush: {e}")))?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&(n as u64).to_le_bytes())?;
        f.sync_all()?;
        Ok(n)
    }

    /// Open a dataset file written by [`StreamLoader::create`].
    pub fn open(path: &str) -> Result<StreamLoader> {
        let mut file = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic)?;
        if &magic != STREAM_MAGIC {
            return Err(Error::msg(format!("'{path}' is not a fyro dataset file")));
        }
        let mut u64buf = [0u8; 8];
        file.read_exact(&mut u64buf)?;
        let n = u64::from_le_bytes(u64buf) as usize;
        let mut u32buf = [0u8; 4];
        file.read_exact(&mut u32buf)?;
        let rank = u32::from_le_bytes(u32buf) as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            file.read_exact(&mut u64buf)?;
            dims.push(u64::from_le_bytes(u64buf) as usize);
        }
        let data_off = (8 + 8 + 4 + 8 * rank) as u64;
        let numel: usize = dims.iter().product();
        let expect = data_off + (n * numel * 4) as u64;
        let actual = file.seek(SeekFrom::End(0))?;
        if actual != expect {
            return Err(Error::msg(format!(
                "dataset '{path}' truncated: {actual} bytes, header promises {expect}"
            )));
        }
        Ok(StreamLoader {
            inner: Mutex::new(StreamInner { file, buf: Vec::new() }),
            n,
            dims,
            data_off,
        })
    }
}

impl ShardedLoader for StreamLoader {
    fn len(&self) -> usize {
        self.n
    }

    fn row_dims(&self) -> &[usize] {
        &self.dims
    }

    fn gather_into(&self, idx: &[usize], out: &mut Vec<f32>) -> Result<()> {
        let numel = self.row_numel();
        let row_bytes = numel * 4;
        out.clear();
        let mut g = self.inner.lock().map_err(|_| Error::msg("stream loader poisoned"))?;
        let StreamInner { file, buf } = &mut *g;
        buf.resize(row_bytes, 0);
        for &i in idx {
            if i >= self.n {
                return Err(Error::msg(format!("row {i} out of range (n = {})", self.n)));
            }
            file.seek(SeekFrom::Start(self.data_off + (i * row_bytes) as u64))?;
            file.read_exact(buf)?;
            for c in buf.chunks_exact(4) {
                out.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
        }
        Ok(())
    }
}

/// Contiguous row range `[lo, lo + n)` of worker `shard` when the
/// dataset is split as evenly as possible over `num_shards` workers
/// (leading shards take the remainder rows).
pub fn shard_bounds(total: usize, num_shards: usize, shard: usize) -> (usize, usize) {
    assert!(num_shards > 0 && shard < num_shards, "shard {shard} of {num_shards}");
    let base = total / num_shards;
    let rem = total % num_shards;
    let n = base + usize::from(shard < rem);
    let lo = shard * base + shard.min(rem);
    (lo, n)
}

/// One worker's epoch-streaming position inside its shard: yields
/// shuffled drop-last batches of **global** row indices, rolling into
/// the next epoch at the shard boundary. The shuffle for epoch `e` is
/// `Pcg64::new(seed ^ hash(e))` — a pure function of (seed, epoch) —
/// so [`ShardCursor::restore`] reproduces the exact batch sequence
/// after a process restart, and two cursors with the same seed walk
/// identical orders regardless of history.
pub struct ShardCursor {
    lo: usize,
    n: usize,
    batch: usize,
    seed: u64,
    epoch: u64,
    pos: usize,
    order: Vec<usize>,
    idx: Vec<usize>,
}

impl ShardCursor {
    pub fn new(lo: usize, n: usize, batch: usize, seed: u64) -> ShardCursor {
        assert!(batch > 0 && batch <= n, "batch {batch} does not fit shard of {n} rows");
        let mut c = ShardCursor {
            lo,
            n,
            batch,
            seed,
            epoch: 0,
            pos: 0,
            order: Vec::with_capacity(n),
            idx: Vec::with_capacity(batch),
        };
        c.reshuffle();
        c
    }

    /// Cursor for worker `shard`'s slice of `loader`, seeded per shard.
    pub fn for_shard(
        loader: &dyn ShardedLoader,
        num_shards: usize,
        shard: usize,
        batch: usize,
        base_seed: u64,
    ) -> ShardCursor {
        let (lo, n) = shard_bounds(loader.len(), num_shards, shard);
        ShardCursor::new(lo, n, batch, base_seed ^ (shard as u64).wrapping_mul(0x9E3779B97F4A7C15))
    }

    fn reshuffle(&mut self) {
        let mut rng = Pcg64::new(self.seed ^ self.epoch.wrapping_mul(0xD1B54A32D192ED03));
        rng.permutation_into(self.n, &mut self.order);
    }

    /// The next batch of global row indices. Allocation-free in steady
    /// state (the shuffle and batch buffers are reused across epochs).
    pub fn next_batch(&mut self) -> &[usize] {
        if self.pos + self.batch > self.n {
            self.epoch += 1;
            self.pos = 0;
            self.reshuffle();
        }
        self.idx.clear();
        for &o in &self.order[self.pos..self.pos + self.batch] {
            self.idx.push(self.lo + o);
        }
        self.pos += self.batch;
        &self.idx
    }

    /// Resumable position: `(epoch, offset)` *before* the next batch.
    pub fn state(&self) -> (u64, usize) {
        (self.epoch, self.pos)
    }

    /// Jump to a saved [`ShardCursor::state`], replaying that epoch's
    /// shuffle; subsequent batches match the original run exactly.
    pub fn restore(&mut self, epoch: u64, pos: usize) {
        assert!(pos <= self.n, "restore offset {pos} past shard of {} rows", self.n);
        self.epoch = epoch;
        self.pos = pos;
        self.reshuffle();
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Rows in this cursor's shard.
    pub fn shard_len(&self) -> usize {
        self.n
    }

    /// Batches per epoch (drop-last).
    pub fn batches_per_epoch(&self) -> usize {
        self.n / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_shapes_and_binary() {
        let ds = SyntheticMnist::generate(100, 20, 1);
        assert_eq!(ds.train.len(), 100);
        assert_eq!(ds.test.len(), 20);
        for img in &ds.train {
            assert_eq!(img.len(), 784);
            assert!(img.iter().all(|&p| p == 0.0 || p == 1.0));
        }
        // digits are distinguishable: per-class mean images differ
        let mean_img = |d: usize| -> Vec<f32> {
            let rows: Vec<&Vec<f32>> = ds
                .train
                .iter()
                .zip(&ds.train_labels)
                .filter(|(_, &l)| l == d)
                .map(|(x, _)| x)
                .collect();
            let mut m = vec![0.0; 784];
            for r in &rows {
                for (a, &b) in m.iter_mut().zip(r.iter()) {
                    *a += b;
                }
            }
            m.iter().map(|&x| x / rows.len().max(1) as f32).collect()
        };
        let m1 = mean_img(1);
        let m8 = mean_img(8);
        let diff: f32 = m1.iter().zip(&m8).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 20.0, "digit classes look identical ({diff})");
    }

    #[test]
    fn mnist_deterministic_given_seed() {
        let a = SyntheticMnist::generate(10, 0, 7);
        let b = SyntheticMnist::generate(10, 0, 7);
        assert_eq!(a.train, b.train);
    }

    #[test]
    fn chorales_shapes_and_polyphony() {
        let ds = SyntheticChorales::generate(20, 5, 32, 2);
        assert_eq!(ds.train.len(), 20);
        for roll in &ds.train {
            assert_eq!(roll.len(), 32);
            for frame in roll {
                assert_eq!(frame.len(), 88);
                let notes: f32 = frame.iter().sum();
                assert!((1.0..=8.0).contains(&notes), "{notes} notes in frame");
            }
        }
    }

    #[test]
    fn chorales_temporal_correlation() {
        // consecutive frames share most notes (chords held 2 steps)
        let ds = SyntheticChorales::generate(50, 0, 32, 3);
        let mut same = 0.0;
        let mut total = 0.0;
        for roll in &ds.train {
            for t in (0..roll.len() - 1).step_by(2) {
                let overlap: f32 =
                    roll[t].iter().zip(&roll[t + 1]).map(|(a, b)| a * b).sum();
                let notes: f32 = roll[t].iter().sum();
                same += overlap;
                total += notes;
            }
        }
        assert!(same / total > 0.8, "weak temporal structure: {}", same / total);
    }

    #[test]
    fn batch_iter_covers_epoch_without_repeats() {
        let mut rng = Pcg64::new(4);
        let batches: Vec<Vec<usize>> = BatchIter::new(100, 32, &mut rng).collect();
        assert_eq!(batches.len(), 3); // 96 used, ragged 4 dropped
        let mut seen: Vec<usize> = batches.concat();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 96);
    }

    #[test]
    fn gather_images_layout() {
        let data = vec![vec![0.0f32; 4], vec![1.0; 4], vec![2.0; 4]];
        let g = gather_images(&data, &[2, 0]);
        assert_eq!(g, vec![2.0, 2.0, 2.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn next_into_matches_iterator_and_reset_matches_fresh() {
        // next_into consumes the same permutation stream as the iterator
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        let alloc: Vec<Vec<usize>> = BatchIter::new(50, 16, &mut a).collect();
        let mut it = BatchIter::new(50, 16, &mut b);
        let mut buf = Vec::new();
        let mut inplace = Vec::new();
        while it.next_into(&mut buf) {
            inplace.push(buf.clone());
        }
        assert_eq!(alloc, inplace);
        // reset == a fresh iterator drawing from the same RNG position
        let fresh: Vec<Vec<usize>> = BatchIter::new(50, 16, &mut a).collect();
        it.reset(&mut b);
        let mut second = Vec::new();
        while it.next_into(&mut buf) {
            second.push(buf.clone());
        }
        assert_eq!(fresh, second);
    }

    #[test]
    fn gather_into_variants_match_allocating() {
        let imgs = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut out = Vec::new();
        gather_images_into(&imgs, &[1, 2, 0], &mut out);
        assert_eq!(out, gather_images(&imgs, &[1, 2, 0]));
        let rolls = vec![
            vec![vec![1.0f32, 0.0], vec![0.0, 1.0]],
            vec![vec![2.0, 2.0], vec![3.0, 3.0]],
        ];
        gather_rolls_into(&rolls, &[1, 0], &mut out);
        assert_eq!(out, gather_rolls(&rolls, &[1, 0]));
    }

    #[test]
    fn mem_and_stream_loaders_agree() {
        let imgs: Vec<Vec<f32>> =
            (0..17).map(|i| (0..5).map(|j| (i * 5 + j) as f32).collect()).collect();
        let mem = MemLoader::from_images(&imgs);
        assert_eq!(mem.len(), 17);
        assert_eq!(mem.row_dims(), &[5]);
        let path = std::env::temp_dir().join("fyro_stream_test.bin");
        let path = path.to_str().unwrap();
        let n = StreamLoader::create(path, &[5], imgs.iter().map(|r| r.as_slice())).unwrap();
        assert_eq!(n, 17);
        let disk = StreamLoader::open(path).unwrap();
        assert_eq!(disk.len(), 17);
        assert_eq!(disk.row_dims(), &[5]);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for idx in [vec![0usize, 16, 7], vec![3, 3, 3], (0..17).collect()] {
            mem.gather_into(&idx, &mut a).unwrap();
            disk.gather_into(&idx, &mut b).unwrap();
            assert_eq!(a, b);
        }
        assert!(disk.gather_into(&[17], &mut b).is_err(), "oob row must fail");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stream_loader_rejects_truncated_file() {
        let rows: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 3]).collect();
        let path = std::env::temp_dir().join("fyro_stream_trunc.bin");
        let path = path.to_str().unwrap();
        StreamLoader::create(path, &[3], rows.iter().map(|r| r.as_slice())).unwrap();
        let bytes = std::fs::read(path).unwrap();
        std::fs::write(path, &bytes[..bytes.len() - 5]).unwrap();
        let err = StreamLoader::open(path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "unexpected error: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shard_bounds_partition_the_dataset() {
        for (total, shards) in [(100, 4), (101, 4), (7, 3), (8, 8)] {
            let mut covered = 0;
            for w in 0..shards {
                let (lo, n) = shard_bounds(total, shards, w);
                assert_eq!(lo, covered, "shards must be contiguous");
                covered += n;
            }
            assert_eq!(covered, total, "shards must cover every row");
        }
    }

    #[test]
    fn shard_cursor_covers_epoch_and_restores() {
        let mut c = ShardCursor::new(10, 20, 8, 0xC0FFEE);
        // one epoch = 2 drop-last batches, all inside [10, 30), no repeats
        let mut seen: Vec<usize> = Vec::new();
        for _ in 0..c.batches_per_epoch() {
            let b = c.next_batch().to_vec();
            assert!(b.iter().all(|&i| (10..30).contains(&i)));
            seen.extend(b);
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 16, "repeats within an epoch");
        assert_eq!(c.epoch(), 0);
        // walk into epoch 2, snapshot, continue, then restore and replay
        for _ in 0..3 {
            c.next_batch();
        }
        let (epoch, pos) = c.state();
        let tail: Vec<Vec<usize>> = (0..5).map(|_| c.next_batch().to_vec()).collect();
        let mut fresh = ShardCursor::new(10, 20, 8, 0xC0FFEE);
        fresh.restore(epoch, pos);
        let replay: Vec<Vec<usize>> = (0..5).map(|_| fresh.next_batch().to_vec()).collect();
        assert_eq!(tail, replay, "restart must reproduce the batch stream");
    }

    #[test]
    fn shard_cursors_differ_across_shards_and_epochs() {
        let imgs: Vec<Vec<f32>> = (0..32).map(|i| vec![i as f32]).collect();
        let mem = MemLoader::from_images(&imgs);
        let mut c0 = ShardCursor::for_shard(&mem, 2, 0, 4, 7);
        let mut c1 = ShardCursor::for_shard(&mem, 2, 1, 4, 7);
        let b0 = c0.next_batch().to_vec();
        let b1 = c1.next_batch().to_vec();
        assert!(b0.iter().all(|&i| i < 16));
        assert!(b1.iter().all(|&i| (16..32).contains(&i)));
        // epoch shuffles differ
        let e0: Vec<usize> = (0..c0.batches_per_epoch() * 2)
            .flat_map(|_| c0.next_batch().to_vec())
            .collect();
        assert!(e0.windows(2).any(|w| w[0] != w[1]), "shuffle looks degenerate: {e0:?}");
    }
}
