//! Synthetic datasets standing in for the paper's MNIST and JSB chorales
//! (no network access in this environment — see DESIGN.md §2).
//!
//! - [`SyntheticMnist`]: procedurally-drawn 28×28 binarized digit glyphs
//!   with stroke jitter and pixel noise. Preserves what the VAE needs:
//!   a multi-modal binary image distribution with low-dimensional class
//!   structure.
//! - [`SyntheticChorales`]: 4-voice harmonic progressions on an 88-key
//!   piano roll driven by a Markov chain over chord degrees. Preserves
//!   what the DMM needs: binary 88-dim frames with strong temporal
//!   correlation and polyphonic structure.

use crate::tensor::Pcg64;

/// f32 design matrix [n, 784] plus labels, split into train/test.
pub struct SyntheticMnist {
    pub train: Vec<Vec<f32>>,
    pub test: Vec<Vec<f32>>,
    pub train_labels: Vec<usize>,
    pub test_labels: Vec<usize>,
}

/// 7-segment-style digit strokes on a 28x28 canvas.
/// Segments: 0 top, 1 top-left, 2 top-right, 3 middle, 4 bottom-left,
/// 5 bottom-right, 6 bottom.
const DIGIT_SEGMENTS: [&[usize]; 10] = [
    &[0, 1, 2, 4, 5, 6],    // 0
    &[2, 5],                // 1
    &[0, 2, 3, 4, 6],       // 2
    &[0, 2, 3, 5, 6],       // 3
    &[1, 2, 3, 5],          // 4
    &[0, 1, 3, 5, 6],       // 5
    &[0, 1, 3, 4, 5, 6],    // 6
    &[0, 2, 5],             // 7
    &[0, 1, 2, 3, 4, 5, 6], // 8
    &[0, 1, 2, 3, 5, 6],    // 9
];

fn draw_digit(digit: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut img = vec![0.0f32; 28 * 28];
    // jittered bounding box
    let x0 = 6 + rng.below(4) as i32;
    let y0 = 4 + rng.below(4) as i32;
    let w = 12 + rng.below(5) as i32;
    let h = 16 + rng.below(5) as i32;
    let thick = 2 + rng.below(2) as i32;
    let line = |xa: i32, ya: i32, xb: i32, yb: i32, img: &mut Vec<f32>| {
        let steps = (xb - xa).abs().max((yb - ya).abs()).max(1);
        for s in 0..=steps {
            let x = xa + (xb - xa) * s / steps;
            let y = ya + (yb - ya) * s / steps;
            for dx in 0..thick {
                for dy in 0..thick {
                    let (px, py) = (x + dx, y + dy);
                    if (0..28).contains(&px) && (0..28).contains(&py) {
                        img[(py * 28 + px) as usize] = 1.0;
                    }
                }
            }
        }
    };
    let mid = y0 + h / 2;
    for &seg in DIGIT_SEGMENTS[digit] {
        match seg {
            0 => line(x0, y0, x0 + w, y0, &mut img),
            1 => line(x0, y0, x0, mid, &mut img),
            2 => line(x0 + w, y0, x0 + w, mid, &mut img),
            3 => line(x0, mid, x0 + w, mid, &mut img),
            4 => line(x0, mid, x0, y0 + h, &mut img),
            5 => line(x0 + w, mid, x0 + w, y0 + h, &mut img),
            6 => line(x0, y0 + h, x0 + w, y0 + h, &mut img),
            _ => unreachable!(),
        }
    }
    // salt-and-pepper noise: flip ~1.5% of pixels
    for p in img.iter_mut() {
        if rng.uniform() < 0.015 {
            *p = 1.0 - *p;
        }
    }
    img
}

impl SyntheticMnist {
    pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let mut gen = |n: usize| {
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for _ in 0..n {
                let d = rng.below(10);
                xs.push(draw_digit(d, &mut rng));
                ys.push(d);
            }
            (xs, ys)
        };
        let (train, train_labels) = gen(n_train);
        let (test, test_labels) = gen(n_test);
        SyntheticMnist { train, test, train_labels, test_labels }
    }
}

/// [n][T][88] binary piano rolls.
pub struct SyntheticChorales {
    pub train: Vec<Vec<Vec<f32>>>,
    pub test: Vec<Vec<Vec<f32>>>,
}

/// Diatonic scale degrees (semitone offsets) of a major key.
const SCALE: [usize; 7] = [0, 2, 4, 5, 7, 9, 11];
/// Chord-degree transition weights (I ii iii IV V vi vii°): classic
/// functional-harmony tendencies.
const CHORD_TRANS: [[f64; 7]; 7] = [
    [0.10, 0.10, 0.05, 0.30, 0.30, 0.10, 0.05], // I ->
    [0.05, 0.05, 0.05, 0.10, 0.55, 0.10, 0.10], // ii ->
    [0.10, 0.10, 0.05, 0.25, 0.15, 0.30, 0.05], // iii ->
    [0.30, 0.10, 0.05, 0.05, 0.35, 0.05, 0.10], // IV ->
    [0.55, 0.05, 0.05, 0.10, 0.05, 0.15, 0.05], // V ->
    [0.10, 0.25, 0.10, 0.25, 0.15, 0.05, 0.10], // vi ->
    [0.60, 0.05, 0.05, 0.05, 0.15, 0.05, 0.05], // vii ->
];

fn chorale(t_len: usize, rng: &mut Pcg64) -> Vec<Vec<f32>> {
    let key = 21 + rng.below(12); // tonic in MIDI, mapped to key 0..87
    let mut degree = 0usize; // start on I
    let mut roll = Vec::with_capacity(t_len);
    for step in 0..t_len {
        if step % 2 == 0 && step > 0 {
            degree = rng.categorical(&CHORD_TRANS[degree]);
        }
        let mut frame = vec![0.0f32; 88];
        // 4 voices: root, third, fifth (+ octave root), soprano jitter
        let triad = [0usize, 2, 4];
        for (v, &off) in triad.iter().enumerate() {
            let scale_deg = (degree + off) % 7;
            let octave = 12 * (v + 2);
            let pitch = key + SCALE[scale_deg] + octave - 21;
            if pitch < 88 {
                frame[pitch] = 1.0;
            }
        }
        // bass: root two octaves down
        let bass = key + SCALE[degree % 7];
        if bass >= 21 {
            let p = bass - 21;
            if p < 88 {
                frame[p] = 1.0;
            }
        }
        // passing-tone noise
        if rng.uniform() < 0.2 {
            let p = rng.below(88);
            frame[p] = 1.0;
        }
        roll.push(frame);
    }
    roll
}

impl SyntheticChorales {
    pub fn generate(n_train: usize, n_test: usize, t_len: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let train = (0..n_train).map(|_| chorale(t_len, &mut rng)).collect();
        let test = (0..n_test).map(|_| chorale(t_len, &mut rng)).collect();
        SyntheticChorales { train, test }
    }
}

/// Shuffled mini-batch index iterator (one epoch).
pub struct BatchIter {
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, rng: &mut Pcg64) -> Self {
        BatchIter { order: rng.permutation(n), batch, pos: 0 }
    }
}

impl Iterator for BatchIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        // drop the ragged tail (standard drop_last=True semantics)
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let out = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        Some(out)
    }
}

/// Gather a [batch, 784] f32 matrix from row indices.
pub fn gather_images(data: &[Vec<f32>], idx: &[usize]) -> Vec<f32> {
    let d = data[0].len();
    let mut out = Vec::with_capacity(idx.len() * d);
    for &i in idx {
        out.extend_from_slice(&data[i]);
    }
    out
}

/// Gather a [batch, T, 88] f32 block from sequence indices.
pub fn gather_rolls(data: &[Vec<Vec<f32>>], idx: &[usize]) -> Vec<f32> {
    let t = data[0].len();
    let d = data[0][0].len();
    let mut out = Vec::with_capacity(idx.len() * t * d);
    for &i in idx {
        for frame in &data[i] {
            out.extend_from_slice(frame);
        }
    }
    let _ = (t, d);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_shapes_and_binary() {
        let ds = SyntheticMnist::generate(100, 20, 1);
        assert_eq!(ds.train.len(), 100);
        assert_eq!(ds.test.len(), 20);
        for img in &ds.train {
            assert_eq!(img.len(), 784);
            assert!(img.iter().all(|&p| p == 0.0 || p == 1.0));
        }
        // digits are distinguishable: per-class mean images differ
        let mean_img = |d: usize| -> Vec<f32> {
            let rows: Vec<&Vec<f32>> = ds
                .train
                .iter()
                .zip(&ds.train_labels)
                .filter(|(_, &l)| l == d)
                .map(|(x, _)| x)
                .collect();
            let mut m = vec![0.0; 784];
            for r in &rows {
                for (a, &b) in m.iter_mut().zip(r.iter()) {
                    *a += b;
                }
            }
            m.iter().map(|&x| x / rows.len().max(1) as f32).collect()
        };
        let m1 = mean_img(1);
        let m8 = mean_img(8);
        let diff: f32 = m1.iter().zip(&m8).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 20.0, "digit classes look identical ({diff})");
    }

    #[test]
    fn mnist_deterministic_given_seed() {
        let a = SyntheticMnist::generate(10, 0, 7);
        let b = SyntheticMnist::generate(10, 0, 7);
        assert_eq!(a.train, b.train);
    }

    #[test]
    fn chorales_shapes_and_polyphony() {
        let ds = SyntheticChorales::generate(20, 5, 32, 2);
        assert_eq!(ds.train.len(), 20);
        for roll in &ds.train {
            assert_eq!(roll.len(), 32);
            for frame in roll {
                assert_eq!(frame.len(), 88);
                let notes: f32 = frame.iter().sum();
                assert!((1.0..=8.0).contains(&notes), "{notes} notes in frame");
            }
        }
    }

    #[test]
    fn chorales_temporal_correlation() {
        // consecutive frames share most notes (chords held 2 steps)
        let ds = SyntheticChorales::generate(50, 0, 32, 3);
        let mut same = 0.0;
        let mut total = 0.0;
        for roll in &ds.train {
            for t in (0..roll.len() - 1).step_by(2) {
                let overlap: f32 =
                    roll[t].iter().zip(&roll[t + 1]).map(|(a, b)| a * b).sum();
                let notes: f32 = roll[t].iter().sum();
                same += overlap;
                total += notes;
            }
        }
        assert!(same / total > 0.8, "weak temporal structure: {}", same / total);
    }

    #[test]
    fn batch_iter_covers_epoch_without_repeats() {
        let mut rng = Pcg64::new(4);
        let batches: Vec<Vec<usize>> = BatchIter::new(100, 32, &mut rng).collect();
        assert_eq!(batches.len(), 3); // 96 used, ragged 4 dropped
        let mut seen: Vec<usize> = batches.concat();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 96);
    }

    #[test]
    fn gather_images_layout() {
        let data = vec![vec![0.0f32; 4], vec![1.0; 4], vec![2.0; 4]];
        let g = gather_images(&data, &[2, 0]);
        assert_eq!(g, vec![2.0, 2.0, 2.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }
}
