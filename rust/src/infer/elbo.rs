//! ELBO estimators.
//!
//! `TraceElbo` is the paper's workhorse: a Monte-Carlo estimate of
//! ELBO = E_q[log p(x,z) - log q(z)] differentiated pathwise through
//! reparameterized sites, with score-function (REINFORCE) surrogate
//! terms — against a decaying-average baseline — for non-reparameterizable
//! guide sites.
//!
//! `TraceMeanFieldElbo` swaps matching (guide, model) site pairs for
//! analytic KL divergences where the registry has one (the paper notes
//! its models use Monte-Carlo KL; the ablation bench compares both).
//!
//! Shape semantics: each `Site::log_prob` is already event-reduced,
//! mask-broadcast and plate-scaled (`cond_indep_stack`), so a
//! vectorized plate of N data points contributes ONE fused term here —
//! mini-batch ELBOs cost a constant number of sites regardless of N.

use crate::autodiff::Var;
use crate::dist::try_analytic_kl;
use crate::poutine::Trace;

/// Which ELBO estimator `Svi` uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElboKind {
    /// Monte-Carlo KL (paper's default).
    Trace,
    /// Analytic KL where available, MC fallback.
    TraceMeanField,
}

/// Shared state for score-function baselines.
#[derive(Clone, Debug, Default)]
pub struct BaselineState {
    avg: f64,
    initialized: bool,
}

impl BaselineState {
    pub fn update(&mut self, value: f64) -> f64 {
        // decaying average baseline (Pyro's default data-independent one)
        const BETA: f64 = 0.90;
        let baseline = if self.initialized { self.avg } else { value };
        self.avg = if self.initialized { BETA * self.avg + (1.0 - BETA) * value } else { value };
        self.initialized = true;
        baseline
    }

    /// Read the current baseline without mutating — parallel particles
    /// all score against the same pre-step snapshot so their surrogate
    /// losses are independent of evaluation order.
    pub fn snapshot(&self) -> Option<f64> {
        if self.initialized {
            Some(self.avg)
        } else {
            None
        }
    }

    /// Fold one observed ELBO value into the decaying average.
    pub fn observe(&mut self, value: f64) {
        const BETA: f64 = 0.90;
        self.avg = if self.initialized { BETA * self.avg + (1.0 - BETA) * value } else { value };
        self.initialized = true;
    }
}

/// Whether the guide trace contains non-reparameterized sites that need
/// score-function surrogate terms (and hence the decaying baseline).
pub fn has_score_sites(guide_trace: &Trace) -> bool {
    guide_trace
        .sites()
        .iter()
        .any(|s| !s.is_observed && !s.dist.has_rsample())
}

/// Monte-Carlo Trace ELBO.
pub struct TraceElbo;

impl TraceElbo {
    /// Differentiable surrogate **loss** (-ELBO) plus the concrete ELBO
    /// value for logging. Reads and updates the baseline sequentially
    /// (single-particle convenience API). As in the original
    /// implementation, the baseline only advances when the trace
    /// actually has score-function sites.
    pub fn loss(
        model_trace: &Trace,
        guide_trace: &Trace,
        baseline: &mut BaselineState,
    ) -> (Var, f64) {
        // preserve the original read-then-update order
        let snapshot = baseline.snapshot();
        let (loss, elbo_value) =
            Self::loss_with_baseline(model_trace, guide_trace, snapshot);
        if has_score_sites(guide_trace) {
            baseline.observe(elbo_value);
        }
        (loss, elbo_value)
    }

    /// Surrogate loss against a fixed baseline snapshot. This is the
    /// form particle workers use: it has no shared mutable state, so
    /// `num_particles` evaluations can run on worker threads and still
    /// produce exactly the serial result when merged in particle order.
    pub fn loss_with_baseline(
        model_trace: &Trace,
        guide_trace: &Trace,
        baseline: Option<f64>,
    ) -> (Var, f64) {
        let model_lp = model_trace
            .log_prob_sum_var()
            .expect("model trace has no sites");
        let guide_lp = guide_trace.log_prob_sum_var();
        let elbo = match &guide_lp {
            Some(g) => model_lp.sub(g),
            None => model_lp,
        };
        let elbo_value = elbo.item();

        // score-function terms for non-reparameterized guide sites
        let mut surrogate = elbo;
        let score_sites: Vec<_> = guide_trace
            .sites()
            .iter()
            .filter(|s| !s.is_observed && !s.dist.has_rsample())
            .collect();
        if !score_sites.is_empty() {
            let coeff = elbo_value - baseline.unwrap_or(elbo_value);
            for site in score_sites {
                surrogate = surrogate.add(&site.log_prob().mul_scalar(coeff));
            }
        }
        (surrogate.neg(), elbo_value)
    }
}

/// Mean-field ELBO with analytic KL terms.
pub struct TraceMeanFieldElbo;

impl TraceMeanFieldElbo {
    pub fn loss(model_trace: &Trace, guide_trace: &Trace) -> (Var, f64) {
        // E_q[log p(obs | z)]: observed model sites
        let mut acc: Option<Var> = None;
        for s in model_trace.sites() {
            if s.is_observed {
                let lp = s.log_prob();
                acc = Some(match acc {
                    None => lp,
                    Some(a) => a.add(&lp),
                });
            }
        }
        // - KL(q || p) per latent site
        for gs in guide_trace.sites() {
            if gs.is_observed {
                continue;
            }
            let ms = model_trace
                .get(&gs.name)
                .unwrap_or_else(|| panic!("guide site '{}' missing from model", gs.name));
            assert!(
                gs.dist.has_rsample(),
                "TraceMeanFieldElbo requires reparameterized guides (site '{}')",
                gs.name
            );
            let term = match try_analytic_kl(gs.dist.as_ref(), ms.dist.as_ref()) {
                Some(kl) => kl.sum().mul_scalar(gs.scale).neg(),
                // MC fallback: log p(z) - log q(z) at the sampled z
                None => ms.log_prob().sub(&gs.log_prob()),
            };
            acc = Some(match acc {
                None => term,
                Some(a) => a.add(&term),
            });
        }
        let elbo = acc.expect("empty traces");
        let v = elbo.item();
        (elbo.neg(), v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Bernoulli, Dist, Normal};
    use crate::poutine::{handlers, trace_with_store, Ctx};
    use crate::params::ParamStore;
    use crate::tensor::{Pcg64, Tensor};

    fn conjugate_model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    #[test]
    fn elbo_equals_loglik_minus_kl_for_exact_guide() {
        // With q = exact posterior N(0.3, 1/sqrt(2)), ELBO = log evidence
        // = log N(0.6 | 0, sqrt(2)) for every draw in expectation; check
        // the MC average.
        let mut rng = Pcg64::new(1);
        let mut store = ParamStore::new();
        let post_loc = 0.3;
        let post_scale = (0.5f64).sqrt();
        let guide = move |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(post_loc, post_scale));
        };
        let mut bl = BaselineState::default();
        let n = 20_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let (gt, _) = trace_with_store(&guide, &mut rng, &mut store);
            let replayed = handlers::replay(conjugate_model, gt.clone());
            let mut ctx = Ctx::with_store_on_tape(
                gt.sites()[0].value.tape().clone(),
                &mut rng,
                &mut store,
            );
            replayed(&mut ctx);
            let mt = ctx.into_trace();
            let (_, elbo) = TraceElbo::loss(&mt, &gt, &mut bl);
            acc += elbo;
        }
        let log_evidence =
            Normal::std(0.0, 2.0f64.sqrt()).log_prob(&Tensor::scalar(0.6)).item();
        assert!(
            (acc / n as f64 - log_evidence).abs() < 0.01,
            "{} vs {log_evidence}",
            acc / n as f64
        );
    }

    #[test]
    fn mean_field_elbo_uses_analytic_kl() {
        let mut rng = Pcg64::new(2);
        let mut store = ParamStore::new();
        let guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.5, 0.8));
        };
        let (gt, _) = trace_with_store(&guide, &mut rng, &mut store);
        let replayed = handlers::replay(conjugate_model, gt.clone());
        let mut ctx =
            Ctx::with_store_on_tape(gt.sites()[0].value.tape().clone(), &mut rng, &mut store);
        replayed(&mut ctx);
        let mt = ctx.into_trace();
        let (_, elbo) = TraceMeanFieldElbo::loss(&mt, &gt);
        // ELBO = E_q log p(x|z) - KL(q||prior); the KL part is exact:
        let kl = crate::dist::kl::kl_normal_normal(
            &Normal::std(0.5, 0.8),
            &Normal::std(0.0, 1.0),
        )
        .item();
        // E_q log p(x|z) at this particular z draw:
        let z = gt.get("z").unwrap().value.value().item();
        let ell = Normal::std(z, 1.0).log_prob(&Tensor::scalar(0.6)).item();
        assert!((elbo - (ell - kl)).abs() < 1e-9);
    }

    #[test]
    fn mean_field_elbo_analytic_kl_through_to_event_guide() {
        // batched conjugate model: z is one vectorized site of 3 points;
        // the guide declares the same site via to_event(1) — the KL
        // registry must look through the wrapper and stay analytic.
        use crate::dist::MvNormalDiag;
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample(
                "z",
                MvNormalDiag::new(ctx.c(Tensor::zeros(vec![3])), ctx.c(Tensor::ones(vec![3]))),
            );
            ctx.observe(
                "x",
                Normal::new(z, ctx.cs(1.0)),
                Tensor::from_vec(vec![0.6, -0.2, 1.1]),
            );
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.c(Tensor::full(vec![3], 0.5));
            let scale = ctx.c(Tensor::full(vec![3], 0.8));
            ctx.sample("z", Normal::new(loc, scale).to_event(1));
        };
        let mut rng = Pcg64::new(21);
        let mut store = ParamStore::new();
        let (gt, _) = trace_with_store(&guide, &mut rng, &mut store);
        let replayed = handlers::replay(model, gt.clone());
        let mut ctx =
            Ctx::with_store_on_tape(gt.sites()[0].value.tape().clone(), &mut rng, &mut store);
        replayed(&mut ctx);
        let mt = ctx.into_trace();
        let (_, elbo) = TraceMeanFieldElbo::loss(&mt, &gt);
        // per-element analytic KL, summed over the 3 points
        let kl = 3.0
            * crate::dist::kl::kl_normal_normal(
                &Normal::std(0.5, 0.8),
                &Normal::std(0.0, 1.0),
            )
            .item();
        let z = gt.get("z").unwrap().value.value().clone();
        let obs = [0.6, -0.2, 1.1];
        let ell: f64 = (0..3)
            .map(|i| Normal::std(z.data()[i], 1.0).log_prob(&Tensor::scalar(obs[i])).item())
            .sum();
        assert!((elbo - (ell - kl)).abs() < 1e-9, "{elbo} vs {}", ell - kl);
    }

    #[test]
    fn score_function_surrogate_has_correct_gradient_sign() {
        // model: x ~ Bern(0.9) observed true; guide: z irrelevant —
        // instead test a discrete-latent model: z ~ Bern(q); p rewards
        // z=1. Gradient should push q's logit up.
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Bernoulli::std(0.5));
            // likelihood strongly prefers z = 1
            let logits = z.mul_scalar(8.0).add_scalar(-4.0);
            ctx.observe("x", Bernoulli::new(logits), Tensor::scalar(1.0));
        };
        let mut rng = Pcg64::new(3);
        let mut store = ParamStore::new();
        let mut bl = BaselineState::default();
        let mut total_grad = 0.0;
        let n = 4000;
        for _ in 0..n {
            let guide = |ctx: &mut Ctx| {
                let logit = ctx.param("q_logit", || Tensor::scalar(0.0));
                ctx.sample("z", Bernoulli::new(logit));
            };
            let (gt, _) = trace_with_store(&guide, &mut rng, &mut store);
            let tape = gt.sites()[0].value.tape().clone();
            let replayed = handlers::replay(model, gt.clone());
            let mut ctx = Ctx::with_store_on_tape(tape.clone(), &mut rng, &mut store);
            replayed(&mut ctx);
            let mt = ctx.into_trace();
            let (loss, _) = TraceElbo::loss(&mt, &gt, &mut bl);
            let leaf = &gt.param_leaves["q_logit"];
            total_grad += tape.grad(&loss, &[leaf]).remove(0).item();
        }
        // minimizing loss should *decrease* via positive logit movement:
        // gradient of loss w.r.t. logit must be negative on average
        assert!(
            (total_grad / n as f64) < -0.05,
            "avg dloss/dlogit = {}",
            total_grad / n as f64
        );
    }
}
