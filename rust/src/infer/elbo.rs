//! ELBO estimators — the open [`Elbo`] trait plus four implementations.
//!
//! The paper's inference API is `SVI(model, guide, optim, loss=Trace_ELBO())`:
//! the loss is a first-class, user-extensible estimator *object*, not an
//! engine-internal switch. This module mirrors that design. [`Svi`]
//! (`crate::infer::svi::Svi`) is generic over any `impl Elbo` (including
//! `Box<dyn Elbo>` for runtime selection); an estimator supplies
//!
//! - a per-particle differentiable surrogate loss
//!   ([`Elbo::differentiable_loss`]), evaluated against a read-only
//!   snapshot of estimator state so particles can run on worker threads;
//! - state hooks ([`Elbo::snapshot`] / [`Elbo::absorb`]) for whatever the
//!   estimator learns across steps (decaying-average baselines), applied
//!   in particle order so parallel == serial bitwise;
//! - a particle combiner ([`Elbo::combine`]) mapping per-particle
//!   statistics to the reported loss and per-particle gradient weights
//!   (uniform `1/K` for plain averaging, importance weights for
//!   Rényi/IWAE).
//!
//! Estimators shipped here:
//!
//! - [`TraceElbo`] — the paper's workhorse: Monte-Carlo
//!   ELBO = E_q\[log p(x,z) − log q(z)\] differentiated pathwise through
//!   reparameterized sites, with score-function (REINFORCE) surrogate
//!   terms — against one global decaying-average baseline — for
//!   non-reparameterizable guide sites.
//! - [`TraceMeanFieldElbo`] — swaps matching (guide, model) site pairs
//!   for analytic KL divergences where the registry has one.
//! - [`TraceGraphElbo`] — variance-reduced score-function gradients:
//!   per-site decaying-average baselines keyed by site name, and
//!   Rao-Blackwellized coefficients that include only *downstream* cost,
//!   computed from stable site ordering plus overlapping [`PlateFrame`]s
//!   in each site's `cond_indep_stack` (within a shared plate, element
//!   `i` of a score site multiplies only element `i`'s cost).
//! - [`RenyiElbo`] — the α-divergence / IWAE family: importance-weights
//!   the multi-particle machinery via a stable logsumexp over
//!   per-particle log weights; degenerates to [`TraceElbo`] at one
//!   particle.
//!
//! Shape semantics: each `Site::log_prob` is already event-reduced,
//! mask-broadcast and plate-scaled (`cond_indep_stack`), so a vectorized
//! plate of N data points contributes ONE fused term here — mini-batch
//! ELBOs cost a constant number of sites regardless of N.
//!
//! [`Svi`]: crate::infer::svi::Svi
//! [`PlateFrame`]: crate::poutine::PlateFrame

use crate::autodiff::Var;
use crate::dist::try_analytic_kl;
use crate::poutine::{Site, Trace};
use crate::tensor::Tensor;
use std::collections::HashMap;

// ------------------------------------------------------------------ state

/// One decaying-average baseline (Pyro's default data-independent one).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BaselineState {
    avg: f64,
    initialized: bool,
}

impl BaselineState {
    /// Read the current baseline without mutating — parallel particles
    /// all score against the same pre-step snapshot so their surrogate
    /// losses are independent of evaluation order.
    pub fn snapshot(&self) -> Option<f64> {
        if self.initialized {
            Some(self.avg)
        } else {
            None
        }
    }

    /// Fold one observed value into the decaying average.
    pub fn observe(&mut self, value: f64) {
        const BETA: f64 = 0.90;
        self.avg = if self.initialized { BETA * self.avg + (1.0 - BETA) * value } else { value };
        self.initialized = true;
    }
}

/// Read-only snapshot of an estimator's cross-step state, taken once per
/// SVI step and shared by every particle of that step.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BaselineSnapshot {
    /// Global baseline ([`TraceElbo`], [`RenyiElbo`]).
    pub global: Option<f64>,
    /// Per-site baselines keyed by site name ([`TraceGraphElbo`]).
    pub per_site: HashMap<String, f64>,
}

/// Per-particle evaluation context. `baselines` is the shared pre-step
/// snapshot; `obs` collects whatever per-site observations the estimator
/// wants folded back into its state (through [`Elbo::absorb`]) after a
/// *training* step — evaluation passes drop them.
pub struct ParticleCtx<'a> {
    pub baselines: &'a BaselineSnapshot,
    pub obs: Vec<(String, f64)>,
}

impl<'a> ParticleCtx<'a> {
    pub fn new(baselines: &'a BaselineSnapshot) -> Self {
        ParticleCtx { baselines, obs: Vec::new() }
    }
}

/// What one particle evaluation reports back: a scalar statistic (the
/// ELBO sample for Trace-style estimators, the log importance weight for
/// Rényi) plus the per-site observations accumulated in [`ParticleCtx`].
/// Plain data, so worker threads can hand it across the thread boundary.
#[derive(Clone, Debug)]
pub struct ParticleStats {
    pub value: f64,
    pub obs: Vec<(String, f64)>,
}

// ------------------------------------------------------------------ trait

/// An ELBO estimator usable with [`Svi`](crate::infer::svi::Svi).
///
/// `Sync` is a supertrait because multi-particle SVI shares `&self`
/// across worker threads; mutable state lives behind the
/// [`snapshot`](Elbo::snapshot)/[`absorb`](Elbo::absorb) pair instead.
pub trait Elbo: Sync {
    /// Short stable name (bench records, diagnostics).
    fn name(&self) -> &'static str {
        "Elbo"
    }

    /// Whether this estimator's surrogate loss is a pure function of the
    /// tape (no score-function terms, no cross-step baseline state in
    /// the loss itself), making it eligible for graph-mode compilation
    /// ([`crate::infer::compile`]). True for [`TraceElbo`] and
    /// [`TraceMeanFieldElbo`]; estimators with baseline-corrected score
    /// surrogates (TraceGraph) or non-default particle combination
    /// (Renyi) must stay on the dynamic path.
    fn compilable(&self) -> bool {
        false
    }

    /// Whether this estimator applies variance reduction
    /// (Rao-Blackwellization, per-site baselines) to score-function
    /// terms. The static analyzer's reparameterization audit
    /// ([`crate::analysis`], lint FY007) warns about
    /// non-reparameterized sites only under estimators where this is
    /// `false` — there the score terms ride the plain pathwise
    /// surrogate with no variance control.
    fn variance_reduced(&self) -> bool {
        false
    }

    /// Differentiable surrogate **loss** (−ELBO) for one particle, plus
    /// the particle's scalar statistic (see [`ParticleStats::value`]).
    /// Reads estimator state only through `ctx.baselines`; any state
    /// updates are staged as `ctx.obs` entries. An empty or
    /// fully-blocked model trace is an [`Err`], not a panic.
    fn differentiable_loss(
        &self,
        model_trace: &Trace,
        guide_trace: &Trace,
        ctx: &mut ParticleCtx<'_>,
    ) -> crate::error::Result<(Var, f64)>;

    /// Pre-step snapshot of estimator state, handed read-only to every
    /// particle of the step.
    fn snapshot(&self) -> BaselineSnapshot {
        BaselineSnapshot::default()
    }

    /// Fold particle observations back into estimator state, in particle
    /// order. Called by `Svi::step` only — never by `evaluate_loss`, so
    /// evaluation passes are side-effect free.
    fn absorb(&mut self, _stats: &[ParticleStats]) {}

    /// Combine per-particle statistics into the reported loss and the
    /// per-particle gradient weights (summing to 1). The default is the
    /// plain Monte-Carlo average.
    fn combine(&self, stats: &[ParticleStats]) -> (f64, Vec<f64>) {
        let n = stats.len().max(1) as f64;
        let mean = stats.iter().map(|s| s.value).sum::<f64>() / n;
        (-mean, vec![1.0 / n; stats.len()])
    }

    /// Single-particle convenience: snapshot → `differentiable_loss` →
    /// `absorb`, returning the surrogate loss and the particle statistic.
    fn loss(
        &mut self,
        model_trace: &Trace,
        guide_trace: &Trace,
    ) -> crate::error::Result<(Var, f64)> {
        let snap = self.snapshot();
        let mut ctx = ParticleCtx::new(&snap);
        let (loss, value) = self.differentiable_loss(model_trace, guide_trace, &mut ctx)?;
        self.absorb(&[ParticleStats { value, obs: ctx.obs }]);
        Ok((loss, value))
    }
}

impl Elbo for Box<dyn Elbo> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn compilable(&self) -> bool {
        (**self).compilable()
    }
    fn variance_reduced(&self) -> bool {
        (**self).variance_reduced()
    }
    fn differentiable_loss(
        &self,
        model_trace: &Trace,
        guide_trace: &Trace,
        ctx: &mut ParticleCtx<'_>,
    ) -> crate::error::Result<(Var, f64)> {
        (**self).differentiable_loss(model_trace, guide_trace, ctx)
    }
    fn snapshot(&self) -> BaselineSnapshot {
        (**self).snapshot()
    }
    fn absorb(&mut self, stats: &[ParticleStats]) {
        (**self).absorb(stats)
    }
    fn combine(&self, stats: &[ParticleStats]) -> (f64, Vec<f64>) {
        (**self).combine(stats)
    }
}

// ---------------------------------------------------------------- helpers

/// Whether the guide trace contains non-reparameterized sites that need
/// score-function surrogate terms (and hence the decaying baseline).
pub fn has_score_sites(guide_trace: &Trace) -> bool {
    guide_trace.sites().iter().any(Site::needs_score_term)
}

/// Log importance weight of a (model, guide) trace pair:
/// `log p(x, z) − log q(z)`. This is both the per-particle statistic
/// behind [`RenyiElbo`] and the weight `Importance` assigns to a guided
/// proposal.
pub fn trace_log_weight(model_trace: &Trace, guide_trace: &Trace) -> f64 {
    model_trace.log_prob_sum() - guide_trace.log_prob_sum()
}

/// Pick a sane default estimator for a guide: [`TraceGraphElbo`] when the
/// guide advertises non-reparameterized sites, plain [`TraceElbo`]
/// otherwise. Autoguides expose `nonreparam_sites()` for exactly this.
pub fn default_elbo(nonreparam_sites: &[String]) -> Box<dyn Elbo> {
    if nonreparam_sites.is_empty() {
        Box::new(TraceElbo::default())
    } else {
        Box::new(TraceGraphElbo::default())
    }
}

fn empty_model_trace_error() -> crate::error::Error {
    crate::error::Error::msg(
        "model trace has no sample sites — an empty or fully-blocked model \
         cannot produce an ELBO (check your block/handlers and that the \
         model actually calls ctx.sample/ctx.observe)",
    )
}

// -------------------------------------------------------------- TraceElbo

/// Monte-Carlo Trace ELBO with a single global decaying-average baseline
/// for score-function sites (the paper's default estimator).
#[derive(Clone, Debug, Default)]
pub struct TraceElbo {
    baseline: BaselineState,
}

impl TraceElbo {
    /// Current global baseline (None until the first score-site step).
    pub fn baseline(&self) -> Option<f64> {
        self.baseline.snapshot()
    }

    /// Surrogate loss against a fixed baseline snapshot. This is the
    /// form particle workers use: it has no shared mutable state, so
    /// `num_particles` evaluations can run on worker threads and still
    /// produce exactly the serial result when merged in particle order.
    pub fn loss_with_baseline(
        model_trace: &Trace,
        guide_trace: &Trace,
        baseline: Option<f64>,
    ) -> crate::error::Result<(Var, f64)> {
        let model_lp = model_trace.log_prob_sum_var().ok_or_else(empty_model_trace_error)?;
        let guide_lp = guide_trace.log_prob_sum_var();
        let elbo = match &guide_lp {
            Some(g) => model_lp.sub(g),
            None => model_lp,
        };
        let elbo_value = elbo.item();

        // score-function terms for non-reparameterized guide sites
        let mut surrogate = elbo;
        let score_sites: Vec<_> =
            guide_trace.sites().iter().filter(|s| s.needs_score_term()).collect();
        if !score_sites.is_empty() {
            let coeff = elbo_value - baseline.unwrap_or(elbo_value);
            for site in score_sites {
                surrogate = surrogate.add(&site.log_prob().mul_scalar(coeff));
            }
        }
        Ok((surrogate.neg(), elbo_value))
    }
}

impl Elbo for TraceElbo {
    fn name(&self) -> &'static str {
        "Trace"
    }

    /// Compilable when the recorded guide is fully reparameterized (the
    /// recorder additionally rejects traces with score sites).
    fn compilable(&self) -> bool {
        true
    }

    fn differentiable_loss(
        &self,
        model_trace: &Trace,
        guide_trace: &Trace,
        ctx: &mut ParticleCtx<'_>,
    ) -> crate::error::Result<(Var, f64)> {
        let (loss, elbo_value) =
            Self::loss_with_baseline(model_trace, guide_trace, ctx.baselines.global)?;
        // the baseline only advances when the trace actually has
        // score-function sites, matching the original estimator
        if has_score_sites(guide_trace) {
            ctx.obs.push((String::new(), elbo_value));
        }
        Ok((loss, elbo_value))
    }

    fn snapshot(&self) -> BaselineSnapshot {
        BaselineSnapshot { global: self.baseline.snapshot(), per_site: HashMap::new() }
    }

    fn absorb(&mut self, stats: &[ParticleStats]) {
        for s in stats {
            for (_, v) in &s.obs {
                self.baseline.observe(*v);
            }
        }
    }
}

// ----------------------------------------------------- TraceMeanFieldElbo

/// Mean-field ELBO with analytic KL terms where the registry has one and
/// Monte-Carlo fallbacks elsewhere. Requires a fully reparameterized
/// guide.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceMeanFieldElbo;

impl Elbo for TraceMeanFieldElbo {
    fn name(&self) -> &'static str {
        "TraceMeanField"
    }

    fn compilable(&self) -> bool {
        true
    }

    fn differentiable_loss(
        &self,
        model_trace: &Trace,
        guide_trace: &Trace,
        _ctx: &mut ParticleCtx<'_>,
    ) -> crate::error::Result<(Var, f64)> {
        // E_q[log p(obs | z)]: observed model sites
        let mut acc: Option<Var> = None;
        for s in model_trace.sites() {
            if s.is_observed {
                let lp = s.log_prob();
                acc = Some(match acc {
                    None => lp,
                    Some(a) => a.add(&lp),
                });
            }
        }
        // - KL(q || p) per latent site
        for gs in guide_trace.sites() {
            if gs.is_observed {
                continue;
            }
            let ms = model_trace.get(&gs.name).ok_or_else(|| {
                crate::error::Error::msg(format!(
                    "guide site '{}' missing from the model trace",
                    gs.name
                ))
            })?;
            if !gs.dist.has_rsample() {
                return Err(crate::error::Error::msg(format!(
                    "TraceMeanFieldElbo requires reparameterized guides \
                     (site '{}' has no rsample); use TraceGraphElbo for \
                     score-function sites",
                    gs.name
                )));
            }
            let term = match try_analytic_kl(gs.dist.as_ref(), ms.dist.as_ref()) {
                Some(kl) => kl.sum().mul_scalar(gs.scale).neg(),
                // MC fallback: log p(z) - log q(z) at the sampled z
                None => ms.log_prob().sub(&gs.log_prob()),
            };
            acc = Some(match acc {
                None => term,
                Some(a) => a.add(&term),
            });
        }
        let elbo = acc.ok_or_else(empty_model_trace_error)?;
        let v = elbo.item();
        Ok((elbo.neg(), v))
    }
}

// --------------------------------------------------------- TraceGraphElbo

/// Variance-reduced score-function estimator: per-site decaying-average
/// baselines plus plate-aware Rao-Blackwellization.
///
/// For every non-reparameterized guide site `z`, the REINFORCE
/// coefficient is not the whole ELBO sample but only the *downstream*
/// cost — terms that `z` can actually influence, determined
/// conservatively from stable site ordering (`Trace::index_of`): model
/// terms at or after `z`'s model-trace position, minus guide terms at or
/// after `z`'s guide-trace position. Within plates shared between `z`
/// and a cost term (overlapping [`PlateFrame`]s, matched by `dim`+name),
/// the cost stays *elementwise*: element `i` of `z`'s batched log-prob
/// multiplies only element `i`'s cost, the classic within-plate
/// Rao-Blackwellization that cuts gradient variance by roughly the
/// plate size on models like the batched-Categorical GMM.
///
/// Baselines are per-site scalars (decaying average of the site's mean
/// downstream cost), keyed by site name — robust under plate
/// subsampling, where elementwise baselines would chase shifting
/// indices.
///
/// [`PlateFrame`]: crate::poutine::PlateFrame
#[derive(Clone, Debug, Default)]
pub struct TraceGraphElbo {
    baselines: HashMap<String, BaselineState>,
}

impl TraceGraphElbo {
    /// Current per-site baselines (None until a site's first step).
    pub fn baseline(&self, site: &str) -> Option<f64> {
        self.baselines.get(site).and_then(BaselineState::snapshot)
    }
}

/// Number of outermost plates shared by two sites: the longest prefix of
/// dims `0, 1, 2, …` (counted from the right, per the global allocator)
/// where both sites carry a frame with that dim and the same plate name.
fn shared_plate_prefix(a: &Site, b: &Site) -> usize {
    let mut k = 0;
    loop {
        let fa = a.frames().iter().find(|f| f.dim == k);
        let fb = b.frames().iter().find(|f| f.dim == k);
        match (fa, fb) {
            (Some(x), Some(y)) if x.name == y.name => k += 1,
            _ => return k,
        }
    }
}

/// Detached, plate-scaled batch log-prob of `site`, reduced onto the
/// plate dims it shares with `z`: axes belonging to plates `z` is *not*
/// in (plus any non-plate batch axes) are summed out, leaving a tensor
/// that broadcasts against `z`'s batch-shaped log-prob.
fn cost_term_reduced_to(site: &Site, z: &Site) -> Tensor {
    let mut t = site.log_prob_batch().value().clone();
    if site.scale != 1.0 {
        t = t.mul_scalar(site.scale);
    }
    let keep = shared_plate_prefix(site, z);
    while t.rank() > keep {
        t = t.sum0();
    }
    t
}

/// Rao-Blackwellized downstream cost for guide site `z` (at guide-trace
/// index `z_guide_index`): the detached sum of model log-prob terms at
/// or after `z`'s model-trace position minus guide log-prob terms at or
/// after `z`'s guide position, each reduced onto the plates it shares
/// with `z` (shared-plate contributions stay elementwise). Broadcastable
/// against `z.log_prob_batch()`. Public so property tests can pin it
/// against a brute-force per-element reference.
pub fn rao_blackwell_downstream_cost(
    z: &Site,
    z_guide_index: usize,
    model_trace: &Trace,
    guide_trace: &Trace,
) -> Tensor {
    // conservative ordering: if z somehow never reached the model trace
    // (auxiliary guide site), every model term counts as downstream
    let z_model_index = model_trace.index_of(&z.name).unwrap_or(0);
    let mut acc: Option<Tensor> = None;
    let push = |t: Tensor, acc: &mut Option<Tensor>| {
        *acc = Some(match acc.take() {
            None => t,
            Some(a) => a.add(&t),
        });
    };
    for (mi, s) in model_trace.sites().iter().enumerate() {
        if mi < z_model_index || s.intervened {
            continue;
        }
        push(cost_term_reduced_to(s, z), &mut acc);
    }
    for (gi, s) in guide_trace.sites().iter().enumerate() {
        if gi < z_guide_index || s.is_observed || s.intervened {
            continue;
        }
        push(cost_term_reduced_to(s, z).neg(), &mut acc);
    }
    acc.unwrap_or_else(|| Tensor::scalar(0.0))
}

impl Elbo for TraceGraphElbo {
    fn name(&self) -> &'static str {
        "TraceGraph"
    }

    fn variance_reduced(&self) -> bool {
        true
    }

    fn differentiable_loss(
        &self,
        model_trace: &Trace,
        guide_trace: &Trace,
        ctx: &mut ParticleCtx<'_>,
    ) -> crate::error::Result<(Var, f64)> {
        let model_lp = model_trace.log_prob_sum_var().ok_or_else(empty_model_trace_error)?;
        let guide_lp = guide_trace.log_prob_sum_var();
        let elbo = match &guide_lp {
            Some(g) => model_lp.sub(g),
            None => model_lp,
        };
        let elbo_value = elbo.item();

        let mut surrogate = elbo;
        for (gi, z) in guide_trace.sites().iter().enumerate() {
            if !z.needs_score_term() {
                continue;
            }
            let cost = rao_blackwell_downstream_cost(z, gi, model_trace, guide_trace);
            ctx.obs.push((z.name.clone(), cost.mean()));
            // No baseline for this site yet (its first step): skip the
            // score term entirely — coefficient 0, exactly TraceElbo's
            // fallback. Centering on the particle's own mean cost would
            // subtract a statistic of the same z draw and bias the
            // gradient; the obs pushed above still warms the baseline.
            let Some(b) = ctx.baselines.per_site.get(&z.name).copied() else {
                continue;
            };
            let coeff = z.value.tape().constant(cost.add_scalar(-b));
            let term = z.log_prob_batch().mul(&coeff).sum();
            let term = if z.scale == 1.0 { term } else { term.mul_scalar(z.scale) };
            surrogate = surrogate.add(&term);
        }
        Ok((surrogate.neg(), elbo_value))
    }

    fn snapshot(&self) -> BaselineSnapshot {
        let per_site = self
            .baselines
            .iter()
            .filter_map(|(k, v)| v.snapshot().map(|b| (k.clone(), b)))
            .collect();
        BaselineSnapshot { global: None, per_site }
    }

    fn absorb(&mut self, stats: &[ParticleStats]) {
        for s in stats {
            for (name, v) in &s.obs {
                self.baselines.entry(name.clone()).or_default().observe(*v);
            }
        }
    }
}

// -------------------------------------------------------------- RenyiElbo

/// Rényi α-divergence objective (Li & Turner's VR bound); `alpha = 0` is
/// the IWAE bound, and `alpha → 1` recovers the ELBO. Per-particle log
/// importance weights `log w_k = log p(x, z_k) − log q(z_k)` are
/// combined through a stable logsumexp:
///
/// `L_α = (1 / (1 − α)) · [logsumexp_k((1 − α) · log w_k) − log K]`
///
/// and each particle's pathwise gradient is weighted by its normalized
/// importance weight `ω_k ∝ w_k^{1−α}`. With one particle the weights
/// collapse to 1 and the estimator degenerates exactly to [`TraceElbo`].
///
/// **Reparameterized guides recommended for `num_particles > 1`.**
/// Non-reparameterized sites are handled like Pyro's `RenyiELBO`: each
/// particle carries its own score-function surrogate (coefficient
/// `log w_k − baseline`), then gets weighted by `ω_k`. Because the
/// logsumexp couples particles, that per-particle coefficient is not the
/// exact measure-score term of the combined bound — the multi-particle
/// score gradient is an approximation (biased in general), while the
/// pathwise part stays exact. At one particle, or with fully
/// reparameterized guides, the estimator is exact.
#[derive(Clone, Debug)]
pub struct RenyiElbo {
    pub alpha: f64,
    baseline: BaselineState,
}

impl RenyiElbo {
    /// `alpha` must not be 1 (the bound degenerates to the plain ELBO —
    /// use [`TraceElbo`] for that).
    pub fn new(alpha: f64) -> Self {
        assert!(alpha != 1.0, "RenyiElbo is undefined at alpha = 1; use TraceElbo");
        RenyiElbo { alpha, baseline: BaselineState::default() }
    }

    /// The IWAE bound (`alpha = 0`).
    pub fn iwae() -> Self {
        RenyiElbo::new(0.0)
    }
}

impl Default for RenyiElbo {
    fn default() -> Self {
        RenyiElbo::iwae()
    }
}

impl Elbo for RenyiElbo {
    fn name(&self) -> &'static str {
        "Renyi"
    }

    fn differentiable_loss(
        &self,
        model_trace: &Trace,
        guide_trace: &Trace,
        ctx: &mut ParticleCtx<'_>,
    ) -> crate::error::Result<(Var, f64)> {
        // per-particle surrogate identical to TraceElbo's; the statistic
        // is the log importance weight (== the ELBO sample), cf.
        // `trace_log_weight`
        let (loss, log_w) = TraceElbo::loss_with_baseline(
            model_trace,
            guide_trace,
            ctx.baselines.global,
        )?;
        if has_score_sites(guide_trace) {
            ctx.obs.push((String::new(), log_w));
        }
        Ok((loss, log_w))
    }

    fn snapshot(&self) -> BaselineSnapshot {
        BaselineSnapshot { global: self.baseline.snapshot(), per_site: HashMap::new() }
    }

    fn absorb(&mut self, stats: &[ParticleStats]) {
        for s in stats {
            for (_, v) in &s.obs {
                self.baseline.observe(*v);
            }
        }
    }

    fn combine(&self, stats: &[ParticleStats]) -> (f64, Vec<f64>) {
        let one_minus = 1.0 - self.alpha;
        let scaled: Vec<f64> = stats.iter().map(|s| s.value * one_minus).collect();
        // the same stable logsumexp Importance uses on its log weights
        let lse = Tensor::from_vec(scaled.clone()).logsumexp();
        let k = stats.len().max(1) as f64;
        let loss = -((lse - k.ln()) / one_minus);
        let weights = scaled.iter().map(|s| (s - lse).exp()).collect();
        (loss, weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Bernoulli, Dist, Normal};
    use crate::params::ParamStore;
    use crate::poutine::{handlers, trace_with_store, Ctx};
    use crate::tensor::{Pcg64, Tensor};

    fn conjugate_model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    /// Run guide then replay model on the same tape (single-tape pair).
    fn pair(
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        rng: &mut Pcg64,
        store: &mut ParamStore,
    ) -> (Trace, Trace) {
        let (gt, _) = trace_with_store(guide, rng, store);
        let replayed = handlers::replay(model, gt.clone());
        let mut ctx =
            Ctx::with_store_on_tape(gt.sites()[0].value.tape().clone(), rng, store);
        replayed(&mut ctx);
        (ctx.into_trace(), gt)
    }

    #[test]
    fn elbo_equals_loglik_minus_kl_for_exact_guide() {
        // With q = exact posterior N(0.3, 1/sqrt(2)), ELBO = log evidence
        // = log N(0.6 | 0, sqrt(2)) for every draw in expectation; check
        // the MC average.
        let mut rng = Pcg64::new(1);
        let mut store = ParamStore::new();
        let post_loc = 0.3;
        let post_scale = (0.5f64).sqrt();
        let guide = move |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(post_loc, post_scale));
        };
        let mut est = TraceElbo::default();
        let n = 20_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let (mt, gt) = pair(&conjugate_model, &guide, &mut rng, &mut store);
            let (_, elbo) = est.loss(&mt, &gt).expect("elbo");
            acc += elbo;
        }
        let log_evidence =
            Normal::std(0.0, 2.0f64.sqrt()).log_prob(&Tensor::scalar(0.6)).item();
        assert!(
            (acc / n as f64 - log_evidence).abs() < 0.01,
            "{} vs {log_evidence}",
            acc / n as f64
        );
    }

    #[test]
    fn mean_field_elbo_uses_analytic_kl() {
        let mut rng = Pcg64::new(2);
        let mut store = ParamStore::new();
        let guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.5, 0.8));
        };
        let (mt, gt) = pair(&conjugate_model, &guide, &mut rng, &mut store);
        let (_, elbo) = TraceMeanFieldElbo.loss(&mt, &gt).expect("elbo");
        // ELBO = E_q log p(x|z) - KL(q||prior); the KL part is exact:
        let kl = crate::dist::kl::kl_normal_normal(
            &Normal::std(0.5, 0.8),
            &Normal::std(0.0, 1.0),
        )
        .item();
        // E_q log p(x|z) at this particular z draw:
        let z = gt.get("z").unwrap().value.value().item();
        let ell = Normal::std(z, 1.0).log_prob(&Tensor::scalar(0.6)).item();
        assert!((elbo - (ell - kl)).abs() < 1e-9);
    }

    #[test]
    fn mean_field_elbo_analytic_kl_through_to_event_guide() {
        // batched conjugate model: z is one vectorized site of 3 points;
        // the guide declares the same site via to_event(1) — the KL
        // registry must look through the wrapper and stay analytic.
        use crate::dist::MvNormalDiag;
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample(
                "z",
                MvNormalDiag::new(ctx.c(Tensor::zeros(vec![3])), ctx.c(Tensor::ones(vec![3]))),
            );
            ctx.observe(
                "x",
                Normal::new(z, ctx.cs(1.0)),
                Tensor::from_vec(vec![0.6, -0.2, 1.1]),
            );
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.c(Tensor::full(vec![3], 0.5));
            let scale = ctx.c(Tensor::full(vec![3], 0.8));
            ctx.sample("z", Normal::new(loc, scale).to_event(1));
        };
        let mut rng = Pcg64::new(21);
        let mut store = ParamStore::new();
        let (mt, gt) = pair(&model, &guide, &mut rng, &mut store);
        let (_, elbo) = TraceMeanFieldElbo.loss(&mt, &gt).expect("elbo");
        // per-element analytic KL, summed over the 3 points
        let kl = 3.0
            * crate::dist::kl::kl_normal_normal(
                &Normal::std(0.5, 0.8),
                &Normal::std(0.0, 1.0),
            )
            .item();
        let z = gt.get("z").unwrap().value.value().clone();
        let obs = [0.6, -0.2, 1.1];
        let ell: f64 = (0..3)
            .map(|i| Normal::std(z.data()[i], 1.0).log_prob(&Tensor::scalar(obs[i])).item())
            .sum();
        assert!((elbo - (ell - kl)).abs() < 1e-9, "{elbo} vs {}", ell - kl);
    }

    #[test]
    fn score_function_surrogate_has_correct_gradient_sign() {
        // discrete-latent model: z ~ Bern(q); likelihood rewards z=1.
        // Gradient of the loss should push q's logit up.
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Bernoulli::std(0.5));
            let logits = z.mul_scalar(8.0).add_scalar(-4.0);
            ctx.observe("x", Bernoulli::new(logits), Tensor::scalar(1.0));
        };
        let mut rng = Pcg64::new(3);
        let mut store = ParamStore::new();
        let mut est = TraceElbo::default();
        let mut total_grad = 0.0;
        let n = 4000;
        for _ in 0..n {
            let guide = |ctx: &mut Ctx| {
                let logit = ctx.param("q_logit", || Tensor::scalar(0.0));
                ctx.sample("z", Bernoulli::new(logit));
            };
            let (mt, gt) = pair(&model, &guide, &mut rng, &mut store);
            let (loss, _) = est.loss(&mt, &gt).expect("elbo");
            let leaf = &gt.param_leaves["q_logit"];
            total_grad += loss.tape().grad(&loss, &[leaf]).remove(0).item();
        }
        // minimizing loss should *decrease* via positive logit movement:
        // gradient of loss w.r.t. logit must be negative on average
        assert!(
            (total_grad / n as f64) < -0.05,
            "avg dloss/dlogit = {}",
            total_grad / n as f64
        );
    }

    #[test]
    fn tracegraph_excludes_upstream_cost_terms() {
        // two score sites in sequence: a's coefficient sees everything,
        // b's must exclude a's prior/guide terms (sampled before b)
        let model = |ctx: &mut Ctx| {
            let a = ctx.sample("a", Bernoulli::std(0.3));
            let b = ctx.sample("b", Bernoulli::std(0.6));
            let logits = a.add(&b).mul_scalar(2.0).add_scalar(-1.0);
            ctx.observe("x", Bernoulli::new(logits), Tensor::scalar(1.0));
        };
        let guide = |ctx: &mut Ctx| {
            let la = ctx.param("la", || Tensor::scalar(0.2));
            let lb = ctx.param("lb", || Tensor::scalar(-0.1));
            ctx.sample("a", Bernoulli::new(la));
            ctx.sample("b", Bernoulli::new(lb));
        };
        let mut rng = Pcg64::new(17);
        let mut store = ParamStore::new();
        let (mt, gt) = pair(&model, &guide, &mut rng, &mut store);
        let lp = |t: &Trace, n: &str| t.get(n).unwrap().log_prob().item();
        let a_cost =
            rao_blackwell_downstream_cost(gt.get("a").unwrap(), 0, &mt, &gt).item();
        let b_cost =
            rao_blackwell_downstream_cost(gt.get("b").unwrap(), 1, &mt, &gt).item();
        let want_a = lp(&mt, "a") + lp(&mt, "b") + lp(&mt, "x") - lp(&gt, "a") - lp(&gt, "b");
        let want_b = lp(&mt, "b") + lp(&mt, "x") - lp(&gt, "b");
        assert!((a_cost - want_a).abs() < 1e-12, "{a_cost} vs {want_a}");
        assert!((b_cost - want_b).abs() < 1e-12, "{b_cost} vs {want_b}");
    }

    #[test]
    fn tracegraph_plate_cost_is_elementwise() {
        // gmm-style: one batched Bernoulli assignment site inside a full
        // plate — each element's downstream cost must be its OWN row's
        // model + likelihood terms minus its own guide term, plus nothing
        // from outside-the-plate upstream sites
        let n = 4;
        let data = Tensor::from_vec(vec![0.5, -0.2, 1.0, 0.1]);
        let model = {
            let data = data.clone();
            move |ctx: &mut Ctx| {
                let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                ctx.plate("data", n, None, |ctx, _p| {
                    let k =
                        ctx.sample("assign", Bernoulli::new(ctx.c(Tensor::zeros(vec![n]))));
                    let loc = mu.mul(&k);
                    ctx.observe("x", Normal::new(loc, ctx.cs(1.0)), data.clone());
                });
            }
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.param("mu.loc", || Tensor::scalar(0.3));
            ctx.sample("mu", Normal::new(loc, ctx.cs(0.5)));
            ctx.plate("data", n, None, |ctx, _p| {
                let logits = ctx.param("assign.logits", || Tensor::zeros(vec![n]));
                ctx.sample("assign", Bernoulli::new(logits));
            });
        };
        let mut rng = Pcg64::new(23);
        let mut store = ParamStore::new();
        let (mt, gt) = pair(&model, &guide, &mut rng, &mut store);
        let gi = gt.index_of("assign").unwrap();
        let cost = rao_blackwell_downstream_cost(gt.get("assign").unwrap(), gi, &mt, &gt);
        assert_eq!(cost.dims(), &[n]);
        let m_assign = mt.get("assign").unwrap().log_prob_batch().value().clone();
        let m_x = mt.get("x").unwrap().log_prob_batch().value().clone();
        let g_assign = gt.get("assign").unwrap().log_prob_batch().value().clone();
        for i in 0..n {
            let want = m_assign.data()[i] + m_x.data()[i] - g_assign.data()[i];
            assert!(
                (cost.data()[i] - want).abs() < 1e-12,
                "element {i}: {} vs {want}",
                cost.data()[i]
            );
        }
    }

    #[test]
    fn renyi_combine_is_logsumexp_weighted() {
        let est = RenyiElbo::iwae();
        let stats: Vec<ParticleStats> = [-1.0f64, -3.0]
            .iter()
            .map(|&v| ParticleStats { value: v, obs: vec![] })
            .collect();
        let (loss, w) = est.combine(&stats);
        let lse = ((-1.0f64).exp() + (-3.0f64).exp()).ln();
        assert!((loss - -(lse - 2.0f64.ln())).abs() < 1e-12);
        assert!((w[0] - ((-1.0f64) - lse).exp()).abs() < 1e-12);
        assert!((w[1] - ((-3.0f64) - lse).exp()).abs() < 1e-12);
        assert!((w[0] + w[1] - 1.0).abs() < 1e-12);

        // one particle: exactly the Trace loss and unit weight
        let one = vec![ParticleStats { value: -2.5, obs: vec![] }];
        let (loss1, w1) = est.combine(&one);
        assert!((loss1 - 2.5).abs() < 1e-12);
        assert!((w1[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_model_trace_is_a_diagnosable_error() {
        let mt = Trace::default();
        let gt = Trace::default();
        let mut est = TraceElbo::default();
        let err = est.loss(&mt, &gt).expect_err("empty trace must be an error");
        assert!(format!("{err}").contains("no sample sites"), "{err}");
        let mut tg = TraceGraphElbo::default();
        assert!(tg.loss(&mt, &gt).is_err());
        assert!(TraceMeanFieldElbo.loss(&mt, &gt).is_err());
        assert!(RenyiElbo::iwae().loss(&mt, &gt).is_err());
    }

    #[test]
    fn default_elbo_picks_estimator_from_advertised_sites() {
        assert_eq!(default_elbo(&[]).name(), "Trace");
        assert_eq!(default_elbo(&["assign".to_string()]).name(), "TraceGraph");
    }

    #[test]
    fn tracegraph_baselines_are_per_site_and_absorb_in_order() {
        let mut est = TraceGraphElbo::default();
        assert_eq!(est.baseline("a"), None);
        est.absorb(&[ParticleStats {
            value: 0.0,
            obs: vec![("a".into(), 2.0), ("b".into(), -1.0)],
        }]);
        assert_eq!(est.baseline("a"), Some(2.0));
        assert_eq!(est.baseline("b"), Some(-1.0));
        est.absorb(&[ParticleStats { value: 0.0, obs: vec![("a".into(), 4.0)] }]);
        // decaying average with beta = 0.9
        assert!((est.baseline("a").unwrap() - (0.9 * 2.0 + 0.1 * 4.0)).abs() < 1e-12);
        assert_eq!(est.baseline("b"), Some(-1.0));
        let snap = est.snapshot();
        assert_eq!(snap.per_site.len(), 2);
        assert_eq!(snap.global, None);
    }
}
