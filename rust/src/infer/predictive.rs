//! Posterior predictive sampling — `pyro.infer.Predictive`.
//!
//! Draws latents from a trained guide, replays them into the model with
//! observed sites *unconditioned* (re-sampled), and collects the values
//! of requested sites.

use crate::params::ParamStore;
use crate::poutine::{handlers, Ctx};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

pub struct Predictive {
    pub num_samples: usize,
}

impl Predictive {
    pub fn new(num_samples: usize) -> Self {
        Predictive { num_samples }
    }

    /// Sample `sites` from the posterior predictive.
    pub fn run(
        &self,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        store: &mut ParamStore,
        rng: &mut Pcg64,
        sites: &[&str],
    ) -> HashMap<String, Vec<Tensor>> {
        let mut out: HashMap<String, Vec<Tensor>> =
            sites.iter().map(|s| (s.to_string(), Vec::new())).collect();
        for _ in 0..self.num_samples {
            // 1. guide draw
            let mut gctx = Ctx::with_store(rng, store);
            guide(&mut gctx);
            let tape = gctx.tape.clone();
            let gt = gctx.into_trace();
            // 2. model with guide latents injected and observes re-sampled
            let predictive_model =
                handlers::uncondition(handlers::replay(model, gt.clone()));
            let mut mctx = Ctx::with_store_on_tape(tape, rng, store);
            predictive_model(&mut mctx);
            let mt = mctx.into_trace();
            for s in sites {
                let site = mt
                    .get(s)
                    .unwrap_or_else(|| panic!("predictive site '{s}' not found"));
                out.get_mut(*s).unwrap().push(site.value.value().clone());
            }
        }
        out
    }

    /// Like [`Predictive::run`], but stacks each site's draws into one
    /// tensor with a leading sample dim: shape
    /// `[num_samples] + batch_shape + event_shape`. With vectorized
    /// plates, a whole posterior-predictive mini-batch comes back as a
    /// single tensor instead of `num_samples` per-point pieces.
    pub fn run_stacked(
        &self,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        store: &mut ParamStore,
        rng: &mut Pcg64,
        sites: &[&str],
    ) -> HashMap<String, Tensor> {
        self.run(model, guide, store, rng, sites)
            .into_iter()
            .map(|(name, draws)| {
                let refs: Vec<&Tensor> = draws.iter().collect();
                (name, Tensor::stack0(&refs))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Constraint, Normal};
    use crate::infer::elbo::TraceElbo;
    use crate::infer::svi::Svi;
    use crate::optim::Adam;

    #[test]
    fn run_stacked_returns_leading_sample_dim() {
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.0));
        };
        let guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(2);
        let out =
            Predictive::new(7).run_stacked(&model, &guide, &mut store, &mut rng, &["x", "z"]);
        assert_eq!(out["x"].dims(), &[7]);
        assert_eq!(out["z"].dims(), &[7]);
    }

    #[test]
    fn predictive_mean_tracks_posterior() {
        // z ~ N(0,1); x ~ N(z,1), observe x = 2.0; posterior z-mean 1.0.
        // Posterior predictive for x has mean 1.0 too.
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(2.0));
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.param("loc", || Tensor::scalar(0.0));
            let scale = ctx.param_constrained(
                "scale",
                || Tensor::scalar(1.0),
                Constraint::Positive,
            );
            ctx.sample("z", Normal::new(loc, scale));
        };
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(1);
        let mut svi = Svi::new(Adam::new(0.03), TraceElbo::default());
        for _ in 0..1200 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let pred = Predictive::new(4000).run(&model, &guide, &mut store, &mut rng, &["x", "z"]);
        let mx: f64 =
            pred["x"].iter().map(|t| t.item()).sum::<f64>() / pred["x"].len() as f64;
        let mz: f64 =
            pred["z"].iter().map(|t| t.item()).sum::<f64>() / pred["z"].len() as f64;
        assert!((mz - 1.0).abs() < 0.1, "posterior z mean {mz}");
        assert!((mx - 1.0).abs() < 0.1, "predictive x mean {mx}");
        // predictive x variance = posterior var + obs var ≈ 0.5 + 1.0
        let vx: f64 = pred["x"].iter().map(|t| (t.item() - mx).powi(2)).sum::<f64>()
            / pred["x"].len() as f64;
        assert!((vx - 1.5).abs() < 0.25, "predictive var {vx}");
    }
}
