//! Posterior predictive sampling — `pyro.infer.Predictive`.
//!
//! Draws latents from a trained guide, replays them into the model with
//! observed sites *unconditioned* (re-sampled), and collects the values
//! of requested sites.
//!
//! All entry points take the [`ParamStore`] by shared reference: a
//! predictive pass only *reads* trained parameters, and the serving
//! layer ([`crate::serve`]) relies on that being enforced by type —
//! a frozen model's store is shared across worker threads and must
//! never be touched. A `ctx.param` on a name absent from the store
//! panics with `[FY016]` instead of silently initializing.

use crate::params::ParamStore;
use crate::poutine::{handlers, Ctx};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

pub struct Predictive {
    pub num_samples: usize,
}

impl Predictive {
    pub fn new(num_samples: usize) -> Self {
        Predictive { num_samples }
    }

    /// One guide→replay→uncondition pass per sample, handing each
    /// requested site's tensor to `sink(site_index, draw_index, value)`.
    /// `run`, `run_stacked`, and `run_stacked_into` are all thin
    /// adapters over this loop.
    fn draws(
        &self,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        store: &ParamStore,
        rng: &mut Pcg64,
        sites: &[&str],
        mut sink: impl FnMut(usize, usize, &Tensor),
    ) {
        for draw in 0..self.num_samples {
            // 1. guide draw (read-only param access)
            let mut gctx = Ctx::with_frozen_store(rng, store);
            guide(&mut gctx);
            let tape = gctx.tape.clone();
            let gt = gctx.into_trace();
            // 2. model with guide latents injected and observes re-sampled
            let predictive_model =
                handlers::uncondition(handlers::replay(model, gt.clone()));
            let mut mctx = Ctx::with_frozen_store_on_tape(tape, rng, store);
            predictive_model(&mut mctx);
            let mt = mctx.into_trace();
            for (i, s) in sites.iter().enumerate() {
                let site = mt
                    .get(s)
                    .unwrap_or_else(|| panic!("predictive site '{s}' not found"));
                sink(i, draw, site.value.value());
            }
        }
    }

    /// Sample `sites` from the posterior predictive.
    pub fn run(
        &self,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        store: &ParamStore,
        rng: &mut Pcg64,
        sites: &[&str],
    ) -> HashMap<String, Vec<Tensor>> {
        let mut cols: Vec<Vec<Tensor>> =
            sites.iter().map(|_| Vec::with_capacity(self.num_samples)).collect();
        self.draws(model, guide, store, rng, sites, |i, _, t| {
            cols[i].push(t.clone());
        });
        sites
            .iter()
            .zip(cols)
            .map(|(s, col)| (s.to_string(), col))
            .collect()
    }

    /// Like [`Predictive::run`], but stacks each site's draws into one
    /// tensor with a leading sample dim: shape
    /// `[num_samples] + batch_shape + event_shape`. With vectorized
    /// plates, a whole posterior-predictive mini-batch comes back as a
    /// single tensor instead of `num_samples` per-point pieces.
    pub fn run_stacked(
        &self,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        store: &ParamStore,
        rng: &mut Pcg64,
        sites: &[&str],
    ) -> HashMap<String, Tensor> {
        let mut out = HashMap::new();
        self.run_stacked_into(model, guide, store, rng, sites, &mut out);
        out
    }

    /// [`Predictive::run_stacked`] writing into caller-owned output
    /// slabs. When `out` already holds a correctly-shaped tensor for a
    /// site (e.g. from a previous call with the same site set and
    /// sample count), its buffer is reused via copy-on-write
    /// `data_mut` — zero per-site allocation in steady state, which is
    /// what keeps the serve worker hot loop off the allocator. Stale or
    /// mis-shaped entries are replaced; entries for sites not in
    /// `sites` are removed.
    pub fn run_stacked_into(
        &self,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        store: &ParamStore,
        rng: &mut Pcg64,
        sites: &[&str],
        out: &mut HashMap<String, Tensor>,
    ) {
        out.retain(|k, _| sites.iter().any(|s| s == k));
        // Slabs are sized lazily on the first draw, when per-site
        // shapes are known; later draws just memcpy into their slice.
        let mut strides: Vec<usize> = vec![0; sites.len()];
        self.draws(model, guide, store, rng, sites, |i, draw, t| {
            let name = sites[i];
            if draw == 0 {
                let mut dims = Vec::with_capacity(t.dims().len() + 1);
                dims.push(self.num_samples);
                dims.extend_from_slice(t.dims());
                strides[i] = t.numel();
                let reusable = out
                    .get(name)
                    .is_some_and(|slab| slab.dims() == dims.as_slice());
                if !reusable {
                    out.insert(name.to_string(), Tensor::zeros(dims));
                }
            }
            let stride = strides[i];
            let slab = out.get_mut(name).expect("slab prepared on first draw");
            assert_eq!(
                t.numel(),
                stride,
                "predictive site '{name}' changed shape across draws"
            );
            slab.data_mut()[draw * stride..(draw + 1) * stride]
                .copy_from_slice(t.data());
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Constraint, Normal};
    use crate::infer::elbo::TraceElbo;
    use crate::infer::svi::Svi;
    use crate::optim::Adam;

    #[test]
    fn run_stacked_returns_leading_sample_dim() {
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.0));
        };
        let guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let store = ParamStore::new();
        let mut rng = Pcg64::new(2);
        let out = Predictive::new(7).run_stacked(&model, &guide, &store, &mut rng, &["x", "z"]);
        assert_eq!(out["x"].dims(), &[7]);
        assert_eq!(out["z"].dims(), &[7]);
    }

    #[test]
    fn run_stacked_into_reuses_and_matches() {
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.0));
        };
        let guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let store = ParamStore::new();
        let pred = Predictive::new(5);

        let mut rng_a = Pcg64::new(42);
        let fresh = pred.run_stacked(&model, &guide, &store, &mut rng_a, &["x"]);

        // warm a reusable slab with a *different* stream, then refill it
        // from the same seed as `fresh` — results must be bitwise equal.
        let mut out = HashMap::new();
        let mut rng_warm = Pcg64::new(7);
        pred.run_stacked_into(&model, &guide, &store, &mut rng_warm, &["x"], &mut out);
        let mut rng_b = Pcg64::new(42);
        pred.run_stacked_into(&model, &guide, &store, &mut rng_b, &["x"], &mut out);
        assert_eq!(out["x"].dims(), fresh["x"].dims());
        let same = out["x"]
            .data()
            .iter()
            .zip(fresh["x"].data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "slab-reusing refill diverged from fresh run");

        // stale sites are dropped
        out.insert("stale".to_string(), Tensor::scalar(0.0));
        let mut rng_c = Pcg64::new(42);
        pred.run_stacked_into(&model, &guide, &store, &mut rng_c, &["x"], &mut out);
        assert!(!out.contains_key("stale"));
    }

    #[test]
    fn predictive_mean_tracks_posterior() {
        // z ~ N(0,1); x ~ N(z,1), observe x = 2.0; posterior z-mean 1.0.
        // Posterior predictive for x has mean 1.0 too.
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(2.0));
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.param("loc", || Tensor::scalar(0.0));
            let scale = ctx.param_constrained(
                "scale",
                || Tensor::scalar(1.0),
                Constraint::Positive,
            );
            ctx.sample("z", Normal::new(loc, scale));
        };
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(1);
        let mut svi = Svi::new(Adam::new(0.03), TraceElbo::default());
        for _ in 0..1200 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let pred = Predictive::new(4000).run(&model, &guide, &store, &mut rng, &["x", "z"]);
        let mx: f64 =
            pred["x"].iter().map(|t| t.item()).sum::<f64>() / pred["x"].len() as f64;
        let mz: f64 =
            pred["z"].iter().map(|t| t.item()).sum::<f64>() / pred["z"].len() as f64;
        assert!((mz - 1.0).abs() < 0.1, "posterior z mean {mz}");
        assert!((mx - 1.0).abs() < 0.1, "predictive x mean {mx}");
        // predictive x variance = posterior var + obs var ≈ 0.5 + 1.0
        let vx: f64 = pred["x"].iter().map(|t| (t.item() - mx).powi(2)).sum::<f64>()
            / pred["x"].len() as f64;
        assert!((vx - 1.5).abs() < 0.25, "predictive var {vx}");
    }
}
