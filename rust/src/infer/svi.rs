//! Stochastic variational inference — `pyro.infer.SVI`.
//!
//! One step (paper Fig 1):
//!   1. run the guide, recording its trace (and touching its params);
//!   2. replay the model against the guide's latent draws on the same
//!      autodiff tape;
//!   3. differentiate the estimator's surrogate loss w.r.t. every
//!      parameter leaf touched by either program;
//!   4. hand the gradients to the optimizer, which updates the store.
//!
//! The guide runs *first* and the model only ever sees its values through
//! replay — structurally enforcing the paper's rule that guides may not
//! depend on values inside the model.
//!
//! ## Estimator objects
//!
//! `Svi` is generic over any [`Elbo`] implementation — the loss is a
//! first-class object, exactly `SVI(model, guide, optim, loss=Trace_ELBO())`
//! in the paper. Pick statically:
//!
//! ```ignore
//! let mut svi = Svi::new(Adam::new(0.01), TraceElbo::default());
//! let mut svi = Svi::new(Adam::new(0.01), TraceGraphElbo::default());
//! ```
//!
//! or dynamically through `Box<dyn Elbo>`:
//!
//! ```ignore
//! let elbo: Box<dyn Elbo> = default_elbo(&auto.nonreparam_sites());
//! let mut svi = Svi::new(Adam::new(0.01), elbo);
//! ```
//!
//! ## Multi-particle execution
//!
//! Each of the `num_particles` Monte-Carlo terms runs against its own
//! seeded RNG and its own tape, so particles are fully independent; they
//! all read the same pre-step [`Elbo::snapshot`] and their observations
//! are absorbed back in particle order. With [`SviConfig::parallel`] set
//! (opt-in) each particle additionally gets a private parameter-store
//! clone and they are evaluated on scoped worker threads and merged back
//! in particle order — making the parallel result **bitwise equal** to
//! the serial one for a given seed. Per-particle seeds are drawn from
//! the caller's RNG up front, so results are reproducible regardless of
//! thread scheduling. [`Elbo::combine`] turns per-particle statistics
//! into the reported loss and per-particle gradient weights (uniform for
//! Trace-style estimators, importance weights for Rényi/IWAE).

use crate::infer::compile::{self, GraphDiagnostics, GraphRunner, Recorded};
use crate::infer::elbo::{BaselineSnapshot, Elbo, ParticleCtx, ParticleStats, TraceElbo};
use crate::optim::{apply_grads, Optimizer};
use crate::params::ParamStore;
use crate::poutine::{handlers, Ctx, Trace};
use crate::telemetry;
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

/// A probabilistic program usable with [`Svi`]: threads may evaluate it
/// concurrently, so its captures must be `Sync` (plain data always is).
pub type ModelFn = dyn Fn(&mut Ctx) + Sync;

/// SVI configuration (particle count and threading; the loss estimator
/// is an [`Elbo`] object passed to [`Svi::new`], no longer a config
/// field).
#[derive(Clone, Copy, Debug)]
pub struct SviConfig {
    /// Monte-Carlo particles per step (gradients averaged / weighted by
    /// the estimator's `combine`).
    pub num_particles: usize,
    /// Evaluate particles on worker threads (opt-in; worth it once a
    /// particle costs more than thread spawn, i.e. real models rather
    /// than toy scalar ones). Purely a throughput switch: serial and
    /// parallel execution produce identical results for a given seed.
    pub parallel: bool,
    /// Worker-thread cap (0 = one per available core).
    pub num_threads: usize,
    /// Compile static traces into straight-line fused ELBO kernels
    /// ([`crate::infer::compile`]). Opt-in: the first step records and
    /// verifies a compiled program; subsequent steps run it as long as
    /// cheap guards hold, falling back loudly to the dynamic path (and
    /// re-recording) when they don't. Requires a compilable estimator
    /// ([`Elbo::compilable`]); otherwise graph mode disables itself and
    /// every step stays dynamic.
    pub graph_mode: bool,
    /// With graph mode on: re-trace dynamically every N compiled steps
    /// to catch structure changes no cheap guard can see
    /// (data-dependent control flow). 0 = never re-validate (trust the
    /// fingerprint guard alone). The re-trace is a full dynamic step,
    /// so its result is exact either way.
    pub graph_revalidate: u64,
    /// Run the static model/guide linter ([`crate::analysis`]) before
    /// the first training step and refuse to train on Error-severity
    /// diagnostics (guide/model site mismatches, plate shape bugs,
    /// out-of-support observations, ...). The lint runs on a cloned
    /// store and a forked RNG, so the training trajectory is bit-for-bit
    /// identical with the flag on or off; diagnostics also flow through
    /// the telemetry warn sink with their stable `FYxxx` codes. Opt-in;
    /// [`Svi::analyze`] runs the same pass standalone.
    pub validate: bool,
}

impl Default for SviConfig {
    fn default() -> Self {
        SviConfig {
            num_particles: 1,
            parallel: false,
            num_threads: 0,
            graph_mode: false,
            graph_revalidate: 0,
            validate: false,
        }
    }
}

impl SviConfig {
    pub(crate) fn effective_threads(&self, particles: usize) -> usize {
        if !self.parallel {
            return 1;
        }
        let hw = if self.num_threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.num_threads
        };
        hw.min(particles).max(1)
    }
}

/// Everything a particle evaluation produces. `Send`, so workers can
/// hand it across the thread boundary; all tape state stays worker-local.
/// Crate-visible: the data-parallel driver ([`crate::infer::data_parallel`])
/// and the async parameter server ([`crate::coordinator`]) evaluate
/// shard gradients through the same function.
pub(crate) struct ParticleOut {
    pub(crate) grads: HashMap<String, Tensor>,
    pub(crate) stats: ParticleStats,
}

/// Evaluate one ELBO particle against `store`: fresh seeded RNG, fresh
/// tape, the estimator called through the [`Elbo`] trait with the shared
/// pre-step snapshot. The serial path hands in the caller's store
/// directly (zero copies); workers hand in private clones. Because
/// `ctx.param` init closures are deterministic per name, the two produce
/// identical results — the parity tests pin this.
pub(crate) fn run_particle<E: Elbo + ?Sized>(
    seed: u64,
    store: &mut ParamStore,
    model: &ModelFn,
    guide: &ModelFn,
    elbo: &E,
    snapshot: &BaselineSnapshot,
) -> crate::error::Result<ParticleOut> {
    let _span = telemetry::span(telemetry::Hist::ParticleNs);
    let local = store;
    let mut rng = Pcg64::new(seed);

    // 1. guide pass
    let mut gctx = Ctx::with_store(&mut rng, local);
    guide(&mut gctx);
    let tape = gctx.tape.clone();
    let guide_trace = gctx.into_trace();

    // 2. model pass, replayed, on the same tape
    let replayed = handlers::replay(model, guide_trace.clone());
    let mut mctx = Ctx::with_store_on_tape(tape.clone(), &mut rng, local);
    replayed(&mut mctx);
    let model_trace = mctx.into_trace();

    // 3. estimator loss + gradients
    let mut pctx = ParticleCtx::new(snapshot);
    let (loss, value) = elbo.differentiable_loss(&model_trace, &guide_trace, &mut pctx)?;
    let mut leaves: Vec<(String, crate::autodiff::Var)> = Vec::new();
    for (name, leaf) in guide_trace
        .param_leaves
        .iter()
        .chain(model_trace.param_leaves.iter())
    {
        if !leaves.iter().any(|(n, _)| n == name) {
            leaves.push((name.clone(), leaf.clone()));
        }
    }
    let leaf_refs: Vec<&crate::autodiff::Var> = leaves.iter().map(|(_, v)| v).collect();
    let grads = tape.grad(&loss, &leaf_refs);
    let grad_map = leaves
        .iter()
        .map(|(n, _)| n.clone())
        .zip(grads)
        .collect::<HashMap<_, _>>();
    Ok(ParticleOut { grads: grad_map, stats: ParticleStats { value, obs: pctx.obs } })
}

/// Run all particles, serially or on scoped worker threads, returning
/// the outputs in particle-index order either way.
///
/// Serial execution works directly on the caller's store (no clones).
/// Parallel execution gives each particle a private store clone and
/// merges params first initialized inside particles back in index
/// order — deterministic because `ctx.param` init closures are
/// deterministic per name, so the two modes match bitwise.
fn run_particles<E: Elbo + ?Sized>(
    config: &SviConfig,
    seeds: &[u64],
    store: &mut ParamStore,
    model: &ModelFn,
    guide: &ModelFn,
    elbo: &E,
    snapshot: &BaselineSnapshot,
) -> crate::error::Result<Vec<ParticleOut>> {
    let n = seeds.len();
    let threads = config.effective_threads(n);
    if threads <= 1 || n <= 1 {
        return seeds
            .iter()
            .map(|&s| run_particle(s, store, model, guide, elbo, snapshot))
            .collect();
    }
    let chunk = n.div_ceil(threads);
    let mut results: Vec<Option<crate::error::Result<(ParticleOut, ParamStore)>>> =
        Vec::with_capacity(n);
    results.resize_with(n, || None);
    {
        let shared = &*store;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(threads);
            for (w, seed_chunk) in seeds.chunks(chunk).enumerate() {
                let base = w * chunk;
                handles.push(scope.spawn(move || {
                    seed_chunk
                        .iter()
                        .enumerate()
                        .map(|(j, &s)| {
                            let mut local = shared.clone();
                            let out =
                                run_particle(s, &mut local, model, guide, elbo, snapshot)
                                    .map(|o| (o, local));
                            (base + j, out)
                        })
                        .collect::<Vec<_>>()
                }));
            }
            for h in handles {
                for (i, out) in h.join().expect("ELBO particle worker panicked") {
                    results[i] = Some(out);
                }
            }
        });
    }
    let mut outs = Vec::with_capacity(n);
    for r in results {
        let (out, local) = r.expect("missing particle result")?;
        store.merge_missing(&local);
        outs.push(out);
    }
    Ok(outs)
}

/// The SVI engine. Generic over the optimizer and the [`Elbo`]
/// estimator (defaulting to [`TraceElbo`]); `Box<dyn Elbo>` works for
/// runtime selection.
pub struct Svi<O: Optimizer, E: Elbo = TraceElbo> {
    pub opt: O,
    /// The loss estimator object; its cross-step state (baselines) is
    /// public so diagnostics can inspect it.
    pub elbo: E,
    pub config: SviConfig,
    steps: u64,
    graph: GraphState,
    diags: GraphDiagnostics,
}

/// Where graph mode currently stands for this engine.
enum GraphState {
    /// Graph mode requested (or off); nothing recorded yet.
    Pending,
    /// A verified compiled program is installed. Boxed: the program and
    /// its arenas are large relative to the rest of `Svi`.
    Active { runner: Box<GraphRunner>, steps_since_validate: u64 },
    /// Compilation failed for a reason that cannot self-heal (inherently
    /// dynamic model, unsupported op, verification mismatch). Every
    /// subsequent step runs the dynamic path; `graph_diagnostics`
    /// carries the reason.
    Disabled,
}

/// What a graph-mode step decided to do, computed under a shared borrow
/// of the state so the acting arms below can borrow `self` mutably.
enum GraphDecision {
    Dynamic { disable: Option<String> },
    Compiled,
    Record { revalidate: bool, fallback: Option<String> },
}

impl<O: Optimizer, E: Elbo> Svi<O, E> {
    /// `SVI(model, guide, optim, loss=Trace_ELBO())` — the estimator is
    /// an object, e.g. `Svi::new(opt, TraceElbo::default())`.
    pub fn new(opt: O, elbo: E) -> Self {
        Self::with_config(opt, elbo, SviConfig::default())
    }

    pub fn with_config(opt: O, elbo: E, config: SviConfig) -> Self {
        Svi {
            opt,
            elbo,
            config,
            steps: 0,
            graph: GraphState::Pending,
            diags: GraphDiagnostics::default(),
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.steps
    }

    /// Counters and last-error text for graph mode ([`SviConfig::graph_mode`]).
    pub fn graph_diagnostics(&self) -> &GraphDiagnostics {
        &self.diags
    }

    /// One SVI step; returns the **loss**, like `pyro.infer.SVI`.
    /// Panics on malformed programs (e.g. an empty model trace); use
    /// [`Svi::try_step`] to handle those as errors.
    pub fn step(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> f64 {
        self.try_step(store, rng, model, guide).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible [`Svi::step`]: estimator failures (empty or fully-blocked
    /// model traces, estimator/guide mismatches) surface as
    /// [`crate::error::Error`].
    pub fn try_step(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> crate::error::Result<f64> {
        let _span = telemetry::span(telemetry::Hist::StepNs);
        if self.config.validate && self.steps == 0 {
            let report = self.analyze(store, rng.clone().next_u64(), model, guide);
            if report.has_errors() {
                return Err(report.to_error());
            }
        }
        if self.config.graph_mode {
            self.try_step_graph(store, rng, model, guide)
        } else {
            self.try_step_dynamic(store, rng, model, guide)
        }
    }

    /// Run the static model/guide linter ([`crate::analysis`]) under
    /// this engine's estimator, standalone and side-effect-free: the
    /// store is cloned before the probe execution (lazily-initialized
    /// params land in the clone and are discarded), the RNG is seeded
    /// from `seed`, and nothing about the engine changes. Diagnostics
    /// are emitted through the telemetry warn sink
    /// ([`crate::analysis::Report::emit`]) and returned for inspection.
    ///
    /// [`SviConfig::validate`] runs exactly this before the first step
    /// and turns Error-severity findings into a refusal to train.
    pub fn analyze(
        &self,
        store: &ParamStore,
        seed: u64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> crate::analysis::Report {
        let mut probe = store.clone();
        let hint = crate::analysis::EstimatorHint {
            name: self.elbo.name(),
            variance_reduced: self.elbo.variance_reduced(),
        };
        let report = crate::analysis::lint_model_guide(
            &mut probe,
            seed,
            &|c: &mut Ctx| model(c),
            &|c: &mut Ctx| guide(c),
            Some(&hint),
        );
        report.emit();
        report
    }

    fn try_step_dynamic(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> crate::error::Result<f64> {
        let n = self.config.num_particles.max(1);
        let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let snapshot = self.elbo.snapshot();
        let config = self.config;
        let results =
            run_particles(&config, &seeds, store, model, guide, &self.elbo, &snapshot)?;
        self.finish_step(results, store)
    }

    /// Shared tail of every dynamic-path step: combine particle stats,
    /// merge gradients deterministically, apply them, absorb estimator
    /// state. Recording steps in graph mode reuse this so a recorded
    /// step *is* a full training step, not a wasted trace.
    fn finish_step(
        &mut self,
        results: Vec<ParticleOut>,
        store: &mut ParamStore,
    ) -> crate::error::Result<f64> {
        let mut stats = Vec::with_capacity(results.len());
        let mut particle_grads = Vec::with_capacity(results.len());
        for r in results {
            stats.push(r.stats);
            particle_grads.push(r.grads);
        }
        let (loss, weights) = self.elbo.combine(&stats);
        debug_assert_eq!(weights.len(), particle_grads.len());

        // deterministic gradient merge: per-name accumulation follows
        // particle-index order, in place. Uniform weights (Trace-style
        // averaging) accumulate raw and scale once; non-uniform weights
        // (Rényi importance weighting) scale each particle first.
        let uniform = weights.windows(2).all(|w| w[0] == w[1]);
        let mut acc_grads: HashMap<String, Tensor> = HashMap::new();
        if uniform {
            for grads in particle_grads {
                for (name, g) in grads {
                    acc_grads
                        .entry(name)
                        .and_modify(|a| a.add_assign(&g))
                        .or_insert(g);
                }
            }
            let w = weights.first().copied().unwrap_or(1.0);
            if w != 1.0 {
                for g in acc_grads.values_mut() {
                    g.scale_inplace(w);
                }
            }
        } else {
            for (grads, &w) in particle_grads.into_iter().zip(&weights) {
                for (name, mut g) in grads {
                    g.scale_inplace(w);
                    acc_grads
                        .entry(name)
                        .and_modify(|a| a.add_assign(&g))
                        .or_insert(g);
                }
            }
        }
        // Telemetry reads what the step already computed (loss, merged
        // grads, per-particle values) and never feeds anything back —
        // enabled vs disabled stays bitwise identical.
        if telemetry::enabled() {
            telemetry::record_loss(loss);
            telemetry::count(telemetry::Counter::DynamicSteps);
            let values: Vec<f64> = stats.iter().map(|s| s.value).collect();
            telemetry::record_particle_spread(&values);
            telemetry::record_grad_norm(&acc_grads);
        }
        apply_grads(&mut self.opt, store, &acc_grads);
        // training only: fold particle observations into estimator state
        self.elbo.absorb(&stats);
        self.steps += 1;
        Ok(loss)
    }

    fn try_step_graph(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> crate::error::Result<f64> {
        let decision = match &self.graph {
            GraphState::Disabled => GraphDecision::Dynamic { disable: None },
            _ if !self.elbo.compilable() => GraphDecision::Dynamic {
                disable: Some(format!(
                    "estimator '{}' is not compilable (score-function surrogate terms or \
                     non-default particle weighting); unset SviConfig::graph_mode or use \
                     TraceElbo / TraceMeanFieldElbo",
                    self.elbo.name()
                )),
            },
            GraphState::Pending => {
                GraphDecision::Record { revalidate: false, fallback: None }
            }
            GraphState::Active { runner, steps_since_validate } => {
                if runner.prog().store_fp != store.fingerprint() {
                    GraphDecision::Record {
                        revalidate: false,
                        fallback: Some(
                            "parameter store changed shape since compilation (a param \
                             was added, removed, reshaped, or re-constrained)"
                                .to_string(),
                        ),
                    }
                } else if self.config.graph_revalidate > 0
                    && *steps_since_validate >= self.config.graph_revalidate
                {
                    GraphDecision::Record { revalidate: true, fallback: None }
                } else {
                    GraphDecision::Compiled
                }
            }
        };
        match decision {
            GraphDecision::Dynamic { disable } => {
                if let Some(why) = disable {
                    self.disable_graph(why);
                }
                self.diags.dynamic_steps += 1;
                self.try_step_dynamic(store, rng, model, guide)
            }
            GraphDecision::Compiled => {
                let GraphState::Active { runner, steps_since_validate } = &mut self.graph
                else {
                    unreachable!("decision computed from Active state")
                };
                let loss = runner.step(store, rng, &mut self.opt, &self.config);
                *steps_since_validate += 1;
                self.diags.compiled_steps += 1;
                self.steps += 1;
                // allocation-free probes only: the compiled step is
                // gated at 0 allocs/step with telemetry enabled
                telemetry::record_loss(loss);
                telemetry::count(telemetry::Counter::CompiledSteps);
                Ok(loss)
            }
            GraphDecision::Record { revalidate, fallback } => {
                if let Some(why) = fallback {
                    self.note_fallback(why);
                }
                self.record_compile_step(store, rng, model, guide, revalidate)
            }
        }
    }

    /// One dynamic step that also records the tape of its first
    /// particle, compiles it, verifies the compiled program against the
    /// recording, and installs it for subsequent steps. The step's own
    /// result comes from the dynamic path (via [`Svi::finish_step`]), so
    /// a recording step is bit-identical to a plain dynamic step.
    fn record_compile_step(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
        revalidate: bool,
    ) -> crate::error::Result<f64> {
        let n = self.config.num_particles.max(1);
        let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let snapshot = self.elbo.snapshot();
        let (recorded, out0) =
            compile::record_particle(seeds[0], store, model, guide, &self.elbo, &snapshot)?;
        let mut results = Vec::with_capacity(n);
        results.push(ParticleOut {
            grads: out0.grads,
            stats: ParticleStats { value: out0.value, obs: out0.obs },
        });
        // Remaining particles run serially here: recording steps are
        // rare (first step + optional revalidation cadence), and the
        // serial path is bitwise-equal to the parallel one anyway.
        for &s in &seeds[1..] {
            results.push(run_particle(s, store, model, guide, &self.elbo, &snapshot)?);
        }
        match recorded {
            Recorded::Inherent(why) => self.disable_graph(why),
            // Verify against the pre-update store — the recorded grads
            // were computed before this step's optimizer update lands in
            // `finish_step` below.
            Recorded::Ready(rec) => self.install_program(store, &rec, seeds[0], revalidate),
        }
        self.diags.dynamic_steps += 1;
        self.finish_step(results, store)
    }

    /// Compile + verify + install a recording; on the re-validation
    /// cadence, keep the existing program when the structure is
    /// unchanged, otherwise report the skeleton diff and rebuild.
    fn install_program(
        &mut self,
        store: &ParamStore,
        rec: &compile::Recording,
        seed: u64,
        revalidate: bool,
    ) {
        if revalidate {
            let unchanged_or_diff = match &self.graph {
                GraphState::Active { runner, .. } => {
                    if runner.prog().struct_hash == rec.struct_hash
                        && runner.prog().store_fp == rec.store_fp
                    {
                        Some(None)
                    } else {
                        Some(Some(compile::skeleton_diff(
                            &runner.prog().skeleton,
                            &rec.skeleton,
                        )))
                    }
                }
                _ => None,
            };
            match unchanged_or_diff {
                Some(None) => {
                    if let GraphState::Active { steps_since_validate, .. } = &mut self.graph {
                        *steps_since_validate = 0;
                    }
                    self.diags.revalidations += 1;
                    telemetry::count(telemetry::Counter::GraphRevalidations);
                    return;
                }
                Some(Some(diff)) => {
                    self.diags.last_structure_diff = Some(diff.clone());
                    self.note_fallback(format!(
                        "model/guide structure changed since compilation:\n{diff}"
                    ));
                }
                None => {}
            }
        }
        match compile::CompiledProgram::compile(rec) {
            Err(e) => self.disable_graph(e.to_string()),
            Ok(prog) => match prog.verify(store, rec, seed) {
                Err(e) => self.disable_graph(e.to_string()),
                Ok(()) => {
                    self.graph = GraphState::Active {
                        runner: Box::new(GraphRunner::new(prog)),
                        steps_since_validate: 0,
                    };
                    self.diags.compiles += 1;
                    self.diags.active = true;
                    telemetry::count(telemetry::Counter::GraphCompiles);
                }
            },
        }
    }

    /// Eagerly record, compile, and verify a graph program for
    /// `(model, guide)` without taking a training step (gradients from
    /// the recording run are discarded; lazily-initialized params do
    /// land in `store`, matching `evaluate_loss` semantics). Turns
    /// [`SviConfig::graph_mode`] on. `Err` means the pair is inherently
    /// dynamic or failed verification — SVI still works, it just runs
    /// the dynamic path every step.
    pub fn compile(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> crate::error::Result<()> {
        self.config.graph_mode = true;
        if !self.elbo.compilable() {
            let why = format!(
                "estimator '{}' is not compilable (score-function surrogate terms or \
                 non-default particle weighting)",
                self.elbo.name()
            );
            self.disable_graph(why.clone());
            return Err(crate::error::Error::msg(why));
        }
        let seed = rng.next_u64();
        let snapshot = self.elbo.snapshot();
        let (recorded, _discarded) =
            compile::record_particle(seed, store, model, guide, &self.elbo, &snapshot)?;
        match recorded {
            Recorded::Inherent(why) => {
                self.disable_graph(why.clone());
                Err(crate::error::Error::msg(why))
            }
            Recorded::Ready(rec) => {
                let prog = match compile::CompiledProgram::compile(&rec) {
                    Ok(p) => p,
                    Err(e) => {
                        self.disable_graph(e.to_string());
                        return Err(e);
                    }
                };
                if let Err(e) = prog.verify(store, &rec, seed) {
                    self.disable_graph(e.to_string());
                    return Err(e);
                }
                self.graph = GraphState::Active {
                    runner: Box::new(GraphRunner::new(prog)),
                    steps_since_validate: 0,
                };
                self.diags.compiles += 1;
                self.diags.active = true;
                telemetry::count(telemetry::Counter::GraphCompiles);
                Ok(())
            }
        }
    }

    /// Permanently give up on graph mode for this engine, loudly.
    fn disable_graph(&mut self, why: String) {
        telemetry::warn(telemetry::WarnKind::GraphDisabled, &why);
        telemetry::count(telemetry::Counter::GraphDisables);
        self.diags.active = false;
        self.diags.last_error = Some(why);
        self.graph = GraphState::Disabled;
    }

    /// Loud, recoverable fallback: this step goes dynamic and re-records.
    fn note_fallback(&mut self, why: String) {
        telemetry::warn(telemetry::WarnKind::GraphFallback, &why);
        telemetry::count(telemetry::Counter::GraphFallbacks);
        self.diags.fallbacks += 1;
        self.diags.active = false;
        self.diags.last_error = Some(why);
    }

    /// Estimate the loss without updating parameters **or estimator
    /// state** — `&self`: evaluation passes cannot advance baselines or
    /// their decay schedules. (The store is still `&mut` only so params
    /// can lazily initialize on a fresh store.)
    pub fn evaluate_loss(
        &self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> f64 {
        self.try_evaluate_loss(store, rng, model, guide)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible [`Svi::evaluate_loss`].
    pub fn try_evaluate_loss(
        &self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> crate::error::Result<f64> {
        let n = self.config.num_particles.max(1);
        let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let snapshot = self.elbo.snapshot();
        let results =
            run_particles(&self.config, &seeds, store, model, guide, &self.elbo, &snapshot)?;
        let stats: Vec<ParticleStats> = results.into_iter().map(|r| r.stats).collect();
        Ok(self.elbo.combine(&stats).0)
    }
}

/// Retrieve the traces of one guide/model pass (diagnostics, tests).
pub fn trace_pair(
    store: &mut ParamStore,
    rng: &mut Pcg64,
    model: &dyn Fn(&mut Ctx),
    guide: &dyn Fn(&mut Ctx),
) -> (Trace, Trace) {
    let mut gctx = Ctx::with_store(rng, store);
    guide(&mut gctx);
    let tape = gctx.tape.clone();
    let guide_trace = gctx.into_trace();
    let replayed = handlers::replay(model, guide_trace.clone());
    let mut mctx = Ctx::with_store_on_tape(tape, rng, store);
    replayed(&mut mctx);
    (mctx.into_trace(), guide_trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Bernoulli, Constraint, Dist, Normal};
    use crate::infer::elbo::{RenyiElbo, TraceGraphElbo, TraceMeanFieldElbo};
    use crate::optim::Adam;
    use crate::poutine::Ctx;

    /// Conjugate 1-D model: z ~ N(0,1), x ~ N(z, 1), x = 0.6 observed.
    /// Posterior: N(0.3, 1/sqrt(2)).
    fn model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    fn guide(ctx: &mut Ctx) {
        let loc = ctx.param("q_loc", || Tensor::scalar(0.0));
        let scale = ctx.param_constrained(
            "q_scale",
            || Tensor::scalar(1.0),
            Constraint::Positive,
        );
        ctx.sample("z", Normal::new(loc, scale));
    }

    /// Discrete-latent model/guide pair (score-function path).
    fn discrete_model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Bernoulli::std(0.5));
        let logits = z.mul_scalar(8.0).add_scalar(-4.0);
        ctx.observe("x", Bernoulli::new(logits), Tensor::scalar(1.0));
    }

    fn discrete_guide(ctx: &mut Ctx) {
        let logit = ctx.param("q_logit", || Tensor::scalar(0.0));
        ctx.sample("z", Bernoulli::new(logit));
    }

    #[test]
    fn svi_recovers_conjugate_posterior() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(7);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            TraceElbo::default(),
            SviConfig { num_particles: 4, ..SviConfig::default() },
        );
        for _ in 0..1500 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let loc = store.get("q_loc").unwrap().item();
        let scale = store.get("q_scale").unwrap().item();
        assert!((loc - 0.3).abs() < 0.06, "posterior loc {loc}");
        assert!((scale - 0.7071).abs() < 0.08, "posterior scale {scale}");
    }

    #[test]
    fn svi_mean_field_matches_analytic_optimum() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(9);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            TraceMeanFieldElbo,
            SviConfig { num_particles: 2, ..SviConfig::default() },
        );
        for _ in 0..1500 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let loc = store.get("q_loc").unwrap().item();
        let scale = store.get("q_scale").unwrap().item();
        assert!((loc - 0.3).abs() < 0.05, "posterior loc {loc}");
        assert!((scale - 0.7071).abs() < 0.06, "posterior scale {scale}");
    }

    #[test]
    fn svi_tracegraph_recovers_conjugate_posterior() {
        // fully reparameterized model: TraceGraph must behave exactly
        // like Trace (no score sites) through the full training loop
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(7);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            TraceGraphElbo::default(),
            SviConfig { num_particles: 4, ..SviConfig::default() },
        );
        for _ in 0..1500 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let loc = store.get("q_loc").unwrap().item();
        assert!((loc - 0.3).abs() < 0.06, "posterior loc {loc}");
    }

    #[test]
    fn tracegraph_trains_discrete_latent() {
        // likelihood strongly rewards z = 1: the guide's logit must move
        // up under Rao-Blackwellized score gradients
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0x7A11);
        let mut svi = Svi::with_config(
            Adam::new(0.05),
            TraceGraphElbo::default(),
            SviConfig { num_particles: 2, ..SviConfig::default() },
        );
        for _ in 0..600 {
            svi.step(&mut store, &mut rng, &discrete_model, &discrete_guide);
        }
        let logit = store.get("q_logit").unwrap().item();
        assert!(logit > 1.0, "q_logit should move up, got {logit}");
    }

    #[test]
    fn box_dyn_elbo_selects_estimator_at_runtime() {
        for graph in [false, true] {
            let elbo: Box<dyn Elbo> = if graph {
                Box::new(TraceGraphElbo::default())
            } else {
                Box::new(TraceElbo::default())
            };
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(13);
            let mut svi = Svi::with_config(
                Adam::new(0.03),
                elbo,
                SviConfig { num_particles: 2, ..SviConfig::default() },
            );
            for _ in 0..1200 {
                svi.step(&mut store, &mut rng, &model, &guide);
            }
            let loc = store.get("q_loc").unwrap().item();
            assert!((loc - 0.3).abs() < 0.1, "graph={graph} posterior loc {loc}");
        }
    }

    #[test]
    fn loss_decreases_on_average() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(11);
        let mut svi = Svi::new(Adam::new(0.05), TraceElbo::default());
        let first: f64 = (0..50)
            .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
            .sum::<f64>()
            / 50.0;
        for _ in 0..400 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let last: f64 = (0..50)
            .map(|_| svi.evaluate_loss(&mut store, &mut rng, &model, &guide))
            .sum::<f64>()
            / 50.0;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        // converged loss ≈ -log evidence = -log N(0.6 | 0, sqrt 2)
        let want = -Normal::std(0.0, 2.0f64.sqrt())
            .log_prob(&Tensor::scalar(0.6))
            .item();
        assert!((last - want).abs() < 0.1, "final loss {last} vs -logZ {want}");
    }

    #[test]
    fn evaluate_loss_does_not_update() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(13);
        let svi = Svi::new(Adam::new(0.1), TraceElbo::default());
        // initialize params
        svi.evaluate_loss(&mut store, &mut rng, &model, &guide);
        let before = store.get("q_loc").unwrap().item();
        for _ in 0..10 {
            svi.evaluate_loss(&mut store, &mut rng, &model, &guide);
        }
        assert_eq!(before, store.get("q_loc").unwrap().item());
        assert_eq!(svi.steps_taken(), 0);
    }

    #[test]
    fn evaluate_loss_does_not_advance_baselines() {
        // regression: evaluation used to route through `absorb`,
        // advancing the decaying-average baseline (and its schedule) on
        // pure evaluation passes. Evaluation must be side-effect free.
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0xE7A1);
        let mut svi = Svi::new(Adam::new(0.05), TraceElbo::default());
        for _ in 0..5 {
            svi.step(&mut store, &mut rng, &discrete_model, &discrete_guide);
        }
        let snap = svi.elbo.snapshot();
        assert!(snap.global.is_some(), "score-site steps must warm the baseline");
        for _ in 0..10 {
            svi.evaluate_loss(&mut store, &mut rng, &discrete_model, &discrete_guide);
        }
        assert_eq!(svi.elbo.snapshot(), snap, "evaluate_loss mutated baseline state");
        // ...and a training step DOES advance it
        svi.step(&mut store, &mut rng, &discrete_model, &discrete_guide);
        assert_ne!(svi.elbo.snapshot(), snap, "step should advance the baseline");
    }

    #[test]
    fn tracegraph_evaluate_loss_does_not_advance_baselines() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0xBA5E);
        let mut svi = Svi::new(Adam::new(0.05), TraceGraphElbo::default());
        for _ in 0..5 {
            svi.step(&mut store, &mut rng, &discrete_model, &discrete_guide);
        }
        let snap = svi.elbo.snapshot();
        assert!(!snap.per_site.is_empty());
        for _ in 0..10 {
            svi.evaluate_loss(&mut store, &mut rng, &discrete_model, &discrete_guide);
        }
        assert_eq!(svi.elbo.snapshot(), snap, "evaluate_loss mutated per-site baselines");
    }

    #[test]
    fn empty_model_trace_is_an_error_not_a_crash() {
        // a fully-blocked model records no sites: try_step must surface
        // a diagnosable error instead of panicking
        let blocked = crate::poutine::block(model, |_| true);
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(19);
        let mut svi = Svi::new(Adam::new(0.05), TraceElbo::default());
        let err = svi
            .try_step(&mut store, &mut rng, &blocked, &guide)
            .expect_err("blocked model must error");
        assert!(format!("{err}").contains("no sample sites"), "{err}");
        let err = svi
            .try_evaluate_loss(&mut store, &mut rng, &blocked, &guide)
            .expect_err("blocked model must error on evaluation too");
        assert!(format!("{err}").contains("no sample sites"), "{err}");
        assert_eq!(svi.steps_taken(), 0, "failed steps must not count");
    }

    #[test]
    fn renyi_one_particle_matches_trace_exactly() {
        let run = |renyi: bool| -> (Vec<f64>, f64) {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0x21A);
            let cfg = SviConfig { num_particles: 1, ..SviConfig::default() };
            let losses: Vec<f64> = if renyi {
                let mut svi = Svi::with_config(Adam::new(0.03), RenyiElbo::iwae(), cfg);
                (0..40).map(|_| svi.step(&mut store, &mut rng, &model, &guide)).collect()
            } else {
                let mut svi = Svi::with_config(Adam::new(0.03), TraceElbo::default(), cfg);
                (0..40).map(|_| svi.step(&mut store, &mut rng, &model, &guide)).collect()
            };
            (losses, store.get_unconstrained("q_loc").unwrap().item())
        };
        let (l_t, loc_t) = run(false);
        let (l_r, loc_r) = run(true);
        for (a, b) in l_t.iter().zip(&l_r) {
            assert!((a - b).abs() < 1e-12, "losses diverged: {a} vs {b}");
        }
        assert!((loc_t - loc_r).abs() < 1e-12, "params diverged: {loc_t} vs {loc_r}");
    }

    #[test]
    fn renyi_iwae_bound_is_tighter_than_elbo() {
        // proposal = prior: the plain ELBO has a large gap to log Z;
        // the IWAE-16 bound must close most of it
        let prior_guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let log_z =
            Normal::std(0.0, 2.0f64.sqrt()).log_prob(&Tensor::scalar(0.6)).item();
        let evals = 400;
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0x1A3E);
        let trace = Svi::new(Adam::new(0.0), TraceElbo::default());
        let renyi = Svi::with_config(
            Adam::new(0.0),
            RenyiElbo::iwae(),
            SviConfig { num_particles: 16, ..SviConfig::default() },
        );
        let mut gap_trace = 0.0;
        let mut gap_renyi = 0.0;
        for _ in 0..evals {
            gap_trace +=
                trace.evaluate_loss(&mut store, &mut rng, &model, &prior_guide) + log_z;
            gap_renyi +=
                renyi.evaluate_loss(&mut store, &mut rng, &model, &prior_guide) + log_z;
        }
        gap_trace /= evals as f64;
        gap_renyi /= evals as f64;
        assert!(gap_trace > 0.0, "ELBO gap should be positive, got {gap_trace}");
        assert!(
            gap_renyi < 0.5 * gap_trace,
            "IWAE-16 gap {gap_renyi} not tighter than ELBO gap {gap_trace}"
        );
    }

    #[test]
    fn parallel_elbo_matches_serial_bitwise() {
        // identical seeds -> identical per-particle RNGs -> the merge
        // order makes parallel == serial exactly, step after step
        let run = |parallel: bool| -> (Vec<f64>, f64, f64) {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0xE1B0);
            let mut svi = Svi::with_config(
                Adam::new(0.03),
                TraceElbo::default(),
                SviConfig {
                    num_particles: 4,
                    parallel,
                    num_threads: if parallel { 2 } else { 0 },
                    ..SviConfig::default()
                },
            );
            let losses: Vec<f64> = (0..40)
                .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
                .collect();
            (
                losses,
                store.get_unconstrained("q_loc").unwrap().item(),
                store.get_unconstrained("q_scale").unwrap().item(),
            )
        };
        let (l_ser, loc_ser, scale_ser) = run(false);
        let (l_par, loc_par, scale_par) = run(true);
        assert_eq!(l_ser, l_par, "losses diverged between serial and parallel");
        assert_eq!(loc_ser, loc_par, "q_loc diverged");
        assert_eq!(scale_ser, scale_par, "q_scale diverged");
    }

    #[test]
    fn parallel_elbo_is_deterministic_given_seed() {
        let run = || -> Vec<f64> {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0xDE7);
            let mut svi = Svi::with_config(
                Adam::new(0.03),
                TraceElbo::default(),
                SviConfig { num_particles: 6, parallel: true, ..SviConfig::default() },
            );
            (0..25)
                .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
                .collect()
        };
        assert_eq!(run(), run(), "same seed must reproduce the same trajectory");
    }

    #[test]
    fn parallel_score_function_model_stays_deterministic() {
        // discrete guide site -> score-function surrogate with the
        // baseline snapshot; parity must hold there too — and for the
        // per-site TraceGraph baselines
        fn run_with<E: Elbo>(elbo: E, parallel: bool) -> f64 {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0x5C0E);
            let mut svi = Svi::with_config(
                Adam::new(0.05),
                elbo,
                SviConfig { num_particles: 4, parallel, ..SviConfig::default() },
            );
            for _ in 0..60 {
                svi.step(&mut store, &mut rng, &discrete_model, &discrete_guide);
            }
            store.get_unconstrained("q_logit").unwrap().item()
        }
        assert_eq!(
            run_with(TraceElbo::default(), false),
            run_with(TraceElbo::default(), true)
        );
        assert_eq!(
            run_with(TraceGraphElbo::default(), false),
            run_with(TraceGraphElbo::default(), true)
        );
    }

    #[test]
    fn validate_gates_first_step_on_lint_errors() {
        // guide samples a typo'd site name: FY001 at Error severity
        let bad_guide = |ctx: &mut Ctx| {
            let loc = ctx.param("q_loc", || Tensor::scalar(0.0));
            let scale = ctx.param_constrained(
                "q_scale",
                || Tensor::scalar(1.0),
                Constraint::Positive,
            );
            ctx.sample("zz", Normal::new(loc, scale));
        };
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(3);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            TraceElbo::default(),
            SviConfig { validate: true, ..SviConfig::default() },
        );
        let err = svi
            .try_step(&mut store, &mut rng, &model, &bad_guide)
            .expect_err("typo'd guide site must fail validation");
        let msg = format!("{err}");
        assert!(msg.contains("FY001"), "{msg}");
        assert!(msg.contains("zz"), "{msg}");
        assert_eq!(svi.steps_taken(), 0, "gated steps must not count");

        let mut svi = Svi::with_config(
            Adam::new(0.02),
            TraceElbo::default(),
            SviConfig { validate: true, ..SviConfig::default() },
        );
        svi.try_step(&mut store, &mut rng, &model, &guide).expect("clean pair trains");
        assert_eq!(svi.steps_taken(), 1);
    }

    #[test]
    fn validate_does_not_perturb_the_trajectory() {
        // the lint probe runs on a cloned store and forked RNG, so the
        // training trajectory must be bitwise identical either way
        let run = |validate: bool| -> (Vec<f64>, f64) {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0x11D);
            let mut svi = Svi::with_config(
                Adam::new(0.03),
                TraceElbo::default(),
                SviConfig { validate, ..SviConfig::default() },
            );
            let losses =
                (0..20).map(|_| svi.step(&mut store, &mut rng, &model, &guide)).collect();
            (losses, store.get_unconstrained("q_loc").unwrap().item())
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn analyze_reports_estimator_dependent_reparam_audit() {
        // Bernoulli guide site: non-reparameterized. Under plain Trace
        // the linter warns (FY007) and recommends TraceGraph; under
        // TraceGraph itself the audit is satisfied.
        let store = ParamStore::new();
        let svi = Svi::new(Adam::new(0.05), TraceElbo::default());
        let report = svi.analyze(&store, 5, &discrete_model, &discrete_guide);
        let warn = report
            .find(crate::analysis::LintCode::NonReparamUnderPathwise)
            .expect("FY007 should fire under plain Trace");
        assert_eq!(warn.severity, crate::analysis::Severity::Warning);
        assert!(!report.has_errors(), "FY007 is advisory: {report}");

        let svi = Svi::new(Adam::new(0.05), TraceGraphElbo::default());
        let report = svi.analyze(&store, 5, &discrete_model, &discrete_guide);
        assert!(
            report.find(crate::analysis::LintCode::NonReparamUnderPathwise).is_none(),
            "TraceGraph is variance-reduced; FY007 must not fire: {report}"
        );
    }

    #[test]
    fn subsampled_plate_svi_converges_to_full_data_posterior() {
        // N(mu, 1) likelihood over 20 points, prior N(0, 10): posterior
        // tightly around the sample mean. Subsample 5 per step.
        let data: Vec<f64> = (0..20).map(|i| 1.5 + 0.1 * ((i as f64) - 9.5)).collect();
        let n = data.len();
        let data_t = Tensor::from_vec(data.clone());
        let model = move |ctx: &mut Ctx| {
            let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
            ctx.plate("data", n, Some(5), |ctx, plate| {
                // ONE broadcast site per step, whatever the subsample
                ctx.observe(
                    "x",
                    Normal::new(mu.clone(), ctx.cs(1.0)),
                    plate.select(&data_t),
                );
            });
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
            let scale = ctx.param_constrained(
                "mu_scale",
                || Tensor::scalar(1.0),
                Constraint::Positive,
            );
            ctx.sample("mu", Normal::new(loc, scale));
        };
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(15);
        let mut svi = Svi::with_config(
            Adam::new(0.03),
            TraceElbo::default(),
            SviConfig { num_particles: 2, ..SviConfig::default() },
        );
        for _ in 0..2000 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let mean = data.iter().sum::<f64>() / data.len() as f64;
        let loc = store.get("mu_loc").unwrap().item();
        assert!((loc - mean).abs() < 0.15, "loc {loc} vs data mean {mean}");
    }
}
