//! Stochastic variational inference — `pyro.infer.SVI`.
//!
//! One step (paper Fig 1):
//!   1. run the guide, recording its trace (and touching its params);
//!   2. replay the model against the guide's latent draws on the same
//!      autodiff tape;
//!   3. differentiate the (surrogate) -ELBO w.r.t. every parameter leaf
//!      touched by either program;
//!   4. hand the gradients to the optimizer, which updates the store.
//!
//! The guide runs *first* and the model only ever sees its values through
//! replay — structurally enforcing the paper's rule that guides may not
//! depend on values inside the model.
//!
//! ## Multi-particle execution
//!
//! Each of the `num_particles` Monte-Carlo terms runs against its own
//! seeded RNG and its own tape, so particles are fully independent.
//! With [`SviConfig::parallel`] set (opt-in) each particle additionally
//! gets a private parameter-store clone and they are evaluated on
//! scoped worker threads and merged
//! back in particle order — making the parallel result **bitwise equal**
//! to the serial one for a given seed. Per-particle seeds are drawn from
//! the caller's RNG up front, so results are reproducible regardless of
//! thread scheduling.

use crate::infer::elbo::{has_score_sites, BaselineState, ElboKind, TraceElbo, TraceMeanFieldElbo};
use crate::optim::{apply_grads, Optimizer};
use crate::params::ParamStore;
use crate::poutine::{handlers, Ctx, Trace};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

/// A probabilistic program usable with [`Svi`]: threads may evaluate it
/// concurrently, so its captures must be `Sync` (plain data always is).
pub type ModelFn = dyn Fn(&mut Ctx) + Sync;

/// SVI configuration.
#[derive(Clone, Copy, Debug)]
pub struct SviConfig {
    pub loss: ElboKind,
    /// Monte-Carlo particles per step (gradients averaged).
    pub num_particles: usize,
    /// Evaluate particles on worker threads (opt-in; worth it once a
    /// particle costs more than thread spawn, i.e. real models rather
    /// than toy scalar ones). Purely a throughput switch: serial and
    /// parallel execution produce identical results for a given seed.
    pub parallel: bool,
    /// Worker-thread cap (0 = one per available core).
    pub num_threads: usize,
}

impl Default for SviConfig {
    fn default() -> Self {
        SviConfig { loss: ElboKind::Trace, num_particles: 1, parallel: false, num_threads: 0 }
    }
}

impl SviConfig {
    fn effective_threads(&self, particles: usize) -> usize {
        if !self.parallel {
            return 1;
        }
        let hw = if self.num_threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.num_threads
        };
        hw.min(particles).max(1)
    }
}

/// Everything a particle evaluation produces. `Send`, so workers can
/// hand it across the thread boundary; all tape state stays worker-local.
struct ParticleOut {
    grads: HashMap<String, Tensor>,
    elbo: f64,
    /// Guide trace had non-reparameterized sites (baseline users).
    score_sites: bool,
}

/// Evaluate one ELBO particle against `store`: fresh seeded RNG, fresh
/// tape. The serial path hands in the caller's store directly (zero
/// copies); workers hand in private clones. Because `ctx.param` init
/// closures are deterministic per name, the two produce identical
/// results — the parity tests pin this.
fn run_particle(
    seed: u64,
    store: &mut ParamStore,
    model: &ModelFn,
    guide: &ModelFn,
    loss_kind: ElboKind,
    baseline: Option<f64>,
) -> ParticleOut {
    let local = store;
    let mut rng = Pcg64::new(seed);

    // 1. guide pass
    let mut gctx = Ctx::with_store(&mut rng, local);
    guide(&mut gctx);
    let tape = gctx.tape.clone();
    let guide_trace = gctx.into_trace();

    // 2. model pass, replayed, on the same tape
    let replayed = handlers::replay(model, guide_trace.clone());
    let mut mctx = Ctx::with_store_on_tape(tape.clone(), &mut rng, local);
    replayed(&mut mctx);
    let model_trace = mctx.into_trace();

    // 3. loss + gradients
    let (loss, elbo) = match loss_kind {
        ElboKind::Trace => {
            TraceElbo::loss_with_baseline(&model_trace, &guide_trace, baseline)
        }
        ElboKind::TraceMeanField => TraceMeanFieldElbo::loss(&model_trace, &guide_trace),
    };
    let mut leaves: Vec<(String, crate::autodiff::Var)> = Vec::new();
    for (name, leaf) in guide_trace
        .param_leaves
        .iter()
        .chain(model_trace.param_leaves.iter())
    {
        if !leaves.iter().any(|(n, _)| n == name) {
            leaves.push((name.clone(), leaf.clone()));
        }
    }
    let leaf_refs: Vec<&crate::autodiff::Var> = leaves.iter().map(|(_, v)| v).collect();
    let grads = tape.grad(&loss, &leaf_refs);
    let grad_map = leaves
        .iter()
        .map(|(n, _)| n.clone())
        .zip(grads)
        .collect::<HashMap<_, _>>();
    ParticleOut { grads: grad_map, elbo, score_sites: has_score_sites(&guide_trace) }
}

/// Run all particles, serially or on scoped worker threads, returning
/// the outputs in particle-index order either way.
///
/// Serial execution works directly on the caller's store (no clones).
/// Parallel execution gives each particle a private store clone and
/// merges params first initialized inside particles back in index
/// order — deterministic because `ctx.param` init closures are
/// deterministic per name, so the two modes match bitwise.
fn run_particles(
    config: &SviConfig,
    seeds: &[u64],
    store: &mut ParamStore,
    model: &ModelFn,
    guide: &ModelFn,
    baseline: Option<f64>,
) -> Vec<ParticleOut> {
    let n = seeds.len();
    let threads = config.effective_threads(n);
    if threads <= 1 || n <= 1 {
        return seeds
            .iter()
            .map(|&s| run_particle(s, store, model, guide, config.loss, baseline))
            .collect();
    }
    let chunk = n.div_ceil(threads);
    let mut results: Vec<Option<(ParticleOut, ParamStore)>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    {
        let shared = &*store;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(threads);
            for (w, seed_chunk) in seeds.chunks(chunk).enumerate() {
                let base = w * chunk;
                let loss_kind = config.loss;
                handles.push(scope.spawn(move || {
                    seed_chunk
                        .iter()
                        .enumerate()
                        .map(|(j, &s)| {
                            let mut local = shared.clone();
                            let out = run_particle(
                                s, &mut local, model, guide, loss_kind, baseline,
                            );
                            (base + j, out, local)
                        })
                        .collect::<Vec<_>>()
                }));
            }
            for h in handles {
                for (i, out, local) in h.join().expect("ELBO particle worker panicked") {
                    results[i] = Some((out, local));
                }
            }
        });
    }
    results
        .into_iter()
        .map(|r| {
            let (out, local) = r.expect("missing particle result");
            store.merge_missing(&local);
            out
        })
        .collect()
}

/// The SVI engine. Generic over the optimizer.
pub struct Svi<O: Optimizer> {
    pub opt: O,
    pub config: SviConfig,
    baseline: BaselineState,
    steps: u64,
}

impl<O: Optimizer> Svi<O> {
    pub fn new(opt: O) -> Self {
        Svi { opt, config: SviConfig::default(), baseline: BaselineState::default(), steps: 0 }
    }

    pub fn with_config(opt: O, config: SviConfig) -> Self {
        Svi { opt, config, baseline: BaselineState::default(), steps: 0 }
    }

    pub fn steps_taken(&self) -> u64 {
        self.steps
    }

    fn particle_baseline(&self) -> Option<f64> {
        match self.config.loss {
            ElboKind::Trace => self.baseline.snapshot(),
            ElboKind::TraceMeanField => None,
        }
    }

    /// Fold particle ELBOs into the decaying-average baseline (only
    /// for traces that actually carry score-function sites, matching
    /// the original sequential estimator), in particle order.
    fn absorb(&mut self, results: &[ParticleOut]) -> f64 {
        let mut acc_elbo = 0.0;
        for r in results {
            if r.score_sites {
                self.baseline.observe(r.elbo);
            }
            acc_elbo += r.elbo;
        }
        acc_elbo
    }

    /// One SVI step; returns the **loss** (-ELBO), like `pyro.infer.SVI`.
    pub fn step(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> f64 {
        let n = self.config.num_particles.max(1);
        let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let baseline = self.particle_baseline();
        let config = self.config;
        let results = run_particles(&config, &seeds, store, model, guide, baseline);
        let acc_elbo = self.absorb(&results);

        // deterministic gradient merge: per-name accumulation follows
        // particle-index order, in place
        let mut acc_grads: HashMap<String, Tensor> = HashMap::new();
        for r in results {
            for (name, g) in r.grads {
                acc_grads
                    .entry(name)
                    .and_modify(|a| a.add_assign(&g))
                    .or_insert(g);
            }
        }
        let scale = 1.0 / n as f64;
        for g in acc_grads.values_mut() {
            g.scale_inplace(scale);
        }
        apply_grads(&mut self.opt, store, &acc_grads);
        self.steps += 1;
        -(acc_elbo * scale)
    }

    /// Estimate the loss without updating parameters.
    pub fn evaluate_loss(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &ModelFn,
        guide: &ModelFn,
    ) -> f64 {
        let n = self.config.num_particles.max(1);
        let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let baseline = self.particle_baseline();
        let config = self.config;
        let results = run_particles(&config, &seeds, store, model, guide, baseline);
        let acc_elbo = self.absorb(&results);
        -(acc_elbo / n as f64)
    }
}

/// Retrieve the traces of one guide/model pass (diagnostics, tests).
pub fn trace_pair(
    store: &mut ParamStore,
    rng: &mut Pcg64,
    model: &dyn Fn(&mut Ctx),
    guide: &dyn Fn(&mut Ctx),
) -> (Trace, Trace) {
    let mut gctx = Ctx::with_store(rng, store);
    guide(&mut gctx);
    let tape = gctx.tape.clone();
    let guide_trace = gctx.into_trace();
    let replayed = handlers::replay(model, guide_trace.clone());
    let mut mctx = Ctx::with_store_on_tape(tape, rng, store);
    replayed(&mut mctx);
    (mctx.into_trace(), guide_trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Constraint, Dist, Normal};
    use crate::optim::Adam;
    use crate::poutine::Ctx;

    /// Conjugate 1-D model: z ~ N(0,1), x ~ N(z, 1), x = 0.6 observed.
    /// Posterior: N(0.3, 1/sqrt(2)).
    fn model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    fn guide(ctx: &mut Ctx) {
        let loc = ctx.param("q_loc", || Tensor::scalar(0.0));
        let scale = ctx.param_constrained(
            "q_scale",
            || Tensor::scalar(1.0),
            Constraint::Positive,
        );
        ctx.sample("z", Normal::new(loc, scale));
    }

    #[test]
    fn svi_recovers_conjugate_posterior() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(7);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            SviConfig { num_particles: 4, ..SviConfig::default() },
        );
        for _ in 0..1500 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let loc = store.get("q_loc").unwrap().item();
        let scale = store.get("q_scale").unwrap().item();
        assert!((loc - 0.3).abs() < 0.06, "posterior loc {loc}");
        assert!((scale - 0.7071).abs() < 0.08, "posterior scale {scale}");
    }

    #[test]
    fn svi_mean_field_matches_analytic_optimum() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(9);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            SviConfig {
                loss: ElboKind::TraceMeanField,
                num_particles: 2,
                ..SviConfig::default()
            },
        );
        for _ in 0..1500 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let loc = store.get("q_loc").unwrap().item();
        let scale = store.get("q_scale").unwrap().item();
        assert!((loc - 0.3).abs() < 0.05, "posterior loc {loc}");
        assert!((scale - 0.7071).abs() < 0.06, "posterior scale {scale}");
    }

    #[test]
    fn loss_decreases_on_average() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(11);
        let mut svi = Svi::new(Adam::new(0.05));
        let first: f64 = (0..50)
            .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
            .sum::<f64>()
            / 50.0;
        for _ in 0..400 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let last: f64 = (0..50)
            .map(|_| svi.evaluate_loss(&mut store, &mut rng, &model, &guide))
            .sum::<f64>()
            / 50.0;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        // converged loss ≈ -log evidence = -log N(0.6 | 0, sqrt 2)
        let want = -Normal::std(0.0, 2.0f64.sqrt())
            .log_prob(&Tensor::scalar(0.6))
            .item();
        assert!((last - want).abs() < 0.1, "final loss {last} vs -logZ {want}");
    }

    #[test]
    fn evaluate_loss_does_not_update() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(13);
        let mut svi = Svi::new(Adam::new(0.1));
        // initialize params
        svi.evaluate_loss(&mut store, &mut rng, &model, &guide);
        let before = store.get("q_loc").unwrap().item();
        for _ in 0..10 {
            svi.evaluate_loss(&mut store, &mut rng, &model, &guide);
        }
        assert_eq!(before, store.get("q_loc").unwrap().item());
    }

    #[test]
    fn parallel_elbo_matches_serial_bitwise() {
        // identical seeds -> identical per-particle RNGs -> the merge
        // order makes parallel == serial exactly, step after step
        let run = |parallel: bool| -> (Vec<f64>, f64, f64) {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0xE1B0);
            let mut svi = Svi::with_config(
                Adam::new(0.03),
                SviConfig {
                    num_particles: 4,
                    parallel,
                    num_threads: if parallel { 2 } else { 0 },
                    ..SviConfig::default()
                },
            );
            let losses: Vec<f64> = (0..40)
                .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
                .collect();
            (
                losses,
                store.get_unconstrained("q_loc").unwrap().item(),
                store.get_unconstrained("q_scale").unwrap().item(),
            )
        };
        let (l_ser, loc_ser, scale_ser) = run(false);
        let (l_par, loc_par, scale_par) = run(true);
        assert_eq!(l_ser, l_par, "losses diverged between serial and parallel");
        assert_eq!(loc_ser, loc_par, "q_loc diverged");
        assert_eq!(scale_ser, scale_par, "q_scale diverged");
    }

    #[test]
    fn parallel_elbo_is_deterministic_given_seed() {
        let run = || -> Vec<f64> {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0xDE7);
            let mut svi = Svi::with_config(
                Adam::new(0.03),
                SviConfig { num_particles: 6, parallel: true, ..SviConfig::default() },
            );
            (0..25)
                .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
                .collect()
        };
        assert_eq!(run(), run(), "same seed must reproduce the same trajectory");
    }

    #[test]
    fn parallel_score_function_model_stays_deterministic() {
        // discrete guide site -> score-function surrogate with the
        // baseline snapshot; parity must hold there too
        use crate::dist::Bernoulli;
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Bernoulli::std(0.5));
            let logits = z.mul_scalar(8.0).add_scalar(-4.0);
            ctx.observe("x", Bernoulli::new(logits), Tensor::scalar(1.0));
        };
        let guide = |ctx: &mut Ctx| {
            let logit = ctx.param("q_logit", || Tensor::scalar(0.0));
            ctx.sample("z", Bernoulli::new(logit));
        };
        let run = |parallel: bool| -> f64 {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(0x5C0E);
            let mut svi = Svi::with_config(
                Adam::new(0.05),
                SviConfig { num_particles: 4, parallel, ..SviConfig::default() },
            );
            for _ in 0..60 {
                svi.step(&mut store, &mut rng, &model, &guide);
            }
            store.get_unconstrained("q_logit").unwrap().item()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn subsampled_plate_svi_converges_to_full_data_posterior() {
        // N(mu, 1) likelihood over 20 points, prior N(0, 10): posterior
        // tightly around the sample mean. Subsample 5 per step.
        let data: Vec<f64> = (0..20).map(|i| 1.5 + 0.1 * ((i as f64) - 9.5)).collect();
        let n = data.len();
        let data_t = Tensor::from_vec(data.clone());
        let model = move |ctx: &mut Ctx| {
            let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
            ctx.plate("data", n, Some(5), |ctx, plate| {
                // ONE broadcast site per step, whatever the subsample
                ctx.observe(
                    "x",
                    Normal::new(mu.clone(), ctx.cs(1.0)),
                    plate.select(&data_t),
                );
            });
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
            let scale = ctx.param_constrained(
                "mu_scale",
                || Tensor::scalar(1.0),
                Constraint::Positive,
            );
            ctx.sample("mu", Normal::new(loc, scale));
        };
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(15);
        let mut svi = Svi::with_config(
            Adam::new(0.03),
            SviConfig { num_particles: 2, ..SviConfig::default() },
        );
        for _ in 0..2000 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let mean = data.iter().sum::<f64>() / data.len() as f64;
        let loc = store.get("mu_loc").unwrap().item();
        assert!((loc - mean).abs() < 0.15, "loc {loc} vs data mean {mean}");
    }
}
