//! Stochastic variational inference — `pyro.infer.SVI`.
//!
//! One step (paper Fig 1):
//!   1. run the guide, recording its trace (and touching its params);
//!   2. replay the model against the guide's latent draws on the same
//!      autodiff tape;
//!   3. differentiate the (surrogate) -ELBO w.r.t. every parameter leaf
//!      touched by either program;
//!   4. hand the gradients to the optimizer, which updates the store.
//!
//! The guide runs *first* and the model only ever sees its values through
//! replay — structurally enforcing the paper's rule that guides may not
//! depend on values inside the model.

use crate::infer::elbo::{BaselineState, ElboKind, TraceElbo, TraceMeanFieldElbo};
use crate::optim::{apply_grads, Optimizer};
use crate::params::ParamStore;
use crate::poutine::{handlers, Ctx, Trace};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

/// SVI configuration.
#[derive(Clone, Copy, Debug)]
pub struct SviConfig {
    pub loss: ElboKind,
    /// Monte-Carlo particles per step (gradients averaged).
    pub num_particles: usize,
}

impl Default for SviConfig {
    fn default() -> Self {
        SviConfig { loss: ElboKind::Trace, num_particles: 1 }
    }
}

/// The SVI engine. Generic over the optimizer.
pub struct Svi<O: Optimizer> {
    pub opt: O,
    pub config: SviConfig,
    baseline: BaselineState,
    steps: u64,
}

impl<O: Optimizer> Svi<O> {
    pub fn new(opt: O) -> Self {
        Svi { opt, config: SviConfig::default(), baseline: BaselineState::default(), steps: 0 }
    }

    pub fn with_config(opt: O, config: SviConfig) -> Self {
        Svi { opt, config, baseline: BaselineState::default(), steps: 0 }
    }

    pub fn steps_taken(&self) -> u64 {
        self.steps
    }

    /// Run one trace pair and return (param grads, elbo value).
    fn particle(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
    ) -> (HashMap<String, Tensor>, f64) {
        // 1. guide pass
        let mut gctx = Ctx::with_store(rng, store);
        guide(&mut gctx);
        let tape = gctx.tape.clone();
        let guide_trace = gctx.into_trace();

        // 2. model pass, replayed, on the same tape
        let replayed = handlers::replay(model, guide_trace.clone());
        let mut mctx = Ctx::with_store_on_tape(tape.clone(), rng, store);
        replayed(&mut mctx);
        let model_trace = mctx.into_trace();

        // 3. loss + gradients
        let (loss, elbo) = match self.config.loss {
            ElboKind::Trace => TraceElbo::loss(&model_trace, &guide_trace, &mut self.baseline),
            ElboKind::TraceMeanField => TraceMeanFieldElbo::loss(&model_trace, &guide_trace),
        };
        let mut leaves: Vec<(String, crate::autodiff::Var)> = Vec::new();
        for (name, leaf) in guide_trace
            .param_leaves
            .iter()
            .chain(model_trace.param_leaves.iter())
        {
            if !leaves.iter().any(|(n, _)| n == name) {
                leaves.push((name.clone(), leaf.clone()));
            }
        }
        let leaf_refs: Vec<&crate::autodiff::Var> = leaves.iter().map(|(_, v)| v).collect();
        let grads = tape.grad(&loss, &leaf_refs);
        let grad_map = leaves
            .iter()
            .map(|(n, _)| n.clone())
            .zip(grads)
            .collect::<HashMap<_, _>>();
        (grad_map, elbo)
    }

    /// One SVI step; returns the **loss** (-ELBO), like `pyro.infer.SVI`.
    pub fn step(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
    ) -> f64 {
        let n = self.config.num_particles.max(1);
        let mut acc_grads: HashMap<String, Tensor> = HashMap::new();
        let mut acc_elbo = 0.0;
        for _ in 0..n {
            let (grads, elbo) = self.particle(store, rng, model, guide);
            acc_elbo += elbo;
            for (name, g) in grads {
                acc_grads
                    .entry(name)
                    .and_modify(|a| *a = a.add(&g))
                    .or_insert(g);
            }
        }
        let scale = 1.0 / n as f64;
        for g in acc_grads.values_mut() {
            *g = g.mul_scalar(scale);
        }
        apply_grads(&mut self.opt, store, &acc_grads);
        self.steps += 1;
        -(acc_elbo * scale)
    }

    /// Estimate the loss without updating parameters.
    pub fn evaluate_loss(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
    ) -> f64 {
        let n = self.config.num_particles.max(1);
        let mut acc = 0.0;
        for _ in 0..n {
            let (_, elbo) = self.particle(store, rng, model, guide);
            acc += elbo;
        }
        -(acc / n as f64)
    }
}

/// Retrieve the traces of one guide/model pass (diagnostics, tests).
pub fn trace_pair(
    store: &mut ParamStore,
    rng: &mut Pcg64,
    model: &dyn Fn(&mut Ctx),
    guide: &dyn Fn(&mut Ctx),
) -> (Trace, Trace) {
    let mut gctx = Ctx::with_store(rng, store);
    guide(&mut gctx);
    let tape = gctx.tape.clone();
    let guide_trace = gctx.into_trace();
    let replayed = handlers::replay(model, guide_trace.clone());
    let mut mctx = Ctx::with_store_on_tape(tape, rng, store);
    replayed(&mut mctx);
    (mctx.into_trace(), guide_trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Constraint, Dist, Normal};
    use crate::optim::Adam;
    use crate::poutine::Ctx;

    /// Conjugate 1-D model: z ~ N(0,1), x ~ N(z, 1), x = 0.6 observed.
    /// Posterior: N(0.3, 1/sqrt(2)).
    fn model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    fn guide(ctx: &mut Ctx) {
        let loc = ctx.param("q_loc", || Tensor::scalar(0.0));
        let scale = ctx.param_constrained(
            "q_scale",
            || Tensor::scalar(1.0),
            Constraint::Positive,
        );
        ctx.sample("z", Normal::new(loc, scale));
    }

    #[test]
    fn svi_recovers_conjugate_posterior() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(7);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            SviConfig { loss: ElboKind::Trace, num_particles: 4 },
        );
        for _ in 0..1500 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let loc = store.get("q_loc").unwrap().item();
        let scale = store.get("q_scale").unwrap().item();
        assert!((loc - 0.3).abs() < 0.06, "posterior loc {loc}");
        assert!((scale - 0.7071).abs() < 0.08, "posterior scale {scale}");
    }

    #[test]
    fn svi_mean_field_matches_analytic_optimum() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(9);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            SviConfig { loss: ElboKind::TraceMeanField, num_particles: 2 },
        );
        for _ in 0..1500 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let loc = store.get("q_loc").unwrap().item();
        let scale = store.get("q_scale").unwrap().item();
        assert!((loc - 0.3).abs() < 0.05, "posterior loc {loc}");
        assert!((scale - 0.7071).abs() < 0.06, "posterior scale {scale}");
    }

    #[test]
    fn loss_decreases_on_average() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(11);
        let mut svi = Svi::new(Adam::new(0.05));
        let first: f64 = (0..50)
            .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
            .sum::<f64>()
            / 50.0;
        for _ in 0..400 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let last: f64 = (0..50)
            .map(|_| svi.evaluate_loss(&mut store, &mut rng, &model, &guide))
            .sum::<f64>()
            / 50.0;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        // converged loss ≈ -log evidence = -log N(0.6 | 0, sqrt 2)
        let want = -Normal::std(0.0, 2.0f64.sqrt())
            .log_prob(&Tensor::scalar(0.6))
            .item();
        assert!((last - want).abs() < 0.1, "final loss {last} vs -logZ {want}");
    }

    #[test]
    fn evaluate_loss_does_not_update() {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(13);
        let mut svi = Svi::new(Adam::new(0.1));
        // initialize params
        svi.evaluate_loss(&mut store, &mut rng, &model, &guide);
        let before = store.get("q_loc").unwrap().item();
        for _ in 0..10 {
            svi.evaluate_loss(&mut store, &mut rng, &model, &guide);
        }
        assert_eq!(before, store.get("q_loc").unwrap().item());
    }

    #[test]
    fn subsampled_plate_svi_converges_to_full_data_posterior() {
        // N(mu, 1) likelihood over 20 points, prior N(0, 10): posterior
        // tightly around the sample mean. Subsample 5 per step.
        let data: Vec<f64> = (0..20).map(|i| 1.5 + 0.1 * ((i as f64) - 9.5)).collect();
        let data2 = data.clone();
        let model = move |ctx: &mut Ctx| {
            let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
            let d = data2.clone();
            ctx.plate("data", d.len(), Some(5), |ctx, idx| {
                for &i in idx {
                    ctx.observe(
                        &format!("x_{i}"),
                        Normal::new(mu.clone(), ctx.cs(1.0)),
                        Tensor::scalar(d[i]),
                    );
                }
            });
        };
        let guide = |ctx: &mut Ctx| {
            let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
            let scale = ctx.param_constrained(
                "mu_scale",
                || Tensor::scalar(1.0),
                Constraint::Positive,
            );
            ctx.sample("mu", Normal::new(loc, scale));
        };
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(15);
        let mut svi = Svi::with_config(
            Adam::new(0.03),
            SviConfig { loss: ElboKind::Trace, num_particles: 2 },
        );
        for _ in 0..2000 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let mean = data.iter().sum::<f64>() / data.len() as f64;
        let loc = store.get("mu_loc").unwrap().item();
        assert!((loc - mean).abs() < 0.15, "loc {loc} vs data mean {mean}");
    }
}
