//! Hamiltonian Monte Carlo and the No-U-Turn Sampler.
//!
//! The paper lists NUTS (Hoffman & Gelman 2014) among Pyro's generic
//! inference algorithms. Fyro implements:
//! - plain HMC with a fixed leapfrog length,
//! - multinomial NUTS with dynamic trajectory doubling,
//! both with dual-averaging step-size adaptation (target acceptance 0.8)
//! and diagonal mass-matrix estimation during warmup.
//!
//! Latents are mapped to unconstrained space via each site's support
//! bijection; the potential includes the log-Jacobian correction, and
//! gradients come from the autodiff tape through a `SubstituteMessenger`.

use crate::autodiff::Var;
use crate::dist::Constraint;
use crate::poutine::handlers::SubstituteMessenger;
use crate::poutine::{trace_fn, Ctx};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

// ------------------------------------------------------------- potential

/// Layout of the flattened unconstrained latent vector.
#[derive(Clone, Debug)]
pub struct LatentLayout {
    pub sites: Vec<(String, Vec<usize>, Constraint)>,
    pub dim: usize,
}

impl LatentLayout {
    pub fn from_model(model: &dyn Fn(&mut Ctx), seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let proto = trace_fn(model, &mut rng);
        let mut sites = Vec::new();
        let mut dim = 0;
        for s in proto.sites() {
            if s.is_observed || s.intervened {
                continue;
            }
            let c = s.dist.support();
            assert!(
                c.is_continuous() && c != Constraint::Simplex,
                "HMC/NUTS requires continuous non-simplex latents (site '{}': {c:?})",
                s.name
            );
            let dims = s.value.value().dims().to_vec();
            dim += dims.iter().product::<usize>().max(1);
            sites.push((s.name.clone(), dims, c));
        }
        assert!(dim > 0, "model has no continuous latent sites");
        LatentLayout { sites, dim }
    }

    /// Initial unconstrained point from a prior draw.
    pub fn init_from_prior(&self, model: &dyn Fn(&mut Ctx), rng: &mut Pcg64) -> Vec<f64> {
        let proto = trace_fn(model, rng);
        let mut theta = Vec::with_capacity(self.dim);
        for (name, _, c) in &self.sites {
            let v = proto.get(name).expect("site vanished").value.value().clone();
            theta.extend_from_slice(c.inverse(&v).data());
        }
        theta
    }

    /// Unpack a flat unconstrained vector into constrained tensors.
    pub fn unpack(&self, theta: &[f64]) -> HashMap<String, Tensor> {
        let mut out = HashMap::new();
        let mut off = 0;
        for (name, dims, c) in &self.sites {
            let n = dims.iter().product::<usize>().max(1);
            let unc = Tensor::new(theta[off..off + n].to_vec(), dims.clone());
            out.insert(name.clone(), c.transform(&unc));
            off += n;
        }
        out
    }
}

/// -log p(x, T(θ)) - log|det J_T(θ)| and its gradient.
pub struct Potential<'m> {
    pub model: &'m dyn Fn(&mut Ctx),
    pub layout: LatentLayout,
}

impl<'m> Potential<'m> {
    pub fn new(model: &'m dyn Fn(&mut Ctx), seed: u64) -> Self {
        Potential { model, layout: LatentLayout::from_model(model, seed) }
    }

    /// Returns (U, ∇U).
    pub fn eval(&self, theta: &[f64], rng: &mut Pcg64) -> (f64, Vec<f64>) {
        let mut ctx = Ctx::new(rng);
        let tape = ctx.tape.clone();
        // build leaves + constrained values + jacobian terms
        let mut leaves: Vec<Var> = Vec::with_capacity(self.layout.sites.len());
        let mut subs: HashMap<String, Var> = HashMap::new();
        let mut ladj: Option<Var> = None;
        let mut off = 0;
        for (name, dims, c) in &self.layout.sites {
            let n = dims.iter().product::<usize>().max(1);
            let leaf = tape.leaf(Tensor::new(theta[off..off + n].to_vec(), dims.clone()));
            off += n;
            let constrained = c.transform(&leaf);
            let j = match c {
                Constraint::Real => None,
                Constraint::Positive | Constraint::NonNegInteger => Some(leaf.sum()),
                Constraint::UnitInterval => {
                    Some(leaf.softplus().add(&leaf.neg().softplus()).neg().sum())
                }
                Constraint::Interval(lo, hi) => Some(
                    leaf.softplus()
                        .add(&leaf.neg().softplus())
                        .neg()
                        .add_scalar((hi - lo).ln())
                        .sum(),
                ),
                _ => unreachable!(),
            };
            if let Some(j) = j {
                ladj = Some(match ladj {
                    None => j,
                    Some(a) => a.add(&j),
                });
            }
            subs.insert(name.clone(), constrained);
            leaves.push(leaf);
        }
        ctx.push_handler(Box::new(SubstituteMessenger::new(subs)));
        (self.model)(&mut ctx);
        ctx.pop_handler();
        let trace = ctx.into_trace();
        let mut logp = trace.log_prob_sum_var().expect("empty model trace");
        if let Some(j) = ladj {
            logp = logp.add(&j);
        }
        let u = -logp.item();
        let leaf_refs: Vec<&Var> = leaves.iter().collect();
        let grads = tape.grad(&logp, &leaf_refs);
        let mut grad = Vec::with_capacity(self.layout.dim);
        for g in grads {
            grad.extend(g.data().iter().map(|&x| -x));
        }
        (u, grad)
    }
}

// ------------------------------------------------------------ adaptation

/// Dual-averaging step-size adaptation (Hoffman & Gelman 2014, §3.2).
struct DualAveraging {
    mu: f64,
    log_eps: f64,
    log_eps_avg: f64,
    h_avg: f64,
    t: f64,
    gamma: f64,
    t0: f64,
    kappa: f64,
    target: f64,
}

impl DualAveraging {
    fn new(eps0: f64, target: f64) -> Self {
        DualAveraging {
            mu: (10.0 * eps0).ln(),
            log_eps: eps0.ln(),
            log_eps_avg: eps0.ln(),
            h_avg: 0.0,
            t: 0.0,
            gamma: 0.05,
            t0: 10.0,
            kappa: 0.75,
            target,
        }
    }

    fn update(&mut self, accept_prob: f64) {
        self.t += 1.0;
        let eta_h = 1.0 / (self.t + self.t0);
        self.h_avg = (1.0 - eta_h) * self.h_avg + eta_h * (self.target - accept_prob);
        self.log_eps = self.mu - self.t.sqrt() / self.gamma * self.h_avg;
        let eta = self.t.powf(-self.kappa);
        self.log_eps_avg = eta * self.log_eps + (1.0 - eta) * self.log_eps_avg;
    }

    fn current(&self) -> f64 {
        self.log_eps.exp()
    }

    fn finalized(&self) -> f64 {
        self.log_eps_avg.exp()
    }
}

/// Online mean/variance (Welford) for diagonal mass estimation.
struct RunningVariance {
    n: usize,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl RunningVariance {
    fn new(dim: usize) -> Self {
        RunningVariance { n: 0, mean: vec![0.0; dim], m2: vec![0.0; dim] }
    }

    fn push(&mut self, x: &[f64]) {
        self.n += 1;
        for i in 0..x.len() {
            let d = x[i] - self.mean[i];
            self.mean[i] += d / self.n as f64;
            self.m2[i] += d * (x[i] - self.mean[i]);
        }
    }

    fn variance(&self) -> Option<Vec<f64>> {
        if self.n < 5 {
            return None;
        }
        // regularized like Stan: shrink towards 1e-3
        let n = self.n as f64;
        Some(
            self.m2
                .iter()
                .map(|&m| (n / (n - 1.0) * m / n) * n / (n + 5.0) + 1e-3 * 5.0 / (n + 5.0))
                .collect(),
        )
    }
}

// -------------------------------------------------------------- samplers

/// Common MCMC configuration.
#[derive(Clone, Copy, Debug)]
pub struct McmcConfig {
    pub warmup: usize,
    pub samples: usize,
    pub seed: u64,
    pub target_accept: f64,
    /// Initial step size.
    pub step_size: f64,
    /// Leapfrog steps (HMC only; NUTS chooses adaptively).
    pub num_steps: usize,
    /// NUTS max tree depth.
    pub max_tree_depth: usize,
    pub adapt_mass: bool,
}

impl Default for McmcConfig {
    fn default() -> Self {
        McmcConfig {
            warmup: 300,
            samples: 500,
            seed: 0,
            target_accept: 0.8,
            step_size: 0.1,
            num_steps: 16,
            max_tree_depth: 8,
            adapt_mass: true,
        }
    }
}

/// Posterior samples keyed by site.
pub struct McmcSamples {
    pub sites: HashMap<String, Vec<Tensor>>,
    pub accept_rate: f64,
    pub step_size: f64,
    /// Average NUTS tree depth (0 for HMC).
    pub mean_tree_depth: f64,
}

impl McmcSamples {
    pub fn mean(&self, site: &str) -> Tensor {
        let xs = &self.sites[site];
        let mut acc = Tensor::zeros(xs[0].dims().to_vec());
        for x in xs {
            acc = acc.add(x);
        }
        acc.mul_scalar(1.0 / xs.len() as f64)
    }

    pub fn std(&self, site: &str) -> Tensor {
        let m = self.mean(site);
        let xs = &self.sites[site];
        let mut acc = Tensor::zeros(m.dims().to_vec());
        for x in xs {
            let d = x.sub(&m);
            acc = acc.add(&d.mul(&d));
        }
        acc.mul_scalar(1.0 / xs.len() as f64).sqrt()
    }

    pub fn quantile(&self, site: &str, q: f64) -> f64 {
        let mut v: Vec<f64> = self.sites[site].iter().map(|t| t.item()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[((v.len() - 1) as f64 * q) as usize]
    }

    pub fn len(&self) -> usize {
        self.sites.values().next().map(|v| v.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn leapfrog(
    pot: &Potential,
    theta: &mut [f64],
    r: &mut [f64],
    grad: &mut Vec<f64>,
    eps: f64,
    inv_mass: &[f64],
    rng: &mut Pcg64,
) -> f64 {
    // half step momentum, full step position, half step momentum
    for i in 0..r.len() {
        r[i] -= 0.5 * eps * grad[i];
    }
    for i in 0..theta.len() {
        theta[i] += eps * inv_mass[i] * r[i];
    }
    let (u, g) = pot.eval(theta, rng);
    *grad = g;
    for i in 0..r.len() {
        r[i] -= 0.5 * eps * grad[i];
    }
    u
}

fn kinetic(r: &[f64], inv_mass: &[f64]) -> f64 {
    0.5 * r.iter().zip(inv_mass).map(|(&ri, &im)| ri * ri * im).sum::<f64>()
}

fn draw_momentum(dim: usize, inv_mass: &[f64], rng: &mut Pcg64) -> Vec<f64> {
    (0..dim).map(|i| rng.normal() / inv_mass[i].sqrt()).collect()
}

/// Plain HMC.
pub struct Hmc;

impl Hmc {
    pub fn run(model: &dyn Fn(&mut Ctx), cfg: McmcConfig) -> McmcSamples {
        let mut rng = Pcg64::new(cfg.seed);
        let pot = Potential::new(model, cfg.seed ^ 0x9E3779B9);
        let dim = pot.layout.dim;
        let mut theta = pot.layout.init_from_prior(model, &mut rng);
        let (mut u, mut grad) = pot.eval(&theta, &mut rng);
        let mut da = DualAveraging::new(cfg.step_size, cfg.target_accept);
        let mut inv_mass = vec![1.0; dim];
        let mut var_est = RunningVariance::new(dim);
        let mut accepts = 0.0;
        let mut collected: Vec<Vec<f64>> = Vec::with_capacity(cfg.samples);

        for iter in 0..cfg.warmup + cfg.samples {
            let warming = iter < cfg.warmup;
            let eps = if warming { da.current() } else { da.finalized() };
            let mut r = draw_momentum(dim, &inv_mass, &mut rng);
            let h0 = u + kinetic(&r, &inv_mass);
            let mut th = theta.clone();
            let mut g = grad.clone();
            let mut u_new = u;
            let mut diverged = false;
            // jitter trajectory length to break periodicity (standard HMC
            // practice; fixed L on a Gaussian is near-periodic)
            let l = 1 + rng.below(cfg.num_steps.max(1));
            for _ in 0..l {
                u_new = leapfrog(&pot, &mut th, &mut r, &mut g, eps, &inv_mass, &mut rng);
                if !u_new.is_finite() {
                    diverged = true;
                    break;
                }
            }
            let h1 = if diverged { f64::INFINITY } else { u_new + kinetic(&r, &inv_mass) };
            let accept_prob = (h0 - h1).exp().min(1.0);
            if rng.uniform() < accept_prob {
                theta = th;
                grad = g;
                u = u_new;
            }
            if warming {
                da.update(if accept_prob.is_nan() { 0.0 } else { accept_prob });
                if cfg.adapt_mass && iter >= cfg.warmup / 2 {
                    var_est.push(&theta);
                    if iter == cfg.warmup - 1 {
                        if let Some(v) = var_est.variance() {
                            inv_mass = v;
                        }
                    }
                }
            } else {
                accepts += accept_prob;
                collected.push(theta.clone());
            }
        }
        package(&pot.layout, collected, accepts / cfg.samples as f64, da.finalized(), 0.0)
    }
}

/// Multinomial No-U-Turn Sampler.
pub struct Nuts;

struct Tree {
    theta_minus: Vec<f64>,
    r_minus: Vec<f64>,
    grad_minus: Vec<f64>,
    theta_plus: Vec<f64>,
    r_plus: Vec<f64>,
    grad_plus: Vec<f64>,
    theta_prop: Vec<f64>,
    grad_prop: Vec<f64>,
    u_prop: f64,
    /// log of the total multinomial weight in the subtree.
    log_w: f64,
    turning: bool,
    diverged: bool,
    sum_accept: f64,
    n_leapfrog: f64,
}

impl Nuts {
    pub fn run(model: &dyn Fn(&mut Ctx), cfg: McmcConfig) -> McmcSamples {
        let mut rng = Pcg64::new(cfg.seed);
        let pot = Potential::new(model, cfg.seed ^ 0x9E3779B9);
        let dim = pot.layout.dim;
        let mut theta = pot.layout.init_from_prior(model, &mut rng);
        let (mut u, mut grad) = pot.eval(&theta, &mut rng);
        let mut da = DualAveraging::new(cfg.step_size, cfg.target_accept);
        let mut inv_mass = vec![1.0; dim];
        let mut var_est = RunningVariance::new(dim);
        let mut collected: Vec<Vec<f64>> = Vec::with_capacity(cfg.samples);
        let mut accepts = 0.0;
        let mut total_depth = 0.0;

        for iter in 0..cfg.warmup + cfg.samples {
            let warming = iter < cfg.warmup;
            let eps = if warming { da.current() } else { da.finalized() };
            let r0 = draw_momentum(dim, &inv_mass, &mut rng);
            let h0 = u + kinetic(&r0, &inv_mass);

            let mut tree = Tree {
                theta_minus: theta.clone(),
                r_minus: r0.clone(),
                grad_minus: grad.clone(),
                theta_plus: theta.clone(),
                r_plus: r0.clone(),
                grad_plus: grad.clone(),
                theta_prop: theta.clone(),
                grad_prop: grad.clone(),
                u_prop: u,
                log_w: 0.0,
                turning: false,
                diverged: false,
                sum_accept: 0.0,
                n_leapfrog: 0.0,
            };
            let mut depth = 0usize;
            while depth < cfg.max_tree_depth && !tree.turning && !tree.diverged {
                let go_right = rng.uniform() < 0.5;
                let sub = Self::build_tree(
                    &pot, &tree, depth, go_right, eps, h0, &inv_mass, &mut rng,
                );
                if !sub.turning && !sub.diverged {
                    // multinomial swap of the proposal
                    let log_total = log_add(tree.log_w, sub.log_w);
                    if rng.uniform().ln() < sub.log_w - log_total {
                        tree.theta_prop = sub.theta_prop.clone();
                        tree.grad_prop = sub.grad_prop.clone();
                        tree.u_prop = sub.u_prop;
                    }
                    tree.log_w = log_total;
                }
                tree.sum_accept += sub.sum_accept;
                tree.n_leapfrog += sub.n_leapfrog;
                // graft the new frontier
                if go_right {
                    tree.theta_plus = sub.theta_plus;
                    tree.r_plus = sub.r_plus;
                    tree.grad_plus = sub.grad_plus;
                } else {
                    tree.theta_minus = sub.theta_minus;
                    tree.r_minus = sub.r_minus;
                    tree.grad_minus = sub.grad_minus;
                }
                tree.turning = tree.turning
                    || sub.turning
                    || is_turning(
                        &tree.theta_minus,
                        &tree.theta_plus,
                        &tree.r_minus,
                        &tree.r_plus,
                        &inv_mass,
                    );
                tree.diverged = tree.diverged || sub.diverged;
                depth += 1;
            }
            theta = tree.theta_prop.clone();
            grad = tree.grad_prop.clone();
            u = tree.u_prop;
            let accept_stat = if tree.n_leapfrog > 0.0 {
                tree.sum_accept / tree.n_leapfrog
            } else {
                0.0
            };
            if warming {
                da.update(accept_stat);
                if cfg.adapt_mass && iter >= cfg.warmup / 2 {
                    var_est.push(&theta);
                    if iter == cfg.warmup - 1 {
                        if let Some(v) = var_est.variance() {
                            inv_mass = v;
                        }
                    }
                }
            } else {
                accepts += accept_stat;
                total_depth += depth as f64;
                collected.push(theta.clone());
            }
        }
        package(
            &pot.layout,
            collected,
            accepts / cfg.samples as f64,
            da.finalized(),
            total_depth / cfg.samples as f64,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn build_tree(
        pot: &Potential,
        tree: &Tree,
        depth: usize,
        go_right: bool,
        eps: f64,
        h0: f64,
        inv_mass: &[f64],
        rng: &mut Pcg64,
    ) -> Tree {
        if depth == 0 {
            // one leapfrog step from the chosen frontier
            let (mut th, mut r, mut g) = if go_right {
                (tree.theta_plus.clone(), tree.r_plus.clone(), tree.grad_plus.clone())
            } else {
                (tree.theta_minus.clone(), tree.r_minus.clone(), tree.grad_minus.clone())
            };
            let dir = if go_right { eps } else { -eps };
            let u_new = leapfrog(pot, &mut th, &mut r, &mut g, dir, inv_mass, rng);
            let h1 = if u_new.is_finite() {
                u_new + kinetic(&r, inv_mass)
            } else {
                f64::INFINITY
            };
            let diverged = !h1.is_finite() || h1 - h0 > 1000.0;
            let log_w = if diverged { f64::NEG_INFINITY } else { h0 - h1 };
            let accept = (h0 - h1).exp().min(1.0);
            return Tree {
                theta_minus: th.clone(),
                r_minus: r.clone(),
                grad_minus: g.clone(),
                theta_plus: th.clone(),
                r_plus: r.clone(),
                grad_plus: g.clone(),
                theta_prop: th,
                grad_prop: g,
                u_prop: u_new,
                log_w,
                turning: false,
                diverged,
                sum_accept: if accept.is_nan() { 0.0 } else { accept },
                n_leapfrog: 1.0,
            };
        }
        // recurse: two subtrees of depth-1 in the same direction
        let mut first =
            Self::build_tree(pot, tree, depth - 1, go_right, eps, h0, inv_mass, rng);
        if first.turning || first.diverged {
            return first;
        }
        let second =
            Self::build_tree(pot, &first, depth - 1, go_right, eps, h0, inv_mass, rng);
        // combine proposals multinomially
        let log_total = log_add(first.log_w, second.log_w);
        if !second.diverged && rng.uniform().ln() < second.log_w - log_total {
            first.theta_prop = second.theta_prop.clone();
            first.grad_prop = second.grad_prop.clone();
            first.u_prop = second.u_prop;
        }
        first.log_w = log_total;
        if go_right {
            first.theta_plus = second.theta_plus;
            first.r_plus = second.r_plus;
            first.grad_plus = second.grad_plus;
        } else {
            first.theta_minus = second.theta_minus;
            first.r_minus = second.r_minus;
            first.grad_minus = second.grad_minus;
        }
        first.sum_accept += second.sum_accept;
        first.n_leapfrog += second.n_leapfrog;
        first.turning = second.turning
            || is_turning(
                &first.theta_minus,
                &first.theta_plus,
                &first.r_minus,
                &first.r_plus,
                inv_mass,
            );
        first.diverged = first.diverged || second.diverged;
        first
    }
}

fn log_add(a: f64, b: f64) -> f64 {
    let m = a.max(b);
    if m.is_infinite() && m < 0.0 {
        return f64::NEG_INFINITY;
    }
    m + ((a - m).exp() + (b - m).exp()).ln()
}

fn is_turning(
    theta_minus: &[f64],
    theta_plus: &[f64],
    r_minus: &[f64],
    r_plus: &[f64],
    inv_mass: &[f64],
) -> bool {
    let mut dot_minus = 0.0;
    let mut dot_plus = 0.0;
    for i in 0..theta_minus.len() {
        let d = theta_plus[i] - theta_minus[i];
        dot_minus += d * r_minus[i] * inv_mass[i];
        dot_plus += d * r_plus[i] * inv_mass[i];
    }
    dot_minus < 0.0 || dot_plus < 0.0
}

fn package(
    layout: &LatentLayout,
    collected: Vec<Vec<f64>>,
    accept_rate: f64,
    step_size: f64,
    mean_tree_depth: f64,
) -> McmcSamples {
    let mut sites: HashMap<String, Vec<Tensor>> = HashMap::new();
    for (name, _, _) in &layout.sites {
        sites.insert(name.clone(), Vec::with_capacity(collected.len()));
    }
    for theta in &collected {
        for (name, v) in layout.unpack(theta) {
            sites.get_mut(&name).unwrap().push(v);
        }
    }
    McmcSamples { sites, accept_rate, step_size, mean_tree_depth }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Gamma, Normal};

    fn conjugate_model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    #[test]
    fn potential_matches_closed_form() {
        let pot = Potential::new(&conjugate_model, 1);
        let mut rng = Pcg64::new(1);
        let z = 0.4;
        let (u, g) = pot.eval(&[z], &mut rng);
        // U = -log N(z|0,1) - log N(0.6|z,1)
        let want = -(Normal::std(0.0, 1.0).log_prob(&Tensor::scalar(z)).item()
            + Normal::std(z, 1.0).log_prob(&Tensor::scalar(0.6)).item());
        assert!((u - want).abs() < 1e-10);
        // dU/dz = z - (0.6 - z) = 2z - 0.6
        assert!((g[0] - (2.0 * z - 0.6)).abs() < 1e-10);
    }

    #[test]
    fn potential_applies_jacobian_for_positive_site() {
        let model = |ctx: &mut Ctx| {
            ctx.sample("s", Gamma::std(2.0, 1.0));
        };
        let pot = Potential::new(&model, 2);
        let mut rng = Pcg64::new(2);
        let theta = 0.3; // s = e^0.3
        let (u, _) = pot.eval(&[theta], &mut rng);
        let s = theta.exp();
        let want = -(Gamma::std(2.0, 1.0).log_prob(&Tensor::scalar(s)).item() + theta);
        assert!((u - want).abs() < 1e-10);
    }

    #[test]
    fn hmc_recovers_conjugate_posterior() {
        let cfg = McmcConfig { warmup: 300, samples: 700, seed: 3, ..Default::default() };
        let out = Hmc::run(&conjugate_model, cfg);
        let m = out.mean("z").item();
        let s = out.std("z").item();
        assert!((m - 0.3).abs() < 0.08, "mean {m} (accept {})", out.accept_rate);
        assert!((s - 0.7071).abs() < 0.1, "std {s}");
        assert!(out.accept_rate > 0.6, "accept {}", out.accept_rate);
    }

    #[test]
    fn nuts_recovers_conjugate_posterior() {
        let cfg = McmcConfig { warmup: 300, samples: 700, seed: 4, ..Default::default() };
        let out = Nuts::run(&conjugate_model, cfg);
        let m = out.mean("z").item();
        let s = out.std("z").item();
        assert!((m - 0.3).abs() < 0.08, "mean {m} (accept {})", out.accept_rate);
        assert!((s - 0.7071).abs() < 0.1, "std {s}");
        assert!(out.mean_tree_depth >= 1.0);
    }

    #[test]
    fn nuts_handles_correlated_2d_gaussian() {
        // z1 ~ N(0,1); z2 ~ N(z1, 0.5): strong correlation
        let model = |ctx: &mut Ctx| {
            let z1 = ctx.sample("z1", Normal::std(0.0, 1.0));
            ctx.sample("z2", Normal::new(z1, ctx.cs(0.5)));
        };
        let cfg = McmcConfig { warmup: 400, samples: 800, seed: 5, ..Default::default() };
        let out = Nuts::run(&model, cfg);
        assert!((out.mean("z1").item()).abs() < 0.15);
        assert!((out.mean("z2").item()).abs() < 0.2);
        // marginal var of z2 = 1 + 0.25
        let s2 = out.std("z2").item();
        assert!((s2 - 1.25f64.sqrt()).abs() < 0.2, "std z2 {s2}");
    }

    #[test]
    fn nuts_positive_support_via_jacobian() {
        // posterior for rate with Gamma prior + Poisson-ish normal obs
        let model = |ctx: &mut Ctx| {
            let rate = ctx.sample("rate", Gamma::std(2.0, 2.0));
            ctx.observe("x", Normal::new(rate, ctx.cs(0.3)), Tensor::scalar(1.2));
        };
        let cfg = McmcConfig { warmup: 300, samples: 600, seed: 6, ..Default::default() };
        let out = Nuts::run(&model, cfg);
        for t in &out.sites["rate"] {
            assert!(t.item() > 0.0, "positivity violated");
        }
        let m = out.mean("rate").item();
        assert!((m - 1.1).abs() < 0.25, "rate mean {m}");
    }
}
