//! Automatic guide generation — `pyro.infer.autoguide`.
//!
//! An autoguide inspects a prototype trace of the model and fabricates a
//! variational family over every continuous latent site: `AutoNormal`
//! (independent Normals in unconstrained space, transported to each
//! site's support) and `AutoDelta` (point masses — MAP inference).

use crate::dist::{
    Constraint, Delta, ExpT, IntervalT, Normal, SigmoidT, TransformedDist,
};
use crate::poutine::{trace_fn, Ctx};
use crate::tensor::{Pcg64, Tensor};

/// One latent site discovered in the prototype trace.
#[derive(Clone, Debug)]
pub struct LatentSite {
    pub name: String,
    pub dims: Vec<usize>,
    pub constraint: Constraint,
    /// Constrained prototype value (initialization).
    pub init: Tensor,
}

/// Discover the latent (non-observed) sites of a model.
pub fn discover_latents(model: &dyn Fn(&mut Ctx), seed: u64) -> Vec<LatentSite> {
    let mut rng = Pcg64::new(seed);
    let proto = trace_fn(model, &mut rng);
    proto
        .sites()
        .iter()
        .filter(|s| !s.is_observed && !s.intervened)
        .map(|s| {
            let c = s.dist.support();
            assert!(
                c.is_continuous(),
                "autoguides require continuous supports (site '{}' has {c:?}); \
                 marginalize discrete latents or use a custom guide",
                s.name
            );
            assert!(
                c != Constraint::Simplex,
                "autoguides do not support simplex sites yet ('{}')",
                s.name
            );
            LatentSite {
                name: s.name.clone(),
                dims: s.value.value().dims().to_vec(),
                constraint: c,
                init: s.value.value().clone(),
            }
        })
        .collect()
}

/// Mean-field Normal guide in unconstrained space.
pub struct AutoNormal {
    pub prefix: String,
    pub sites: Vec<LatentSite>,
    pub init_scale: f64,
}

impl AutoNormal {
    pub fn new(model: &dyn Fn(&mut Ctx)) -> Self {
        AutoNormal {
            prefix: "auto".to_string(),
            sites: discover_latents(model, 0x0A07_0A07),
            init_scale: 0.1,
        }
    }

    /// The generated guide program.
    pub fn guide(&self) -> impl Fn(&mut Ctx) + '_ {
        move |ctx: &mut Ctx| {
            for site in &self.sites {
                let unc_init = site.constraint.inverse(&site.init);
                let loc = ctx.param(&format!("{}.{}.loc", self.prefix, site.name), || {
                    unc_init.clone()
                });
                let dims = site.dims.clone();
                let scale = ctx.param_constrained(
                    &format!("{}.{}.scale", self.prefix, site.name),
                    || Tensor::full(dims.clone(), self.init_scale),
                    Constraint::Positive,
                );
                let base = Normal::new(loc, scale);
                match site.constraint {
                    Constraint::Real => {
                        ctx.sample(&site.name, base);
                    }
                    Constraint::Positive | Constraint::NonNegInteger => {
                        ctx.sample(&site.name, TransformedDist::new(base, ExpT));
                    }
                    Constraint::UnitInterval => {
                        ctx.sample(&site.name, TransformedDist::new(base, SigmoidT));
                    }
                    Constraint::Interval(lo, hi) => {
                        ctx.sample(
                            &site.name,
                            TransformedDist::new(base, IntervalT { lo, hi }),
                        );
                    }
                    _ => unreachable!("checked in discover_latents"),
                }
            }
        }
    }

    /// Posterior median (= transformed loc) per site, after training.
    pub fn median(&self, store: &crate::params::ParamStore) -> Vec<(String, Tensor)> {
        self.sites
            .iter()
            .map(|s| {
                let loc = store
                    .get(&format!("{}.{}.loc", self.prefix, s.name))
                    .expect("guide params uninitialized — run SVI first");
                (s.name.clone(), s.constraint.transform(&loc))
            })
            .collect()
    }
}

/// Point-mass guide: SVI with `AutoDelta` is MAP estimation.
pub struct AutoDelta {
    pub prefix: String,
    pub sites: Vec<LatentSite>,
}

impl AutoDelta {
    pub fn new(model: &dyn Fn(&mut Ctx)) -> Self {
        AutoDelta { prefix: "map".to_string(), sites: discover_latents(model, 0x0A07_0A07) }
    }

    pub fn guide(&self) -> impl Fn(&mut Ctx) + '_ {
        move |ctx: &mut Ctx| {
            for site in &self.sites {
                let init = site.init.clone();
                let v = ctx.param_constrained(
                    &format!("{}.{}", self.prefix, site.name),
                    || init,
                    site.constraint,
                );
                ctx.sample(&site.name, Delta::new(v));
            }
        }
    }

    /// The MAP point estimate per site.
    pub fn values(&self, store: &crate::params::ParamStore) -> Vec<(String, Tensor)> {
        self.sites
            .iter()
            .map(|s| {
                (
                    s.name.clone(),
                    store
                        .get(&format!("{}.{}", self.prefix, s.name))
                        .expect("guide params uninitialized — run SVI first"),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Gamma, LogNormal};
    use crate::infer::svi::{Svi, SviConfig};
    use crate::infer::ElboKind;
    use crate::optim::Adam;
    use crate::params::ParamStore;

    fn model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    #[test]
    fn discovers_latents_with_constraints() {
        let m = |ctx: &mut Ctx| {
            ctx.sample("a", Normal::std(0.0, 1.0));
            ctx.sample("b", LogNormal::std(0.0, 1.0));
            ctx.observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.0));
        };
        let sites = discover_latents(&m, 1);
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].constraint, Constraint::Real);
        assert_eq!(sites[1].constraint, Constraint::Positive);
    }

    #[test]
    fn autonormal_recovers_conjugate_posterior() {
        let auto = AutoNormal::new(&model);
        let guide = auto.guide();
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(3);
        let mut svi = Svi::with_config(
            Adam::new(0.03),
            SviConfig { num_particles: 4, ..SviConfig::default() },
        );
        for _ in 0..3000 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let med = auto.median(&store);
        assert_eq!(med[0].0, "z");
        assert!((med[0].1.item() - 0.3).abs() < 0.08, "median {}", med[0].1.item());
    }

    #[test]
    fn autonormal_handles_positive_support() {
        // rate ~ Gamma(3, 1); observe counts -> posterior concentrates
        // near MLE; just check the guide runs and produces positive sims
        let m = |ctx: &mut Ctx| {
            let rate = ctx.sample("rate", Gamma::std(3.0, 1.0));
            ctx.observe("x", Normal::new(rate, ctx.cs(0.5)), Tensor::scalar(2.0));
        };
        let auto = AutoNormal::new(&m);
        let guide = auto.guide();
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(5);
        let mut svi = Svi::new(Adam::new(0.05));
        for _ in 0..500 {
            let loss = svi.step(&mut store, &mut rng, &m, &guide);
            assert!(loss.is_finite());
        }
        let med = auto.median(&store);
        assert!(med[0].1.item() > 0.0, "positive-support median");
        assert!((med[0].1.item() - 2.0).abs() < 0.6, "median {}", med[0].1.item());
    }

    #[test]
    fn autodelta_finds_map() {
        // MAP of the conjugate model = posterior mean 0.3 (Gaussian)
        let auto = AutoDelta::new(&model);
        let guide = auto.guide();
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(7);
        let mut svi = Svi::new(Adam::new(0.05));
        for _ in 0..800 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let vals = auto.values(&store);
        assert!((vals[0].1.item() - 0.3).abs() < 0.02, "MAP {}", vals[0].1.item());
    }

    #[test]
    #[should_panic(expected = "continuous supports")]
    fn discrete_latents_rejected() {
        let m = |ctx: &mut Ctx| {
            ctx.sample("k", crate::dist::Bernoulli::std(0.5));
        };
        AutoNormal::new(&m);
    }
}
