//! Automatic guide generation — `pyro.infer.autoguide`.
//!
//! An autoguide inspects a prototype trace of the model and fabricates a
//! variational family over every continuous latent site: `AutoNormal`
//! (independent Normals in unconstrained space, transported to each
//! site's support) and `AutoDelta` (point masses — MAP inference).
//!
//! Both generated guides are fully reparameterized with a fixed site
//! set, so pairing one with a static model satisfies the graph-mode
//! staticness conditions ([`crate::infer::compile`]): with
//! [`crate::infer::svi::SviConfig::graph_mode`] set, the compiled
//! straight-line kernel takes over after the first (recorded) step.
//!
//! Generated guides also lint clean by construction: the site set is
//! derived from the model's own prototype trace, so the static analyzer
//! ([`crate::analysis`], reachable as `Svi::analyze` or
//! [`crate::infer::svi::SviConfig::validate`]) reports no
//! correspondence, shape, or reparameterization diagnostics for an
//! autoguide paired with the model it was built from.

use crate::dist::{
    Constraint, Delta, Dist, ExpT, IntervalT, Normal, SigmoidT, TransformedDist,
};
use crate::poutine::{trace_fn, Ctx};
use crate::tensor::{Pcg64, Tensor};

/// One latent site discovered in the prototype trace, sized from the
/// model distribution's `batch_shape`/`event_shape` rather than the raw
/// prototype value. Latent sites inside *subsampled* plates are
/// rejected at discovery time: the generated guides have no access to
/// each step's subsample indices, so they cannot produce a
/// correctly-sliced local latent (use a custom guide there).
#[derive(Clone, Debug)]
pub struct LatentSite {
    pub name: String,
    /// Per-site dims (batch + event).
    pub dims: Vec<usize>,
    /// Event rank of the model site's distribution; the generated guide
    /// site matches it via `to_event`.
    pub event_rank: usize,
    pub constraint: Constraint,
    /// Constrained init at `dims` (the prototype value where shapes
    /// agree, a constraint-transformed zero tensor otherwise).
    pub init: Tensor,
}

/// Discover the latent (non-observed) sites of a model.
pub fn discover_latents(model: &dyn Fn(&mut Ctx), seed: u64) -> Vec<LatentSite> {
    let mut rng = Pcg64::new(seed);
    let proto = trace_fn(model, &mut rng);
    proto
        .sites()
        .iter()
        .filter(|s| !s.is_observed && !s.intervened)
        .map(|s| {
            let c = s.dist.support();
            assert!(
                c.is_continuous(),
                "autoguides require continuous supports (site '{}' has {c:?}); \
                 marginalize discrete latents or use a custom guide",
                s.name
            );
            assert!(
                c != Constraint::Simplex,
                "autoguides do not support simplex sites yet ('{}')",
                s.name
            );
            if let Some(f) = s.cond_indep_stack.iter().find(|f| f.subsample != f.size) {
                panic!(
                    "autoguides do not support latent sites inside subsampled \
                     plates (site '{}' in plate '{}', subsample {}/{}); \
                     use a custom guide or run the plate without subsampling",
                    s.name, f.name, f.subsample, f.size
                );
            }
            let batch = s.dist.batch_shape();
            let event = s.dist.event_shape();
            let event_rank = event.rank();
            let mut dims: Vec<usize> = batch.dims().to_vec();
            dims.extend_from_slice(event.dims());
            let init = if dims == s.value.value().dims() {
                s.value.value().clone()
            } else {
                // dist shapes and drawn value disagree (exotic wrapper):
                // fall back to a synthetic init centered in the support
                c.transform(&Tensor::zeros(dims.clone()))
            };
            LatentSite {
                name: s.name.clone(),
                dims,
                event_rank,
                constraint: c,
                init,
            }
        })
        .collect()
}

/// Discover the non-reparameterized sites of an arbitrary guide program
/// by tracing it once (params initialize into `store`). Feed the result
/// to [`default_elbo`](crate::infer::elbo::default_elbo) to pick an
/// estimator: custom guides with discrete sites get the
/// Rao-Blackwellized TraceGraph estimator, fully reparameterized ones
/// the plain pathwise Trace ELBO.
pub fn guide_nonreparam_sites(
    guide: &dyn Fn(&mut Ctx),
    store: &mut crate::params::ParamStore,
    seed: u64,
) -> Vec<String> {
    let mut rng = Pcg64::new(seed);
    let (trace, _) = crate::poutine::trace_with_store(guide, &mut rng, store);
    trace
        .sites()
        .iter()
        .filter(|s| s.needs_score_term())
        .map(|s| s.name.clone())
        .collect()
}

/// Mean-field Normal guide in unconstrained space.
pub struct AutoNormal {
    pub prefix: String,
    pub sites: Vec<LatentSite>,
    pub init_scale: f64,
}

impl AutoNormal {
    pub fn new(model: &dyn Fn(&mut Ctx)) -> Self {
        AutoNormal {
            prefix: "auto".to_string(),
            sites: discover_latents(model, 0x0A07_0A07),
            init_scale: 0.1,
        }
    }

    /// The generated guide program. Each guide site mirrors the model
    /// site's event structure (`to_event(event_rank)`), so a model site
    /// with `batch [N], event [d]` gets a guide whose log-prob is also
    /// reduced to one joint density per batch element.
    pub fn guide(&self) -> impl Fn(&mut Ctx) + '_ {
        move |ctx: &mut Ctx| {
            for site in &self.sites {
                let unc_init = site.constraint.inverse(&site.init);
                let loc = ctx.param(&format!("{}.{}.loc", self.prefix, site.name), || {
                    unc_init.clone()
                });
                let dims = site.dims.clone();
                let scale = ctx.param_constrained(
                    &format!("{}.{}.scale", self.prefix, site.name),
                    || Tensor::full(dims.clone(), self.init_scale),
                    Constraint::Positive,
                );
                let base = Normal::new(loc, scale);
                let er = site.event_rank;
                match site.constraint {
                    Constraint::Real => {
                        ctx.sample(&site.name, base.to_event(er));
                    }
                    Constraint::Positive | Constraint::NonNegInteger => {
                        ctx.sample(&site.name, TransformedDist::new(base, ExpT).to_event(er));
                    }
                    Constraint::UnitInterval => {
                        ctx.sample(
                            &site.name,
                            TransformedDist::new(base, SigmoidT).to_event(er),
                        );
                    }
                    Constraint::Interval(lo, hi) => {
                        ctx.sample(
                            &site.name,
                            TransformedDist::new(base, IntervalT { lo, hi }).to_event(er),
                        );
                    }
                    _ => unreachable!("checked in discover_latents"),
                }
            }
        }
    }

    /// Guide sites that need score-function gradients: none — every
    /// `AutoNormal` site is a (transformed) Normal with `rsample`, so
    /// [`Svi`](crate::infer::svi::Svi) can safely default to the plain
    /// pathwise [`TraceElbo`](crate::infer::elbo::TraceElbo). See
    /// [`recommended_elbo`](AutoNormal::recommended_elbo).
    pub fn nonreparam_sites(&self) -> Vec<String> {
        Vec::new()
    }

    /// The estimator [`default_elbo`](crate::infer::elbo::default_elbo)
    /// picks for this guide's advertised sites.
    pub fn recommended_elbo(&self) -> Box<dyn crate::infer::elbo::Elbo> {
        crate::infer::elbo::default_elbo(&self.nonreparam_sites())
    }

    /// Posterior median (= transformed loc) per site, after training.
    pub fn median(&self, store: &crate::params::ParamStore) -> Vec<(String, Tensor)> {
        self.sites
            .iter()
            .map(|s| {
                let loc = store
                    .get(&format!("{}.{}.loc", self.prefix, s.name))
                    .expect("guide params uninitialized — run SVI first");
                (s.name.clone(), s.constraint.transform(&loc))
            })
            .collect()
    }
}

/// Point-mass guide: SVI with `AutoDelta` is MAP estimation.
pub struct AutoDelta {
    pub prefix: String,
    pub sites: Vec<LatentSite>,
}

impl AutoDelta {
    pub fn new(model: &dyn Fn(&mut Ctx)) -> Self {
        AutoDelta { prefix: "map".to_string(), sites: discover_latents(model, 0x0A07_0A07) }
    }

    pub fn guide(&self) -> impl Fn(&mut Ctx) + '_ {
        move |ctx: &mut Ctx| {
            for site in &self.sites {
                let init = site.init.clone();
                let v = ctx.param_constrained(
                    &format!("{}.{}", self.prefix, site.name),
                    || init,
                    site.constraint,
                );
                ctx.sample(&site.name, Delta::new(v).to_event(site.event_rank));
            }
        }
    }

    /// Guide sites that need score-function gradients: none — `Delta`
    /// point masses are reparameterized (the value IS the parameter).
    pub fn nonreparam_sites(&self) -> Vec<String> {
        Vec::new()
    }

    /// The estimator [`default_elbo`](crate::infer::elbo::default_elbo)
    /// picks for this guide's advertised sites.
    pub fn recommended_elbo(&self) -> Box<dyn crate::infer::elbo::Elbo> {
        crate::infer::elbo::default_elbo(&self.nonreparam_sites())
    }

    /// The MAP point estimate per site.
    pub fn values(&self, store: &crate::params::ParamStore) -> Vec<(String, Tensor)> {
        self.sites
            .iter()
            .map(|s| {
                (
                    s.name.clone(),
                    store
                        .get(&format!("{}.{}", self.prefix, s.name))
                        .expect("guide params uninitialized — run SVI first"),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Gamma, LogNormal};
    use crate::infer::elbo::{Elbo, TraceElbo};
    use crate::infer::svi::{Svi, SviConfig};
    use crate::optim::Adam;
    use crate::params::ParamStore;

    fn model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    #[test]
    fn discovers_latents_with_constraints() {
        let m = |ctx: &mut Ctx| {
            ctx.sample("a", Normal::std(0.0, 1.0));
            ctx.sample("b", LogNormal::std(0.0, 1.0));
            ctx.observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.0));
        };
        let sites = discover_latents(&m, 1);
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].constraint, Constraint::Real);
        assert_eq!(sites[1].constraint, Constraint::Positive);
    }

    #[test]
    fn autonormal_recovers_conjugate_posterior() {
        let auto = AutoNormal::new(&model);
        let guide = auto.guide();
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(3);
        let mut svi = Svi::with_config(
            Adam::new(0.03),
            TraceElbo::default(),
            SviConfig { num_particles: 4, ..SviConfig::default() },
        );
        for _ in 0..3000 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let med = auto.median(&store);
        assert_eq!(med[0].0, "z");
        assert!((med[0].1.item() - 0.3).abs() < 0.08, "median {}", med[0].1.item());
    }

    #[test]
    fn autonormal_handles_positive_support() {
        // rate ~ Gamma(3, 1); observe counts -> posterior concentrates
        // near MLE; just check the guide runs and produces positive sims
        let m = |ctx: &mut Ctx| {
            let rate = ctx.sample("rate", Gamma::std(3.0, 1.0));
            ctx.observe("x", Normal::new(rate, ctx.cs(0.5)), Tensor::scalar(2.0));
        };
        let auto = AutoNormal::new(&m);
        let guide = auto.guide();
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(5);
        let mut svi = Svi::new(Adam::new(0.05), TraceElbo::default());
        for _ in 0..500 {
            let loss = svi.step(&mut store, &mut rng, &m, &guide);
            assert!(loss.is_finite());
        }
        let med = auto.median(&store);
        assert!(med[0].1.item() > 0.0, "positive-support median");
        assert!((med[0].1.item() - 2.0).abs() < 0.6, "median {}", med[0].1.item());
    }

    #[test]
    fn autodelta_finds_map() {
        // MAP of the conjugate model = posterior mean 0.3 (Gaussian)
        let auto = AutoDelta::new(&model);
        let guide = auto.guide();
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(7);
        let mut svi = Svi::new(Adam::new(0.05), auto.recommended_elbo());
        for _ in 0..800 {
            svi.step(&mut store, &mut rng, &model, &guide);
        }
        let vals = auto.values(&store);
        assert!((vals[0].1.item() - 0.3).abs() < 0.02, "MAP {}", vals[0].1.item());
    }

    #[test]
    fn autoguides_advertise_reparameterization() {
        let auto = AutoNormal::new(&model);
        assert!(auto.nonreparam_sites().is_empty());
        assert_eq!(auto.recommended_elbo().name(), "Trace");
        let map = AutoDelta::new(&model);
        assert!(map.nonreparam_sites().is_empty());
        assert_eq!(map.recommended_elbo().name(), "Trace");
    }

    #[test]
    fn custom_guide_nonreparam_discovery_drives_estimator_choice() {
        // a guide with a discrete site advertises it, and default_elbo
        // upgrades to the Rao-Blackwellized TraceGraph estimator
        let discrete_guide = |ctx: &mut Ctx| {
            let logit = ctx.param("q_logit", || Tensor::scalar(0.0));
            ctx.sample("k", crate::dist::Bernoulli::new(logit));
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let mut store = ParamStore::new();
        let sites = guide_nonreparam_sites(&discrete_guide, &mut store, 11);
        assert_eq!(sites, vec!["k".to_string()]);
        assert_eq!(crate::infer::elbo::default_elbo(&sites).name(), "TraceGraph");

        let reparam_guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let sites = guide_nonreparam_sites(&reparam_guide, &mut store, 11);
        assert!(sites.is_empty());
        assert_eq!(crate::infer::elbo::default_elbo(&sites).name(), "Trace");
    }

    #[test]
    fn autoguides_lint_clean_against_their_model() {
        // the analyzer sees an exact site correspondence (the guide was
        // fabricated from the model's prototype trace) and a fully
        // reparameterized family -> zero diagnostics, even under the
        // pathwise Trace estimator
        let auto = AutoNormal::new(&model);
        let guide = auto.guide();
        let store = ParamStore::new();
        let svi = Svi::new(Adam::new(0.01), TraceElbo::default());
        let report = svi.analyze(&store, 17, &model, &guide);
        assert!(report.is_clean(), "AutoNormal should lint clean: {report}");

        let map = AutoDelta::new(&model);
        let guide = map.guide();
        let svi = Svi::new(Adam::new(0.01), TraceElbo::default());
        let report = svi.analyze(&store, 17, &model, &guide);
        assert!(report.is_clean(), "AutoDelta should lint clean: {report}");
    }

    #[test]
    #[should_panic(expected = "continuous supports")]
    fn discrete_latents_rejected() {
        let m = |ctx: &mut Ctx| {
            ctx.sample("k", crate::dist::Bernoulli::std(0.5));
        };
        AutoNormal::new(&m);
    }

    #[test]
    #[should_panic(expected = "subsampled plates")]
    fn latents_inside_subsampled_plates_rejected() {
        // the generated guide cannot know each step's subsample indices,
        // so this must fail loudly at discovery, not mid-SVI
        let m = |ctx: &mut Ctx| {
            ctx.plate("data", 8, Some(2), |ctx, _plate| {
                ctx.sample(
                    "z",
                    Normal::new(ctx.c(Tensor::zeros(vec![2])), ctx.c(Tensor::ones(vec![2]))),
                );
            });
        };
        AutoNormal::new(&m);
    }

    #[test]
    fn autoguide_supports_latents_in_full_plates() {
        // full (non-subsampled) plate: guide params sized from batch+event
        let m = |ctx: &mut Ctx| {
            ctx.plate("data", 3, None, |ctx, _plate| {
                let z = ctx.sample(
                    "z",
                    Normal::new(ctx.c(Tensor::zeros(vec![3])), ctx.c(Tensor::ones(vec![3]))),
                );
                ctx.observe(
                    "x",
                    Normal::new(z, ctx.cs(1.0)),
                    Tensor::from_vec(vec![0.1, 0.2, 0.3]),
                );
            });
        };
        let auto = AutoNormal::new(&m);
        assert_eq!(auto.sites.len(), 1);
        assert_eq!(auto.sites[0].dims, vec![3]);
        assert_eq!(auto.sites[0].event_rank, 0);
    }
}
