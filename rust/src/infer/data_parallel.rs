//! Sharded data-parallel SVI: W workers, each owning a contiguous shard
//! of the dataset, evaluate one ELBO particle per step against their own
//! streamed minibatch and merge gradients **deterministically in shard
//! order** — extending the particle-order merge discipline, so for a
//! fixed shard decomposition the thread count is purely a throughput
//! knob: W-threaded training is bitwise identical to single-threaded
//! training. (The shard count itself is semantic — it fixes which rows
//! form each step's combined minibatch — so changing `num_shards`
//! changes the trajectory exactly like changing the batch size does.)
//!
//! Composition with graph mode ([`crate::infer::compile`]): the model is
//! compiled **once** against worker 0's recording, then every worker
//! gets a private arena over the shared straight-line program; each
//! step refreshes the per-worker minibatch view nodes in place and
//! replays the kernel. Compile once, instantiate W arenas — never
//! compile W times.
//!
//! For the asynchronous parameter-server mode (bounded staleness,
//! non-deterministic by design) see [`crate::coordinator::ParamServer`].

use crate::data::{ShardCursor, ShardedLoader};
use crate::error::{Error, Result};
use crate::infer::compile::{self, GraphDiagnostics, Recorded, ShardRunner};
use crate::infer::elbo::{Elbo, ParticleStats, TraceElbo};
use crate::infer::svi::{run_particle, ParticleOut};
use crate::optim::{apply_grads, Optimizer};
use crate::params::ParamStore;
use crate::poutine::Ctx;
use crate::telemetry;
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

/// What one worker sees each step: its freshly-gathered minibatch view
/// tensors (driver-owned, refilled in place) plus the global row
/// indices of the batch for [`Ctx::plate_idx`] bookkeeping.
pub struct ShardBatch<'a> {
    /// One tensor per [`BatchLayout`] view, dims `[batch] + view_dims`.
    pub views: &'a [Tensor],
    /// Dataset-global row indices of this batch.
    pub idx: &'a [usize],
    /// Total dataset rows — the `size` for the subsampling plate.
    pub total: usize,
}

/// A data-parallel probabilistic program. Evaluated concurrently by
/// worker threads, so captures must be `Sync`. Graph-mode contract: the
/// body must put each view tensor on the tape **directly** (observe it,
/// or lift it with `ctx.c(views[i].clone())`), never a derived copy, so
/// compiled steps can refresh the data in place.
pub type ShardModelFn = dyn Fn(&mut Ctx, &ShardBatch) + Sync;

/// How each dataset row splits into model-facing view tensors. Views
/// partition the row contiguously: view `k` covers the next
/// `view_dims[k].numel()` elements. A VAE sees one `[784]` view per
/// image row; a DMM sees `T` views of `[88]` per roll row (one tensor
/// per time step, each batched to `[batch, 88]`).
#[derive(Clone, Debug)]
pub struct BatchLayout {
    pub views: Vec<Vec<usize>>,
}

impl BatchLayout {
    /// One view covering the whole row.
    pub fn single(row_dims: &[usize]) -> BatchLayout {
        BatchLayout { views: vec![row_dims.to_vec()] }
    }

    /// `t` equal frame views (sequence models: one tensor per step).
    pub fn frames(t: usize, frame_dims: &[usize]) -> BatchLayout {
        BatchLayout { views: (0..t).map(|_| frame_dims.to_vec()).collect() }
    }

    pub(crate) fn numels(&self) -> Vec<usize> {
        self.views.iter().map(|d| d.iter().product()).collect()
    }
}

/// Data-parallel SVI configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Worker count W — the **semantic** decomposition: each step's
    /// effective minibatch is the union of W per-shard batches.
    pub num_shards: usize,
    /// Rows per shard per step.
    pub batch: usize,
    /// Evaluate shards on scoped worker threads. Purely a throughput
    /// switch: serial and parallel execution match bitwise.
    pub parallel: bool,
    /// Worker-thread cap (0 = one per available core).
    pub num_threads: usize,
    /// Compile the model once and run every worker over a private arena
    /// of the shared program ([`crate::infer::compile`]); falls back
    /// loudly to the dynamic path when guards fail.
    pub graph_mode: bool,
    /// Seed base for the per-shard epoch shuffles (restart-reproducible;
    /// independent of the training RNG passed to `step`).
    pub base_seed: u64,
}

impl ShardConfig {
    pub fn new(num_shards: usize, batch: usize) -> ShardConfig {
        ShardConfig {
            num_shards,
            batch,
            parallel: false,
            num_threads: 0,
            graph_mode: false,
            base_seed: 0x5EED_DA7A,
        }
    }

    fn effective_threads(&self) -> usize {
        if !self.parallel {
            return 1;
        }
        let hw = if self.num_threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.num_threads
        };
        hw.min(self.num_shards).max(1)
    }
}

/// One worker's loading state: its epoch cursor plus reusable gather
/// scratch and view tensors (all refilled in place — the epoch loop is
/// allocation-free in steady state).
struct Worker {
    cursor: ShardCursor,
    views: Vec<Tensor>,
    scratch: Vec<f32>,
    idx: Vec<usize>,
}

impl Worker {
    fn fill_views(
        &mut self,
        loader: &dyn ShardedLoader,
        numels: &[usize],
        row_numel: usize,
    ) -> Result<()> {
        loader.gather_into(&self.idx, &mut self.scratch)?;
        fill_views_from_scratch(&self.scratch, self.idx.len(), numels, row_numel, &mut self.views);
        Ok(())
    }
}

/// Scatter a gathered `[b, row_numel]` f32 block into per-view f64
/// tensors (each `[b] + view_dims`), in place. Shared by the
/// synchronous driver and the async parameter-server workers
/// ([`crate::coordinator::train_async`]).
pub(crate) fn fill_views_from_scratch(
    scratch: &[f32],
    b: usize,
    numels: &[usize],
    row_numel: usize,
    views: &mut [Tensor],
) {
    let mut off = 0usize;
    for (view, &ne) in views.iter_mut().zip(numels) {
        let dst = view.data_mut();
        for r in 0..b {
            let src = &scratch[r * row_numel + off..r * row_numel + off + ne];
            for (d, &s) in dst[r * ne..(r + 1) * ne].iter_mut().zip(src) {
                *d = s as f64;
            }
        }
        off += ne;
    }
}

enum ShardGraphState {
    Pending,
    Active(Box<ShardRunner>),
    Disabled,
}

/// The data-parallel SVI engine. Synchronous and deterministic: each
/// step draws W seeds in shard order from the caller's RNG, evaluates
/// every shard (serially or on scoped threads — same result), merges
/// gradients in shard order with a single final `1/W` scale, and
/// applies them through one optimizer in param-name order.
pub struct DataParallelSvi<O: Optimizer, E: Elbo = TraceElbo> {
    pub opt: O,
    pub elbo: E,
    pub config: ShardConfig,
    layout: BatchLayout,
    numels: Vec<usize>,
    workers: Vec<Worker>,
    steps: u64,
    graph: ShardGraphState,
    diags: GraphDiagnostics,
}

impl<O: Optimizer, E: Elbo> DataParallelSvi<O, E> {
    pub fn new(opt: O, elbo: E, config: ShardConfig, layout: BatchLayout) -> Self {
        assert!(config.num_shards > 0, "need at least one shard");
        assert!(config.batch > 0, "need a positive per-shard batch");
        let numels = layout.numels();
        DataParallelSvi {
            opt,
            elbo,
            config,
            layout,
            numels,
            workers: Vec::new(),
            steps: 0,
            graph: ShardGraphState::Pending,
            diags: GraphDiagnostics::default(),
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.steps
    }

    pub fn graph_diagnostics(&self) -> &GraphDiagnostics {
        &self.diags
    }

    /// Build the per-shard cursors and view buffers against `loader`
    /// (idempotent; `step` calls it implicitly). Needed before
    /// [`DataParallelSvi::restore_cursors`] on a fresh engine.
    pub fn init(&mut self, loader: &dyn ShardedLoader) -> Result<()> {
        if !self.workers.is_empty() {
            return Ok(());
        }
        let row_numel = loader.row_numel();
        let view_sum: usize = self.numels.iter().sum();
        if view_sum != row_numel {
            return Err(Error::msg(format!(
                "batch layout covers {view_sum} elements per row, loader rows have {row_numel}"
            )));
        }
        let w = self.config.num_shards;
        if loader.len() < w * self.config.batch {
            return Err(Error::msg(format!(
                "{} rows cannot feed {} shards × batch {}",
                loader.len(),
                w,
                self.config.batch
            )));
        }
        let b = self.config.batch;
        self.workers = (0..w)
            .map(|shard| Worker {
                cursor: ShardCursor::for_shard(loader, w, shard, b, self.config.base_seed),
                views: self
                    .layout
                    .views
                    .iter()
                    .map(|d| {
                        let mut dims = vec![b];
                        dims.extend_from_slice(d);
                        Tensor::zeros(dims)
                    })
                    .collect(),
                scratch: Vec::with_capacity(b * row_numel),
                idx: Vec::with_capacity(b),
            })
            .collect();
        Ok(())
    }

    /// Per-shard `(epoch, offset)` resume points, in shard order — save
    /// these alongside the param store to restart mid-epoch.
    pub fn cursor_states(&self) -> Vec<(u64, usize)> {
        self.workers.iter().map(|w| w.cursor.state()).collect()
    }

    /// Restore saved [`DataParallelSvi::cursor_states`] (call
    /// [`DataParallelSvi::init`] first on a fresh engine). The epoch
    /// shuffles are pure functions of (seed, epoch), so the restored
    /// engine replays the exact batch stream the original would have.
    pub fn restore_cursors(&mut self, states: &[(u64, usize)]) {
        assert_eq!(states.len(), self.workers.len(), "cursor state count mismatch (init first?)");
        for (w, &(epoch, pos)) in self.workers.iter_mut().zip(states) {
            w.cursor.restore(epoch, pos);
        }
    }

    /// One synchronous data-parallel step; returns the loss (mean over
    /// shards of each shard's estimator loss).
    pub fn step(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        loader: &dyn ShardedLoader,
        model: &ShardModelFn,
        guide: &ShardModelFn,
    ) -> Result<f64> {
        let _span = telemetry::span(telemetry::Hist::StepNs);
        self.init(loader)?;
        let row_numel = loader.row_numel();
        // 1. advance every cursor and gather, in shard order (the
        // cursors are deterministic state machines, so gathering on the
        // driver thread costs nothing semantically; StreamLoader reads
        // serialize on its file lock anyway)
        for worker in &mut self.workers {
            worker.idx.clear();
            let batch = worker.cursor.next_batch();
            worker.idx.extend_from_slice(batch);
        }
        for worker in &mut self.workers {
            worker.fill_views(loader, &self.numels, row_numel)?;
        }
        // 2. per-shard particle seeds, drawn up front in shard order
        let w = self.config.num_shards;
        let seeds: Vec<u64> = (0..w).map(|_| rng.next_u64()).collect();

        if self.config.graph_mode {
            self.step_graph(store, &seeds, loader.len(), model, guide)
        } else {
            let results = self
                .run_shards_dynamic(store, &seeds, loader.len(), model, guide, false)?
                .0;
            self.merge_and_apply(results, store)
        }
    }

    /// Evaluate every shard dynamically (serial or scoped threads —
    /// bitwise identical), in shard-index order. With `record`, shard 0
    /// runs instrumented for graph compilation.
    #[allow(clippy::type_complexity)]
    fn run_shards_dynamic(
        &self,
        store: &mut ParamStore,
        seeds: &[u64],
        total: usize,
        model: &ShardModelFn,
        guide: &ShardModelFn,
        record: bool,
    ) -> Result<(Vec<ParticleOut>, Option<Recorded>)> {
        let w = seeds.len();
        let batches: Vec<ShardBatch> = self
            .workers
            .iter()
            .map(|wk| ShardBatch { views: &wk.views, idx: &wk.idx, total })
            .collect();
        let snapshot = self.elbo.snapshot();
        let elbo = &self.elbo;

        if record {
            // Recording steps are rare (first step + guard fallbacks);
            // run serially — bitwise equal to the parallel path anyway.
            let b0 = &batches[0];
            let m0 = |ctx: &mut Ctx| model(ctx, b0);
            let g0 = |ctx: &mut Ctx| guide(ctx, b0);
            let (recorded, out0) =
                compile::record_particle(seeds[0], store, &m0, &g0, elbo, &snapshot)?;
            let mut results = Vec::with_capacity(w);
            results.push(ParticleOut {
                grads: out0.grads,
                stats: ParticleStats { value: out0.value, obs: out0.obs },
            });
            for (b, &seed) in batches.iter().zip(seeds).skip(1) {
                let m = |ctx: &mut Ctx| model(ctx, b);
                let g = |ctx: &mut Ctx| guide(ctx, b);
                results.push(run_particle(seed, store, &m, &g, elbo, &snapshot)?);
            }
            return Ok((results, Some(recorded)));
        }

        let threads = self.config.effective_threads();
        if threads <= 1 || w <= 1 {
            let mut results = Vec::with_capacity(w);
            for (b, &seed) in batches.iter().zip(seeds) {
                let m = |ctx: &mut Ctx| model(ctx, b);
                let g = |ctx: &mut Ctx| guide(ctx, b);
                results.push(run_particle(seed, store, &m, &g, elbo, &snapshot)?);
            }
            return Ok((results, None));
        }

        // Parallel: private store clones per shard, merged back in shard
        // order below — the PR 1 discipline, so thread count is
        // invisible in the results.
        let chunk = w.div_ceil(threads);
        let mut slots: Vec<Option<Result<(ParticleOut, ParamStore)>>> = Vec::with_capacity(w);
        slots.resize_with(w, || None);
        // Covers dispatch, the wait for the slowest worker, and the
        // shard-order merge; per-worker compute lands in
        // `Hist::ParticleNs` (inside `run_particle`), so wait time is
        // the difference.
        let merge_span = telemetry::span(telemetry::Hist::MergeWaitNs);
        {
            let shared = &*store;
            let snapshot = &snapshot;
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for (ci, (bchunk, schunk)) in
                    batches.chunks(chunk).zip(seeds.chunks(chunk)).enumerate()
                {
                    let base = ci * chunk;
                    handles.push(scope.spawn(move || {
                        bchunk
                            .iter()
                            .zip(schunk)
                            .enumerate()
                            .map(|(j, (b, &seed))| {
                                let mut local = shared.clone();
                                let m = |ctx: &mut Ctx| model(ctx, b);
                                let g = |ctx: &mut Ctx| guide(ctx, b);
                                let out = run_particle(seed, &mut local, &m, &g, elbo, snapshot)
                                    .map(|o| (o, local));
                                (base + j, out)
                            })
                            .collect::<Vec<_>>()
                    }));
                }
                for h in handles {
                    for (i, out) in h.join().expect("shard worker panicked") {
                        slots[i] = Some(out);
                    }
                }
            });
        }
        let mut results = Vec::with_capacity(w);
        for s in slots {
            let (out, local) = s.expect("missing shard result")?;
            store.merge_missing(&local);
            results.push(out);
        }
        drop(merge_span);
        Ok((results, None))
    }

    /// The deterministic tail of every dynamic step: combine shard
    /// stats through the estimator, merge gradients in shard order
    /// (raw accumulation, one final scale for uniform weights), apply
    /// in param-name order, fold estimator state in shard order.
    fn merge_and_apply(
        &mut self,
        results: Vec<ParticleOut>,
        store: &mut ParamStore,
    ) -> Result<f64> {
        let mut stats = Vec::with_capacity(results.len());
        let mut shard_grads = Vec::with_capacity(results.len());
        for r in results {
            stats.push(r.stats);
            shard_grads.push(r.grads);
        }
        let (loss, weights) = self.elbo.combine(&stats);
        let uniform = weights.windows(2).all(|w| w[0] == w[1]);
        let mut acc: HashMap<String, Tensor> = HashMap::new();
        if uniform {
            for grads in shard_grads {
                for (name, g) in grads {
                    acc.entry(name).and_modify(|a| a.add_assign(&g)).or_insert(g);
                }
            }
            let w = weights.first().copied().unwrap_or(1.0);
            if w != 1.0 {
                for g in acc.values_mut() {
                    g.scale_inplace(w);
                }
            }
        } else {
            for (grads, &w) in shard_grads.into_iter().zip(&weights) {
                for (name, mut g) in grads {
                    g.scale_inplace(w);
                    acc.entry(name).and_modify(|a| a.add_assign(&g)).or_insert(g);
                }
            }
        }
        // read-only probes; enabled vs disabled stays bitwise identical
        if telemetry::enabled() {
            telemetry::record_loss(loss);
            telemetry::count(telemetry::Counter::DynamicSteps);
            let values: Vec<f64> = stats.iter().map(|s| s.value).collect();
            telemetry::record_particle_spread(&values);
            telemetry::record_grad_norm(&acc);
        }
        apply_grads(&mut self.opt, store, &acc);
        self.elbo.absorb(&stats);
        self.steps += 1;
        Ok(loss)
    }

    fn step_graph(
        &mut self,
        store: &mut ParamStore,
        seeds: &[u64],
        total: usize,
        model: &ShardModelFn,
        guide: &ShardModelFn,
    ) -> Result<f64> {
        // Guards, computed under a shared borrow.
        enum Decision {
            Compiled,
            Record { fallback: Option<String> },
            Dynamic { disable: Option<String> },
        }
        let decision = match &self.graph {
            ShardGraphState::Disabled => Decision::Dynamic { disable: None },
            _ if !self.elbo.compilable() => Decision::Dynamic {
                disable: Some(format!(
                    "estimator '{}' is not compilable; unset ShardConfig::graph_mode or \
                     use TraceElbo / TraceMeanFieldElbo",
                    self.elbo.name()
                )),
            },
            ShardGraphState::Pending => Decision::Record { fallback: None },
            ShardGraphState::Active(runner) => {
                if runner.prog().store_fp != store.fingerprint() {
                    Decision::Record {
                        fallback: Some(
                            "parameter store changed shape since compilation".to_string(),
                        ),
                    }
                } else {
                    Decision::Compiled
                }
            }
        };
        match decision {
            Decision::Dynamic { disable } => {
                if let Some(why) = disable {
                    self.disable_graph(why);
                }
                self.diags.dynamic_steps += 1;
                let results =
                    self.run_shards_dynamic(store, seeds, total, model, guide, false)?.0;
                self.merge_and_apply(results, store)
            }
            Decision::Compiled => {
                let ShardGraphState::Active(runner) = &mut self.graph else {
                    unreachable!("decision computed from Active state")
                };
                let views: Vec<&[Tensor]> =
                    self.workers.iter().map(|w| w.views.as_slice()).collect();
                let threads = self.config.effective_threads();
                let loss = runner.step(store, seeds, &views, threads, &mut self.opt);
                self.diags.compiled_steps += 1;
                self.steps += 1;
                telemetry::record_loss(loss);
                telemetry::count(telemetry::Counter::CompiledSteps);
                Ok(loss)
            }
            Decision::Record { fallback } => {
                if let Some(why) = fallback {
                    self.note_fallback(why);
                }
                let (results, recorded) =
                    self.run_shards_dynamic(store, seeds, total, model, guide, true)?;
                match recorded.expect("recording requested") {
                    Recorded::Inherent(why) => self.disable_graph(why),
                    // Verify against the pre-update store — recorded
                    // grads precede this step's optimizer update.
                    Recorded::Ready(rec) => {
                        let views0: Vec<Tensor> = self.workers[0].views.clone();
                        match compile::CompiledProgram::compile(&rec)
                            .and_then(|prog| {
                                prog.verify(store, &rec, seeds[0])?;
                                Ok(prog)
                            })
                            .and_then(|prog| ShardRunner::new(prog, &rec, &views0))
                        {
                            Err(e) => self.disable_graph(e.to_string()),
                            Ok(runner) => {
                                self.graph = ShardGraphState::Active(Box::new(runner));
                                self.diags.compiles += 1;
                                self.diags.active = true;
                                telemetry::count(telemetry::Counter::GraphCompiles);
                            }
                        }
                    }
                }
                self.diags.dynamic_steps += 1;
                self.merge_and_apply(results, store)
            }
        }
    }

    fn disable_graph(&mut self, why: String) {
        telemetry::warn(telemetry::WarnKind::DataParallelGraphDisabled, &why);
        telemetry::count(telemetry::Counter::GraphDisables);
        self.diags.last_error = Some(why);
        self.diags.active = false;
        self.graph = ShardGraphState::Disabled;
    }

    fn note_fallback(&mut self, why: String) {
        telemetry::warn(telemetry::WarnKind::DataParallelGraphFallback, &why);
        telemetry::count(telemetry::Counter::GraphFallbacks);
        self.diags.fallbacks += 1;
        self.diags.last_error = Some(why);
        self.diags.active = false;
    }
}
