//! MCMC diagnostics: effective sample size and split-R̂ (Gelman–Rubin),
//! the standard convergence checks Pyro exposes via `pyro.infer.mcmc`.

/// Autocorrelation-based effective sample size (Geyer initial positive
/// sequence estimator over sample pairs).
pub fn ess(samples: &[f64]) -> f64 {
    let n = samples.len();
    if n < 4 {
        return n as f64;
    }
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    if var == 0.0 {
        return f64::NAN;
    }
    let autocov = |lag: usize| -> f64 {
        (0..n - lag)
            .map(|i| (samples[i] - mean) * (samples[i + lag] - mean))
            .sum::<f64>()
            / n as f64
    };
    // sum consecutive-pair autocorrelations while they stay positive
    let mut rho_sum = 0.0;
    let mut lag = 1;
    while lag + 1 < n {
        let pair = (autocov(lag) + autocov(lag + 1)) / var;
        if pair <= 0.0 {
            break;
        }
        rho_sum += pair;
        lag += 2;
    }
    n as f64 / (1.0 + 2.0 * rho_sum)
}

/// Split-R̂: potential scale reduction on one chain split in half
/// (≈1.00 indicates convergence; >1.05 is trouble).
///
/// Odd-length chains drop the **middle** sample (Stan's convention),
/// so both halves keep their temporal extremes — dropping the last
/// sample instead would blunt exactly the drift the statistic exists
/// to detect.
pub fn split_rhat(samples: &[f64]) -> f64 {
    let n = samples.len() / 2;
    if n < 2 {
        return f64::NAN;
    }
    let chains = [&samples[..n], &samples[samples.len() - n..]];
    let means: Vec<f64> = chains.iter().map(|c| c.iter().sum::<f64>() / n as f64).collect();
    let grand = (means[0] + means[1]) / 2.0;
    let b = n as f64 * ((means[0] - grand).powi(2) + (means[1] - grand).powi(2));
    let w = chains
        .iter()
        .zip(&means)
        .map(|(c, m)| c.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n as f64 - 1.0))
        .sum::<f64>()
        / 2.0;
    if w == 0.0 {
        return f64::NAN;
    }
    let var_plus = (n as f64 - 1.0) / n as f64 * w + b / n as f64;
    (var_plus / w).sqrt()
}

/// Summarize a scalar site from [`McmcSamples`](super::mcmc::McmcSamples).
pub fn summarize_site(out: &super::mcmc::McmcSamples, site: &str) -> SiteSummary {
    let xs: Vec<f64> = out.sites[site].iter().map(|t| t.item()).collect();
    SiteSummary {
        mean: out.mean(site).item(),
        std: out.std(site).item(),
        ess: ess(&xs),
        rhat: split_rhat(&xs),
    }
}

#[derive(Clone, Debug)]
pub struct SiteSummary {
    pub mean: f64,
    pub std: f64,
    pub ess: f64,
    pub rhat: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn iid_samples_have_full_ess() {
        let mut rng = Pcg64::new(1);
        let xs: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let e = ess(&xs);
        assert!(e > 2500.0, "iid ESS too low: {e}");
        let r = split_rhat(&xs);
        assert!((r - 1.0).abs() < 0.02, "iid rhat {r}");
    }

    #[test]
    fn correlated_chain_has_reduced_ess() {
        // AR(1) with phi = 0.9: ESS ratio ~ (1-phi)/(1+phi) ≈ 0.053
        let mut rng = Pcg64::new(2);
        let mut x = 0.0;
        let xs: Vec<f64> = (0..8000)
            .map(|_| {
                x = 0.9 * x + rng.normal() * (1.0f64 - 0.81).sqrt();
                x
            })
            .collect();
        let e = ess(&xs);
        let ratio = e / xs.len() as f64;
        assert!((0.02..0.12).contains(&ratio), "AR(1) ESS ratio {ratio}");
    }

    #[test]
    fn nonstationary_chain_flagged_by_rhat() {
        // drifting chain: two halves with different means
        let mut rng = Pcg64::new(3);
        let xs: Vec<f64> = (0..2000)
            .map(|i| if i < 1000 { rng.normal() } else { 5.0 + rng.normal() })
            .collect();
        let r = split_rhat(&xs);
        assert!(r > 1.5, "drift not flagged: rhat {r}");
    }

    /// Deterministic series from an explicit 64-bit LCG: exact integer
    /// arithmetic plus power-of-two float conversion, so an independent
    /// implementation (the reference values below come from a Python
    /// oracle of the same estimators) reproduces the series bit-for-bit.
    fn lcg_series(n: usize) -> Vec<f64> {
        let mut s: u64 = 0x9E37_79B9_7F4A_7C15;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (s >> 11) as f64 / (1u64 << 53) as f64 - 0.5
            })
            .collect()
    }

    fn ar1_series(n: usize, phi: f64) -> Vec<f64> {
        let mut x = 0.0;
        lcg_series(n)
            .into_iter()
            .map(|e| {
                x = phi * x + e;
                x
            })
            .collect()
    }

    fn close_rel(got: f64, want: f64, tag: &str) {
        let tol = 1e-9 * want.abs().max(1.0);
        assert!((got - want).abs() < tol, "{tag}: got {got}, want {want}");
    }

    #[test]
    fn lcg_series_matches_reference() {
        // guards the generator itself: if these drift, every pin below
        // is meaningless
        let xs = lcg_series(256);
        close_rel(xs[0], -0.3245402495965425, "iid[0]");
        close_rel(xs[255], -0.42531851040115964, "iid[255]");
        let ar = ar1_series(512, 0.9);
        close_rel(ar[511], 0.30732788023810154, "ar[511]");
    }

    #[test]
    fn ess_pinned_on_iid_and_ar1() {
        // reference values from an independent Python implementation of
        // the same Geyer initial-positive-sequence estimator
        close_rel(ess(&lcg_series(256)), 254.5360695088404, "ess iid");
        close_rel(ess(&ar1_series(512, 0.9)), 15.272496561571513, "ess ar1");
        close_rel(ess(&lcg_series(201)), 200.4399005229563, "ess odd");
    }

    #[test]
    fn split_rhat_pinned_on_iid_ar1_and_drift() {
        close_rel(split_rhat(&lcg_series(256)), 1.0174381695873853, "rhat iid");
        close_rel(split_rhat(&ar1_series(512, 0.9)), 1.0028196670327914, "rhat ar1");
        let drift: Vec<f64> = lcg_series(200)
            .into_iter()
            .enumerate()
            .map(|(i, x)| if i < 100 { x } else { x + 3.0 })
            .collect();
        close_rel(split_rhat(&drift), 7.514198534462529, "rhat drift");
    }

    #[test]
    fn split_rhat_odd_length_drops_middle_sample() {
        // pinned against the same Python oracle with the middle-drop
        // convention
        close_rel(split_rhat(&lcg_series(201)), 1.016103685664113, "rhat odd");
        // regression for the old behavior (dropping the *last* sample):
        // the terminal spike must stay in the second half. With it, W is
        // positive and R-hat is finite; the old split dropped the spike,
        // leaving two constant halves and a NaN.
        let r = split_rhat(&[0.0, 0.0, 0.0, 0.0, 100.0]);
        assert!(r.is_finite(), "terminal sample dropped from split: {r}");
        // and the spike inflates R-hat once the halves also differ in
        // spread (the old split reported exactly 1.0 here)
        let r = split_rhat(&[0.0, 0.0, 0.0, 1.0, 100.0]);
        close_rel(r, 1.010151513888952, "rhat spike");
        // even lengths are untouched by the fix
        let even = [1.0, 2.0, 3.0, 4.0];
        assert!(split_rhat(&even).is_finite());
        // too short still yields NaN
        assert!(split_rhat(&[1.0, 2.0, 3.0]).is_nan());
    }

    #[test]
    fn nuts_chain_diagnostics_healthy() {
        use crate::dist::Normal;
        use crate::infer::mcmc::{McmcConfig, Nuts};
        use crate::poutine::Ctx;
        use crate::tensor::Tensor;
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
        };
        let out = Nuts::run(
            &model,
            McmcConfig { warmup: 300, samples: 600, seed: 7, ..Default::default() },
        );
        let s = summarize_site(&out, "z");
        assert!(s.ess > 100.0, "NUTS ESS {}", s.ess);
        assert!(s.rhat < 1.05, "NUTS rhat {}", s.rhat);
    }
}
