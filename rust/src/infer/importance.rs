//! Importance sampling — `pyro.infer.Importance`.
//!
//! Draws proposals from an arbitrary guide program (or the model's own
//! prior when no guide is given — likelihood weighting) and weights them
//! by the model/guide density ratio.

use crate::infer::elbo::trace_log_weight;
use crate::poutine::{handlers, trace_fn, Ctx, Trace};
use crate::tensor::{Pcg64, Tensor};

/// A set of weighted posterior samples.
pub struct Importance {
    pub traces: Vec<Trace>,
    pub log_weights: Vec<f64>,
}

impl Importance {
    /// Likelihood weighting: propose from the prior, weight by the
    /// observed-site likelihood.
    pub fn from_prior(model: &dyn Fn(&mut Ctx), n: usize, rng: &mut Pcg64) -> Self {
        let mut traces = Vec::with_capacity(n);
        let mut log_weights = Vec::with_capacity(n);
        for _ in 0..n {
            let t = trace_fn(model, rng);
            log_weights.push(t.log_likelihood());
            traces.push(t);
        }
        Importance { traces, log_weights }
    }

    /// Propose from `guide`; weight = log p(x, z) - log q(z) — the same
    /// [`trace_log_weight`] statistic the Rényi/IWAE estimator combines.
    pub fn with_guide(
        model: &dyn Fn(&mut Ctx),
        guide: &dyn Fn(&mut Ctx),
        n: usize,
        rng: &mut Pcg64,
    ) -> Self {
        let mut traces = Vec::with_capacity(n);
        let mut log_weights = Vec::with_capacity(n);
        for _ in 0..n {
            let gt = trace_fn(guide, rng);
            let replayed = handlers::replay(model, gt.clone());
            let mt = trace_fn(&replayed, rng);
            log_weights.push(trace_log_weight(&mt, &gt));
            traces.push(mt);
        }
        Importance { traces, log_weights }
    }

    pub fn len(&self) -> usize {
        self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.traces.is_empty()
    }

    /// Normalized weights.
    pub fn weights(&self) -> Vec<f64> {
        let lse = Tensor::from_vec(self.log_weights.clone()).logsumexp();
        self.log_weights.iter().map(|&lw| (lw - lse).exp()).collect()
    }

    /// log evidence estimate: logsumexp(w)/n.
    pub fn log_evidence(&self) -> f64 {
        Tensor::from_vec(self.log_weights.clone()).logsumexp() - (self.len() as f64).ln()
    }

    /// Effective sample size of the normalized weights.
    pub fn ess(&self) -> f64 {
        let w = self.weights();
        1.0 / w.iter().map(|&x| x * x).sum::<f64>()
    }

    /// Self-normalized posterior mean of a scalar site.
    pub fn posterior_mean(&self, site: &str) -> Tensor {
        let w = self.weights();
        let mut acc: Option<Tensor> = None;
        for (t, &wi) in self.traces.iter().zip(&w) {
            let v = t
                .get(site)
                .unwrap_or_else(|| panic!("site '{site}' not in trace"))
                .value
                .value()
                .mul_scalar(wi);
            acc = Some(match acc {
                None => v,
                Some(a) => a.add(&v),
            });
        }
        acc.expect("no samples")
    }

    /// Systematic resampling into equally-weighted traces.
    pub fn resample(&self, n: usize, rng: &mut Pcg64) -> Vec<&Trace> {
        let w = self.weights();
        let mut cum = 0.0;
        let cumsum: Vec<f64> = w
            .iter()
            .map(|&x| {
                cum += x;
                cum
            })
            .collect();
        let start = rng.uniform() / n as f64;
        let mut out = Vec::with_capacity(n);
        let mut j = 0usize;
        for i in 0..n {
            let u = start + i as f64 / n as f64;
            while j < cumsum.len() - 1 && cumsum[j] < u {
                j += 1;
            }
            out.push(&self.traces[j]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Normal};

    fn model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    #[test]
    fn prior_proposal_estimates_evidence() {
        let mut rng = Pcg64::new(1);
        let imp = Importance::from_prior(&model, 50_000, &mut rng);
        let want = Normal::std(0.0, 2.0f64.sqrt())
            .log_prob(&Tensor::scalar(0.6))
            .item();
        assert!((imp.log_evidence() - want).abs() < 0.01, "{} vs {want}", imp.log_evidence());
    }

    #[test]
    fn posterior_mean_matches_conjugate() {
        let mut rng = Pcg64::new(2);
        let imp = Importance::from_prior(&model, 50_000, &mut rng);
        let m = imp.posterior_mean("z").item();
        assert!((m - 0.3).abs() < 0.02, "posterior mean {m}");
    }

    #[test]
    fn good_guide_gives_high_ess() {
        let mut rng = Pcg64::new(3);
        let exact_guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.3, 0.7071));
        };
        let bad_guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(-3.0, 0.5));
        };
        let n = 2000;
        let good = Importance::with_guide(&model, &exact_guide, n, &mut rng);
        let bad = Importance::with_guide(&model, &bad_guide, n, &mut rng);
        assert!(good.ess() > 0.9 * n as f64, "exact-guide ESS {}", good.ess());
        assert!(bad.ess() < 0.25 * n as f64, "bad-guide ESS {}", bad.ess());
        // the well-matched proposal estimates the evidence accurately
        let want = Normal::std(0.0, 2.0f64.sqrt()).log_prob(&Tensor::scalar(0.6)).item();
        assert!((good.log_evidence() - want).abs() < 0.05);
    }

    #[test]
    fn resample_concentrates_on_high_weight_traces() {
        let mut rng = Pcg64::new(4);
        let imp = Importance::from_prior(&model, 5000, &mut rng);
        let res = imp.resample(5000, &mut rng);
        let mean: f64 = res
            .iter()
            .map(|t| t.get("z").unwrap().value.value().item())
            .sum::<f64>()
            / res.len() as f64;
        assert!((mean - 0.3).abs() < 0.05, "resampled mean {mean}");
    }
}
