//! Graph-mode SVI: compile a static trace into a straight-line fused
//! ELBO kernel.
//!
//! The dynamic path (`Svi::step`) re-runs the guide and model through
//! the full poutine handler stack every step — HashMap trace lookups,
//! per-site boxed closures, a fresh autodiff tape, and one heap
//! allocation per intermediate tensor. For *static* models (the common
//! case: fixed site set, fixed shapes, fixed plate structure) all of
//! that work is identical every step except for the numbers flowing
//! through it. This module records ONE instrumented dynamic execution
//! and turns its tape into a [`CompiledProgram`]: a flat arena of
//! preallocated tensors plus straight-line forward and backward plans
//! that compute the same loss and the same gradients with zero handler
//! dispatch, zero name lookups, and zero steady-state allocations.
//!
//! The dynamic interpreter remains the semantics oracle:
//!
//! * at compile time, [`CompiledProgram::verify`] replays the recorded
//!   seed and requires the compiled value and every gradient to match
//!   the recorded dynamic results (and the RNG end state to match, which
//!   proves the recorded input schedule accounts for every draw);
//! * each step, cheap guards (a [`ParamStore::fingerprint`] compare)
//!   re-validate the world; on mismatch graph mode falls back **loudly**
//!   to the dynamic path and re-records;
//! * optionally ([`crate::infer::svi::SviConfig::graph_revalidate`]) a
//!   full dynamic re-trace every N steps catches structure changes that
//!   no cheap guard can see (data-dependent control flow).
//!
//! Multi-particle steps compose with the scoped-thread parallelism from
//! the allocation-free SVI work: each particle owns a private [`Arena`],
//! gradients merge in particle-index order, so parallel and serial
//! compiled execution are bitwise identical for a given seed.
//!
//! Naming note: the XLA coordinator has its own `CompiledModel` (a PJRT
//! executable for batched log-density evaluation). That is a different
//! artifact for a different backend; everything in this module executes
//! on the CPU interpreter's own tensors.

use crate::autodiff::{DrawKind, Op, TapeEvent, TapeNode};
use crate::error::{Error, Result};
use crate::infer::elbo::{has_score_sites, BaselineSnapshot, Elbo, ParticleCtx};
use crate::infer::svi::{ModelFn, SviConfig};
use crate::optim::Optimizer;
use crate::params::ParamStore;
use crate::poutine::{handlers, Ctx, Trace};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

// ------------------------------------------------------------ diagnostics

/// Counters describing what graph mode actually did — exposed through
/// `Svi::graph_diagnostics` so tests and users can assert on fallback
/// behavior instead of parsing stderr.
#[derive(Clone, Debug, Default)]
pub struct GraphDiagnostics {
    /// A compiled program is currently installed and being used.
    pub active: bool,
    /// Successful record→compile→verify passes.
    pub compiles: u64,
    /// Steps executed by the compiled program.
    pub compiled_steps: u64,
    /// Steps executed by the dynamic interpreter (recording steps count
    /// here too — they produce their result dynamically).
    pub dynamic_steps: u64,
    /// Loud fallbacks: a guard tripped and the step re-recorded.
    pub fallbacks: u64,
    /// Scheduled re-validations that confirmed the structure unchanged.
    pub revalidations: u64,
    /// Why graph mode was last disabled or fell back, if it ever did.
    pub last_error: Option<String>,
    /// Site-level diff from the last structure-change fallback.
    pub last_structure_diff: Option<String>,
}

impl GraphDiagnostics {
    /// Serde-free JSON rendering — embeddable in bench records and
    /// telemetry JSONL events alike.
    pub fn to_json(&self) -> crate::benchkit::json::JsonObj {
        let mut obj = crate::benchkit::json::JsonObj::new()
            .bool("active", self.active)
            .int("compiles", self.compiles as usize)
            .int("compiled_steps", self.compiled_steps as usize)
            .int("dynamic_steps", self.dynamic_steps as usize)
            .int("fallbacks", self.fallbacks as usize)
            .int("revalidations", self.revalidations as usize);
        if let Some(e) = &self.last_error {
            obj = obj.str("last_error", e);
        }
        if let Some(d) = &self.last_structure_diff {
            obj = obj.str("last_structure_diff", d);
        }
        obj
    }

    /// Fold these counters into the telemetry JSONL stream as one
    /// `graph_diagnostics` event (no-op without an installed sink —
    /// see [`crate::telemetry::export::set_jsonl_path`]). The live
    /// increments already land in the global telemetry counters
    /// (`graph_compiles`, `graph_fallbacks`, `graph_revalidations`);
    /// this snapshot event ties them to a specific engine.
    pub fn emit_telemetry_event(&self, engine: &str) {
        crate::telemetry::export::emit_object(
            "graph_diagnostics",
            crate::benchkit::json::JsonObj::new().str("engine", engine).merge(self.to_json()),
        );
    }
}

// ---------------------------------------------------------------- hashing

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn fnv_u64(h: u64, v: u64) -> u64 {
    fnv1a(h, &v.to_le_bytes())
}

fn op_code(op: &Op) -> u64 {
    match op {
        Op::Leaf => 0,
        Op::Add => 1,
        Op::Sub => 2,
        Op::Mul => 3,
        Op::Div => 4,
        Op::MatMul => 5,
        Op::Neg => 6,
        Op::Exp => 7,
        Op::Ln => 8,
        Op::Sqrt => 9,
        Op::Square => 10,
        Op::Tanh => 11,
        Op::Sigmoid => 12,
        Op::Relu => 13,
        Op::Softplus => 14,
        Op::Lgamma => 15,
        Op::Abs => 16,
        Op::GatherLast(_) => 17,
        Op::AddScalar(_) => 18,
        Op::MulScalar(_) => 19,
        Op::NarrowLast(..) => 20,
        Op::Reshape => 21,
        Op::Sum => 22,
        Op::SumLast => 23,
        Op::Sum0 => 24,
    }
}

/// Hash of everything that makes a recorded tape *structurally* itself:
/// op kinds with their static payloads, the wiring, every node's shape,
/// and the input-event schedule. Two executions with the same structural
/// hash run the identical straight-line program (only the numbers
/// differ), so an installed [`CompiledProgram`] stays valid.
pub(crate) fn structural_hash(nodes: &[TapeNode], events: &[TapeEvent]) -> u64 {
    let mut h = fnv_u64(FNV_OFFSET, nodes.len() as u64);
    for n in nodes {
        h = fnv_u64(h, op_code(&n.op));
        match &n.op {
            Op::GatherLast(idx) => {
                for &i in idx {
                    h = fnv_u64(h, i as u64);
                }
            }
            Op::AddScalar(s) | Op::MulScalar(s) => h = fnv_u64(h, s.to_bits()),
            Op::NarrowLast(o, l) => {
                h = fnv_u64(h, *o as u64);
                h = fnv_u64(h, *l as u64);
            }
            _ => {}
        }
        for &p in &n.parents {
            h = fnv_u64(h, p as u64);
        }
        h = fnv_u64(h, n.value.rank() as u64);
        for &d in n.value.dims() {
            h = fnv_u64(h, d as u64);
        }
    }
    h = fnv_u64(h, events.len() as u64);
    for ev in events {
        match ev {
            TapeEvent::Draw { id, kind } => {
                h = fnv_u64(h, 100);
                h = fnv_u64(h, *id as u64);
                h = fnv_u64(
                    h,
                    match kind {
                        DrawKind::StdNormal => 0,
                        DrawKind::Uniform => 1,
                        DrawKind::UniformOpen => 2,
                    },
                );
            }
            TapeEvent::Permutation { size, take, vectorized } => {
                h = fnv_u64(h, 101);
                h = fnv_u64(h, *size as u64);
                h = fnv_u64(h, *take as u64);
                h = fnv_u64(h, *vectorized as u64);
            }
            // Deliberately NOT hashing `ptr`: storage addresses change
            // run to run while the structure stays identical.
            TapeEvent::Select { source, perm, .. } => {
                h = fnv_u64(h, 102);
                for &d in source.dims() {
                    h = fnv_u64(h, d as u64);
                }
                h = fnv_u64(h, *perm as u64);
            }
        }
    }
    h
}

// --------------------------------------------------------------- skeleton

/// Human-diffable summary of a traced execution: one line per site and
/// per parameter. When a structure guard trips, the diff of two
/// skeletons is the diagnosable part of the error message.
#[derive(Clone, Debug)]
pub(crate) struct Skeleton {
    pub lines: Vec<String>,
    pub hash: u64,
}

fn site_line(role: &str, site: &crate::poutine::Site) -> String {
    use std::fmt::Write;
    let mut plates = String::new();
    for (i, f) in site.cond_indep_stack.iter().enumerate() {
        if i > 0 {
            plates.push(',');
        }
        let _ = write!(plates, "{}[{}/{}]@-{}", f.name, f.subsample, f.size, f.dim + 1);
    }
    format!(
        "{role} {}: {} value{:?} obs={} scale={} plates=[{plates}]",
        site.name,
        site.dist.dist_name(),
        site.value.dims(),
        site.is_observed,
        site.scale,
    )
}

impl Skeleton {
    fn build(
        guide_trace: &Trace,
        model_trace: &Trace,
        leaves: &[(String, crate::autodiff::Var)],
    ) -> Skeleton {
        let mut lines = Vec::new();
        for s in guide_trace.sites() {
            lines.push(site_line("guide", s));
        }
        for s in model_trace.sites() {
            lines.push(site_line("model", s));
        }
        for (name, leaf) in leaves {
            lines.push(format!("param {name}: {:?}", leaf.dims()));
        }
        let mut hash = FNV_OFFSET;
        for l in &lines {
            hash = fnv1a(hash, l.as_bytes());
        }
        Skeleton { lines, hash }
    }
}

/// Site-level diff between the compiled skeleton and a re-trace. Empty
/// site diff means the change is below site granularity (op-level).
pub(crate) fn skeleton_diff(old: &Skeleton, new: &Skeleton) -> String {
    let mut out = String::new();
    for l in &old.lines {
        if !new.lines.contains(l) {
            out.push_str("- ");
            out.push_str(l);
            out.push('\n');
        }
    }
    for l in &new.lines {
        if !old.lines.contains(l) {
            out.push_str("+ ");
            out.push_str(l);
            out.push('\n');
        }
    }
    if out.is_empty() {
        out.push_str(
            "(site skeletons identical; op-level tape structure changed — e.g. a \
             data-dependent branch inside a distribution or nn layer)",
        );
    }
    out
}

// -------------------------------------------------------------- recording

/// Outcome of an instrumented dynamic execution: either everything
/// needed to compile, or the reason this (model, guide, estimator)
/// combination is inherently dynamic.
pub(crate) enum Recorded {
    Ready(Box<Recording>),
    /// Compilation is impossible for a structural reason that recording
    /// again will not fix (score-function sites, non-reparameterized
    /// model-only latents). Graph mode should disable itself.
    Inherent(String),
}

/// The dynamic result of the recorded particle — still a perfectly good
/// SVI step, used by the caller so recording steps are never wasted.
pub(crate) struct RecordedOut {
    pub grads: HashMap<String, Tensor>,
    pub value: f64,
    pub obs: Vec<(String, f64)>,
}

/// One instrumented execution, frozen: the tape snapshot, the per-step
/// input schedule, and the dynamic results that `verify` checks the
/// compiled program against.
pub(crate) struct Recording {
    pub nodes: Vec<TapeNode>,
    pub events: Vec<TapeEvent>,
    pub loss_id: usize,
    pub value: f64,
    /// (param name, leaf node id), in `run_particle`'s dedup order.
    pub leaves: Vec<(String, usize)>,
    /// Dynamic gradients, aligned with `leaves` — the verify oracle.
    pub grads: Vec<Tensor>,
    /// RNG state after the dynamic run; replay must land exactly here.
    pub rng_end: Pcg64,
    pub skeleton: Skeleton,
    pub struct_hash: u64,
    pub store_fp: u64,
}

/// Run one ELBO particle exactly like `run_particle`, with tape
/// recording switched on. The numeric result is identical to the
/// uninstrumented path (recording only appends to a side log).
pub(crate) fn record_particle<E: Elbo + ?Sized>(
    seed: u64,
    store: &mut ParamStore,
    model: &ModelFn,
    guide: &ModelFn,
    elbo: &E,
    snapshot: &BaselineSnapshot,
) -> Result<(Recorded, RecordedOut)> {
    let local = store;
    let mut rng = Pcg64::new(seed);

    // 1. guide pass (instrumented)
    let mut gctx = Ctx::with_store(&mut rng, local);
    gctx.tape.start_recording();
    guide(&mut gctx);
    let tape = gctx.tape.clone();
    let guide_trace = gctx.into_trace();

    // 2. model pass, replayed, on the same tape
    let replayed = handlers::replay(model, guide_trace.clone());
    let mut mctx = Ctx::with_store_on_tape(tape.clone(), &mut rng, local);
    replayed(&mut mctx);
    let model_trace = mctx.into_trace();

    // 3. estimator loss + gradients, exactly as the dynamic path
    let mut pctx = ParticleCtx::new(snapshot);
    let (loss, value) = elbo.differentiable_loss(&model_trace, &guide_trace, &mut pctx)?;
    let mut leaves: Vec<(String, crate::autodiff::Var)> = Vec::new();
    for (name, leaf) in guide_trace
        .param_leaves
        .iter()
        .chain(model_trace.param_leaves.iter())
    {
        if !leaves.iter().any(|(n, _)| n == name) {
            leaves.push((name.clone(), leaf.clone()));
        }
    }
    let leaf_refs: Vec<&crate::autodiff::Var> = leaves.iter().map(|(_, v)| v).collect();
    let grads = tape.grad(&loss, &leaf_refs);

    let events = tape.take_recording().expect("recording was started above");
    let nodes = tape.snapshot_nodes();
    let rng_end = rng.clone();

    let grad_map: HashMap<String, Tensor> = leaves
        .iter()
        .map(|(n, _)| n.clone())
        .zip(grads.iter().cloned())
        .collect();
    let out = RecordedOut { grads: grad_map, value, obs: pctx.obs.clone() };

    // Inherent-staticness checks. The dynamic result above is still a
    // valid step either way, so these are soft failures.
    if has_score_sites(&guide_trace) {
        let names: Vec<&str> = guide_trace
            .sites()
            .iter()
            .filter(|s| crate::poutine::Site::needs_score_term(s))
            .map(|s| s.name.as_str())
            .collect();
        return Ok((
            Recorded::Inherent(format!(
                "guide has score-function (non-reparameterized) sites {names:?}; their \
                 surrogate terms carry cross-step baseline state the straight-line \
                 kernel cannot replay"
            )),
            out,
        ));
    }
    for site in model_trace.sites() {
        if !site.is_observed
            && !site.intervened
            && guide_trace.get(&site.name).is_none()
            && !site.dist.has_rsample()
        {
            return Ok((
                Recorded::Inherent(format!(
                    "model-only latent site '{}' has no reparameterized sampler \
                     ({}); its draw cannot be replayed as a deterministic function \
                     of recorded RNG fills",
                    site.name,
                    site.dist.dist_name()
                )),
                out,
            ));
        }
    }
    if !out.obs.is_empty() {
        return Ok((
            Recorded::Inherent(
                "estimator staged per-step observations (cross-step state); \
                 compiled steps would silently drop them"
                    .to_string(),
            ),
            out,
        ));
    }

    let skeleton = Skeleton::build(&guide_trace, &model_trace, &leaves);
    let struct_hash = structural_hash(&nodes, &events);
    // Post-run fingerprint: first-touch params initialized during this
    // very trace are part of the world subsequent steps will see.
    let store_fp = local.fingerprint();

    let rec = Recording {
        loss_id: loss.id,
        value,
        leaves: leaves.iter().map(|(n, v)| (n.clone(), v.id)).collect(),
        grads,
        nodes,
        events,
        rng_end,
        skeleton,
        struct_hash,
        store_fp,
    };
    Ok((Recorded::Ready(Box::new(rec)), out))
}

// ------------------------------------------------------------ plan types

#[derive(Clone, Copy, Debug)]
enum ZipOp {
    Add,
    Sub,
    Mul,
    Div,
}

/// Forward elementwise unary kinds (scalar payloads inlined).
#[derive(Clone, Copy, Debug)]
enum MapKind {
    Neg,
    Exp,
    Ln,
    Sqrt,
    Square,
    Tanh,
    Sigmoid,
    Relu,
    Softplus,
    Lgamma,
    Abs,
    AddScalar(f64),
    MulScalar(f64),
}

/// One forward instruction: compute node `id`'s value from parents.
#[derive(Clone, Debug)]
enum FwPlan {
    Zip { a: usize, b: usize, op: ZipOp, sa: Vec<usize>, sb: Vec<usize> },
    MatMul { a: usize, b: usize },
    Map { a: usize, kind: MapKind },
    Gather { a: usize, idx: Vec<usize>, last: usize },
    Narrow { a: usize, offset: usize, len: usize, last: usize },
    CopyFlat { a: usize },
    SumAll { a: usize },
    SumLast { a: usize },
    Sum0 { a: usize },
}

/// Fused unary backward: `p[i] += f(g[i], out[i], a[i])`.
#[derive(Clone, Copy, Debug)]
enum UKind {
    Neg,
    Exp,
    Ln,
    Sqrt,
    Square,
    Tanh,
    Sigmoid,
    Relu,
    Softplus,
    Lgamma,
    Abs,
}

/// Shape-moving backward: scatter/broadcast the output adjoint into the
/// parent adjoint. Geometry (outer/inner/last) is derived at run time
/// from the two buffers' lengths, so these carry minimal payload.
#[derive(Clone, Debug)]
enum SKind {
    Flat,
    FlatScale(f64),
    SumAll,
    SumLast,
    Sum0,
    Gather(Vec<usize>),
    Narrow { offset: usize, len: usize },
}

/// Where a backward operand lives in the arena.
#[derive(Clone, Copy, Debug)]
enum Src {
    Val(usize),
    Adj(usize),
    Scratch(usize),
}

/// How a binary edge turns the output adjoint into the pre-reduction
/// parent gradient. Mirrors the dynamic backward closures op for op.
#[derive(Clone, Debug)]
enum Pre {
    /// Parent grad is the output adjoint itself (Add, and Sub's lhs).
    G,
    /// Sub rhs: negate. `buf: None` fuses the negation into the final
    /// accumulate (only valid when no reduction follows).
    NegG { buf: Option<usize> },
    /// Mul edge: `g * other_parent_value`.
    MulVal { other: usize, buf: usize, sg: Vec<usize>, so: Vec<usize> },
    /// Div lhs: `g / b`.
    DivVal { other: usize, buf: usize, sg: Vec<usize>, so: Vec<usize> },
    /// Div rhs: `-(g * a) / (b * b)`, staged exactly like the dynamic
    /// closure (t1 = g*a, t2 = b*b, t3 = t1/t2, t4 = -t3).
    DivB {
        av: usize,
        bv: usize,
        t1: usize,
        t2: usize,
        t3: usize,
        t4: usize,
        sg: Vec<usize>,
        sav: Vec<usize>,
        st1: Vec<usize>,
        st2: Vec<usize>,
    },
}

/// One step of the broadcast-reduction chain (`reduce_grad_to` mirrored
/// onto preallocated buffers): `axis: None` drops the leading dim
/// (sum0), `Some(i)` sums axis `i` keeping it as size 1.
#[derive(Clone, Copy, Debug)]
struct Red {
    axis: Option<usize>,
    buf: usize,
}

#[derive(Clone, Debug)]
struct EdgePlan {
    parent: usize,
    pre: Pre,
    chain: Vec<Red>,
}

/// One backward instruction for node `id`.
#[derive(Clone, Debug)]
enum BwPlan {
    Unary { parent: usize, kind: UKind },
    Scatter { parent: usize, kind: SKind },
    Binary { edges: Vec<EdgePlan> },
    /// `ga = g @ b^T`, `gb = a^T @ g` with preallocated transpose and
    /// product scratch.
    MatMul { av: usize, bv: usize, tb: usize, ga: usize, ta: usize, gb: usize },
}

/// One entry of the per-step input schedule, in recorded (= RNG
/// consumption) order.
#[derive(Clone, Debug)]
enum StepInput {
    /// Draw a fresh permutation of `size` indices into perm slot `slot`.
    Perm { slot: usize, size: usize },
    /// Refill leaf `id` from the given RNG stream.
    Fill { id: usize, kind: DrawKind },
    /// Re-gather minibatch rows of `source` into the target leaves using
    /// the first `take` indices of perm slot `slot`.
    Select { targets: Vec<usize>, source: Tensor, slot: usize, take: usize },
}

/// A parameter's entry point into the arena.
#[derive(Clone, Debug)]
struct ParamSlot {
    name: String,
    id: usize,
    dims: Vec<usize>,
}

struct ScratchAlloc(Vec<Vec<usize>>);

impl ScratchAlloc {
    fn alloc(&mut self, dims: &[usize]) -> usize {
        self.0.push(dims.to_vec());
        self.0.len() - 1
    }
}

/// Mirror of `reduce_grad_to`'s control flow, emitting a chain of
/// preallocated reduction buffers instead of fresh tensors. The final
/// buffer's element count always equals the target's, so the accumulate
/// into the parent adjoint is a flat add.
fn reduce_chain(
    src_dims: &[usize],
    target_dims: &[usize],
    scratch: &mut ScratchAlloc,
) -> Vec<Red> {
    if src_dims == target_dims {
        return Vec::new();
    }
    let mut cur = src_dims.to_vec();
    let mut chain = Vec::new();
    while cur.len() > target_dims.len() {
        cur.remove(0);
        chain.push(Red { axis: None, buf: scratch.alloc(&cur) });
    }
    for i in 0..target_dims.len() {
        if target_dims[i] == 1 && cur[i] != 1 {
            cur[i] = 1;
            chain.push(Red { axis: Some(i), buf: scratch.alloc(&cur) });
        }
    }
    chain
}

// ------------------------------------------------------ compiled program

/// A recorded tape lowered to straight-line plans over a flat arena.
/// Plain `Send + Sync` data: worker threads share `&CompiledProgram`
/// and each own a private mutable [`Arena`].
pub(crate) struct CompiledProgram {
    /// Record-time value of every node — the template every arena's
    /// buffers are deep-copied from (constants keep these values
    /// forever; everything else is overwritten each step).
    init_vals: Vec<Tensor>,
    /// Forward instructions, ascending node id (a valid topo order).
    fw: Vec<(usize, FwPlan)>,
    /// Backward instructions, descending node id.
    bw: Vec<(usize, BwPlan)>,
    /// Adjoint buffers to zero at the start of each backward pass.
    zero_ids: Vec<usize>,
    /// Which node ids get real adjoint buffers (reachable nodes and all
    /// param leaves); the rest get a shared dummy scalar.
    adj_alloc: Vec<bool>,
    scratch_dims: Vec<Vec<usize>>,
    perm_sizes: Vec<usize>,
    schedule: Vec<StepInput>,
    /// Sorted by name — the optimizer application order the dynamic
    /// path's `apply_grads` produces by sorting each step.
    params: Vec<ParamSlot>,
    loss_id: usize,
    value_id: usize,
    pub skeleton: Skeleton,
    pub struct_hash: u64,
    pub store_fp: u64,
    /// Instruction bookkeeping from the DCE pass. One backward
    /// "instruction" is one accumulate target: a whole Unary/Scatter
    /// entry, one Binary edge, or one MatMul gradient. The forward plan
    /// is already pruned to loss-reachable nodes at construction, so
    /// `fw_eliminated` stays 0 and is kept only to make the accounting
    /// explicit in bench output.
    fw_total: usize,
    bw_total: usize,
    fw_eliminated: usize,
    bw_eliminated: usize,
}

/// DCE accounting unit for one backward entry (see
/// [`CompiledProgram::eliminate_dead`]).
fn bw_units(plan: &BwPlan) -> usize {
    match plan {
        BwPlan::Unary { .. } | BwPlan::Scatter { .. } => 1,
        BwPlan::Binary { edges } => edges.len(),
        BwPlan::MatMul { .. } => 2,
    }
}

/// Parent adjoints a backward entry accumulates into.
fn bw_parents(plan: &BwPlan) -> Vec<usize> {
    match plan {
        BwPlan::Unary { parent, .. } | BwPlan::Scatter { parent, .. } => vec![*parent],
        BwPlan::Binary { edges } => edges.iter().map(|e| e.parent).collect(),
        BwPlan::MatMul { av, bv, .. } => vec![*av, *bv],
    }
}

impl CompiledProgram {
    /// Lower a recording into a compiled program and run the mandatory
    /// analysis passes: liveness-based dead-code elimination over the
    /// backward plan, then the graph-IR verifier ([`Self::verify_ir`],
    /// lint FY012). Every install path — `Svi::step`'s graph mode,
    /// `Svi::compile`, and the data-parallel [`ShardRunner`] — goes
    /// through here, so no program executes without passing the
    /// verifier.
    pub(crate) fn compile(rec: &Recording) -> Result<CompiledProgram> {
        let mut prog = Self::compile_raw(rec)?;
        prog.eliminate_dead();
        prog.verify_ir()?;
        Ok(prog)
    }

    /// Plan construction only — no DCE, no verifier. Split out so
    /// [`dce_audit`] can compare the pruned program against the exact
    /// unpruned lowering.
    fn compile_raw(rec: &Recording) -> Result<CompiledProgram> {
        let nodes = &rec.nodes;
        let loss_id = rec.loss_id;

        // The loss must be the final negation of the ELBO value node —
        // true for TraceElbo (without score sites) and
        // TraceMeanFieldElbo. Anything else means the estimator's
        // surrogate is not the plain -ELBO form.
        if !matches!(nodes[loss_id].op, Op::Neg) {
            return Err(Error::msg(
                "graph compile: expected the loss to be a final negation of the ELBO \
                 value node (plain -ELBO surrogate); this estimator builds a different \
                 surrogate and must stay on the dynamic path",
            ));
        }
        let value_id = nodes[loss_id].parents[0];
        if nodes[value_id].value.numel() != 1 {
            return Err(Error::msg("graph compile: ELBO value node is not scalar"));
        }

        // Reverse reachability from the loss — the set of nodes whose
        // adjoints the dynamic backward pass materializes.
        let mut reach = vec![false; nodes.len()];
        reach[loss_id] = true;
        for id in (0..=loss_id).rev() {
            if !reach[id] {
                continue;
            }
            for &p in &nodes[id].parents {
                reach[p] = true;
            }
        }
        for (id, n) in nodes.iter().enumerate() {
            if reach[id] && n.value.rank() > 12 {
                return Err(Error::msg(format!(
                    "graph compile: node {id} has rank {} > the strided-kernel \
                     maximum of 12",
                    n.value.rank()
                )));
            }
        }

        // Lower the event log into the per-step input schedule.
        let mut perm_sizes = Vec::new();
        let mut perm_takes = Vec::new();
        let mut schedule = Vec::new();
        let mut has_select = false;
        for ev in &rec.events {
            match ev {
                TapeEvent::Draw { id, kind } => {
                    if !matches!(nodes[*id].op, Op::Leaf) {
                        return Err(Error::msg(
                            "graph compile: RNG draw recorded against a non-leaf node",
                        ));
                    }
                    schedule.push(StepInput::Fill { id: *id, kind: *kind });
                }
                TapeEvent::Permutation { size, take, vectorized } => {
                    if !vectorized {
                        return Err(Error::msg(
                            "graph compile: sequential plate (`plate_seq`) subsampling \
                             creates per-index site names that change with every draw; \
                             use a vectorized `ctx.plate` instead",
                        ));
                    }
                    let slot = perm_sizes.len();
                    perm_sizes.push(*size);
                    perm_takes.push(*take);
                    schedule.push(StepInput::Perm { slot, size: *size });
                }
                TapeEvent::Select { ptr, source, perm } => {
                    has_select = true;
                    let targets: Vec<usize> = (0..nodes.len())
                        .filter(|&i| {
                            matches!(nodes[i].op, Op::Leaf)
                                && nodes[i].value.storage_ptr() == *ptr
                        })
                        .collect();
                    if targets.is_empty() {
                        return Err(Error::msg(
                            "graph compile: a `plate.select` minibatch never reached \
                             the tape as a leaf — lift the selected tensor directly \
                             (reshapes and copies between select and the tape lose \
                             the storage identity the recorder matches on)",
                        ));
                    }
                    let take = *perm_takes.get(*perm).ok_or_else(|| {
                        Error::msg("graph compile: select references an unrecorded permutation")
                    })?;
                    schedule.push(StepInput::Select {
                        targets,
                        source: source.clone(),
                        slot: *perm,
                        take,
                    });
                }
            }
        }
        if has_select && nodes.iter().any(|n| matches!(n.op, Op::GatherLast(_))) {
            return Err(Error::msg(
                "graph compile: subsampled plates combined with discrete-observation \
                 gathers — gather indices are recorded as static data but the \
                 minibatch changes every step, so the compiled kernel would silently \
                 index the wrong rows; this model stays on the dynamic path",
            ));
        }

        // Forward and backward plans.
        let mut scratch = ScratchAlloc(Vec::new());
        let mut fw = Vec::new();
        let mut bw_rev = Vec::new();
        for (id, node) in nodes.iter().enumerate() {
            if !reach[id] || matches!(node.op, Op::Leaf) {
                continue;
            }
            let out_shape = node.value.shape();
            let out_dims = node.value.dims();
            let p = &node.parents;
            let stride_to_out =
                |x: usize| nodes[x].value.shape().broadcast_strides(out_shape);
            let (fwp, bwp) = match &node.op {
                Op::Leaf => unreachable!(),
                Op::Add | Op::Sub | Op::Mul | Op::Div => {
                    let (a, b) = (p[0], p[1]);
                    let (ad, bd) = (nodes[a].value.dims(), nodes[b].value.dims());
                    let zop = match node.op {
                        Op::Add => ZipOp::Add,
                        Op::Sub => ZipOp::Sub,
                        Op::Mul => ZipOp::Mul,
                        _ => ZipOp::Div,
                    };
                    // Edges in parent order (a first), matching the
                    // dynamic closure's accumulation order.
                    let mut edges = Vec::with_capacity(2);
                    match node.op {
                        Op::Add => {
                            edges.push(EdgePlan {
                                parent: a,
                                pre: Pre::G,
                                chain: reduce_chain(out_dims, ad, &mut scratch),
                            });
                            edges.push(EdgePlan {
                                parent: b,
                                pre: Pre::G,
                                chain: reduce_chain(out_dims, bd, &mut scratch),
                            });
                        }
                        Op::Sub => {
                            edges.push(EdgePlan {
                                parent: a,
                                pre: Pre::G,
                                chain: reduce_chain(out_dims, ad, &mut scratch),
                            });
                            let chain = reduce_chain(out_dims, bd, &mut scratch);
                            let buf = if chain.is_empty() {
                                None
                            } else {
                                Some(scratch.alloc(out_dims))
                            };
                            edges.push(EdgePlan { parent: b, pre: Pre::NegG { buf }, chain });
                        }
                        Op::Mul => {
                            edges.push(EdgePlan {
                                parent: a,
                                pre: Pre::MulVal {
                                    other: b,
                                    buf: scratch.alloc(out_dims),
                                    sg: stride_to_out(id),
                                    so: stride_to_out(b),
                                },
                                chain: reduce_chain(out_dims, ad, &mut scratch),
                            });
                            edges.push(EdgePlan {
                                parent: b,
                                pre: Pre::MulVal {
                                    other: a,
                                    buf: scratch.alloc(out_dims),
                                    sg: stride_to_out(id),
                                    so: stride_to_out(a),
                                },
                                chain: reduce_chain(out_dims, bd, &mut scratch),
                            });
                        }
                        _ => {
                            edges.push(EdgePlan {
                                parent: a,
                                pre: Pre::DivVal {
                                    other: b,
                                    buf: scratch.alloc(out_dims),
                                    sg: stride_to_out(id),
                                    so: stride_to_out(b),
                                },
                                chain: reduce_chain(out_dims, ad, &mut scratch),
                            });
                            edges.push(EdgePlan {
                                parent: b,
                                pre: Pre::DivB {
                                    av: a,
                                    bv: b,
                                    t1: scratch.alloc(out_dims),
                                    t2: scratch.alloc(bd),
                                    t3: scratch.alloc(out_dims),
                                    t4: scratch.alloc(out_dims),
                                    sg: stride_to_out(id),
                                    sav: stride_to_out(a),
                                    // t3 = t1 / t2: t1 has out's shape,
                                    // t2 has b's.
                                    st1: stride_to_out(id),
                                    st2: stride_to_out(b),
                                },
                                chain: reduce_chain(out_dims, bd, &mut scratch),
                            });
                        }
                    }
                    (
                        FwPlan::Zip {
                            a,
                            b,
                            op: zop,
                            sa: stride_to_out(a),
                            sb: stride_to_out(b),
                        },
                        BwPlan::Binary { edges },
                    )
                }
                Op::MatMul => {
                    let (a, b) = (p[0], p[1]);
                    let (m, k) = (nodes[a].value.dims()[0], nodes[a].value.dims()[1]);
                    let n = nodes[b].value.dims()[1];
                    (
                        FwPlan::MatMul { a, b },
                        BwPlan::MatMul {
                            av: a,
                            bv: b,
                            tb: scratch.alloc(&[n, k]),
                            ga: scratch.alloc(&[m, k]),
                            ta: scratch.alloc(&[k, m]),
                            gb: scratch.alloc(&[k, n]),
                        },
                    )
                }
                Op::Neg => (FwPlan::Map { a: p[0], kind: MapKind::Neg }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Neg,
                }),
                Op::Exp => (FwPlan::Map { a: p[0], kind: MapKind::Exp }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Exp,
                }),
                Op::Ln => (FwPlan::Map { a: p[0], kind: MapKind::Ln }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Ln,
                }),
                Op::Sqrt => (FwPlan::Map { a: p[0], kind: MapKind::Sqrt }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Sqrt,
                }),
                Op::Square => (FwPlan::Map { a: p[0], kind: MapKind::Square }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Square,
                }),
                Op::Tanh => (FwPlan::Map { a: p[0], kind: MapKind::Tanh }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Tanh,
                }),
                Op::Sigmoid => (FwPlan::Map { a: p[0], kind: MapKind::Sigmoid }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Sigmoid,
                }),
                Op::Relu => (FwPlan::Map { a: p[0], kind: MapKind::Relu }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Relu,
                }),
                Op::Softplus => (FwPlan::Map { a: p[0], kind: MapKind::Softplus }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Softplus,
                }),
                Op::Lgamma => (FwPlan::Map { a: p[0], kind: MapKind::Lgamma }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Lgamma,
                }),
                Op::Abs => (FwPlan::Map { a: p[0], kind: MapKind::Abs }, BwPlan::Unary {
                    parent: p[0],
                    kind: UKind::Abs,
                }),
                Op::GatherLast(idx) => {
                    let last = *nodes[p[0]].value.dims().last().unwrap();
                    (
                        FwPlan::Gather { a: p[0], idx: idx.clone(), last },
                        BwPlan::Scatter { parent: p[0], kind: SKind::Gather(idx.clone()) },
                    )
                }
                Op::AddScalar(s) => (
                    FwPlan::Map { a: p[0], kind: MapKind::AddScalar(*s) },
                    BwPlan::Scatter { parent: p[0], kind: SKind::Flat },
                ),
                Op::MulScalar(s) => (
                    FwPlan::Map { a: p[0], kind: MapKind::MulScalar(*s) },
                    BwPlan::Scatter { parent: p[0], kind: SKind::FlatScale(*s) },
                ),
                Op::NarrowLast(offset, len) => {
                    let last = *nodes[p[0]].value.dims().last().unwrap();
                    (
                        FwPlan::Narrow { a: p[0], offset: *offset, len: *len, last },
                        BwPlan::Scatter {
                            parent: p[0],
                            kind: SKind::Narrow { offset: *offset, len: *len },
                        },
                    )
                }
                Op::Reshape => (
                    FwPlan::CopyFlat { a: p[0] },
                    BwPlan::Scatter { parent: p[0], kind: SKind::Flat },
                ),
                Op::Sum => (
                    FwPlan::SumAll { a: p[0] },
                    BwPlan::Scatter { parent: p[0], kind: SKind::SumAll },
                ),
                Op::SumLast => (
                    FwPlan::SumLast { a: p[0] },
                    BwPlan::Scatter { parent: p[0], kind: SKind::SumLast },
                ),
                Op::Sum0 => (
                    FwPlan::Sum0 { a: p[0] },
                    BwPlan::Scatter { parent: p[0], kind: SKind::Sum0 },
                ),
            };
            fw.push((id, fwp));
            bw_rev.push((id, bwp));
        }
        bw_rev.reverse();

        // Param slots sorted by name — matches the dynamic path's
        // `apply_grads`, which sorts names before stepping the optimizer.
        let mut params: Vec<ParamSlot> = rec
            .leaves
            .iter()
            .map(|(name, id)| {
                let leaf = &nodes[*id];
                if !matches!(leaf.op, Op::Leaf) {
                    return Err(Error::msg(format!(
                        "graph compile: param '{name}' is not a tape leaf"
                    )));
                }
                Ok(ParamSlot {
                    name: name.clone(),
                    id: *id,
                    dims: leaf.value.dims().to_vec(),
                })
            })
            .collect::<Result<_>>()?;
        params.sort_by(|a, b| a.name.cmp(&b.name));

        let mut adj_alloc = reach.clone();
        for slot in &params {
            adj_alloc[slot.id] = true;
        }
        let zero_ids: Vec<usize> = (0..nodes.len()).filter(|&i| reach[i]).collect();

        let fw_total = fw.len();
        let bw_total = bw_rev.iter().map(|(_, p)| bw_units(p)).sum();
        Ok(CompiledProgram {
            init_vals: nodes
                .iter()
                .map(|n| Tensor::new(n.value.to_vec(), n.value.dims().to_vec()))
                .collect(),
            fw,
            bw: bw_rev,
            zero_ids,
            adj_alloc,
            scratch_dims: scratch.0,
            perm_sizes,
            schedule,
            params,
            loss_id,
            value_id,
            skeleton: rec.skeleton.clone(),
            struct_hash: rec.struct_hash,
            store_fp: rec.store_fp,
            fw_total,
            bw_total,
            fw_eliminated: 0,
            bw_eliminated: 0,
        })
    }

    /// Liveness-based dead-code elimination over the backward plan.
    ///
    /// An adjoint buffer `adjs[id]` is *useful* iff it is a parameter
    /// gradient output, or node `id`'s own backward entry is kept (it
    /// propagates `adjs[id]` into some useful parent). Parents always
    /// have smaller tape ids, so one ascending pass computes the fixed
    /// point. Backward entries whose every target adjoint is dead are
    /// removed outright; inside kept [`BwPlan::Binary`] entries, edges
    /// into dead parents are removed individually (each edge owns its
    /// scratch buffers, so siblings are untouched). [`BwPlan::MatMul`]
    /// stages both gradients through shared transposes and is kept
    /// whole. The typical kill: edges accumulating into observed-data
    /// and other constant leaves.
    ///
    /// The pass is bitwise semantics-preserving (pinned by
    /// [`dce_audit`] and the analysis test suite): the forward plan and
    /// input schedule are untouched, so the loss value and the RNG
    /// stream are bit-identical, and every writer into a useful adjoint
    /// is kept — if node `id`'s entry is kept, `adjs[id]` is useful, so
    /// each child entry (or child edge) writing `adjs[id]` survives by
    /// the same criterion, in the original descending order.
    fn eliminate_dead(&mut self) {
        let n = self.init_vals.len();
        let param_ids: std::collections::HashSet<usize> =
            self.params.iter().map(|s| s.id).collect();
        let mut plan_of: Vec<Option<usize>> = vec![None; n];
        for (k, (id, _)) in self.bw.iter().enumerate() {
            plan_of[*id] = Some(k);
        }
        let mut useful = vec![false; n];
        for id in 0..n {
            let kept = plan_of[id]
                .map(|k| bw_parents(&self.bw[k].1).iter().any(|&p| useful[p]))
                .unwrap_or(false);
            useful[id] = param_ids.contains(&id) || kept;
        }
        let old = std::mem::take(&mut self.bw);
        for (id, plan) in old {
            if !bw_parents(&plan).iter().any(|&p| useful[p]) {
                self.bw_eliminated += bw_units(&plan);
                continue;
            }
            let plan = match plan {
                BwPlan::Binary { edges } => {
                    let (live, dead): (Vec<EdgePlan>, Vec<EdgePlan>) =
                        edges.into_iter().partition(|e| useful[e.parent]);
                    self.bw_eliminated += dead.len();
                    BwPlan::Binary { edges: live }
                }
                other => other,
            };
            self.bw.push((id, plan));
        }
    }

    pub(crate) fn dce_counts(&self) -> (usize, usize, usize, usize) {
        (self.fw_total, self.bw_total, self.fw_eliminated, self.bw_eliminated)
    }

    /// Execute one fused forward+backward pass. After this returns,
    /// `arena.adjs[slot.id]` holds the gradient for every param slot and
    /// the return value is the particle's ELBO statistic. Steady-state
    /// allocation-free: every buffer was preallocated by [`Arena::new`].
    pub(crate) fn run_step(&self, arena: &mut Arena, store: &ParamStore, rng: &mut Pcg64) -> f64 {
        // 1. refresh parameter leaves from the store
        for slot in &self.params {
            let src = store.peek_unconstrained(&slot.name).unwrap_or_else(|| {
                panic!(
                    "graph mode: param '{}' vanished despite the fingerprint guard",
                    slot.name
                )
            });
            arena.vals[slot.id].copy_from(src);
        }

        // 2. replay the per-step input schedule in recorded order, so the
        // RNG stream is consumed exactly as the dynamic path would
        for input in &self.schedule {
            match input {
                StepInput::Perm { slot, size } => {
                    rng.permutation_into(*size, &mut arena.perms[*slot]);
                }
                StepInput::Fill { id, kind } => {
                    let t = &mut arena.vals[*id];
                    match kind {
                        DrawKind::StdNormal => t.fill_randn(rng),
                        DrawKind::Uniform => t.fill_rand(rng),
                        DrawKind::UniformOpen => t.fill_uniform_open(rng),
                    }
                }
                StepInput::Select { targets, source, slot, take } => {
                    for &t in targets {
                        source.index_select0_into(&arena.perms[*slot][..*take], &mut arena.vals[t]);
                    }
                }
            }
        }

        // 3. forward sweep (ascending id; parents always precede children)
        for (id, plan) in &self.fw {
            let (head, tail) = arena.vals.split_at_mut(*id);
            let out = &mut tail[0];
            match plan {
                FwPlan::Zip { a, b, op, sa, sb } => match op {
                    ZipOp::Add => head[*a].zip_into_planned(&head[*b], out, sa, sb, |x, y| x + y),
                    ZipOp::Sub => head[*a].zip_into_planned(&head[*b], out, sa, sb, |x, y| x - y),
                    ZipOp::Mul => head[*a].zip_into_planned(&head[*b], out, sa, sb, |x, y| x * y),
                    ZipOp::Div => head[*a].zip_into_planned(&head[*b], out, sa, sb, |x, y| x / y),
                },
                FwPlan::MatMul { a, b } => head[*a].matmul_into(&head[*b], out),
                FwPlan::Map { a, kind } => match kind {
                    MapKind::Neg => head[*a].map_into(out, |v| -v),
                    MapKind::Exp => head[*a].map_into(out, f64::exp),
                    MapKind::Ln => head[*a].map_into(out, f64::ln),
                    MapKind::Sqrt => head[*a].map_into(out, f64::sqrt),
                    MapKind::Square => head[*a].map_into(out, |v| v * v),
                    MapKind::Tanh => head[*a].map_into(out, f64::tanh),
                    MapKind::Sigmoid => head[*a].map_into(out, |v| 1.0 / (1.0 + (-v).exp())),
                    MapKind::Relu => head[*a].map_into(out, |v| v.max(0.0)),
                    MapKind::Softplus => {
                        head[*a].map_into(out, |v| v.max(0.0) + (-v.abs()).exp().ln_1p())
                    }
                    MapKind::Lgamma => head[*a].map_into(out, crate::tensor::lgamma),
                    MapKind::Abs => head[*a].map_into(out, f64::abs),
                    MapKind::AddScalar(s) => {
                        let s = *s;
                        head[*a].map_into(out, move |v| v + s)
                    }
                    MapKind::MulScalar(s) => {
                        let s = *s;
                        head[*a].map_into(out, move |v| v * s)
                    }
                },
                FwPlan::Gather { a, idx, last } => {
                    let sd = head[*a].data();
                    let od = out.data_mut();
                    for (i, &j) in idx.iter().enumerate() {
                        od[i] = sd[i * last + j];
                    }
                }
                FwPlan::Narrow { a, offset, len, last } => {
                    let sd = head[*a].data();
                    let od = out.data_mut();
                    let outer = od.len() / len;
                    for i in 0..outer {
                        od[i * len..(i + 1) * len]
                            .copy_from_slice(&sd[i * last + offset..i * last + offset + len]);
                    }
                }
                FwPlan::CopyFlat { a } => out.copy_from(&head[*a]),
                FwPlan::SumAll { a } => {
                    let s: f64 = head[*a].data().iter().sum();
                    out.data_mut()[0] = s;
                }
                FwPlan::SumLast { a } => head[*a].sum_last_into(out),
                FwPlan::Sum0 { a } => head[*a].sum0_into(out),
            }
        }

        // 4. zero touched adjoints, seed the loss
        for &id in &self.zero_ids {
            arena.adjs[id].data_mut().fill(0.0);
        }
        arena.adjs[self.loss_id].data_mut()[0] = 1.0;

        // 5. backward sweep (descending id — the dynamic pass's order)
        for (id, plan) in &self.bw {
            match plan {
                BwPlan::Unary { parent, kind } => {
                    let (head, tail) = arena.adjs.split_at_mut(*id);
                    unary_accum(
                        &mut head[*parent],
                        &tail[0],
                        &arena.vals[*id],
                        &arena.vals[*parent],
                        *kind,
                    );
                }
                BwPlan::Scatter { parent, kind } => {
                    let (head, tail) = arena.adjs.split_at_mut(*id);
                    scatter_accum(&mut head[*parent], &tail[0], kind);
                }
                BwPlan::Binary { edges } => {
                    for e in edges {
                        run_edge(arena, *id, e);
                    }
                }
                BwPlan::MatMul { av, bv, tb, ga, ta, gb } => {
                    // ga = g @ b^T, accumulated into a's adjoint
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*tb]);
                    arena.vals[*bv].transpose_into(&mut arena.spare);
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*tb]);
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*ga]);
                    arena.adjs[*id].matmul_into(&arena.scratch[*tb], &mut arena.spare);
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*ga]);
                    accum_flat(arena, *av, Src::Scratch(*ga));
                    // gb = a^T @ g, accumulated into b's adjoint
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*ta]);
                    arena.vals[*av].transpose_into(&mut arena.spare);
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*ta]);
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*gb]);
                    arena.scratch[*ta].matmul_into(&arena.adjs[*id], &mut arena.spare);
                    std::mem::swap(&mut arena.spare, &mut arena.scratch[*gb]);
                    accum_flat(arena, *bv, Src::Scratch(*gb));
                }
            }
        }

        arena.vals[self.value_id].item()
    }

    pub(crate) fn param_names(&self) -> impl Iterator<Item = &str> {
        self.params.iter().map(|s| s.name.as_str())
    }

    /// Prove the compiled program against its own recording: run it once
    /// on a fresh arena with the recorded seed and require (a) the RNG to
    /// land exactly on the recorded end state — anything else means some
    /// sampler consumed randomness without being instrumented — and
    /// (b) the ELBO value and every parameter gradient to match the
    /// dynamic oracle.
    pub(crate) fn verify(&self, store: &ParamStore, rec: &Recording, seed: u64) -> Result<()> {
        let mut arena = Arena::new(self);
        let mut rng = Pcg64::new(seed);
        let value = self.run_step(&mut arena, store, &mut rng);
        if rng != rec.rng_end {
            return Err(Error::msg(
                "graph verify: replaying the recorded input schedule left the RNG in \
                 a different state than the dynamic run — some sampler consumed \
                 randomness without being instrumented (a non-reparameterized or \
                 custom sampler?)",
            ));
        }
        if !close(value, rec.value) {
            return Err(Error::msg(format!(
                "graph verify: compiled ELBO value {value} != dynamic {}",
                rec.value
            )));
        }
        for slot in &self.params {
            let idx = rec
                .leaves
                .iter()
                .position(|(n, _)| n == &slot.name)
                .expect("param slot came from rec.leaves");
            let want = &rec.grads[idx];
            let got = &arena.adjs[slot.id];
            if got.numel() != want.numel() {
                return Err(Error::msg(format!(
                    "graph verify: gradient shape mismatch for '{}'",
                    slot.name
                )));
            }
            for (i, (&g, &w)) in got.data().iter().zip(want.data().iter()).enumerate() {
                if !close(g, w) {
                    return Err(Error::msg(format!(
                        "graph verify: gradient mismatch for '{}' at element {i}: \
                         compiled {g} vs dynamic {w}",
                        slot.name
                    )));
                }
            }
        }
        Ok(())
    }

    /// The graph-IR verifier (lint FY012): re-derive, from the flat
    /// plans alone, every structural invariant [`Self::run_step`]
    /// silently assumes and would otherwise violate as a panic, an
    /// out-of-bounds slice, or — worst — a silently wrong gradient:
    ///
    /// * **def-before-use / alias safety** — every forward operand id is
    ///   strictly below its output id (the `split_at_mut(id)` borrow
    ///   puts operands in the head and the output in the tail, so this
    ///   single ordering check covers both properties), and forward ids
    ///   are strictly ascending (a valid topological order);
    /// * **static shape inference** — per-plan element-count and rank
    ///   consistency against the recorded buffer shapes in `init_vals`,
    ///   including matmul conformability and gather/narrow bounds;
    /// * **backward well-formedness** — descending entry order, parent
    ///   ids strictly below the node id, real (non-dummy) adjoint
    ///   buffers for every accumulate target, scratch/stride payloads in
    ///   range and shape-consistent;
    /// * **schedule sanity** — RNG fills and minibatch selects target
    ///   leaves only (never a computed node, which the next forward
    ///   sweep would clobber), permutation slots exist and are large
    ///   enough, select geometry matches `index_select0_into`;
    /// * **interface** — params are distinct sorted-by-name leaf slots
    ///   with adjoint storage, the loss is the negation of the scalar
    ///   value node, and zeroed adjoint ids all have real buffers.
    ///
    /// Runs inside [`Self::compile`] after DCE, so every program that
    /// installs — interactive, graph-mode SVI, or data-parallel — has
    /// passed it.
    pub(crate) fn verify_ir(&self) -> Result<()> {
        let n = self.init_vals.len();
        let numel = |id: usize| self.init_vals[id].numel();
        let rank = |id: usize| self.init_vals[id].dims().len();
        if self.adj_alloc.len() != n {
            return Err(ir_err(format!(
                "adj_alloc covers {} nodes but the arena has {n}",
                self.adj_alloc.len()
            )));
        }
        if self.loss_id >= n || self.value_id >= n {
            return Err(ir_err(format!(
                "loss id {} / value id {} out of range for {n} nodes",
                self.loss_id, self.value_id
            )));
        }
        if numel(self.loss_id) != 1 || numel(self.value_id) != 1 {
            return Err(ir_err("loss and ELBO value nodes must be scalar".into()));
        }
        if !self.adj_alloc[self.loss_id] {
            return Err(ir_err("loss node has no adjoint buffer to seed".into()));
        }

        // ---- forward sweep ----
        let mut is_fw_out = vec![false; n];
        let mut prev: Option<usize> = None;
        for (id, plan) in &self.fw {
            let id = *id;
            if id >= n {
                return Err(ir_err(format!("forward output id {id} out of range")));
            }
            if prev.is_some_and(|p| p >= id) {
                return Err(ir_err(format!(
                    "forward plan ids not strictly ascending at node {id} — \
                     the sweep is not a topological order"
                )));
            }
            prev = Some(id);
            is_fw_out[id] = true;
            let operands: Vec<usize> = match plan {
                FwPlan::Zip { a, b, .. } | FwPlan::MatMul { a, b } => vec![*a, *b],
                FwPlan::Map { a, .. }
                | FwPlan::Gather { a, .. }
                | FwPlan::Narrow { a, .. }
                | FwPlan::CopyFlat { a }
                | FwPlan::SumAll { a }
                | FwPlan::SumLast { a }
                | FwPlan::Sum0 { a } => vec![*a],
            };
            for &a in &operands {
                if a >= id {
                    return Err(ir_err(format!(
                        "node {id}: operand {a} does not strictly precede its output — \
                         def-before-use/alias safety of the split-borrow sweep is broken"
                    )));
                }
            }
            match plan {
                FwPlan::Zip { a, b, sa, sb, .. } => {
                    if sa.len() != rank(id) || sb.len() != rank(id) {
                        return Err(ir_err(format!(
                            "node {id}: broadcast stride ranks ({}, {}) do not match \
                             the output rank {}",
                            sa.len(),
                            sb.len(),
                            rank(id)
                        )));
                    }
                    let _ = (a, b);
                }
                FwPlan::MatMul { a, b } => {
                    let (ad, bd) = (self.init_vals[*a].dims(), self.init_vals[*b].dims());
                    let od = self.init_vals[id].dims();
                    if ad.len() != 2
                        || bd.len() != 2
                        || od.len() != 2
                        || ad[1] != bd[0]
                        || od != [ad[0], bd[1]]
                    {
                        return Err(ir_err(format!(
                            "node {id}: matmul shapes {ad:?} @ {bd:?} -> {od:?} \
                             are not conformable"
                        )));
                    }
                }
                FwPlan::Map { a, .. } | FwPlan::CopyFlat { a } => {
                    if numel(*a) != numel(id) {
                        return Err(ir_err(format!(
                            "node {id}: elementwise plan over {} input elements but \
                             {} output elements",
                            numel(*a),
                            numel(id)
                        )));
                    }
                }
                FwPlan::Gather { a, idx, last } => {
                    if *last == 0
                        || numel(id) != idx.len()
                        || numel(*a) != idx.len() * last
                        || idx.iter().any(|&j| j >= *last)
                    {
                        return Err(ir_err(format!(
                            "node {id}: gather geometry (rows {}, last {last}) is \
                             inconsistent with buffers of {} -> {} elements",
                            idx.len(),
                            numel(*a),
                            numel(id)
                        )));
                    }
                }
                FwPlan::Narrow { a, offset, len, last } => {
                    let ok = *len > 0
                        && numel(id) % len == 0
                        && offset + len <= *last
                        && numel(*a) == (numel(id) / len) * last;
                    if !ok {
                        return Err(ir_err(format!(
                            "node {id}: narrow [{offset}..{}] of last dim {last} is \
                             inconsistent with buffers of {} -> {} elements",
                            offset + len,
                            numel(*a),
                            numel(id)
                        )));
                    }
                }
                FwPlan::SumAll { a } => {
                    if numel(id) != 1 {
                        return Err(ir_err(format!("node {id}: sum-all output is not scalar")));
                    }
                    let _ = a;
                }
                FwPlan::SumLast { a } => {
                    let l = self.init_vals[*a].dims().last().copied().unwrap_or(1);
                    if l == 0 || numel(id) * l != numel(*a) {
                        return Err(ir_err(format!(
                            "node {id}: sum-last over last dim {l} does not map {} \
                             elements onto {}",
                            numel(*a),
                            numel(id)
                        )));
                    }
                }
                FwPlan::Sum0 { a } => {
                    let d0 = self.init_vals[*a].dims().first().copied().unwrap_or(1);
                    if d0 == 0 || numel(id) * d0 != numel(*a) {
                        return Err(ir_err(format!(
                            "node {id}: sum-axis0 over leading dim {d0} does not map \
                             {} elements onto {}",
                            numel(*a),
                            numel(id)
                        )));
                    }
                }
            }
        }
        // The loss must still be the final negation of the value node —
        // validated against the recording at lowering time, re-derived
        // here from the IR alone.
        match self.fw.iter().find(|(id, _)| *id == self.loss_id) {
            Some((_, FwPlan::Map { a, kind: MapKind::Neg })) if *a == self.value_id => {}
            _ => {
                return Err(ir_err(
                    "loss node is not a negation of the ELBO value node".into(),
                ))
            }
        }

        // ---- backward sweep ----
        let scr = |i: usize| -> Result<&Vec<usize>> {
            self.scratch_dims
                .get(i)
                .ok_or_else(|| ir_err(format!("scratch index {i} out of range")))
        };
        let check_chain = |chain: &[Red], parent: usize, src_numel: usize| -> Result<()> {
            let mut cur = src_numel;
            for red in chain {
                cur = scr(red.buf)?.iter().product::<usize>().max(1);
            }
            if cur != numel(parent) {
                return Err(ir_err(format!(
                    "reduction chain delivers {cur} elements into a parent adjoint \
                     of {} (node {parent})",
                    numel(parent)
                )));
            }
            Ok(())
        };
        let mut prev_bw: Option<usize> = None;
        for (id, plan) in &self.bw {
            let id = *id;
            if id >= n || !is_fw_out[id] {
                return Err(ir_err(format!(
                    "backward entry for node {id} which no forward plan computes"
                )));
            }
            if prev_bw.is_some_and(|p| p <= id) {
                return Err(ir_err(format!(
                    "backward plan ids not strictly descending at node {id}"
                )));
            }
            prev_bw = Some(id);
            for &parent in &bw_parents(plan) {
                if parent >= id {
                    return Err(ir_err(format!(
                        "node {id}: backward parent {parent} does not strictly \
                         precede the node — the adjoint split-borrow is broken"
                    )));
                }
                if !self.adj_alloc[parent] {
                    return Err(ir_err(format!(
                        "node {id}: backward accumulates into parent {parent} which \
                         has only a dummy adjoint buffer"
                    )));
                }
            }
            match plan {
                BwPlan::Unary { parent, .. } => {
                    if numel(*parent) != numel(id) {
                        return Err(ir_err(format!(
                            "node {id}: unary backward parent has {} elements, \
                             output adjoint {}",
                            numel(*parent),
                            numel(id)
                        )));
                    }
                }
                BwPlan::Scatter { parent, kind } => {
                    let (pn, gn) = (numel(*parent), numel(id));
                    let ok = match kind {
                        SKind::Flat | SKind::FlatScale(_) => pn == gn,
                        SKind::SumAll => gn == 1,
                        SKind::SumLast | SKind::Sum0 => gn > 0 && pn % gn == 0,
                        SKind::Gather(idx) => {
                            !idx.is_empty()
                                && gn == idx.len()
                                && pn % idx.len() == 0
                                && idx.iter().all(|&j| j < pn / idx.len())
                        }
                        SKind::Narrow { offset, len } => {
                            *len > 0
                                && gn % len == 0
                                && pn % (gn / len).max(1) == 0
                                && offset + len <= pn / (gn / len).max(1)
                        }
                    };
                    if !ok {
                        return Err(ir_err(format!(
                            "node {id}: scatter backward {kind:?} is inconsistent \
                             with buffers of {gn} -> {pn} elements"
                        )));
                    }
                }
                BwPlan::Binary { edges } => {
                    for e in edges {
                        let src_numel = match &e.pre {
                            Pre::G => numel(id),
                            Pre::NegG { buf: None } => {
                                if !e.chain.is_empty() || numel(e.parent) != numel(id) {
                                    return Err(ir_err(format!(
                                        "node {id}: fused negation edge requires an \
                                         empty reduction chain and equal extents"
                                    )));
                                }
                                continue;
                            }
                            Pre::NegG { buf: Some(buf) } => {
                                let bn = scr(*buf)?.iter().product::<usize>().max(1);
                                if bn != numel(id) {
                                    return Err(ir_err(format!(
                                        "node {id}: negation staging buffer holds {bn} \
                                         elements, the output adjoint {}",
                                        numel(id)
                                    )));
                                }
                                bn
                            }
                            Pre::MulVal { other, buf, sg, so }
                            | Pre::DivVal { other, buf, sg, so } => {
                                if *other >= id {
                                    return Err(ir_err(format!(
                                        "node {id}: binary edge reads co-parent value \
                                         {other} which does not precede the node"
                                    )));
                                }
                                if sg.len() != rank(id) || so.len() != rank(id) {
                                    return Err(ir_err(format!(
                                        "node {id}: edge stride ranks do not match the \
                                         output rank {}",
                                        rank(id)
                                    )));
                                }
                                let bn = scr(*buf)?.iter().product::<usize>().max(1);
                                if bn != numel(id) {
                                    return Err(ir_err(format!(
                                        "node {id}: edge staging buffer holds {bn} \
                                         elements, the output adjoint {}",
                                        numel(id)
                                    )));
                                }
                                bn
                            }
                            Pre::DivB { av, bv, t1, t2, t3, t4, sg, sav, st1, st2 } => {
                                if *av >= id || *bv >= id {
                                    return Err(ir_err(format!(
                                        "node {id}: division backward reads operand \
                                         values that do not precede the node"
                                    )));
                                }
                                if [sg, sav, st1, st2].iter().any(|s| s.len() != rank(id)) {
                                    return Err(ir_err(format!(
                                        "node {id}: division edge stride ranks do not \
                                         match the output rank {}",
                                        rank(id)
                                    )));
                                }
                                for (buf, want) in
                                    [(t1, numel(id)), (t2, numel(*bv)), (t3, numel(id))]
                                {
                                    if scr(*buf)?.iter().product::<usize>().max(1) != want {
                                        return Err(ir_err(format!(
                                            "node {id}: division staging buffer has the \
                                             wrong extent"
                                        )));
                                    }
                                }
                                scr(*t4)?.iter().product::<usize>().max(1)
                            }
                        };
                        check_chain(&e.chain, e.parent, src_numel)?;
                    }
                }
                BwPlan::MatMul { av, bv, tb, ga, ta, gb } => {
                    let (ad, bd) = (self.init_vals[*av].dims(), self.init_vals[*bv].dims());
                    let (m, k) = (ad[0], ad[1]);
                    let nn = bd[1];
                    for (buf, want) in [
                        (tb, [nn, k]),
                        (ga, [m, k]),
                        (ta, [k, m]),
                        (gb, [k, nn]),
                    ] {
                        if scr(*buf)?.as_slice() != want {
                            return Err(ir_err(format!(
                                "node {id}: matmul backward scratch has shape {:?}, \
                                 expected {want:?}",
                                scr(*buf)?
                            )));
                        }
                    }
                }
            }
        }

        // ---- input schedule ----
        for input in &self.schedule {
            match input {
                StepInput::Perm { slot, size } => {
                    if self.perm_sizes.get(*slot) != Some(size) {
                        return Err(ir_err(format!(
                            "permutation slot {slot} missing or of the wrong size"
                        )));
                    }
                }
                StepInput::Fill { id, .. } => {
                    if *id >= n || is_fw_out[*id] {
                        return Err(ir_err(format!(
                            "RNG fill targets node {id}, which is not a leaf — the \
                             forward sweep would clobber the draw"
                        )));
                    }
                }
                StepInput::Select { targets, source, slot, take } => {
                    let Some(&size) = self.perm_sizes.get(*slot) else {
                        return Err(ir_err(format!(
                            "select references unknown permutation slot {slot}"
                        )));
                    };
                    let rows = source.dims().first().copied().unwrap_or(0);
                    if *take > size || size > rows || rows == 0 {
                        return Err(ir_err(format!(
                            "select takes {take} of a {size}-permutation over a \
                             {rows}-row source"
                        )));
                    }
                    let stride: usize = source.dims()[1..].iter().product();
                    for &t in targets {
                        if t >= n || is_fw_out[t] || numel(t) != take * stride {
                            return Err(ir_err(format!(
                                "select target {t} is not a leaf of {} elements",
                                take * stride
                            )));
                        }
                    }
                }
            }
        }

        // ---- parameter interface ----
        for w in self.params.windows(2) {
            if w[0].name >= w[1].name {
                return Err(ir_err(
                    "param slots are not strictly sorted by name — optimizer \
                     application order would diverge from the dynamic path"
                        .into(),
                ));
            }
        }
        for slot in &self.params {
            if slot.id >= n || is_fw_out[slot.id] {
                return Err(ir_err(format!(
                    "param '{}' slot {} is not a leaf node",
                    slot.name, slot.id
                )));
            }
            if !self.adj_alloc[slot.id] {
                return Err(ir_err(format!(
                    "param '{}' has no adjoint buffer to read its gradient from",
                    slot.name
                )));
            }
            if slot.dims != self.init_vals[slot.id].dims() {
                return Err(ir_err(format!(
                    "param '{}' slot dims {:?} disagree with the recorded buffer {:?}",
                    slot.name,
                    slot.dims,
                    self.init_vals[slot.id].dims()
                )));
            }
        }
        for &id in &self.zero_ids {
            if id >= n || !self.adj_alloc[id] {
                return Err(ir_err(format!(
                    "zeroed adjoint id {id} is out of range or has no buffer"
                )));
            }
        }
        Ok(())
    }
}

/// FY012 is the lint code reserved for graph-IR verifier failures —
/// see [`crate::analysis::LintCode::IrVerifier`].
fn ir_err(msg: String) -> Error {
    Error::msg(format!("[FY012] graph-ir verify: {msg}"))
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
}

// ------------------------------------------------------------------ arena

/// Per-particle mutable state: one preallocated buffer per tape node
/// value and adjoint, reduction scratch, permutation index buffers, and
/// a spare tensor that scratch buffers are swapped through during writes
/// (disjoint-field borrows instead of clones — `Tensor::clone` would
/// allocate a `Shape`).
pub(crate) struct Arena {
    vals: Vec<Tensor>,
    adjs: Vec<Tensor>,
    scratch: Vec<Tensor>,
    perms: Vec<Vec<usize>>,
    spare: Tensor,
    /// The last step's ELBO statistic (written by `GraphRunner` workers,
    /// read back in particle order for the combine).
    value: f64,
}

impl Arena {
    pub(crate) fn new(prog: &CompiledProgram) -> Arena {
        Arena {
            // Deep copies (fresh backing storage, unique Arcs): constants
            // keep their recorded values forever; no copy-on-write can
            // ever trigger in the hot loop.
            vals: prog
                .init_vals
                .iter()
                .map(|t| Tensor::new(t.to_vec(), t.dims().to_vec()))
                .collect(),
            adjs: prog
                .init_vals
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    if prog.adj_alloc[i] {
                        Tensor::zeros(t.dims().to_vec())
                    } else {
                        Tensor::scalar(0.0)
                    }
                })
                .collect(),
            scratch: prog.scratch_dims.iter().map(|d| Tensor::zeros(d.clone())).collect(),
            perms: prog.perm_sizes.iter().map(|&n| Vec::with_capacity(n)).collect(),
            spare: Tensor::scalar(0.0),
            value: 0.0,
        }
    }
}

// ------------------------------------------------------ backward helpers

fn resolve<'a>(vals: &'a [Tensor], adjs: &'a [Tensor], scratch: &'a [Tensor], s: Src) -> &'a Tensor {
    match s {
        Src::Val(i) => &vals[i],
        Src::Adj(i) => &adjs[i],
        Src::Scratch(i) => &scratch[i],
    }
}

fn zip_into_scratch(
    arena: &mut Arena,
    buf: usize,
    a: Src,
    b: Src,
    sa: &[usize],
    sb: &[usize],
    f: impl Fn(f64, f64) -> f64,
) {
    std::mem::swap(&mut arena.spare, &mut arena.scratch[buf]);
    {
        let ta = resolve(&arena.vals, &arena.adjs, &arena.scratch, a);
        let tb = resolve(&arena.vals, &arena.adjs, &arena.scratch, b);
        ta.zip_into_planned(tb, &mut arena.spare, sa, sb, f);
    }
    std::mem::swap(&mut arena.spare, &mut arena.scratch[buf]);
}

fn map_into_scratch(arena: &mut Arena, buf: usize, a: Src, f: impl Fn(f64) -> f64) {
    std::mem::swap(&mut arena.spare, &mut arena.scratch[buf]);
    {
        let ta = resolve(&arena.vals, &arena.adjs, &arena.scratch, a);
        ta.map_into(&mut arena.spare, f);
    }
    std::mem::swap(&mut arena.spare, &mut arena.scratch[buf]);
}

/// `sum_axis_keepdim` into a preallocated buffer — identical
/// zero-then-accumulate order, zero allocations.
fn sum_axis_keepdim_into(src: &Tensor, axis: usize, out: &mut Tensor) {
    let dims = src.dims();
    let outer: usize = dims[..axis].iter().product();
    let mid = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let data = src.data();
    let od = out.data_mut();
    od.fill(0.0);
    for o in 0..outer {
        for m in 0..mid {
            let base = (o * mid + m) * inner;
            for i in 0..inner {
                od[o * inner + i] += data[base + i];
            }
        }
    }
}

fn reduce_into_scratch(arena: &mut Arena, red: &Red, src: Src) {
    std::mem::swap(&mut arena.spare, &mut arena.scratch[red.buf]);
    {
        let t = resolve(&arena.vals, &arena.adjs, &arena.scratch, src);
        match red.axis {
            None => t.sum0_into(&mut arena.spare),
            Some(axis) => sum_axis_keepdim_into(t, axis, &mut arena.spare),
        }
    }
    std::mem::swap(&mut arena.spare, &mut arena.scratch[red.buf]);
}

/// Flat equal-numel accumulate of a gradient source into a parent
/// adjoint. (Not `add_assign`: the reduced gradient can legitimately
/// have shape `[1, 3]` against a `[3]` parent — equal numel, different
/// shape — which `zip_assign`'s broadcast assert rejects.)
fn accum_flat(arena: &mut Arena, parent: usize, src: Src) {
    match src {
        Src::Adj(i) => {
            // Parents always precede children on the tape.
            let (head, tail) = arena.adjs.split_at_mut(i);
            let pd = head[parent].data_mut();
            let gd = tail[0].data();
            for k in 0..pd.len() {
                pd[k] += gd[k];
            }
        }
        Src::Scratch(i) => {
            let gd = arena.scratch[i].data();
            let pd = arena.adjs[parent].data_mut();
            for k in 0..pd.len() {
                pd[k] += gd[k];
            }
        }
        Src::Val(_) => unreachable!("node values are never gradient sources"),
    }
}

/// Fused unary backward: `p[i] += f(g[i], out[i], a[i])`, with `f`
/// matching the dynamic backward closure's arithmetic per element.
fn unary_accum(p: &mut Tensor, g: &Tensor, o: &Tensor, a: &Tensor, kind: UKind) {
    let gd = g.data();
    let od = o.data();
    let ad = a.data();
    let pd = p.data_mut();
    match kind {
        UKind::Neg => {
            for i in 0..pd.len() {
                pd[i] += -gd[i];
            }
        }
        UKind::Exp => {
            for i in 0..pd.len() {
                pd[i] += gd[i] * od[i];
            }
        }
        UKind::Ln => {
            for i in 0..pd.len() {
                pd[i] += gd[i] / ad[i];
            }
        }
        UKind::Sqrt => {
            for i in 0..pd.len() {
                pd[i] += gd[i] / (od[i] * 2.0);
            }
        }
        UKind::Square => {
            for i in 0..pd.len() {
                pd[i] += (gd[i] * ad[i]) * 2.0;
            }
        }
        UKind::Tanh => {
            for i in 0..pd.len() {
                pd[i] += gd[i] * (-(od[i] * od[i]) + 1.0);
            }
        }
        UKind::Sigmoid => {
            for i in 0..pd.len() {
                pd[i] += gd[i] * (od[i] * (-od[i] + 1.0));
            }
        }
        UKind::Relu => {
            for i in 0..pd.len() {
                pd[i] += gd[i] * if ad[i] > 0.0 { 1.0 } else { 0.0 };
            }
        }
        UKind::Softplus => {
            for i in 0..pd.len() {
                pd[i] += gd[i] * (1.0 / (1.0 + (-ad[i]).exp()));
            }
        }
        UKind::Lgamma => {
            for i in 0..pd.len() {
                pd[i] += gd[i] * crate::tensor::digamma(ad[i]);
            }
        }
        UKind::Abs => {
            for i in 0..pd.len() {
                let s = if ad[i] > 0.0 {
                    1.0
                } else if ad[i] < 0.0 {
                    -1.0
                } else {
                    0.0
                };
                pd[i] += gd[i] * s;
            }
        }
    }
}

/// Shape-moving backward: scatter/broadcast `g` into `p`. Geometry is
/// derived from the two buffer lengths (the compile-time shapes made
/// them consistent).
fn scatter_accum(p: &mut Tensor, g: &Tensor, kind: &SKind) {
    let gd = g.data();
    let pd = p.data_mut();
    match kind {
        SKind::Flat => {
            for i in 0..pd.len() {
                pd[i] += gd[i];
            }
        }
        SKind::FlatScale(s) => {
            for i in 0..pd.len() {
                pd[i] += gd[i] * s;
            }
        }
        SKind::SumAll => {
            let g0 = gd[0];
            for v in pd.iter_mut() {
                *v += g0;
            }
        }
        SKind::SumLast => {
            let last = pd.len() / gd.len();
            for (o, &gv) in gd.iter().enumerate() {
                for j in 0..last {
                    pd[o * last + j] += gv;
                }
            }
        }
        SKind::Sum0 => {
            let inner = gd.len();
            let n0 = pd.len() / inner;
            for r in 0..n0 {
                for i in 0..inner {
                    pd[r * inner + i] += gd[i];
                }
            }
        }
        SKind::Gather(idx) => {
            let last = pd.len() / idx.len();
            for (i, &j) in idx.iter().enumerate() {
                pd[i * last + j] += gd[i];
            }
        }
        SKind::Narrow { offset, len } => {
            let outer = gd.len() / len;
            let last = pd.len() / outer;
            for i in 0..outer {
                for j in 0..*len {
                    pd[i * last + offset + j] += gd[i * len + j];
                }
            }
        }
    }
}

/// One binary-op edge: stage the pre-reduction gradient, run the
/// broadcast-reduction chain, accumulate into the parent adjoint.
fn run_edge(arena: &mut Arena, id: usize, edge: &EdgePlan) {
    let mut src = match &edge.pre {
        Pre::G => Src::Adj(id),
        Pre::NegG { buf: None } => {
            // No reduction follows — fuse the negation into the add.
            let (head, tail) = arena.adjs.split_at_mut(id);
            let pd = head[edge.parent].data_mut();
            let gd = tail[0].data();
            for k in 0..pd.len() {
                pd[k] += -gd[k];
            }
            return;
        }
        Pre::NegG { buf: Some(buf) } => {
            map_into_scratch(arena, *buf, Src::Adj(id), |v| -v);
            Src::Scratch(*buf)
        }
        Pre::MulVal { other, buf, sg, so } => {
            zip_into_scratch(arena, *buf, Src::Adj(id), Src::Val(*other), sg, so, |x, y| x * y);
            Src::Scratch(*buf)
        }
        Pre::DivVal { other, buf, sg, so } => {
            zip_into_scratch(arena, *buf, Src::Adj(id), Src::Val(*other), sg, so, |x, y| x / y);
            Src::Scratch(*buf)
        }
        Pre::DivB { av, bv, t1, t2, t3, t4, sg, sav, st1, st2 } => {
            // -(g * a) / (b * b), staged like the dynamic closure.
            zip_into_scratch(arena, *t1, Src::Adj(id), Src::Val(*av), sg, sav, |x, y| x * y);
            zip_into_scratch(arena, *t2, Src::Val(*bv), Src::Val(*bv), &[], &[], |x, y| x * y);
            zip_into_scratch(
                arena,
                *t3,
                Src::Scratch(*t1),
                Src::Scratch(*t2),
                st1,
                st2,
                |x, y| x / y,
            );
            map_into_scratch(arena, *t4, Src::Scratch(*t3), |v| -v);
            Src::Scratch(*t4)
        }
    };
    for red in &edge.chain {
        reduce_into_scratch(arena, red, src);
        src = Src::Scratch(red.buf);
    }
    accum_flat(arena, edge.parent, src);
}

// ----------------------------------------------------------------- runner

/// Executes an installed [`CompiledProgram`] across particles with the
/// exact merge arithmetic of the dynamic `Svi::step`: per-particle
/// seeds drawn up front, gradients summed in particle-index order, the
/// uniform 1/n weight applied once, optimizer updates in name order.
/// Parallel execution (scoped threads over private arenas) is therefore
/// bitwise equal to serial execution for a given seed.
pub(crate) struct GraphRunner {
    prog: CompiledProgram,
    arenas: Vec<Arena>,
    merged: Vec<Tensor>,
    seeds: Vec<u64>,
}

impl GraphRunner {
    pub(crate) fn new(prog: CompiledProgram) -> GraphRunner {
        GraphRunner { prog, arenas: Vec::new(), merged: Vec::new(), seeds: Vec::new() }
    }

    pub(crate) fn prog(&self) -> &CompiledProgram {
        &self.prog
    }

    fn ensure(&mut self, n: usize) {
        if self.arenas.len() != n {
            self.arenas = (0..n).map(|_| Arena::new(&self.prog)).collect();
            self.merged = self
                .prog
                .params
                .iter()
                .map(|s| Tensor::zeros(s.dims.clone()))
                .collect();
        }
    }

    /// One full compiled SVI step. Returns the reported loss (−mean
    /// ELBO over particles).
    pub(crate) fn step<O: Optimizer>(
        &mut self,
        store: &mut ParamStore,
        rng: &mut Pcg64,
        opt: &mut O,
        config: &SviConfig,
    ) -> f64 {
        let n = config.num_particles.max(1);
        self.ensure(n);
        self.seeds.clear();
        for _ in 0..n {
            let s = rng.next_u64();
            self.seeds.push(s);
        }
        let threads = config.effective_threads(n);
        let prog = &self.prog;
        let shared: &ParamStore = store;
        if threads <= 1 || n <= 1 {
            for (arena, &seed) in self.arenas.iter_mut().zip(&self.seeds) {
                arena.value = prog.run_step(arena, shared, &mut Pcg64::new(seed));
            }
        } else {
            let chunk = n.div_ceil(threads);
            let seeds = &self.seeds;
            std::thread::scope(|scope| {
                for (achunk, schunk) in self.arenas.chunks_mut(chunk).zip(seeds.chunks(chunk)) {
                    scope.spawn(move || {
                        for (arena, &seed) in achunk.iter_mut().zip(schunk) {
                            arena.value = prog.run_step(arena, shared, &mut Pcg64::new(seed));
                        }
                    });
                }
            });
        }

        // Uniform Monte-Carlo combine — the only combine compilable
        // estimators use (compile rejects anything with a custom one).
        let mean = self.arenas.iter().map(|a| a.value).sum::<f64>() / n as f64;
        let loss = -mean;

        // Merge gradients in particle order, then the single 1/n scale —
        // the dynamic uniform-weight path's exact arithmetic.
        let w = 1.0 / n as f64;
        for (k, slot) in self.prog.params.iter().enumerate() {
            let merged = &mut self.merged[k];
            merged.copy_from(&self.arenas[0].adjs[slot.id]);
            for arena in &self.arenas[1..] {
                let gd = arena.adjs[slot.id].data();
                let md = merged.data_mut();
                for i in 0..md.len() {
                    md[i] += gd[i];
                }
            }
            if w != 1.0 {
                merged.scale_inplace(w);
            }
        }

        // Optimizer application in name order (params are pre-sorted).
        for (k, slot) in self.prog.params.iter().enumerate() {
            let g = &self.merged[k];
            store.update_unconstrained(&slot.name, |p| opt.step_inplace(&slot.name, p, g));
        }
        opt.finish_step();
        loss
    }
}

// ------------------------------------------------- data-parallel sharing

/// Locate every tape node whose value **is** one of the driver-owned
/// minibatch view tensors (shared storage — `ctx.observe` and `ctx.c`
/// put the caller's tensor on the tape as a leaf constant without
/// copying). Returns `(view_index, node_id)` pairs; one view may back
/// several nodes (e.g. observed in the model AND lifted as guide
/// input), and a compiled data-parallel step must refresh all of them.
pub(crate) fn data_slots(rec: &Recording, views: &[Tensor]) -> Result<Vec<(usize, usize)>> {
    let mut slots = Vec::new();
    for (v, view) in views.iter().enumerate() {
        let ptr = view.storage_ptr();
        let before = slots.len();
        for (id, node) in rec.nodes.iter().enumerate() {
            if node.value.storage_ptr() == ptr {
                if node.value.numel() != view.numel() {
                    return Err(Error::msg(format!(
                        "graph compile: minibatch view {v} reached the tape with {} elements \
                         but the driver tensor has {} — partial views of a batch tensor \
                         cannot be refreshed; pass each slice as its own view",
                        node.value.numel(),
                        view.numel()
                    )));
                }
                slots.push((v, id));
            }
        }
        if slots.len() == before {
            return Err(Error::msg(format!(
                "graph compile: minibatch view {v} never reached the tape — data-parallel \
                 graph mode requires the model/guide to observe (or lift via ctx.c) each \
                 driver-provided view tensor directly, not a derived copy, so compiled \
                 steps can refresh the data in place"
            )));
        }
    }
    Ok(slots)
}

/// One compiled program shared by W data-parallel workers: compile
/// once, give every worker a private [`Arena`], and each step (a)
/// refresh the worker's minibatch view nodes from freshly-gathered
/// data, (b) run the straight-line kernel with the worker's seeded
/// RNG, (c) merge gradients **in shard order** with a single final
/// `1/W` scale — the same arithmetic as the dynamic shard merge, so
/// thread count never changes results.
pub(crate) struct ShardRunner {
    prog: CompiledProgram,
    slots: Vec<(usize, usize)>,
    arenas: Vec<Arena>,
    merged: Vec<Tensor>,
}

impl ShardRunner {
    /// `views` are the driver-owned view tensors the recording was made
    /// against (worker 0's batch buffers).
    pub(crate) fn new(
        prog: CompiledProgram,
        rec: &Recording,
        views: &[Tensor],
    ) -> Result<ShardRunner> {
        let slots = data_slots(rec, views)?;
        Ok(ShardRunner { prog, slots, arenas: Vec::new(), merged: Vec::new() })
    }

    pub(crate) fn prog(&self) -> &CompiledProgram {
        &self.prog
    }

    fn ensure(&mut self, w: usize) {
        if self.arenas.len() != w {
            self.arenas = (0..w).map(|_| Arena::new(&self.prog)).collect();
            self.merged = self
                .prog
                .params
                .iter()
                .map(|s| Tensor::zeros(s.dims.clone()))
                .collect();
        }
    }

    /// One data-parallel compiled step. `views[w]` holds worker w's
    /// freshly-gathered batch (same layout as the recording views),
    /// `seeds[w]` its pre-drawn particle seed. Returns the mean shard
    /// loss (−mean ELBO), bitwise-invariant in `threads`.
    pub(crate) fn step<O: Optimizer>(
        &mut self,
        store: &mut ParamStore,
        seeds: &[u64],
        views: &[&[Tensor]],
        threads: usize,
        opt: &mut O,
    ) -> f64 {
        let w = seeds.len();
        assert_eq!(views.len(), w, "one view set per worker");
        self.ensure(w);
        let prog = &self.prog;
        let slots = &self.slots;
        let shared: &ParamStore = store;
        let run = |arena: &mut Arena, seed: u64, v: &[Tensor]| {
            for &(vi, id) in slots {
                arena.vals[id].data_mut().copy_from_slice(v[vi].data());
            }
            arena.value = prog.run_step(arena, shared, &mut Pcg64::new(seed));
        };
        if threads <= 1 || w <= 1 {
            for ((arena, &seed), v) in self.arenas.iter_mut().zip(seeds).zip(views) {
                run(arena, seed, v);
            }
        } else {
            let chunk = w.div_ceil(threads);
            std::thread::scope(|scope| {
                for ((achunk, schunk), vchunk) in self
                    .arenas
                    .chunks_mut(chunk)
                    .zip(seeds.chunks(chunk))
                    .zip(views.chunks(chunk))
                {
                    let run = &run;
                    scope.spawn(move || {
                        for ((arena, &seed), v) in achunk.iter_mut().zip(schunk).zip(vchunk) {
                            run(arena, seed, v);
                        }
                    });
                }
            });
        }

        let mean = self.arenas.iter().map(|a| a.value).sum::<f64>() / w as f64;
        let loss = -mean;

        // Shard-order merge, single 1/W scale — the dynamic uniform
        // combine's exact arithmetic.
        let scale = 1.0 / w as f64;
        for (k, slot) in self.prog.params.iter().enumerate() {
            let merged = &mut self.merged[k];
            merged.copy_from(&self.arenas[0].adjs[slot.id]);
            for arena in &self.arenas[1..] {
                let gd = arena.adjs[slot.id].data();
                let md = merged.data_mut();
                for i in 0..md.len() {
                    md[i] += gd[i];
                }
            }
            if scale != 1.0 {
                merged.scale_inplace(scale);
            }
        }
        for (k, slot) in self.prog.params.iter().enumerate() {
            let g = &self.merged[k];
            store.update_unconstrained(&slot.name, |p| opt.step_inplace(&slot.name, p, g));
        }
        opt.finish_step();
        loss
    }
}

// ------------------------------------------------------------- DCE audit

/// Outcome of [`dce_audit`]: the dead-code-elimination instruction
/// accounting plus the bitwise-equivalence verdict of the pruned
/// program against the unpruned lowering.
#[derive(Clone, Copy, Debug)]
pub struct DceAudit {
    /// Forward instructions (already pruned to loss-reachable nodes at
    /// lowering time, so none are ever DCE-eliminated).
    pub fw_total: usize,
    /// Backward instructions before DCE (one per accumulate target).
    pub bw_total: usize,
    /// Always 0 — see `fw_total`; kept explicit for bench output.
    pub fw_eliminated: usize,
    /// Backward instructions removed by the liveness pass.
    pub bw_eliminated: usize,
    /// Loss value, every parameter gradient, and the RNG end state were
    /// bit-for-bit identical between pruned and unpruned programs on
    /// every audited step.
    pub bitwise_match: bool,
}

impl DceAudit {
    /// Serde-free JSON rendering for bench records
    /// (`BENCH_fig3.json["analysis"]`).
    pub fn to_json(&self) -> crate::benchkit::json::JsonObj {
        crate::benchkit::json::JsonObj::new()
            .int("fw_total", self.fw_total)
            .int("bw_total", self.bw_total)
            .int("fw_eliminated", self.fw_eliminated)
            .int("bw_eliminated", self.bw_eliminated)
            .bool("dce_bitwise_match", self.bitwise_match)
    }
}

/// Record one ELBO particle, compile it twice — once raw, once through
/// the DCE pass — run both for several steps with identical seeds, and
/// require the loss, every parameter gradient, and the RNG end state to
/// agree *bitwise*. This is the machine-checked form of the claim that
/// [`CompiledProgram::eliminate_dead`] is semantics-preserving, and the
/// source of the instruction counts published in bench output.
pub fn dce_audit<E: Elbo + ?Sized>(
    seed: u64,
    store: &mut ParamStore,
    model: &ModelFn,
    guide: &ModelFn,
    elbo: &E,
) -> Result<DceAudit> {
    let snapshot = elbo.snapshot();
    let (recorded, _out) = record_particle(seed, store, model, guide, elbo, &snapshot)?;
    let rec = match recorded {
        Recorded::Ready(rec) => rec,
        Recorded::Inherent(why) => {
            return Err(Error::msg(format!(
                "dce audit: model is inherently dynamic, nothing to compile: {why}"
            )))
        }
    };
    let raw = CompiledProgram::compile_raw(&rec)?;
    raw.verify_ir()?;
    let mut pruned = CompiledProgram::compile_raw(&rec)?;
    pruned.eliminate_dead();
    pruned.verify_ir()?;

    let mut a_raw = Arena::new(&raw);
    let mut a_dce = Arena::new(&pruned);
    let mut bitwise = true;
    for step in 0..3u64 {
        let s = seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(step + 1);
        let mut rng_raw = Pcg64::new(s);
        let mut rng_dce = Pcg64::new(s);
        let v_raw = raw.run_step(&mut a_raw, store, &mut rng_raw);
        let v_dce = pruned.run_step(&mut a_dce, store, &mut rng_dce);
        if v_raw.to_bits() != v_dce.to_bits() || rng_raw != rng_dce {
            bitwise = false;
        }
        for slot in &pruned.params {
            let g_raw = a_raw.adjs[slot.id].data();
            let g_dce = a_dce.adjs[slot.id].data();
            if g_raw.len() != g_dce.len()
                || g_raw.iter().zip(g_dce.iter()).any(|(x, y)| x.to_bits() != y.to_bits())
            {
                bitwise = false;
            }
        }
    }
    let (fw_total, bw_total, fw_eliminated, bw_eliminated) = pruned.dce_counts();
    Ok(DceAudit { fw_total, bw_total, fw_eliminated, bw_eliminated, bitwise_match: bitwise })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(op: Op, parents: Vec<usize>, dims: Vec<usize>) -> TapeNode {
        TapeNode { op, parents, value: Tensor::zeros(dims) }
    }

    /// ids: 0 param leaf [2], 1 const leaf [2], 2 = 0*1, 3 = sum(2),
    /// 4 = neg(3) = loss. The Mul edge into the constant leaf 1 is the
    /// canonical DCE kill.
    fn tiny_prog() -> CompiledProgram {
        CompiledProgram {
            init_vals: vec![
                Tensor::zeros(vec![2]),
                Tensor::zeros(vec![2]),
                Tensor::zeros(vec![2]),
                Tensor::scalar(0.0),
                Tensor::scalar(0.0),
            ],
            fw: vec![
                (2, FwPlan::Zip { a: 0, b: 1, op: ZipOp::Mul, sa: vec![1], sb: vec![1] }),
                (3, FwPlan::SumAll { a: 2 }),
                (4, FwPlan::Map { a: 3, kind: MapKind::Neg }),
            ],
            bw: vec![
                (4, BwPlan::Unary { parent: 3, kind: UKind::Neg }),
                (3, BwPlan::Scatter { parent: 2, kind: SKind::SumAll }),
                (2, BwPlan::Binary {
                    edges: vec![
                        EdgePlan {
                            parent: 0,
                            pre: Pre::MulVal { other: 1, buf: 0, sg: vec![1], so: vec![1] },
                            chain: vec![],
                        },
                        EdgePlan {
                            parent: 1,
                            pre: Pre::MulVal { other: 0, buf: 1, sg: vec![1], so: vec![1] },
                            chain: vec![],
                        },
                    ],
                }),
            ],
            zero_ids: vec![0, 1, 2, 3, 4],
            adj_alloc: vec![true; 5],
            scratch_dims: vec![vec![2], vec![2]],
            perm_sizes: vec![],
            schedule: vec![],
            params: vec![ParamSlot { name: "p".into(), id: 0, dims: vec![2] }],
            loss_id: 4,
            value_id: 3,
            skeleton: Skeleton { lines: vec![], hash: 0 },
            struct_hash: 0,
            store_fp: 0,
            fw_total: 3,
            bw_total: 4,
            fw_eliminated: 0,
            bw_eliminated: 0,
        }
    }

    #[test]
    fn verify_ir_accepts_a_wellformed_program() {
        tiny_prog().verify_ir().expect("tiny program is well-formed");
    }

    #[test]
    fn verify_ir_rejects_operand_after_output() {
        let mut bad = tiny_prog();
        bad.fw[1] = (3, FwPlan::SumAll { a: 4 });
        let e = bad.verify_ir().unwrap_err().to_string();
        assert!(e.contains("[FY012]"), "{e}");
        assert!(e.contains("precede"), "{e}");
    }

    #[test]
    fn verify_ir_rejects_backward_parent_at_or_after_node() {
        let mut bad = tiny_prog();
        bad.bw[0] = (4, BwPlan::Unary { parent: 4, kind: UKind::Neg });
        let e = bad.verify_ir().unwrap_err().to_string();
        assert!(e.contains("[FY012]"), "{e}");
    }

    #[test]
    fn verify_ir_rejects_shape_drift() {
        // Shrink the recorded product buffer: Zip strides stay rank-1 but
        // the elementwise counts disagree downstream.
        let mut bad = tiny_prog();
        bad.init_vals[2] = Tensor::zeros(vec![3]);
        assert!(bad.verify_ir().is_err());
    }

    #[test]
    fn verify_ir_rejects_fill_into_computed_node() {
        let mut bad = tiny_prog();
        bad.schedule.push(StepInput::Fill { id: 2, kind: DrawKind::StdNormal });
        let e = bad.verify_ir().unwrap_err().to_string();
        assert!(e.contains("not a leaf"), "{e}");
    }

    #[test]
    fn verify_ir_rejects_unsorted_params() {
        let mut bad = tiny_prog();
        bad.params = vec![
            ParamSlot { name: "b".into(), id: 0, dims: vec![2] },
            ParamSlot { name: "a".into(), id: 1, dims: vec![2] },
        ];
        let e = bad.verify_ir().unwrap_err().to_string();
        assert!(e.contains("sorted"), "{e}");
    }

    #[test]
    fn dce_drops_edges_into_constant_leaves_and_nothing_else() {
        let mut prog = tiny_prog();
        prog.verify_ir().expect("well-formed before DCE");
        prog.eliminate_dead();
        assert_eq!(prog.bw_eliminated, 1, "exactly the constant-leaf edge dies");
        assert_eq!(prog.fw_eliminated, 0);
        assert_eq!(prog.bw.len(), 3, "all three entries still have live targets");
        let edges = match &prog.bw.iter().find(|(id, _)| *id == 2).unwrap().1 {
            BwPlan::Binary { edges } => edges,
            other => panic!("expected Binary, got {other:?}"),
        };
        assert_eq!(edges.len(), 1);
        assert_eq!(edges[0].parent, 0, "the param edge survives");
        prog.verify_ir().expect("still well-formed after DCE");
    }

    #[test]
    fn dce_removes_whole_dead_subgraphs() {
        // Make the param a constant instead: every adjoint is dead and
        // the entire backward plan should vanish.
        let mut prog = tiny_prog();
        prog.params.clear();
        prog.eliminate_dead();
        assert!(prog.bw.is_empty());
        assert_eq!(prog.bw_eliminated, 4);
        prog.verify_ir().expect("an empty backward plan is well-formed");
    }

    #[test]
    fn structural_hash_sensitive_to_ops_shapes_events() {
        let base = vec![node(Op::Leaf, vec![], vec![2]), node(Op::Exp, vec![0], vec![2])];
        let h0 = structural_hash(&base, &[]);
        let other_op = vec![node(Op::Leaf, vec![], vec![2]), node(Op::Ln, vec![0], vec![2])];
        assert_ne!(h0, structural_hash(&other_op, &[]), "op kind must change the hash");
        let other_shape = vec![node(Op::Leaf, vec![], vec![3]), node(Op::Exp, vec![0], vec![3])];
        assert_ne!(h0, structural_hash(&other_shape, &[]), "shape must change the hash");
        let ev = [TapeEvent::Draw { id: 0, kind: DrawKind::StdNormal }];
        assert_ne!(h0, structural_hash(&base, &ev), "events must change the hash");
        // Same structure, different values — the hash must NOT change.
        let same_structure = vec![
            TapeNode { op: Op::Leaf, parents: vec![], value: Tensor::full(vec![2], 7.0) },
            TapeNode { op: Op::Exp, parents: vec![0], value: Tensor::full(vec![2], 3.0) },
        ];
        assert_eq!(h0, structural_hash(&same_structure, &[]));
    }

    #[test]
    fn skeleton_diff_reports_site_changes() {
        let a = Skeleton {
            lines: vec!["guide z: Normal value[2]".to_string(), "param loc: [2]".to_string()],
            hash: 0,
        };
        let b = Skeleton {
            lines: vec!["guide z: Normal value[3]".to_string(), "param loc: [2]".to_string()],
            hash: 1,
        };
        let d = skeleton_diff(&a, &b);
        assert!(d.contains("- guide z: Normal value[2]"), "{d}");
        assert!(d.contains("+ guide z: Normal value[3]"), "{d}");
        assert!(!d.contains("param loc"), "unchanged lines must not appear: {d}");
        let same = skeleton_diff(&a, &a.clone());
        assert!(same.contains("op-level"), "{same}");
    }

    #[test]
    fn reduce_chain_mirrors_reduce_grad_to() {
        let mut s = ScratchAlloc(Vec::new());
        assert!(reduce_chain(&[4, 3], &[4, 3], &mut s).is_empty());
        // [2,4,3] -> [4,1]: drop the leading dim, then sum axis 1 keepdim.
        let c = reduce_chain(&[2, 4, 3], &[4, 1], &mut s);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].axis, None);
        assert_eq!(c[1].axis, Some(1));
        assert_eq!(s.0[c[0].buf], vec![4, 3]);
        assert_eq!(s.0[c[1].buf], vec![4, 1]);
    }
}

