//! Inference algorithms — `pyro.infer`.
//!
//! The primary algorithm is gradient-based stochastic variational
//! inference ([`svi::Svi`]) with Monte-Carlo ELBO estimates over
//! mini-batches (paper §2 "scalable"). The loss is an open estimator
//! object ([`elbo::Elbo`]): plain Trace, analytic-KL mean-field,
//! Rao-Blackwellized TraceGraph, and the Rényi/IWAE family all ship
//! in-tree, and user crates can implement their own. Also here:
//! importance sampling, autoguides, posterior predictive, and the
//! No-U-Turn Sampler / Hamiltonian Monte Carlo family.
//!
//! Graph-mode SVI ([`compile`]) records the tape of one dynamic step
//! and compiles it into a straight-line fused ELBO kernel — opt in via
//! [`svi::SviConfig::graph_mode`]; the dynamic interpreter stays the
//! semantics oracle and every compiled program is verified against it.
//! Compilation also runs the graph-IR verifier and a liveness-based
//! dead-code-elimination pass ([`compile::dce_audit`] pins the latter
//! bitwise); the trace-level counterpart is the model/guide linter in
//! [`crate::analysis`], reachable as `Svi::analyze` and
//! [`svi::SviConfig::validate`].
//!
//! Data-parallel SVI ([`data_parallel`]) scales past one core and past
//! RAM: W workers stream shard-local minibatches
//! ([`crate::data::ShardedLoader`]) and merge gradients
//! deterministically in shard order, composing with graph mode by
//! compiling once and instantiating per-worker arenas.

pub mod autoguide;
pub mod compile;
pub mod data_parallel;
pub mod diagnostics;
pub mod elbo;
pub mod importance;
pub mod mcmc;
pub mod predictive;
pub mod svi;

pub use autoguide::{AutoDelta, AutoNormal};
pub use compile::{dce_audit, DceAudit, GraphDiagnostics};
pub use data_parallel::{BatchLayout, DataParallelSvi, ShardBatch, ShardConfig, ShardModelFn};
pub use diagnostics::{ess, split_rhat, SiteSummary};
pub use elbo::{
    default_elbo, has_score_sites, trace_log_weight, BaselineSnapshot, BaselineState,
    Elbo, ParticleCtx, ParticleStats, RenyiElbo, TraceElbo, TraceGraphElbo,
    TraceMeanFieldElbo,
};
pub use importance::Importance;
pub use mcmc::{Hmc, McmcConfig, McmcSamples, Nuts};
pub use predictive::Predictive;
pub use svi::Svi;
