//! Inference algorithms — `pyro.infer`.
//!
//! The primary algorithm is gradient-based stochastic variational
//! inference ([`svi::Svi`]) with Monte-Carlo ELBO estimates over
//! mini-batches (paper §2 "scalable"). Also here: analytic-KL mean-field
//! ELBO, importance sampling, autoguides, posterior predictive, and the
//! No-U-Turn Sampler / Hamiltonian Monte Carlo family.

pub mod autoguide;
pub mod diagnostics;
pub mod elbo;
pub mod importance;
pub mod mcmc;
pub mod predictive;
pub mod svi;

pub use autoguide::{AutoDelta, AutoNormal};
pub use diagnostics::{ess, split_rhat, SiteSummary};
pub use elbo::{ElboKind, TraceElbo, TraceMeanFieldElbo};
pub use importance::Importance;
pub use mcmc::{Hmc, McmcConfig, McmcSamples, Nuts};
pub use predictive::Predictive;
pub use svi::Svi;
