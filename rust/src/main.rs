//! `fyro` — the CLI launcher for the compiled-path coordinator.
//!
//! Subcommands:
//!   list                      — show available model artifacts
//!   train-vae                 — train a VAE on synthetic MNIST
//!   train-dmm                 — train a DMM on synthetic chorales
//!   bench-overhead            — one Fig-3 cell (raw vs traced step time)
//!   demo-svi                  — dynamic-path SVI demo (no artifacts)
//!   lint                      — static-analyze the model zoo (CI gate)
//!   serve-bench               — serving-layer load sweep (BENCH_serve.json)
//!
//! Common flags: --artifacts DIR (default "artifacts"), --model NAME,
//! --epochs N, --train N, --test N, --seed S, --checkpoint PATH.

use fyro::cli::Args;
use fyro::error::{Error, Result};
use fyro::coordinator::{save_checkpoint, DmmTrainer, StepPath, VaeTrainer};
use fyro::runtime::ArtifactCache;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    match args.command.as_str() {
        "list" => list(&args),
        "train-vae" => train_vae(&args),
        "train-dmm" => train_dmm(&args),
        "bench-overhead" => bench_overhead(&args),
        "demo-svi" => demo_svi(&args),
        "lint" => lint(&args),
        "serve-bench" => serve_bench(&args),
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    eprintln!(
        "usage: fyro <list|train-vae|train-dmm|bench-overhead|demo-svi|lint|serve-bench> [--flag value]...
  fyro list           [--artifacts DIR]
  fyro train-vae      [--model vae_z10_h400] [--epochs 5] [--train 8192] [--test 1024]
                      [--path raw|traced] [--checkpoint out.bin]
  fyro train-dmm      [--model dmm_iaf0] [--epochs 10] [--train 512] [--test 64]
  fyro bench-overhead [--model vae_z10_h400] [--iters 20]
  fyro demo-svi       [--steps 1000] [--seed 0]
  fyro lint           [--seed 11]
  fyro serve-bench    [--out BENCH_serve.json] [--smoke 1]"
    );
}

fn cache(args: &Args) -> Result<ArtifactCache> {
    ArtifactCache::open(args.get_str("artifacts", "artifacts"))
}

fn list(args: &Args) -> Result<()> {
    let cache = cache(args)?;
    println!("{:<16} {:>10} {:>8}  shapes", "model", "params", "batch");
    for m in cache.models() {
        println!(
            "{:<16} {:>10} {:>8}  x{:?} eps{:?}",
            m.name, m.p, m.batch, m.x_dims, m.eps_dims
        );
    }
    Ok(())
}

fn train_vae(args: &Args) -> Result<()> {
    let cache = cache(args)?;
    let name = args.get_str("model", "vae_z10_h400");
    let epochs = args.get_usize("epochs", 5);
    let n_train = args.get_usize("train", 8192);
    let n_test = args.get_usize("test", 1024);
    let path = match args.get_str("path", "raw") {
        "raw" => StepPath::Raw,
        "traced" => StepPath::Traced,
        other => return Err(Error::msg(format!("--path must be raw|traced, got {other}"))),
    };
    println!("loading + compiling {name} ...");
    let model = cache.load(name)?;
    let mut trainer = VaeTrainer::new(model, n_train, n_test, path)?;
    println!("training {epochs} epochs on {n_train} synthetic-MNIST images ({path:?} path)");
    for e in 0..epochs {
        let s = trainer.run_epoch(e)?;
        println!(
            "epoch {:>3}  train -ELBO {:>9.3}  test -ELBO {:>9.3}  {:>6.1} img/s",
            s.epoch,
            s.train_loss,
            s.test_loss,
            s.throughput(trainer.svi.model.meta.batch)
        );
    }
    if let Some(ckpt) = args.get("checkpoint") {
        save_checkpoint(ckpt, &trainer.svi.host_state()?)?;
        println!("checkpoint -> {ckpt}");
    }
    Ok(())
}

fn train_dmm(args: &Args) -> Result<()> {
    let cache = cache(args)?;
    let name = args.get_str("model", "dmm_iaf0");
    let epochs = args.get_usize("epochs", 10);
    let n_train = args.get_usize("train", 512);
    let n_test = args.get_usize("test", 64);
    println!("loading + compiling {name} ...");
    let model = cache.load(name)?;
    let mut trainer = DmmTrainer::new(model, n_train, n_test)?;
    println!("training {epochs} epochs on {n_train} synthetic chorales");
    for e in 0..epochs {
        let s = trainer.run_epoch(e)?;
        println!(
            "epoch {:>3}  train -ELBO/t {:>8.4}  test -ELBO/t {:>8.4}  ({:.1}s)",
            s.epoch, s.train_loss, s.test_loss, s.secs
        );
    }
    if let Some(ckpt) = args.get("checkpoint") {
        save_checkpoint(ckpt, &trainer.svi.host_state()?)?;
        println!("checkpoint -> {ckpt}");
    }
    Ok(())
}

fn bench_overhead(args: &Args) -> Result<()> {
    use fyro::benchkit;
    use fyro::coordinator::CompiledSvi;
    use fyro::data::{gather_images, SyntheticMnist};
    use fyro::runtime::F32Buf;

    let cache = cache(args)?;
    let name = args.get_str("model", "vae_z10_h400");
    let iters = args.get_usize("iters", 20);
    let model = cache.load(name)?;
    let meta = model.meta.clone();
    let data = SyntheticMnist::generate(meta.batch * 4, 0, 1);
    let idx: Vec<usize> = (0..meta.batch).collect();
    let x = F32Buf { data: gather_images(&data.train, &idx), dims: meta.x_dims.clone() };

    let mut svi = CompiledSvi::new(model, 7)?;
    let raw = benchkit::bench(&format!("{name} raw"), 3, iters, || {
        svi.step_raw(&x).unwrap();
    });
    let model2 = cache.load(name)?;
    let mut svi2 = CompiledSvi::new(model2, 7)?;
    let mut store = fyro::params::ParamStore::new();
    let traced = benchkit::bench(&format!("{name} traced"), 3, iters, || {
        svi2.step_traced(&x, &mut store).unwrap();
    });
    println!("{}", raw.report());
    println!("{}", traced.report());
    println!("overhead: {:.2}x", traced.mean_ms / raw.mean_ms);
    Ok(())
}

fn demo_svi(args: &Args) -> Result<()> {
    use fyro::dist::{Constraint, Normal};
    use fyro::infer::{Svi, TraceElbo};
    use fyro::optim::Adam;
    use fyro::params::ParamStore;
    use fyro::poutine::Ctx;
    use fyro::tensor::{Pcg64, Tensor};

    let steps = args.get_usize("steps", 1000);
    let seed = args.get_u64("seed", 0);
    let model = |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    };
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("loc", || Tensor::scalar(0.0));
        let scale =
            ctx.param_constrained("scale", || Tensor::scalar(1.0), Constraint::Positive);
        ctx.sample("z", Normal::new(loc, scale));
    };
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(seed);
    let mut svi = Svi::new(Adam::new(0.02), TraceElbo::default());
    for s in 0..steps {
        let loss = svi.step(&mut store, &mut rng, &model, &guide);
        if s % (steps / 10).max(1) == 0 {
            println!("step {s:>5}  loss {loss:>8.4}");
        }
    }
    println!(
        "posterior: loc {:.4} (exact 0.3)  scale {:.4} (exact 0.7071)",
        store.get("loc").unwrap().item(),
        store.get("scale").unwrap().item()
    );
    Ok(())
}

fn serve_bench(args: &Args) -> Result<()> {
    use fyro::serve::loadgen;

    let smoke = args.get("smoke").is_some() || std::env::var("FYRO_BENCH_SMOKE").is_ok();
    let default_out = std::env::var("FYRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let out = args.get_str("out", &default_out);
    println!("serve-bench: mixed-version load sweep (smoke={smoke})");
    let record = loadgen::run_bench(smoke);
    record.write(out)?;
    println!("{}", record.render());
    println!("wrote {out}");
    Ok(())
}

fn lint(args: &Args) -> Result<()> {
    use fyro::analysis::{lint_model_guide, zoo};
    use fyro::params::ParamStore;

    let seed = args.get_u64("seed", 11);
    let pairs = zoo::all();
    let mut total = 0usize;
    for pair in &pairs {
        let mut store = ParamStore::new();
        let report = lint_model_guide(
            &mut store,
            seed,
            &pair.model,
            &pair.guide,
            Some(&pair.estimator),
        );
        println!("{:<24} {report}", pair.name);
        total += report.len();
    }
    if total > 0 {
        return Err(Error::msg(format!(
            "lint: {total} diagnostic(s) across {} zoo pair(s)",
            pairs.len()
        )));
    }
    println!("lint: {} pair(s) clean", pairs.len());
    Ok(())
}
