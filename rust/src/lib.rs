//! # Fyro: deep universal probabilistic programming in Rust + JAX + Pallas
//!
//! A reproduction of *Pyro: Deep Universal Probabilistic Programming*
//! (Bingham et al., 2018) as a three-layer system:
//!
//! - **Layer 3 (this crate)** — the PPL itself: `sample`/`param`
//!   primitives, the Poutine effect-handler stack, a distributions
//!   library, SVI/ELBO inference, HMC/NUTS, autoguides and optimizers,
//!   plus the substrates Pyro inherited from PyTorch (tensor, autodiff,
//!   RNG, nn modules), all built in-tree.
//! - **Layer 2 (python/compile, build-time only)** — JAX definitions of
//!   the paper's evaluation models (VAE, Deep Markov Model ± IAF guides),
//!   AOT-lowered to HLO text artifacts.
//! - **Layer 1 (python/compile/kernels)** — Pallas kernels for the
//!   numeric hot-spots, validated against pure-jnp oracles.
//!
//! The compiled path executes the HLO artifacts through PJRT (`runtime`)
//! under a Rust training coordinator (`coordinator`); Python never runs
//! at inference/training time.
//!
//! ## Quickstart (dynamic path)
//!
//! ```
//! use fyro::prelude::*;
//!
//! // model: z ~ N(0,1); x ~ N(z, 0.5) observed
//! let model = |ctx: &mut Ctx| {
//!     let z = ctx.sample("z", Normal::std(0.0, 1.0));
//!     ctx.observe("x", Normal::new(z, ctx.cs(0.5)), Tensor::scalar(1.3));
//! };
//! let mut rng = Pcg64::new(0);
//! let trace = fyro::poutine::trace_fn(&model, &mut rng);
//! assert!(trace.log_prob_sum().is_finite());
//! ```
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod analysis;
pub mod autodiff;
pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod error;
pub mod infer;
pub mod nn;
pub mod optim;
pub mod params;
pub mod poutine;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod testkit;

/// Convenient glob import for examples and tests.
#[allow(unused)]
pub mod prelude {
    pub use crate::analysis::{Diagnostic, LintCode, Report, Severity};
    pub use crate::autodiff::{Tape, Var};
    pub use crate::dist::{
        Bernoulli, Beta, Categorical, Constraint, Dirichlet, Dist, Expanded, Exponential,
        Field, Gamma, HalfCauchy, Independent, LogNormal, MvNormalDiag, Normal, Uniform,
    };
    pub use crate::coordinator::{AsyncConfig, ParamServer, PushOutcome};
    pub use crate::data::{MemLoader, ShardCursor, ShardedLoader, StreamLoader};
    pub use crate::infer::{
        default_elbo, BatchLayout, DataParallelSvi, Elbo, RenyiElbo, ShardBatch, ShardConfig,
        Svi, TraceElbo, TraceGraphElbo, TraceMeanFieldElbo,
    };
    pub use crate::optim::{Adam, ClippedAdam, Sgd};
    pub use crate::params::ParamStore;
    pub use crate::poutine::{Ctx, Plate, PlateFrame, Trace};
    pub use crate::serve::{
        FrozenModel, Query, Registry, Request, Response, ServeConfig, ServeError, Server,
    };
    pub use crate::telemetry::{TelemetryMessenger, TelemetrySnapshot};
    pub use crate::tensor::{Pcg64, Shape, Tensor};
}
