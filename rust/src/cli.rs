//! Tiny command-line parsing (no `clap` in the offline registry).
//!
//! Supports `fyro <subcommand> [--flag value]...` with typed accessors
//! and automatic usage reporting.

use std::collections::HashMap;

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from `std::env::args()`-style strings (program name first).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().skip(1);
        match it.next() {
            Some(cmd) if !cmd.starts_with("--") => out.command = cmd.clone(),
            Some(flag) => return Err(format!("expected subcommand before '{flag}'")),
            None => return Err("no subcommand".to_string()),
        }
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{k}'"))?;
            let v = it.next().ok_or_else(|| format!("missing value for --{key}"))?;
            out.flags.insert(key.to_string(), v.clone());
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("integer flag")).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().expect("integer flag")).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect("float flag")).unwrap_or(default)
    }

    pub fn get_str<'s>(&'s self, key: &str, default: &'s str) -> &'s str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&argv("fyro train-vae --model vae_z10_h400 --epochs 3")).unwrap();
        assert_eq!(a.command, "train-vae");
        assert_eq!(a.get_str("model", ""), "vae_z10_h400");
        assert_eq!(a.get_usize("epochs", 0), 3);
        assert_eq!(a.get_usize("missing", 9), 9);
    }

    #[test]
    fn rejects_missing_value() {
        assert!(Args::parse(&argv("fyro run --x")).is_err());
    }

    #[test]
    fn rejects_no_subcommand() {
        assert!(Args::parse(&argv("fyro")).is_err());
    }
}
