//! Minimal error type for fallible subsystems (runtime, coordinator,
//! CLI). The offline registry has no `anyhow`; this is the crate's
//! stand-in: a single string-backed error with `?`-friendly conversions.

use std::fmt;

/// A string-backed error.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }

    /// Add context to an error, anyhow-style.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Attach context to a `Result`'s error, anyhow-style.
pub trait ResultExt<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
}

impl<T, E: Into<Error>> ResultExt<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_prepends() {
        let e: Result<()> = Err(Error::msg("boom"));
        let e = e.context("loading x");
        assert_eq!(format!("{}", e.unwrap_err()), "loading x: boom");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read().is_err());
    }
}
