//! Minimal benchmarking harness (the offline registry has no criterion).
//!
//! Provides warmup + timed iterations with mean/std/min reporting, and a
//! tiny table printer used by the Fig-3/Fig-4 bench binaries.

use std::time::Instant;

/// Timing summary over the measured iterations.
#[derive(Clone, Debug)]
pub struct Timing {
    pub label: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    /// Median — the noise-robust statistic benches report on shared or
    /// single-core machines.
    pub median_ms: f64,
    /// Nearest-rank percentiles over the measured samples — the tail
    /// statistics ROADMAP item 2's serving benches gate on, and the
    /// sample-exact counterpart to telemetry's bucketed histogram
    /// percentiles.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl Timing {
    pub fn report(&self) -> String {
        format!(
            "{:<28} {:>8.2} ms ± {:>6.2} (min {:>8.2}, n={})",
            self.label, self.mean_ms, self.std_ms, self.min_ms, self.iters
        )
    }

    /// Median nanoseconds per iteration — the unit the machine-readable
    /// bench records use.
    pub fn ns_per_iter(&self) -> f64 {
        self.median_ms * 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured ones.
pub fn bench(label: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    summarize(label, &samples)
}

/// Nearest-rank percentile of an ascending-sorted sample slice:
/// the smallest sample with at least `q * n` samples at or below it.
/// Returns 0.0 for an empty slice (matching the other empty-sample
/// defaults in [`summarize`]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Build a [`Timing`] from raw millisecond samples.
pub fn summarize(label: &str, samples_ms: &[f64]) -> Timing {
    let n = samples_ms.len().max(1) as f64;
    let mean = samples_ms.iter().sum::<f64>() / n;
    let var = samples_ms.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let mut sorted = samples_ms.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if sorted.is_empty() { 0.0 } else { sorted[sorted.len() / 2] };
    Timing {
        label: label.to_string(),
        iters: samples_ms.len(),
        mean_ms: mean,
        std_ms: var.sqrt(),
        min_ms: sorted.first().copied().unwrap_or(0.0),
        median_ms: median,
        p50_ms: percentile(&sorted, 0.50),
        p95_ms: percentile(&sorted, 0.95),
        p99_ms: percentile(&sorted, 0.99),
    }
}

/// Interleaved A/B benchmark: alternate the two closures per iteration so
/// slow drift (thermal, paging, background load) cancels out of the
/// ratio. Returns (timing_a, timing_b).
pub fn bench_pair(
    label_a: &str,
    label_b: &str,
    warmup: usize,
    iters: usize,
    mut a: impl FnMut(),
    mut b: impl FnMut(),
) -> (Timing, Timing) {
    for _ in 0..warmup {
        a();
        b();
    }
    let mut sa = Vec::with_capacity(iters);
    let mut sb = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        a();
        sa.push(t0.elapsed().as_secs_f64() * 1e3);
        let t1 = Instant::now();
        b();
        sb.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    (summarize(label_a, &sa), summarize(label_b, &sb))
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

pub mod json {
    //! Minimal JSON emission for machine-readable bench records
    //! (`BENCH_*.json`) — the offline registry has no serde.

    use std::fmt::Write as _;

    /// An ordered JSON object under construction (builder style).
    #[derive(Clone, Debug, Default)]
    pub struct JsonObj {
        fields: Vec<(String, String)>,
    }

    fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out
    }

    impl JsonObj {
        pub fn new() -> Self {
            Self::default()
        }

        fn raw(mut self, k: &str, v: String) -> Self {
            self.fields.push((k.to_string(), v));
            self
        }

        /// Finite numbers render as-is; NaN/Inf become `null`.
        pub fn num(self, k: &str, v: f64) -> Self {
            let r = if v.is_finite() { format!("{v}") } else { "null".to_string() };
            self.raw(k, r)
        }

        pub fn int(self, k: &str, v: usize) -> Self {
            self.raw(k, format!("{v}"))
        }

        pub fn bool(self, k: &str, v: bool) -> Self {
            self.raw(k, format!("{v}"))
        }

        pub fn str(self, k: &str, v: &str) -> Self {
            let s = format!("\"{}\"", escape(v));
            self.raw(k, s)
        }

        pub fn obj(self, k: &str, o: JsonObj) -> Self {
            let s = o.render();
            self.raw(k, s)
        }

        /// Append all of `other`'s fields after this object's fields —
        /// lets callers prefix bookkeeping keys (telemetry's JSONL
        /// writer prepends `seq` this way).
        pub fn merge(mut self, other: JsonObj) -> Self {
            self.fields.extend(other.fields);
            self
        }

        pub fn arr(self, k: &str, items: Vec<JsonObj>) -> Self {
            let s = format!(
                "[{}]",
                items.iter().map(JsonObj::render).collect::<Vec<_>>().join(", ")
            );
            self.raw(k, s)
        }

        pub fn render(&self) -> String {
            let body = self
                .fields
                .iter()
                .map(|(k, v)| format!("\"{}\": {v}", escape(k)))
                .collect::<Vec<_>>()
                .join(", ");
            format!("{{{body}}}")
        }

        /// Write the record to disk (pretty enough for diffs: one line).
        pub fn write(&self, path: &str) -> std::io::Result<()> {
            std::fs::write(path, self.render() + "\n")
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn renders_valid_json() {
            let o = JsonObj::new()
                .str("bench", "fig3")
                .num("ns", 1234.5)
                .int("particles", 4)
                .bool("ok", true)
                .num("bad", f64::NAN)
                .obj("nested", JsonObj::new().int("a", 1))
                .arr("rows", vec![JsonObj::new().int("i", 0), JsonObj::new().int("i", 1)]);
            let s = o.render();
            assert_eq!(
                s,
                "{\"bench\": \"fig3\", \"ns\": 1234.5, \"particles\": 4, \
                 \"ok\": true, \"bad\": null, \"nested\": {\"a\": 1}, \
                 \"rows\": [{\"i\": 0}, {\"i\": 1}]}"
            );
        }

        #[test]
        fn escapes_strings() {
            let s = JsonObj::new().str("k", "a\"b\\c\nd").render();
            assert_eq!(s, "{\"k\": \"a\\\"b\\\\c\\nd\"}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let t = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert!(t.mean_ms >= 0.0);
        assert_eq!(t.iters, 5);
        assert!(t.min_ms <= t.mean_ms);
    }

    #[test]
    fn summarize_stats() {
        let t = summarize("x", &[1.0, 2.0, 3.0]);
        assert!((t.mean_ms - 2.0).abs() < 1e-12);
        assert!((t.std_ms - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(t.min_ms, 1.0);
        assert_eq!(t.p50_ms, 2.0);
        assert_eq!(t.p99_ms, 3.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&sorted, 0.50), 50.0);
        assert_eq!(percentile(&sorted, 0.95), 95.0);
        assert_eq!(percentile(&sorted, 0.99), 99.0);
        assert_eq!(percentile(&sorted, 1.0), 100.0);
        assert_eq!(percentile(&sorted, 0.0), 1.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // p50 agrees with the reported median on odd-length samples
        let t = summarize("m", &[5.0, 1.0, 9.0]);
        assert_eq!(t.p50_ms, t.median_ms);
    }
}
