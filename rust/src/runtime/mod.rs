//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! This is the bridge between the Rust coordinator and the Layer-2/1
//! compute: `artifacts/*.hlo.txt` (HLO **text** — the xla_extension
//! 0.5.1 in this image rejects jax≥0.5 serialized protos) are parsed,
//! compiled once per process on the PJRT CPU client, and cached.
//! Python never runs here.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape/layout metadata for one compiled model, read from
/// `artifacts/manifest.json` (written by `python -m compile.aot`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub kind: String,
    /// Total flat parameter count.
    pub p: usize,
    pub batch: usize,
    pub x_dims: Vec<usize>,
    pub eps_dims: Vec<usize>,
    pub extra: HashMap<String, f64>,
}

/// Minimal JSON parsing for the manifest (flat {name: {k: num|str|[num]}}
/// structure; no external crates offline).
pub fn parse_manifest(text: &str) -> Result<Vec<ModelMeta>> {
    let mut out = Vec::new();
    let mut chars = text.char_indices().peekable();
    // find each top-level "name": { ... } block
    let bytes = text.as_bytes();
    let mut depth = 0i32;
    let mut cur_name: Option<String> = None;
    let mut block_start = 0usize;
    let mut last_key: Option<String> = None;
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => {
                // read string
                let mut s = String::new();
                for (_, c2) in chars.by_ref() {
                    if c2 == '"' {
                        break;
                    }
                    s.push(c2);
                }
                if depth == 1 {
                    last_key = Some(s);
                }
            }
            '{' => {
                depth += 1;
                if depth == 2 {
                    cur_name = last_key.clone();
                    block_start = i;
                }
            }
            '}' => {
                if depth == 2 {
                    if let Some(name) = cur_name.take() {
                        let block = &text[block_start..=i];
                        out.push(parse_model_block(&name, block)?);
                    }
                }
                depth -= 1;
            }
            _ => {}
        }
    }
    let _ = bytes;
    Ok(out)
}

fn parse_model_block(name: &str, block: &str) -> Result<ModelMeta> {
    let get_num = |key: &str| -> Option<f64> {
        let pat = format!("\"{key}\":");
        let idx = block.find(&pat)?;
        let rest = block[idx + pat.len()..].trim_start();
        let end = rest
            .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == 'E' || c == '+'))
            .unwrap_or(rest.len());
        rest[..end].parse().ok()
    };
    let get_str = |key: &str| -> Option<String> {
        let pat = format!("\"{key}\":");
        let idx = block.find(&pat)?;
        let rest = block[idx + pat.len()..].trim_start();
        let rest = rest.strip_prefix('"')?;
        Some(rest[..rest.find('"')?].to_string())
    };
    let get_arr = |key: &str| -> Option<Vec<usize>> {
        let pat = format!("\"{key}\":");
        let idx = block.find(&pat)?;
        let rest = block[idx + pat.len()..].trim_start();
        let rest = rest.strip_prefix('[')?;
        let end = rest.find(']')?;
        Some(
            rest[..end]
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect(),
        )
    };
    let mut extra = HashMap::new();
    for k in ["z", "h", "T", "num_iafs", "lr"] {
        if let Some(v) = get_num(k) {
            extra.insert(k.to_string(), v);
        }
    }
    Ok(ModelMeta {
        name: name.to_string(),
        kind: get_str("kind").ok_or_else(|| anyhow!("manifest: no kind for {name}"))?,
        p: get_num("P").ok_or_else(|| anyhow!("manifest: no P for {name}"))? as usize,
        batch: get_num("batch").unwrap_or(0.0) as usize,
        x_dims: get_arr("x_dims").unwrap_or_default(),
        eps_dims: get_arr("eps_dims").unwrap_or_default(),
        extra,
    })
}

/// A compiled three-stage model (init / train / eval executables).
pub struct CompiledModel {
    pub meta: ModelMeta,
    init: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
}

/// f32 host-side tensor used on the compiled path.
#[derive(Clone, Debug)]
pub struct F32Buf {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl F32Buf {
    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        F32Buf { data: vec![0.0; n], dims }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims_i64)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(F32Buf { data: lit.to_vec::<f32>()?, dims })
    }
}

/// Training state threaded between steps (params + Adam moments).
#[derive(Clone)]
pub struct TrainState {
    pub params: F32Buf,
    pub m: F32Buf,
    pub v: F32Buf,
    pub t: F32Buf,
    pub step: u64,
}

/// Training state held as PJRT literals, avoiding the host round-trip
/// of params + Adam moments on every step (§Perf optimization 1: the
/// train executable's state outputs feed the next call directly; only
/// the scalar loss is copied to host per step).
pub struct DeviceState {
    params: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    t: xla::Literal,
    pub step: u64,
}

impl CompiledModel {
    /// Upload a host state into literals.
    pub fn to_device(&self, state: &TrainState) -> Result<DeviceState> {
        Ok(DeviceState {
            params: state.params.to_literal()?,
            m: state.m.to_literal()?,
            v: state.v.to_literal()?,
            t: state.t.to_literal()?,
            step: state.step,
        })
    }

    /// Download a device state to host buffers (checkpoints, inspection).
    pub fn to_host(&self, dev: &DeviceState) -> Result<TrainState> {
        Ok(TrainState {
            params: F32Buf::from_literal(&dev.params)?,
            m: F32Buf::from_literal(&dev.m)?,
            v: F32Buf::from_literal(&dev.v)?,
            t: F32Buf::from_literal(&dev.t)?,
            step: dev.step,
        })
    }

    /// Hot-path train step over device state: state literals are reused
    /// in place and only the loss scalar crosses to host.
    pub fn train_step_dev(
        &self,
        dev: &mut DeviceState,
        x: &F32Buf,
        eps: &F32Buf,
    ) -> Result<f32> {
        assert_eq!(x.dims, self.meta.x_dims, "x shape mismatch");
        assert_eq!(eps.dims, self.meta.eps_dims, "eps shape mismatch");
        let x_lit = x.to_literal()?;
        let eps_lit = eps.to_literal()?;
        let args = [&dev.params, &dev.m, &dev.v, &dev.t, &x_lit, &eps_lit];
        let mut result = self
            .train
            .execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let mut outs = result.decompose_tuple()?;
        anyhow::ensure!(outs.len() == 5, "train_step returned {} outputs", outs.len());
        let loss = outs[4].to_vec::<f32>()?[0];
        dev.t = outs.remove(3);
        dev.v = outs.remove(2);
        dev.m = outs.remove(1);
        dev.params = outs.remove(0);
        dev.step += 1;
        Ok(loss)
    }

    /// Eval over device-resident parameters.
    pub fn eval_step_dev(&self, dev: &DeviceState, x: &F32Buf, eps: &F32Buf) -> Result<f32> {
        let x_lit = x.to_literal()?;
        let eps_lit = eps.to_literal()?;
        let args = [&dev.params, &x_lit, &eps_lit];
        let result = self.eval.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(F32Buf::from_literal(&result.to_tuple1()?)?.data[0])
    }

    /// Run the init program to produce the initial training state.
    pub fn init_state(&self) -> Result<TrainState> {
        let result = self
            .init
            .execute::<xla::Literal>(&[])
            .context("init execute")?[0][0]
            .to_literal_sync()?;
        let params = F32Buf::from_literal(&result.to_tuple1()?)?;
        assert_eq!(params.data.len(), self.meta.p, "param count mismatch");
        let p = self.meta.p;
        Ok(TrainState {
            params,
            m: F32Buf::zeros(vec![p]),
            v: F32Buf::zeros(vec![p]),
            t: F32Buf::zeros(vec![1]),
            step: 0,
        })
    }

    /// One optimizer step; returns the mini-batch loss.
    pub fn train_step(&self, state: &mut TrainState, x: &F32Buf, eps: &F32Buf) -> Result<f32> {
        assert_eq!(x.dims, self.meta.x_dims, "x shape mismatch");
        assert_eq!(eps.dims, self.meta.eps_dims, "eps shape mismatch");
        let args = [
            state.params.to_literal()?,
            state.m.to_literal()?,
            state.v.to_literal()?,
            state.t.to_literal()?,
            x.to_literal()?,
            eps.to_literal()?,
        ];
        let result = self.train.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut result = result;
        let mut outs = result.decompose_tuple()?;
        anyhow::ensure!(outs.len() == 5, "train_step returned {} outputs", outs.len());
        let loss = F32Buf::from_literal(&outs[4])?.data[0];
        state.t = F32Buf::from_literal(&outs[3])?;
        state.v = F32Buf::from_literal(&outs[2])?;
        state.m = F32Buf::from_literal(&outs[1])?;
        state.params = F32Buf::from_literal(&outs[0])?;
        let _ = outs.drain(..);
        state.step += 1;
        Ok(loss)
    }

    /// Loss on a batch without updating.
    pub fn eval_step(&self, state: &TrainState, x: &F32Buf, eps: &F32Buf) -> Result<f32> {
        let args = [state.params.to_literal()?, x.to_literal()?, eps.to_literal()?];
        let result = self.eval.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(F32Buf::from_literal(&result.to_tuple1()?)?.data[0])
    }
}

/// Loads, compiles and caches model artifacts.
pub struct ArtifactCache {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: HashMap<String, ModelMeta>,
}

impl ArtifactCache {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {manifest_path:?} — run `make artifacts` first",
            )
        })?;
        let metas = parse_manifest(&text)?
            .into_iter()
            .map(|m| (m.name.clone(), m))
            .collect();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ArtifactCache { client, dir, metas })
    }

    pub fn models(&self) -> Vec<&ModelMeta> {
        let mut v: Vec<&ModelMeta> = self.metas.values().collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ModelMeta> {
        self.metas.get(name)
    }

    fn compile_stage(&self, name: &str, stage: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(format!("{name}_{stage}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}_{stage}: {e:?}"))
    }

    /// Compile all three stages of a model (cached by the caller).
    pub fn load(&self, name: &str) -> Result<CompiledModel> {
        let meta = self
            .metas
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}' (have: {:?})", self.metas.keys()))?
            .clone();
        Ok(CompiledModel {
            meta,
            init: self.compile_stage(name, "init")?,
            train: self.compile_stage(name, "train")?,
            eval: self.compile_stage(name, "eval")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
  "dmm_iaf0": {
    "P": 65144, "T": 32, "batch": 16,
    "eps_dims": [16, 32, 32], "kind": "dmm", "lr": 0.0003,
    "num_iafs": 0, "x_dims": [16, 32, 88], "z": 32
  },
  "vae_z10_h400": {
    "P": 961604, "batch": 128, "eps_dims": [128, 10],
    "h": 400, "kind": "vae", "lr": 0.001,
    "x_dims": [128, 784], "z": 10
  }
}"#;

    #[test]
    fn manifest_parses_models() {
        let metas = parse_manifest(MANIFEST).unwrap();
        assert_eq!(metas.len(), 2);
        let vae = metas.iter().find(|m| m.name == "vae_z10_h400").unwrap();
        assert_eq!(vae.p, 961604);
        assert_eq!(vae.x_dims, vec![128, 784]);
        assert_eq!(vae.eps_dims, vec![128, 10]);
        assert_eq!(vae.kind, "vae");
        assert_eq!(vae.extra["h"], 400.0);
        let dmm = metas.iter().find(|m| m.name == "dmm_iaf0").unwrap();
        assert_eq!(dmm.extra["num_iafs"], 0.0);
        assert_eq!(dmm.x_dims, vec![16, 32, 88]);
    }

    #[test]
    fn f32buf_roundtrip() {
        let b = F32Buf { data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], dims: vec![2, 3] };
        let lit = b.to_literal().unwrap();
        let b2 = F32Buf::from_literal(&lit).unwrap();
        assert_eq!(b.data, b2.data);
        assert_eq!(b.dims, b2.dims);
    }
}
