//! Runtime for AOT-compiled HLO artifacts.
//!
//! The compiled path rides on a PJRT client (`xla` crate) that is not
//! present in this offline build environment, so this module ships in
//! two halves:
//!
//! - the **portable half** (always built): artifact manifest parsing,
//!   host-side `f32` buffers, and the training-state plumbing that the
//!   coordinator, checkpoints and tests use;
//! - the **backend half**: `CompiledModel` execution. Without a PJRT
//!   client every execution entry point returns a descriptive error;
//!   callers (CLI, benches, integration tests) detect missing artifacts
//!   up front and skip gracefully, so `cargo test` passes with no
//!   backend while the dynamic path stays fully functional.

use crate::error::{Error, Result, ResultExt};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn backend_unavailable() -> Error {
    Error::msg(
        "PJRT/XLA backend is not available in this offline build; \
         the compiled path requires the xla-enabled runtime (see rust/README.md)",
    )
}

/// Shape/layout metadata for one compiled model, read from
/// `artifacts/manifest.json` (written by `python -m compile.aot`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub kind: String,
    /// Total flat parameter count.
    pub p: usize,
    pub batch: usize,
    pub x_dims: Vec<usize>,
    pub eps_dims: Vec<usize>,
    pub extra: HashMap<String, f64>,
}

/// Minimal JSON parsing for the manifest (flat {name: {k: num|str|[num]}}
/// structure; no external crates offline).
pub fn parse_manifest(text: &str) -> Result<Vec<ModelMeta>> {
    let mut out = Vec::new();
    let mut chars = text.char_indices();
    let mut depth = 0i32;
    let mut cur_name: Option<String> = None;
    let mut block_start = 0usize;
    let mut last_key: Option<String> = None;
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => {
                // read string
                let mut s = String::new();
                for (_, c2) in chars.by_ref() {
                    if c2 == '"' {
                        break;
                    }
                    s.push(c2);
                }
                if depth == 1 {
                    last_key = Some(s);
                }
            }
            '{' => {
                depth += 1;
                if depth == 2 {
                    cur_name = last_key.clone();
                    block_start = i;
                }
            }
            '}' => {
                if depth == 2 {
                    if let Some(name) = cur_name.take() {
                        let block = &text[block_start..=i];
                        out.push(parse_model_block(&name, block)?);
                    }
                }
                depth -= 1;
            }
            _ => {}
        }
    }
    Ok(out)
}

fn parse_model_block(name: &str, block: &str) -> Result<ModelMeta> {
    let get_num = |key: &str| -> Option<f64> {
        let pat = format!("\"{key}\":");
        let idx = block.find(&pat)?;
        let rest = block[idx + pat.len()..].trim_start();
        let end = rest
            .find(|c: char| {
                !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == 'E' || c == '+')
            })
            .unwrap_or(rest.len());
        rest[..end].parse().ok()
    };
    let get_str = |key: &str| -> Option<String> {
        let pat = format!("\"{key}\":");
        let idx = block.find(&pat)?;
        let rest = block[idx + pat.len()..].trim_start();
        let rest = rest.strip_prefix('"')?;
        Some(rest[..rest.find('"')?].to_string())
    };
    let get_arr = |key: &str| -> Option<Vec<usize>> {
        let pat = format!("\"{key}\":");
        let idx = block.find(&pat)?;
        let rest = block[idx + pat.len()..].trim_start();
        let rest = rest.strip_prefix('[')?;
        let end = rest.find(']')?;
        Some(
            rest[..end]
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect(),
        )
    };
    let mut extra = HashMap::new();
    for k in ["z", "h", "T", "num_iafs", "lr"] {
        if let Some(v) = get_num(k) {
            extra.insert(k.to_string(), v);
        }
    }
    Ok(ModelMeta {
        name: name.to_string(),
        kind: get_str("kind")
            .ok_or_else(|| Error::msg(format!("manifest: no kind for {name}")))?,
        p: get_num("P").ok_or_else(|| Error::msg(format!("manifest: no P for {name}")))?
            as usize,
        batch: get_num("batch").unwrap_or(0.0) as usize,
        x_dims: get_arr("x_dims").unwrap_or_default(),
        eps_dims: get_arr("eps_dims").unwrap_or_default(),
        extra,
    })
}

/// f32 host-side tensor used on the compiled path.
#[derive(Clone, Debug)]
pub struct F32Buf {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl F32Buf {
    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        F32Buf { data: vec![0.0; n], dims }
    }
}

/// Training state threaded between steps (params + Adam moments).
#[derive(Clone)]
pub struct TrainState {
    pub params: F32Buf,
    pub m: F32Buf,
    pub v: F32Buf,
    pub t: F32Buf,
    pub step: u64,
}

/// Training state resident on the accelerator between steps. In the
/// stub backend this only tracks the step counter.
pub struct DeviceState {
    pub step: u64,
}

/// A compiled three-stage model (init / train / eval executables). The
/// stub backend holds the metadata only; every execution call errors.
pub struct CompiledModel {
    pub meta: ModelMeta,
}

impl CompiledModel {
    /// Upload a host state into device literals.
    pub fn to_device(&self, _state: &TrainState) -> Result<DeviceState> {
        Err(backend_unavailable())
    }

    /// Download a device state to host buffers (checkpoints, inspection).
    pub fn to_host(&self, _dev: &DeviceState) -> Result<TrainState> {
        Err(backend_unavailable())
    }

    /// Hot-path train step over device state.
    pub fn train_step_dev(
        &self,
        _dev: &mut DeviceState,
        _x: &F32Buf,
        _eps: &F32Buf,
    ) -> Result<f32> {
        Err(backend_unavailable())
    }

    /// Eval over device-resident parameters.
    pub fn eval_step_dev(&self, _dev: &DeviceState, _x: &F32Buf, _eps: &F32Buf) -> Result<f32> {
        Err(backend_unavailable())
    }

    /// Run the init program to produce the initial training state.
    pub fn init_state(&self) -> Result<TrainState> {
        Err(backend_unavailable())
    }

    /// One optimizer step; returns the mini-batch loss.
    pub fn train_step(
        &self,
        _state: &mut TrainState,
        _x: &F32Buf,
        _eps: &F32Buf,
    ) -> Result<f32> {
        Err(backend_unavailable())
    }

    /// Loss on a batch without updating.
    pub fn eval_step(&self, _state: &TrainState, _x: &F32Buf, _eps: &F32Buf) -> Result<f32> {
        Err(backend_unavailable())
    }
}

/// Loads and caches model artifact metadata; `load` would compile the
/// three HLO stages on a PJRT client when a backend is present.
pub struct ArtifactCache {
    dir: PathBuf,
    metas: HashMap<String, ModelMeta>,
}

impl ArtifactCache {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .context(format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let metas = parse_manifest(&text)?
            .into_iter()
            .map(|m| (m.name.clone(), m))
            .collect();
        Ok(ArtifactCache { dir, metas })
    }

    pub fn models(&self) -> Vec<&ModelMeta> {
        let mut v: Vec<&ModelMeta> = self.metas.values().collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ModelMeta> {
        self.metas.get(name)
    }

    /// Compile all three stages of a model (cached by the caller).
    pub fn load(&self, name: &str) -> Result<CompiledModel> {
        let meta = self
            .metas
            .get(name)
            .ok_or_else(|| {
                Error::msg(format!(
                    "unknown model '{name}' (have: {:?})",
                    self.models().iter().map(|m| &m.name).collect::<Vec<_>>()
                ))
            })?
            .clone();
        for stage in ["init", "train", "eval"] {
            let path = self.dir.join(format!("{name}_{stage}.hlo.txt"));
            if !path.exists() {
                return Err(Error::msg(format!("missing artifact stage {path:?}")));
            }
        }
        // Artifacts exist but there is no PJRT client to compile them
        // against in this build.
        Err(backend_unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
  "dmm_iaf0": {
    "P": 65144, "T": 32, "batch": 16,
    "eps_dims": [16, 32, 32], "kind": "dmm", "lr": 0.0003,
    "num_iafs": 0, "x_dims": [16, 32, 88], "z": 32
  },
  "vae_z10_h400": {
    "P": 961604, "batch": 128, "eps_dims": [128, 10],
    "h": 400, "kind": "vae", "lr": 0.001,
    "x_dims": [128, 784], "z": 10
  }
}"#;

    #[test]
    fn manifest_parses_models() {
        let metas = parse_manifest(MANIFEST).unwrap();
        assert_eq!(metas.len(), 2);
        let vae = metas.iter().find(|m| m.name == "vae_z10_h400").unwrap();
        assert_eq!(vae.p, 961604);
        assert_eq!(vae.x_dims, vec![128, 784]);
        assert_eq!(vae.eps_dims, vec![128, 10]);
        assert_eq!(vae.kind, "vae");
        assert_eq!(vae.extra["h"], 400.0);
        let dmm = metas.iter().find(|m| m.name == "dmm_iaf0").unwrap();
        assert_eq!(dmm.extra["num_iafs"], 0.0);
        assert_eq!(dmm.x_dims, vec![16, 32, 88]);
    }

    #[test]
    fn f32buf_zeros_shape() {
        let b = F32Buf::zeros(vec![2, 3]);
        assert_eq!(b.data.len(), 6);
        assert_eq!(b.dims, vec![2, 3]);
    }

    #[test]
    fn stub_backend_errors_are_descriptive() {
        let model = CompiledModel {
            meta: parse_manifest(MANIFEST).unwrap().remove(1),
        };
        let err = model.init_state().unwrap_err();
        assert!(format!("{err}").contains("PJRT"), "{err}");
    }
}
