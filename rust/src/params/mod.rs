//! The global parameter store — `pyro.param` semantics.
//!
//! Learnable parameters live outside any single model execution, keyed by
//! name. Storage is always the *unconstrained* value; constrained reads
//! go through `biject_to`-style transforms ([`Constraint::transform`]),
//! so optimizers act in ℝⁿ exactly as in Pyro.

use crate::dist::Constraint;
use crate::tensor::Tensor;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    /// Unconstrained storage (what optimizers update).
    pub unconstrained: Tensor,
    pub constraint: Constraint,
}

/// Named learnable parameters with constraint bookkeeping.
#[derive(Default, Clone, Debug)]
pub struct ParamStore {
    entries: HashMap<String, ParamEntry>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the unconstrained value, initializing from a *constrained*
    /// init on first touch (mirrors `pyro.param(name, init, constraint)`).
    pub fn get_or_init(
        &mut self,
        name: &str,
        init: impl FnOnce() -> Tensor,
        constraint: Constraint,
    ) -> Tensor {
        self.get_or_init_entry(name, init, constraint).0
    }

    /// Like [`ParamStore::get_or_init`], but returns the entry's
    /// registered constraint in the same map access — `ctx.param_*`
    /// previously paid a second lookup just to re-fetch it. The returned
    /// constraint is the one registered at first touch, which may differ
    /// from `constraint` when the param already existed.
    pub fn get_or_init_entry(
        &mut self,
        name: &str,
        init: impl FnOnce() -> Tensor,
        constraint: Constraint,
    ) -> (Tensor, Constraint) {
        let e = self.entries.entry(name.to_string()).or_insert_with(|| {
            let c = init();
            assert!(
                constraint.check(&c),
                "param '{name}' init violates {constraint:?}"
            );
            ParamEntry { unconstrained: constraint.inverse(&c), constraint }
        });
        (e.unconstrained.clone(), e.constraint)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn constraint(&self, name: &str) -> Constraint {
        self.entries[name].constraint
    }

    /// Constrained view of a parameter.
    pub fn get(&self, name: &str) -> Option<Tensor> {
        self.entries
            .get(name)
            .map(|e| e.constraint.transform(&e.unconstrained))
    }

    pub fn get_unconstrained(&self, name: &str) -> Option<Tensor> {
        self.entries.get(name).map(|e| e.unconstrained.clone())
    }

    /// Borrow the unconstrained buffer without cloning. Graph-mode SVI
    /// refreshes its arena leaves from this every step; `get_unconstrained`
    /// would allocate a fresh `Shape` per call.
    pub fn peek_unconstrained(&self, name: &str) -> Option<&Tensor> {
        self.entries.get(name).map(|e| &e.unconstrained)
    }

    /// Borrow the unconstrained buffer and the registered constraint in
    /// one map access, without cloning. The frozen-store read path
    /// ([`Ctx::with_frozen_store`](crate::poutine::Ctx::with_frozen_store))
    /// resolves `ctx.param` through this — one lookup, no insert.
    pub fn peek_entry(&self, name: &str) -> Option<(&Tensor, Constraint)> {
        self.entries.get(name).map(|e| (&e.unconstrained, e.constraint))
    }

    /// Register an entry directly in unconstrained space, replacing any
    /// existing entry of the same name. This is the deserialization path
    /// ([`crate::coordinator::load_snapshot`]) — the normal training
    /// entry point stays [`ParamStore::get_or_init`], which inits from a
    /// *constrained* value.
    pub fn insert_unconstrained(
        &mut self,
        name: &str,
        unconstrained: Tensor,
        constraint: Constraint,
    ) {
        self.entries
            .insert(name.to_string(), ParamEntry { unconstrained, constraint });
    }

    /// Mutate a parameter's unconstrained buffer in place — the
    /// optimizer hot path. When the tensor's storage is uniquely held
    /// (true between SVI steps, once the tape is dropped) the update is
    /// allocation-free; shape changes are the caller's responsibility
    /// (optimizers assert grad/param shape agreement).
    pub fn update_unconstrained(&mut self, name: &str, f: impl FnOnce(&mut Tensor)) {
        let e = self
            .entries
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"));
        f(&mut e.unconstrained);
    }

    /// Copy over entries present in `other` but absent here. Parallel
    /// ELBO particles initialize parameters in per-worker store clones;
    /// the first particle's initializations are merged back through
    /// this (deterministic because `ctx.param` init closures are
    /// deterministic per name).
    pub fn merge_missing(&mut self, other: &ParamStore) {
        for (k, v) in &other.entries {
            if !self.entries.contains_key(k) {
                self.entries.insert(k.clone(), v.clone());
            }
        }
    }

    pub fn set_unconstrained(&mut self, name: &str, value: Tensor) {
        let e = self
            .entries
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"));
        assert_eq!(e.unconstrained.dims(), value.dims(), "param '{name}' shape change");
        e.unconstrained = value;
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total scalar parameter count.
    pub fn numel(&self) -> usize {
        self.entries.values().map(|e| e.unconstrained.numel()).sum()
    }

    /// Cheap structural fingerprint: an order-independent hash over
    /// (name, dims) of every entry. Graph-mode SVI compares this each
    /// step to detect externally added/removed/reshaped parameters
    /// without re-tracing the model. Values are deliberately excluded —
    /// they change every optimizer step. Allocation-free.
    pub fn fingerprint(&self) -> u64 {
        let mut acc = 0xcbf2_9ce4_8422_2325u64 ^ (self.entries.len() as u64);
        for (name, e) in &self.entries {
            // FNV-1a per entry, combined with wrapping add so HashMap
            // iteration order cannot affect the result.
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            for b in name.as_bytes() {
                h ^= *b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
            for &d in e.unconstrained.dims() {
                h ^= d as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
            h ^= e.constraint.tag();
            acc = acc.wrapping_add(h.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_once_then_stable() {
        let mut ps = ParamStore::new();
        let a = ps.get_or_init("w", || Tensor::scalar(2.0), Constraint::Real);
        let b = ps.get_or_init("w", || Tensor::scalar(99.0), Constraint::Real);
        assert_eq!(a.item(), b.item());
    }

    #[test]
    fn get_or_init_entry_returns_registered_constraint() {
        let mut ps = ParamStore::new();
        let (v, c) =
            ps.get_or_init_entry("scale", || Tensor::scalar(0.5), Constraint::Positive);
        assert_eq!(c, Constraint::Positive);
        assert!((v.item() - 0.5f64.ln()).abs() < 1e-12);
        // second touch with a different constraint returns the original
        let (_, c2) = ps.get_or_init_entry("scale", || Tensor::scalar(1.0), Constraint::Real);
        assert_eq!(c2, Constraint::Positive);
    }

    #[test]
    fn positive_param_roundtrips_through_log_space() {
        let mut ps = ParamStore::new();
        ps.get_or_init("scale", || Tensor::scalar(0.5), Constraint::Positive);
        // stored unconstrained = ln(0.5)
        assert!((ps.get_unconstrained("scale").unwrap().item() - 0.5f64.ln()).abs() < 1e-12);
        assert!((ps.get("scale").unwrap().item() - 0.5).abs() < 1e-12);
        // gradient step in unconstrained space keeps positivity
        ps.set_unconstrained("scale", Tensor::scalar(-10.0));
        assert!(ps.get("scale").unwrap().item() > 0.0);
    }

    #[test]
    #[should_panic(expected = "violates")]
    fn bad_init_rejected() {
        let mut ps = ParamStore::new();
        ps.get_or_init("scale", || Tensor::scalar(-1.0), Constraint::Positive);
    }

    #[test]
    #[should_panic(expected = "shape change")]
    fn shape_change_rejected() {
        let mut ps = ParamStore::new();
        ps.get_or_init("w", || Tensor::zeros(vec![3]), Constraint::Real);
        ps.set_unconstrained("w", Tensor::zeros(vec![4]));
    }

    #[test]
    fn numel_counts_all() {
        let mut ps = ParamStore::new();
        ps.get_or_init("a", || Tensor::zeros(vec![3, 4]), Constraint::Real);
        ps.get_or_init("b", || Tensor::zeros(vec![5]), Constraint::Real);
        assert_eq!(ps.numel(), 17);
    }

    #[test]
    fn update_in_place_changes_value() {
        let mut ps = ParamStore::new();
        ps.get_or_init("w", || Tensor::scalar(2.0), Constraint::Real);
        ps.update_unconstrained("w", |t| t.scale_inplace(3.0));
        assert!((ps.get_unconstrained("w").unwrap().item() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn merge_missing_keeps_existing_entries() {
        let mut a = ParamStore::new();
        a.get_or_init("x", || Tensor::scalar(1.0), Constraint::Real);
        let mut b = ParamStore::new();
        b.get_or_init("x", || Tensor::scalar(99.0), Constraint::Real);
        b.get_or_init("y", || Tensor::scalar(2.0), Constraint::Real);
        a.merge_missing(&b);
        assert!((a.get("x").unwrap().item() - 1.0).abs() < 1e-12, "existing clobbered");
        assert!((a.get("y").unwrap().item() - 2.0).abs() < 1e-12, "missing not merged");
    }
}
