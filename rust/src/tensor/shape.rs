//! Shapes, strides and broadcasting rules for the tensor substrate.
//!
//! Broadcasting follows NumPy/PyTorch semantics: trailing dimensions are
//! aligned, a dimension of size 1 stretches to match the other operand.

use std::fmt;

/// A tensor shape: dimension sizes, outermost first.
#[derive(Clone, PartialEq, Eq, Hash, Default)]
pub struct Shape(pub Vec<usize>);

impl fmt::Debug for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.0)
    }
}

impl Shape {
    pub fn scalar() -> Self {
        Shape(vec![])
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Row-major (C-order) strides, in elements.
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![0; self.0.len()];
        let mut acc = 1usize;
        for (i, &d) in self.0.iter().enumerate().rev() {
            strides[i] = acc;
            acc *= d;
        }
        strides
    }

    /// Broadcast two shapes together, NumPy-style.
    pub fn broadcast(&self, other: &Shape) -> Option<Shape> {
        let r = self.rank().max(other.rank());
        let mut out = vec![0usize; r];
        for i in 0..r {
            let a = if i < r - self.rank() { 1 } else { self.0[i - (r - self.rank())] };
            let b = if i < r - other.rank() { 1 } else { other.0[i - (r - other.rank())] };
            out[i] = if a == b {
                a
            } else if a == 1 {
                b
            } else if b == 1 {
                a
            } else {
                return None;
            };
        }
        Some(Shape(out))
    }

    /// Linear index -> multi-index under this shape.
    pub fn unravel(&self, mut idx: usize) -> Vec<usize> {
        let mut out = vec![0usize; self.rank()];
        for (i, &d) in self.0.iter().enumerate().rev() {
            out[i] = idx % d;
            idx /= d;
        }
        out
    }

    /// Strides of this shape aligned to a broadcast target shape: one
    /// stride per *output* dimension, with 0 where this shape broadcasts
    /// (missing leading dims, or size-1 dims stretched to match). This
    /// is what lets binary kernels walk both operands with plain
    /// pointer arithmetic instead of per-element `unravel`.
    pub fn broadcast_strides(&self, out: &Shape) -> Vec<usize> {
        debug_assert!(out.rank() >= self.rank());
        let own = self.strides();
        let off = out.rank() - self.rank();
        let mut s = vec![0usize; out.rank()];
        for i in 0..self.rank() {
            s[off + i] = if self.0[i] == 1 && out.0[off + i] != 1 { 0 } else { own[i] };
        }
        s
    }

    /// Multi-index -> linear index, broadcasting this shape against the
    /// index (dimensions of size 1 are pinned to 0).
    pub fn ravel_broadcast(&self, multi: &[usize]) -> usize {
        let offset = multi.len() - self.rank();
        let strides = self.strides();
        let mut idx = 0usize;
        for i in 0..self.rank() {
            let m = if self.0[i] == 1 { 0 } else { multi[i + offset] };
            idx += m * strides[i];
        }
        idx
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Self {
        Shape(v)
    }
}

impl From<&[usize]> for Shape {
    fn from(v: &[usize]) -> Self {
        Shape(v.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_basic() {
        let a = Shape(vec![3, 1]);
        let b = Shape(vec![4]);
        assert_eq!(a.broadcast(&b).unwrap(), Shape(vec![3, 4]));
    }

    #[test]
    fn broadcast_scalar() {
        let a = Shape::scalar();
        let b = Shape(vec![2, 5]);
        assert_eq!(a.broadcast(&b).unwrap(), Shape(vec![2, 5]));
    }

    #[test]
    fn broadcast_fail() {
        let a = Shape(vec![3]);
        let b = Shape(vec![4]);
        assert!(a.broadcast(&b).is_none());
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(Shape(vec![2, 3, 4]).strides(), vec![12, 4, 1]);
    }

    #[test]
    fn unravel_ravel_roundtrip() {
        let s = Shape(vec![2, 3, 4]);
        for i in 0..s.numel() {
            let m = s.unravel(i);
            assert_eq!(s.ravel_broadcast(&m), i);
        }
    }

    #[test]
    fn broadcast_strides_zero_out_stretched_dims() {
        let a = Shape(vec![3, 1]);
        let out = Shape(vec![2, 3, 4]);
        // leading missing dim -> 0; kept dim -> own stride; stretched -> 0
        assert_eq!(a.broadcast_strides(&out), vec![0, 1, 0]);
        let b = Shape(vec![4]);
        assert_eq!(b.broadcast_strides(&out), vec![0, 0, 1]);
        let full = Shape(vec![2, 3, 4]);
        assert_eq!(full.broadcast_strides(&out), full.strides());
    }

    #[test]
    fn broadcast_strides_agree_with_ravel_broadcast() {
        let a = Shape(vec![5, 1, 3]);
        let out = Shape(vec![2, 5, 4, 3]);
        let s = a.broadcast_strides(&out);
        for i in 0..out.numel() {
            let multi = out.unravel(i);
            let via_strides: usize =
                multi.iter().zip(&s).map(|(m, st)| m * st).sum();
            assert_eq!(via_strides, a.ravel_broadcast(&multi));
        }
    }
}
