//! The tensor substrate for Fyro's dynamic execution path.
//!
//! Pyro sits on PyTorch; the offline Rust environment has no tensor
//! library, so Fyro carries its own: a contiguous row-major f64 n-d array
//! with NumPy-style broadcasting, the elementwise/matmul/reduction ops the
//! distributions and autodiff layers need, and a seeded PCG64 RNG.
//!
//! Design notes:
//! - f64 everywhere on the dynamic path: log-prob accumulation and HMC
//!   energies are precision-sensitive and this path is CPU-bound anyway.
//!   The compiled (PJRT) path uses f32 like the paper's GPU code.
//! - Contiguous storage only; broadcasting is materialized through index
//!   arithmetic in the binary-op kernels rather than through views. The
//!   dynamic path works on small-to-medium tensors where this is fine;
//!   big tensors live on the compiled path.

pub mod rng;
pub mod shape;

pub use rng::Pcg64;
pub use shape::Shape;

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// When set, broadcast kernels take the per-element `unravel` reference
/// path instead of the precomputed-stride fast path. The toggle exists
/// so benches can measure the pre-optimization baseline in the same
/// binary and property tests can cross-check both implementations.
static REFERENCE_KERNELS: AtomicBool = AtomicBool::new(false);

/// Force (or release) the reference broadcast kernels globally.
pub fn set_reference_kernels(on: bool) {
    REFERENCE_KERNELS.store(on, Ordering::Relaxed);
}

/// Whether the reference kernels are currently forced.
pub fn reference_kernels() -> bool {
    REFERENCE_KERNELS.load(Ordering::Relaxed)
}

/// Walk an output shape in row-major order evaluating `f(a_i, b_i)`
/// over two stride-broadcast operands. The innermost dimension runs as
/// a unit-stride loop when both operands are contiguous there; outer
/// dimensions advance through an odometer of precomputed strides, so no
/// per-element index arithmetic survives on the hot path.
fn zip_strided(
    a: &[f64],
    ashape: &Shape,
    b: &[f64],
    bshape: &Shape,
    out_shape: &Shape,
    out: &mut Vec<f64>,
    f: impl Fn(f64, f64) -> f64,
) {
    let dims = out_shape.dims();
    let rank = dims.len();
    debug_assert!(rank >= 1);
    let sa = ashape.broadcast_strides(out_shape);
    let sb = bshape.broadcast_strides(out_shape);
    let inner = dims[rank - 1];
    let outer: usize = dims[..rank - 1].iter().product();
    let (step_a, step_b) = (sa[rank - 1], sb[rank - 1]);
    let mut idx = vec![0usize; rank - 1];
    let (mut off_a, mut off_b) = (0usize, 0usize);
    for _ in 0..outer {
        if step_a == 1 && step_b == 1 {
            let ar = &a[off_a..off_a + inner];
            let br = &b[off_b..off_b + inner];
            out.extend(ar.iter().zip(br).map(|(&x, &y)| f(x, y)));
        } else {
            let (mut ia, mut ib) = (off_a, off_b);
            for _ in 0..inner {
                out.push(f(a[ia], b[ib]));
                ia += step_a;
                ib += step_b;
            }
        }
        for d in (0..rank - 1).rev() {
            idx[d] += 1;
            off_a += sa[d];
            off_b += sb[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            off_a -= sa[d] * dims[d];
            off_b -= sb[d] * dims[d];
        }
    }
}

/// Like [`zip_strided`], but writing into a caller-owned slice with
/// *precomputed* broadcast strides and a stack-allocated odometer — the
/// per-element expressions and visit order are identical, so results are
/// bitwise equal to the allocating kernel, and the call itself performs
/// zero heap allocations. Used by the graph-mode executor
/// ([`crate::infer::compile`]) which plans `sa`/`sb` once at compile time.
pub(crate) fn zip_strided_into(
    a: &[f64],
    sa: &[usize],
    b: &[f64],
    sb: &[usize],
    dims: &[usize],
    out: &mut [f64],
    f: impl Fn(f64, f64) -> f64,
) {
    const MAX_RANK: usize = 12;
    let rank = dims.len();
    debug_assert!(rank >= 1 && sa.len() == rank && sb.len() == rank);
    assert!(rank <= MAX_RANK, "zip_strided_into: rank {rank} > {MAX_RANK}");
    let inner = dims[rank - 1];
    let outer: usize = dims[..rank - 1].iter().product();
    let (step_a, step_b) = (sa[rank - 1], sb[rank - 1]);
    let mut idx = [0usize; MAX_RANK];
    let (mut off_a, mut off_b) = (0usize, 0usize);
    let mut w = 0usize;
    for _ in 0..outer {
        if step_a == 1 && step_b == 1 {
            let ar = &a[off_a..off_a + inner];
            let br = &b[off_b..off_b + inner];
            for ((o, &x), &y) in out[w..w + inner].iter_mut().zip(ar).zip(br) {
                *o = f(x, y);
            }
        } else {
            let (mut ia, mut ib) = (off_a, off_b);
            for o in out[w..w + inner].iter_mut() {
                *o = f(a[ia], b[ib]);
                ia += step_a;
                ib += step_b;
            }
        }
        w += inner;
        for d in (0..rank - 1).rev() {
            idx[d] += 1;
            off_a += sa[d];
            off_b += sb[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            off_a -= sa[d] * dims[d];
            off_b -= sb[d] * dims[d];
        }
    }
}

/// A dense row-major f64 tensor.
///
/// Cloning is cheap: storage is behind an `Arc` and copy-on-write is
/// applied by mutating ops.
#[derive(Clone)]
pub struct Tensor {
    data: Arc<Vec<f64>>,
    shape: Shape,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.numel() <= 16 {
            write!(f, "Tensor{:?} {:?}", self.shape, self.data)
        } else {
            write!(
                f,
                "Tensor{:?} [{} elems, first={:.4}]",
                self.shape,
                self.numel(),
                self.data[0]
            )
        }
    }
}

impl Tensor {
    // ---------- constructors ----------

    pub fn new(data: Vec<f64>, shape: impl Into<Shape>) -> Self {
        let shape = shape.into();
        assert_eq!(
            data.len(),
            shape.numel(),
            "data length {} != shape numel {:?}",
            data.len(),
            shape
        );
        Tensor { data: Arc::new(data), shape }
    }

    pub fn scalar(v: f64) -> Self {
        Tensor::new(vec![v], Shape::scalar())
    }

    pub fn zeros(shape: impl Into<Shape>) -> Self {
        let shape = shape.into();
        Tensor { data: Arc::new(vec![0.0; shape.numel()]), shape }
    }

    pub fn ones(shape: impl Into<Shape>) -> Self {
        Tensor::full(shape, 1.0)
    }

    pub fn full(shape: impl Into<Shape>, v: f64) -> Self {
        let shape = shape.into();
        Tensor { data: Arc::new(vec![v; shape.numel()]), shape }
    }

    pub fn from_vec(v: Vec<f64>) -> Self {
        let n = v.len();
        Tensor::new(v, vec![n])
    }

    /// [start, start+step, ...) of length n.
    pub fn arange(n: usize) -> Self {
        Tensor::from_vec((0..n).map(|i| i as f64).collect())
    }

    pub fn randn(shape: impl Into<Shape>, rng: &mut Pcg64) -> Self {
        let shape = shape.into();
        let data: Vec<f64> = (0..shape.numel()).map(|_| rng.normal()).collect();
        Tensor { data: Arc::new(data), shape }
    }

    pub fn rand(shape: impl Into<Shape>, rng: &mut Pcg64) -> Self {
        let shape = shape.into();
        let data: Vec<f64> = (0..shape.numel()).map(|_| rng.uniform()).collect();
        Tensor { data: Arc::new(data), shape }
    }

    // ---------- accessors ----------

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn numel(&self) -> usize {
        self.shape.numel()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Scalar extraction; panics unless numel == 1.
    pub fn item(&self) -> f64 {
        assert_eq!(self.numel(), 1, "item() on tensor with {} elems", self.numel());
        self.data[0]
    }

    pub fn at(&self, multi: &[usize]) -> f64 {
        self.data[self.shape.ravel_broadcast(multi)]
    }

    pub fn to_vec(&self) -> Vec<f64> {
        self.data.as_ref().clone()
    }

    /// Mutable access to storage (copy-on-write).
    pub fn data_mut(&mut self) -> &mut Vec<f64> {
        Arc::make_mut(&mut self.data)
    }

    /// Identity of the backing storage (the `Arc` pointer). Clones and
    /// reshapes share storage and therefore compare equal; any op that
    /// materializes new data gets a fresh pointer. The graph-mode
    /// recorder uses this to match `plate.select` outputs to tape leaves.
    pub fn storage_ptr(&self) -> usize {
        Arc::as_ptr(&self.data) as *const f64 as usize
    }

    /// Copy `src`'s elements into this tensor's storage (flat, row-major).
    /// Requires equal element counts; shapes may differ (reshape-free
    /// refresh of preallocated buffers). Allocation-free when uniquely
    /// held.
    pub fn copy_from(&mut self, src: &Tensor) {
        assert_eq!(self.numel(), src.numel(), "copy_from numel mismatch");
        Arc::make_mut(&mut self.data).copy_from_slice(&src.data);
    }

    /// Refill in place with standard normals — consumes the identical RNG
    /// stream as [`Tensor::randn`] (flat row-major order, one Box–Muller
    /// draw per element), so a refilled buffer is bitwise equal to a
    /// freshly constructed one given the same generator state.
    pub fn fill_randn(&mut self, rng: &mut Pcg64) {
        for v in Arc::make_mut(&mut self.data).iter_mut() {
            *v = rng.normal();
        }
    }

    /// Refill in place with U[0,1) — stream-identical to [`Tensor::rand`].
    pub fn fill_rand(&mut self, rng: &mut Pcg64) {
        for v in Arc::make_mut(&mut self.data).iter_mut() {
            *v = rng.uniform();
        }
    }

    /// Refill in place with U(0,1) open-interval draws (the stream the
    /// inverse-CDF exponential sampler consumes).
    pub fn fill_uniform_open(&mut self, rng: &mut Pcg64) {
        for v in Arc::make_mut(&mut self.data).iter_mut() {
            *v = rng.uniform_open();
        }
    }

    // ---------- shape ops ----------

    pub fn reshape(&self, shape: impl Into<Shape>) -> Tensor {
        let shape = shape.into();
        assert_eq!(self.numel(), shape.numel(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { data: self.data.clone(), shape }
    }

    /// Broadcast-copy this tensor to a target shape.
    pub fn broadcast_to(&self, shape: impl Into<Shape>) -> Tensor {
        let shape = shape.into();
        if self.shape == shape {
            return self.clone();
        }
        assert!(
            self.shape.broadcast(&shape) == Some(shape.clone()),
            "cannot broadcast {:?} to {:?}",
            self.shape,
            shape
        );
        if self.numel() == 1 {
            return Tensor::full(shape, self.data[0]);
        }
        if reference_kernels() {
            let mut out = vec![0.0; shape.numel()];
            for (i, o) in out.iter_mut().enumerate() {
                let multi = shape.unravel(i);
                *o = self.data[self.shape.ravel_broadcast(&multi)];
            }
            return Tensor::new(out, shape);
        }
        let mut out = Vec::with_capacity(shape.numel());
        let zero = [0.0f64];
        let zshape = Shape::scalar();
        zip_strided(&self.data, &self.shape, &zero, &zshape, &shape, &mut out, |a, _| a);
        Tensor { data: Arc::new(out), shape }
    }

    /// Transpose a rank-2 tensor.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2, "t() requires rank 2, got {:?}", self.shape);
        let (r, c) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0.0; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(out, vec![c, r])
    }

    /// [`Tensor::t`] into a preallocated `[c, r]` buffer — allocation-free
    /// transpose for gradient scratch space.
    pub fn transpose_into(&self, out: &mut Tensor) {
        assert_eq!(self.rank(), 2, "transpose_into requires rank 2");
        let (r, c) = (self.dims()[0], self.dims()[1]);
        assert_eq!(out.dims(), &[c, r], "transpose_into output shape");
        let dst = Arc::make_mut(&mut out.data);
        for i in 0..r {
            for j in 0..c {
                dst[j * r + i] = self.data[i * c + j];
            }
        }
    }

    /// Concatenate along axis 0.
    pub fn cat0(tensors: &[&Tensor]) -> Tensor {
        assert!(!tensors.is_empty());
        let tail: Vec<usize> = tensors[0].dims()[1..].to_vec();
        let mut rows = 0usize;
        let mut data = Vec::new();
        for t in tensors {
            assert_eq!(&t.dims()[1..], &tail[..], "cat0 tail mismatch");
            rows += t.dims()[0];
            data.extend_from_slice(t.data());
        }
        let mut dims = vec![rows];
        dims.extend_from_slice(&tail);
        Tensor::new(data, dims)
    }

    /// Stack scalars/vectors along a new axis 0.
    pub fn stack0(tensors: &[&Tensor]) -> Tensor {
        assert!(!tensors.is_empty());
        let inner = tensors[0].dims().to_vec();
        let mut data = Vec::with_capacity(tensors.len() * tensors[0].numel());
        for t in tensors {
            assert_eq!(t.dims(), &inner[..], "stack0 shape mismatch");
            data.extend_from_slice(t.data());
        }
        let mut dims = vec![tensors.len()];
        dims.extend_from_slice(&inner);
        Tensor::new(data, dims)
    }

    /// Select row i along axis 0 (returns a copy with that axis dropped).
    pub fn row(&self, i: usize) -> Tensor {
        assert!(self.rank() >= 1 && i < self.dims()[0]);
        let stride: usize = self.dims()[1..].iter().product();
        let data = self.data[i * stride..(i + 1) * stride].to_vec();
        Tensor::new(data, self.dims()[1..].to_vec())
    }

    /// Contiguous slice along the last axis: out[..., j] = self[..., offset+j].
    pub fn narrow_last(&self, offset: usize, len: usize) -> Tensor {
        let last = *self.dims().last().unwrap();
        assert!(offset + len <= last, "narrow_last {offset}+{len} > {last}");
        let outer = self.numel() / last;
        let mut data = Vec::with_capacity(outer * len);
        for i in 0..outer {
            data.extend_from_slice(&self.data[i * last + offset..i * last + offset + len]);
        }
        let mut dims = self.dims().to_vec();
        *dims.last_mut().unwrap() = len;
        Tensor::new(data, dims)
    }

    /// Gather one element per row along the last axis:
    /// out[i] = self[i, idx[i]] for self flattened to [outer, last].
    /// The result keeps the leading (batch) dims.
    pub fn gather_last(&self, idx: &[usize]) -> Tensor {
        let last = *self.dims().last().unwrap();
        let outer = self.numel() / last;
        assert_eq!(idx.len(), outer, "gather_last: {} indices for {} rows", idx.len(), outer);
        let data: Vec<f64> = idx
            .iter()
            .enumerate()
            .map(|(i, &j)| {
                assert!(j < last, "gather_last index {j} out of range {last}");
                self.data[i * last + j]
            })
            .collect();
        Tensor::new(data, self.dims()[..self.rank() - 1].to_vec())
    }

    /// Gather rows along axis 0.
    pub fn index_select0(&self, idx: &[usize]) -> Tensor {
        let stride: usize = self.dims()[1..].iter().product();
        let mut data = Vec::with_capacity(idx.len() * stride);
        for &i in idx {
            assert!(i < self.dims()[0]);
            data.extend_from_slice(&self.data[i * stride..(i + 1) * stride]);
        }
        let mut dims = vec![idx.len()];
        dims.extend_from_slice(&self.dims()[1..]);
        Tensor::new(data, dims)
    }

    /// [`Tensor::index_select0`] into a preallocated `[idx.len(), ...]`
    /// buffer — allocation-free row gather for the graph-mode minibatch
    /// refresh.
    pub fn index_select0_into(&self, idx: &[usize], out: &mut Tensor) {
        let stride: usize = self.dims()[1..].iter().product();
        assert_eq!(out.numel(), idx.len() * stride, "index_select0_into shape");
        let dst = Arc::make_mut(&mut out.data);
        for (r, &i) in idx.iter().enumerate() {
            assert!(i < self.dims()[0]);
            dst[r * stride..(r + 1) * stride]
                .copy_from_slice(&self.data[i * stride..(i + 1) * stride]);
        }
    }

    // ---------- elementwise binary ----------

    fn zip(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        if self.shape == other.shape {
            // Aligned iteration: no index arithmetic at all.
            let data: Vec<f64> = self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect();
            return Tensor { data: Arc::new(data), shape: self.shape.clone() };
        }
        if reference_kernels() {
            return self.zip_reference(other, f);
        }
        let shape = self
            .shape
            .broadcast(&other.shape)
            .unwrap_or_else(|| panic!("broadcast {:?} vs {:?}", self.shape, other.shape));
        // Scalar-operand fast paths: a single dense sweep.
        if self.numel() == 1 {
            let a = self.data[0];
            let data: Vec<f64> = other.data.iter().map(|&b| f(a, b)).collect();
            return Tensor { data: Arc::new(data), shape };
        }
        if other.numel() == 1 {
            let b = other.data[0];
            let data: Vec<f64> = self.data.iter().map(|&a| f(a, b)).collect();
            return Tensor { data: Arc::new(data), shape };
        }
        let mut out = Vec::with_capacity(shape.numel());
        zip_strided(&self.data, &self.shape, &other.data, &other.shape, &shape, &mut out, f);
        Tensor { data: Arc::new(out), shape }
    }

    /// Reference broadcast kernel: per-element `unravel`/`ravel_broadcast`
    /// index arithmetic, O(rank) work per element. Kept as the bitwise
    /// oracle for the strided fast path (property tests) and as the
    /// measurable pre-optimization baseline (fig3 bench).
    pub fn zip_reference(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        let shape = self
            .shape
            .broadcast(&other.shape)
            .unwrap_or_else(|| panic!("broadcast {:?} vs {:?}", self.shape, other.shape));
        let mut out = vec![0.0; shape.numel()];
        for (i, o) in out.iter_mut().enumerate() {
            let multi = shape.unravel(i);
            let a = self.data[self.shape.ravel_broadcast(&multi)];
            let b = other.data[other.shape.ravel_broadcast(&multi)];
            *o = f(a, b);
        }
        Tensor::new(out, shape)
    }

    // ---------- elementwise in-place (copy-on-write) ----------

    /// `self op= other` with `other` broadcast into `self`'s shape.
    /// Requires that broadcasting does not grow the result beyond
    /// `self`'s shape. Storage is mutated through `Arc::make_mut`, so a
    /// uniquely-held tensor updates with zero allocations.
    fn zip_assign(&mut self, other: &Tensor, f: impl Fn(f64, f64) -> f64) {
        assert!(
            self.shape.broadcast(&other.shape).as_ref() == Some(&self.shape),
            "in-place op: {:?} cannot absorb {:?}",
            self.shape,
            other.shape
        );
        if self.shape == other.shape {
            let dst = Arc::make_mut(&mut self.data);
            for (d, &s) in dst.iter_mut().zip(other.data.iter()) {
                *d = f(*d, s);
            }
            return;
        }
        if other.numel() == 1 {
            let b = other.data[0];
            let dst = Arc::make_mut(&mut self.data);
            for d in dst.iter_mut() {
                *d = f(*d, b);
            }
            return;
        }
        let shape = self.shape.clone();
        let dims = shape.dims();
        let rank = dims.len();
        let sb = other.shape.broadcast_strides(&shape);
        let inner = dims[rank - 1];
        let outer: usize = dims[..rank - 1].iter().product();
        let step_b = sb[rank - 1];
        let src = &other.data;
        let dst = Arc::make_mut(&mut self.data);
        let mut idx = vec![0usize; rank - 1];
        let mut off_b = 0usize;
        for row in 0..outer {
            let drow = &mut dst[row * inner..(row + 1) * inner];
            let mut ib = off_b;
            for d in drow.iter_mut() {
                *d = f(*d, src[ib]);
                ib += step_b;
            }
            for di in (0..rank - 1).rev() {
                idx[di] += 1;
                off_b += sb[di];
                if idx[di] < dims[di] {
                    break;
                }
                idx[di] = 0;
                off_b -= sb[di] * dims[di];
            }
        }
    }

    /// In-place `self += other` (gradient accumulation hot path).
    pub fn add_assign(&mut self, other: &Tensor) {
        self.zip_assign(other, |a, b| a + b);
    }

    /// In-place `self -= other`.
    pub fn sub_assign(&mut self, other: &Tensor) {
        self.zip_assign(other, |a, b| a - b);
    }

    /// In-place `self += alpha * x` (fused scale-accumulate).
    pub fn axpy(&mut self, alpha: f64, x: &Tensor) {
        self.zip_assign(x, move |a, b| a + alpha * b);
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f64) -> f64) {
        for v in Arc::make_mut(&mut self.data).iter_mut() {
            *v = f(*v);
        }
    }

    /// In-place `self *= s`.
    pub fn scale_inplace(&mut self, s: f64) {
        self.map_inplace(|v| v * s);
    }

    pub fn add(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a + b)
    }
    pub fn sub(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a - b)
    }
    pub fn mul(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a * b)
    }
    pub fn div(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a / b)
    }
    pub fn pow(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a.powf(b))
    }
    pub fn maximum(&self, o: &Tensor) -> Tensor {
        self.zip(o, f64::max)
    }
    pub fn minimum(&self, o: &Tensor) -> Tensor {
        self.zip(o, f64::min)
    }
    /// 1.0 where self > other else 0.0 (broadcasting).
    pub fn gt(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| if a > b { 1.0 } else { 0.0 })
    }

    /// Elementwise binary op into a preallocated output buffer, with
    /// `zip`'s exact fast-path structure (same-shape sweep, scalar
    /// operand sweeps, strided odometer) so results are bitwise equal to
    /// the allocating path — but zero heap allocations when broadcast
    /// strides are precomputed by the caller. `sa`/`sb` must be
    /// `broadcast_strides` of the operands against `out`'s shape (they
    /// are ignored on the fast paths).
    pub fn zip_into_planned(
        &self,
        other: &Tensor,
        out: &mut Tensor,
        sa: &[usize],
        sb: &[usize],
        f: impl Fn(f64, f64) -> f64,
    ) {
        if self.shape == other.shape {
            debug_assert_eq!(out.numel(), self.numel());
            let dst = Arc::make_mut(&mut out.data);
            for ((o, &a), &b) in dst.iter_mut().zip(self.data.iter()).zip(other.data.iter()) {
                *o = f(a, b);
            }
            return;
        }
        if self.numel() == 1 {
            let a = self.data[0];
            debug_assert_eq!(out.numel(), other.numel());
            let dst = Arc::make_mut(&mut out.data);
            for (o, &b) in dst.iter_mut().zip(other.data.iter()) {
                *o = f(a, b);
            }
            return;
        }
        if other.numel() == 1 {
            let b = other.data[0];
            debug_assert_eq!(out.numel(), self.numel());
            let dst = Arc::make_mut(&mut out.data);
            for (o, &a) in dst.iter_mut().zip(self.data.iter()) {
                *o = f(a, b);
            }
            return;
        }
        // Disjoint field borrows: no Shape clone on the strided path.
        let Tensor { data, shape } = out;
        let dst = Arc::make_mut(data);
        zip_strided_into(&self.data, sa, &other.data, sb, shape.dims(), dst, f);
    }

    // ---------- elementwise unary ----------

    pub fn map(&self, f: impl Fn(f64) -> f64) -> Tensor {
        let data: Vec<f64> = self.data.iter().map(|&a| f(a)).collect();
        Tensor { data: Arc::new(data), shape: self.shape.clone() }
    }

    /// Elementwise unary map into a preallocated buffer (equal numel).
    pub fn map_into(&self, out: &mut Tensor, f: impl Fn(f64) -> f64) {
        assert_eq!(self.numel(), out.numel(), "map_into numel mismatch");
        let dst = Arc::make_mut(&mut out.data);
        for (o, &a) in dst.iter_mut().zip(self.data.iter()) {
            *o = f(a);
        }
    }

    pub fn neg(&self) -> Tensor {
        self.map(|a| -a)
    }
    pub fn exp(&self) -> Tensor {
        self.map(f64::exp)
    }
    pub fn ln(&self) -> Tensor {
        self.map(f64::ln)
    }
    pub fn sqrt(&self) -> Tensor {
        self.map(f64::sqrt)
    }
    pub fn abs(&self) -> Tensor {
        self.map(f64::abs)
    }
    pub fn tanh(&self) -> Tensor {
        self.map(f64::tanh)
    }
    pub fn sigmoid(&self) -> Tensor {
        self.map(|a| 1.0 / (1.0 + (-a).exp()))
    }
    pub fn relu(&self) -> Tensor {
        self.map(|a| a.max(0.0))
    }
    pub fn softplus(&self) -> Tensor {
        // Numerically stable: log(1 + e^x) = max(x,0) + log1p(e^{-|x|})
        self.map(|a| a.max(0.0) + (-a.abs()).exp().ln_1p())
    }
    pub fn lgamma(&self) -> Tensor {
        self.map(crate::tensor::lgamma)
    }
    pub fn digamma(&self) -> Tensor {
        self.map(crate::tensor::digamma)
    }
    pub fn square(&self) -> Tensor {
        self.map(|a| a * a)
    }
    pub fn tan(&self) -> Tensor {
        self.map(f64::tan)
    }
    pub fn sign(&self) -> Tensor {
        self.map(|a| {
            if a > 0.0 {
                1.0
            } else if a < 0.0 {
                -1.0
            } else {
                0.0
            }
        })
    }
    pub fn add_scalar(&self, s: f64) -> Tensor {
        self.map(|a| a + s)
    }
    pub fn mul_scalar(&self, s: f64) -> Tensor {
        self.map(|a| a * s)
    }

    // ---------- reductions ----------

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        self.sum() / self.numel() as f64
    }

    pub fn max_val(&self) -> f64 {
        self.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min_val(&self) -> f64 {
        self.data.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Sum over the last axis.
    pub fn sum_last(&self) -> Tensor {
        assert!(self.rank() >= 1);
        let last = *self.dims().last().unwrap();
        let outer = self.numel() / last;
        let mut out = vec![0.0; outer];
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.data[i * last..(i + 1) * last].iter().sum();
        }
        Tensor::new(out, self.dims()[..self.rank() - 1].to_vec())
    }

    /// [`Tensor::sum_last`] into a preallocated buffer — identical
    /// accumulation order, zero allocations.
    pub fn sum_last_into(&self, out: &mut Tensor) {
        let last = *self.dims().last().unwrap();
        let outer = self.numel() / last;
        assert_eq!(out.numel(), outer, "sum_last_into shape");
        let dst = Arc::make_mut(&mut out.data);
        for (i, o) in dst.iter_mut().enumerate() {
            *o = self.data[i * last..(i + 1) * last].iter().sum();
        }
    }

    /// Sum over axis 0.
    pub fn sum0(&self) -> Tensor {
        assert!(self.rank() >= 1);
        let n0 = self.dims()[0];
        let inner = self.numel() / n0;
        let mut out = vec![0.0; inner];
        for i in 0..n0 {
            for j in 0..inner {
                out[j] += self.data[i * inner + j];
            }
        }
        Tensor::new(out, self.dims()[1..].to_vec())
    }

    /// [`Tensor::sum0`] into a preallocated buffer — identical
    /// accumulation order, zero allocations.
    pub fn sum0_into(&self, out: &mut Tensor) {
        let n0 = self.dims()[0];
        let inner = self.numel() / n0;
        assert_eq!(out.numel(), inner, "sum0_into shape");
        let dst = Arc::make_mut(&mut out.data);
        dst.fill(0.0);
        for i in 0..n0 {
            for (j, o) in dst.iter_mut().enumerate() {
                *o += self.data[i * inner + j];
            }
        }
    }

    /// Max over the last axis, keeping it as size 1.
    pub fn max_last_keepdim(&self) -> Tensor {
        let last = *self.dims().last().unwrap();
        let outer = self.numel() / last;
        let mut out = vec![0.0; outer];
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.data[i * last..(i + 1) * last]
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max);
        }
        let mut dims = self.dims().to_vec();
        *dims.last_mut().unwrap() = 1;
        Tensor::new(out, dims)
    }

    /// log(sum(exp(x))) over all elements, numerically stable.
    pub fn logsumexp(&self) -> f64 {
        let m = self.max_val();
        if m.is_infinite() {
            return m;
        }
        m + self.data.iter().map(|&a| (a - m).exp()).sum::<f64>().ln()
    }

    /// log-softmax over the last axis.
    pub fn log_softmax_last(&self) -> Tensor {
        let last = *self.dims().last().unwrap();
        let outer = self.numel() / last;
        let mut out = vec![0.0; self.numel()];
        for i in 0..outer {
            let row = &self.data[i * last..(i + 1) * last];
            let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lse = m + row.iter().map(|&a| (a - m).exp()).sum::<f64>().ln();
            for j in 0..last {
                out[i * last + j] = row[j] - lse;
            }
        }
        Tensor::new(out, self.dims().to_vec())
    }

    /// argmax over the last axis.
    pub fn argmax_last(&self) -> Vec<usize> {
        let last = *self.dims().last().unwrap();
        let outer = self.numel() / last;
        (0..outer)
            .map(|i| {
                let row = &self.data[i * last..(i + 1) * last];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect()
    }

    // ---------- linear algebra ----------

    /// Matrix multiply. Supports [m,k]x[k,n], [k]x[k,n], [m,k]x[k],
    /// and batched [b,m,k]x[k,n].
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        match (self.rank(), other.rank()) {
            (2, 2) => self.mm2(other),
            (1, 2) => {
                let r = self.reshape(vec![1, self.numel()]).mm2(other);
                let n = r.dims()[1];
                r.reshape(vec![n])
            }
            (2, 1) => {
                let k = other.numel();
                let r = self.mm2(&other.reshape(vec![k, 1]));
                let m = r.dims()[0];
                r.reshape(vec![m])
            }
            (3, 2) => {
                let (b, m, k) = (self.dims()[0], self.dims()[1], self.dims()[2]);
                let flat = self.reshape(vec![b * m, k]).mm2(other);
                let n = flat.dims()[1];
                flat.reshape(vec![b, m, n])
            }
            _ => panic!("matmul: unsupported ranks {:?} x {:?}", self.shape, other.shape),
        }
    }

    fn mm2(&self, other: &Tensor) -> Tensor {
        let (m, n) = (self.dims()[0], other.dims()[1]);
        let mut out = Tensor::zeros(vec![m, n]);
        self.matmul_into(other, &mut out);
        out
    }

    /// Blocked rank-2 matmul into a caller-owned `[m, n]` output buffer
    /// (zeroed here): the allocation-free path for loops that reuse a
    /// scratch tensor across steps. ikj order with i/k tiling keeps the
    /// `b`-row and `out`-row accesses unit-stride and cache-resident.
    ///
    /// Unlike the previous kernel there is **no** zero-skip on `a[i,k]`:
    /// IEEE exceptional values must propagate (`0.0 * NaN` is NaN). Use
    /// [`Tensor::matmul_sparse_lhs`] when a sparsity shortcut is wanted.
    pub fn matmul_into(&self, other: &Tensor, out: &mut Tensor) {
        let (m, k) = (self.dims()[0], self.dims()[1]);
        let (k2, n) = (other.dims()[0], other.dims()[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        assert_eq!(out.dims(), &[m, n], "matmul_into output shape");
        const BI: usize = 32;
        const BK: usize = 64;
        let a = &self.data;
        let b = &other.data;
        let o = Arc::make_mut(&mut out.data);
        o.fill(0.0);
        for ib in (0..m).step_by(BI) {
            let ie = (ib + BI).min(m);
            for kb in (0..k).step_by(BK) {
                let ke = (kb + BK).min(k);
                for i in ib..ie {
                    let arow = &a[i * k..(i + 1) * k];
                    let orow = &mut o[i * n..(i + 1) * n];
                    for kk in kb..ke {
                        let aik = arow[kk];
                        let brow = &b[kk * n..(kk + 1) * n];
                        for (oj, &bj) in orow.iter_mut().zip(brow) {
                            *oj += aik * bj;
                        }
                    }
                }
            }
        }
    }

    /// Rank-2 matmul that skips zero entries of `self` — worthwhile for
    /// one-hot / highly sparse left operands. Explicitly opt-in because
    /// the skip silently drops NaN/Inf propagation from `other` wherever
    /// `self` is exactly 0.0; the dense paths never do this.
    pub fn matmul_sparse_lhs(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.dims()[0], self.dims()[1]);
        let (k2, n) = (other.dims()[0], other.dims()[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let a = self.data();
        let b = other.data();
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for kk in 0..k {
                let aik = a[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for (oj, &bj) in orow.iter_mut().zip(brow) {
                    *oj += aik * bj;
                }
            }
        }
        Tensor::new(out, vec![m, n])
    }

    pub fn dot(&self, other: &Tensor) -> f64 {
        assert_eq!(self.numel(), other.numel());
        self.data.iter().zip(other.data.iter()).map(|(&a, &b)| a * b).sum()
    }

    /// Max-abs difference, for tests.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn allclose(&self, other: &Tensor, tol: f64) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= tol
    }
}

/// Log-gamma via the Lanczos approximation (g=7, n=9), |err| < 1e-13 on
/// the positive real axis; reflected for x < 0.5.
pub fn lgamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let s = (std::f64::consts::PI * x).sin();
        return std::f64::consts::PI.ln() - s.abs().ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Digamma (ψ) via asymptotic series with recurrence shift.
pub fn digamma(mut x: f64) -> f64 {
    let mut result = 0.0;
    while x < 6.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + x.ln() - 0.5 * inv
        - inv2 * (1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 / 240.0)))
}

/// log of the Beta function.
pub fn lbeta(a: f64, b: f64) -> f64 {
    lgamma(a) + lgamma(b) - lgamma(a + b)
}

/// Error function, Abramowitz & Stegun 7.1.26 (|err| < 1.5e-7) — ample for
/// the CDF evaluations the library needs.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn std_normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_broadcast() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0], vec![3, 1]);
        let b = Tensor::new(vec![10.0, 20.0], vec![2]);
        let c = a.add(&b);
        assert_eq!(c.dims(), &[3, 2]);
        assert_eq!(c.to_vec(), vec![11.0, 21.0, 12.0, 22.0, 13.0, 23.0]);
    }

    #[test]
    fn matmul_2x2() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let b = Tensor::new(vec![5.0, 6.0, 7.0, 8.0], vec![2, 2]);
        let c = a.matmul(&b);
        assert_eq!(c.to_vec(), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_vec() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let v = Tensor::from_vec(vec![1.0, 1.0, 1.0]);
        let r = a.matmul(&v);
        assert_eq!(r.to_vec(), vec![6.0, 15.0]);
    }

    #[test]
    fn transpose() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let t = a.t();
        assert_eq!(t.dims(), &[3, 2]);
        assert_eq!(t.to_vec(), vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn sum_last_and_sum0() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        assert_eq!(a.sum_last().to_vec(), vec![6.0, 15.0]);
        assert_eq!(a.sum0().to_vec(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn logsumexp_stable() {
        let a = Tensor::from_vec(vec![1000.0, 1000.0]);
        assert!((a.logsumexp() - (1000.0 + 2.0_f64.ln())).abs() < 1e-9);
    }

    #[test]
    fn log_softmax_rows_normalize() {
        let a = Tensor::new(vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0], vec![2, 3]);
        let ls = a.log_softmax_last();
        for i in 0..2 {
            let s: f64 = ls.row(i).exp().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn lgamma_known_values() {
        assert!((lgamma(1.0)).abs() < 1e-10);
        assert!((lgamma(2.0)).abs() < 1e-10);
        assert!((lgamma(5.0) - 24.0_f64.ln()).abs() < 1e-9);
        assert!((lgamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }

    #[test]
    fn digamma_known_values() {
        // psi(1) = -gamma
        assert!((digamma(1.0) + 0.5772156649015329).abs() < 1e-9);
        // psi(x+1) = psi(x) + 1/x
        assert!((digamma(3.5) - digamma(2.5) - 1.0 / 2.5).abs() < 1e-9);
    }

    #[test]
    fn erf_symmetry_and_values() {
        assert!(erf(0.0).abs() < 1e-8);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 2e-7);
        assert!((erf(-1.0) + erf(1.0)).abs() < 1e-12);
    }

    #[test]
    fn softplus_stable() {
        let big = Tensor::scalar(800.0);
        assert!((big.softplus().item() - 800.0).abs() < 1e-9);
        let small = Tensor::scalar(-800.0);
        assert!(small.softplus().item() >= 0.0);
        assert!(small.softplus().item() < 1e-300);
    }

    #[test]
    fn index_select_rows() {
        let a = Tensor::new((0..12).map(|i| i as f64).collect(), vec![4, 3]);
        let s = a.index_select0(&[2, 0]);
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.to_vec(), vec![6.0, 7.0, 8.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn stack_and_cat() {
        let a = Tensor::from_vec(vec![1.0, 2.0]);
        let b = Tensor::from_vec(vec![3.0, 4.0]);
        let s = Tensor::stack0(&[&a, &b]);
        assert_eq!(s.dims(), &[2, 2]);
        let c = Tensor::cat0(&[&s, &s]);
        assert_eq!(c.dims(), &[4, 2]);
    }

    #[test]
    fn strided_zip_matches_reference_on_awkward_shapes() {
        let mut rng = Pcg64::new(0x57A1D);
        let cases: [(&[usize], &[usize]); 6] = [
            (&[3, 1], &[2]),
            (&[1, 4], &[5, 1]),
            (&[2, 1, 3], &[4, 1]),
            (&[6], &[1]),
            (&[2, 3, 4], &[3, 1]),
            (&[1, 1, 1], &[2, 2, 2]),
        ];
        for (da, db) in cases {
            let a = Tensor::randn(da.to_vec(), &mut rng);
            let b = Tensor::randn(db.to_vec(), &mut rng);
            for f in [
                (|x: f64, y: f64| x + y) as fn(f64, f64) -> f64,
                |x, y| x * y,
                |x, y| x - y,
            ] {
                let fast = a.zip(&b, f);
                let slow = a.zip_reference(&b, f);
                assert_eq!(fast.dims(), slow.dims());
                assert_eq!(fast.to_vec(), slow.to_vec(), "shapes {da:?} x {db:?}");
            }
        }
    }

    #[test]
    fn broadcast_to_matches_reference() {
        // oracle through the reference kernel directly — the global
        // toggle is left alone so concurrent tests are unaffected
        let mut rng = Pcg64::new(0xB0A);
        let a = Tensor::randn(vec![4, 1, 3], &mut rng);
        let fast = a.broadcast_to(vec![2, 4, 5, 3]);
        let slow = a.zip_reference(&Tensor::ones(vec![2, 4, 5, 3]), |x, _| x);
        assert_eq!(fast.dims(), slow.dims());
        assert_eq!(fast.to_vec(), slow.to_vec());
    }

    #[test]
    fn add_assign_matches_add() {
        let mut rng = Pcg64::new(0xADD);
        let a = Tensor::randn(vec![3, 4], &mut rng);
        let cases = [
            Tensor::randn(vec![3, 4], &mut rng),
            Tensor::randn(vec![4], &mut rng),
            Tensor::randn(vec![3, 1], &mut rng),
            Tensor::scalar(2.5),
        ];
        for b in cases {
            let want = a.add(&b);
            let mut got = a.clone();
            got.add_assign(&b);
            assert_eq!(got.to_vec(), want.to_vec());
        }
    }

    #[test]
    fn axpy_and_scale_inplace() {
        let mut x = Tensor::from_vec(vec![1.0, 2.0, 3.0]);
        x.axpy(0.5, &Tensor::from_vec(vec![2.0, 4.0, 6.0]));
        assert_eq!(x.to_vec(), vec![2.0, 4.0, 6.0]);
        x.scale_inplace(0.5);
        assert_eq!(x.to_vec(), vec![1.0, 2.0, 3.0]);
        x.map_inplace(|v| v * v);
        assert_eq!(x.to_vec(), vec![1.0, 4.0, 9.0]);
    }

    #[test]
    fn inplace_ops_respect_copy_on_write() {
        let a = Tensor::from_vec(vec![1.0, 2.0]);
        let mut b = a.clone(); // shares storage
        b.add_assign(&Tensor::from_vec(vec![10.0, 10.0]));
        assert_eq!(a.to_vec(), vec![1.0, 2.0], "shared storage mutated");
        assert_eq!(b.to_vec(), vec![11.0, 12.0]);
    }

    #[test]
    fn matmul_propagates_nan_from_zero_lhs() {
        // 0.0 * NaN must be NaN on the dense path (IEEE semantics)
        let a = Tensor::new(vec![0.0, 1.0], vec![1, 2]);
        let b = Tensor::new(vec![f64::NAN, 2.0], vec![2, 1]);
        let c = a.matmul(&b);
        assert!(c.data()[0].is_nan(), "dense matmul dropped NaN: {c:?}");
        // the explicit sparse variant documents the opposite trade
        let s = a.matmul_sparse_lhs(&b);
        assert_eq!(s.data()[0], 2.0);
    }

    #[test]
    fn matmul_into_reuses_buffer_and_matches() {
        let mut rng = Pcg64::new(0x3E3);
        let a = Tensor::randn(vec![7, 5], &mut rng);
        let b = Tensor::randn(vec![5, 9], &mut rng);
        let want = a.matmul(&b);
        let mut out = Tensor::full(vec![7, 9], 123.0); // stale contents
        a.matmul_into(&b, &mut out);
        assert!(out.allclose(&want, 0.0));
    }

    #[test]
    fn blocked_matmul_matches_naive_on_large_k() {
        // k > block size exercises the tile seams
        let mut rng = Pcg64::new(0xB10C);
        let a = Tensor::randn(vec![3, 150], &mut rng);
        let b = Tensor::randn(vec![150, 4], &mut rng);
        let naive = {
            let mut out = vec![0.0; 3 * 4];
            for i in 0..3 {
                for j in 0..4 {
                    for kk in 0..150 {
                        out[i * 4 + j] += a.data()[i * 150 + kk] * b.data()[kk * 4 + j];
                    }
                }
            }
            Tensor::new(out, vec![3, 4])
        };
        assert!(a.matmul(&b).allclose(&naive, 1e-10));
    }
}
