//! Pseudo-random number generation for the dynamic execution path.
//!
//! The offline environment has no `rand` crate, so Fyro carries its own
//! PCG64 generator (O'Neill 2014, PCG-XSL-RR 128/64) plus the standard
//! transforms used by the distributions library: Box–Muller normals,
//! Marsaglia–Tsang gamma, inverse-CDF exponential, alias-free categorical.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// `PartialEq` compares generator state: two generators seeded alike are
/// equal iff they have consumed the same number of values. Graph-mode
/// compilation uses this to prove its recorded input schedule accounts
/// for *every* RNG draw of the traced execution.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed the generator. Two generators with the same seed produce the
    /// same stream — inference results are reproducible given a seed.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(0xda3e39cb94b95bdb_u128 ^ ((seed as u128) << 64));
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1) — never exactly zero, safe for logs.
    #[inline]
    pub fn uniform_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's nearly-divisionless method would be overkill here; the
        // modulo bias for n << 2^64 is negligible for our workloads.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; the pair's
    /// second member is discarded to keep the stream stateless).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform_open();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang squeeze (alpha >= 1), with the
    /// boost trick for alpha < 1.
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // G(a) = G(a+1) * U^{1/a}
            let u = self.uniform_open();
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform_open();
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Exponential(rate) via inverse CDF.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform_open().ln() / rate
    }

    /// Poisson(lambda): Knuth for small lambda, PTRS-ish normal cutoff for
    /// large lambda (approximate; fine for the workloads here).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction, clipped at 0.
            let x = lambda + lambda.sqrt() * self.normal() + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle_indices(&mut v);
        v
    }

    /// [`Pcg64::permutation`] into a caller-owned buffer — consumes the
    /// identical RNG stream, allocation-free once `buf` has capacity `n`.
    pub fn permutation_into(&mut self, n: usize, buf: &mut Vec<usize>) {
        buf.clear();
        buf.extend(0..n);
        self.shuffle_indices(buf);
    }

    fn shuffle_indices(&mut self, v: &mut [usize]) {
        let n = v.len();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Fork a child generator with a decorrelated stream (used by plates
    /// and by parallel chains).
    pub fn fork(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Pcg64::new(13);
        for &alpha in &[0.5, 1.0, 2.5, 9.0] {
            let n = 100_000;
            let xs: Vec<f64> = (0..n).map(|_| rng.gamma(alpha)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            assert!(
                (mean - alpha).abs() < 0.08 * alpha.max(1.0),
                "alpha {alpha} mean {mean}"
            );
        }
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Pcg64::new(17);
        for &lam in &[0.5, 4.0, 80.0] {
            let n = 50_000;
            let m = (0..n).map(|_| rng.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((m - lam).abs() < 0.1 * lam.max(1.0), "lam {lam} mean {m}");
        }
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Pcg64::new(19);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.categorical(&w)] += 1;
        }
        for i in 0..3 {
            let p = w[i] / 10.0;
            let f = counts[i] as f64 / n as f64;
            assert!((f - p).abs() < 0.01, "i {i} freq {f} expected {p}");
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Pcg64::new(23);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
