//! Closed-form KL divergences (`torch.distributions.kl` analog).
//!
//! Only the pairs the inference layer actually exploits live here;
//! [`super::try_analytic_kl`] is the runtime registry lookup over
//! type-erased site distributions.

use super::{Field, Normal};

/// KL(q ‖ p) for two (broadcastable) Gaussians, elementwise:
/// ln(σp/σq) + (σq² + (μq-μp)²) / (2σp²) − ½.
pub fn kl_normal_normal<F: Field>(q: &Normal<F>, p: &Normal<F>) -> F {
    let var_ratio = q.scale.div(&p.scale).square();
    let t1 = q.loc.sub(&p.loc).div(&p.scale).square();
    var_ratio
        .add(&t1)
        .sub(&var_ratio.ln())
        .add_scalar(-1.0)
        .mul_scalar(0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Pcg64, Tensor};

    #[test]
    fn kl_zero_iff_equal() {
        let p = Normal::std(0.3, 1.2);
        let q = Normal::std(0.3, 1.2);
        assert!(kl_normal_normal(&q, &p).item().abs() < 1e-12);
        let r = Normal::std(0.9, 0.7);
        assert!(kl_normal_normal(&r, &p).item() > 0.0);
    }

    #[test]
    fn kl_matches_monte_carlo() {
        use crate::dist::Dist;
        let q = Normal::std(0.5, 0.8);
        let p = Normal::std(-0.2, 1.4);
        let analytic = kl_normal_normal(&q, &p).item();
        let mut rng = Pcg64::new(1);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let x: Tensor = q.sample(&mut rng);
            acc += q.log_prob(&x).item() - p.log_prob(&x).item();
        }
        let mc = acc / n as f64;
        assert!((analytic - mc).abs() < 0.01, "{analytic} vs {mc}");
    }

    #[test]
    fn kl_is_asymmetric() {
        let q = Normal::std(0.0, 0.5);
        let p = Normal::std(0.0, 2.0);
        let a = kl_normal_normal(&q, &p).item();
        let b = kl_normal_normal(&p, &q).item();
        assert!((a - b).abs() > 0.1, "{a} vs {b}");
    }
}
