//! The distributions library — Fyro's `pyro.distributions`.
//!
//! Every distribution is generic over a [`Field`]: the numeric carrier
//! type of its parameters and samples. Two fields exist:
//!
//! - [`Tensor`] — concrete evaluation (tests, diagnostics, MCMC oracles);
//! - [`Var`] — tape-recorded evaluation, so `log_prob` is differentiable
//!   and reparameterized `sample` calls are pathwise-differentiable
//!   through their parameters (the `rsample` semantics SVI needs).
//!
//! [`IntoVarDist`] lifts a `Dist<Tensor>` onto a tape (its parameters
//! become constants) so model code can write `Normal::std(0.0, 1.0)`
//! and hand it straight to `ctx.sample`.
//!
//! [`Constraint`] carries each distribution's support plus the
//! `biject_to`-style transform pair the param store and autoguides use.

pub mod kl;

use crate::autodiff::{Tape, Var};
use crate::tensor::{Pcg64, Shape, Tensor};
use std::any::Any;
use std::rc::Rc;

/// ln(2π), the normal log-density constant.
pub const LN_2PI: f64 = 1.8378770664093453;

// ===================================================================
// Field
// ===================================================================

/// The numeric carrier a distribution computes over: either a concrete
/// [`Tensor`] or a tape-recorded [`Var`]. Operations mirror the shared
/// subset of the two inherent APIs.
pub trait Field: Clone + 'static {
    /// The concrete value (identity for tensors).
    fn value(&self) -> &Tensor;
    /// Lift a concrete tensor into this field (a tape constant for
    /// `Var`, identity for `Tensor`).
    fn lift(&self, t: Tensor) -> Self;
    /// Lift a tensor that was *drawn from an elementary RNG stream* —
    /// behaviorally identical to [`Field::lift`], but the `Var` impl
    /// additionally notes the (leaf id, stream) pair on a recording
    /// tape so graph mode can refill the buffer each compiled step.
    fn lift_draw(&self, t: Tensor, kind: crate::autodiff::DrawKind) -> Self {
        let _ = kind;
        self.lift(t)
    }

    fn add(&self, o: &Self) -> Self;
    fn sub(&self, o: &Self) -> Self;
    fn mul(&self, o: &Self) -> Self;
    fn div(&self, o: &Self) -> Self;
    fn neg(&self) -> Self;
    fn exp(&self) -> Self;
    fn ln(&self) -> Self;
    fn sqrt(&self) -> Self;
    fn square(&self) -> Self;
    fn abs(&self) -> Self;
    fn tanh(&self) -> Self;
    fn sigmoid(&self) -> Self;
    fn softplus(&self) -> Self;
    fn lgamma(&self) -> Self;
    fn add_scalar(&self, s: f64) -> Self;
    fn mul_scalar(&self, s: f64) -> Self;
    /// Sum all elements to a scalar element of the field.
    fn sum_all(&self) -> Self;
    /// Sum over the last axis (event-dim reduction).
    fn sum_last(&self) -> Self;
    /// Reinterpret the value under new dims (same numel).
    fn reshape(&self, dims: Vec<usize>) -> Self;
    /// Gather one element per row along the last axis.
    fn gather_last(&self, idx: &[usize]) -> Self;
}

impl Field for Tensor {
    fn value(&self) -> &Tensor {
        self
    }
    fn lift(&self, t: Tensor) -> Self {
        t
    }
    fn add(&self, o: &Self) -> Self {
        Tensor::add(self, o)
    }
    fn sub(&self, o: &Self) -> Self {
        Tensor::sub(self, o)
    }
    fn mul(&self, o: &Self) -> Self {
        Tensor::mul(self, o)
    }
    fn div(&self, o: &Self) -> Self {
        Tensor::div(self, o)
    }
    fn neg(&self) -> Self {
        Tensor::neg(self)
    }
    fn exp(&self) -> Self {
        Tensor::exp(self)
    }
    fn ln(&self) -> Self {
        Tensor::ln(self)
    }
    fn sqrt(&self) -> Self {
        Tensor::sqrt(self)
    }
    fn square(&self) -> Self {
        Tensor::square(self)
    }
    fn abs(&self) -> Self {
        Tensor::abs(self)
    }
    fn tanh(&self) -> Self {
        Tensor::tanh(self)
    }
    fn sigmoid(&self) -> Self {
        Tensor::sigmoid(self)
    }
    fn softplus(&self) -> Self {
        Tensor::softplus(self)
    }
    fn lgamma(&self) -> Self {
        Tensor::lgamma(self)
    }
    fn add_scalar(&self, s: f64) -> Self {
        Tensor::add_scalar(self, s)
    }
    fn mul_scalar(&self, s: f64) -> Self {
        Tensor::mul_scalar(self, s)
    }
    fn sum_all(&self) -> Self {
        Tensor::scalar(self.sum())
    }
    fn sum_last(&self) -> Self {
        Tensor::sum_last(self)
    }
    fn reshape(&self, dims: Vec<usize>) -> Self {
        Tensor::reshape(self, dims)
    }
    fn gather_last(&self, idx: &[usize]) -> Self {
        Tensor::gather_last(self, idx)
    }
}

impl Field for Var {
    fn value(&self) -> &Tensor {
        Var::value(self)
    }
    fn lift(&self, t: Tensor) -> Self {
        self.tape().constant(t)
    }
    fn lift_draw(&self, t: Tensor, kind: crate::autodiff::DrawKind) -> Self {
        let v = self.tape().constant(t);
        v.tape().note_draw(v.id, kind);
        v
    }
    fn add(&self, o: &Self) -> Self {
        Var::add(self, o)
    }
    fn sub(&self, o: &Self) -> Self {
        Var::sub(self, o)
    }
    fn mul(&self, o: &Self) -> Self {
        Var::mul(self, o)
    }
    fn div(&self, o: &Self) -> Self {
        Var::div(self, o)
    }
    fn neg(&self) -> Self {
        Var::neg(self)
    }
    fn exp(&self) -> Self {
        Var::exp(self)
    }
    fn ln(&self) -> Self {
        Var::ln(self)
    }
    fn sqrt(&self) -> Self {
        Var::sqrt(self)
    }
    fn square(&self) -> Self {
        Var::square(self)
    }
    fn abs(&self) -> Self {
        Var::abs(self)
    }
    fn tanh(&self) -> Self {
        Var::tanh(self)
    }
    fn sigmoid(&self) -> Self {
        Var::sigmoid(self)
    }
    fn softplus(&self) -> Self {
        Var::softplus(self)
    }
    fn lgamma(&self) -> Self {
        Var::lgamma(self)
    }
    fn add_scalar(&self, s: f64) -> Self {
        Var::add_scalar(self, s)
    }
    fn mul_scalar(&self, s: f64) -> Self {
        Var::mul_scalar(self, s)
    }
    fn sum_all(&self) -> Self {
        Var::sum(self)
    }
    fn sum_last(&self) -> Self {
        Var::sum_last(self)
    }
    fn reshape(&self, dims: Vec<usize>) -> Self {
        Var::reshape(self, dims)
    }
    fn gather_last(&self, idx: &[usize]) -> Self {
        Var::gather_last(self, idx)
    }
}

// ===================================================================
// Constraint
// ===================================================================

/// Supports and their `biject_to` transforms (`pyro.distributions
/// .constraints`). Storage in the param store is always unconstrained;
/// [`Constraint::transform`] maps ℝⁿ onto the support and
/// [`Constraint::inverse`] maps back.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Constraint {
    Real,
    Positive,
    UnitInterval,
    Interval(f64, f64),
    Simplex,
    /// Non-negative integers (counts, category indices).
    NonNegInteger,
    /// {0, 1} outcomes.
    Boolean,
}

impl Constraint {
    /// Small stable discriminant for hashing (param-store fingerprints).
    /// Interval bounds are folded in so re-registering a param with a
    /// different interval reads as a structural change.
    pub fn tag(&self) -> u64 {
        match self {
            Constraint::Real => 1,
            Constraint::Positive => 2,
            Constraint::UnitInterval => 3,
            Constraint::Interval(lo, hi) => {
                5u64.wrapping_add(lo.to_bits() ^ hi.to_bits().rotate_left(17))
            }
            Constraint::Simplex => 7,
            Constraint::NonNegInteger => 11,
            Constraint::Boolean => 13,
        }
    }

    /// Whether samples range over a continuum (HMC / autoguide support).
    pub fn is_continuous(&self) -> bool {
        !matches!(self, Constraint::NonNegInteger | Constraint::Boolean)
    }

    /// Does `t` lie inside the support?
    pub fn check(&self, t: &Tensor) -> bool {
        match self {
            Constraint::Real => t.data().iter().all(|v| v.is_finite()),
            Constraint::Positive => t.data().iter().all(|&v| v.is_finite() && v > 0.0),
            Constraint::UnitInterval => t.data().iter().all(|&v| (0.0..=1.0).contains(&v)),
            Constraint::Interval(lo, hi) => {
                t.data().iter().all(|v| (*lo..=*hi).contains(v))
            }
            Constraint::Simplex => {
                let last = t.dims().last().copied().unwrap_or(1).max(1);
                let rows = (t.numel() / last) as f64;
                t.data().iter().all(|&v| v >= 0.0) && (t.sum() - rows).abs() < 1e-6 * rows.max(1.0)
            }
            Constraint::NonNegInteger => {
                t.data().iter().all(|&v| v >= 0.0 && v.fract() == 0.0)
            }
            Constraint::Boolean => t.data().iter().all(|&v| v == 0.0 || v == 1.0),
        }
    }

    /// Unconstrained -> constrained.
    pub fn transform<F: Field>(&self, x: &F) -> F {
        match self {
            Constraint::Real | Constraint::Boolean | Constraint::NonNegInteger => x.clone(),
            Constraint::Positive => x.exp(),
            Constraint::UnitInterval => x.sigmoid(),
            Constraint::Interval(lo, hi) => x.sigmoid().mul_scalar(hi - lo).add_scalar(*lo),
            Constraint::Simplex => {
                let e = x.exp();
                e.div(&e.sum_all())
            }
        }
    }

    /// Constrained -> unconstrained.
    pub fn inverse<F: Field>(&self, y: &F) -> F {
        match self {
            Constraint::Real | Constraint::Boolean | Constraint::NonNegInteger => y.clone(),
            Constraint::Positive => y.ln(),
            Constraint::UnitInterval => logit(y),
            Constraint::Interval(lo, hi) => {
                logit(&y.add_scalar(-lo).mul_scalar(1.0 / (hi - lo)))
            }
            Constraint::Simplex => y.ln(),
        }
    }
}

fn logit<F: Field>(y: &F) -> F {
    y.ln().sub(&y.neg().add_scalar(1.0).ln())
}

// ===================================================================
// Dist
// ===================================================================

/// A probability distribution over a [`Field`], with PyTorch-style
/// shape semantics: a sample has shape `batch_shape + event_shape`,
/// where batch dims index conditionally-independent draws (parameter
/// broadcasting, plates) and event dims index one dependent draw.
///
/// `log_prob` returns a **batch-shaped** value: event dims are reduced
/// inside the distribution (a scalar-event distribution is elementwise).
/// [`crate::poutine::Site::log_prob`] then applies masks and plate
/// scaling over batch dims only and sums to the scalar contribution.
pub trait Dist<F: Field> {
    /// Draw a value of shape `batch_shape + event_shape`. For
    /// reparameterized distributions over `Var` the draw is
    /// pathwise-differentiable through the parameters.
    fn sample(&self, rng: &mut Pcg64) -> F;
    /// Batch-shaped log-density at `x` (event dims reduced),
    /// differentiable in the parameters when `F = Var`.
    fn log_prob(&self, x: &F) -> F;
    /// Shape of the conditionally-independent (broadcastable) dims.
    fn batch_shape(&self) -> Shape;
    /// Shape of one dependent draw (reduced out of `log_prob`).
    fn event_shape(&self) -> Shape {
        Shape::scalar()
    }
    /// The support of the distribution.
    fn support(&self) -> Constraint;
    /// Whether `sample` is reparameterized (pathwise gradients flow).
    fn has_rsample(&self) -> bool;
    fn dist_name(&self) -> &'static str;
    /// Downcasting hook (analytic-KL registry).
    fn as_any(&self) -> &dyn Any;

    /// Reinterpret the trailing `ndims` batch dims as event dims
    /// (`pyro.distributions.Independent`): `log_prob` sums over them.
    fn to_event(self, ndims: usize) -> Independent<Self>
    where
        Self: Sized,
    {
        Independent::new(self, ndims)
    }

    /// Expand the batch shape to `batch` (`Distribution.expand`). Extra
    /// leading dims hold fresh independent draws; see [`Expanded`] for
    /// the reparameterization caveat.
    fn expand(self, batch: Vec<usize>) -> Expanded<Self>
    where
        Self: Sized,
    {
        Expanded::new(self, batch)
    }
}

/// Trait-object forwarding: an `Rc<dyn Dist<F>>` is itself a
/// distribution, so shape wrappers ([`Independent`], [`Expanded`]) can
/// hold type-erased bases (what `IntoVarDist` produces).
impl<F: Field> Dist<F> for Rc<dyn Dist<F>> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        (**self).sample(rng)
    }
    fn log_prob(&self, x: &F) -> F {
        (**self).log_prob(x)
    }
    fn batch_shape(&self) -> Shape {
        (**self).batch_shape()
    }
    fn event_shape(&self) -> Shape {
        (**self).event_shape()
    }
    fn support(&self) -> Constraint {
        (**self).support()
    }
    fn has_rsample(&self) -> bool {
        (**self).has_rsample()
    }
    fn dist_name(&self) -> &'static str {
        (**self).dist_name()
    }
    fn as_any(&self) -> &dyn Any {
        (**self).as_any()
    }
}

/// Anything `ctx.sample` accepts: a distribution that can be placed on
/// the current tape.
pub trait IntoVarDist {
    fn into_var_dist(self, tape: &Tape) -> Rc<dyn Dist<Var>>;
}

impl IntoVarDist for Rc<dyn Dist<Var>> {
    fn into_var_dist(self, _tape: &Tape) -> Rc<dyn Dist<Var>> {
        self
    }
}

// ===================================================================
// Independent / Expanded (shape wrappers)
// ===================================================================

/// Reinterprets the trailing `ndims` batch dims of `base` as event dims
/// (`dist.to_event(n)`): `log_prob` additionally sums over them, so the
/// wrapped distribution scores one joint value per remaining batch
/// element. Sampling is unchanged.
#[derive(Clone)]
pub struct Independent<D> {
    pub base: D,
    pub ndims: usize,
}

impl<D> Independent<D> {
    pub fn new(base: D, ndims: usize) -> Self {
        Independent { base, ndims }
    }
}

impl<F: Field, D: Dist<F> + 'static> Dist<F> for Independent<D> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        self.base.sample(rng)
    }
    fn log_prob(&self, x: &F) -> F {
        let mut lp = self.base.log_prob(x);
        assert!(
            lp.value().rank() >= self.ndims,
            "to_event({}) exceeds the base batch rank {:?}",
            self.ndims,
            lp.value().dims()
        );
        for _ in 0..self.ndims {
            lp = lp.sum_last();
        }
        lp
    }
    fn batch_shape(&self) -> Shape {
        let b = self.base.batch_shape();
        assert!(
            self.ndims <= b.rank(),
            "to_event({}) exceeds the base batch rank {:?}",
            self.ndims,
            b
        );
        Shape(b.dims()[..b.rank() - self.ndims].to_vec())
    }
    fn event_shape(&self) -> Shape {
        let b = self.base.batch_shape();
        let mut e = b.dims()[b.rank() - self.ndims..].to_vec();
        e.extend_from_slice(self.base.event_shape().dims());
        Shape(e)
    }
    fn support(&self) -> Constraint {
        self.base.support()
    }
    fn has_rsample(&self) -> bool {
        self.base.has_rsample()
    }
    fn dist_name(&self) -> &'static str {
        "Independent"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

impl<D: IntoVarDist> IntoVarDist for Independent<D> {
    fn into_var_dist(self, tape: &Tape) -> Rc<dyn Dist<Var>> {
        Rc::new(Independent::new(self.base.into_var_dist(tape), self.ndims))
    }
}

/// True when `full` is `base` with extra leading dims prepended (the
/// expansion shape [`Expanded`] supports); leading 1-dims of `base` are
/// ignored.
fn is_trailing_expansion(full: &[usize], base: &[usize]) -> bool {
    let mut b = base;
    while b.first() == Some(&1) {
        b = &b[1..];
    }
    full.len() >= b.len() && full[full.len() - b.len()..] == *b
}

/// Expands `base` to a larger batch shape (`dist.expand(shape)`) by
/// prepending leading dims. Extra elements are **fresh independent
/// draws** (the base is sampled once per replica), and `log_prob`
/// relies on the base's elementwise parameter broadcasting — so only
/// scalar-event, elementwise distributions are accepted (`Dirichlet`'s
/// joint and `Categorical`'s per-row gather do not broadcast; for a
/// batch of categoricals use `[N, K]` logits directly).
///
/// Caveats: when the expansion is non-trivial the replicated draw is
/// assembled concretely and lifted, so `has_rsample` reports `false`
/// and gradients reach the parameters through `log_prob` only (the
/// score-function path); with `Var`-valued parameters each replica
/// also records dead sampling ops on the tape. Guides on the hot path
/// should use full-shape parameters instead of `expand`.
#[derive(Clone)]
pub struct Expanded<D> {
    pub base: D,
    batch: Shape,
}

impl<D> Expanded<D> {
    pub fn new(base: D, batch: Vec<usize>) -> Self {
        Expanded { base, batch: Shape(batch) }
    }
}

impl<D> Expanded<D> {
    fn check_elementwise<F: Field>(&self)
    where
        D: Dist<F> + 'static,
    {
        assert!(
            self.base.event_shape().rank() == 0,
            "expand supports scalar-event elementwise distributions only \
             (got {} with event shape {:?})",
            self.base.dist_name(),
            self.base.event_shape()
        );
        assert!(
            self.base.as_any().downcast_ref::<Categorical<F>>().is_none(),
            "expand does not support Categorical; use batched [N, K] logits instead"
        );
    }
}

impl<F: Field, D: Dist<F> + 'static> Dist<F> for Expanded<D> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        self.check_elementwise::<F>();
        let proto = self.base.sample(rng);
        let mut full = self.batch.dims().to_vec();
        full.extend_from_slice(self.base.event_shape().dims());
        if proto.value().dims() == full.as_slice() {
            return proto;
        }
        let total: usize = full.iter().product::<usize>().max(1);
        let base_numel = proto.value().numel();
        assert!(
            total % base_numel == 0
                && is_trailing_expansion(&full, proto.value().dims()),
            "expand {:?} -> {:?} must only add leading dims",
            proto.value().dims(),
            full
        );
        let reps = total / base_numel;
        let mut data = Vec::with_capacity(total);
        data.extend_from_slice(proto.value().data());
        for _ in 1..reps {
            data.extend_from_slice(self.base.sample(rng).value().data());
        }
        proto.lift(Tensor::new(data, full))
    }
    fn log_prob(&self, x: &F) -> F {
        self.check_elementwise::<F>();
        self.base.log_prob(x)
    }
    fn batch_shape(&self) -> Shape {
        self.batch.clone()
    }
    fn event_shape(&self) -> Shape {
        self.base.event_shape()
    }
    fn support(&self) -> Constraint {
        self.base.support()
    }
    fn has_rsample(&self) -> bool {
        self.base.has_rsample() && self.batch == self.base.batch_shape()
    }
    fn dist_name(&self) -> &'static str {
        "Expanded"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

impl<D: IntoVarDist> IntoVarDist for Expanded<D> {
    fn into_var_dist(self, tape: &Tape) -> Rc<dyn Dist<Var>> {
        let batch = self.batch.0;
        Rc::new(Expanded::new(self.base.into_var_dist(tape), batch))
    }
}

/// Value-level support mask: `None` when every element of `x` satisfies
/// `pred` (the hot path — no allocation), otherwise a 0/-inf penalty
/// carrier to add to the log-density so out-of-support points score
/// -inf instead of a silently-finite value.
fn support_penalty<F: Field>(x: &F, pred: impl Fn(f64) -> bool) -> Option<F> {
    let xv = x.value();
    if xv.data().iter().all(|&v| pred(v)) {
        return None;
    }
    let pen: Vec<f64> = xv
        .data()
        .iter()
        .map(|&v| if pred(v) { 0.0 } else { f64::NEG_INFINITY })
        .collect();
    Some(x.lift(Tensor::new(pen, xv.dims().to_vec())))
}

/// Broadcast shape of a two-parameter family (its full sample shape).
fn param_broadcast<F: Field>(a: &F, b: &F, who: &str) -> Shape {
    a.value().shape().broadcast(b.value().shape()).unwrap_or_else(|| {
        panic!(
            "{who} parameter shapes {:?} vs {:?} do not broadcast",
            a.value().shape(),
            b.value().shape()
        )
    })
}

/// Broadcast two parameter tensors to their common shape.
fn broadcast_pair(a: &Tensor, b: &Tensor) -> (Tensor, Tensor) {
    let shape = a
        .shape()
        .broadcast(b.shape())
        .unwrap_or_else(|| panic!("parameter broadcast {:?} vs {:?}", a.shape(), b.shape()));
    (a.broadcast_to(shape.clone()), b.broadcast_to(shape))
}

macro_rules! into_var_dist_2 {
    ($T:ident, $a:ident, $b:ident) => {
        impl IntoVarDist for $T<Tensor> {
            fn into_var_dist(self, tape: &Tape) -> Rc<dyn Dist<Var>> {
                Rc::new($T { $a: tape.constant(self.$a), $b: tape.constant(self.$b) })
            }
        }
        impl IntoVarDist for $T<Var> {
            fn into_var_dist(self, _tape: &Tape) -> Rc<dyn Dist<Var>> {
                Rc::new(self)
            }
        }
    };
}

macro_rules! into_var_dist_1 {
    ($T:ident, $a:ident) => {
        impl IntoVarDist for $T<Tensor> {
            fn into_var_dist(self, tape: &Tape) -> Rc<dyn Dist<Var>> {
                Rc::new($T { $a: tape.constant(self.$a) })
            }
        }
        impl IntoVarDist for $T<Var> {
            fn into_var_dist(self, _tape: &Tape) -> Rc<dyn Dist<Var>> {
                Rc::new(self)
            }
        }
    };
}

// ===================================================================
// Normal / MvNormalDiag
// ===================================================================

/// Univariate (optionally broadcast) Gaussian.
#[derive(Clone)]
pub struct Normal<F: Field> {
    pub loc: F,
    pub scale: F,
}

impl<F: Field> Normal<F> {
    pub fn new(loc: F, scale: F) -> Self {
        Normal { loc, scale }
    }
}

impl Normal<Tensor> {
    /// Concrete-parameter constructor.
    pub fn std(loc: f64, scale: f64) -> Self {
        assert!(scale > 0.0, "Normal scale must be positive, got {scale}");
        Normal { loc: Tensor::scalar(loc), scale: Tensor::scalar(scale) }
    }
}

fn normal_log_prob<F: Field>(loc: &F, scale: &F, x: &F) -> F {
    let z = x.sub(loc).div(scale);
    z.square().mul_scalar(-0.5).sub(&scale.ln()).add_scalar(-0.5 * LN_2PI)
}

fn normal_rsample<F: Field>(loc: &F, scale: &F, rng: &mut Pcg64) -> F {
    let shape = loc
        .value()
        .shape()
        .broadcast(scale.value().shape())
        .expect("Normal parameter shapes do not broadcast");
    let eps = loc.lift_draw(
        Tensor::randn(shape.dims().to_vec(), rng),
        crate::autodiff::DrawKind::StdNormal,
    );
    loc.add(&scale.mul(&eps))
}

impl<F: Field> Dist<F> for Normal<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        normal_rsample(&self.loc, &self.scale, rng)
    }
    fn log_prob(&self, x: &F) -> F {
        normal_log_prob(&self.loc, &self.scale, x)
    }
    fn batch_shape(&self) -> Shape {
        param_broadcast(&self.loc, &self.scale, "Normal")
    }
    fn support(&self) -> Constraint {
        Constraint::Real
    }
    fn has_rsample(&self) -> bool {
        true
    }
    fn dist_name(&self) -> &'static str {
        "Normal"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_2!(Normal, loc, scale);

/// Diagonal-covariance multivariate Gaussian: a Normal whose **last**
/// parameter dim is the event dim, so `log_prob` is reduced over it and
/// returns one joint density per batch element (equivalent to
/// `Normal::new(loc, scale).to_event(1)`).
#[derive(Clone)]
pub struct MvNormalDiag<F: Field> {
    pub loc: F,
    pub scale: F,
}

impl<F: Field> MvNormalDiag<F> {
    pub fn new(loc: F, scale: F) -> Self {
        MvNormalDiag { loc, scale }
    }
}

impl<F: Field> Dist<F> for MvNormalDiag<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        normal_rsample(&self.loc, &self.scale, rng)
    }
    fn log_prob(&self, x: &F) -> F {
        normal_log_prob(&self.loc, &self.scale, x).sum_last()
    }
    fn batch_shape(&self) -> Shape {
        let full = param_broadcast(&self.loc, &self.scale, "MvNormalDiag");
        assert!(full.rank() >= 1, "MvNormalDiag requires rank >= 1 parameters");
        Shape(full.dims()[..full.rank() - 1].to_vec())
    }
    fn event_shape(&self) -> Shape {
        let full = param_broadcast(&self.loc, &self.scale, "MvNormalDiag");
        assert!(full.rank() >= 1, "MvNormalDiag requires rank >= 1 parameters");
        Shape(vec![*full.dims().last().unwrap()])
    }
    fn support(&self) -> Constraint {
        Constraint::Real
    }
    fn has_rsample(&self) -> bool {
        true
    }
    fn dist_name(&self) -> &'static str {
        "MvNormalDiag"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_2!(MvNormalDiag, loc, scale);

// ===================================================================
// LogNormal
// ===================================================================

#[derive(Clone)]
pub struct LogNormal<F: Field> {
    pub loc: F,
    pub scale: F,
}

impl<F: Field> LogNormal<F> {
    pub fn new(loc: F, scale: F) -> Self {
        LogNormal { loc, scale }
    }
}

impl LogNormal<Tensor> {
    pub fn std(loc: f64, scale: f64) -> Self {
        assert!(scale > 0.0, "LogNormal scale must be positive");
        LogNormal { loc: Tensor::scalar(loc), scale: Tensor::scalar(scale) }
    }
}

impl<F: Field> Dist<F> for LogNormal<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        normal_rsample(&self.loc, &self.scale, rng).exp()
    }
    fn log_prob(&self, x: &F) -> F {
        let lx = x.ln();
        normal_log_prob(&self.loc, &self.scale, &lx).sub(&lx)
    }
    fn batch_shape(&self) -> Shape {
        param_broadcast(&self.loc, &self.scale, "LogNormal")
    }
    fn support(&self) -> Constraint {
        Constraint::Positive
    }
    fn has_rsample(&self) -> bool {
        true
    }
    fn dist_name(&self) -> &'static str {
        "LogNormal"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_2!(LogNormal, loc, scale);

// ===================================================================
// Uniform
// ===================================================================

#[derive(Clone)]
pub struct Uniform<F: Field> {
    pub lo: F,
    pub hi: F,
}

impl<F: Field> Uniform<F> {
    pub fn new(lo: F, hi: F) -> Self {
        Uniform { lo, hi }
    }
}

impl Uniform<Tensor> {
    pub fn std(lo: f64, hi: f64) -> Self {
        assert!(hi > lo, "Uniform requires hi > lo");
        Uniform { lo: Tensor::scalar(lo), hi: Tensor::scalar(hi) }
    }
}

impl<F: Field> Dist<F> for Uniform<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let shape = self
            .lo
            .value()
            .shape()
            .broadcast(self.hi.value().shape())
            .expect("Uniform parameter shapes do not broadcast");
        let u = self.lo.lift_draw(
            Tensor::rand(shape.dims().to_vec(), rng),
            crate::autodiff::DrawKind::Uniform,
        );
        self.lo.add(&self.hi.sub(&self.lo).mul(&u))
    }
    fn log_prob(&self, x: &F) -> F {
        // -ln(hi - lo), broadcast over x via a zero-valued carrier;
        // -inf outside [lo, hi]
        let base = x.mul_scalar(0.0).sub(&self.hi.sub(&self.lo).ln());
        let (lo, hi) = (self.lo.value().data()[0], self.hi.value().data()[0]);
        match support_penalty(x, |v| (lo..=hi).contains(&v)) {
            None => base,
            Some(p) => base.add(&p),
        }
    }
    fn batch_shape(&self) -> Shape {
        param_broadcast(&self.lo, &self.hi, "Uniform")
    }
    fn support(&self) -> Constraint {
        Constraint::Interval(self.lo.value().data()[0], self.hi.value().data()[0])
    }
    fn has_rsample(&self) -> bool {
        true
    }
    fn dist_name(&self) -> &'static str {
        "Uniform"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_2!(Uniform, lo, hi);

// ===================================================================
// Exponential
// ===================================================================

#[derive(Clone)]
pub struct Exponential<F: Field> {
    pub rate: F,
}

impl<F: Field> Exponential<F> {
    pub fn new(rate: F) -> Self {
        Exponential { rate }
    }
}

impl Exponential<Tensor> {
    pub fn std(rate: f64) -> Self {
        assert!(rate > 0.0, "Exponential rate must be positive");
        Exponential { rate: Tensor::scalar(rate) }
    }
}

impl<F: Field> Dist<F> for Exponential<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        // inverse CDF, pathwise through the rate: x = -ln(u) / rate
        let dims = self.rate.value().dims().to_vec();
        let n: usize = dims.iter().product::<usize>().max(1);
        let u: Vec<f64> = (0..n).map(|_| rng.uniform_open()).collect();
        let u = self
            .rate
            .lift_draw(Tensor::new(u, dims), crate::autodiff::DrawKind::UniformOpen);
        u.ln().neg().div(&self.rate)
    }
    fn log_prob(&self, x: &F) -> F {
        let base = self.rate.ln().sub(&self.rate.mul(x));
        match support_penalty(x, |v| v >= 0.0) {
            None => base,
            Some(p) => base.add(&p),
        }
    }
    fn batch_shape(&self) -> Shape {
        self.rate.value().shape().clone()
    }
    fn support(&self) -> Constraint {
        Constraint::Positive
    }
    fn has_rsample(&self) -> bool {
        true
    }
    fn dist_name(&self) -> &'static str {
        "Exponential"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_1!(Exponential, rate);

// ===================================================================
// Gamma
// ===================================================================

/// Gamma(concentration, rate), mean = concentration / rate.
#[derive(Clone)]
pub struct Gamma<F: Field> {
    pub conc: F,
    pub rate: F,
}

impl<F: Field> Gamma<F> {
    pub fn new(conc: F, rate: F) -> Self {
        Gamma { conc, rate }
    }
}

impl Gamma<Tensor> {
    pub fn std(conc: f64, rate: f64) -> Self {
        assert!(conc > 0.0 && rate > 0.0, "Gamma parameters must be positive");
        Gamma { conc: Tensor::scalar(conc), rate: Tensor::scalar(rate) }
    }
}

impl<F: Field> Dist<F> for Gamma<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let (a, b) = broadcast_pair(self.conc.value(), self.rate.value());
        let data: Vec<f64> = a
            .data()
            .iter()
            .zip(b.data())
            .map(|(&ai, &bi)| rng.gamma(ai) / bi)
            .collect();
        self.conc.lift(Tensor::new(data, a.dims().to_vec()))
    }
    fn log_prob(&self, x: &F) -> F {
        self.conc
            .mul(&self.rate.ln())
            .add(&self.conc.add_scalar(-1.0).mul(&x.ln()))
            .sub(&self.rate.mul(x))
            .sub(&self.conc.lgamma())
    }
    fn batch_shape(&self) -> Shape {
        param_broadcast(&self.conc, &self.rate, "Gamma")
    }
    fn support(&self) -> Constraint {
        Constraint::Positive
    }
    fn has_rsample(&self) -> bool {
        false
    }
    fn dist_name(&self) -> &'static str {
        "Gamma"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_2!(Gamma, conc, rate);

// ===================================================================
// Beta
// ===================================================================

#[derive(Clone)]
pub struct Beta<F: Field> {
    pub a: F,
    pub b: F,
}

impl<F: Field> Beta<F> {
    pub fn new(a: F, b: F) -> Self {
        Beta { a, b }
    }
}

impl Beta<Tensor> {
    pub fn std(a: f64, b: f64) -> Self {
        assert!(a > 0.0 && b > 0.0, "Beta parameters must be positive");
        Beta { a: Tensor::scalar(a), b: Tensor::scalar(b) }
    }
}

impl<F: Field> Dist<F> for Beta<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let (a, b) = broadcast_pair(self.a.value(), self.b.value());
        let data: Vec<f64> = a
            .data()
            .iter()
            .zip(b.data())
            .map(|(&ai, &bi)| rng.beta(ai, bi))
            .collect();
        self.a.lift(Tensor::new(data, a.dims().to_vec()))
    }
    fn log_prob(&self, x: &F) -> F {
        let lbeta = self
            .a
            .lgamma()
            .add(&self.b.lgamma())
            .sub(&self.a.add(&self.b).lgamma());
        self.a
            .add_scalar(-1.0)
            .mul(&x.ln())
            .add(&self.b.add_scalar(-1.0).mul(&x.neg().add_scalar(1.0).ln()))
            .sub(&lbeta)
    }
    fn batch_shape(&self) -> Shape {
        param_broadcast(&self.a, &self.b, "Beta")
    }
    fn support(&self) -> Constraint {
        Constraint::UnitInterval
    }
    fn has_rsample(&self) -> bool {
        false
    }
    fn dist_name(&self) -> &'static str {
        "Beta"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_2!(Beta, a, b);

// ===================================================================
// HalfCauchy
// ===================================================================

#[derive(Clone)]
pub struct HalfCauchy<F: Field> {
    pub scale: F,
}

impl<F: Field> HalfCauchy<F> {
    pub fn new(scale: F) -> Self {
        HalfCauchy { scale }
    }
}

impl HalfCauchy<Tensor> {
    pub fn std(scale: f64) -> Self {
        assert!(scale > 0.0, "HalfCauchy scale must be positive");
        HalfCauchy { scale: Tensor::scalar(scale) }
    }
}

impl<F: Field> Dist<F> for HalfCauchy<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let s = self.scale.value();
        let data: Vec<f64> = s
            .data()
            .iter()
            .map(|&si| (si * (std::f64::consts::FRAC_PI_2 * rng.uniform_open()).tan()).abs())
            .collect();
        self.scale.lift(Tensor::new(data, s.dims().to_vec()))
    }
    fn log_prob(&self, x: &F) -> F {
        let base = x
            .div(&self.scale)
            .square()
            .add_scalar(1.0)
            .ln()
            .neg()
            .sub(&self.scale.ln())
            .add_scalar((2.0 / std::f64::consts::PI).ln());
        match support_penalty(x, |v| v >= 0.0) {
            None => base,
            Some(p) => base.add(&p),
        }
    }
    fn batch_shape(&self) -> Shape {
        self.scale.value().shape().clone()
    }
    fn support(&self) -> Constraint {
        Constraint::Positive
    }
    fn has_rsample(&self) -> bool {
        false
    }
    fn dist_name(&self) -> &'static str {
        "HalfCauchy"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_1!(HalfCauchy, scale);

// ===================================================================
// Bernoulli
// ===================================================================

/// Bernoulli parameterized by logits (the numerically-stable form).
#[derive(Clone)]
pub struct Bernoulli<F: Field> {
    pub logits: F,
}

impl<F: Field> Bernoulli<F> {
    pub fn new(logits: F) -> Self {
        Bernoulli { logits }
    }
}

impl Bernoulli<Tensor> {
    /// Construct from a success probability.
    pub fn std(p: f64) -> Self {
        assert!((0.0..1.0).contains(&p) && p > 0.0, "Bernoulli p must be in (0, 1)");
        Bernoulli { logits: Tensor::scalar((p / (1.0 - p)).ln()) }
    }
}

impl<F: Field> Dist<F> for Bernoulli<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let p = self.logits.value().sigmoid();
        let data: Vec<f64> = p
            .data()
            .iter()
            .map(|&pi| f64::from(rng.uniform() < pi))
            .collect();
        self.logits.lift(Tensor::new(data, p.dims().to_vec()))
    }
    fn log_prob(&self, x: &F) -> F {
        // x*l - softplus(l): exact for x in {0, 1}
        x.mul(&self.logits).sub(&self.logits.softplus())
    }
    fn batch_shape(&self) -> Shape {
        self.logits.value().shape().clone()
    }
    fn support(&self) -> Constraint {
        Constraint::Boolean
    }
    fn has_rsample(&self) -> bool {
        false
    }
    fn dist_name(&self) -> &'static str {
        "Bernoulli"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_1!(Bernoulli, logits);

// ===================================================================
// Categorical
// ===================================================================

/// Categorical over {0, .., K-1}, parameterized by logits whose **last**
/// dim is the K categories: rank-1 logits give one scalar draw, rank-2
/// `[N, K]` logits give a batch of `N` independent draws (one vectorized
/// plate site instead of N scalar ones). Samples are indices carried as
/// f64, shaped like the logits' batch dims.
#[derive(Clone)]
pub struct Categorical<F: Field> {
    pub logits: F,
}

impl<F: Field> Categorical<F> {
    pub fn new(logits: F) -> Self {
        Categorical { logits }
    }
}

impl Categorical<Tensor> {
    /// Construct from unnormalized non-negative weights.
    pub fn from_weights(w: &[f64]) -> Self {
        assert!(w.iter().all(|&x| x > 0.0), "Categorical weights must be positive");
        Categorical { logits: Tensor::from_vec(w.iter().map(|x| x.ln()).collect()) }
    }
}

impl<F: Field> Dist<F> for Categorical<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let l = self.logits.value();
        assert!(l.rank() >= 1, "Categorical expects rank >= 1 logits");
        let k = *l.dims().last().unwrap();
        let rows = l.numel() / k;
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &l.data()[r * k..(r + 1) * k];
            let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let w: Vec<f64> = row.iter().map(|&x| (x - m).exp()).collect();
            out.push(rng.categorical(&w) as f64);
        }
        self.logits
            .lift(Tensor::new(out, l.dims()[..l.rank() - 1].to_vec()))
    }
    fn log_prob(&self, x: &F) -> F {
        let l = self.logits.value();
        assert!(l.rank() >= 1, "Categorical expects rank >= 1 logits");
        let k = *l.dims().last().unwrap();
        let rows = l.numel() / k;
        let xv = x.value();
        assert_eq!(
            xv.numel(),
            rows,
            "Categorical expects one index per logits row"
        );
        let idx: Vec<usize> = xv.data().iter().map(|&v| v as usize).collect();
        for &i in &idx {
            assert!(i < k, "Categorical index {i} out of range {k}");
        }
        // stable per-row log-softmax: subtracting the (constant) row max
        // leaves the gradient exact
        let m = self.logits.lift(l.max_last_keepdim());
        let shifted = self.logits.sub(&m);
        let mut keep = l.dims()[..l.rank() - 1].to_vec();
        keep.push(1);
        let lse = shifted.exp().sum_last().ln().reshape(keep);
        shifted.sub(&lse).gather_last(&idx)
    }
    fn batch_shape(&self) -> Shape {
        let l = self.logits.value();
        assert!(l.rank() >= 1, "Categorical expects rank >= 1 logits");
        Shape(l.dims()[..l.rank() - 1].to_vec())
    }
    fn support(&self) -> Constraint {
        Constraint::NonNegInteger
    }
    fn has_rsample(&self) -> bool {
        false
    }
    fn dist_name(&self) -> &'static str {
        "Categorical"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_1!(Categorical, logits);

// ===================================================================
// Poisson
// ===================================================================

#[derive(Clone)]
pub struct Poisson<F: Field> {
    pub rate: F,
}

impl<F: Field> Poisson<F> {
    pub fn new(rate: F) -> Self {
        Poisson { rate }
    }
}

impl Poisson<Tensor> {
    pub fn std(rate: f64) -> Self {
        assert!(rate > 0.0, "Poisson rate must be positive");
        Poisson { rate: Tensor::scalar(rate) }
    }
}

impl<F: Field> Dist<F> for Poisson<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let r = self.rate.value();
        let data: Vec<f64> = r.data().iter().map(|&l| rng.poisson(l) as f64).collect();
        self.rate.lift(Tensor::new(data, r.dims().to_vec()))
    }
    fn log_prob(&self, x: &F) -> F {
        x.mul(&self.rate.ln())
            .sub(&self.rate)
            .sub(&x.add_scalar(1.0).lgamma())
    }
    fn batch_shape(&self) -> Shape {
        self.rate.value().shape().clone()
    }
    fn support(&self) -> Constraint {
        Constraint::NonNegInteger
    }
    fn has_rsample(&self) -> bool {
        false
    }
    fn dist_name(&self) -> &'static str {
        "Poisson"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_1!(Poisson, rate);

// ===================================================================
// Dirichlet
// ===================================================================

/// Dirichlet over the probability simplex (rank-1 concentration).
/// `log_prob` returns the scalar joint density.
#[derive(Clone)]
pub struct Dirichlet<F: Field> {
    pub conc: F,
}

impl<F: Field> Dirichlet<F> {
    pub fn new(conc: F) -> Self {
        Dirichlet { conc }
    }
}

impl Dirichlet<Tensor> {
    pub fn std(conc: Vec<f64>) -> Self {
        assert!(conc.iter().all(|&a| a > 0.0), "Dirichlet concentration must be positive");
        Dirichlet { conc: Tensor::from_vec(conc) }
    }
}

impl<F: Field> Dist<F> for Dirichlet<F> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        let a = self.conc.value();
        assert_eq!(a.rank(), 1, "Dirichlet expects rank-1 concentration");
        let gs: Vec<f64> = a.data().iter().map(|&ai| rng.gamma(ai)).collect();
        let total: f64 = gs.iter().sum();
        self.conc
            .lift(Tensor::from_vec(gs.iter().map(|g| g / total).collect()))
    }
    fn log_prob(&self, x: &F) -> F {
        let term = self.conc.add_scalar(-1.0).mul(&x.ln()).sum_all();
        let norm = self
            .conc
            .lgamma()
            .sum_all()
            .sub(&self.conc.sum_all().lgamma());
        term.sub(&norm)
    }
    fn batch_shape(&self) -> Shape {
        Shape::scalar()
    }
    fn event_shape(&self) -> Shape {
        Shape(self.conc.value().dims().to_vec())
    }
    fn support(&self) -> Constraint {
        Constraint::Simplex
    }
    fn has_rsample(&self) -> bool {
        false
    }
    fn dist_name(&self) -> &'static str {
        "Dirichlet"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_1!(Dirichlet, conc);

// ===================================================================
// Delta
// ===================================================================

/// A point mass: samples return the point, log_prob is zero (carried on
/// the graph so gradients still flow through the point itself).
#[derive(Clone)]
pub struct Delta<F: Field> {
    pub point: F,
}

impl<F: Field> Delta<F> {
    pub fn new(point: F) -> Self {
        Delta { point }
    }
}

impl<F: Field> Dist<F> for Delta<F> {
    fn sample(&self, _rng: &mut Pcg64) -> F {
        self.point.clone()
    }
    fn log_prob(&self, x: &F) -> F {
        x.mul_scalar(0.0)
    }
    fn batch_shape(&self) -> Shape {
        self.point.value().shape().clone()
    }
    fn support(&self) -> Constraint {
        Constraint::Real
    }
    fn has_rsample(&self) -> bool {
        true
    }
    fn dist_name(&self) -> &'static str {
        "Delta"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

into_var_dist_1!(Delta, point);

// ===================================================================
// Transforms + TransformedDist
// ===================================================================

/// A smooth bijection ℝ -> support with a tractable log-Jacobian,
/// expressed as a function of the *unconstrained* input.
pub trait Transform: Clone + 'static {
    fn forward<F: Field>(&self, x: &F) -> F;
    fn inverse<F: Field>(&self, y: &F) -> F;
    /// Elementwise log |d forward / dx| at unconstrained `x`.
    fn log_abs_det_jacobian<F: Field>(&self, x: &F) -> F;
    fn codomain(&self) -> Constraint;
}

/// y = exp(x).
#[derive(Clone, Copy, Debug)]
pub struct ExpT;

impl Transform for ExpT {
    fn forward<F: Field>(&self, x: &F) -> F {
        x.exp()
    }
    fn inverse<F: Field>(&self, y: &F) -> F {
        y.ln()
    }
    fn log_abs_det_jacobian<F: Field>(&self, x: &F) -> F {
        x.clone()
    }
    fn codomain(&self) -> Constraint {
        Constraint::Positive
    }
}

/// y = sigmoid(x).
#[derive(Clone, Copy, Debug)]
pub struct SigmoidT;

impl Transform for SigmoidT {
    fn forward<F: Field>(&self, x: &F) -> F {
        x.sigmoid()
    }
    fn inverse<F: Field>(&self, y: &F) -> F {
        logit(y)
    }
    fn log_abs_det_jacobian<F: Field>(&self, x: &F) -> F {
        // ln sigma'(x) = -softplus(x) - softplus(-x)
        x.softplus().add(&x.neg().softplus()).neg()
    }
    fn codomain(&self) -> Constraint {
        Constraint::UnitInterval
    }
}

/// y = lo + (hi - lo) * sigmoid(x).
#[derive(Clone, Copy, Debug)]
pub struct IntervalT {
    pub lo: f64,
    pub hi: f64,
}

impl Transform for IntervalT {
    fn forward<F: Field>(&self, x: &F) -> F {
        x.sigmoid().mul_scalar(self.hi - self.lo).add_scalar(self.lo)
    }
    fn inverse<F: Field>(&self, y: &F) -> F {
        logit(&y.add_scalar(-self.lo).mul_scalar(1.0 / (self.hi - self.lo)))
    }
    fn log_abs_det_jacobian<F: Field>(&self, x: &F) -> F {
        x.softplus()
            .add(&x.neg().softplus())
            .neg()
            .add_scalar((self.hi - self.lo).ln())
    }
    fn codomain(&self) -> Constraint {
        Constraint::Interval(self.lo, self.hi)
    }
}

/// Push a base distribution through a transform (change of variables).
#[derive(Clone)]
pub struct TransformedDist<D, T> {
    pub base: D,
    pub transform: T,
}

impl<D, T> TransformedDist<D, T> {
    pub fn new(base: D, transform: T) -> Self {
        TransformedDist { base, transform }
    }
}

impl<F: Field, D: Dist<F> + 'static, T: Transform> Dist<F> for TransformedDist<D, T> {
    fn sample(&self, rng: &mut Pcg64) -> F {
        self.transform.forward(&self.base.sample(rng))
    }
    fn log_prob(&self, y: &F) -> F {
        let x = self.transform.inverse(y);
        self.base
            .log_prob(&x)
            .sub(&self.transform.log_abs_det_jacobian(&x))
    }
    fn batch_shape(&self) -> Shape {
        self.base.batch_shape()
    }
    fn event_shape(&self) -> Shape {
        self.base.event_shape()
    }
    fn support(&self) -> Constraint {
        self.transform.codomain()
    }
    fn has_rsample(&self) -> bool {
        self.base.has_rsample()
    }
    fn dist_name(&self) -> &'static str {
        "Transformed"
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

impl<D: Dist<Var> + 'static, T: Transform> IntoVarDist for TransformedDist<D, T> {
    fn into_var_dist(self, _tape: &Tape) -> Rc<dyn Dist<Var>> {
        Rc::new(self)
    }
}

// ===================================================================
// Analytic-KL registry
// ===================================================================

fn normal_params(d: &dyn Dist<Var>) -> Option<(Var, Var)> {
    if let Some(n) = d.as_any().downcast_ref::<Normal<Var>>() {
        return Some((n.loc.clone(), n.scale.clone()));
    }
    if let Some(n) = d.as_any().downcast_ref::<MvNormalDiag<Var>>() {
        return Some((n.loc.clone(), n.scale.clone()));
    }
    // `to_event` only reinterprets independence; the elementwise KL
    // summed over all dims is unchanged, so look through the wrapper
    // (sites built from `IntoVarDist` always hold the type-erased base).
    if let Some(i) = d.as_any().downcast_ref::<Independent<Rc<dyn Dist<Var>>>>() {
        return normal_params(i.base.as_ref());
    }
    None
}

/// KL(q ‖ p) in closed form where the registry has one (Gaussian pairs,
/// including `MvNormalDiag`); `None` triggers the MC fallback.
pub fn try_analytic_kl(q: &dyn Dist<Var>, p: &dyn Dist<Var>) -> Option<Var> {
    let (ql, qs) = normal_params(q)?;
    let (pl, ps) = normal_params(p)?;
    Some(kl::kl_normal_normal(&Normal::new(ql, qs), &Normal::new(pl, ps)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc_moments(d: &dyn Dist<Tensor>, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Pcg64::new(seed);
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng).item()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        (mean, var)
    }

    #[test]
    fn normal_log_prob_matches_closed_form() {
        let d = Normal::std(1.0, 2.0);
        let lp = d.log_prob(&Tensor::scalar(0.0)).item();
        let want = -0.5 * (1.0f64 / 4.0) - 2.0f64.ln() - 0.5 * LN_2PI;
        assert!((lp - want).abs() < 1e-12, "{lp} vs {want}");
    }

    #[test]
    fn normal_sampling_moments() {
        let (m, v) = mc_moments(&Normal::std(0.5, 1.5), 100_000, 1);
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
        assert!((v - 2.25).abs() < 0.05, "var {v}");
    }

    #[test]
    fn lognormal_log_prob_change_of_variables() {
        let d = LogNormal::std(0.3, 0.9);
        let x = 1.7;
        let want = Normal::std(0.3, 0.9).log_prob(&Tensor::scalar(x.ln())).item() - x.ln();
        assert!((d.log_prob(&Tensor::scalar(x)).item() - want).abs() < 1e-12);
    }

    #[test]
    fn gamma_moments_and_log_prob() {
        let (m, v) = mc_moments(&Gamma::std(3.0, 2.0), 100_000, 2);
        assert!((m - 1.5).abs() < 0.02, "mean {m}");
        assert!((v - 0.75).abs() < 0.04, "var {v}");
        // Gamma(1, b) == Exponential(b)
        let g = Gamma::std(1.0, 2.0).log_prob(&Tensor::scalar(0.8)).item();
        let e = Exponential::std(2.0).log_prob(&Tensor::scalar(0.8)).item();
        assert!((g - e).abs() < 1e-10);
    }

    #[test]
    fn beta_density_integrates_symmetry() {
        // Beta(a, b) at x equals Beta(b, a) at 1 - x
        let lp1 = Beta::std(2.0, 5.0).log_prob(&Tensor::scalar(0.3)).item();
        let lp2 = Beta::std(5.0, 2.0).log_prob(&Tensor::scalar(0.7)).item();
        assert!((lp1 - lp2).abs() < 1e-10);
    }

    #[test]
    fn bernoulli_log_prob_both_outcomes() {
        let d = Bernoulli::std(0.8);
        let lp1 = d.log_prob(&Tensor::scalar(1.0)).item();
        let lp0 = d.log_prob(&Tensor::scalar(0.0)).item();
        assert!((lp1 - 0.8f64.ln()).abs() < 1e-10);
        assert!((lp0 - 0.2f64.ln()).abs() < 1e-10);
    }

    #[test]
    fn categorical_log_probs_normalize() {
        let d = Categorical::from_weights(&[1.0, 2.0, 7.0]);
        let total: f64 = (0..3)
            .map(|k| d.log_prob(&Tensor::scalar(k as f64)).item().exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-10, "{total}");
        let lp2 = d.log_prob(&Tensor::scalar(2.0)).item();
        assert!((lp2 - 0.7f64.ln()).abs() < 1e-10);
    }

    #[test]
    fn categorical_gradient_pushes_up_chosen_logit() {
        let tape = Tape::new();
        let logits = tape.leaf(Tensor::from_vec(vec![0.0, 0.0, 0.0]));
        let d = Categorical::new(logits.clone());
        let lp = d.log_prob(&tape.constant(Tensor::scalar(1.0)));
        let g = tape.grad(&lp.sum(), &[&logits]).remove(0);
        // d log p(k=1) / d logits = onehot(1) - softmax
        assert!((g.data()[0] + 1.0 / 3.0).abs() < 1e-10);
        assert!((g.data()[1] - 2.0 / 3.0).abs() < 1e-10);
    }

    #[test]
    fn poisson_log_prob_matches_pmf() {
        let d = Poisson::std(3.0);
        let lp = d.log_prob(&Tensor::scalar(2.0)).item();
        let want = (3.0f64.powi(2) * (-3.0f64).exp() / 2.0).ln();
        assert!((lp - want).abs() < 1e-10);
    }

    #[test]
    fn dirichlet_samples_live_on_simplex() {
        let d = Dirichlet::std(vec![2.0, 3.0, 4.0]);
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let s = d.sample(&mut rng);
            assert!(Constraint::Simplex.check(&s), "{s:?}");
        }
        assert!(d.log_prob(&Tensor::from_vec(vec![0.2, 0.3, 0.5])).item().is_finite());
    }

    #[test]
    fn constraint_transform_roundtrips() {
        for (c, v) in [
            (Constraint::Real, 0.7),
            (Constraint::Positive, 1.3),
            (Constraint::UnitInterval, 0.42),
            (Constraint::Interval(-2.0, 5.0), 1.1),
        ] {
            let y = Tensor::scalar(v);
            let x = c.inverse(&y);
            let back = c.transform(&x);
            assert!((back.item() - v).abs() < 1e-10, "{c:?}");
            assert!(c.check(&back), "{c:?}");
        }
    }

    #[test]
    fn transformed_dist_matches_lognormal() {
        // exp-transformed Normal IS LogNormal
        let base = Normal::std(0.2, 0.8);
        let td = TransformedDist::new(base, ExpT);
        let ln = LogNormal::std(0.2, 0.8);
        for &x in &[0.5, 1.0, 2.5] {
            let a = td.log_prob(&Tensor::scalar(x)).item();
            let b = ln.log_prob(&Tensor::scalar(x)).item();
            assert!((a - b).abs() < 1e-10, "{a} vs {b} at {x}");
        }
        assert_eq!(td.support(), Constraint::Positive);
        assert!(td.has_rsample());
    }

    #[test]
    fn interval_transform_density_integrates() {
        // MC check: samples of the transformed dist respect the interval
        let base = Normal::std(0.0, 1.0);
        let td = TransformedDist::new(base, IntervalT { lo: -1.0, hi: 3.0 });
        let mut rng = Pcg64::new(4);
        for _ in 0..200 {
            let s = td.sample(&mut rng).item();
            assert!((-1.0..=3.0).contains(&s));
        }
    }

    #[test]
    fn reparam_gradient_flows_through_sample() {
        let tape = Tape::new();
        let loc = tape.leaf(Tensor::scalar(0.0));
        let scale = tape.leaf(Tensor::scalar(1.0));
        let d = Normal::new(loc.clone(), scale.clone());
        let mut rng = Pcg64::new(5);
        let z = d.sample(&mut rng);
        let g = tape.grad(&z.sum(), &[&loc]).remove(0);
        assert!((g.item() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn analytic_kl_registry_hits_gaussian_pairs() {
        let tape = Tape::new();
        let q: Rc<dyn Dist<Var>> = Rc::new(Normal::new(
            tape.constant(Tensor::scalar(0.5)),
            tape.constant(Tensor::scalar(0.8)),
        ));
        let p: Rc<dyn Dist<Var>> = Rc::new(Normal::new(
            tape.constant(Tensor::scalar(0.0)),
            tape.constant(Tensor::scalar(1.0)),
        ));
        let kl = try_analytic_kl(q.as_ref(), p.as_ref()).expect("registry miss");
        let want = kl::kl_normal_normal(&Normal::std(0.5, 0.8), &Normal::std(0.0, 1.0)).item();
        assert!((kl.value().item() - want).abs() < 1e-12);
        // non-Gaussian pair misses
        let b: Rc<dyn Dist<Var>> = Rc::new(Bernoulli::new(tape.constant(Tensor::scalar(0.0))));
        assert!(try_analytic_kl(b.as_ref(), p.as_ref()).is_none());
    }

    #[test]
    fn uniform_log_prob_is_flat() {
        let d = Uniform::std(-1.0, 3.0);
        let lp = d.log_prob(&Tensor::scalar(0.0)).item();
        assert!((lp - (0.25f64).ln()).abs() < 1e-12);
        let (m, _) = mc_moments(&d, 50_000, 7);
        assert!((m - 1.0).abs() < 0.03, "mean {m}");
    }

    #[test]
    fn out_of_support_scores_neg_infinity() {
        assert_eq!(
            Uniform::std(0.0, 1.0).log_prob(&Tensor::scalar(2.0)).item(),
            f64::NEG_INFINITY
        );
        assert_eq!(
            Exponential::std(1.0).log_prob(&Tensor::scalar(-3.0)).item(),
            f64::NEG_INFINITY
        );
        assert_eq!(
            HalfCauchy::std(1.0).log_prob(&Tensor::scalar(-0.5)).item(),
            f64::NEG_INFINITY
        );
        // mixed in-/out-of-support vector: only the violating element
        let lp = Exponential::std(2.0).log_prob(&Tensor::from_vec(vec![0.5, -1.0]));
        assert!(lp.data()[0].is_finite());
        assert_eq!(lp.data()[1], f64::NEG_INFINITY);
    }

    #[test]
    fn batch_and_event_shapes_follow_parameters() {
        let n = Normal::new(Tensor::zeros(vec![4, 3]), Tensor::ones(vec![3]));
        assert_eq!(n.batch_shape().dims(), &[4, 3]);
        assert_eq!(n.event_shape().rank(), 0);
        let mv = MvNormalDiag::new(Tensor::zeros(vec![4, 3]), Tensor::ones(vec![4, 3]));
        assert_eq!(mv.batch_shape().dims(), &[4]);
        assert_eq!(mv.event_shape().dims(), &[3]);
        let c = Categorical::new(Tensor::zeros(vec![5, 2]));
        assert_eq!(c.batch_shape().dims(), &[5]);
        assert_eq!(c.event_shape().rank(), 0);
        let d = Dirichlet::std(vec![1.0, 2.0, 3.0]);
        assert_eq!(d.batch_shape().rank(), 0);
        assert_eq!(d.event_shape().dims(), &[3]);
    }

    #[test]
    fn mvnormal_diag_log_prob_reduces_event_dim() {
        let mv = MvNormalDiag::new(Tensor::zeros(vec![2, 3]), Tensor::ones(vec![2, 3]));
        let x = Tensor::zeros(vec![2, 3]);
        let lp = mv.log_prob(&x);
        assert_eq!(lp.dims(), &[2]);
        let per = -0.5 * LN_2PI;
        for &v in lp.data().iter() {
            assert!((v - 3.0 * per).abs() < 1e-12);
        }
    }

    #[test]
    fn to_event_sums_trailing_batch_dims() {
        let n = Normal::new(Tensor::zeros(vec![4, 3]), Tensor::ones(vec![4, 3]));
        let elementwise = n.log_prob(&Tensor::ones(vec![4, 3]));
        let ind = n.to_event(1);
        assert_eq!(ind.batch_shape().dims(), &[4]);
        assert_eq!(ind.event_shape().dims(), &[3]);
        let joint = ind.log_prob(&Tensor::ones(vec![4, 3]));
        assert_eq!(joint.dims(), &[4]);
        assert!(joint.allclose(&elementwise.sum_last(), 1e-12));
        // to_event(1) of a Normal == MvNormalDiag over the same params
        let mv = MvNormalDiag::new(Tensor::zeros(vec![4, 3]), Tensor::ones(vec![4, 3]));
        assert!(joint.allclose(&mv.log_prob(&Tensor::ones(vec![4, 3])), 1e-12));
    }

    #[test]
    fn expand_draws_independent_replicas() {
        let d = Normal::std(0.0, 1.0).expand(vec![64]);
        assert_eq!(d.batch_shape().dims(), &[64]);
        assert_eq!(d.event_shape().rank(), 0);
        let mut rng = Pcg64::new(3);
        let s = d.sample(&mut rng);
        assert_eq!(s.dims(), &[64]);
        let sd = s.data();
        assert!(
            sd.iter().any(|&v| (v - sd[0]).abs() > 1e-6),
            "expanded draws must be independent, not tiled"
        );
        let lp = d.log_prob(&Tensor::zeros(vec![64]));
        assert_eq!(lp.dims(), &[64]);
        assert!((lp.data()[0] - (-0.5 * LN_2PI)).abs() < 1e-12);
        // identity expansion keeps the pathwise sampler
        assert!(!d.has_rsample());
        assert!(Normal::std(0.0, 1.0).expand(vec![]).has_rsample());
    }

    #[test]
    #[should_panic(expected = "scalar-event")]
    fn expand_rejects_event_carrying_bases() {
        let d = Dirichlet::std(vec![1.0, 2.0]).expand(vec![4]);
        let mut rng = Pcg64::new(1);
        let _ = d.sample(&mut rng);
    }

    #[test]
    #[should_panic(expected = "does not support Categorical")]
    fn expand_rejects_categorical() {
        let d = Categorical::from_weights(&[1.0, 2.0]).expand(vec![4]);
        let _ = d.log_prob(&Tensor::from_vec(vec![0.0, 1.0, 0.0, 1.0]));
    }

    #[test]
    fn batched_categorical_matches_per_row_scalar() {
        let logits =
            Tensor::new(vec![0.0, 1.0, -0.5, 0.3, 0.3, 0.3, 2.0, -1.0, 0.0], vec![3, 3]);
        let d = Categorical::new(logits.clone());
        let x = Tensor::from_vec(vec![1.0, 0.0, 2.0]);
        let lp = d.log_prob(&x);
        assert_eq!(lp.dims(), &[3]);
        for r in 0..3 {
            let row = Categorical::new(logits.row(r));
            let want = row.log_prob(&Tensor::scalar(x.data()[r])).item();
            assert!((lp.data()[r] - want).abs() < 1e-10, "row {r}");
        }
        // batched samples land in range, one per row
        let mut rng = Pcg64::new(9);
        let s = d.sample(&mut rng);
        assert_eq!(s.dims(), &[3]);
        assert!(s.data().iter().all(|&v| (0.0..3.0).contains(&v)));
    }

    #[test]
    fn analytic_kl_looks_through_to_event() {
        let tape = Tape::new();
        let q = Normal::new(
            tape.constant(Tensor::full(vec![4], 0.5)),
            tape.constant(Tensor::full(vec![4], 0.8)),
        )
        .to_event(1);
        let q: Rc<dyn Dist<Var>> = q.into_var_dist(&tape);
        let p: Rc<dyn Dist<Var>> = Rc::new(Normal::new(
            tape.constant(Tensor::zeros(vec![4])),
            tape.constant(Tensor::ones(vec![4])),
        ));
        let kl = try_analytic_kl(q.as_ref(), p.as_ref()).expect("look-through miss");
        let per = kl::kl_normal_normal(&Normal::std(0.5, 0.8), &Normal::std(0.0, 1.0)).item();
        assert!((kl.value().sum() - 4.0 * per).abs() < 1e-10);
    }

    #[test]
    fn half_cauchy_is_positive_and_heavy_tailed() {
        let d = HalfCauchy::std(1.0);
        let mut rng = Pcg64::new(8);
        let xs: Vec<f64> = (0..20_000).map(|_| d.sample(&mut rng).item()).collect();
        assert!(xs.iter().all(|&x| x >= 0.0));
        // median of HalfCauchy(1) is tan(pi/4) = 1
        let mut s = xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = s[s.len() / 2];
        assert!((med - 1.0).abs() < 0.05, "median {med}");
    }
}
