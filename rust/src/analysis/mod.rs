//! Static model analyzer: the trace-skeleton linter (Pass 1) and the
//! shared diagnostic framework for the graph-IR verifier (Pass 2, in
//! [`crate::infer::compile`]).
//!
//! Pyro ships a validation layer (`pyro.enable_validation`,
//! `check_model_guide_match`, per-site shape checks) because most PPL
//! user errors — guide/model mismatch, forgotten subsample slicing,
//! non-reparameterized sites silently inflating gradient variance — are
//! *statically detectable* from one recorded trace, before any training
//! step is wasted. This module is Fyro's rendering of that layer: record
//! one model+guide skeleton (no optimizer step), abstractly interpret
//! it, and report every problem at once as structured [`Diagnostic`]
//! records with stable lint codes, severity levels, and site/frame
//! provenance. Diagnostics export through the telemetry warn-event sink
//! ([`Report::emit`]) and render as a `Display` report.
//!
//! Recording runs the contexts in **lenient** mode
//! ([`crate::poutine::Ctx::lenient`]), so handler-raised shape errors
//! (forgot `plate.select`, plate-dim collisions) do not abort the run —
//! the static pass re-derives the same codes from the recorded skeleton.
//! Runtime and static paths therefore emit the same diagnostics.
//!
//! ```
//! use fyro::prelude::*;
//! use fyro::analysis::{self, LintCode};
//! let model = |ctx: &mut Ctx| {
//!     let z = ctx.sample("z", Normal::std(0.0, 1.0));
//!     ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.5));
//! };
//! let guide = |ctx: &mut Ctx| {
//!     ctx.sample("typo", Normal::std(0.0, 1.0)); // not a model site
//! };
//! let mut store = ParamStore::new();
//! let report = analysis::lint_model_guide(&mut store, 0, &model, &guide, None);
//! assert!(report.contains(LintCode::GuideSiteNotInModel));
//! ```

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::params::ParamStore;
use crate::poutine::{Ctx, PlateFrame, Site, Trace};
use crate::tensor::Pcg64;

pub mod zoo;

// ----------------------------------------------------------- lint codes

/// Stable lint codes. Codes never change meaning once shipped; new
/// checks append new codes. `FY001`–`FY011` come from the trace-skeleton
/// linter (Pass 1), `FY012` from the graph-IR verifier (Pass 2), and
/// `FY013`–`FY015` tag runtime-only errors so runtime panics and static
/// diagnostics share one namespace.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LintCode {
    /// Guide samples a site the model never samples.
    GuideSiteNotInModel,
    /// An observed site appears in the guide (either the guide observes
    /// it directly, or it samples a site the model observes).
    ObservedSiteInGuide,
    /// Model latent not covered by the guide (sampled from the prior).
    ModelLatentNotInGuide,
    /// Same plate name with different size/subsample/dim between model
    /// and guide, or two plates colliding on one batch dim.
    PlateFrameMismatch,
    /// A site's value does not fit its plate's allocated batch dim —
    /// the classic forgotten `plate.select`.
    PlateShapeMismatch,
    /// A site mask cannot broadcast against the site's batch shape.
    MaskShapeMismatch,
    /// Non-reparameterized site under a pathwise-only estimator.
    NonReparamUnderPathwise,
    /// An observed value lies outside the distribution's support.
    ObservedOutsideSupport,
    /// A parameter holds non-finite values.
    NonFiniteParam,
    /// A store parameter neither model nor guide touches.
    UnusedParam,
    /// A guide parameter that can never receive a gradient.
    GuideParamNoGradient,
    /// Graph-IR verifier violation (def-before-use, alias safety,
    /// static shape inference).
    IrVerifier,
    /// `ctx.param` called on a context without a `ParamStore`.
    MissingParamStore,
    /// Duplicate sample site name in one execution.
    DuplicateSite,
    /// Plate subsample size out of range for the population.
    PlateSubsampleRange,
}

impl LintCode {
    /// Every code, in code order (for catalogs and docs).
    pub const ALL: [LintCode; 15] = [
        LintCode::GuideSiteNotInModel,
        LintCode::ObservedSiteInGuide,
        LintCode::ModelLatentNotInGuide,
        LintCode::PlateFrameMismatch,
        LintCode::PlateShapeMismatch,
        LintCode::MaskShapeMismatch,
        LintCode::NonReparamUnderPathwise,
        LintCode::ObservedOutsideSupport,
        LintCode::NonFiniteParam,
        LintCode::UnusedParam,
        LintCode::GuideParamNoGradient,
        LintCode::IrVerifier,
        LintCode::MissingParamStore,
        LintCode::DuplicateSite,
        LintCode::PlateSubsampleRange,
    ];

    /// The stable code string (`"FY001"`...).
    pub const fn code(&self) -> &'static str {
        match self {
            LintCode::GuideSiteNotInModel => "FY001",
            LintCode::ObservedSiteInGuide => "FY002",
            LintCode::ModelLatentNotInGuide => "FY003",
            LintCode::PlateFrameMismatch => "FY004",
            LintCode::PlateShapeMismatch => "FY005",
            LintCode::MaskShapeMismatch => "FY006",
            LintCode::NonReparamUnderPathwise => "FY007",
            LintCode::ObservedOutsideSupport => "FY008",
            LintCode::NonFiniteParam => "FY009",
            LintCode::UnusedParam => "FY010",
            LintCode::GuideParamNoGradient => "FY011",
            LintCode::IrVerifier => "FY012",
            LintCode::MissingParamStore => "FY013",
            LintCode::DuplicateSite => "FY014",
            LintCode::PlateSubsampleRange => "FY015",
        }
    }

    /// Short kebab-case name.
    pub const fn name(&self) -> &'static str {
        match self {
            LintCode::GuideSiteNotInModel => "guide-site-not-in-model",
            LintCode::ObservedSiteInGuide => "observed-site-in-guide",
            LintCode::ModelLatentNotInGuide => "model-latent-not-in-guide",
            LintCode::PlateFrameMismatch => "plate-frame-mismatch",
            LintCode::PlateShapeMismatch => "plate-shape-mismatch",
            LintCode::MaskShapeMismatch => "mask-shape-mismatch",
            LintCode::NonReparamUnderPathwise => "nonreparam-under-pathwise",
            LintCode::ObservedOutsideSupport => "observed-outside-support",
            LintCode::NonFiniteParam => "non-finite-param",
            LintCode::UnusedParam => "unused-param",
            LintCode::GuideParamNoGradient => "guide-param-no-gradient",
            LintCode::IrVerifier => "ir-verifier",
            LintCode::MissingParamStore => "missing-param-store",
            LintCode::DuplicateSite => "duplicate-site",
            LintCode::PlateSubsampleRange => "plate-subsample-range",
        }
    }

    /// Default severity: errors produce wrong inference results (or
    /// crash); warnings degrade it (variance, wasted parameters).
    pub const fn severity(&self) -> Severity {
        match self {
            LintCode::ModelLatentNotInGuide
            | LintCode::NonReparamUnderPathwise
            | LintCode::UnusedParam
            | LintCode::GuideParamNoGradient => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

impl fmt::Display for LintCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.code())
    }
}

/// Diagnostic severity. `Error` means the fit is wrong or will crash;
/// `Warning` means it is statistically degraded (gradient variance,
/// dead parameters) but well-defined.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub const fn as_str(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

// ---------------------------------------------------------- diagnostics

/// One structured finding: stable code, severity, provenance (site
/// and/or plate frame name) and a human message. The message does not
/// repeat the provenance — `Display` composes them.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub code: LintCode,
    pub severity: Severity,
    /// Sample-site name the finding anchors to, when there is one.
    pub site: Option<String>,
    /// Plate-frame (or parameter) name the finding anchors to.
    pub frame: Option<String>,
    pub message: String,
}

impl Diagnostic {
    pub fn new(
        code: LintCode,
        site: Option<&str>,
        frame: Option<&str>,
        message: impl Into<String>,
    ) -> Self {
        Diagnostic {
            code,
            severity: code.severity(),
            site: site.map(str::to_string),
            frame: frame.map(str::to_string),
            message: message.into(),
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}][{}]", self.code, self.severity.as_str())?;
        match (&self.site, &self.frame) {
            (Some(s), Some(p)) => write!(f, " site '{s}' / '{p}'")?,
            (Some(s), None) => write!(f, " site '{s}'")?,
            (None, Some(p)) => write!(f, " '{p}'")?,
            (None, None) => {}
        }
        write!(f, ": {}", self.message)
    }
}

/// The linter's output: every diagnostic found in one pass, in
/// deterministic check order.
#[derive(Clone, Debug, Default)]
pub struct Report {
    diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }

    pub fn diagnostics(&self) -> &[Diagnostic] {
        &self.diagnostics
    }

    pub fn len(&self) -> usize {
        self.diagnostics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// No diagnostics at all (errors or warnings).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    pub fn contains(&self, code: LintCode) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// First diagnostic with `code`, if any.
    pub fn find(&self, code: LintCode) -> Option<&Diagnostic> {
        self.diagnostics.iter().find(|d| d.code == code)
    }

    /// Send every diagnostic through the telemetry warn-event sink with
    /// its stable code (and bump the `lint_diagnostics` counter).
    pub fn emit(&self) {
        for d in &self.diagnostics {
            let site = d.site.as_deref().or(d.frame.as_deref()).unwrap_or("-");
            crate::telemetry::warn_lint(d.code.code(), site, &d.message);
        }
    }

    /// Collapse the report into one structured [`crate::error::Error`]
    /// (for loud first-step validation failures).
    pub fn to_error(&self) -> crate::error::Error {
        crate::error::Error::msg(format!("{self}"))
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            return write!(f, "model lint: clean");
        }
        write!(
            f,
            "model lint: {} diagnostic(s) ({} error(s), {} warning(s))",
            self.len(),
            self.error_count(),
            self.warning_count()
        )?;
        for d in &self.diagnostics {
            write!(f, "\n  {d}")?;
        }
        Ok(())
    }
}

// ------------------------------------------------------------ estimator

/// What the linter needs to know about the ELBO estimator in use, for
/// the reparameterization audit (FY007). Built by
/// [`Svi::analyze`](crate::infer::Svi::analyze) from
/// [`Elbo::name`](crate::infer::Elbo::name) and
/// [`Elbo::variance_reduced`](crate::infer::Elbo::variance_reduced).
#[derive(Clone, Copy, Debug)]
pub struct EstimatorHint {
    /// Estimator display name (`"TraceElbo"`, ...).
    pub name: &'static str,
    /// True when the estimator Rao-Blackwellizes score-function terms
    /// (TraceGraph); non-reparameterized sites are then fine.
    pub variance_reduced: bool,
}

// ------------------------------------------------------------ recording

/// Record one model+guide skeleton with **lenient** contexts: the guide
/// runs first, then the model replays the guide's latents on the same
/// tape (exactly the SVI pairing), and handler-raised diagnostics are
/// collected instead of aborting. Returns
/// `(model_trace, guide_trace, runtime_errors)`; the static linter
/// re-derives the runtime errors' codes from the traces, so callers
/// that go on to [`lint_traces`] can drop the third element.
pub fn record_pair(
    store: &mut ParamStore,
    rng: &mut Pcg64,
    model: &dyn Fn(&mut Ctx),
    guide: &dyn Fn(&mut Ctx),
) -> (Trace, Trace, Vec<crate::error::Error>) {
    let mut errors = Vec::new();
    let (guide_trace, tape) = {
        let mut gctx = Ctx::with_store(rng, store);
        gctx.lenient();
        guide(&mut gctx);
        errors.extend(gctx.take_lint_errors());
        let tape = gctx.tape.clone();
        (gctx.into_trace(), tape)
    };
    let model_trace = {
        let mut mctx = Ctx::with_store_on_tape(tape, rng, store);
        mctx.lenient();
        let replayed =
            crate::poutine::replay(|c: &mut Ctx| model(c), guide_trace.clone());
        replayed(&mut mctx);
        errors.extend(mctx.take_lint_errors());
        mctx.into_trace()
    };
    (model_trace, guide_trace, errors)
}

/// Record (leniently) and lint one model/guide pair: the one-call
/// front door used by the CLI `lint` subcommand and tests.
/// [`Svi::analyze`](crate::infer::Svi::analyze) wraps this with the
/// estimator hint filled in from its ELBO.
pub fn lint_model_guide(
    store: &mut ParamStore,
    seed: u64,
    model: &dyn Fn(&mut Ctx),
    guide: &dyn Fn(&mut Ctx),
    estimator: Option<&EstimatorHint>,
) -> Report {
    let mut rng = Pcg64::new(seed);
    let (model_trace, guide_trace, _runtime) =
        record_pair(store, &mut rng, model, guide);
    lint_traces(&model_trace, &guide_trace, store, estimator)
}

// --------------------------------------------------------- pass 1: lint

/// The trace-skeleton linter (Pass 1): abstractly interpret one recorded
/// model+guide pair and report every statically detectable problem.
/// Pure function of its inputs; diagnostics come back in deterministic
/// check order.
pub fn lint_traces(
    model_trace: &Trace,
    guide_trace: &Trace,
    store: &ParamStore,
    estimator: Option<&EstimatorHint>,
) -> Report {
    let mut report = Report::default();
    check_site_correspondence(model_trace, guide_trace, &mut report);
    check_plate_frames(model_trace, guide_trace, &mut report);
    for (role, trace) in [("model", model_trace), ("guide", guide_trace)] {
        for site in trace.sites() {
            check_site_shapes(role, site, &mut report);
            check_mask(role, site, &mut report);
        }
    }
    check_reparameterization(model_trace, guide_trace, estimator, &mut report);
    check_observed_support(model_trace, &mut report);
    check_params(model_trace, guide_trace, store, &mut report);
    report
}

/// FY001/FY002/FY003: guide sites ⊆ model latent sites, no observed
/// sites in the guide, and (warning) every model latent covered.
fn check_site_correspondence(model: &Trace, guide: &Trace, report: &mut Report) {
    for g in guide.sites() {
        if g.intervened {
            continue;
        }
        if g.is_observed {
            report.push(Diagnostic::new(
                LintCode::ObservedSiteInGuide,
                Some(&g.name),
                None,
                "the guide observes this site — observations belong in the model",
            ));
            continue;
        }
        match model.get(&g.name) {
            None => report.push(Diagnostic::new(
                LintCode::GuideSiteNotInModel,
                Some(&g.name),
                None,
                "the guide samples this site but the model never does",
            )),
            Some(m) if m.is_observed => report.push(Diagnostic::new(
                LintCode::ObservedSiteInGuide,
                Some(&g.name),
                None,
                "the guide samples this site, but the model observes it — \
                 a guide may only sample the model's latent sites",
            )),
            Some(_) => {}
        }
    }
    for m in model.sites() {
        if m.is_observed || m.intervened || m.dist.dist_name() == "Delta" {
            continue; // deterministic sites need no guide coverage
        }
        if guide.get(&m.name).is_none() {
            report.push(Diagnostic::new(
                LintCode::ModelLatentNotInGuide,
                Some(&m.name),
                None,
                "the guide never samples this model latent; SVI will fall \
                 back to the prior as its variational family for it",
            ));
        }
    }
}

/// FY004: same plate name ⇒ same size/subsample/dim between model and
/// guide, and no two frames of one site may occupy the same batch dim.
fn check_plate_frames(model: &Trace, guide: &Trace, report: &mut Report) {
    let mut model_frames: BTreeMap<String, PlateFrame> = BTreeMap::new();
    for site in model.sites() {
        for f in site.frames() {
            model_frames.entry(f.name.clone()).or_insert_with(|| f.clone());
        }
    }
    let mut reported: BTreeSet<String> = BTreeSet::new();
    for site in guide.sites() {
        for f in site.frames() {
            let Some(mf) = model_frames.get(&f.name) else { continue };
            let same = mf.size == f.size && mf.subsample == f.subsample && mf.dim == f.dim;
            if !same && reported.insert(f.name.clone()) {
                report.push(Diagnostic::new(
                    LintCode::PlateFrameMismatch,
                    Some(&site.name),
                    Some(&f.name),
                    format!(
                        "plate disagrees between model and guide: model has \
                         size {}/subsample {}/dim {}, guide has size \
                         {}/subsample {}/dim {}",
                        mf.size, mf.subsample, mf.dim, f.size, f.subsample, f.dim
                    ),
                ));
            }
        }
    }
    for (role, trace) in [("model", model), ("guide", guide)] {
        for site in trace.sites() {
            let frames = site.frames();
            for (i, f) in frames.iter().enumerate() {
                if let Some(clash) = frames[..i].iter().find(|g| g.dim == f.dim) {
                    report.push(Diagnostic::new(
                        LintCode::PlateFrameMismatch,
                        Some(&site.name),
                        Some(&f.name),
                        format!(
                            "{role} plates '{}' and '{}' collide on batch \
                             dim {} — enclosing plates must occupy \
                             distinct dims",
                            clash.name, f.name, f.dim
                        ),
                    ));
                }
            }
        }
    }
}

/// FY005: the static rendering of the runtime forgot-`plate.select`
/// check — at each enclosing plate's allocated dim, the site's value
/// must carry the subsample size, broadcast (size 1), or not extend to
/// the dim at all.
fn check_site_shapes(role: &str, site: &Site, report: &mut Report) {
    if site.intervened {
        return;
    }
    let vdims = site.value.value().dims().to_vec();
    let event_rank = site.dist.event_shape().rank();
    for frame in site.frames() {
        let from_right = event_rank + frame.dim;
        if from_right >= vdims.len() {
            continue;
        }
        let d = vdims[vdims.len() - 1 - from_right];
        if d != frame.subsample && d != 1 {
            report.push(Diagnostic::new(
                LintCode::PlateShapeMismatch,
                Some(&site.name),
                Some(&frame.name),
                format!(
                    "{role} batch dim {} (from the right) has size {d}, but \
                     the plate expects its subsample size {} there (did you \
                     forget `plate.select`, or mean `to_event`?)",
                    frame.dim, frame.subsample
                ),
            ));
        }
    }
}

/// FY006: the site mask must broadcast against the site's batch shape
/// (right-aligned, sizes equal or 1, and no extra mask dims).
fn check_mask(role: &str, site: &Site, report: &mut Report) {
    let Some(mask) = &site.mask else { return };
    let vdims = site.value.value().dims().to_vec();
    let event_rank = site.dist.event_shape().rank();
    if event_rank > vdims.len() {
        return; // value/event mismatch reported elsewhere
    }
    let batch = &vdims[..vdims.len() - event_rank];
    let mdims = mask.dims();
    let mut broadcastable = mdims.len() <= batch.len();
    if broadcastable {
        for i in 1..=mdims.len() {
            let m = mdims[mdims.len() - i];
            let b = batch[batch.len() - i];
            if m != b && m != 1 && b != 1 {
                broadcastable = false;
                break;
            }
        }
    }
    if !broadcastable {
        report.push(Diagnostic::new(
            LintCode::MaskShapeMismatch,
            Some(&site.name),
            None,
            format!(
                "{role} mask shape {mdims:?} cannot broadcast against the \
                 site's batch shape {batch:?}"
            ),
        ));
    }
}

/// FY007: non-reparameterized latents under a pathwise-only estimator.
fn check_reparameterization(
    model: &Trace,
    guide: &Trace,
    estimator: Option<&EstimatorHint>,
    report: &mut Report,
) {
    let Some(est) = estimator else { return };
    if est.variance_reduced {
        return;
    }
    let mut flagged: BTreeSet<&str> = BTreeSet::new();
    for site in guide.sites() {
        if site.needs_score_term() {
            flagged.insert(&site.name);
        }
    }
    for site in model.sites() {
        if site.needs_score_term() && guide.get(&site.name).is_none() {
            flagged.insert(&site.name);
        }
    }
    for name in flagged {
        report.push(Diagnostic::new(
            LintCode::NonReparamUnderPathwise,
            Some(name),
            None,
            format!(
                "site has no reparameterized sampler, so {} must fall back \
                 to score-function (REINFORCE) gradients with no variance \
                 reduction — use TraceGraphElbo (Rao-Blackwellized) instead",
                est.name
            ),
        ));
    }
}

/// FY008: observed values must lie inside their distribution's support
/// (which also catches non-finite observations).
fn check_observed_support(model: &Trace, report: &mut Report) {
    for site in model.sites() {
        if !site.is_observed || site.intervened {
            continue;
        }
        let support = site.dist.support();
        if !support.check(site.value.value()) {
            report.push(Diagnostic::new(
                LintCode::ObservedOutsideSupport,
                Some(&site.name),
                None,
                format!(
                    "observed value lies outside the {support:?} support of {}",
                    site.dist.dist_name()
                ),
            ));
        }
    }
}

/// FY009/FY010/FY011: non-finite initial params, params nobody touches,
/// and guide params that can never receive a gradient.
fn check_params(model: &Trace, guide: &Trace, store: &ParamStore, report: &mut Report) {
    let mut used: BTreeSet<&str> = BTreeSet::new();
    used.extend(model.param_leaves.keys().map(String::as_str));
    used.extend(guide.param_leaves.keys().map(String::as_str));
    for name in store.names() {
        let finite = |t: Option<crate::tensor::Tensor>| {
            t.map(|t| t.data().iter().all(|v| v.is_finite())).unwrap_or(true)
        };
        if !finite(store.get(&name)) || !finite(store.get_unconstrained(&name)) {
            report.push(Diagnostic::new(
                LintCode::NonFiniteParam,
                None,
                Some(&name),
                "parameter holds non-finite values (NaN or infinity); \
                 gradients through it are poisoned",
            ));
        }
        if !used.contains(name.as_str()) {
            report.push(Diagnostic::new(
                LintCode::UnusedParam,
                None,
                Some(&name),
                "parameter exists in the store but neither model nor guide \
                 touched it in this trace",
            ));
        }
    }
    let guide_has_latents =
        guide.sites().iter().any(|s| !s.is_observed && !s.intervened);
    if !guide_has_latents && !guide.param_leaves.is_empty() {
        let mut names: Vec<&str> =
            guide.param_leaves.keys().map(String::as_str).collect();
        names.sort_unstable();
        for name in names {
            report.push(Diagnostic::new(
                LintCode::GuideParamNoGradient,
                None,
                Some(name),
                "guide parameter can never receive a gradient: the guide \
                 records no latent sample sites",
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Constraint, Normal};
    use crate::tensor::Tensor;

    fn conj_model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    }

    fn conj_guide(ctx: &mut Ctx) {
        let loc = ctx.param("q.loc", || Tensor::scalar(0.0));
        let scale =
            ctx.param_constrained("q.scale", || Tensor::scalar(1.0), Constraint::Positive);
        ctx.sample("z", Normal::new(loc, scale));
    }

    #[test]
    fn clean_pair_is_clean() {
        let mut store = ParamStore::new();
        let report =
            lint_model_guide(&mut store, 0, &conj_model, &conj_guide, None);
        assert!(report.is_clean(), "unexpected diagnostics: {report}");
        assert_eq!(format!("{report}"), "model lint: clean");
    }

    #[test]
    fn guide_typo_reports_fy001_and_fy003() {
        let guide = |ctx: &mut Ctx| {
            ctx.sample("zz", Normal::std(0.0, 1.0));
        };
        let mut store = ParamStore::new();
        let report = lint_model_guide(&mut store, 0, &conj_model, &guide, None);
        let d = report.find(LintCode::GuideSiteNotInModel).expect("FY001");
        assert_eq!(d.site.as_deref(), Some("zz"));
        assert_eq!(d.severity, Severity::Error);
        // and the model latent 'z' is now uncovered
        let d = report.find(LintCode::ModelLatentNotInGuide).expect("FY003");
        assert_eq!(d.site.as_deref(), Some("z"));
        assert_eq!(d.severity, Severity::Warning);
    }

    #[test]
    fn forgotten_select_is_linted_not_panicked() {
        let data = Tensor::from_vec(vec![0.0; 10]);
        let model = move |ctx: &mut Ctx| {
            ctx.plate("data", 10, Some(3), |ctx, _plate| {
                ctx.observe("x", Normal::std(0.0, 1.0), data.clone());
            });
        };
        let guide = |_ctx: &mut Ctx| {};
        let mut store = ParamStore::new();
        let report = lint_model_guide(&mut store, 0, &model, &guide, None);
        let d = report.find(LintCode::PlateShapeMismatch).expect("FY005");
        assert_eq!(d.site.as_deref(), Some("x"));
        assert_eq!(d.frame.as_deref(), Some("data"));
        assert!(d.message.contains("forget `plate.select`"));
    }

    #[test]
    fn display_carries_code_and_provenance() {
        let d = Diagnostic::new(
            LintCode::PlateShapeMismatch,
            Some("x"),
            Some("data"),
            "boom",
        );
        assert_eq!(format!("{d}"), "[FY005][error] site 'x' / 'data': boom");
    }

    #[test]
    fn codes_are_stable_and_unique() {
        let codes: BTreeSet<&str> = LintCode::ALL.iter().map(|c| c.code()).collect();
        assert_eq!(codes.len(), LintCode::ALL.len());
        assert_eq!(LintCode::PlateShapeMismatch.code(), "FY005");
        assert_eq!(LintCode::IrVerifier.code(), "FY012");
    }
}
