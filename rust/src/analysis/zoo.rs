//! The example zoo: small, known-good model/guide pairs spanning the
//! modeling surface (conjugate scalar, subsampled plate + `select`,
//! vectorized hierarchical plate, discrete latent under TraceGraph).
//! They serve double duty: the CLI `lint` subcommand and the
//! zero-false-positive test sweep both gate the linter against every
//! pair — a clean linter must report nothing on any of them.

use super::EstimatorHint;
use crate::dist::{Bernoulli, Constraint, Normal};
use crate::poutine::Ctx;
use crate::tensor::Tensor;

/// One known-good model/guide pair plus the estimator it is meant to
/// train under (the linter's FY007 audit is estimator-dependent).
#[derive(Clone, Copy)]
pub struct ZooPair {
    pub name: &'static str,
    pub model: fn(&mut Ctx),
    pub guide: fn(&mut Ctx),
    pub estimator: EstimatorHint,
}

/// Every zoo pair, in stable order.
pub fn all() -> Vec<ZooPair> {
    vec![
        ZooPair {
            name: "conjugate_normal",
            model: conjugate_model,
            guide: conjugate_guide,
            estimator: EstimatorHint { name: "Trace", variance_reduced: false },
        },
        ZooPair {
            name: "plated_regression",
            model: plated_model,
            guide: plated_guide,
            estimator: EstimatorHint { name: "Trace", variance_reduced: false },
        },
        ZooPair {
            name: "hierarchical_groups",
            model: hierarchical_model,
            guide: hierarchical_guide,
            estimator: EstimatorHint { name: "TraceMeanField", variance_reduced: false },
        },
        ZooPair {
            name: "bernoulli_tracegraph",
            model: bernoulli_model,
            guide: bernoulli_guide,
            estimator: EstimatorHint { name: "TraceGraph", variance_reduced: true },
        },
    ]
}

// ---- conjugate_normal: scalar latent, scalar observation ----------

fn conjugate_model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
}

fn conjugate_guide(ctx: &mut Ctx) {
    let loc = ctx.param("z.loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("z.scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("z", Normal::new(loc, scale));
}

// ---- plated_regression: subsampled plate with `plate.select` ------

fn regression_data() -> Tensor {
    Tensor::new((0..12).map(|i| (i as f64 * 0.37).sin()).collect(), vec![12])
}

fn plated_model(ctx: &mut Ctx) {
    let w = ctx.sample("w", Normal::std(0.0, 1.0));
    let data = regression_data();
    ctx.plate("data", 12, Some(4), |ctx, plate| {
        ctx.observe("obs", Normal::new(w.clone(), ctx.cs(1.0)), plate.select(&data));
    });
}

fn plated_guide(ctx: &mut Ctx) {
    let loc = ctx.param("w.loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("w.scale", || Tensor::scalar(0.5), Constraint::Positive);
    ctx.sample("w", Normal::new(loc, scale));
}

// ---- hierarchical_groups: vectorized latent inside a full plate ---

fn group_data() -> Tensor {
    Tensor::new((0..6).map(|i| 0.25 * i as f64 - 0.5).collect(), vec![6])
}

fn hierarchical_model(ctx: &mut Ctx) {
    ctx.plate("groups", 6, None, |ctx, _plate| {
        let theta = ctx.sample(
            "theta",
            Normal::new(ctx.c(Tensor::zeros(vec![6])), ctx.c(Tensor::ones(vec![6]))),
        );
        ctx.observe("y", Normal::new(theta, ctx.cs(1.0)), group_data());
    });
}

fn hierarchical_guide(ctx: &mut Ctx) {
    ctx.plate("groups", 6, None, |ctx, _plate| {
        let loc = ctx.param("theta.loc", || Tensor::zeros(vec![6]));
        let scale = ctx.param_constrained(
            "theta.scale",
            || Tensor::ones(vec![6]),
            Constraint::Positive,
        );
        ctx.sample("theta", Normal::new(loc, scale));
    });
}

// ---- bernoulli_tracegraph: discrete latent, Rao-Blackwellized -----

fn bernoulli_model(ctx: &mut Ctx) {
    let k = ctx.sample("k", Bernoulli::std(0.3));
    ctx.observe("x", Normal::new(k, ctx.cs(1.0)), Tensor::scalar(0.8));
}

fn bernoulli_guide(ctx: &mut Ctx) {
    let logit = ctx.param("k.logit", || Tensor::scalar(0.0));
    ctx.sample("k", Bernoulli::new(logit));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;

    #[test]
    fn every_zoo_pair_lints_clean() {
        for pair in all() {
            let mut store = ParamStore::new();
            let report = super::super::lint_model_guide(
                &mut store,
                11,
                &pair.model,
                &pair.guide,
                Some(&pair.estimator),
            );
            assert!(
                report.is_clean(),
                "zoo pair '{}' should lint clean, got:\n{report}",
                pair.name
            );
        }
    }
}
