//! Telemetry exporters: JSONL event stream, serde-free JSON snapshot,
//! and a `Display` dashboard table.
//!
//! Three ways out of the recorder, all zero-dependency:
//!
//! - **JSONL events** — install a sink with [`set_jsonl_path`] and
//!   structured events (warn events, explicit snapshot dumps) append
//!   one JSON object per line, flushed per event.
//! - **Snapshot JSON** — [`TelemetrySnapshot::to_json`] renders the
//!   full metric state as a [`crate::benchkit::json::JsonObj`], so
//!   bench records can embed telemetry verbatim
//!   (`record.obj("telemetry", snap.to_json())`).
//! - **Dashboard** — [`TelemetrySnapshot`] implements `Display` as a
//!   fixed-width table for terminals (`examples/quickstart.rs` prints
//!   it).
//!
//! Everything here is a cold path: snapshots and events allocate
//! freely. The hot-path guarantees live in [`crate::telemetry`].

use std::fmt;
use std::io::Write;
use std::sync::Mutex;

use super::HIST_BUCKETS;
use crate::benchkit::json::JsonObj;

// ------------------------------------------------------------ snapshots

/// Owned copy of one histogram's state. Percentiles are approximate
/// (bucket upper bound, clamped to the observed min/max): the estimate
/// is within a factor of two of the true value, and exact when all
/// observations share a value.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub counts: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
}

impl HistSnapshot {
    /// Approximate quantile `q` in `[0, 1]`; NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                let hi: u64 = if b == 0 {
                    0
                } else if b >= 63 {
                    u64::MAX
                } else {
                    (1u64 << b) - 1
                };
                return hi.clamp(self.min, self.max) as f64;
            }
        }
        self.max as f64
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Exact mean of the recorded values; NaN when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    fn to_json(&self) -> JsonObj {
        JsonObj::new()
            .int("count", self.count as usize)
            .num("mean", self.mean())
            .num("p50", self.p50())
            .num("p95", self.p95())
            .num("p99", self.p99())
            .num("min", if self.count == 0 { f64::NAN } else { self.min as f64 })
            .num("max", if self.count == 0 { f64::NAN } else { self.max as f64 })
    }
}

/// Per-site summary captured by
/// [`TelemetryMessenger`](super::handler::TelemetryMessenger):
/// hit count, cumulative handler-measured nanoseconds, value shape and
/// unscaled log-prob summary (raw `dist.log_prob(value)` sums — plate
/// scaling and masks are not applied).
#[derive(Clone, Debug)]
pub struct SiteSnapshot {
    pub name: String,
    pub hits: u64,
    pub total_ns: u64,
    pub numel: usize,
    pub dims: Vec<usize>,
    pub last_log_prob: f64,
    pub sum_log_prob: f64,
    pub min_log_prob: f64,
    pub max_log_prob: f64,
}

impl SiteSnapshot {
    pub fn mean_ns(&self) -> f64 {
        if self.hits == 0 {
            f64::NAN
        } else {
            self.total_ns as f64 / self.hits as f64
        }
    }

    pub fn mean_log_prob(&self) -> f64 {
        if self.hits == 0 {
            f64::NAN
        } else {
            self.sum_log_prob / self.hits as f64
        }
    }

    fn to_json(&self) -> JsonObj {
        let dims = self.dims.iter().map(|&d| JsonObj::new().int("d", d)).collect();
        JsonObj::new()
            .str("name", &self.name)
            .int("hits", self.hits as usize)
            .num("mean_ns", self.mean_ns())
            .int("numel", self.numel)
            .arr("dims", dims)
            .num("last_log_prob", self.last_log_prob)
            .num("mean_log_prob", self.mean_log_prob())
            .num("min_log_prob", self.min_log_prob)
            .num("max_log_prob", self.max_log_prob)
    }
}

/// A point-in-time copy of every metric, taken by
/// [`snapshot`](super::snapshot). Render it with [`Self::to_json`]
/// (machine) or `Display` (terminal dashboard).
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, f64)>,
    pub hists: Vec<(&'static str, HistSnapshot)>,
    pub sites: Vec<SiteSnapshot>,
}

impl TelemetrySnapshot {
    /// Counter value by name; 0 for unknown names.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    /// Gauge value by name.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    /// Histogram by name.
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    /// Site summary by name.
    pub fn site(&self, name: &str) -> Option<&SiteSnapshot> {
        self.sites.iter().find(|s| s.name == name)
    }

    /// Serde-free JSON rendering, embeddable in benchkit records.
    pub fn to_json(&self) -> JsonObj {
        let mut counters = JsonObj::new();
        for (name, v) in &self.counters {
            counters = counters.int(name, *v as usize);
        }
        let mut gauges = JsonObj::new();
        for (name, v) in &self.gauges {
            gauges = gauges.num(name, *v);
        }
        let mut hists = JsonObj::new();
        for (name, h) in &self.hists {
            hists = hists.obj(name, h.to_json());
        }
        JsonObj::new()
            .obj("counters", counters)
            .obj("gauges", gauges)
            .obj("hists", hists)
            .arr("sites", self.sites.iter().map(SiteSnapshot::to_json).collect())
    }
}

fn fmt_ns(ns: f64) -> String {
    if !ns.is_finite() {
        "-".to_string()
    } else if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

impl fmt::Display for TelemetrySnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "telemetry dashboard")?;
        writeln!(f, "===================")?;
        let live: Vec<String> = self
            .counters
            .iter()
            .filter(|(_, v)| *v > 0)
            .map(|(n, v)| format!("{n}={v}"))
            .collect();
        writeln!(
            f,
            "counters: {}",
            if live.is_empty() { "(none)".to_string() } else { live.join("  ") }
        )?;
        let gauges: Vec<String> =
            self.gauges.iter().map(|(n, v)| format!("{n}={v:.6}")).collect();
        writeln!(f, "gauges:   {}", gauges.join("  "))?;
        writeln!(f)?;
        writeln!(
            f,
            "{:<14} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "histogram", "count", "mean", "p50", "p95", "p99", "max"
        )?;
        for (name, h) in &self.hists {
            if h.is_empty() {
                continue;
            }
            let unit = |v: f64| {
                if name.ends_with("_ns") {
                    fmt_ns(v)
                } else {
                    format!("{v:.0}")
                }
            };
            writeln!(
                f,
                "{:<14} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
                name,
                h.count,
                unit(h.mean()),
                unit(h.p50()),
                unit(h.p95()),
                unit(h.p99()),
                unit(h.max as f64)
            )?;
        }
        if !self.sites.is_empty() {
            writeln!(f)?;
            writeln!(
                f,
                "{:<14} {:>8} {:>10} {:>8} {:>12} {:>12}",
                "site", "hits", "mean", "numel", "last_logp", "mean_logp"
            )?;
            for s in &self.sites {
                writeln!(
                    f,
                    "{:<14} {:>8} {:>10} {:>8} {:>12.4} {:>12.4}",
                    s.name,
                    s.hits,
                    fmt_ns(s.mean_ns()),
                    s.numel,
                    s.last_log_prob,
                    s.mean_log_prob()
                )?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------- JSONL sink

struct Sink {
    out: std::io::BufWriter<std::fs::File>,
    seq: u64,
}

static SINK: Mutex<Option<Sink>> = Mutex::new(None);

/// Install a JSONL event sink at `path` (truncates an existing file).
/// Events flow whenever a sink is installed, independent of the metric
/// enable switch — installing the sink *is* the opt-in.
pub fn set_jsonl_path(path: &str) -> std::io::Result<()> {
    let file = std::fs::File::create(path)?;
    *SINK.lock().unwrap() = Some(Sink { out: std::io::BufWriter::new(file), seq: 0 });
    Ok(())
}

/// Flush and remove the JSONL sink (no-op when none is installed).
pub fn clear_jsonl() {
    if let Some(mut sink) = SINK.lock().unwrap().take() {
        let _ = sink.out.flush();
    }
}

fn write_line(obj: JsonObj) {
    let mut guard = SINK.lock().unwrap();
    if let Some(sink) = guard.as_mut() {
        let line = JsonObj::new().int("seq", sink.seq as usize).merge(obj);
        sink.seq += 1;
        let _ = writeln!(sink.out, "{}", line.render());
        let _ = sink.out.flush();
    }
}

/// Append one event line (`{"seq": n, "event": kind, ...fields}`) to
/// the installed sink; no-op without a sink.
pub fn emit_event(kind: &str, fields: &[(&str, &str)]) {
    if SINK.lock().unwrap().is_none() {
        return;
    }
    let mut obj = JsonObj::new().str("event", kind);
    for (k, v) in fields {
        obj = obj.str(k, v);
    }
    write_line(obj);
}

/// Append one event line whose payload is an already-built JSON object
/// (`{"seq": n, "event": kind, ...obj fields}`); no-op without a sink.
pub fn emit_object(kind: &str, obj: JsonObj) {
    if SINK.lock().unwrap().is_none() {
        return;
    }
    write_line(JsonObj::new().str("event", kind).merge(obj));
}

/// Append a full snapshot event
/// (`{"seq": n, "event": "snapshot", "label": ..., "telemetry": {...}}`).
pub fn emit_snapshot(label: &str) {
    if SINK.lock().unwrap().is_none() {
        return;
    }
    let obj = JsonObj::new()
        .str("event", "snapshot")
        .str("label", label)
        .obj("telemetry", super::snapshot().to_json());
    write_line(obj);
}

// ------------------------------------------------------ JSONL reading

/// Parse one flat JSONL line into `(key, value)` pairs: string values
/// are unescaped; numbers, booleans and `null` come back as their raw
/// text; nested objects/arrays come back as their raw balanced text.
/// Returns `None` on malformed input. This is the test-side half of the
/// JSONL round trip (the emitter is [`emit_event`]); it is not a
/// general-purpose JSON parser.
pub fn parse_jsonl_line(line: &str) -> Option<Vec<(String, String)>> {
    let line = line.trim();
    let inner = line.strip_prefix('{')?.strip_suffix('}')?;
    let bytes = inner.as_bytes();
    let mut fields = Vec::new();
    let mut i = 0usize;
    loop {
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i >= bytes.len() {
            break;
        }
        let (key, next) = parse_string(inner, i)?;
        i = next;
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b':' {
            return None;
        }
        i += 1;
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        let (value, next) = parse_value(inner, i)?;
        i = next;
        fields.push((key, value));
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i < bytes.len() {
            if bytes[i] != b',' {
                return None;
            }
            i += 1;
        }
    }
    Some(fields)
}

/// Parse a `"..."` string starting at byte `i`; returns (unescaped,
/// index past the closing quote).
fn parse_string(s: &str, i: usize) -> Option<(String, usize)> {
    let bytes = s.as_bytes();
    if bytes.get(i) != Some(&b'"') {
        return None;
    }
    let mut out = String::new();
    let mut chars = s[i + 1..].char_indices();
    while let Some((off, c)) = chars.next() {
        match c {
            '"' => return Some((out, i + 1 + off + 1)),
            '\\' => {
                let (_, esc) = chars.next()?;
                match esc {
                    '"' => out.push('"'),
                    '\\' => out.push('\\'),
                    '/' => out.push('/'),
                    'n' => out.push('\n'),
                    't' => out.push('\t'),
                    'r' => out.push('\r'),
                    'b' => out.push('\u{8}'),
                    'f' => out.push('\u{c}'),
                    'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let (_, h) = chars.next()?;
                            code = code * 16 + h.to_digit(16)?;
                        }
                        out.push(char::from_u32(code)?);
                    }
                    _ => return None,
                }
            }
            c => out.push(c),
        }
    }
    None
}

/// Parse any JSON value starting at byte `i`; strings are unescaped,
/// everything else is returned as raw text (nested containers
/// balanced-brace matched).
fn parse_value(s: &str, i: usize) -> Option<(String, usize)> {
    let bytes = s.as_bytes();
    match *bytes.get(i)? {
        b'"' => parse_string(s, i),
        b'{' | b'[' => {
            let (open, close) = if bytes[i] == b'{' { (b'{', b'}') } else { (b'[', b']') };
            let mut depth = 0usize;
            let mut j = i;
            let mut in_str = false;
            let mut escaped = false;
            while j < bytes.len() {
                let b = bytes[j];
                if in_str {
                    if escaped {
                        escaped = false;
                    } else if b == b'\\' {
                        escaped = true;
                    } else if b == b'"' {
                        in_str = false;
                    }
                } else if b == b'"' {
                    in_str = true;
                } else if b == open {
                    depth += 1;
                } else if b == close {
                    depth -= 1;
                    if depth == 0 {
                        return Some((s[i..=j].to_string(), j + 1));
                    }
                }
                j += 1;
            }
            None
        }
        _ => {
            let mut j = i;
            while j < bytes.len() && bytes[j] != b',' && !(bytes[j] as char).is_whitespace()
            {
                j += 1;
            }
            if j == i {
                None
            } else {
                Some((s[i..j].to_string(), j))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist_with(values: &[u64]) -> HistSnapshot {
        let mut h = HistSnapshot {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        };
        for &v in values {
            h.counts[super::super::HistCell::bucket(v)] += 1;
            h.count += 1;
            h.sum += v;
            h.min = h.min.min(v);
            h.max = h.max.max(v);
        }
        h
    }

    #[test]
    fn single_valued_hist_is_exact() {
        let h = hist_with(&[1000; 32]);
        assert_eq!(h.p50(), 1000.0);
        assert_eq!(h.p95(), 1000.0);
        assert_eq!(h.p99(), 1000.0);
        assert_eq!(h.mean(), 1000.0);
    }

    #[test]
    fn empty_hist_is_nan() {
        let h = hist_with(&[]);
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
        assert!(h.is_empty());
    }

    #[test]
    fn quantiles_within_bucket_factor() {
        let mut values = vec![100u64; 90];
        values.extend([100_000u64; 10]);
        let h = hist_with(&values);
        let p50 = h.p50();
        assert!(p50 >= 64.0 && p50 <= 200.0, "p50 {p50} out of bucket range");
        // p99 lands in the tail bucket; clamped to the observed max it
        // is exact here.
        assert_eq!(h.p99(), 100_000.0);
        assert!(h.p50() <= h.p95() && h.p95() <= h.p99());
    }

    #[test]
    fn parse_round_trips_escapes() {
        let msg = "a \"quoted\"\nline\\with\ttabs";
        let line = crate::benchkit::json::JsonObj::new()
            .str("event", "warn")
            .str("message", msg)
            .render();
        let fields = parse_jsonl_line(&line).expect("parse");
        assert_eq!(fields[0], ("event".to_string(), "warn".to_string()));
        assert_eq!(fields[1].1, msg);
    }

    #[test]
    fn parse_handles_numbers_and_nesting() {
        let line = "{\"seq\": 3, \"ok\": true, \"inner\": {\"a\": [1, 2], \"s\": \"x}\"}}";
        let fields = parse_jsonl_line(line).expect("parse");
        assert_eq!(fields[0], ("seq".to_string(), "3".to_string()));
        assert_eq!(fields[1], ("ok".to_string(), "true".to_string()));
        assert_eq!(fields[2].0, "inner");
        assert!(fields[2].1.starts_with('{') && fields[2].1.ends_with('}'));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse_jsonl_line("not json").is_none());
        assert!(parse_jsonl_line("{\"k\" 1}").is_none());
    }
}
