//! Telemetry: zero-dependency observability for every inference engine.
//!
//! The Pyro paper's thesis is that inference should be *inspectable* —
//! every piece of machinery is an effect handler you can compose and
//! observe. This module extends that discipline to production metrics:
//!
//! - a global, lock-free-when-off metric recorder — counters, gauges,
//!   fixed-bucket log-scale histograms (p50/p95/p99) and monotonic span
//!   timers, all preregistered as enums over static atomics;
//! - a [`TelemetryMessenger`](handler::TelemetryMessenger) Poutine
//!   handler that composes like `block`/`scale` and records per-site
//!   timings, log-prob summaries and sample shapes — observability as
//!   just another effect handler;
//! - engine instrumentation threaded through `Svi::step`,
//!   graph-mode compilation, `DataParallelSvi` and the async
//!   `ParamServer` (see the call sites in those modules);
//! - exporters ([`export`]): a JSONL event stream, a serde-free JSON
//!   snapshot that bench records embed, and a `Display` dashboard.
//!
//! ## The determinism contract
//!
//! Telemetry **never touches the RNG stream and never perturbs
//! numerics**: every probe reads values the engine already computed,
//! after it computed them. Training with telemetry enabled is bitwise
//! identical to training with it disabled on the dynamic, graph-mode
//! and threaded data-parallel paths alike — pinned by
//! `tests/test_telemetry.rs`. Metrics themselves are deterministic
//! where the underlying execution is: gradient norms accumulate in
//! sorted parameter order, so the same run reports the same numbers.
//!
//! ## Cost model
//!
//! Disabled (the default), every probe is **one relaxed atomic load**
//! — no time syscall, no lock, no allocation. Enabled, the steady
//! state allocates nothing: metric identity is a `Copy` enum index
//! into static atomic arrays, histograms bump a fixed bucket, span
//! timers are two `Instant` reads. The only allocating probes are the
//! first touch of a named site in the per-site table and the explicitly
//! cold paths (snapshots, JSONL events, warn events). `ci.sh` gates the
//! compiled hot path at 0 allocations/step with telemetry **on** and
//! bounds the enabled-vs-disabled overhead at 2%.

pub mod export;
pub mod handler;

pub use export::{SiteSnapshot, TelemetrySnapshot};
pub use handler::{instrument, TelemetryMessenger};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

// ---------------------------------------------------------------- switch

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is telemetry recording? The entire disabled fast path is this one
/// relaxed load.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn metric recording on or off (off by default). Enabling never
/// changes training results — see the module-level determinism
/// contract.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

// --------------------------------------------------------------- metrics

/// Monotonic event counters, preregistered so recording is an array
/// index away and allocation-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Optimizer steps taken (any engine, any path).
    Steps,
    /// Steps executed by a compiled graph-mode program.
    CompiledSteps,
    /// Steps executed by the dynamic interpreter.
    DynamicSteps,
    /// Successful record-compile-verify passes.
    GraphCompiles,
    /// Recoverable graph-mode fallbacks (guard tripped, re-recording).
    GraphFallbacks,
    /// Permanent graph-mode disables (inherently dynamic model, ...).
    GraphDisables,
    /// Scheduled re-validations that confirmed the structure unchanged.
    GraphRevalidations,
    /// Steps whose reported loss was NaN or infinite.
    NonFiniteLoss,
    /// Steps with at least one NaN/Inf gradient element.
    NonFiniteGrad,
    /// Parameter-server pushes applied.
    PsPushApplied,
    /// Parameter-server pushes rejected as stale.
    PsPushRejected,
    /// Structured warn events emitted ([`warn`]).
    WarnEvents,
    /// Lint diagnostics emitted through [`warn_lint`] (the static
    /// analyzer's [`Report::emit`](crate::analysis::Report::emit) and
    /// runtime shape checks share this).
    LintDiagnostics,
    /// Serving requests answered (successfully) by a worker
    /// ([`crate::serve`]).
    RequestsServed,
    /// Serving requests rejected at admission with
    /// `ServeError::Overloaded` (bounded-queue backpressure).
    RequestsRejected,
    /// Batches the serve dispatcher handed to the worker pool (each
    /// coalesces 1..=max_batch same-version requests).
    BatchesDispatched,
}

impl Counter {
    pub(crate) const COUNT: usize = 16;
    pub(crate) const ALL: [Counter; Counter::COUNT] = [
        Counter::Steps,
        Counter::CompiledSteps,
        Counter::DynamicSteps,
        Counter::GraphCompiles,
        Counter::GraphFallbacks,
        Counter::GraphDisables,
        Counter::GraphRevalidations,
        Counter::NonFiniteLoss,
        Counter::NonFiniteGrad,
        Counter::PsPushApplied,
        Counter::PsPushRejected,
        Counter::WarnEvents,
        Counter::LintDiagnostics,
        Counter::RequestsServed,
        Counter::RequestsRejected,
        Counter::BatchesDispatched,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Steps => "steps",
            Counter::CompiledSteps => "compiled_steps",
            Counter::DynamicSteps => "dynamic_steps",
            Counter::GraphCompiles => "graph_compiles",
            Counter::GraphFallbacks => "graph_fallbacks",
            Counter::GraphDisables => "graph_disables",
            Counter::GraphRevalidations => "graph_revalidations",
            Counter::NonFiniteLoss => "nonfinite_loss",
            Counter::NonFiniteGrad => "nonfinite_grad",
            Counter::PsPushApplied => "ps_push_applied",
            Counter::PsPushRejected => "ps_push_rejected",
            Counter::WarnEvents => "warn_events",
            Counter::LintDiagnostics => "lint_diagnostics",
            Counter::RequestsServed => "requests_served",
            Counter::RequestsRejected => "requests_rejected",
            Counter::BatchesDispatched => "batches_dispatched",
        }
    }
}

/// Last-value gauges (f64 stored as bits; 0.0 until first set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// Most recent reported step loss.
    Loss,
    /// L2 norm of the most recent merged gradient (sorted-name order,
    /// so the value is deterministic for a deterministic run).
    GradNorm,
    /// Variance of per-particle loss values in the most recent
    /// multi-particle step (0 for single-particle steps).
    ParticleVar,
}

impl Gauge {
    pub(crate) const COUNT: usize = 3;
    pub(crate) const ALL: [Gauge; Gauge::COUNT] =
        [Gauge::Loss, Gauge::GradNorm, Gauge::ParticleVar];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::Loss => "loss",
            Gauge::GradNorm => "grad_norm",
            Gauge::ParticleVar => "particle_var",
        }
    }
}

/// Fixed-bucket log-scale histograms (power-of-two buckets; exact
/// count/sum/min/max alongside, so single-valued distributions report
/// exact percentiles).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// Wall nanoseconds per engine step (all engines, all paths).
    StepNs,
    /// Wall nanoseconds per particle/shard-worker evaluation.
    ParticleNs,
    /// Wall nanoseconds the data-parallel driver spends dispatching
    /// workers and merging their gradients (includes the wait for the
    /// slowest worker; subtract the max [`Hist::ParticleNs`] for pure
    /// wait time).
    MergeWaitNs,
    /// Parameter-server push staleness in versions (applied and
    /// rejected pushes both land here).
    PsStaleness,
    /// Wall nanoseconds a serve worker spends answering one request
    /// (evaluation + reply scatter; queue wait excluded —
    /// [`Hist::QueueWaitNs`] carries that).
    RequestNs,
    /// Requests coalesced into each dispatched serve batch
    /// (1..=max_batch; a right-leaning distribution means the batcher
    /// is earning its keep).
    BatchFill,
    /// Wall nanoseconds a request waits between admission and the
    /// moment a worker dequeues its batch.
    QueueWaitNs,
}

impl Hist {
    pub(crate) const COUNT: usize = 7;
    pub(crate) const ALL: [Hist; Hist::COUNT] = [
        Hist::StepNs,
        Hist::ParticleNs,
        Hist::MergeWaitNs,
        Hist::PsStaleness,
        Hist::RequestNs,
        Hist::BatchFill,
        Hist::QueueWaitNs,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Hist::StepNs => "step_ns",
            Hist::ParticleNs => "particle_ns",
            Hist::MergeWaitNs => "merge_wait_ns",
            Hist::PsStaleness => "ps_staleness",
            Hist::RequestNs => "request_ns",
            Hist::BatchFill => "batch_fill",
            Hist::QueueWaitNs => "queue_wait_ns",
        }
    }
}

/// Number of histogram buckets: bucket 0 holds zeros, bucket `b >= 1`
/// holds values in `[2^(b-1), 2^b - 1]` (the last bucket absorbs the
/// tail), so relative resolution is a factor of two.
pub const HIST_BUCKETS: usize = 64;

pub(crate) struct HistCell {
    counts: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

impl HistCell {
    const fn new() -> Self {
        HistCell {
            counts: [ATOMIC_ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub(crate) fn bucket(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    fn record(&self, v: u64) {
        self.counts[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    fn snapshot(&self) -> export::HistSnapshot {
        let mut counts = [0u64; HIST_BUCKETS];
        for (dst, src) in counts.iter_mut().zip(&self.counts) {
            *dst = src.load(Ordering::Relaxed);
        }
        export::HistSnapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

struct Metrics {
    counters: [AtomicU64; Counter::COUNT],
    gauges: [AtomicU64; Gauge::COUNT],
    hists: [HistCell; Hist::COUNT],
}

#[allow(clippy::declare_interior_mutable_const)]
const HIST_CELL: HistCell = HistCell::new();

static METRICS: Metrics = Metrics {
    counters: [ATOMIC_ZERO; Counter::COUNT],
    gauges: [ATOMIC_ZERO; Gauge::COUNT],
    hists: [HIST_CELL; Hist::COUNT],
};

/// Increment a counter by 1 (no-op unless [`enabled`]).
#[inline]
pub fn count(c: Counter) {
    if enabled() {
        count_always(c);
    }
}

pub(crate) fn count_always(c: Counter) {
    METRICS.counters[c as usize].fetch_add(1, Ordering::Relaxed);
}

/// Set a gauge (no-op unless [`enabled`]).
#[inline]
pub fn gauge(g: Gauge, v: f64) {
    if enabled() {
        METRICS.gauges[g as usize].store(v.to_bits(), Ordering::Relaxed);
    }
}

/// Record one histogram observation (no-op unless [`enabled`]).
#[inline]
pub fn record(h: Hist, v: u64) {
    if enabled() {
        METRICS.hists[h as usize].record(v);
    }
}

// ----------------------------------------------------------------- spans

/// A monotonic span timer: created by [`span`], records its elapsed
/// nanoseconds into the named histogram on drop. When telemetry is
/// disabled at creation the guard holds no clock reading and drop does
/// nothing — the whole probe is one relaxed load.
pub struct Span {
    start: Option<Instant>,
    hist: Hist,
}

/// Start timing a span against histogram `h`.
#[inline]
pub fn span(h: Hist) -> Span {
    Span { start: if enabled() { Some(Instant::now()) } else { None }, hist: h }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            METRICS.hists[self.hist as usize].record(t0.elapsed().as_nanos() as u64);
        }
    }
}

// ------------------------------------------------------- engine helpers

/// Record the outcome of one optimizer step: loss gauge, step counter,
/// NaN/Inf loss detection. Allocation-free; called by every engine on
/// both the dynamic and compiled paths.
#[inline]
pub fn record_loss(loss: f64) {
    if !enabled() {
        return;
    }
    METRICS.gauges[Gauge::Loss as usize].store(loss.to_bits(), Ordering::Relaxed);
    count_always(Counter::Steps);
    if !loss.is_finite() {
        count_always(Counter::NonFiniteLoss);
    }
}

/// Record the spread of per-particle loss values for a multi-particle
/// step (population variance; 0.0 for a single particle).
/// Allocation-free.
#[inline]
pub fn record_particle_spread(values: &[f64]) {
    if !enabled() || values.is_empty() {
        return;
    }
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    METRICS.gauges[Gauge::ParticleVar as usize].store(var.to_bits(), Ordering::Relaxed);
}

/// Record the L2 norm of a merged gradient map and count NaN/Inf
/// elements. Accumulates in **sorted parameter order** so the reported
/// norm is deterministic for a deterministic run. Allocates a name
/// vector — dynamic-path only (the compiled path never materializes a
/// gradient map).
pub fn record_grad_norm(grads: &HashMap<String, crate::tensor::Tensor>) {
    if !enabled() {
        return;
    }
    let mut names: Vec<&String> = grads.keys().collect();
    names.sort();
    let mut sq = 0.0f64;
    let mut nonfinite = false;
    for name in names {
        for &g in grads[name].data() {
            sq += g * g;
            nonfinite |= !g.is_finite();
        }
    }
    METRICS.gauges[Gauge::GradNorm as usize].store(sq.sqrt().to_bits(), Ordering::Relaxed);
    if nonfinite {
        count_always(Counter::NonFiniteGrad);
    }
}

// ------------------------------------------------------------ site table

/// Per-site accumulators fed by
/// [`TelemetryMessenger`](handler::TelemetryMessenger). Keyed by site
/// name; the entry allocates once on first touch and is updated in
/// place afterwards.
#[derive(Clone, Debug)]
pub(crate) struct SiteStats {
    pub hits: u64,
    pub total_ns: u64,
    pub numel: usize,
    pub dims: Vec<usize>,
    pub last_log_prob: f64,
    pub sum_log_prob: f64,
    pub min_log_prob: f64,
    pub max_log_prob: f64,
}

static SITES: Mutex<Option<Vec<(String, SiteStats)>>> = Mutex::new(None);

pub(crate) fn record_site(name: &str, ns: u64, numel: usize, dims: &[usize], log_prob: f64) {
    let mut guard = SITES.lock().unwrap();
    let table = guard.get_or_insert_with(Vec::new);
    match table.iter_mut().find(|(n, _)| n == name) {
        Some((_, s)) => {
            s.hits += 1;
            s.total_ns += ns;
            s.numel = numel;
            s.last_log_prob = log_prob;
            s.sum_log_prob += log_prob;
            s.min_log_prob = s.min_log_prob.min(log_prob);
            s.max_log_prob = s.max_log_prob.max(log_prob);
        }
        None => table.push((
            name.to_string(),
            SiteStats {
                hits: 1,
                total_ns: ns,
                numel,
                dims: dims.to_vec(),
                last_log_prob: log_prob,
                sum_log_prob: log_prob,
                min_log_prob: log_prob,
                max_log_prob: log_prob,
            },
        )),
    }
}

pub(crate) fn sites_snapshot() -> Vec<(String, SiteStats)> {
    SITES.lock().unwrap().as_ref().map(|t| t.to_vec()).unwrap_or_default()
}

// ---------------------------------------------------------- warn events

/// What a structured warning is about (stable machine-readable codes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WarnKind {
    /// Graph mode permanently disabled for an SVI engine.
    GraphDisabled,
    /// Graph mode fell back to the dynamic path and is re-recording.
    GraphFallback,
    /// Data-parallel graph mode permanently disabled.
    DataParallelGraphDisabled,
    /// Data-parallel graph mode fell back and is re-recording.
    DataParallelGraphFallback,
    /// Static-analysis lint diagnostic (see [`warn_lint`] for the
    /// richer entry point carrying the stable `FYxxx` code).
    Lint,
    /// A serve-layer compiled-program cache entry fell back to (or was
    /// permanently pinned on) the dynamic path for a frozen model.
    ServeGraphFallback,
    /// A serve admission queue filled and requests are being rejected
    /// with `Overloaded` (emitted once per server, counted per request
    /// via [`Counter::RequestsRejected`]).
    ServeOverloaded,
}

impl WarnKind {
    pub fn code(self) -> &'static str {
        match self {
            WarnKind::GraphDisabled => "graph_disabled",
            WarnKind::GraphFallback => "graph_fallback",
            WarnKind::DataParallelGraphDisabled => "dp_graph_disabled",
            WarnKind::DataParallelGraphFallback => "dp_graph_fallback",
            WarnKind::Lint => "lint",
            WarnKind::ServeGraphFallback => "serve_graph_fallback",
            WarnKind::ServeOverloaded => "serve_overloaded",
        }
    }

    fn label(self) -> &'static str {
        match self {
            WarnKind::GraphDisabled => "graph mode disabled",
            WarnKind::GraphFallback => "graph mode falling back to dynamic trace",
            WarnKind::DataParallelGraphDisabled => "data-parallel graph mode disabled",
            WarnKind::DataParallelGraphFallback => {
                "data-parallel graph fallback, re-recording"
            }
            WarnKind::Lint => "lint",
            WarnKind::ServeGraphFallback => "serve falling back to dynamic evaluation",
            WarnKind::ServeOverloaded => "serve queue full, rejecting requests",
        }
    }
}

static STDERR_ECHO: AtomicBool = AtomicBool::new(true);

/// Control whether [`warn`] echoes to stderr (on by default, so
/// replacing an `eprintln!` with a warn event never makes a failure
/// quieter).
pub fn set_stderr_echo(on: bool) {
    STDERR_ECHO.store(on, Ordering::SeqCst);
}

/// Emit a structured warning: echoes to stderr (unless suppressed via
/// [`set_stderr_echo`]), bumps [`Counter::WarnEvents`] when telemetry
/// is enabled, and appends a JSONL event when a sink is installed
/// ([`export::set_jsonl_path`]). A cold path — allocation here is fine.
pub fn warn(kind: WarnKind, msg: &str) {
    if STDERR_ECHO.load(Ordering::Relaxed) {
        eprintln!("[fyro] {}: {msg}", kind.label());
    }
    if enabled() {
        count_always(Counter::WarnEvents);
    }
    export::emit_event("warn", &[("kind", kind.code()), ("message", msg)]);
}

/// Emit one lint diagnostic as a structured warn event: echoes to
/// stderr (unless suppressed), bumps [`Counter::WarnEvents`] **and**
/// [`Counter::LintDiagnostics`] when telemetry is enabled, and appends
/// a JSONL `warn` event with `kind=lint` plus the stable `FYxxx` code
/// and the site/frame the diagnostic anchors to. Both the static
/// analyzer ([`Report::emit`](crate::analysis::Report::emit)) and
/// callers surfacing runtime shape errors route through here, so the
/// two paths produce identical telemetry. A cold path.
pub fn warn_lint(code: &str, site: &str, msg: &str) {
    if STDERR_ECHO.load(Ordering::Relaxed) {
        eprintln!("[fyro] lint [{code}] {site}: {msg}");
    }
    if enabled() {
        count_always(Counter::WarnEvents);
        count_always(Counter::LintDiagnostics);
    }
    export::emit_event(
        "warn",
        &[("kind", WarnKind::Lint.code()), ("code", code), ("site", site), ("message", msg)],
    );
}

// ------------------------------------------------------------- snapshot

/// Read every metric into an owned [`TelemetrySnapshot`] (cold path).
pub fn snapshot() -> TelemetrySnapshot {
    TelemetrySnapshot {
        counters: Counter::ALL
            .iter()
            .map(|&c| (c.name(), METRICS.counters[c as usize].load(Ordering::Relaxed)))
            .collect(),
        gauges: Gauge::ALL
            .iter()
            .map(|&g| {
                (g.name(), f64::from_bits(METRICS.gauges[g as usize].load(Ordering::Relaxed)))
            })
            .collect(),
        hists: Hist::ALL
            .iter()
            .map(|&h| (h.name(), METRICS.hists[h as usize].snapshot()))
            .collect(),
        sites: sites_snapshot()
            .into_iter()
            .map(|(name, s)| SiteSnapshot {
                name,
                hits: s.hits,
                total_ns: s.total_ns,
                numel: s.numel,
                dims: s.dims,
                last_log_prob: s.last_log_prob,
                sum_log_prob: s.sum_log_prob,
                min_log_prob: s.min_log_prob,
                max_log_prob: s.max_log_prob,
            })
            .collect(),
    }
}

/// Zero every counter, gauge, histogram and the per-site table (the
/// enabled flag and exporters are untouched). For tests and bench
/// sections that need a clean slate.
pub fn reset() {
    for c in &METRICS.counters {
        c.store(0, Ordering::Relaxed);
    }
    for g in &METRICS.gauges {
        g.store(0, Ordering::Relaxed);
    }
    for h in &METRICS.hists {
        h.reset();
    }
    *SITES.lock().unwrap() = None;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unit tests share the process-global recorder with every other
    /// lib test; serialize the ones that read counters end-to-end.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_probes_are_inert() {
        let _g = LOCK.lock().unwrap();
        reset();
        set_enabled(false);
        count(Counter::Steps);
        gauge(Gauge::Loss, 1.0);
        record(Hist::StepNs, 100);
        drop(span(Hist::StepNs));
        let s = snapshot();
        assert_eq!(s.counter("steps"), 0);
        assert_eq!(s.gauge("loss"), Some(0.0));
        assert_eq!(s.hist("step_ns").unwrap().count, 0);
    }

    #[test]
    fn enabled_probes_record() {
        let _g = LOCK.lock().unwrap();
        reset();
        set_enabled(true);
        count(Counter::Steps);
        count(Counter::Steps);
        gauge(Gauge::Loss, -3.25);
        record(Hist::StepNs, 1000);
        record_loss(f64::NAN);
        set_enabled(false);
        let s = snapshot();
        assert_eq!(s.counter("steps"), 3, "two counts + one record_loss");
        assert_eq!(s.counter("nonfinite_loss"), 1);
        // record_loss overwrote the gauge with NaN
        assert!(s.gauge("loss").unwrap().is_nan());
        assert_eq!(s.hist("step_ns").unwrap().count, 1);
        reset();
    }

    #[test]
    fn bucket_bounds() {
        assert_eq!(HistCell::bucket(0), 0);
        assert_eq!(HistCell::bucket(1), 1);
        assert_eq!(HistCell::bucket(2), 2);
        assert_eq!(HistCell::bucket(3), 2);
        assert_eq!(HistCell::bucket(4), 3);
        assert_eq!(HistCell::bucket(1023), 10);
        assert_eq!(HistCell::bucket(1024), 11);
        assert_eq!(HistCell::bucket(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn grad_norm_is_sorted_order_deterministic() {
        let _g = LOCK.lock().unwrap();
        reset();
        set_enabled(true);
        let mut grads = HashMap::new();
        grads.insert("b".to_string(), crate::tensor::Tensor::from_vec(vec![3.0]));
        grads.insert("a".to_string(), crate::tensor::Tensor::from_vec(vec![4.0]));
        record_grad_norm(&grads);
        set_enabled(false);
        let s = snapshot();
        assert_eq!(s.gauge("grad_norm"), Some(5.0));
        assert_eq!(s.counter("nonfinite_grad"), 0);
        reset();
    }

    #[test]
    fn nonfinite_grad_detected() {
        let _g = LOCK.lock().unwrap();
        reset();
        set_enabled(true);
        let mut grads = HashMap::new();
        grads.insert("w".to_string(), crate::tensor::Tensor::from_vec(vec![1.0, f64::NAN]));
        record_grad_norm(&grads);
        set_enabled(false);
        assert_eq!(snapshot().counter("nonfinite_grad"), 1);
        reset();
    }

    #[test]
    fn particle_spread_variance() {
        let _g = LOCK.lock().unwrap();
        reset();
        set_enabled(true);
        record_particle_spread(&[1.0, 3.0]);
        set_enabled(false);
        assert_eq!(snapshot().gauge("particle_var"), Some(1.0));
        reset();
    }

    #[test]
    fn site_table_accumulates() {
        let _g = LOCK.lock().unwrap();
        reset();
        record_site("z", 100, 4, &[4], -1.5);
        record_site("z", 300, 4, &[4], -0.5);
        let sites = sites_snapshot();
        let (name, s) = &sites[0];
        assert_eq!(name, "z");
        assert_eq!(s.hits, 2);
        assert_eq!(s.total_ns, 400);
        assert_eq!(s.dims, vec![4]);
        assert_eq!(s.sum_log_prob, -2.0);
        assert_eq!(s.min_log_prob, -1.5);
        assert_eq!(s.max_log_prob, -0.5);
        reset();
        assert!(sites_snapshot().is_empty());
    }
}
