//! Observability as an effect handler: `TelemetryMessenger`.
//!
//! The paper's design point is that inference machinery should be
//! composable effect handlers — so the profiler is one too. Wrap any
//! model with [`instrument`] (it composes exactly like
//! `poutine::handlers::block` or a plate) and every sample site feeds
//! the per-site table read back via
//! [`snapshot`](super::snapshot): hit counts, handler-measured
//! latency, value shape, and an unscaled log-prob summary.
//!
//! Determinism: the handler never mutates the message, never draws
//! from the RNG, and only reads the site's value after the normal
//! effect stack produced it. The log-prob summary re-scores the value
//! through the site's distribution, which appends passive nodes to the
//! current tape — those nodes are never upstream of any loss, so
//! gradients, parameter updates and the RNG stream are bit-for-bit
//! unchanged (pinned by `tests/test_telemetry.rs`). Under graph-mode
//! *recording* the passive re-score would be captured into the
//! compiled program; prefer instrumenting dynamic runs (or record
//! first, instrument after) when the extra compiled work matters.
//!
//! Cost: disabled, each site costs one relaxed atomic load on the way
//! in and nothing on the way out. Enabled, a site costs two clock
//! reads, one log-prob evaluation and a locked table update.

use std::time::Instant;

use crate::poutine::{Ctx, Message, Messenger};

/// A Poutine handler that records per-site timings, sample shapes and
/// log-prob summaries into the global telemetry recorder. Push it
/// directly with `ctx.push_handler` or wrap a model with
/// [`instrument`].
#[derive(Default)]
pub struct TelemetryMessenger {
    t0: Option<Instant>,
}

impl TelemetryMessenger {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Messenger for TelemetryMessenger {
    fn process(&mut self, _msg: &mut Message) {
        self.t0 = if super::enabled() { Some(Instant::now()) } else { None };
    }

    fn postprocess(&mut self, msg: &mut Message) {
        let Some(t0) = self.t0.take() else { return };
        let Some(value) = msg.value.as_ref() else { return };
        let lp = msg.dist.log_prob(value);
        let lp_sum: f64 = lp.value().data().iter().sum();
        let ns = t0.elapsed().as_nanos() as u64;
        super::record_site(&msg.name, ns, value.value().numel(), value.dims(), lp_sum);
    }
}

/// Wrap a model so every sample site inside it is profiled — composes
/// like `block`/`scale`:
///
/// ```ignore
/// let model = telemetry::instrument(|ctx: &mut Ctx| { ... });
/// ```
///
/// Handlers see sites innermost-first on the way in and
/// outermost-first on the way out, so the span measured per site
/// covers the default sampling effect plus any handlers *outside* the
/// `instrument` wrapper; handlers pushed inside the model (plates,
/// blocks) run outside the measured window.
pub fn instrument<'m, R>(model: impl Fn(&mut Ctx) -> R + 'm) -> impl Fn(&mut Ctx) -> R + 'm {
    move |ctx| {
        ctx.push_handler(Box::new(TelemetryMessenger::new()));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}
