//! Poutine: composable effect handlers for probabilistic programs.
//!
//! This is the paper's central architectural contribution (§2, §3): every
//! piece of inference machinery — tracing, replay, conditioning,
//! blocking, scaling, interventions — is an *effect handler* that
//! intercepts the `sample`/`param` effects emitted by a model as it runs.
//! Inference algorithms are then compositions of handlers, never
//! modifications of models.
//!
//! Execution model: a model is any `Fn(&mut Ctx) -> R`. `Ctx` owns the
//! autodiff tape, the RNG, the handler stack and the trace being
//! recorded. A `ctx.sample(name, dist)` call builds a [`Message`], runs
//! it through the stack **innermost-handler-first** (exactly Pyro's
//! `apply_stack`), applies the default behavior (draw a value if none was
//! injected), then runs `postprocess` outermost-first.

pub mod handlers;

pub use handlers::{block, condition, do_intervention, mask, replay, scale, seed, uncondition};

use crate::autodiff::{Tape, Var};
use crate::dist::{Constraint, Dist, Field, IntoVarDist};
use crate::params::ParamStore;
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;
use std::rc::Rc;

/// One conditional-independence frame: a vectorized `plate` a site sits
/// inside. Recorded on [`Message`]/[`Site`] `cond_indep_stack`s so
/// inference code can reason about plate structure (subsample scaling,
/// dim layout) instead of seeing only an opaque scalar scale.
#[derive(Clone, Debug, PartialEq)]
pub struct PlateFrame {
    pub name: String,
    /// Full population size declared by the plate.
    pub size: usize,
    /// Number of elements actually present this execution.
    pub subsample: usize,
    /// Batch dim this plate occupies, counted from the right (0 is the
    /// rightmost batch dim; nested plates allocate right-to-left in
    /// entry order, like Pyro's dim allocator).
    pub dim: usize,
}

impl PlateFrame {
    /// Log-prob multiplier correcting for subsampling.
    pub fn scale(&self) -> f64 {
        self.size as f64 / self.subsample as f64
    }
}

/// Handle passed to a vectorized plate body: the subsampled indices plus
/// the frame metadata, with helpers for slicing mini-batches. A full
/// (non-subsampled) plate stores no index vector at all — the identity
/// subsample is implicit, keeping the hot path allocation-free.
pub struct Plate {
    frame: PlateFrame,
    /// `Some(indices)` only when genuinely subsampled.
    subsampled: Option<Vec<usize>>,
    /// When the execution's tape is recording for graph-mode
    /// compilation: the tape plus this plate's permutation ordinal, so
    /// [`Plate::select`] can log minibatch provenance.
    rec: Option<(Tape, usize)>,
}

impl Plate {
    pub fn frame(&self) -> &PlateFrame {
        &self.frame
    }

    /// Subsampled indices into the full population; `None` when the
    /// whole population is present (indices are then just `0..size`).
    pub fn indices(&self) -> Option<&[usize]> {
        self.subsampled.as_deref()
    }

    /// Number of elements present this execution (the subsample size).
    pub fn len(&self) -> usize {
        self.frame.subsample
    }

    pub fn is_empty(&self) -> bool {
        self.frame.subsample == 0
    }

    /// Whether the whole population is present (no subsampling).
    pub fn is_full(&self) -> bool {
        self.subsampled.is_none()
    }

    /// Rows of `data` (axis 0) owned by this execution's subsample.
    /// `data` must be laid out with THIS plate's population on axis 0 —
    /// the common case of one plate over data rows. Inside nested
    /// plates, axis 0 belongs to the *innermost* plate's layout; slice
    /// other axes explicitly via [`Plate::indices`].
    pub fn select(&self, data: &Tensor) -> Tensor {
        match &self.subsampled {
            None => data.clone(),
            Some(idx) => {
                let out = data.index_select0(idx);
                if let Some((tape, ord)) = &self.rec {
                    tape.note_select(out.storage_ptr(), data.clone(), *ord);
                }
                out
            }
        }
    }

    /// Log-prob multiplier correcting for subsampling.
    pub fn scale(&self) -> f64 {
        self.frame.scale()
    }
}

/// The effect payload seen by handlers at every sample site.
pub struct Message {
    /// The tape of the current execution (for lifting injected values).
    pub tape: Tape,
    pub name: String,
    pub dist: Rc<dyn Dist<Var>>,
    /// Injected or drawn value.
    pub value: Option<Var>,
    /// True when the value is data (observed or conditioned).
    pub is_observed: bool,
    /// Log-prob multiplier (plates, annealing).
    pub scale: f64,
    /// Optional mask on the batch-shaped log-prob.
    pub mask: Option<Tensor>,
    /// Excluded from the joint density (a `do` intervention).
    pub intervened: bool,
    /// Hidden from the recorded trace (`block`).
    pub hidden: bool,
    /// A handler already finalized the value; skip default sampling.
    pub done: bool,
    /// Plate frames enclosing this site, innermost first (handlers run
    /// innermost-first on the way in).
    pub cond_indep_stack: Vec<PlateFrame>,
    /// Diagnostic raised by a handler (shape checks, plate-dim
    /// collisions). Checked after `postprocess`: strict contexts fail
    /// the sample call with this error; lenient contexts (the static
    /// analyzer, [`crate::analysis`]) collect it and keep recording so
    /// one pass can report every problem. Handlers should set it only
    /// when it is still `None` — the first diagnostic wins.
    pub error: Option<crate::error::Error>,
}

/// An effect handler. Handlers see sample messages on the way in
/// (`process`, innermost first) and on the way out (`postprocess`,
/// outermost first), mirroring Pyro's Messenger API.
pub trait Messenger {
    fn process(&mut self, _msg: &mut Message) {}
    fn postprocess(&mut self, _msg: &mut Message) {}
}

/// One recorded sample site.
#[derive(Clone)]
pub struct Site {
    pub name: String,
    pub dist: Rc<dyn Dist<Var>>,
    pub value: Var,
    pub is_observed: bool,
    pub scale: f64,
    pub mask: Option<Tensor>,
    pub intervened: bool,
    /// Plate frames enclosing this site, innermost first.
    pub cond_indep_stack: Vec<PlateFrame>,
}

impl Site {
    /// Plate frames enclosing this site, innermost first (alias for
    /// `cond_indep_stack`, the name inference code reads).
    pub fn frames(&self) -> &[PlateFrame] {
        &self.cond_indep_stack
    }

    /// True when the value was produced by a reparameterized sampler,
    /// so pathwise gradients flow through it.
    pub fn is_reparam(&self) -> bool {
        self.dist.has_rsample()
    }

    /// Latent, non-reparameterized and non-intervened: ELBO gradients
    /// for this site need a score-function (REINFORCE) surrogate term.
    pub fn needs_score_term(&self) -> bool {
        !self.is_observed && !self.intervened && !self.dist.has_rsample()
    }

    /// Batch-shaped log-prob of this site: the distribution reduces its
    /// event dims, then the mask (if any) broadcasts against the batch
    /// dims. Plate/handler scaling is NOT applied here.
    pub fn log_prob_batch(&self) -> Var {
        let mut lp = self.dist.log_prob(&self.value);
        if let Some(m) = &self.mask {
            lp = lp.mul(&lp.lift(m.clone()));
        }
        lp
    }

    /// Differentiable total log-prob contribution of this site (mask and
    /// scale applied). Intervened sites contribute a tape **constant**
    /// zero — no live graph hangs off the intervention value, so `do`
    /// sites cost nothing in the backward pass.
    pub fn log_prob(&self) -> Var {
        if self.intervened {
            return self.value.tape().constant(Tensor::scalar(0.0));
        }
        let lp = self.log_prob_batch().sum();
        if self.scale == 1.0 {
            lp
        } else {
            lp.mul_scalar(self.scale)
        }
    }
}

/// An execution trace: ordered sample sites plus the parameter leaves
/// touched during the run.
#[derive(Clone, Default)]
pub struct Trace {
    sites: Vec<Site>,
    by_name: HashMap<String, usize>,
    /// name -> unconstrained leaf Var for every `ctx.param` touched.
    pub param_leaves: HashMap<String, Var>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.sites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn sites(&self) -> &[Site] {
        &self.sites
    }

    pub fn get(&self, name: &str) -> Option<&Site> {
        self.by_name.get(name).map(|&i| &self.sites[i])
    }

    /// Stable execution-order index of a site. Estimators use this for
    /// downstream ordering: a site can only depend on sites recorded
    /// before it, so everything at or after index `i` is (conservatively)
    /// downstream of site `i`.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn names(&self) -> Vec<&str> {
        self.sites.iter().map(|s| s.name.as_str()).collect()
    }

    fn record(&mut self, site: Site) -> crate::error::Result<()> {
        if self.by_name.contains_key(&site.name) {
            return Err(crate::error::Error::msg(format!(
                "[FY014] duplicate sample site '{}'",
                site.name
            )));
        }
        self.by_name.insert(site.name.clone(), self.sites.len());
        self.sites.push(site);
        Ok(())
    }

    /// Differentiable total log-joint of the trace.
    pub fn log_prob_sum_var(&self) -> Option<Var> {
        let mut acc: Option<Var> = None;
        for s in &self.sites {
            let lp = s.log_prob();
            acc = Some(match acc {
                None => lp,
                Some(a) => a.add(&lp),
            });
        }
        acc
    }

    /// Concrete total log-joint.
    pub fn log_prob_sum(&self) -> f64 {
        self.log_prob_sum_var().map(|v| v.item()).unwrap_or(0.0)
    }

    /// Observed sites' log-likelihood only.
    pub fn log_likelihood(&self) -> f64 {
        self.sites
            .iter()
            .filter(|s| s.is_observed)
            .map(|s| s.log_prob().item())
            .sum()
    }
}

/// How a context sees the parameter store. `Mut` is the training mode:
/// `ctx.param` lazily initializes missing entries. `Frozen` is the
/// serving mode ([`crate::serve`]): the store is shared read-only
/// across threads and a missing parameter is a registration-time bug,
/// not an init opportunity — the type makes mutation impossible.
enum StoreRef<'a> {
    None,
    Mut(&'a mut ParamStore),
    Frozen(&'a ParamStore),
}

/// Execution context threaded through a model: tape + RNG + handler
/// stack + live trace (+ optional parameter store).
pub struct Ctx<'a> {
    pub tape: Tape,
    pub rng: &'a mut Pcg64,
    store: StoreRef<'a>,
    stack: Vec<Box<dyn Messenger>>,
    trace: Trace,
    plate_depth: usize,
    /// `Some` puts the context in lenient (lint) mode: handler-raised
    /// diagnostics collect here instead of failing the sample call.
    lint_errors: Option<Vec<crate::error::Error>>,
}

impl<'a> Ctx<'a> {
    pub fn new(rng: &'a mut Pcg64) -> Self {
        Ctx {
            tape: Tape::new(),
            rng,
            store: StoreRef::None,
            stack: Vec::new(),
            trace: Trace::default(),
            plate_depth: 0,
            lint_errors: None,
        }
    }

    pub fn with_store(rng: &'a mut Pcg64, store: &'a mut ParamStore) -> Self {
        let mut ctx = Ctx::new(rng);
        ctx.store = StoreRef::Mut(store);
        ctx
    }

    /// Continue recording on an existing tape (SVI shares one tape
    /// between the guide run and the replayed model run).
    pub fn with_store_on_tape(
        tape: Tape,
        rng: &'a mut Pcg64,
        store: &'a mut ParamStore,
    ) -> Self {
        let mut ctx = Ctx::new(rng);
        ctx.tape = tape;
        ctx.store = StoreRef::Mut(store);
        ctx
    }

    /// Read-only store mode: `ctx.param` looks entries up but never
    /// initializes them — a missing parameter panics with a stable
    /// `[FY016]` code. This is what lets [`crate::serve`] share one
    /// `ParamStore` across worker threads behind a plain `&` borrow
    /// (serving never mutates params, enforced by type) and what
    /// [`crate::infer::Predictive`] runs on after the satellite change
    /// to `&ParamStore`.
    pub fn with_frozen_store(rng: &'a mut Pcg64, store: &'a ParamStore) -> Self {
        let mut ctx = Ctx::new(rng);
        ctx.store = StoreRef::Frozen(store);
        ctx
    }

    /// [`Ctx::with_frozen_store`] continuing on an existing tape (the
    /// guide-then-replayed-model pattern, read-only edition).
    pub fn with_frozen_store_on_tape(
        tape: Tape,
        rng: &'a mut Pcg64,
        store: &'a ParamStore,
    ) -> Self {
        let mut ctx = Ctx::new(rng);
        ctx.tape = tape;
        ctx.store = StoreRef::Frozen(store);
        ctx
    }

    pub fn push_handler(&mut self, h: Box<dyn Messenger>) {
        self.stack.push(h);
    }

    pub fn pop_handler(&mut self) -> Option<Box<dyn Messenger>> {
        self.stack.pop()
    }

    /// Switch the context into lenient (lint) mode: handler-raised
    /// diagnostics (forgotten `plate.select`, plate-dim collisions) are
    /// collected instead of failing the run, so the static analyzer can
    /// record a complete skeleton from a broken model and report every
    /// problem at once. Retrieve them with [`Ctx::take_lint_errors`].
    pub fn lenient(&mut self) {
        if self.lint_errors.is_none() {
            self.lint_errors = Some(Vec::new());
        }
    }

    /// Diagnostics collected so far in lenient mode (empty when the
    /// context is strict or nothing went wrong).
    pub fn take_lint_errors(&mut self) -> Vec<crate::error::Error> {
        self.lint_errors.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Lift a plain tensor to a constant on this context's tape.
    pub fn c(&self, t: Tensor) -> Var {
        self.tape.constant(t)
    }

    /// Lift a scalar.
    pub fn cs(&self, v: f64) -> Var {
        self.tape.constant(Tensor::scalar(v))
    }

    /// The `pyro.sample` primitive. Panics on a duplicate site name; use
    /// [`Ctx::try_sample`] to surface that as an [`crate::error::Error`]
    /// instead.
    pub fn sample(&mut self, name: &str, dist: impl IntoVarDist) -> Var {
        self.try_sample(name, dist).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible `sample`: duplicate site names come back as `Err`.
    pub fn try_sample(
        &mut self,
        name: &str,
        dist: impl IntoVarDist,
    ) -> crate::error::Result<Var> {
        let dist = dist.into_var_dist(&self.tape);
        self.apply(Message {
            tape: self.tape.clone(),
            name: name.to_string(),
            dist,
            value: None,
            is_observed: false,
            scale: 1.0,
            mask: None,
            intervened: false,
            hidden: false,
            done: false,
            cond_indep_stack: Vec::new(),
            error: None,
        })
    }

    /// `pyro.sample(name, dist, obs=value)`. Panics on a duplicate site
    /// name; use [`Ctx::try_observe`] for the fallible form.
    pub fn observe(&mut self, name: &str, dist: impl IntoVarDist, value: Tensor) -> Var {
        self.try_observe(name, dist, value)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible `observe`: duplicate site names come back as `Err`.
    pub fn try_observe(
        &mut self,
        name: &str,
        dist: impl IntoVarDist,
        value: Tensor,
    ) -> crate::error::Result<Var> {
        let dist = dist.into_var_dist(&self.tape);
        let v = self.tape.constant(value);
        self.apply(Message {
            tape: self.tape.clone(),
            name: name.to_string(),
            dist,
            value: Some(v),
            is_observed: true,
            scale: 1.0,
            mask: None,
            intervened: false,
            hidden: false,
            done: true,
            cond_indep_stack: Vec::new(),
            error: None,
        })
    }

    /// Record a deterministic site (`pyro.deterministic`).
    pub fn deterministic(&mut self, name: &str, value: Var) -> Var {
        use crate::dist::Delta;
        let dist: Rc<dyn Dist<Var>> = Rc::new(Delta::new(value.clone()));
        self.apply(Message {
            tape: self.tape.clone(),
            name: name.to_string(),
            dist,
            value: Some(value),
            is_observed: false,
            scale: 1.0,
            mask: None,
            intervened: false,
            hidden: false,
            done: true,
            cond_indep_stack: Vec::new(),
            error: None,
        })
        .unwrap_or_else(|e| panic!("{e}"))
    }

    fn apply(&mut self, mut msg: Message) -> crate::error::Result<Var> {
        // process: innermost handler first (reversed stack), like Pyro
        for h in self.stack.iter_mut().rev() {
            h.process(&mut msg);
        }
        // default behavior: draw if nothing injected
        if msg.value.is_none() {
            msg.value = Some(msg.dist.sample(self.rng));
        }
        // postprocess: outermost first
        for h in self.stack.iter_mut() {
            h.postprocess(&mut msg);
        }
        // a handler flagged this site: strict contexts fail the call,
        // lenient ones (the static analyzer) collect and keep recording
        if let Some(err) = msg.error.take() {
            match self.lint_errors.as_mut() {
                Some(sink) => sink.push(err),
                None => return Err(err),
            }
        }
        let value = msg.value.clone().unwrap();
        if !msg.hidden {
            self.trace.record(Site {
                name: msg.name,
                dist: msg.dist,
                value: value.clone(),
                is_observed: msg.is_observed,
                scale: msg.scale,
                mask: msg.mask,
                intervened: msg.intervened,
                cond_indep_stack: msg.cond_indep_stack,
            })?;
        }
        Ok(value)
    }

    /// The `pyro.param` primitive: fetch-or-create a learnable parameter
    /// (constrained view) and register its unconstrained leaf in the
    /// trace so optimizers can reach it.
    pub fn param(&mut self, name: &str, init: impl FnOnce() -> Tensor) -> Var {
        self.param_constrained(name, init, Constraint::Real)
    }

    pub fn param_constrained(
        &mut self,
        name: &str,
        init: impl FnOnce() -> Tensor,
        constraint: Constraint,
    ) -> Var {
        if let Some(existing) = self.trace.param_leaves.get(name) {
            // same param touched twice in one run: reuse the leaf so
            // gradients accumulate on a single node
            let registered = match &self.store {
                StoreRef::Mut(s) => s.constraint(name),
                StoreRef::Frozen(s) => s.constraint(name),
                StoreRef::None => panic!(
                    "[FY013] ctx.param('{name}') requires a ParamStore (use Ctx::with_store)"
                ),
            };
            return registered.transform(existing);
        }
        // single store access: the entry's value and registered
        // constraint come back together
        let (unconstrained, actual_constraint) = match &mut self.store {
            StoreRef::Mut(s) => s.get_or_init_entry(name, init, constraint),
            StoreRef::Frozen(s) => match s.peek_entry(name) {
                Some((t, c)) => (t.clone(), c),
                None => panic!(
                    "[FY016] ctx.param('{name}') is missing from a frozen (read-only) \
                     ParamStore — serving stores never initialize; train and snapshot \
                     this param before freezing"
                ),
            },
            StoreRef::None => panic!(
                "[FY013] ctx.param('{name}') requires a ParamStore (use Ctx::with_store)"
            ),
        };
        let leaf = self.tape.leaf(unconstrained);
        self.trace.param_leaves.insert(name.to_string(), leaf.clone());
        actual_constraint.transform(&leaf)
    }

    /// `pyro.plate`: **vectorized** conditional-independence context
    /// with optional subsampling. The body records ONE broadcast site
    /// per plate (its batch shape carries the plate dim), not one site
    /// per data point: every enclosed site gets this plate's
    /// [`PlateFrame`] pushed onto its `cond_indep_stack` and its
    /// log-prob scaled by `size / subsample`. The body receives a
    /// [`Plate`] handle with the subsampled indices and a
    /// [`Plate::select`] helper for slicing mini-batches.
    ///
    /// Nested plates allocate batch dims right-to-left in entry order
    /// (the outermost plate owns the rightmost dim), like Pyro's dim
    /// allocator. For data-dependent bodies that genuinely need one
    /// site per index, use [`Ctx::plate_seq`].
    pub fn plate<R>(
        &mut self,
        name: &str,
        size: usize,
        subsample: Option<usize>,
        body: impl FnOnce(&mut Ctx, &Plate) -> R,
    ) -> R {
        assert!(size > 0, "plate '{name}' must have size > 0");
        let m = subsample.unwrap_or(size).min(size).max(1);
        let (subsampled, rec) = if m == size {
            (None, None)
        } else {
            let ord = self.tape.note_permutation(size, m, true);
            let idx = self.rng.permutation(size)[..m].to_vec();
            (Some(idx), ord.map(|o| (self.tape.clone(), o)))
        };
        let frame = PlateFrame {
            name: name.to_string(),
            size,
            subsample: m,
            dim: self.plate_depth,
        };
        let plate = Plate { frame: frame.clone(), subsampled, rec };
        self.push_handler(Box::new(handlers::PlateMessenger::new(frame)));
        self.plate_depth += 1;
        let out = body(self, &plate);
        self.plate_depth -= 1;
        self.pop_handler();
        out
    }

    /// Vectorized plate with **caller-provided** subsample indices —
    /// Pyro's `plate(..., subsample=idx)`, and the data-parallel
    /// minibatch primitive: the data loader owns which rows this step
    /// covers (a worker's shard, a streamed batch), the plate only
    /// applies the `size / idx.len()` scale correction. Unlike
    /// [`Ctx::plate`] no permutation is drawn from the model RNG and
    /// nothing lands on the tape, so the RNG stream is independent of
    /// the population size and the trace stays static for graph
    /// compilation ([`crate::infer::compile`]).
    pub fn plate_idx<R>(
        &mut self,
        name: &str,
        size: usize,
        idx: &[usize],
        body: impl FnOnce(&mut Ctx, &Plate) -> R,
    ) -> R {
        assert!(size > 0, "plate '{name}' must have size > 0");
        let m = idx.len();
        assert!(
            m > 0 && m <= size,
            "plate '{name}': {m} subsample indices against population {size}"
        );
        debug_assert!(
            idx.iter().all(|&i| i < size),
            "plate '{name}': subsample index out of range"
        );
        let frame =
            PlateFrame { name: name.to_string(), size, subsample: m, dim: self.plate_depth };
        let subsampled = if m == size { None } else { Some(idx.to_vec()) };
        let plate = Plate { frame: frame.clone(), subsampled, rec: None };
        self.push_handler(Box::new(handlers::PlateMessenger::new(frame)));
        self.plate_depth += 1;
        let out = body(self, &plate);
        self.plate_depth -= 1;
        self.pop_handler();
        out
    }

    /// Sequential plate: the pre-vectorization behavior, retained for
    /// data-dependent bodies (one string-named site per index, O(N)
    /// sites). Scales every log-prob inside by size/subsample and hands
    /// the body the chosen indices.
    pub fn plate_seq<R>(
        &mut self,
        name: &str,
        size: usize,
        subsample: Option<usize>,
        body: impl FnOnce(&mut Ctx, &[usize]) -> R,
    ) -> R {
        let m = subsample.unwrap_or(size).min(size);
        let idx: Vec<usize> = if m == size {
            (0..size).collect()
        } else {
            // vectorized: false -> graph mode rejects (site names vary
            // with the drawn indices, so the trace is not static)
            self.tape.note_permutation(size, m, false);
            self.rng.permutation(size)[..m].to_vec()
        };
        let factor = size as f64 / m as f64;
        self.push_handler(Box::new(handlers::ScaleMessenger::new(factor)));
        let _ = name;
        let out = body(self, &idx);
        self.pop_handler();
        out
    }

    /// Finish the run and take the trace.
    pub fn into_trace(self) -> Trace {
        self.trace
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }
}

/// Run a model under a fresh context and return its trace.
pub fn trace_fn<R>(model: &dyn Fn(&mut Ctx) -> R, rng: &mut Pcg64) -> Trace {
    let mut ctx = Ctx::new(rng);
    model(&mut ctx);
    ctx.into_trace()
}

/// Run a model with a param store; returns (trace, model return).
pub fn trace_with_store<R>(
    model: &dyn Fn(&mut Ctx) -> R,
    rng: &mut Pcg64,
    store: &mut ParamStore,
) -> (Trace, R) {
    let mut ctx = Ctx::with_store(rng, store);
    let out = model(&mut ctx);
    (ctx.into_trace(), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Bernoulli, Normal};

    #[test]
    fn trace_records_sites_in_order() {
        let mut rng = Pcg64::new(1);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(0.5)), Tensor::scalar(1.0));
        };
        let t = trace_fn(&model, &mut rng);
        assert_eq!(t.names(), vec!["z", "x"]);
        assert!(!t.get("z").unwrap().is_observed);
        assert!(t.get("x").unwrap().is_observed);
        assert!(t.log_prob_sum().is_finite());
    }

    #[test]
    fn log_prob_sum_matches_manual() {
        let mut rng = Pcg64::new(2);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(0.5)), Tensor::scalar(1.0));
        };
        let t = trace_fn(&model, &mut rng);
        let z = t.get("z").unwrap().value.value().item();
        let n01 = Normal::std(0.0, 1.0);
        let nz = Normal::std(z, 0.5);
        let want = n01.log_prob(&Tensor::scalar(z)).item()
            + nz.log_prob(&Tensor::scalar(1.0)).item();
        assert!((t.log_prob_sum() - want).abs() < 1e-12);
    }

    #[test]
    fn data_dependent_control_flow_traces() {
        // geometric-style recursion: number of latents depends on draws —
        // the "universal PPL" property (paper Fig 2 expressivity row).
        fn flips(ctx: &mut Ctx, i: usize) -> usize {
            let v = ctx.sample(&format!("flip_{i}"), Bernoulli::std(0.4));
            if v.value().item() == 1.0 {
                i
            } else {
                flips(ctx, i + 1)
            }
        }
        let mut rng = Pcg64::new(3);
        let model = |ctx: &mut Ctx| flips(ctx, 0);
        let t = trace_fn(&model, &mut rng);
        assert!(!t.is_empty());
        // all sites are flips, last one is the success
        let last = t.sites().last().unwrap();
        assert_eq!(last.value.value().item(), 1.0);
        for s in &t.sites()[..t.len() - 1] {
            assert_eq!(s.value.value().item(), 0.0);
        }
    }

    #[test]
    fn trace_lookup_is_indexed_and_execution_ordered() {
        // `get`/`index_of` go through the `by_name` map — O(1), no site
        // scan — and `index_of` must report stable execution order even
        // with many sites (estimator downstream-ordering relies on it).
        let mut rng = Pcg64::new(41);
        let model = |ctx: &mut Ctx| {
            for i in 0..64 {
                ctx.sample(&format!("s{i}"), Normal::std(0.0, 1.0));
            }
        };
        let t = trace_fn(&model, &mut rng);
        for i in 0..64 {
            let name = format!("s{i}");
            assert_eq!(t.index_of(&name), Some(i));
            assert_eq!(t.get(&name).unwrap().name, name);
        }
        assert_eq!(t.get("nope").map(|s| s.name.as_str()), None);
        assert_eq!(t.index_of("nope"), None);
    }

    #[test]
    #[should_panic(expected = "duplicate sample site")]
    fn duplicate_site_panics() {
        let mut rng = Pcg64::new(4);
        let model = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        trace_fn(&model, &mut rng);
    }

    #[test]
    fn vectorized_plate_records_one_scaled_site() {
        let mut rng = Pcg64::new(5);
        // full data of 4, subsample 2 => ONE site of batch 2, scale 2
        let data = Tensor::from_vec(vec![0.0, 0.0, 0.0, 0.0]);
        let model = move |ctx: &mut Ctx| {
            ctx.plate("data", 4, Some(2), |ctx, plate| {
                assert_eq!(plate.len(), 2);
                assert!(!plate.is_full());
                ctx.observe("x", Normal::std(0.0, 1.0), plate.select(&data));
            });
        };
        let t = trace_fn(&model, &mut rng);
        assert_eq!(t.len(), 1);
        let site = t.get("x").unwrap();
        assert_eq!(site.scale, 2.0);
        assert_eq!(site.value.value().dims(), &[2]);
        assert_eq!(site.cond_indep_stack.len(), 1);
        let frame = &site.cond_indep_stack[0];
        assert_eq!(frame.name, "data");
        assert_eq!((frame.size, frame.subsample, frame.dim), (4, 2, 0));
        let per_site = -0.5 * crate::dist::LN_2PI;
        assert!((t.log_prob_sum() - 4.0 * per_site).abs() < 1e-12);
    }

    #[test]
    fn plate_seq_scales_log_prob() {
        let mut rng = Pcg64::new(5);
        // the retained sequential path: one site per index, each scaled
        let model = |ctx: &mut Ctx| {
            ctx.plate_seq("data", 4, Some(2), |ctx, idx| {
                assert_eq!(idx.len(), 2);
                for &i in idx {
                    ctx.observe(
                        &format!("x_{i}"),
                        Normal::std(0.0, 1.0),
                        Tensor::scalar(0.0),
                    );
                }
            });
        };
        let t = trace_fn(&model, &mut rng);
        assert_eq!(t.len(), 2);
        let per_site = -0.5 * crate::dist::LN_2PI;
        assert!((t.log_prob_sum() - 4.0 * per_site).abs() < 1e-12);
        for s in t.sites() {
            assert_eq!(s.scale, 2.0);
        }
    }

    #[test]
    fn nested_plates_compose_scales_and_allocate_dims() {
        let mut rng = Pcg64::new(55);
        let model = |ctx: &mut Ctx| {
            ctx.plate("outer", 6, Some(3), |ctx, _o| {
                ctx.plate("inner", 10, Some(2), |ctx, _i| {
                    // site batch [inner, outer]: outer owns the
                    // rightmost dim (entered first)
                    ctx.observe(
                        "x",
                        Normal::new(
                            ctx.c(Tensor::zeros(vec![2, 3])),
                            ctx.c(Tensor::ones(vec![2, 3])),
                        ),
                        Tensor::zeros(vec![2, 3]),
                    );
                });
            });
        };
        let t = trace_fn(&model, &mut rng);
        assert_eq!(t.len(), 1);
        let s = t.get("x").unwrap();
        assert!((s.scale - 2.0 * 5.0).abs() < 1e-12);
        assert_eq!(s.cond_indep_stack.len(), 2);
        // innermost frame first; dims allocate right-to-left
        assert_eq!(s.cond_indep_stack[0].name, "inner");
        assert_eq!(s.cond_indep_stack[0].dim, 1);
        assert_eq!(s.cond_indep_stack[1].name, "outer");
        assert_eq!(s.cond_indep_stack[1].dim, 0);
        let per = -0.5 * crate::dist::LN_2PI;
        assert!((t.log_prob_sum() - 60.0 * per).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "forget `plate.select`")]
    fn plate_shape_check_catches_forgotten_select() {
        let mut rng = Pcg64::new(77);
        let data = Tensor::from_vec(vec![0.0; 10]);
        let model = move |ctx: &mut Ctx| {
            ctx.plate("data", 10, Some(3), |ctx, _plate| {
                // bug under test: scoring the FULL data inside a
                // subsampled plate (missing `plate.select`)
                ctx.observe("x", Normal::std(0.0, 1.0), data.clone());
            });
        };
        trace_fn(&model, &mut rng);
    }

    #[test]
    fn duplicate_site_surfaces_error_through_try_sample() {
        let mut rng = Pcg64::new(40);
        let mut ctx = Ctx::new(&mut rng);
        ctx.try_sample("z", Normal::std(0.0, 1.0)).expect("first draw");
        let err = ctx
            .try_sample("z", Normal::std(0.0, 1.0))
            .expect_err("second draw must fail");
        assert!(format!("{err}").contains("duplicate sample site 'z'"));
        // the duplicate was not recorded
        assert_eq!(ctx.trace().len(), 1);
    }

    #[test]
    fn intervened_site_log_prob_is_a_tape_constant() {
        let mut rng = Pcg64::new(41);
        let model = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let intervened =
            crate::poutine::do_intervention(model, [("z", Tensor::scalar(3.0))]);
        let t = trace_fn(&intervened, &mut rng);
        let site = t.get("z").unwrap();
        let tape_len_before = site.value.tape().len();
        let lp = site.log_prob();
        assert_eq!(lp.item(), 0.0);
        // exactly one node appended: the constant itself, no live graph
        assert_eq!(site.value.tape().len(), tape_len_before + 1);
    }

    #[test]
    fn site_helpers_expose_ordering_and_reparam_status() {
        let mut rng = Pcg64::new(42);
        let model = |ctx: &mut Ctx| {
            ctx.sample("k", Bernoulli::std(0.5));
            ctx.plate("data", 3, None, |ctx, _p| {
                ctx.observe(
                    "x",
                    Normal::new(ctx.c(Tensor::zeros(vec![3])), ctx.c(Tensor::ones(vec![3]))),
                    Tensor::zeros(vec![3]),
                );
            });
        };
        let t = trace_fn(&model, &mut rng);
        assert_eq!(t.index_of("k"), Some(0));
        assert_eq!(t.index_of("x"), Some(1));
        assert_eq!(t.index_of("missing"), None);
        let k = t.get("k").unwrap();
        assert!(!k.is_reparam() && k.needs_score_term());
        assert!(k.frames().is_empty());
        let x = t.get("x").unwrap();
        assert!(x.is_reparam() && !x.needs_score_term());
        assert_eq!(x.frames().len(), 1);
        assert_eq!(x.frames()[0].name, "data");
    }

    #[test]
    fn param_store_roundtrip_through_ctx() {
        let mut rng = Pcg64::new(6);
        let mut store = ParamStore::new();
        let model = |ctx: &mut Ctx| {
            let w = ctx.param("w", || Tensor::scalar(1.5));
            let z = ctx.sample("z", Normal::new(w.clone(), ctx.cs(1.0)));
            z
        };
        let (t, _) = trace_with_store(&model, &mut rng, &mut store);
        assert!(t.param_leaves.contains_key("w"));
        assert!(store.contains("w"));
        assert!((store.get("w").unwrap().item() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn param_reuse_shares_leaf() {
        let mut rng = Pcg64::new(7);
        let mut store = ParamStore::new();
        let model = |ctx: &mut Ctx| {
            let a = ctx.param("w", || Tensor::scalar(2.0));
            let b = ctx.param("w", || Tensor::scalar(99.0));
            a.add(&b)
        };
        let (t, out) = trace_with_store(&model, &mut rng, &mut store);
        assert_eq!(t.param_leaves.len(), 1);
        assert!((out.value().item() - 4.0).abs() < 1e-12);
        // gradient flows to the single leaf with coefficient 2
        let leaf = &t.param_leaves["w"];
        let g = out.tape().grad(&out.sum(), &[leaf]).remove(0);
        assert!((g.item() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_site_recorded_with_zero_logprob() {
        let mut rng = Pcg64::new(8);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            let z2 = z.square();
            ctx.deterministic("z_squared", z2);
        };
        let t = trace_fn(&model, &mut rng);
        let site = t.get("z_squared").unwrap();
        assert!((site.log_prob().item()).abs() < 1e-12);
        let z = t.get("z").unwrap().value.value().item();
        assert!((site.value.value().item() - z * z).abs() < 1e-12);
    }

    #[test]
    fn observed_gradient_flows_to_upstream_latent() {
        // d log N(x | z, 1) / dz = (x - z)
        let mut rng = Pcg64::new(9);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z.clone(), ctx.cs(1.0)), Tensor::scalar(2.0));
            z
        };
        let mut ctx = Ctx::new(&mut rng);
        let z = model(&mut ctx);
        let t = ctx.into_trace();
        let lp = t.get("x").unwrap().log_prob();
        let g = z.tape().grad(&lp, &[&z]).remove(0);
        let want = 2.0 - z.value().item();
        assert!((g.item() - want).abs() < 1e-10);
    }
}
