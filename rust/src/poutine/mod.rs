//! Poutine: composable effect handlers for probabilistic programs.
//!
//! This is the paper's central architectural contribution (§2, §3): every
//! piece of inference machinery — tracing, replay, conditioning,
//! blocking, scaling, interventions — is an *effect handler* that
//! intercepts the `sample`/`param` effects emitted by a model as it runs.
//! Inference algorithms are then compositions of handlers, never
//! modifications of models.
//!
//! Execution model: a model is any `Fn(&mut Ctx) -> R`. `Ctx` owns the
//! autodiff tape, the RNG, the handler stack and the trace being
//! recorded. A `ctx.sample(name, dist)` call builds a [`Message`], runs
//! it through the stack **innermost-handler-first** (exactly Pyro's
//! `apply_stack`), applies the default behavior (draw a value if none was
//! injected), then runs `postprocess` outermost-first.

pub mod handlers;

pub use handlers::{block, condition, do_intervention, mask, replay, scale, seed, uncondition};

use crate::autodiff::{Tape, Var};
use crate::dist::{Constraint, Dist, Field, IntoVarDist};
use crate::params::ParamStore;
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;
use std::rc::Rc;

/// The effect payload seen by handlers at every sample site.
pub struct Message {
    /// The tape of the current execution (for lifting injected values).
    pub tape: Tape,
    pub name: String,
    pub dist: Rc<dyn Dist<Var>>,
    /// Injected or drawn value.
    pub value: Option<Var>,
    /// True when the value is data (observed or conditioned).
    pub is_observed: bool,
    /// Log-prob multiplier (plates, annealing).
    pub scale: f64,
    /// Optional elementwise mask on the log-prob.
    pub mask: Option<Tensor>,
    /// Excluded from the joint density (a `do` intervention).
    pub intervened: bool,
    /// Hidden from the recorded trace (`block`).
    pub hidden: bool,
    /// A handler already finalized the value; skip default sampling.
    pub done: bool,
}

/// An effect handler. Handlers see sample messages on the way in
/// (`process`, innermost first) and on the way out (`postprocess`,
/// outermost first), mirroring Pyro's Messenger API.
pub trait Messenger {
    fn process(&mut self, _msg: &mut Message) {}
    fn postprocess(&mut self, _msg: &mut Message) {}
}

/// One recorded sample site.
#[derive(Clone)]
pub struct Site {
    pub name: String,
    pub dist: Rc<dyn Dist<Var>>,
    pub value: Var,
    pub is_observed: bool,
    pub scale: f64,
    pub mask: Option<Tensor>,
    pub intervened: bool,
}

impl Site {
    /// Differentiable log-prob contribution of this site (scale and mask
    /// applied; zero if intervened).
    pub fn log_prob(&self) -> Var {
        if self.intervened {
            return self.value.mul_scalar(0.0).sum();
        }
        let mut lp = self.dist.log_prob(&self.value);
        if let Some(m) = &self.mask {
            lp = lp.mul(&lp.lift(m.clone()));
        }
        lp.sum().mul_scalar(self.scale)
    }
}

/// An execution trace: ordered sample sites plus the parameter leaves
/// touched during the run.
#[derive(Clone, Default)]
pub struct Trace {
    sites: Vec<Site>,
    by_name: HashMap<String, usize>,
    /// name -> unconstrained leaf Var for every `ctx.param` touched.
    pub param_leaves: HashMap<String, Var>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.sites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn sites(&self) -> &[Site] {
        &self.sites
    }

    pub fn get(&self, name: &str) -> Option<&Site> {
        self.by_name.get(name).map(|&i| &self.sites[i])
    }

    pub fn names(&self) -> Vec<&str> {
        self.sites.iter().map(|s| s.name.as_str()).collect()
    }

    fn record(&mut self, site: Site) {
        assert!(
            !self.by_name.contains_key(&site.name),
            "duplicate sample site '{}'",
            site.name
        );
        self.by_name.insert(site.name.clone(), self.sites.len());
        self.sites.push(site);
    }

    /// Differentiable total log-joint of the trace.
    pub fn log_prob_sum_var(&self) -> Option<Var> {
        let mut acc: Option<Var> = None;
        for s in &self.sites {
            let lp = s.log_prob();
            acc = Some(match acc {
                None => lp,
                Some(a) => a.add(&lp),
            });
        }
        acc
    }

    /// Concrete total log-joint.
    pub fn log_prob_sum(&self) -> f64 {
        self.log_prob_sum_var().map(|v| v.item()).unwrap_or(0.0)
    }

    /// Observed sites' log-likelihood only.
    pub fn log_likelihood(&self) -> f64 {
        self.sites
            .iter()
            .filter(|s| s.is_observed)
            .map(|s| s.log_prob().item())
            .sum()
    }
}

/// Execution context threaded through a model: tape + RNG + handler
/// stack + live trace (+ optional parameter store).
pub struct Ctx<'a> {
    pub tape: Tape,
    pub rng: &'a mut Pcg64,
    store: Option<&'a mut ParamStore>,
    stack: Vec<Box<dyn Messenger>>,
    trace: Trace,
    plate_depth: usize,
}

impl<'a> Ctx<'a> {
    pub fn new(rng: &'a mut Pcg64) -> Self {
        Ctx {
            tape: Tape::new(),
            rng,
            store: None,
            stack: Vec::new(),
            trace: Trace::default(),
            plate_depth: 0,
        }
    }

    pub fn with_store(rng: &'a mut Pcg64, store: &'a mut ParamStore) -> Self {
        let mut ctx = Ctx::new(rng);
        ctx.store = Some(store);
        ctx
    }

    /// Continue recording on an existing tape (SVI shares one tape
    /// between the guide run and the replayed model run).
    pub fn with_store_on_tape(
        tape: Tape,
        rng: &'a mut Pcg64,
        store: &'a mut ParamStore,
    ) -> Self {
        let mut ctx = Ctx::new(rng);
        ctx.tape = tape;
        ctx.store = Some(store);
        ctx
    }

    pub fn push_handler(&mut self, h: Box<dyn Messenger>) {
        self.stack.push(h);
    }

    pub fn pop_handler(&mut self) -> Option<Box<dyn Messenger>> {
        self.stack.pop()
    }

    /// Lift a plain tensor to a constant on this context's tape.
    pub fn c(&self, t: Tensor) -> Var {
        self.tape.constant(t)
    }

    /// Lift a scalar.
    pub fn cs(&self, v: f64) -> Var {
        self.tape.constant(Tensor::scalar(v))
    }

    /// The `pyro.sample` primitive.
    pub fn sample(&mut self, name: &str, dist: impl IntoVarDist) -> Var {
        let dist = dist.into_var_dist(&self.tape);
        self.apply(Message {
            tape: self.tape.clone(),
            name: name.to_string(),
            dist,
            value: None,
            is_observed: false,
            scale: 1.0,
            mask: None,
            intervened: false,
            hidden: false,
            done: false,
        })
    }

    /// `pyro.sample(name, dist, obs=value)`.
    pub fn observe(&mut self, name: &str, dist: impl IntoVarDist, value: Tensor) -> Var {
        let dist = dist.into_var_dist(&self.tape);
        let v = self.tape.constant(value);
        self.apply(Message {
            tape: self.tape.clone(),
            name: name.to_string(),
            dist,
            value: Some(v),
            is_observed: true,
            scale: 1.0,
            mask: None,
            intervened: false,
            hidden: false,
            done: true,
        })
    }

    /// Record a deterministic site (`pyro.deterministic`).
    pub fn deterministic(&mut self, name: &str, value: Var) -> Var {
        use crate::dist::Delta;
        let dist: Rc<dyn Dist<Var>> = Rc::new(Delta::new(value.clone()));
        self.apply(Message {
            tape: self.tape.clone(),
            name: name.to_string(),
            dist,
            value: Some(value),
            is_observed: false,
            scale: 1.0,
            mask: None,
            intervened: false,
            hidden: false,
            done: true,
        })
    }

    fn apply(&mut self, mut msg: Message) -> Var {
        // process: innermost handler first (reversed stack), like Pyro
        for h in self.stack.iter_mut().rev() {
            h.process(&mut msg);
        }
        // default behavior: draw if nothing injected
        if msg.value.is_none() {
            msg.value = Some(msg.dist.sample(self.rng));
        }
        // postprocess: outermost first
        for h in self.stack.iter_mut() {
            h.postprocess(&mut msg);
        }
        let value = msg.value.clone().unwrap();
        if !msg.hidden {
            self.trace.record(Site {
                name: msg.name,
                dist: msg.dist,
                value: value.clone(),
                is_observed: msg.is_observed,
                scale: msg.scale,
                mask: msg.mask,
                intervened: msg.intervened,
            });
        }
        value
    }

    /// The `pyro.param` primitive: fetch-or-create a learnable parameter
    /// (constrained view) and register its unconstrained leaf in the
    /// trace so optimizers can reach it.
    pub fn param(&mut self, name: &str, init: impl FnOnce() -> Tensor) -> Var {
        self.param_constrained(name, init, Constraint::Real)
    }

    pub fn param_constrained(
        &mut self,
        name: &str,
        init: impl FnOnce() -> Tensor,
        constraint: Constraint,
    ) -> Var {
        if let Some(existing) = self.trace.param_leaves.get(name) {
            // same param touched twice in one run: reuse the leaf so
            // gradients accumulate on a single node
            let store = self.store.as_ref().expect("param store");
            return store.constraint(name).transform(existing);
        }
        let store = self.store.as_mut().expect(
            "ctx.param requires a ParamStore (use Ctx::with_store)",
        );
        let unconstrained = store.get_or_init(name, init, constraint);
        let actual_constraint = store.constraint(name);
        let leaf = self.tape.leaf(unconstrained);
        self.trace.param_leaves.insert(name.to_string(), leaf.clone());
        actual_constraint.transform(&leaf)
    }

    /// `pyro.plate`: conditional-independence context with optional
    /// subsampling. Scales every log-prob inside by size/subsample and
    /// hands the body the chosen indices.
    pub fn plate<R>(
        &mut self,
        name: &str,
        size: usize,
        subsample: Option<usize>,
        body: impl FnOnce(&mut Ctx, &[usize]) -> R,
    ) -> R {
        let m = subsample.unwrap_or(size).min(size);
        let idx: Vec<usize> = if m == size {
            (0..size).collect()
        } else {
            self.rng.permutation(size)[..m].to_vec()
        };
        let factor = size as f64 / m as f64;
        self.push_handler(Box::new(handlers::ScaleMessenger::new(factor)));
        self.plate_depth += 1;
        let _ = name;
        let out = body(self, &idx);
        self.plate_depth -= 1;
        self.pop_handler();
        out
    }

    /// Finish the run and take the trace.
    pub fn into_trace(self) -> Trace {
        self.trace
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }
}

/// Run a model under a fresh context and return its trace.
pub fn trace_fn<R>(model: &dyn Fn(&mut Ctx) -> R, rng: &mut Pcg64) -> Trace {
    let mut ctx = Ctx::new(rng);
    model(&mut ctx);
    ctx.into_trace()
}

/// Run a model with a param store; returns (trace, model return).
pub fn trace_with_store<R>(
    model: &dyn Fn(&mut Ctx) -> R,
    rng: &mut Pcg64,
    store: &mut ParamStore,
) -> (Trace, R) {
    let mut ctx = Ctx::with_store(rng, store);
    let out = model(&mut ctx);
    (ctx.into_trace(), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Bernoulli, Normal};

    #[test]
    fn trace_records_sites_in_order() {
        let mut rng = Pcg64::new(1);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(0.5)), Tensor::scalar(1.0));
        };
        let t = trace_fn(&model, &mut rng);
        assert_eq!(t.names(), vec!["z", "x"]);
        assert!(!t.get("z").unwrap().is_observed);
        assert!(t.get("x").unwrap().is_observed);
        assert!(t.log_prob_sum().is_finite());
    }

    #[test]
    fn log_prob_sum_matches_manual() {
        let mut rng = Pcg64::new(2);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(0.5)), Tensor::scalar(1.0));
        };
        let t = trace_fn(&model, &mut rng);
        let z = t.get("z").unwrap().value.value().item();
        let n01 = Normal::std(0.0, 1.0);
        let nz = Normal::std(z, 0.5);
        let want = n01.log_prob(&Tensor::scalar(z)).item()
            + nz.log_prob(&Tensor::scalar(1.0)).item();
        assert!((t.log_prob_sum() - want).abs() < 1e-12);
    }

    #[test]
    fn data_dependent_control_flow_traces() {
        // geometric-style recursion: number of latents depends on draws —
        // the "universal PPL" property (paper Fig 2 expressivity row).
        fn flips(ctx: &mut Ctx, i: usize) -> usize {
            let v = ctx.sample(&format!("flip_{i}"), Bernoulli::std(0.4));
            if v.value().item() == 1.0 {
                i
            } else {
                flips(ctx, i + 1)
            }
        }
        let mut rng = Pcg64::new(3);
        let model = |ctx: &mut Ctx| flips(ctx, 0);
        let t = trace_fn(&model, &mut rng);
        assert!(!t.is_empty());
        // all sites are flips, last one is the success
        let last = t.sites().last().unwrap();
        assert_eq!(last.value.value().item(), 1.0);
        for s in &t.sites()[..t.len() - 1] {
            assert_eq!(s.value.value().item(), 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "duplicate sample site")]
    fn duplicate_site_panics() {
        let mut rng = Pcg64::new(4);
        let model = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        trace_fn(&model, &mut rng);
    }

    #[test]
    fn plate_scales_log_prob() {
        let mut rng = Pcg64::new(5);
        // full-data plate of 4, subsample 2 => factor 2 on each site
        let model = |ctx: &mut Ctx| {
            ctx.plate("data", 4, Some(2), |ctx, idx| {
                assert_eq!(idx.len(), 2);
                for &i in idx {
                    ctx.observe(
                        &format!("x_{i}"),
                        Normal::std(0.0, 1.0),
                        Tensor::scalar(0.0),
                    );
                }
            });
        };
        let t = trace_fn(&model, &mut rng);
        assert_eq!(t.len(), 2);
        let per_site = -0.5 * crate::dist::LN_2PI;
        assert!((t.log_prob_sum() - 4.0 * per_site).abs() < 1e-12);
        for s in t.sites() {
            assert_eq!(s.scale, 2.0);
        }
    }

    #[test]
    fn param_store_roundtrip_through_ctx() {
        let mut rng = Pcg64::new(6);
        let mut store = ParamStore::new();
        let model = |ctx: &mut Ctx| {
            let w = ctx.param("w", || Tensor::scalar(1.5));
            let z = ctx.sample("z", Normal::new(w.clone(), ctx.cs(1.0)));
            z
        };
        let (t, _) = trace_with_store(&model, &mut rng, &mut store);
        assert!(t.param_leaves.contains_key("w"));
        assert!(store.contains("w"));
        assert!((store.get("w").unwrap().item() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn param_reuse_shares_leaf() {
        let mut rng = Pcg64::new(7);
        let mut store = ParamStore::new();
        let model = |ctx: &mut Ctx| {
            let a = ctx.param("w", || Tensor::scalar(2.0));
            let b = ctx.param("w", || Tensor::scalar(99.0));
            a.add(&b)
        };
        let (t, out) = trace_with_store(&model, &mut rng, &mut store);
        assert_eq!(t.param_leaves.len(), 1);
        assert!((out.value().item() - 4.0).abs() < 1e-12);
        // gradient flows to the single leaf with coefficient 2
        let leaf = &t.param_leaves["w"];
        let g = out.tape().grad(&out.sum(), &[leaf]).remove(0);
        assert!((g.item() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_site_recorded_with_zero_logprob() {
        let mut rng = Pcg64::new(8);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            let z2 = z.square();
            ctx.deterministic("z_squared", z2);
        };
        let t = trace_fn(&model, &mut rng);
        let site = t.get("z_squared").unwrap();
        assert!((site.log_prob().item()).abs() < 1e-12);
        let z = t.get("z").unwrap().value.value().item();
        assert!((site.value.value().item() - z * z).abs() < 1e-12);
    }

    #[test]
    fn observed_gradient_flows_to_upstream_latent() {
        // d log N(x | z, 1) / dz = (x - z)
        let mut rng = Pcg64::new(9);
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z.clone(), ctx.cs(1.0)), Tensor::scalar(2.0));
            z
        };
        let mut ctx = Ctx::new(&mut rng);
        let z = model(&mut ctx);
        let t = ctx.into_trace();
        let lp = t.get("x").unwrap().log_prob();
        let g = z.tape().grad(&lp, &[&z]).remove(0);
        let want = 2.0 - z.value().item();
        assert!((g.item() - want).abs() < 1e-10);
    }
}
