//! The standard handler library: Fyro's rendering of `pyro.poutine`.
//!
//! Each messenger implements one orthogonal control operation; inference
//! algorithms compose them. The free functions (`replay`, `condition`,
//! `block`, ...) wrap a model closure in a handler push/pop pair so
//! composition reads like Pyro:
//!
//! ```
//! use fyro::prelude::*;
//! use fyro::poutine::{self, condition};
//! let model = |ctx: &mut Ctx| { ctx.sample("z", Normal::std(0.0, 1.0)); };
//! let conditioned = condition(model, [("z", Tensor::scalar(0.3))]);
//! let mut rng = Pcg64::new(0);
//! let t = poutine::trace_fn(&conditioned, &mut rng);
//! assert_eq!(t.get("z").unwrap().value.value().item(), 0.3);
//! ```

use super::{Ctx, Message, Messenger, PlateFrame, Trace};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;

// ------------------------------------------------------------------ plate

/// The vectorized-plate messenger: multiplies every enclosed site's
/// scale by the subsampling correction `size / subsample` and records
/// the plate's [`PlateFrame`] on the message's `cond_indep_stack`
/// (innermost frame first, since handlers process innermost-first).
/// This replaces the ad-hoc `ScaleMessenger` push the old per-index
/// plate used — sites now carry the full plate structure.
pub struct PlateMessenger {
    frame: PlateFrame,
}

impl PlateMessenger {
    pub fn new(frame: PlateFrame) -> Self {
        assert!(
            frame.subsample > 0 && frame.subsample <= frame.size,
            "[FY015] plate '{}': subsample {} out of range for size {}",
            frame.name,
            frame.subsample,
            frame.size
        );
        PlateMessenger { frame }
    }
}

impl Messenger for PlateMessenger {
    fn process(&mut self, msg: &mut Message) {
        msg.scale *= self.frame.scale();
        // two plates fighting over the same batch dim would silently
        // broadcast one against the other; flag it with the same lint
        // code the static analyzer uses (FY004).
        if msg.error.is_none() {
            if let Some(clash) =
                msg.cond_indep_stack.iter().find(|f| f.dim == self.frame.dim)
            {
                msg.error = Some(crate::error::Error::msg(format!(
                    "[FY004] site '{}': plates '{}' and '{}' collide on \
                     batch dim {} — enclosing plates must occupy distinct \
                     dims",
                    msg.name, clash.name, self.frame.name, self.frame.dim
                )));
            }
        }
        msg.cond_indep_stack.push(self.frame.clone());
    }

    fn postprocess(&mut self, msg: &mut Message) {
        // Pyro-style shape check: at this plate's allocated dim, the
        // site's value must either carry the subsample size, broadcast
        // (size 1), or not extend to the dim at all. This is what
        // catches "forgot `plate.select`" — scoring all N points while
        // also scaling by N/m would silently inflate the likelihood.
        // Intervened sites are excluded from the density, so their
        // shape is not this plate's business.
        if msg.intervened {
            return;
        }
        let Some(value) = &msg.value else { return };
        let vdims = value.value().dims();
        let from_right = msg.dist.event_shape().rank() + self.frame.dim;
        if from_right >= vdims.len() {
            return;
        }
        let d = vdims[vdims.len() - 1 - from_right];
        if !(d == self.frame.subsample || d == 1) && msg.error.is_none() {
            msg.error = Some(crate::error::Error::msg(format!(
                "[FY005] site '{}': batch dim {} (from the right) has \
                 size {d}, but plate '{}' expects its subsample size {} \
                 there (did you forget `plate.select`, or mean \
                 `to_event`?)",
                msg.name, self.frame.dim, self.frame.name, self.frame.subsample
            )));
        }
    }
}

// ----------------------------------------------------------------- replay

/// Inject values from a previous trace at matching non-observed sites
/// (`poutine.replay`). The backbone of SVI's model-against-guide pass.
pub struct ReplayMessenger {
    trace: Trace,
}

impl ReplayMessenger {
    pub fn new(trace: Trace) -> Self {
        ReplayMessenger { trace }
    }
}

impl Messenger for ReplayMessenger {
    fn process(&mut self, msg: &mut Message) {
        if msg.is_observed {
            return;
        }
        if let Some(site) = self.trace.get(&msg.name) {
            msg.value = Some(site.value.clone());
            msg.done = true;
        }
    }
}

/// Wrap `model` so it replays `trace`'s values.
pub fn replay<'m, R>(
    model: impl Fn(&mut Ctx) -> R + 'm,
    trace: Trace,
) -> impl Fn(&mut Ctx) -> R + 'm {
    move |ctx| {
        ctx.push_handler(Box::new(ReplayMessenger::new(trace.clone())));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}

// --------------------------------------------------------------- condition

/// Fix named sites to data and mark them observed (`pyro.condition`).
pub struct ConditionMessenger {
    data: HashMap<String, Tensor>,
}

impl ConditionMessenger {
    pub fn new(data: HashMap<String, Tensor>) -> Self {
        ConditionMessenger { data }
    }
}

impl Messenger for ConditionMessenger {
    fn process(&mut self, msg: &mut Message) {
        if let Some(v) = self.data.get(&msg.name) {
            msg.value = Some(msg.tape.constant(v.clone()));
            msg.is_observed = true;
            msg.done = true;
        }
    }
}

/// Wrap `model`, conditioning sites on data: `pyro.condition`.
pub fn condition<'m, R, I>(
    model: impl Fn(&mut Ctx) -> R + 'm,
    data: I,
) -> impl Fn(&mut Ctx) -> R + 'm
where
    I: IntoIterator<Item = (&'static str, Tensor)>,
{
    let map: HashMap<String, Tensor> =
        data.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    move |ctx| {
        ctx.push_handler(Box::new(ConditionMessenger::new(map.clone())));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}

// --------------------------------------------------------------------- do

/// Causal intervention (`pyro.do`): fix values like `condition` but
/// exclude the site from the joint density.
pub struct DoMessenger {
    data: HashMap<String, Tensor>,
}

impl DoMessenger {
    pub fn new(data: HashMap<String, Tensor>) -> Self {
        DoMessenger { data }
    }
}

impl Messenger for DoMessenger {
    fn process(&mut self, msg: &mut Message) {
        if let Some(v) = self.data.get(&msg.name) {
            msg.value = Some(msg.tape.constant(v.clone()));
            msg.intervened = true;
            msg.done = true;
        }
    }
}

/// Wrap `model` with interventions.
pub fn do_intervention<'m, R, I>(
    model: impl Fn(&mut Ctx) -> R + 'm,
    data: I,
) -> impl Fn(&mut Ctx) -> R + 'm
where
    I: IntoIterator<Item = (&'static str, Tensor)>,
{
    let map: HashMap<String, Tensor> =
        data.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    move |ctx| {
        ctx.push_handler(Box::new(DoMessenger::new(map.clone())));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}

// ------------------------------------------------------------------ block

/// Hide matching sites from the recorded trace (`poutine.block`).
pub struct BlockMessenger {
    pred: Box<dyn Fn(&str) -> bool>,
}

impl BlockMessenger {
    pub fn hiding(pred: impl Fn(&str) -> bool + 'static) -> Self {
        BlockMessenger { pred: Box::new(pred) }
    }
}

impl Messenger for BlockMessenger {
    fn process(&mut self, msg: &mut Message) {
        if (self.pred)(&msg.name) {
            msg.hidden = true;
        }
    }
}

/// Wrap `model`, hiding sites whose name satisfies `pred`.
pub fn block<'m, R>(
    model: impl Fn(&mut Ctx) -> R + 'm,
    pred: impl Fn(&str) -> bool + Clone + 'static,
) -> impl Fn(&mut Ctx) -> R + 'm {
    move |ctx| {
        ctx.push_handler(Box::new(BlockMessenger::hiding(pred.clone())));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}

// ------------------------------------------------------------------ scale

/// Multiply log-probs by a constant (`poutine.scale`) — subsampling
/// correction, KL annealing.
pub struct ScaleMessenger {
    factor: f64,
}

impl ScaleMessenger {
    pub fn new(factor: f64) -> Self {
        assert!(factor.is_finite() && factor > 0.0, "scale must be positive");
        ScaleMessenger { factor }
    }
}

impl Messenger for ScaleMessenger {
    fn process(&mut self, msg: &mut Message) {
        msg.scale *= self.factor;
    }
}

/// Wrap `model`, scaling all site log-probs.
pub fn scale<'m, R>(model: impl Fn(&mut Ctx) -> R + 'm, factor: f64) -> impl Fn(&mut Ctx) -> R + 'm {
    move |ctx| {
        ctx.push_handler(Box::new(ScaleMessenger::new(factor)));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}

// ------------------------------------------------------------------- mask

/// Apply an elementwise {0,1} mask to site log-probs (`poutine.mask`) —
/// variable-length sequences in a padded batch (the DMM's T_max trick).
pub struct MaskMessenger {
    mask: Tensor,
}

impl MaskMessenger {
    pub fn new(mask: Tensor) -> Self {
        MaskMessenger { mask }
    }
}

impl Messenger for MaskMessenger {
    fn process(&mut self, msg: &mut Message) {
        msg.mask = Some(match &msg.mask {
            None => self.mask.clone(),
            Some(existing) => existing.mul(&self.mask),
        });
    }
}

/// Wrap `model`, masking all site log-probs.
pub fn mask<'m, R>(model: impl Fn(&mut Ctx) -> R + 'm, m: Tensor) -> impl Fn(&mut Ctx) -> R + 'm {
    move |ctx| {
        ctx.push_handler(Box::new(MaskMessenger::new(m.clone())));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}

// ------------------------------------------------------------- substitute

/// Inject raw `Var` values at named non-observed sites, keeping them
/// scored. Unlike `condition` the values stay differentiable — this is
/// how HMC/NUTS propose new latent states and get ∇ log p back.
pub struct SubstituteMessenger {
    map: HashMap<String, crate::autodiff::Var>,
}

impl SubstituteMessenger {
    pub fn new(map: HashMap<String, crate::autodiff::Var>) -> Self {
        SubstituteMessenger { map }
    }
}

impl Messenger for SubstituteMessenger {
    fn process(&mut self, msg: &mut Message) {
        if msg.is_observed {
            return;
        }
        if let Some(v) = self.map.get(&msg.name) {
            msg.value = Some(v.clone());
            msg.done = true;
        }
    }
}

// ------------------------------------------------------------ uncondition

/// Turn observed sites back into sampled ones (`poutine.uncondition`) —
/// the posterior-predictive mechanism.
pub struct UnconditionMessenger;

impl Messenger for UnconditionMessenger {
    fn process(&mut self, msg: &mut Message) {
        if msg.is_observed {
            msg.is_observed = false;
            msg.value = None;
            msg.done = false;
        }
    }
}

/// Wrap `model`, re-sampling its observed sites.
pub fn uncondition<'m, R>(model: impl Fn(&mut Ctx) -> R + 'm) -> impl Fn(&mut Ctx) -> R + 'm {
    move |ctx| {
        ctx.push_handler(Box::new(UnconditionMessenger));
        let out = model(ctx);
        ctx.pop_handler();
        out
    }
}

// ------------------------------------------------------------------- seed

/// Run a model with a fixed RNG seed (`pyro.poutine.seed` analog).
pub fn seed<R>(model: impl Fn(&mut Ctx) -> R, s: u64) -> impl Fn(&mut Ctx) -> R {
    move |ctx| {
        // swap in a fresh seeded stream for the duration of the run
        let mut seeded = Pcg64::new(s);
        std::mem::swap(ctx.rng, &mut seeded);
        let out = model(ctx);
        std::mem::swap(ctx.rng, &mut seeded);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Normal};
    use crate::poutine::trace_fn;

    fn simple_model(ctx: &mut Ctx) {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(0.5)), Tensor::scalar(1.0));
    }

    #[test]
    fn replay_injects_guide_values() {
        let mut rng = Pcg64::new(1);
        let guide = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(5.0, 0.001));
        };
        let gt = trace_fn(&guide, &mut rng);
        let z_guide = gt.get("z").unwrap().value.value().item();
        let replayed = replay(simple_model, gt);
        let mt = trace_fn(&replayed, &mut rng);
        assert_eq!(mt.get("z").unwrap().value.value().item(), z_guide);
        // model trace scores the replayed value under the model prior
        assert!(mt.log_prob_sum() < -5.0); // z≈5 is deep in the N(0,1) tail
    }

    #[test]
    fn condition_marks_observed() {
        let model = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let cond = condition(model, [("z", Tensor::scalar(0.25))]);
        let mut rng = Pcg64::new(2);
        let t = trace_fn(&cond, &mut rng);
        let site = t.get("z").unwrap();
        assert!(site.is_observed);
        assert_eq!(site.value.value().item(), 0.25);
        let want = Normal::std(0.0, 1.0).log_prob(&Tensor::scalar(0.25)).item();
        assert!((t.log_prob_sum() - want).abs() < 1e-12);
    }

    #[test]
    fn do_excludes_from_density() {
        let model = |ctx: &mut Ctx| {
            let z = ctx.sample("z", Normal::std(0.0, 1.0));
            ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.0));
        };
        let intervened = do_intervention(model, [("z", Tensor::scalar(3.0))]);
        let mut rng = Pcg64::new(3);
        let t = trace_fn(&intervened, &mut rng);
        let z_site = t.get("z").unwrap();
        assert!(z_site.intervened);
        assert_eq!(z_site.value.value().item(), 3.0);
        // density contains only the x term: N(0 | 3, 1)
        let want = Normal::std(3.0, 1.0).log_prob(&Tensor::scalar(0.0)).item();
        assert!((t.log_prob_sum() - want).abs() < 1e-12);
    }

    #[test]
    fn block_hides_sites() {
        let mut rng = Pcg64::new(4);
        let blocked = block(simple_model, |name: &str| name == "z");
        let t = trace_fn(&blocked, &mut rng);
        assert!(t.get("z").is_none());
        assert!(t.get("x").is_some());
    }

    #[test]
    fn scale_multiplies_log_prob() {
        let mut rng = Pcg64::new(5);
        let base = trace_fn(&simple_model, &mut rng);
        let scaled_model = scale(simple_model, 3.0);
        let mut rng2 = Pcg64::new(5); // same seed -> same draws
        let t = trace_fn(&scaled_model, &mut rng2);
        assert!((t.log_prob_sum() - 3.0 * base.log_prob_sum()).abs() < 1e-9);
    }

    #[test]
    fn nested_scales_compose_multiplicatively() {
        let mut rng = Pcg64::new(6);
        let model = |ctx: &mut Ctx| {
            ctx.observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.0));
        };
        let nested = scale(scale(model, 2.0), 5.0);
        let t = trace_fn(&nested, &mut rng);
        assert_eq!(t.get("x").unwrap().scale, 10.0);
    }

    #[test]
    fn mask_zeroes_selected_elements() {
        let mut rng = Pcg64::new(7);
        let model = |ctx: &mut Ctx| {
            ctx.observe(
                "x",
                Normal::new(ctx.c(Tensor::zeros(vec![3])), ctx.c(Tensor::ones(vec![3]))),
                Tensor::from_vec(vec![0.0, 10.0, 0.0]),
            );
        };
        let masked = mask(model, Tensor::from_vec(vec![1.0, 0.0, 1.0]));
        let t = trace_fn(&masked, &mut rng);
        // the outlier 10.0 is masked out: lp = 2 * logN(0|0,1)
        let want = 2.0 * Normal::std(0.0, 1.0).log_prob(&Tensor::scalar(0.0)).item();
        assert!((t.log_prob_sum() - want).abs() < 1e-12);
    }

    #[test]
    fn seed_reproduces_draws() {
        let mut rng1 = Pcg64::new(100);
        let mut rng2 = Pcg64::new(200);
        let seeded = seed(simple_model, 7);
        let t1 = trace_fn(&seeded, &mut rng1);
        let t2 = trace_fn(&seeded, &mut rng2);
        assert_eq!(
            t1.get("z").unwrap().value.value().item(),
            t2.get("z").unwrap().value.value().item()
        );
    }

    #[test]
    fn handlers_compose_condition_then_scale() {
        let mut rng = Pcg64::new(8);
        let model = |ctx: &mut Ctx| {
            ctx.sample("z", Normal::std(0.0, 1.0));
        };
        let composed = scale(condition(model, [("z", Tensor::scalar(1.0))]), 2.0);
        let t = trace_fn(&composed, &mut rng);
        let want = 2.0 * Normal::std(0.0, 1.0).log_prob(&Tensor::scalar(1.0)).item();
        assert!((t.log_prob_sum() - want).abs() < 1e-12);
    }

    #[test]
    fn custom_messenger_fig2_flexibility() {
        // A user-defined handler (paper Fig 2 "flexible inference" row):
        // records every site name it sees, demonstrating the open
        // Messenger API.
        use std::cell::RefCell;
        use std::rc::Rc;
        struct Recorder(Rc<RefCell<Vec<String>>>);
        impl Messenger for Recorder {
            fn process(&mut self, msg: &mut Message) {
                self.0.borrow_mut().push(msg.name.clone());
            }
        }
        let log = Rc::new(RefCell::new(Vec::new()));
        let log2 = log.clone();
        let mut rng = Pcg64::new(9);
        let mut ctx = Ctx::new(&mut rng);
        ctx.push_handler(Box::new(Recorder(log2)));
        simple_model(&mut ctx);
        ctx.pop_handler();
        assert_eq!(*log.borrow(), vec!["z".to_string(), "x".to_string()]);
    }
}
