//! Minimal property-based testing support (the offline registry has no
//! `proptest`, so Fyro carries its own).
//!
//! A property test here is: a seeded generator strategy, N random cases,
//! and an assertion closure. On failure the failing case and its seed are
//! printed so the case can be replayed deterministically. No shrinking —
//! generated cases are kept small instead.

use crate::tensor::{Pcg64, Tensor};

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xF1_70 }
    }
}

/// Run `prop` on `cfg.cases` random inputs drawn by `gen`.
/// Panics with the case index + seed on the first failure.
pub fn for_all<T: std::fmt::Debug>(
    cfg: Config,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}):\n  input: {:?}\n  {msg}",
                cfg.seed, input
            );
        }
    }
}

/// Shorthand with the default config.
pub fn check<T: std::fmt::Debug>(
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for_all(Config::default(), gen, prop)
}

// ---------- generators ----------

/// Uniform float in [lo, hi).
pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.uniform()
}

/// Positive float, log-uniform in [1e-3, 1e3).
pub fn positive(rng: &mut Pcg64) -> f64 {
    10f64.powf(f64_in(rng, -3.0, 3.0))
}

/// Random small shape (rank 0..=3, dims 1..=6).
pub fn small_shape(rng: &mut Pcg64) -> Vec<usize> {
    let rank = rng.below(4);
    (0..rank).map(|_| 1 + rng.below(6)).collect()
}

/// Random tensor with entries ~ N(0, scale).
pub fn tensor(rng: &mut Pcg64, shape: &[usize], scale: f64) -> Tensor {
    Tensor::randn(shape.to_vec(), rng).mul_scalar(scale)
}

/// Assert helper producing Result for use inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate equality helper.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    ensure(
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())),
        format!("{a} !~ {b} (tol {tol})"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_passes_trivial_property() {
        check(|rng| f64_in(rng, -1.0, 1.0), |&x| ensure((-1.0..1.0).contains(&x), "range"));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn for_all_reports_failure() {
        check(|rng| rng.uniform(), |&x| ensure(x < 0.5, "always fails eventually"));
    }

    #[test]
    fn broadcast_commutes_with_add_property() {
        // a + b == b + a for random broadcastable shapes
        check(
            |rng| {
                let shape = small_shape(rng);
                let a = tensor(rng, &shape, 1.0);
                let b = tensor(rng, &shape, 1.0);
                (a, b)
            },
            |(a, b)| ensure(a.add(b).allclose(&b.add(a), 1e-12), "a+b != b+a"),
        );
    }
}
