//! The compiled-path training coordinator.
//!
//! Mirrors how Pyro rides on PyTorch: the dense numeric work (model fwd +
//! guide fwd + ELBO + backward + Adam) is a compiled artifact (L2 JAX →
//! HLO → PJRT), and the PPL machinery wraps *around* it — the RNG, the
//! trace/messenger stack, the param-store bookkeeping, mini-batching,
//! epochs and metrics all live here in Rust.
//!
//! Two step paths exist on purpose (paper Fig 3):
//! - [`CompiledSvi::step_raw`] — the "idiomatic PyTorch" baseline: feed
//!   the artifact, nothing else.
//! - [`CompiledSvi::step_traced`] — the "Fyro" path: the same artifact
//!   call, but the noise draw is a real `ctx.sample` through the full
//!   handler stack (plate-scaled, prior-scored), the data is a recorded
//!   observe site, and parameters go through the param store — i.e. all
//!   the abstraction cost Pyro layers on top of its kernels.
//!
//! Not to be confused with graph-mode SVI ([`crate::infer::compile`]):
//! that path compiles a recorded *trace* of the pure-Rust dynamic
//! interpreter into a straight-line CPU kernel, with no PJRT artifact
//! involved. This module targets an external accelerator executable;
//! graph mode removes interpreter overhead on the in-process path.

use crate::data::{gather_images, gather_rolls, BatchIter, SyntheticChorales, SyntheticMnist};
use crate::dist::{Delta, MvNormalDiag};
use crate::poutine::Ctx;
use crate::error::{Error, Result};
use crate::runtime::{CompiledModel, DeviceState, F32Buf, TrainState};
use crate::tensor::{Pcg64, Tensor};
use std::io::{Read, Write};
use std::sync::mpsc;
use std::time::Instant;

/// Which step path to use (the Fig-3 comparison axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPath {
    /// Bare artifact execution ("PyTorch" baseline).
    Raw,
    /// Full PPL machinery around the artifact ("Fyro").
    Traced,
}

/// SVI over a compiled model artifact. Training state stays as PJRT
/// literals between steps (§Perf: skips the host round-trip of params +
/// Adam moments); use [`CompiledSvi::host_state`] for checkpoints.
pub struct CompiledSvi {
    pub model: CompiledModel,
    pub dev: DeviceState,
    pub rng: Pcg64,
}

impl CompiledSvi {
    pub fn new(model: CompiledModel, seed: u64) -> Result<Self> {
        let state = model.init_state()?;
        let dev = model.to_device(&state)?;
        Ok(CompiledSvi { model, dev, rng: Pcg64::new(seed) })
    }

    /// Materialize the training state on host (checkpoints, tests).
    pub fn host_state(&self) -> Result<TrainState> {
        self.model.to_host(&self.dev)
    }

    /// Replace the device state from a host state (checkpoint restore).
    pub fn load_state(&mut self, state: &TrainState) -> Result<()> {
        self.dev = self.model.to_device(state)?;
        Ok(())
    }

    fn draw_eps(&mut self) -> F32Buf {
        let dims = self.model.meta.eps_dims.clone();
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| self.rng.normal() as f32).collect();
        F32Buf { data, dims }
    }

    /// Bare step: artifact execution only.
    pub fn step_raw(&mut self, x: &F32Buf) -> Result<f32> {
        let eps = self.draw_eps();
        self.model.train_step_dev(&mut self.dev, x, &eps)
    }

    /// Full-PPL step: the noise is a traced `sample` site, the data a
    /// traced `observe` site, parameters round through the param store.
    pub fn step_traced(
        &mut self,
        x: &F32Buf,
        store: &mut crate::params::ParamStore,
    ) -> Result<f32> {
        let meta_batch = self.model.meta.batch;
        let eps_dims = self.model.meta.eps_dims.clone();
        let x_dims = self.model.meta.x_dims.clone();

        // ---- guide trace: eps ~ N(0, I) through the handler stack ----
        let mut ctx = Ctx::with_store(&mut self.rng, store);
        let loc = ctx.c(Tensor::zeros(eps_dims.clone()));
        let scale = ctx.c(Tensor::ones(eps_dims.clone()));
        let eps_var = ctx.plate("batch", meta_batch, None, |ctx, _| {
            ctx.sample("eps", MvNormalDiag::new(loc.clone(), scale.clone()))
        });
        // score the draw (what Pyro's guide trace does for every site)
        let _guide_lp = ctx.trace().get("eps").unwrap().log_prob().item();

        // ---- model trace: data recorded as an observed site ----
        // (its density is computed *inside* the artifact, exactly like a
        // fused CUDA op in Pyro; the trace records the site + metadata)
        let x_f64 = Tensor::new(x.data.iter().map(|&v| v as f64).collect(), x_dims);
        let x_var = ctx.c(x_f64);
        ctx.observe("x", Delta::new(x_var), Tensor::zeros(vec![1]).reshape(vec![1]));
        let trace = ctx.into_trace();
        debug_assert_eq!(trace.len(), 2);

        // ---- compiled ELBO step with the traced noise ----
        let eps_f32: Vec<f32> =
            eps_var.value().data().iter().map(|&v| v as f32).collect();
        let eps = F32Buf { data: eps_f32, dims: eps_var.value().dims().to_vec() };
        let loss = self.model.train_step_dev(&mut self.dev, x, &eps)?;

        // ---- param-store bookkeeping (Pyro: params live in the store) --
        store.get_or_init(
            &format!("{}.flat", self.model.meta.name),
            || Tensor::zeros(vec![1]),
            crate::dist::Constraint::Real,
        );
        Ok(loss)
    }

    pub fn eval(&self, x: &F32Buf, eps: &F32Buf) -> Result<f32> {
        self.model.eval_step_dev(&self.dev, x, eps)
    }

    /// The PPL machinery of [`CompiledSvi::step_traced`] *without* the
    /// artifact execution — used by the Fig-3 bench to quantify the
    /// abstraction cost directly (it is otherwise below the noise floor
    /// of the compiled step on this testbed).
    pub fn trace_machinery_only(
        &mut self,
        x: &F32Buf,
        store: &mut crate::params::ParamStore,
    ) -> F32Buf {
        let meta_batch = self.model.meta.batch;
        let eps_dims = self.model.meta.eps_dims.clone();
        let x_dims = self.model.meta.x_dims.clone();
        let mut ctx = Ctx::with_store(&mut self.rng, store);
        let loc = ctx.c(Tensor::zeros(eps_dims.clone()));
        let scale = ctx.c(Tensor::ones(eps_dims.clone()));
        let eps_var = ctx.plate("batch", meta_batch, None, |ctx, _| {
            ctx.sample("eps", MvNormalDiag::new(loc.clone(), scale.clone()))
        });
        let _guide_lp = ctx.trace().get("eps").unwrap().log_prob().item();
        let x_f64 = Tensor::new(x.data.iter().map(|&v| v as f64).collect(), x_dims);
        let x_var = ctx.c(x_f64);
        ctx.observe("x", Delta::new(x_var), Tensor::zeros(vec![1]).reshape(vec![1]));
        let _trace = ctx.into_trace();
        let eps_f32: Vec<f32> = eps_var.value().data().iter().map(|&v| v as f32).collect();
        F32Buf { data: eps_f32, dims: eps_var.value().dims().to_vec() }
    }
}

// ----------------------------------------------------------- checkpoints

/// Write the training state to a flat little-endian f32 file.
pub fn save_checkpoint(path: &str, state: &TrainState) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    for buf in [&state.params, &state.m, &state.v, &state.t] {
        for &v in &buf.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Restore a checkpoint written by [`save_checkpoint`] into a state with
/// matching shapes.
pub fn load_checkpoint(path: &str, state: &mut TrainState) -> Result<()> {
    let mut f = std::fs::File::open(path)?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    let total = state.params.data.len() + state.m.data.len() + state.v.data.len() + 1;
    if bytes.len() != total * 4 {
        return Err(Error::msg("checkpoint size mismatch"));
    }
    let mut off = 0usize;
    for buf in [&mut state.params, &mut state.m, &mut state.v, &mut state.t] {
        for v in buf.data.iter_mut() {
            *v = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
        }
    }
    Ok(())
}

// ------------------------------------------------------------- training

/// Per-epoch training metrics.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    pub steps: usize,
    pub secs: f64,
}

impl EpochStats {
    pub fn throughput(&self, batch: usize) -> f64 {
        self.steps as f64 * batch as f64 / self.secs
    }
}

/// VAE trainer over synthetic MNIST with a prefetch thread feeding
/// batches through a bounded channel (the coordinator's pipeline).
pub struct VaeTrainer {
    pub svi: CompiledSvi,
    pub data: SyntheticMnist,
    pub path: StepPath,
    pub store: crate::params::ParamStore,
}

impl VaeTrainer {
    pub fn new(model: CompiledModel, n_train: usize, n_test: usize, path: StepPath) -> Result<Self> {
        let data = SyntheticMnist::generate(n_train, n_test, 0xDA7A);
        let svi = CompiledSvi::new(model, 0x5EED)?;
        Ok(VaeTrainer { svi, data, path, store: crate::params::ParamStore::new() })
    }

    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochStats> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let started = Instant::now();

        // prefetch thread: gathers batch matrices while PJRT computes
        let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(2);
        let order: Vec<Vec<usize>> = {
            let mut rng = Pcg64::new(0xE10C ^ epoch as u64);
            BatchIter::new(self.data.train.len(), batch, &mut rng).collect()
        };
        let n_steps = order.len();
        std::thread::scope(|scope| -> Result<(f64, usize)> {
            let train_ref = &self.data.train;
            scope.spawn(move || {
                for idx in &order {
                    if tx.send(gather_images(train_ref, idx)).is_err() {
                        break;
                    }
                }
            });
            let mut total = 0.0;
            let mut steps = 0usize;
            while let Ok(data) = rx.recv() {
                let x = F32Buf { data, dims: x_dims.clone() };
                let loss = match self.path {
                    StepPath::Raw => self.svi.step_raw(&x)?,
                    StepPath::Traced => self.svi.step_traced(&x, &mut self.store)?,
                };
                total += loss as f64;
                steps += 1;
            }
            Ok((total, steps))
        })
        .map(|(total, steps)| {
            let secs = started.elapsed().as_secs_f64();
            let test_loss = self.test_loss().unwrap_or(f64::NAN);
            EpochStats {
                epoch,
                train_loss: total / steps.max(1) as f64,
                test_loss,
                steps: n_steps,
                secs,
            }
        })
    }

    pub fn test_loss(&mut self) -> Result<f64> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let mut total = 0.0;
        let mut n = 0;
        let mut rng = Pcg64::new(0x7E57);
        for chunk in self.data.test.chunks(batch) {
            if chunk.len() < batch {
                break;
            }
            let idx: Vec<usize> = (0..batch).collect();
            let x = F32Buf { data: gather_images(chunk, &idx), dims: x_dims.clone() };
            let eps_dims = self.svi.model.meta.eps_dims.clone();
            let ne: usize = eps_dims.iter().product();
            let eps = F32Buf {
                data: (0..ne).map(|_| rng.normal() as f32).collect(),
                dims: eps_dims,
            };
            total += self.svi.eval(&x, &eps)? as f64;
            n += 1;
        }
        Ok(total / n.max(1) as f64)
    }
}

/// DMM trainer over synthetic chorales.
pub struct DmmTrainer {
    pub svi: CompiledSvi,
    pub data: SyntheticChorales,
}

impl DmmTrainer {
    pub fn new(model: CompiledModel, n_train: usize, n_test: usize) -> Result<Self> {
        let t_len = model.meta.x_dims[1];
        let data = SyntheticChorales::generate(n_train, n_test, t_len, 0xC0DA);
        let svi = CompiledSvi::new(model, 0xD1CE)?;
        Ok(DmmTrainer { svi, data })
    }

    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochStats> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let started = Instant::now();
        let mut rng = Pcg64::new(0xE20C ^ epoch as u64);
        let mut total = 0.0;
        let mut steps = 0usize;
        for idx in BatchIter::new(self.data.train.len(), batch, &mut rng) {
            let x = F32Buf { data: gather_rolls(&self.data.train, &idx), dims: x_dims.clone() };
            total += self.svi.step_raw(&x)? as f64;
            steps += 1;
        }
        let secs = started.elapsed().as_secs_f64();
        let test_loss = self.test_loss()?;
        Ok(EpochStats { epoch, train_loss: total / steps.max(1) as f64, test_loss, steps, secs })
    }

    /// Mean test -ELBO per timestep (the Fig-4 number, negated).
    pub fn test_loss(&mut self) -> Result<f64> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let eps_dims = self.svi.model.meta.eps_dims.clone();
        let mut rng = Pcg64::new(0x7E58);
        let mut total = 0.0;
        let mut n = 0;
        for chunk in self.data.test.chunks(batch) {
            if chunk.len() < batch {
                break;
            }
            let idx: Vec<usize> = (0..batch).collect();
            let x = F32Buf { data: gather_rolls(chunk, &idx), dims: x_dims.clone() };
            let ne: usize = eps_dims.iter().product();
            let eps = F32Buf {
                data: (0..ne).map(|_| rng.normal() as f32).collect(),
                dims: eps_dims.clone(),
            };
            total += self.svi.eval(&x, &eps)? as f64;
            n += 1;
        }
        Ok(total / n.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::F32Buf;

    #[test]
    fn checkpoint_roundtrip() {
        let mut state = TrainState {
            params: F32Buf { data: vec![1.0, 2.0, 3.0], dims: vec![3] },
            m: F32Buf { data: vec![0.1, 0.2, 0.3], dims: vec![3] },
            v: F32Buf { data: vec![0.4, 0.5, 0.6], dims: vec![3] },
            t: F32Buf { data: vec![7.0], dims: vec![1] },
            step: 7,
        };
        let path = "/tmp/fyro_ckpt_test.bin";
        save_checkpoint(path, &state).unwrap();
        let orig = state.params.data.clone();
        state.params.data = vec![0.0; 3];
        load_checkpoint(path, &mut state).unwrap();
        assert_eq!(state.params.data, orig);
        assert_eq!(state.t.data, vec![7.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn epoch_stats_throughput() {
        let s = EpochStats { epoch: 0, train_loss: 1.0, test_loss: 1.0, steps: 10, secs: 2.0 };
        assert_eq!(s.throughput(128), 640.0);
    }
}
