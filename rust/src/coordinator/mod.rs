//! The compiled-path training coordinator.
//!
//! Mirrors how Pyro rides on PyTorch: the dense numeric work (model fwd +
//! guide fwd + ELBO + backward + Adam) is a compiled artifact (L2 JAX →
//! HLO → PJRT), and the PPL machinery wraps *around* it — the RNG, the
//! trace/messenger stack, the param-store bookkeeping, mini-batching,
//! epochs and metrics all live here in Rust.
//!
//! Two step paths exist on purpose (paper Fig 3):
//! - [`CompiledSvi::step_raw`] — the "idiomatic PyTorch" baseline: feed
//!   the artifact, nothing else.
//! - [`CompiledSvi::step_traced`] — the "Fyro" path: the same artifact
//!   call, but the noise draw is a real `ctx.sample` through the full
//!   handler stack (plate-scaled, prior-scored), the data is a recorded
//!   observe site, and parameters go through the param store — i.e. all
//!   the abstraction cost Pyro layers on top of its kernels.
//!
//! Not to be confused with graph-mode SVI ([`crate::infer::compile`]):
//! that path compiles a recorded *trace* of the pure-Rust dynamic
//! interpreter into a straight-line CPU kernel, with no PJRT artifact
//! involved. This module targets an external accelerator executable;
//! graph mode removes interpreter overhead on the in-process path.

use crate::data::{
    gather_images, gather_rolls, BatchIter, ShardCursor, ShardedLoader, SyntheticChorales,
    SyntheticMnist,
};
use crate::dist::{Constraint, Delta, MvNormalDiag};
use crate::error::{Error, Result};
use crate::infer::data_parallel::{fill_views_from_scratch, BatchLayout, ShardBatch, ShardModelFn};
use crate::infer::elbo::Elbo;
use crate::infer::svi::run_particle;
use crate::optim::{apply_grads, Optimizer};
use crate::params::ParamStore;
use crate::poutine::Ctx;
use crate::runtime::{CompiledModel, DeviceState, F32Buf, TrainState};
use crate::tensor::{Pcg64, Tensor};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

/// Which step path to use (the Fig-3 comparison axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPath {
    /// Bare artifact execution ("PyTorch" baseline).
    Raw,
    /// Full PPL machinery around the artifact ("Fyro").
    Traced,
}

/// SVI over a compiled model artifact. Training state stays as PJRT
/// literals between steps (§Perf: skips the host round-trip of params +
/// Adam moments); use [`CompiledSvi::host_state`] for checkpoints.
pub struct CompiledSvi {
    pub model: CompiledModel,
    pub dev: DeviceState,
    pub rng: Pcg64,
}

impl CompiledSvi {
    pub fn new(model: CompiledModel, seed: u64) -> Result<Self> {
        let state = model.init_state()?;
        let dev = model.to_device(&state)?;
        Ok(CompiledSvi { model, dev, rng: Pcg64::new(seed) })
    }

    /// Materialize the training state on host (checkpoints, tests).
    pub fn host_state(&self) -> Result<TrainState> {
        self.model.to_host(&self.dev)
    }

    /// Replace the device state from a host state (checkpoint restore).
    pub fn load_state(&mut self, state: &TrainState) -> Result<()> {
        self.dev = self.model.to_device(state)?;
        Ok(())
    }

    fn draw_eps(&mut self) -> F32Buf {
        let dims = self.model.meta.eps_dims.clone();
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| self.rng.normal() as f32).collect();
        F32Buf { data, dims }
    }

    /// Bare step: artifact execution only.
    pub fn step_raw(&mut self, x: &F32Buf) -> Result<f32> {
        let eps = self.draw_eps();
        self.model.train_step_dev(&mut self.dev, x, &eps)
    }

    /// Full-PPL step: the noise is a traced `sample` site, the data a
    /// traced `observe` site, parameters round through the param store.
    pub fn step_traced(
        &mut self,
        x: &F32Buf,
        store: &mut crate::params::ParamStore,
    ) -> Result<f32> {
        let meta_batch = self.model.meta.batch;
        let eps_dims = self.model.meta.eps_dims.clone();
        let x_dims = self.model.meta.x_dims.clone();

        // ---- guide trace: eps ~ N(0, I) through the handler stack ----
        let mut ctx = Ctx::with_store(&mut self.rng, store);
        let loc = ctx.c(Tensor::zeros(eps_dims.clone()));
        let scale = ctx.c(Tensor::ones(eps_dims.clone()));
        let eps_var = ctx.plate("batch", meta_batch, None, |ctx, _| {
            ctx.sample("eps", MvNormalDiag::new(loc.clone(), scale.clone()))
        });
        // score the draw (what Pyro's guide trace does for every site)
        let _guide_lp = ctx.trace().get("eps").unwrap().log_prob().item();

        // ---- model trace: data recorded as an observed site ----
        // (its density is computed *inside* the artifact, exactly like a
        // fused CUDA op in Pyro; the trace records the site + metadata)
        let x_f64 = Tensor::new(x.data.iter().map(|&v| v as f64).collect(), x_dims);
        let x_var = ctx.c(x_f64);
        ctx.observe("x", Delta::new(x_var), Tensor::zeros(vec![1]).reshape(vec![1]));
        let trace = ctx.into_trace();
        debug_assert_eq!(trace.len(), 2);

        // ---- compiled ELBO step with the traced noise ----
        let eps_f32: Vec<f32> =
            eps_var.value().data().iter().map(|&v| v as f32).collect();
        let eps = F32Buf { data: eps_f32, dims: eps_var.value().dims().to_vec() };
        let loss = self.model.train_step_dev(&mut self.dev, x, &eps)?;

        // ---- param-store bookkeeping (Pyro: params live in the store) --
        store.get_or_init(
            &format!("{}.flat", self.model.meta.name),
            || Tensor::zeros(vec![1]),
            crate::dist::Constraint::Real,
        );
        Ok(loss)
    }

    pub fn eval(&self, x: &F32Buf, eps: &F32Buf) -> Result<f32> {
        self.model.eval_step_dev(&self.dev, x, eps)
    }

    /// The PPL machinery of [`CompiledSvi::step_traced`] *without* the
    /// artifact execution — used by the Fig-3 bench to quantify the
    /// abstraction cost directly (it is otherwise below the noise floor
    /// of the compiled step on this testbed).
    pub fn trace_machinery_only(
        &mut self,
        x: &F32Buf,
        store: &mut crate::params::ParamStore,
    ) -> F32Buf {
        let meta_batch = self.model.meta.batch;
        let eps_dims = self.model.meta.eps_dims.clone();
        let x_dims = self.model.meta.x_dims.clone();
        let mut ctx = Ctx::with_store(&mut self.rng, store);
        let loc = ctx.c(Tensor::zeros(eps_dims.clone()));
        let scale = ctx.c(Tensor::ones(eps_dims.clone()));
        let eps_var = ctx.plate("batch", meta_batch, None, |ctx, _| {
            ctx.sample("eps", MvNormalDiag::new(loc.clone(), scale.clone()))
        });
        let _guide_lp = ctx.trace().get("eps").unwrap().log_prob().item();
        let x_f64 = Tensor::new(x.data.iter().map(|&v| v as f64).collect(), x_dims);
        let x_var = ctx.c(x_f64);
        ctx.observe("x", Delta::new(x_var), Tensor::zeros(vec![1]).reshape(vec![1]));
        let _trace = ctx.into_trace();
        let eps_f32: Vec<f32> = eps_var.value().data().iter().map(|&v| v as f32).collect();
        F32Buf { data: eps_f32, dims: eps_var.value().dims().to_vec() }
    }
}

// ----------------------------------------------------------- checkpoints

/// Write the training state to a flat little-endian f32 file.
///
/// The write is atomic: bytes go to `<path>.tmp`, which is fsynced and
/// then renamed over `path`. A crash mid-save leaves either the old
/// checkpoint intact or a stray `.tmp` — never a truncated file at
/// `path` (a truncated file would still fail loudly on
/// [`load_checkpoint`], but atomicity means restarts don't even see
/// one).
pub fn save_checkpoint(path: &str, state: &TrainState) -> Result<()> {
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        for buf in [&state.params, &state.m, &state.v, &state.t] {
            for &v in &buf.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Restore a checkpoint written by [`save_checkpoint`] into a state with
/// matching shapes.
pub fn load_checkpoint(path: &str, state: &mut TrainState) -> Result<()> {
    let mut f = std::fs::File::open(path)?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    let total = state.params.data.len() + state.m.data.len() + state.v.data.len() + 1;
    if bytes.len() != total * 4 {
        return Err(Error::msg("checkpoint size mismatch"));
    }
    let mut off = 0usize;
    for buf in [&mut state.params, &mut state.m, &mut state.v, &mut state.t] {
        for v in buf.data.iter_mut() {
            *v = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
        }
    }
    Ok(())
}

// ------------------------------------------------- param-store snapshots

/// A named, versioned [`ParamStore`] snapshot as read back from disk —
/// the unit the serving layer ([`crate::serve`]) registers. Unlike
/// [`TrainState`] checkpoints (flat f32 optimizer state with no
/// metadata), snapshots carry names, shapes, and constraints so a
/// load-time [`ParamStore::fingerprint`] check can reject a mismatched
/// or corrupted file at registration instead of mid-request.
#[derive(Clone, Debug)]
pub struct ParamSnapshot {
    /// Model name the snapshot was saved under.
    pub name: String,
    /// Monotonic model version (the serve registry's key).
    pub version: u64,
    /// The reconstructed parameter store.
    pub store: ParamStore,
    /// `store.fingerprint()` as recorded at save time (always equal to
    /// the reconstructed store's fingerprint — load fails otherwise).
    pub fingerprint: u64,
}

const SNAPSHOT_MAGIC: &[u8; 8] = b"FYSNAP01";

fn constraint_code(c: Constraint) -> u8 {
    match c {
        Constraint::Real => 0,
        Constraint::Positive => 1,
        Constraint::UnitInterval => 2,
        Constraint::Interval(_, _) => 3,
        Constraint::Simplex => 4,
        Constraint::NonNegInteger => 5,
        Constraint::Boolean => 6,
    }
}

/// Serialize a [`ParamStore`] to `path` in the `FYSNAP01` format:
/// magic, model name, version, store fingerprint, then per-entry
/// (name, constraint, dims, unconstrained f64 data) in sorted-name
/// order. Written atomically (`<path>.tmp` + fsync + rename), same as
/// [`save_checkpoint`].
pub fn save_snapshot(path: &str, name: &str, version: u64, store: &ParamStore) -> Result<()> {
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(SNAPSHOT_MAGIC)?;
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&version.to_le_bytes())?;
        f.write_all(&store.fingerprint().to_le_bytes())?;
        let names = store.names();
        f.write_all(&(names.len() as u32).to_le_bytes())?;
        for pname in &names {
            let (t, c) = store
                .peek_entry(pname)
                .expect("names() listed a missing entry");
            f.write_all(&(pname.len() as u32).to_le_bytes())?;
            f.write_all(pname.as_bytes())?;
            f.write_all(&[constraint_code(c)])?;
            if let Constraint::Interval(lo, hi) = c {
                f.write_all(&lo.to_le_bytes())?;
                f.write_all(&hi.to_le_bytes())?;
            }
            f.write_all(&(t.dims().len() as u32).to_le_bytes())?;
            for &d in t.dims() {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in t.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

struct SnapReader {
    bytes: Vec<u8>,
    off: usize,
}

impl SnapReader {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.off + n > self.bytes.len() {
            return Err(Error::msg("snapshot truncated"));
        }
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::msg("snapshot name is not utf-8"))
    }
}

/// Read a snapshot written by [`save_snapshot`], rebuilding the store
/// and validating that the reconstructed [`ParamStore::fingerprint`]
/// (over names, shapes, and constraints) matches the one recorded at
/// save time — a renamed, reshaped, or re-constrained parameter fails
/// here, at load, with the offending detail in the error.
pub fn load_snapshot(path: &str) -> Result<ParamSnapshot> {
    let mut f = std::fs::File::open(path)?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    let mut r = SnapReader { bytes, off: 0 };
    if r.take(8)? != SNAPSHOT_MAGIC {
        return Err(Error::msg("not a FYSNAP01 snapshot (bad magic)"));
    }
    let name = r.string()?;
    let version = r.u64()?;
    let fingerprint = r.u64()?;
    let n_entries = r.u32()? as usize;
    let mut store = ParamStore::new();
    for _ in 0..n_entries {
        let pname = r.string()?;
        let constraint = match r.u8()? {
            0 => Constraint::Real,
            1 => Constraint::Positive,
            2 => Constraint::UnitInterval,
            3 => {
                let lo = r.f64()?;
                let hi = r.f64()?;
                Constraint::Interval(lo, hi)
            }
            4 => Constraint::Simplex,
            5 => Constraint::NonNegInteger,
            6 => Constraint::Boolean,
            code => {
                return Err(Error::msg(format!(
                    "snapshot param '{pname}': unknown constraint code {code}"
                )))
            }
        };
        let ndims = r.u32()? as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(r.u64()? as usize);
        }
        let numel: usize = dims.iter().product();
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            let v = r.f64()?;
            if !v.is_finite() {
                return Err(Error::msg(format!(
                    "snapshot param '{pname}' contains non-finite values"
                )));
            }
            data.push(v);
        }
        store.insert_unconstrained(&pname, Tensor::new(data, dims), constraint);
    }
    if r.off != r.bytes.len() {
        return Err(Error::msg("snapshot has trailing bytes"));
    }
    let actual = store.fingerprint();
    if actual != fingerprint {
        return Err(Error::msg(format!(
            "snapshot fingerprint mismatch: file records {fingerprint:#018x}, \
             reconstructed store hashes to {actual:#018x} \
             (param names/shapes/constraints changed since save)"
        )));
    }
    Ok(ParamSnapshot { name, version, store, fingerprint })
}

// ------------------------------------------------------- parameter server

/// Result of a [`ParamServer::push`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// The gradient was applied; the server is now at `version`.
    Applied { version: u64 },
    /// Rejected: the snapshot the gradient was computed against is more
    /// than `max_staleness` versions behind the server. The worker must
    /// re-pull and recompute — pushing anyway would apply a gradient
    /// evaluated at parameters too far from the ones it updates.
    Stale { version: u64 },
}

struct PsInner<O: Optimizer> {
    store: ParamStore,
    opt: O,
    version: u64,
    applied: u64,
    rejected: u64,
}

/// Versioned parameter server for **asynchronous** data-parallel SVI.
///
/// Workers [`pull`](ParamServer::pull) a `(version, ParamStore)`
/// snapshot, compute a minibatch gradient against it, and
/// [`push`](ParamServer::push) the delta back. The server applies a
/// push through its optimizer only if the base version is at most
/// `max_staleness` behind the current version; staler pushes are
/// rejected ([`PushOutcome::Stale`]) and the worker recomputes against
/// a fresh snapshot.
///
/// **Staleness bound and the synchronous fallback.** With
/// `max_staleness = k`, every applied gradient was computed against
/// parameters at most `k` optimizer steps old. At `k = 0` a push only
/// lands if *no* other update arrived between pull and push, so each
/// applied gradient was evaluated at exactly the parameters it
/// updates: the update sequence equals some serial interleaving of
/// worker steps — this rejection semantics at `k = 0` *is* the
/// synchronous fallback. (We deliberately reject rather than block:
/// blocking a push until the version catches up deadlocks at `k = 0`,
/// because no other worker's push can advance the version either.)
///
/// Unlike [`crate::infer::DataParallelSvi`]'s synchronous shard-order
/// merge, the arrival order of async pushes is nondeterministic, so
/// async runs are *not* bitwise reproducible — they trade determinism
/// for never making fast workers wait on slow ones.
pub struct ParamServer<O: Optimizer> {
    inner: Mutex<PsInner<O>>,
    max_staleness: u64,
}

impl<O: Optimizer> ParamServer<O> {
    pub fn new(store: ParamStore, opt: O, max_staleness: u64) -> Self {
        ParamServer {
            inner: Mutex::new(PsInner { store, opt, version: 0, applied: 0, rejected: 0 }),
            max_staleness,
        }
    }

    /// Snapshot the current parameters. Cheap-ish: tensor storages are
    /// Arc-shared until a worker writes (copy-on-write).
    pub fn pull(&self) -> (u64, ParamStore) {
        let g = self.inner.lock().unwrap();
        (g.version, g.store.clone())
    }

    /// Offer a gradient computed against `base_version`. `local` is the
    /// worker's post-step store: any parameters it initialized that the
    /// server has not yet seen are merged in before the update.
    pub fn push(
        &self,
        base_version: u64,
        local: &ParamStore,
        grads: &HashMap<String, Tensor>,
    ) -> PushOutcome {
        let mut g = self.inner.lock().unwrap();
        let staleness = g.version.saturating_sub(base_version);
        crate::telemetry::record(crate::telemetry::Hist::PsStaleness, staleness);
        if staleness > self.max_staleness {
            g.rejected += 1;
            crate::telemetry::count(crate::telemetry::Counter::PsPushRejected);
            return PushOutcome::Stale { version: g.version };
        }
        let inner = &mut *g;
        inner.store.merge_missing(local);
        apply_grads(&mut inner.opt, &mut inner.store, grads);
        inner.version += 1;
        inner.applied += 1;
        crate::telemetry::count(crate::telemetry::Counter::PsPushApplied);
        PushOutcome::Applied { version: inner.version }
    }

    pub fn version(&self) -> u64 {
        self.inner.lock().unwrap().version
    }

    /// `(applied, rejected)` push counts so far.
    pub fn counts(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.applied, g.rejected)
    }

    pub fn max_staleness(&self) -> u64 {
        self.max_staleness
    }

    /// Consume the server and return the trained parameters.
    pub fn into_store(self) -> ParamStore {
        self.inner.into_inner().unwrap().store
    }
}

/// Configuration for [`train_async`].
#[derive(Clone, Copy, Debug)]
pub struct AsyncConfig {
    /// Worker count W; worker `w` owns shard `w` of the loader.
    pub num_workers: usize,
    /// Minibatch size per worker step.
    pub batch: usize,
    /// Steps each worker pushes before exiting (rejected pushes are
    /// retried, not counted).
    pub steps_per_worker: usize,
    /// Base seed for shard shuffles and particle noise.
    pub base_seed: u64,
    /// Hard cap on consecutive [`PushOutcome::Stale`] recomputes per
    /// step before the run errors out (a safety valve against
    /// pathological contention, not a tuning knob).
    pub max_retries: usize,
}

impl AsyncConfig {
    pub fn new(num_workers: usize, batch: usize, steps_per_worker: usize) -> Self {
        AsyncConfig {
            num_workers,
            batch,
            steps_per_worker,
            base_seed: 0xA57C_5EED,
            max_retries: 4096,
        }
    }
}

/// What [`train_async`] observed, in push-arrival order.
#[derive(Clone, Debug)]
pub struct AsyncReport {
    /// Per-applied-push losses, in the (nondeterministic) order they
    /// arrived at the server.
    pub losses: Vec<f64>,
    pub applied: u64,
    pub rejected: u64,
    pub final_version: u64,
}

impl AsyncReport {
    /// Mean loss over the last `n` applied pushes.
    pub fn tail_mean(&self, n: usize) -> f64 {
        let k = n.min(self.losses.len()).max(1);
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f64>() / k as f64
    }
}

/// Asynchronous data-parallel SVI: W scoped worker threads loop
/// pull → shard minibatch → one-particle gradient → push, with the
/// staleness discipline documented on [`ParamServer`].
///
/// The model/guide see the same [`ShardBatch`] contract as
/// [`crate::infer::DataParallelSvi`], so one model definition runs
/// under both drivers. Estimator cross-step state is frozen: the
/// baseline snapshot is taken once at entry, and `absorb` is not
/// replayed into `elbo` (arrival order is nondeterministic, so there
/// is no well-defined order to absorb in). Use stateless estimators
/// ([`crate::infer::TraceElbo`], [`crate::infer::TraceMeanFieldElbo`])
/// for async runs.
pub fn train_async<O, E>(
    server: &ParamServer<O>,
    elbo: &E,
    loader: &dyn ShardedLoader,
    layout: &BatchLayout,
    cfg: &AsyncConfig,
    model: &ShardModelFn,
    guide: &ShardModelFn,
) -> Result<AsyncReport>
where
    O: Optimizer + Send,
    E: Elbo + Sync,
{
    assert!(cfg.num_workers > 0, "train_async: num_workers must be > 0");
    assert!(cfg.batch > 0, "train_async: batch must be > 0");
    let row_numel = loader.row_numel();
    let numels = layout.numels();
    let layout_numel: usize = numels.iter().sum();
    if layout_numel != row_numel {
        return Err(Error::msg(format!(
            "train_async: BatchLayout covers {layout_numel} elements but loader rows \
             have {row_numel}"
        )));
    }
    if loader.len() < cfg.num_workers * cfg.batch {
        return Err(Error::msg(format!(
            "train_async: {} rows cannot feed {} workers with batch {}",
            loader.len(),
            cfg.num_workers,
            cfg.batch
        )));
    }
    let snapshot = elbo.snapshot();
    let total = loader.len();

    let losses = std::thread::scope(|scope| -> Result<Vec<f64>> {
        let (tx, rx) = mpsc::channel::<f64>();
        let snapshot = &snapshot;
        let numels = &numels;
        let mut handles = Vec::with_capacity(cfg.num_workers);
        for w in 0..cfg.num_workers {
            let tx = tx.clone();
            handles.push(scope.spawn(move || -> Result<()> {
                let mut cursor =
                    ShardCursor::for_shard(loader, cfg.num_workers, w, cfg.batch, cfg.base_seed);
                let mut views: Vec<Tensor> = layout
                    .views
                    .iter()
                    .map(|d| {
                        let mut dims = Vec::with_capacity(d.len() + 1);
                        dims.push(cfg.batch);
                        dims.extend_from_slice(d);
                        Tensor::zeros(dims)
                    })
                    .collect();
                let mut scratch: Vec<f32> = Vec::with_capacity(cfg.batch * row_numel);
                let mut rng = Pcg64::new(
                    cfg.base_seed
                        ^ 0x517E_D00D
                        ^ (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                for _ in 0..cfg.steps_per_worker {
                    let idx = cursor.next_batch();
                    loader.gather_into(idx, &mut scratch)?;
                    fill_views_from_scratch(&scratch, idx.len(), numels, row_numel, &mut views);
                    // Fixed seed per (worker, step): a Stale retry
                    // re-evaluates the same particle at fresher params.
                    let seed = rng.next_u64();
                    let mut retries = 0usize;
                    loop {
                        let (version, mut local) = server.pull();
                        let batch = ShardBatch { views: &views, idx, total };
                        let m = |ctx: &mut Ctx| model(ctx, &batch);
                        let g = |ctx: &mut Ctx| guide(ctx, &batch);
                        let out = run_particle(seed, &mut local, &m, &g, elbo, snapshot)?;
                        match server.push(version, &local, &out.grads) {
                            PushOutcome::Applied { .. } => {
                                let (loss, _) =
                                    elbo.combine(std::slice::from_ref(&out.stats));
                                let _ = tx.send(loss);
                                break;
                            }
                            PushOutcome::Stale { .. } => {
                                retries += 1;
                                if retries > cfg.max_retries {
                                    return Err(Error::msg(format!(
                                        "train_async: worker {w} exceeded {} stale-push \
                                         retries; raise max_staleness or max_retries",
                                        cfg.max_retries
                                    )));
                                }
                            }
                        }
                    }
                }
                Ok(())
            }));
        }
        drop(tx);
        let losses: Vec<f64> = rx.iter().collect();
        for h in handles {
            h.join().map_err(|_| Error::msg("train_async: worker thread panicked"))??;
        }
        Ok(losses)
    })?;

    let (applied, rejected) = server.counts();
    Ok(AsyncReport { losses, applied, rejected, final_version: server.version() })
}

// ------------------------------------------------------------- training

/// Per-epoch training metrics.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    pub steps: usize,
    pub secs: f64,
}

impl EpochStats {
    pub fn throughput(&self, batch: usize) -> f64 {
        self.steps as f64 * batch as f64 / self.secs
    }
}

/// VAE trainer over synthetic MNIST with a prefetch thread feeding
/// batches through a bounded channel (the coordinator's pipeline).
pub struct VaeTrainer {
    pub svi: CompiledSvi,
    pub data: SyntheticMnist,
    pub path: StepPath,
    pub store: crate::params::ParamStore,
}

impl VaeTrainer {
    pub fn new(model: CompiledModel, n_train: usize, n_test: usize, path: StepPath) -> Result<Self> {
        let data = SyntheticMnist::generate(n_train, n_test, 0xDA7A);
        let svi = CompiledSvi::new(model, 0x5EED)?;
        Ok(VaeTrainer { svi, data, path, store: crate::params::ParamStore::new() })
    }

    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochStats> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let started = Instant::now();

        // prefetch thread: gathers batch matrices while PJRT computes
        let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(2);
        let order: Vec<Vec<usize>> = {
            let mut rng = Pcg64::new(0xE10C ^ epoch as u64);
            BatchIter::new(self.data.train.len(), batch, &mut rng).collect()
        };
        let n_steps = order.len();
        std::thread::scope(|scope| -> Result<(f64, usize)> {
            let train_ref = &self.data.train;
            scope.spawn(move || {
                for idx in &order {
                    if tx.send(gather_images(train_ref, idx)).is_err() {
                        break;
                    }
                }
            });
            let mut total = 0.0;
            let mut steps = 0usize;
            while let Ok(data) = rx.recv() {
                let x = F32Buf { data, dims: x_dims.clone() };
                let loss = match self.path {
                    StepPath::Raw => self.svi.step_raw(&x)?,
                    StepPath::Traced => self.svi.step_traced(&x, &mut self.store)?,
                };
                total += loss as f64;
                steps += 1;
            }
            Ok((total, steps))
        })
        .map(|(total, steps)| {
            let secs = started.elapsed().as_secs_f64();
            let test_loss = self.test_loss().unwrap_or(f64::NAN);
            EpochStats {
                epoch,
                train_loss: total / steps.max(1) as f64,
                test_loss,
                steps: n_steps,
                secs,
            }
        })
    }

    pub fn test_loss(&mut self) -> Result<f64> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let mut total = 0.0;
        let mut n = 0;
        let mut rng = Pcg64::new(0x7E57);
        for chunk in self.data.test.chunks(batch) {
            if chunk.len() < batch {
                break;
            }
            let idx: Vec<usize> = (0..batch).collect();
            let x = F32Buf { data: gather_images(chunk, &idx), dims: x_dims.clone() };
            let eps_dims = self.svi.model.meta.eps_dims.clone();
            let ne: usize = eps_dims.iter().product();
            let eps = F32Buf {
                data: (0..ne).map(|_| rng.normal() as f32).collect(),
                dims: eps_dims,
            };
            total += self.svi.eval(&x, &eps)? as f64;
            n += 1;
        }
        Ok(total / n.max(1) as f64)
    }
}

/// DMM trainer over synthetic chorales.
pub struct DmmTrainer {
    pub svi: CompiledSvi,
    pub data: SyntheticChorales,
}

impl DmmTrainer {
    pub fn new(model: CompiledModel, n_train: usize, n_test: usize) -> Result<Self> {
        let t_len = model.meta.x_dims[1];
        let data = SyntheticChorales::generate(n_train, n_test, t_len, 0xC0DA);
        let svi = CompiledSvi::new(model, 0xD1CE)?;
        Ok(DmmTrainer { svi, data })
    }

    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochStats> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let started = Instant::now();
        let mut rng = Pcg64::new(0xE20C ^ epoch as u64);
        let mut total = 0.0;
        let mut steps = 0usize;
        for idx in BatchIter::new(self.data.train.len(), batch, &mut rng) {
            let x = F32Buf { data: gather_rolls(&self.data.train, &idx), dims: x_dims.clone() };
            total += self.svi.step_raw(&x)? as f64;
            steps += 1;
        }
        let secs = started.elapsed().as_secs_f64();
        let test_loss = self.test_loss()?;
        Ok(EpochStats { epoch, train_loss: total / steps.max(1) as f64, test_loss, steps, secs })
    }

    /// Mean test -ELBO per timestep (the Fig-4 number, negated).
    pub fn test_loss(&mut self) -> Result<f64> {
        let batch = self.svi.model.meta.batch;
        let x_dims = self.svi.model.meta.x_dims.clone();
        let eps_dims = self.svi.model.meta.eps_dims.clone();
        let mut rng = Pcg64::new(0x7E58);
        let mut total = 0.0;
        let mut n = 0;
        for chunk in self.data.test.chunks(batch) {
            if chunk.len() < batch {
                break;
            }
            let idx: Vec<usize> = (0..batch).collect();
            let x = F32Buf { data: gather_rolls(chunk, &idx), dims: x_dims.clone() };
            let ne: usize = eps_dims.iter().product();
            let eps = F32Buf {
                data: (0..ne).map(|_| rng.normal() as f32).collect(),
                dims: eps_dims.clone(),
            };
            total += self.svi.eval(&x, &eps)? as f64;
            n += 1;
        }
        Ok(total / n.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::F32Buf;

    #[test]
    fn checkpoint_roundtrip() {
        let mut state = TrainState {
            params: F32Buf { data: vec![1.0, 2.0, 3.0], dims: vec![3] },
            m: F32Buf { data: vec![0.1, 0.2, 0.3], dims: vec![3] },
            v: F32Buf { data: vec![0.4, 0.5, 0.6], dims: vec![3] },
            t: F32Buf { data: vec![7.0], dims: vec![1] },
            step: 7,
        };
        let path = "/tmp/fyro_ckpt_test.bin";
        save_checkpoint(path, &state).unwrap();
        let orig = state.params.data.clone();
        state.params.data = vec![0.0; 3];
        load_checkpoint(path, &mut state).unwrap();
        assert_eq!(state.params.data, orig);
        assert_eq!(state.t.data, vec![7.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn snapshot_roundtrip_preserves_store() {
        let mut store = ParamStore::new();
        store.get_or_init("loc", || Tensor::new(vec![1.5, -0.5], vec![2]), Constraint::Real);
        store.get_or_init("scale", || Tensor::scalar(0.25), Constraint::Positive);
        store.get_or_init("p", || Tensor::scalar(0.5), Constraint::Interval(0.0, 2.0));
        let path = "/tmp/fyro_snap_test.bin";
        save_snapshot(path, "toy", 3, &store).unwrap();
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let snap = load_snapshot(path).unwrap();
        assert_eq!(snap.name, "toy");
        assert_eq!(snap.version, 3);
        assert_eq!(snap.fingerprint, store.fingerprint());
        assert_eq!(snap.store.names(), store.names());
        for name in store.names() {
            let a = store.get_unconstrained(&name).unwrap();
            let b = snap.store.get_unconstrained(&name).unwrap();
            assert_eq!(a.dims(), b.dims(), "param '{name}' shape");
            // bitwise: snapshots are exact, not approximate
            let same = a
                .data()
                .iter()
                .zip(b.data().iter())
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "param '{name}' values not bitwise equal");
            assert_eq!(store.constraint(&name), snap.store.constraint(&name));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn snapshot_rejects_corruption() {
        let mut store = ParamStore::new();
        store.get_or_init("w", || Tensor::new(vec![1.0, 2.0, 3.0], vec![3]), Constraint::Real);
        let path = "/tmp/fyro_snap_corrupt_test.bin";
        save_snapshot(path, "toy", 1, &store).unwrap();

        // truncation fails loudly
        let bytes = std::fs::read(path).unwrap();
        std::fs::write(path, &bytes[..bytes.len() - 4]).unwrap();
        let err = load_snapshot(path).unwrap_err();
        assert!(err.to_string().contains("truncated"), "unexpected error: {err}");

        // bad magic fails loudly
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        std::fs::write(path, &bad).unwrap();
        let err = load_snapshot(path).unwrap_err();
        assert!(err.to_string().contains("magic"), "unexpected error: {err}");

        // flipping a byte inside a param *name* breaks the fingerprint
        let mut renamed = bytes.clone();
        // the param name "w" appears after the 8B magic + (4B len + "toy")
        // + 8B version + 8B fingerprint + 4B count + 4B name-len
        let name_off = 8 + 4 + 3 + 8 + 8 + 4 + 4;
        assert_eq!(renamed[name_off], b'w');
        renamed[name_off] = b'q';
        std::fs::write(path, &renamed).unwrap();
        let err = load_snapshot(path).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "unexpected error: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn epoch_stats_throughput() {
        let s = EpochStats { epoch: 0, train_loss: 1.0, test_loss: 1.0, steps: 10, secs: 2.0 };
        assert_eq!(s.throughput(128), 640.0);
    }

    #[test]
    fn truncated_checkpoint_fails_loudly() {
        let mut state = TrainState {
            params: F32Buf { data: vec![1.0, 2.0, 3.0], dims: vec![3] },
            m: F32Buf { data: vec![0.1, 0.2, 0.3], dims: vec![3] },
            v: F32Buf { data: vec![0.4, 0.5, 0.6], dims: vec![3] },
            t: F32Buf { data: vec![7.0], dims: vec![1] },
            step: 7,
        };
        let path = "/tmp/fyro_ckpt_trunc_test.bin";
        save_checkpoint(path, &state).unwrap();
        // atomic save leaves no stray temp file behind
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        // chop off the tail and reload: must error, not silently zero-fill
        let bytes = std::fs::read(path).unwrap();
        std::fs::write(path, &bytes[..bytes.len() - 4]).unwrap();
        let err = load_checkpoint(path, &mut state).unwrap_err();
        assert!(err.to_string().contains("size mismatch"), "unexpected error: {err}");
        // the failed load must not have clobbered the state
        assert_eq!(state.params.data, vec![1.0, 2.0, 3.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn param_server_staleness_discipline() {
        use crate::dist::Constraint;
        use crate::optim::Adam;

        let mut store = ParamStore::new();
        store.get_or_init("w", || Tensor::scalar(0.0), Constraint::Real);
        let mut grads = HashMap::new();
        grads.insert("w".to_string(), Tensor::scalar(1.0));

        // k = 0: only gradients against the current version land.
        let server = ParamServer::new(store.clone(), Adam::new(0.1), 0);
        let (v0, local) = server.pull();
        assert_eq!(v0, 0);
        assert_eq!(server.push(v0, &local, &grads), PushOutcome::Applied { version: 1 });
        assert_eq!(server.push(v0, &local, &grads), PushOutcome::Stale { version: 1 });
        let (v1, local1) = server.pull();
        assert_eq!(v1, 1);
        assert_eq!(server.push(v1, &local1, &grads), PushOutcome::Applied { version: 2 });
        assert_eq!(server.counts(), (2, 1));

        // k = 1: one-version-stale pushes land, two-stale are rejected.
        let server = ParamServer::new(store, Adam::new(0.1), 1);
        let (v0, local) = server.pull();
        assert_eq!(server.push(v0, &local, &grads), PushOutcome::Applied { version: 1 });
        assert_eq!(server.push(v0, &local, &grads), PushOutcome::Applied { version: 2 });
        assert_eq!(server.push(v0, &local, &grads), PushOutcome::Stale { version: 2 });
    }

    fn async_scalar_model(ctx: &mut Ctx, b: &ShardBatch) {
        use crate::dist::Normal;
        let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
        let x = b.views[0].clone().reshape(vec![b.idx.len()]);
        ctx.plate_idx("data", b.total, b.idx, |ctx, _| {
            ctx.observe("x", Normal::new(mu.clone(), ctx.cs(1.0)), x);
        });
    }

    fn async_scalar_guide(ctx: &mut Ctx, _b: &ShardBatch) {
        use crate::dist::{Constraint, Normal};
        let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
        let scale =
            ctx.param_constrained("mu_scale", || Tensor::scalar(1.0), Constraint::Positive);
        ctx.sample("mu", Normal::new(loc, scale));
    }

    #[test]
    fn train_async_converges_on_scalar_gaussian() {
        use crate::data::MemLoader;
        use crate::infer::TraceElbo;
        use crate::optim::Adam;

        let rows: Vec<Vec<f32>> =
            (0..32).map(|i| vec![1.5 + 0.05 * (i as f32 - 15.5)]).collect();
        let loader = MemLoader::from_images(&rows);
        let layout = BatchLayout::single(&[1]);
        let server = ParamServer::new(ParamStore::new(), Adam::new(0.05), 4);
        let cfg = AsyncConfig::new(2, 8, 200);
        let report = train_async(
            &server,
            &TraceElbo::default(),
            &loader,
            &layout,
            &cfg,
            &async_scalar_model,
            &async_scalar_guide,
        )
        .unwrap();
        assert_eq!(report.applied, 400, "every counted step is an applied push");
        assert_eq!(report.losses.len(), 400);
        assert_eq!(report.final_version, 400);
        let store = server.into_store();
        let loc = store.get("mu_loc").unwrap().item();
        assert!((loc - 1.5).abs() < 0.4, "async posterior loc {loc}, want ~1.5");
        assert!(report.tail_mean(50).is_finite());
    }
}
