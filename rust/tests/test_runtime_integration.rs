//! Integration: the full compiled path — artifacts -> PJRT -> coordinator.
//! Requires `make artifacts` (skips gracefully when absent so plain
//! `cargo test` works pre-AOT; `make test` always builds artifacts first).

use fyro::coordinator::{load_checkpoint, save_checkpoint, CompiledSvi, StepPath, VaeTrainer};
use fyro::data::{gather_images, SyntheticMnist};
use fyro::params::ParamStore;
use fyro::runtime::{ArtifactCache, F32Buf};
use fyro::tensor::Pcg64;

fn cache() -> Option<ArtifactCache> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(ArtifactCache::open("artifacts").expect("open artifacts"))
}

fn batch_x(meta: &fyro::runtime::ModelMeta) -> F32Buf {
    let data = SyntheticMnist::generate(meta.batch, 0, 3);
    let idx: Vec<usize> = (0..meta.batch).collect();
    F32Buf { data: gather_images(&data.train, &idx), dims: meta.x_dims.clone() }
}

#[test]
fn manifest_lists_all_eight_models() {
    let Some(cache) = cache() else { return };
    let names: Vec<&str> = cache.models().iter().map(|m| m.name.as_str()).collect();
    for want in [
        "vae_z10_h400",
        "vae_z10_h2000",
        "vae_z30_h400",
        "vae_z30_h2000",
        "dmm_iaf0",
        "dmm_iaf1",
        "dmm_iaf2",
    ] {
        assert!(names.contains(&want), "missing artifact {want}; have {names:?}");
    }
}

#[test]
fn vae_train_step_decreases_loss() {
    let Some(cache) = cache() else { return };
    let model = cache.load("vae_z10_h400").expect("compile vae");
    let meta = model.meta.clone();
    let x = batch_x(&meta);
    let mut svi = CompiledSvi::new(model, 1).unwrap();
    let first = svi.step_raw(&x).unwrap();
    for _ in 0..30 {
        svi.step_raw(&x).unwrap();
    }
    let last = svi.step_raw(&x).unwrap();
    assert!(
        last < first * 0.8,
        "loss did not decrease on a fixed batch: {first} -> {last}"
    );
    assert!(first.is_finite() && last.is_finite());
}

#[test]
fn traced_path_matches_raw_semantics() {
    // same seed => same eps draws => identical losses on both paths
    let Some(cache) = cache() else { return };
    let x = batch_x(cache.meta("vae_z10_h400").unwrap());

    let m1 = cache.load("vae_z10_h400").unwrap();
    let mut raw = CompiledSvi::new(m1, 42).unwrap();
    let m2 = cache.load("vae_z10_h400").unwrap();
    let mut traced = CompiledSvi::new(m2, 42).unwrap();
    let mut store = ParamStore::new();
    for step in 0..3 {
        let lr = raw.step_raw(&x).unwrap();
        let lt = traced.step_traced(&x, &mut store).unwrap();
        assert!(
            (lr - lt).abs() < 2e-3 * lr.abs().max(1.0),
            "step {step}: raw {lr} vs traced {lt}"
        );
    }
}

#[test]
fn vae_eval_is_deterministic_given_eps() {
    let Some(cache) = cache() else { return };
    let model = cache.load("vae_z10_h400").unwrap();
    let meta = model.meta.clone();
    let x = batch_x(&meta);
    let svi = CompiledSvi::new(model, 2).unwrap();
    let n: usize = meta.eps_dims.iter().product();
    let mut rng = Pcg64::new(9);
    let eps = F32Buf {
        data: (0..n).map(|_| rng.normal() as f32).collect(),
        dims: meta.eps_dims.clone(),
    };
    let a = svi.eval(&x, &eps).unwrap();
    let b = svi.eval(&x, &eps).unwrap();
    assert_eq!(a, b);
}

#[test]
fn dmm_artifact_trains() {
    let Some(cache) = cache() else { return };
    let model = cache.load("dmm_iaf1").expect("compile dmm_iaf1");
    let mut trainer = fyro::coordinator::DmmTrainer::new(model, 64, 16).unwrap();
    let s0 = trainer.run_epoch(0).unwrap();
    let s1 = trainer.run_epoch(1).unwrap();
    let s2 = trainer.run_epoch(2).unwrap();
    assert!(s0.train_loss.is_finite());
    assert!(
        s2.train_loss < s0.train_loss,
        "DMM loss flat: {} -> {} -> {}",
        s0.train_loss,
        s1.train_loss,
        s2.train_loss
    );
}

#[test]
fn checkpoint_restores_training_state() {
    let Some(cache) = cache() else { return };
    let model = cache.load("vae_z10_h400").unwrap();
    let meta = model.meta.clone();
    let x = batch_x(&meta);
    let mut svi = CompiledSvi::new(model, 3).unwrap();
    for _ in 0..3 {
        svi.step_raw(&x).unwrap();
    }
    let path = "/tmp/fyro_integration_ckpt.bin";
    save_checkpoint(path, &svi.host_state().unwrap()).unwrap();
    let snapshot = svi.host_state().unwrap().params.data;
    for _ in 0..3 {
        svi.step_raw(&x).unwrap();
    }
    assert_ne!(snapshot, svi.host_state().unwrap().params.data);
    let mut restored = svi.host_state().unwrap();
    load_checkpoint(path, &mut restored).unwrap();
    svi.load_state(&restored).unwrap();
    assert_eq!(snapshot, svi.host_state().unwrap().params.data);
    std::fs::remove_file(path).ok();
}

#[test]
fn vae_trainer_epoch_improves_test_loss() {
    let Some(cache) = cache() else { return };
    let model = cache.load("vae_z10_h400").unwrap();
    let mut trainer = VaeTrainer::new(model, 512, 256, StepPath::Raw).unwrap();
    let before = trainer.test_loss().unwrap();
    let s = trainer.run_epoch(0).unwrap();
    assert!(s.test_loss < before, "test loss flat: {before} -> {}", s.test_loss);
    assert!(s.secs > 0.0 && s.steps > 0);
}
