//! Data-parallel SVI end-to-end: bitwise thread-invariance at fixed
//! shards (losses AND final parameters) on three model shapes, graph-
//! mode composition, streaming-loader restart reproducibility, and
//! async-vs-sync convergence.

use fyro::coordinator::{train_async, AsyncConfig, ParamServer};
use fyro::data::{MemLoader, StreamLoader};
use fyro::infer::{
    BatchLayout, DataParallelSvi, GraphDiagnostics, ShardBatch, ShardConfig, ShardModelFn,
};
use fyro::nn::Linear;
use fyro::prelude::*;

// ------------------------------------------------------------- helpers

fn config(w: usize, batch: usize, parallel: bool, graph: bool) -> ShardConfig {
    ShardConfig {
        parallel,
        num_threads: if parallel { 4 } else { 1 },
        graph_mode: graph,
        ..ShardConfig::new(w, batch)
    }
}

/// Run `steps` data-parallel steps from a fresh store/RNG; return the
/// loss trajectory, the final params (name-sorted), and diagnostics.
fn run_traj(
    loader: &dyn ShardedLoader,
    layout: &BatchLayout,
    sc: ShardConfig,
    steps: usize,
    lr: f64,
    model: &ShardModelFn,
    guide: &ShardModelFn,
) -> (Vec<f64>, Vec<(String, Vec<f64>)>, GraphDiagnostics) {
    let mut dp = DataParallelSvi::new(Adam::new(lr), TraceElbo::default(), sc, layout.clone());
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0x7E57);
    let losses: Vec<f64> = (0..steps)
        .map(|_| dp.step(&mut store, &mut rng, loader, model, guide).expect("dp step"))
        .collect();
    let params = final_params(&store);
    (losses, params, dp.graph_diagnostics().clone())
}

fn final_params(store: &ParamStore) -> Vec<(String, Vec<f64>)> {
    store
        .names()
        .into_iter()
        .map(|n| {
            let v = store.get(&n).expect("named param").data().to_vec();
            (n, v)
        })
        .collect()
}

fn assert_bitwise_invariant(
    loader: &dyn ShardedLoader,
    layout: &BatchLayout,
    w: usize,
    batch: usize,
    model: &ShardModelFn,
    guide: &ShardModelFn,
) {
    let (l_ser, p_ser, _) =
        run_traj(loader, layout, config(w, batch, false, false), 6, 0.01, model, guide);
    let (l_par, p_par, _) =
        run_traj(loader, layout, config(w, batch, true, false), 6, 0.01, model, guide);
    assert_eq!(l_ser, l_par, "threaded losses diverged from serial at W={w}");
    assert_eq!(p_ser, p_par, "threaded final params diverged from serial at W={w}");
    assert!(l_ser.iter().all(|l| l.is_finite()), "non-finite losses: {l_ser:?}");
}

// --------------------------------------------------- the three models

/// (a) scalar global latent, subsampled observation plate.
fn scalar_model(ctx: &mut Ctx, b: &ShardBatch) {
    let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
    let x = b.views[0].clone().reshape(vec![b.idx.len()]);
    ctx.plate_idx("data", b.total, b.idx, |ctx, _| {
        ctx.observe("x", Normal::new(mu.clone(), ctx.cs(1.0)), x);
    });
}

fn scalar_guide(ctx: &mut Ctx, _b: &ShardBatch) {
    let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
    let scale = ctx.param_constrained("mu_scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("mu", Normal::new(loc, scale));
}

fn scalar_rows(n: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| vec![1.5 + 0.05 * (i as f32 - (n as f32 - 1.0) / 2.0)]).collect()
}

/// (b) per-row local latent inside the subsampled plate (VAE-shaped).
const LOCAL_XD: usize = 4;
const LOCAL_ZD: usize = 2;

fn local_model(ctx: &mut Ctx, b: &ShardBatch) {
    let batch = b.idx.len();
    ctx.plate_idx("batch", b.total, b.idx, |ctx, _| {
        let loc = ctx.c(Tensor::zeros(vec![batch, LOCAL_ZD]));
        let scale = ctx.c(Tensor::ones(vec![batch, LOCAL_ZD]));
        let z = ctx.sample("z", MvNormalDiag::new(loc, scale));
        let dec = Linear::new("dec", LOCAL_ZD, LOCAL_XD);
        let logits = dec.forward(ctx, &z);
        ctx.observe("x", Bernoulli::new(logits).to_event(1), b.views[0].clone());
    });
}

fn local_guide(ctx: &mut Ctx, b: &ShardBatch) {
    let enc_loc = Linear::new("enc.loc", LOCAL_XD, LOCAL_ZD);
    let enc_ls = Linear::new("enc.ls", LOCAL_XD, LOCAL_ZD);
    let xv = ctx.c(b.views[0].clone());
    let loc = enc_loc.forward(ctx, &xv);
    let scale = enc_ls.forward(ctx, &xv).mul_scalar(0.25).exp();
    ctx.sample("z", MvNormalDiag::new(loc, scale));
}

fn local_rows(n: usize) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::new(0xB0B);
    (0..n)
        .map(|_| (0..LOCAL_XD).map(|_| f32::from(rng.uniform() < 0.4)).collect())
        .collect()
}

/// (c) DMM-shaped: a latent chain with one frame view per time step.
const DMM_T: usize = 3;
const DMM_ZD: usize = 2;
const DMM_XD: usize = 4;

fn dmm_model(ctx: &mut Ctx, b: &ShardBatch) {
    let batch = b.idx.len();
    ctx.plate_idx("batch", b.total, b.idx, |ctx, _| {
        let trans = Linear::new("m.trans", DMM_ZD, DMM_ZD);
        let emit = Linear::new("m.emit", DMM_ZD, DMM_XD);
        let ones = ctx.c(Tensor::ones(vec![batch, DMM_ZD]));
        let mut z_prev: Option<Var> = None;
        for t in 0..DMM_T {
            let loc = match &z_prev {
                None => ctx.c(Tensor::zeros(vec![batch, DMM_ZD])),
                Some(z) => trans.forward(ctx, z),
            };
            let z = ctx.sample(&format!("z_{t}"), MvNormalDiag::new(loc, ones.clone()));
            let logits = emit.forward(ctx, &z);
            ctx.observe(
                &format!("x_{t}"),
                Bernoulli::new(logits).to_event(1),
                b.views[t].clone(),
            );
            z_prev = Some(z);
        }
    });
}

fn dmm_guide(ctx: &mut Ctx, b: &ShardBatch) {
    let enc = Linear::new("g.enc", DMM_XD, DMM_ZD);
    let trans = Linear::new("g.trans", DMM_ZD, DMM_ZD);
    let head_ls = Linear::new("g.ls", DMM_XD, DMM_ZD);
    let mut z_prev: Option<Var> = None;
    for t in 0..DMM_T {
        let xv = ctx.c(b.views[t].clone());
        let mut loc = enc.forward(ctx, &xv);
        if let Some(z) = &z_prev {
            loc = loc.add(&trans.forward(ctx, z));
        }
        let scale = head_ls.forward(ctx, &xv).mul_scalar(0.25).exp();
        let z = ctx.sample(&format!("z_{t}"), MvNormalDiag::new(loc, scale));
        z_prev = Some(z);
    }
}

fn dmm_rolls(n: usize) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::new(0xD33);
    (0..n)
        .map(|_| {
            (0..DMM_T)
                .map(|_| (0..DMM_XD).map(|_| f32::from(rng.uniform() < 0.3)).collect())
                .collect()
        })
        .collect()
}

// ---------------------------------------------------------- the tests

#[test]
fn threaded_matches_serial_bitwise_scalar() {
    let loader = MemLoader::from_images(&scalar_rows(36));
    let layout = BatchLayout::single(&[1]);
    assert_bitwise_invariant(&loader, &layout, 3, 4, &scalar_model, &scalar_guide);
}

#[test]
fn threaded_matches_serial_bitwise_local_latent() {
    let loader = MemLoader::from_images(&local_rows(40));
    let layout = BatchLayout::single(&[LOCAL_XD]);
    assert_bitwise_invariant(&loader, &layout, 4, 5, &local_model, &local_guide);
}

#[test]
fn threaded_matches_serial_bitwise_dmm() {
    let loader = MemLoader::from_rolls(&dmm_rolls(30));
    let layout = BatchLayout::frames(DMM_T, &[DMM_XD]);
    assert_bitwise_invariant(&loader, &layout, 3, 5, &dmm_model, &dmm_guide);
}

#[test]
fn changing_shards_changes_the_decomposition() {
    // W is the SEMANTIC knob (like batch size): different shard counts
    // legitimately give different trajectories. This guards against the
    // invariance tests passing vacuously.
    let loader = MemLoader::from_images(&scalar_rows(36));
    let layout = BatchLayout::single(&[1]);
    let sc2 = config(2, 4, false, false);
    let sc3 = config(3, 4, false, false);
    let (l2, _, _) = run_traj(&loader, &layout, sc2, 4, 0.01, &scalar_model, &scalar_guide);
    let (l3, _, _) = run_traj(&loader, &layout, sc3, 4, 0.01, &scalar_model, &scalar_guide);
    assert_ne!(l2, l3, "different shard counts should sample different batches");
}

#[test]
fn graph_mode_composes_with_sharding_on_dmm() {
    let loader = MemLoader::from_rolls(&dmm_rolls(30));
    let layout = BatchLayout::frames(DMM_T, &[DMM_XD]);
    let (l_dyn, p_dyn, _) =
        run_traj(&loader, &layout, config(2, 5, false, false), 6, 0.01, &dmm_model, &dmm_guide);
    let (l_graph, p_graph, diags) =
        run_traj(&loader, &layout, config(2, 5, false, true), 6, 0.01, &dmm_model, &dmm_guide);
    assert!(diags.active, "graph mode failed to engage: {:?}", diags.last_error);
    assert_eq!(diags.fallbacks, 0, "graph mode fell back: {:?}", diags.last_error);
    assert!(diags.compiled_steps >= 4, "expected compiled steps, got {diags:?}");
    for (g, d) in l_graph.iter().zip(&l_dyn) {
        assert!(
            (g - d).abs() <= 1e-12 * (1.0 + d.abs()),
            "graph loss {g} diverged from dynamic {d}"
        );
    }
    for ((gn, gv), (dn, dv)) in p_graph.iter().zip(&p_dyn) {
        assert_eq!(gn, dn);
        for (a, b) in gv.iter().zip(dv) {
            assert!((a - b).abs() <= 1e-10 * (1.0 + b.abs()), "param {gn} diverged");
        }
    }
    // and the compiled path is itself thread-invariant, bitwise
    let (l_gpar, p_gpar, _) =
        run_traj(&loader, &layout, config(2, 5, true, true), 6, 0.01, &dmm_model, &dmm_guide);
    assert_eq!(l_graph, l_gpar, "compiled threaded losses diverged from compiled serial");
    assert_eq!(p_graph, p_gpar, "compiled threaded params diverged from compiled serial");
}

#[test]
fn streaming_restart_replays_the_exact_batch_stream() {
    // Write the dataset to disk, train through the StreamLoader, then
    // restart from saved (cursor, store, rng) state: the continuation
    // must match an uninterrupted run bitwise.
    let rolls = dmm_rolls(24);
    let flat: Vec<Vec<f32>> = rolls.iter().map(|r| r.iter().flatten().copied().collect()).collect();
    let dir = std::env::temp_dir().join("fyro_dp_restart_test.bin");
    let path = dir.to_str().unwrap();
    StreamLoader::create(path, &[DMM_T, DMM_XD], flat.iter().map(|r| r.as_slice())).unwrap();
    let loader = StreamLoader::open(path).unwrap();
    let layout = BatchLayout::frames(DMM_T, &[DMM_XD]);
    let sc = config(2, 4, false, false);

    // uninterrupted run: 4 + 3 steps
    let mut dp_a =
        DataParallelSvi::new(Adam::new(0.01), TraceElbo::default(), sc, layout.clone());
    let mut store_a = ParamStore::new();
    let mut rng_a = Pcg64::new(0xC0FFEE);
    for _ in 0..4 {
        dp_a.step(&mut store_a, &mut rng_a, &loader, &dmm_model, &dmm_guide).unwrap();
    }
    // checkpoint everything a restart needs
    let saved_cursors = dp_a.cursor_states();
    let saved_store = store_a.clone();
    let saved_rng = rng_a.clone();
    let tail_a: Vec<f64> = (0..3)
        .map(|_| dp_a.step(&mut store_a, &mut rng_a, &loader, &dmm_model, &dmm_guide).unwrap())
        .collect();

    // "restart": fresh engine + fresh loader handle, state restored
    let loader_b = StreamLoader::open(path).unwrap();
    let mut dp_b =
        DataParallelSvi::new(Adam::new(0.01), TraceElbo::default(), sc, layout.clone());
    dp_b.init(&loader_b).unwrap();
    dp_b.restore_cursors(&saved_cursors);
    let mut store_b = saved_store;
    let mut rng_b = saved_rng;
    let tail_b: Vec<f64> = (0..3)
        .map(|_| dp_b.step(&mut store_b, &mut rng_b, &loader_b, &dmm_model, &dmm_guide).unwrap())
        .collect();

    assert_eq!(tail_a, tail_b, "restarted run diverged from the uninterrupted one");
    assert_eq!(final_params(&store_a), final_params(&store_b));
    std::fs::remove_file(path).ok();
}

#[test]
fn stream_loader_matches_mem_loader_bitwise() {
    let rows = scalar_rows(24);
    let mem = MemLoader::from_images(&rows);
    let dir = std::env::temp_dir().join("fyro_dp_stream_vs_mem.bin");
    let path = dir.to_str().unwrap();
    StreamLoader::create(path, &[1], rows.iter().map(|r| r.as_slice())).unwrap();
    let streamed = StreamLoader::open(path).unwrap();
    let layout = BatchLayout::single(&[1]);
    let sc = config(2, 4, true, false);
    let (l_mem, p_mem, _) =
        run_traj(&mem, &layout, sc, 5, 0.01, &scalar_model, &scalar_guide);
    let (l_stream, p_stream, _) =
        run_traj(&streamed, &layout, sc, 5, 0.01, &scalar_model, &scalar_guide);
    assert_eq!(l_mem, l_stream, "loader backend leaked into the trajectory");
    assert_eq!(p_mem, p_stream);
    std::fs::remove_file(path).ok();
}

#[test]
fn async_converges_within_tolerance_of_sync() {
    let rows = scalar_rows(32);
    let loader = MemLoader::from_images(&rows);
    let layout = BatchLayout::single(&[1]);

    // synchronous reference
    let mut dp = DataParallelSvi::new(
        Adam::new(0.05),
        TraceElbo::default(),
        config(2, 8, false, false),
        layout.clone(),
    );
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0x5EED);
    for _ in 0..200 {
        dp.step(&mut store, &mut rng, &loader, &scalar_model, &scalar_guide).unwrap();
    }
    let sync_loc = store.get("mu_loc").unwrap().item();

    // async parameter server, same model and data
    let server = ParamServer::new(ParamStore::new(), Adam::new(0.05), 4);
    let report = train_async(
        &server,
        &TraceElbo::default(),
        &loader,
        &layout,
        &AsyncConfig::new(2, 8, 200),
        &scalar_model,
        &scalar_guide,
    )
    .unwrap();
    assert_eq!(report.applied, 400);
    let async_loc = server.into_store().get("mu_loc").unwrap().item();

    assert!((sync_loc - 1.5).abs() < 0.3, "sync loc {sync_loc}, want ~1.5");
    assert!(
        (async_loc - sync_loc).abs() < 0.4,
        "async loc {async_loc} too far from sync loc {sync_loc}"
    );
}
