//! Counting-allocator gate for `Predictive::run_stacked_into` (the
//! serve hot loop): refilling caller-owned slabs must allocate strictly
//! less than a fresh run that has to build them — the per-site output
//! allocations disappear in steady state.
//!
//! Lives in its own test binary so the global counting allocator sees
//! no unrelated concurrent test threads (same proxy pattern as
//! `test_telemetry.rs`).

use fyro::dist::Normal;
use fyro::infer::Predictive;
use fyro::params::ParamStore;
use fyro::poutine::Ctx;
use fyro::tensor::{Pcg64, Tensor};
use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn run_stacked_into_refill_allocates_less_than_fresh() {
    const N: usize = 64;
    let idx: Vec<usize> = (0..N).collect();
    let data = Tensor::zeros(vec![N]);
    let model = move |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.plate_idx("pix", N, &idx, |ctx, _plate| {
            ctx.observe("x", Normal::new(z.clone(), ctx.cs(1.0)), data.clone());
        });
    };
    let guide = |ctx: &mut Ctx| {
        ctx.sample("z", Normal::std(0.0, 1.0));
    };
    let store = ParamStore::new();
    let pred = Predictive::new(8);
    let sites = ["x", "z"];

    // Warm the reusable slabs once so every measured refill hits the
    // steady state ([8, 64] and [8] tensors already in place).
    let mut reused: HashMap<String, Tensor> = HashMap::new();
    let mut rng = Pcg64::new(0);
    pred.run_stacked_into(&model, &guide, &store, &mut rng, &sites, &mut reused);

    // The interpreter pass itself allocates (traces, tapes); the claim
    // under test is only that refill drops the per-site output
    // allocations a fresh run must make. Same seed on both sides makes
    // the interpreter's allocations identical; min-over-windows keeps
    // harness noise (stdout, test runner) from inflating either side.
    let mut fresh_min = u64::MAX;
    let mut refill_min = u64::MAX;
    for _ in 0..5 {
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let mut fresh: HashMap<String, Tensor> = HashMap::new();
        let mut rng_a = Pcg64::new(42);
        pred.run_stacked_into(&model, &guide, &store, &mut rng_a, &sites, &mut fresh);
        fresh_min = fresh_min.min(ALLOCS.load(Ordering::Relaxed) - a0);

        let b0 = ALLOCS.load(Ordering::Relaxed);
        let mut rng_b = Pcg64::new(42);
        pred.run_stacked_into(&model, &guide, &store, &mut rng_b, &sites, &mut reused);
        refill_min = refill_min.min(ALLOCS.load(Ordering::Relaxed) - b0);

        // and the reuse must not change the answer, bit for bit
        for s in sites {
            let same = fresh[s]
                .data()
                .iter()
                .zip(reused[s].data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "slab-reusing refill diverged at site '{s}'");
        }
    }
    assert!(
        refill_min < fresh_min,
        "slab reuse saved no allocations: refill {refill_min} vs fresh {fresh_min}"
    );
}
