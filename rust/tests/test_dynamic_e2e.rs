//! Cross-algorithm integration on the dynamic path: the same model
//! solved by SVI, NUTS and importance sampling must agree on the
//! posterior — the strongest internal-consistency check the PPL has.

use fyro::infer::importance::Importance;
use fyro::infer::mcmc::{McmcConfig, Nuts};
use fyro::infer::svi::{Svi, SviConfig};
use fyro::infer::AutoNormal;
use fyro::prelude::*;

/// z ~ N(0,1); three observations from N(z, 0.8).
/// Posterior: precision 1 + 3/0.64; mean = (Σx/0.64) / prec.
fn model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    for (i, &x) in [1.2, 0.7, 1.5].iter().enumerate() {
        ctx.observe(&format!("x{i}"), Normal::new(z.clone(), ctx.cs(0.8)), Tensor::scalar(x));
    }
}

fn exact_posterior() -> (f64, f64) {
    let tau = 1.0 + 3.0 / 0.64;
    let mean = ((1.2 + 0.7 + 1.5) / 0.64) / tau;
    (mean, (1.0 / tau).sqrt())
}

#[test]
fn svi_nuts_and_importance_agree() {
    let (mean, sd) = exact_posterior();

    // --- SVI with an autoguide ---
    let auto = AutoNormal::new(&model);
    let guide = auto.guide();
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(21);
    let mut svi = Svi::with_config(
        Adam::new(0.03),
        auto.recommended_elbo(),
        SviConfig { num_particles: 4, ..SviConfig::default() },
    );
    for _ in 0..2500 {
        svi.step(&mut store, &mut rng, &model, &guide);
    }
    let svi_mean = auto.median(&store)[0].1.item();

    // --- NUTS ---
    let out = Nuts::run(
        &model,
        McmcConfig { warmup: 400, samples: 800, seed: 22, ..Default::default() },
    );
    let nuts_mean = out.mean("z").item();
    let nuts_sd = out.std("z").item();

    // --- importance sampling from the prior ---
    let mut rng2 = Pcg64::new(23);
    let imp = Importance::from_prior(&model, 40_000, &mut rng2);
    let imp_mean = imp.posterior_mean("z").item();

    for (label, got) in [("svi", svi_mean), ("nuts", nuts_mean), ("importance", imp_mean)] {
        assert!(
            (got - mean).abs() < 0.12,
            "{label} mean {got} vs exact {mean}"
        );
    }
    assert!((nuts_sd - sd).abs() < 0.08, "nuts sd {nuts_sd} vs exact {sd}");
}

#[test]
fn posterior_predictive_covers_data() {
    use fyro::infer::Predictive;
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("zl", || Tensor::scalar(0.0));
        let scale = ctx.param_constrained("zs", || Tensor::scalar(1.0), Constraint::Positive);
        ctx.sample("z", Normal::new(loc, scale));
    };
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(31);
    let mut svi = Svi::new(Adam::new(0.03), TraceElbo::default());
    for _ in 0..1500 {
        svi.step(&mut store, &mut rng, &model, &guide);
    }
    let pred = Predictive::new(2000).run(&model, &guide, &store, &mut rng, &["x0"]);
    let xs: Vec<f64> = pred["x0"].iter().map(|t| t.item()).collect();
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let (pm, _) = exact_posterior();
    assert!((m - pm).abs() < 0.15, "predictive mean {m} vs posterior mean {pm}");
    // the actual observation 1.2 is inside the central predictive mass
    let mut sorted = xs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = sorted[(xs.len() as f64 * 0.05) as usize];
    let hi = sorted[(xs.len() as f64 * 0.95) as usize];
    assert!(lo < 1.2 && 1.2 < hi, "1.2 outside 90% predictive interval [{lo}, {hi}]");
}

#[test]
fn intervention_differs_from_conditioning() {
    // classic do vs condition distinction on a 2-node chain a -> b
    let chain = |ctx: &mut Ctx| {
        let a = ctx.sample("a", Normal::std(0.0, 1.0));
        ctx.sample("b", Normal::new(a.mul_scalar(2.0), ctx.cs(0.5)));
    };
    let mut rng = Pcg64::new(41);

    // condition on b=4: posterior for a shifts (a ≈ 2·4/(4+0.25))
    let cond = fyro::poutine::condition(chain, [("b", Tensor::scalar(4.0))]);
    let imp = Importance::from_prior(&cond, 40_000, &mut rng);
    let a_cond = imp.posterior_mean("a").item();

    // do(b=4): a is unaffected (mean stays 0)
    let mut acc = 0.0;
    let n = 20_000;
    let intervened = fyro::poutine::do_intervention(chain, [("b", Tensor::scalar(4.0))]);
    for _ in 0..n {
        let t = fyro::poutine::trace_fn(&intervened, &mut rng);
        acc += t.get("a").unwrap().value.value().item();
    }
    let a_do = acc / n as f64;

    assert!(a_cond > 1.5, "conditioning should move a: {a_cond}");
    assert!(a_do.abs() < 0.05, "intervention should NOT move a: {a_do}");
}

#[test]
fn masked_sequence_model_ignores_padding() {
    // DMM-style padding: two sequences of different length in one batch,
    // mask removes the pad timestep from the likelihood
    let seq_model = |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        let obs = Tensor::from_vec(vec![0.5, 0.8, 99.0]); // 99 is padding
        let masked = fyro::poutine::mask(
            |ctx: &mut Ctx| {
                let zc = ctx
                    .trace()
                    .get("z")
                    .map(|s| s.value.clone())
                    .expect("z sampled");
                let mean = zc.mul(&ctx.c(Tensor::ones(vec![3])));
                ctx.observe("x", Normal::new(mean, ctx.c(Tensor::ones(vec![3]))), obs.clone());
            },
            Tensor::from_vec(vec![1.0, 1.0, 0.0]),
        );
        masked(ctx);
        let _ = z;
    };
    let mut rng = Pcg64::new(51);
    let t = fyro::poutine::trace_fn(&seq_model, &mut rng);
    let lp = t.log_prob_sum();
    // the 99.0 outlier contributes nothing; log prob is moderate
    assert!(lp.is_finite() && lp > -30.0, "padding leaked into likelihood: {lp}");
}
