//! Hot-path invariants: the stride-aware broadcast kernels must be
//! bitwise-equal to the retained `unravel`-based reference kernels on
//! random shapes; the in-place ops must match their allocating
//! counterparts; the fused optimizer and the parallel multi-particle
//! ELBO must reproduce the serial/allocating trajectories exactly.

use fyro::infer::svi::{Svi, SviConfig};
use fyro::optim::reference::AdamRef;
use fyro::optim::Adam;
use fyro::params::ParamStore;
use fyro::prelude::*;
use fyro::testkit::{self, Config};

fn bits(t: &Tensor) -> Vec<u64> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// A random shape plus a broadcast-compatible partner: leading dims
/// optionally dropped, remaining dims optionally squashed to 1.
fn random_broadcast_pair(rng: &mut Pcg64) -> (Tensor, Tensor) {
    let rank = 1 + rng.below(4);
    let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5)).collect();
    let squash = |rng: &mut Pcg64, dims: &[usize]| -> Vec<usize> {
        let drop = rng.below(dims.len());
        dims[drop..]
            .iter()
            .map(|&d| if rng.below(3) == 0 { 1 } else { d })
            .collect()
    };
    let da = squash(rng, &dims);
    let db = squash(rng, &dims);
    (
        testkit::tensor(rng, &da, 1.0),
        testkit::tensor(rng, &db, 1.0),
    )
}

#[test]
fn strided_broadcast_is_bitwise_equal_to_reference() {
    testkit::for_all(
        Config { cases: 200, seed: 0x57_21D },
        |rng| random_broadcast_pair(rng),
        |(a, b)| {
            for (name, f) in [
                ("add", (|x: f64, y: f64| x + y) as fn(f64, f64) -> f64),
                ("sub", |x, y| x - y),
                ("mul", |x, y| x * y),
                ("div", |x, y| x / y),
                ("max", f64::max),
            ] {
                let fast = a.zip_reference(b, f); // oracle
                let got = match name {
                    "add" => a.add(b),
                    "sub" => a.sub(b),
                    "mul" => a.mul(b),
                    "div" => a.div(b),
                    _ => a.maximum(b),
                };
                testkit::ensure(
                    got.dims() == fast.dims() && bits(&got) == bits(&fast),
                    format!("{name} diverged on {:?} x {:?}", a.dims(), b.dims()),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn broadcast_to_is_bitwise_equal_to_reference_gather() {
    testkit::for_all(
        Config { cases: 100, seed: 0xB17_CA57 },
        |rng| {
            let rank = 1 + rng.below(3);
            let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(4)).collect();
            let drop = rng.below(dims.len());
            let src_dims: Vec<usize> = dims[drop..]
                .iter()
                .map(|&d| if rng.below(3) == 0 { 1 } else { d })
                .collect();
            (testkit::tensor(rng, &src_dims, 1.0), dims)
        },
        |(src, target)| {
            let fast = src.broadcast_to(target.clone());
            // oracle: ones-shaped zip through the reference kernel
            let ones = Tensor::ones(target.clone());
            let slow = src.zip_reference(&ones, |a, _| a);
            testkit::ensure(
                fast.dims() == slow.dims() && bits(&fast) == bits(&slow),
                format!("broadcast_to diverged: {:?} -> {:?}", src.dims(), target),
            )
        },
    );
}

#[test]
fn inplace_ops_match_allocating_ops() {
    testkit::for_all(
        Config { cases: 120, seed: 0x1_4B1A5 },
        |rng| {
            let rank = 1 + rng.below(3);
            let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5)).collect();
            let sub: Vec<usize> = {
                let drop = rng.below(dims.len());
                dims[drop..]
                    .iter()
                    .map(|&d| if rng.below(3) == 0 { 1 } else { d })
                    .collect()
            };
            let alpha = testkit::f64_in(rng, -2.0, 2.0);
            (
                testkit::tensor(rng, &dims, 1.0),
                testkit::tensor(rng, &sub, 1.0),
                alpha,
            )
        },
        |(a, b, alpha)| {
            let mut x = a.clone();
            x.add_assign(b);
            testkit::ensure(bits(&x) == bits(&a.add(b)), "add_assign != add")?;
            let mut y = a.clone();
            y.sub_assign(b);
            testkit::ensure(bits(&y) == bits(&a.sub(b)), "sub_assign != sub")?;
            let mut z = a.clone();
            z.axpy(*alpha, b);
            let want = a.add(&b.mul_scalar(*alpha));
            // a + alpha*b computed fused vs two-op: equal up to fp
            // associativity — here the op orders are identical, so exact
            testkit::ensure(bits(&z) == bits(&want), "axpy != add(mul_scalar)")?;
            let mut w = a.clone();
            w.scale_inplace(*alpha);
            testkit::ensure(bits(&w) == bits(&a.mul_scalar(*alpha)), "scale_inplace")?;
            Ok(())
        },
    );
}

#[test]
fn no_unravel_on_hot_path_matmul_nan_semantics() {
    // 0 * NaN must stay NaN through the dense matmul (the old kernel's
    // zero-skip silently dropped it)
    let a = Tensor::new(vec![0.0, 0.0, 1.0, 0.5], vec![2, 2]);
    let b = Tensor::new(vec![f64::NAN, 1.0, 2.0, f64::INFINITY], vec![2, 2]);
    let c = a.matmul(&b);
    assert!(c.data()[0].is_nan(), "row with 0*NaN must be NaN");
    assert!(c.data()[3].is_infinite(), "Inf must propagate");
}

/// The same conjugate model/guide used across the infer tests.
fn model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
}

fn guide(ctx: &mut Ctx) {
    let loc = ctx.param("q_loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("q_scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("z", Normal::new(loc, scale));
}

#[test]
fn fused_optimizer_preserves_svi_trajectory() {
    // Adam (fused, in-place) and AdamRef (original allocating chain)
    // must yield bitwise-identical SVI trajectories.
    fn run<O: fyro::optim::Optimizer>(opt: O) -> (Vec<f64>, f64, f64) {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0xF00D);
        let mut svi = Svi::with_config(
            opt,
            TraceElbo::default(),
            SviConfig { num_particles: 2, ..SviConfig::default() },
        );
        let losses: Vec<f64> = (0..50)
            .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
            .collect();
        (
            losses,
            store.get_unconstrained("q_loc").unwrap().item(),
            store.get_unconstrained("q_scale").unwrap().item(),
        )
    }
    let (l_fast, loc_fast, scale_fast) = run(Adam::new(0.02));
    let (l_ref, loc_ref, scale_ref) = run(AdamRef::new(0.02));
    assert_eq!(l_fast, l_ref, "fused Adam changed the loss trajectory");
    assert_eq!(loc_fast.to_bits(), loc_ref.to_bits());
    assert_eq!(scale_fast.to_bits(), scale_ref.to_bits());
}

#[test]
fn parallel_elbo_matches_serial_on_plate_model() {
    // subsampled vectorized plate + params first initialized inside
    // particles: the strongest parity surface for the threaded path
    let data: Vec<f64> = (0..16).map(|i| 0.8 + 0.05 * i as f64).collect();
    let n = data.len();
    let data_t = Tensor::from_vec(data);
    let model = move |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 5.0));
        ctx.plate("data", n, Some(4), |ctx, plate| {
            ctx.observe(
                "x",
                Normal::new(mu.clone(), ctx.cs(1.0)),
                plate.select(&data_t),
            );
        });
    };
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
        let scale =
            ctx.param_constrained("mu_scale", || Tensor::scalar(0.5), Constraint::Positive);
        ctx.sample("mu", Normal::new(loc, scale));
    };
    let run = |parallel: bool, threads: usize| -> (Vec<f64>, f64) {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0x9A9A);
        let mut svi = Svi::with_config(
            Adam::new(0.05),
            TraceElbo::default(),
            SviConfig { num_particles: 5, parallel, num_threads: threads, ..SviConfig::default() },
        );
        let losses: Vec<f64> = (0..30)
            .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
            .collect();
        (losses, store.get_unconstrained("mu_loc").unwrap().item())
    };
    let (l_serial, loc_serial) = run(false, 0);
    for threads in [2usize, 3, 5] {
        let (l_par, loc_par) = run(true, threads);
        assert_eq!(l_serial, l_par, "trajectory diverged at {threads} threads");
        assert_eq!(loc_serial.to_bits(), loc_par.to_bits());
    }
}
