//! Paper Figure 1, as a runnable Fyro program: the complete VAE example —
//! generative model, amortized guide with an NN encoder (`pyro.module`),
//! conditioning, and SVI with Adam — on the dynamic path.
//!
//! This is deliberately the *literal* structure of the paper's listing,
//! scaled to CPU: z ∈ ℝ^4, x ∈ {0,1}^16, a 1-hidden-layer encoder.

use fyro::infer::svi::{Svi, SviConfig};
use fyro::nn::{Activation, Linear, Mlp};
use fyro::prelude::*;

const ZD: usize = 4;
const XD: usize = 16;

/// model(): z ~ N(0, I); x ~ Bernoulli(sigmoid(z W + b))
fn model(ctx: &mut Ctx, x: Tensor) {
    let loc = ctx.c(Tensor::zeros(vec![ZD]));
    let scale = ctx.c(Tensor::ones(vec![ZD]));
    let z = ctx.sample("z", MvNormalDiag::new(loc, scale));
    // pyro.param("weight"), pyro.param("bias")
    let w = ctx.param("weight", || {
        Tensor::randn(vec![ZD, XD], &mut Pcg64::new(99)).mul_scalar(0.3)
    });
    let b = ctx.param("bias", || Tensor::zeros(vec![XD]));
    let logits = z.reshape(vec![1, ZD]).matmul(&w).add(&b).reshape(vec![XD]);
    ctx.observe("x", Bernoulli::new(logits), x);
}

/// guide(x): pyro.module("encoder", nn) ; z ~ N(encoder(x))
fn guide(ctx: &mut Ctx, x: Tensor) {
    let encoder = Mlp::new("encoder", &[XD, 8], Activation::Tanh, Activation::Tanh);
    let head_loc = Linear::new("encoder.loc", 8, ZD);
    let head_ls = Linear::new("encoder.ls", 8, ZD);
    let xv = ctx.c(x);
    let h = encoder.forward(ctx, &xv);
    let loc = head_loc.forward(ctx, &h);
    let scale = head_ls.forward(ctx, &h).mul_scalar(0.25).exp();
    ctx.sample("z", MvNormalDiag::new(loc, scale));
}

fn make_data(n: usize) -> Vec<Tensor> {
    // two prototype patterns + bit noise: a compressible binary dataset
    let mut rng = Pcg64::new(5);
    let protos = [
        Tensor::from_vec((0..XD).map(|i| f64::from(i % 2 == 0)).collect::<Vec<_>>()),
        Tensor::from_vec((0..XD).map(|i| f64::from(i < XD / 2)).collect::<Vec<_>>()),
    ];
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let p = protos[rng.below(2)].clone();
        let flips: Vec<f64> = (0..XD).map(|_| rng.uniform()).collect();
        let data: Vec<f64> = p
            .data()
            .iter()
            .zip(&flips)
            .map(|(&v, &u)| if u < 0.05 { 1.0 - v } else { v })
            .collect();
        out.push(Tensor::from_vec(data));
    }
    out
}

#[test]
fn fig1_vae_structure_trains() {
    let data = make_data(64);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(1);
    let mut svi = Svi::with_config(
        Adam::new(0.01),
        TraceElbo::default(),
        SviConfig { num_particles: 1, ..SviConfig::default() },
    );

    // losses.append(svi.step(batch)) — exactly the Fig-1 loop
    let mut losses = Vec::new();
    for epoch in 0..60 {
        let mut epoch_loss = 0.0;
        for x in &data {
            let xb = x.clone();
            let xg = x.clone();
            let m = move |ctx: &mut Ctx| model(ctx, xb.clone());
            let g = move |ctx: &mut Ctx| guide(ctx, xg.clone());
            epoch_loss += svi.step(&mut store, &mut rng, &m, &g);
        }
        losses.push(epoch_loss / data.len() as f64);
        let _ = epoch;
    }
    let first: f64 = losses[..5].iter().sum::<f64>() / 5.0;
    let last: f64 = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(
        last < first - 1.0,
        "VAE did not learn: {first:.2} -> {last:.2}"
    );
    // all Fig-1 ingredients registered
    assert!(store.contains("weight"));
    assert!(store.contains("bias"));
    assert!(store.contains("encoder.l0.w"));
    assert!(store.contains("encoder.loc.w"));
}

#[test]
fn fig1_conditioned_model_scores_data() {
    // pyro.condition(model, data={"x": x}) equivalence: observe == condition
    let x = make_data(1).remove(0);
    let x2 = x.clone();
    let unconditioned = move |ctx: &mut Ctx| {
        let loc = ctx.c(Tensor::zeros(vec![ZD]));
        let scale = ctx.c(Tensor::ones(vec![ZD]));
        let z = ctx.sample("z", MvNormalDiag::new(loc, scale));
        let w = ctx.c(Tensor::randn(vec![ZD, XD], &mut Pcg64::new(99)).mul_scalar(0.3));
        let logits = z.reshape(vec![1, ZD]).matmul(&w).reshape(vec![XD]);
        ctx.sample("x", Bernoulli::new(logits));
    };
    let conditioned = fyro::poutine::condition(unconditioned, [("x", x2)]);
    let mut rng = Pcg64::new(2);
    let t = fyro::poutine::trace_fn(&conditioned, &mut rng);
    let site = t.get("x").unwrap();
    assert!(site.is_observed);
    assert_eq!(site.value.value().dims(), &[XD]);
    assert!(t.log_prob_sum().is_finite());
}
