//! Telemetry guarantees, end to end: training with telemetry enabled is
//! **bitwise identical** to training with it disabled on the dynamic,
//! graph-mode and threaded data-parallel engines; the compiled hot path
//! stays allocation-free with telemetry on; histogram percentiles are
//! within their documented bucket bounds; JSONL events round-trip.
//!
//! The recorder is process-global and Rust runs tests on concurrent
//! threads, so every test here serializes on one mutex and resets the
//! recorder before asserting on counters.

use fyro::data::MemLoader;
use fyro::infer::svi::{Svi, SviConfig};
use fyro::infer::{BatchLayout, DataParallelSvi, ShardBatch, ShardConfig};
use fyro::prelude::*;
use fyro::telemetry::{self, export};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

// ------------------------------------------------- allocations proxy

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Serializes all tests in this binary: the recorder is global state.
static LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ------------------------------------------------- the shared models

/// The conjugate scalar model/guide used across the infer tests.
fn model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
}

fn guide(ctx: &mut Ctx) {
    let loc = ctx.param("q_loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("q_scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("z", Normal::new(loc, scale));
}

/// Run `steps` SVI steps from a fresh store/RNG; return the loss
/// trajectory and the final params as exact bits.
fn run_svi(svi_cfg: SviConfig, steps: usize) -> (Vec<u64>, Vec<u64>) {
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0xF00D);
    let mut svi = Svi::with_config(Adam::new(0.02), TraceElbo::default(), svi_cfg);
    let losses: Vec<u64> = (0..steps)
        .map(|_| svi.step(&mut store, &mut rng, &model, &guide).to_bits())
        .collect();
    let params = vec![
        store.get_unconstrained("q_loc").unwrap().item().to_bits(),
        store.get_unconstrained("q_scale").unwrap().item().to_bits(),
    ];
    (losses, params)
}

fn shard_model(ctx: &mut Ctx, b: &ShardBatch) {
    let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
    let x = b.views[0].clone().reshape(vec![b.idx.len()]);
    ctx.plate_idx("data", b.total, b.idx, |ctx, _| {
        ctx.observe("x", Normal::new(mu.clone(), ctx.cs(1.0)), x);
    });
}

fn shard_guide(ctx: &mut Ctx, _b: &ShardBatch) {
    let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("mu_scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("mu", Normal::new(loc, scale));
}

fn run_data_parallel(parallel: bool, steps: usize) -> (Vec<u64>, u64) {
    let rows: Vec<Vec<f32>> = (0..64).map(|i| vec![1.5 + 0.05 * i as f32]).collect();
    let loader = MemLoader::from_rows(rows.iter().map(|r| r.as_slice()), vec![1]);
    let layout = BatchLayout::single(&[1]);
    let sc = ShardConfig {
        parallel,
        num_threads: if parallel { 4 } else { 1 },
        ..ShardConfig::new(4, 8)
    };
    let mut dp = DataParallelSvi::new(Adam::new(0.01), TraceElbo::default(), sc, layout);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0x7E57);
    let losses: Vec<u64> = (0..steps)
        .map(|_| {
            dp.step(&mut store, &mut rng, &loader, &shard_model, &shard_guide)
                .expect("dp step")
                .to_bits()
        })
        .collect();
    (losses, store.get_unconstrained("mu_loc").unwrap().item().to_bits())
}

// ------------------------------------------------------ parity tests

#[test]
fn bitwise_parity_dynamic_svi() {
    let _g = locked();
    telemetry::set_enabled(false);
    telemetry::reset();
    let cfg = SviConfig { num_particles: 2, ..SviConfig::default() };
    let (losses_off, params_off) = run_svi(cfg, 40);

    telemetry::set_enabled(true);
    let (losses_on, params_on) = run_svi(cfg, 40);
    telemetry::set_enabled(false);

    assert_eq!(losses_off, losses_on, "telemetry perturbed the dynamic loss trajectory");
    assert_eq!(params_off, params_on, "telemetry perturbed the final params");

    // and it actually recorded while enabled
    let s = telemetry::snapshot();
    assert_eq!(s.counter("steps"), 40);
    assert_eq!(s.counter("dynamic_steps"), 40);
    assert_eq!(s.hist("step_ns").unwrap().count, 40);
    // 2 particles per step
    assert_eq!(s.hist("particle_ns").unwrap().count, 80);
    assert!(s.gauge("loss").unwrap().is_finite());
    assert!(s.gauge("grad_norm").unwrap() > 0.0);
    assert_eq!(s.counter("nonfinite_loss"), 0);
    assert_eq!(s.counter("nonfinite_grad"), 0);
    telemetry::reset();
}

#[test]
fn bitwise_parity_graph_mode_svi() {
    let _g = locked();
    telemetry::set_enabled(false);
    telemetry::reset();
    let cfg = SviConfig { graph_mode: true, ..SviConfig::default() };
    let (losses_off, params_off) = run_svi(cfg, 40);

    telemetry::set_enabled(true);
    let (losses_on, params_on) = run_svi(cfg, 40);
    telemetry::set_enabled(false);

    assert_eq!(losses_off, losses_on, "telemetry perturbed the compiled trajectory");
    assert_eq!(params_off, params_on);

    let s = telemetry::snapshot();
    assert_eq!(s.counter("steps"), 40);
    assert_eq!(s.counter("graph_compiles"), 1);
    assert_eq!(s.counter("compiled_steps"), 39, "step 1 records, the rest run compiled");
    assert_eq!(s.counter("dynamic_steps"), 1);
    assert_eq!(s.counter("graph_fallbacks"), 0);
    telemetry::reset();
}

#[test]
fn bitwise_parity_data_parallel_threaded() {
    let _g = locked();
    telemetry::set_enabled(false);
    telemetry::reset();
    let (losses_off, param_off) = run_data_parallel(true, 12);

    telemetry::set_enabled(true);
    let (losses_on, param_on) = run_data_parallel(true, 12);
    telemetry::set_enabled(false);

    assert_eq!(losses_off, losses_on, "telemetry perturbed threaded data-parallel SVI");
    assert_eq!(param_off, param_on);

    let s = telemetry::snapshot();
    assert_eq!(s.counter("steps"), 12);
    // 4 shards per step, recorded from inside the worker threads
    assert_eq!(s.hist("particle_ns").unwrap().count, 48);
    assert!(s.hist("merge_wait_ns").unwrap().count > 0, "merge span never recorded");
    telemetry::reset();

    // threaded and serial agree with telemetry on, too
    telemetry::set_enabled(true);
    let (losses_ser, param_ser) = run_data_parallel(false, 12);
    telemetry::set_enabled(false);
    assert_eq!(losses_off, losses_ser, "serial vs threaded diverged under telemetry");
    assert_eq!(param_off, param_ser);
    telemetry::reset();
}

#[test]
fn instrumented_trace_is_bitwise_identical_and_records_sites() {
    let _g = locked();
    telemetry::set_enabled(false);
    telemetry::reset();

    let mut rng = Pcg64::new(42);
    let plain = fyro::poutine::trace_fn(&model, &mut rng);

    telemetry::set_enabled(true);
    let wrapped = telemetry::instrument(model);
    let mut rng = Pcg64::new(42);
    let instrumented = fyro::poutine::trace_fn(&wrapped, &mut rng);
    telemetry::set_enabled(false);

    assert_eq!(
        plain.log_prob_sum().to_bits(),
        instrumented.log_prob_sum().to_bits(),
        "instrument() changed the trace"
    );

    let s = telemetry::snapshot();
    let z = s.site("z").expect("latent site recorded");
    assert_eq!(z.hits, 1);
    assert_eq!(z.numel, 1);
    assert!(z.last_log_prob.is_finite());
    let x = s.site("x").expect("observed site recorded");
    assert_eq!(x.hits, 1);
    // the dashboard renders without panicking and mentions the sites
    let dash = format!("{s}");
    assert!(dash.contains("z") && dash.contains("x"), "dashboard missing sites:\n{dash}");
    telemetry::reset();
}

// -------------------------------------------------- allocation budget

#[test]
fn compiled_steady_state_allocs_zero_with_telemetry_on() {
    let _g = locked();
    telemetry::set_enabled(false);
    telemetry::reset();

    let cfg = SviConfig { graph_mode: true, ..SviConfig::default() };
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0xF00D);
    let mut svi = Svi::with_config(Adam::new(0.02), TraceElbo::default(), cfg);
    // warmup: recording step + first compiled step (arena build)
    for _ in 0..3 {
        svi.step(&mut store, &mut rng, &model, &guide);
    }
    assert!(svi.graph_diagnostics().active, "graph mode failed to engage");

    telemetry::set_enabled(true);
    // Other binaries' threads can't pollute ALLOCS (separate process),
    // but this harness's main thread may print while we measure; take
    // the min over windows so one noisy window can't fail the gate.
    let mut min_allocs = u64::MAX;
    for _ in 0..5 {
        let a0 = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..4 {
            std::hint::black_box(svi.step(&mut store, &mut rng, &model, &guide));
        }
        min_allocs = min_allocs.min(ALLOCS.load(Ordering::Relaxed) - a0);
    }
    telemetry::set_enabled(false);
    assert_eq!(
        min_allocs, 0,
        "compiled steady-state step allocated with telemetry enabled"
    );
    let s = telemetry::snapshot();
    assert!(s.counter("compiled_steps") >= 20);
    telemetry::reset();
}

// ------------------------------------------------- histogram behavior

#[test]
fn histogram_percentiles_within_bucket_bounds() {
    let _g = locked();
    telemetry::set_enabled(true);
    telemetry::reset();
    // 90% at ~1000, 10% at ~100_000
    for _ in 0..90 {
        telemetry::record(telemetry::Hist::StepNs, 1000);
    }
    for _ in 0..10 {
        telemetry::record(telemetry::Hist::StepNs, 100_000);
    }
    telemetry::set_enabled(false);
    let s = telemetry::snapshot();
    let h = s.hist("step_ns").unwrap();
    assert_eq!(h.count, 100);
    assert_eq!(h.min, 1000);
    assert_eq!(h.max, 100_000);
    // log-scale buckets: estimates within a factor of two of the truth
    let p50 = h.p50();
    assert!((500.0..=2000.0).contains(&p50), "p50 {p50} outside 2x of 1000");
    // the p99 bucket is clamped to the observed max here — exact
    assert_eq!(h.p99(), 100_000.0);
    assert!(h.p50() <= h.p95() && h.p95() <= h.p99(), "percentiles not monotone");
    let mean = h.mean();
    assert!((mean - 10_900.0).abs() < 1e-9, "exact mean expected, got {mean}");
    telemetry::reset();
}

// ------------------------------------------------------ JSONL events

#[test]
fn jsonl_events_round_trip() {
    let _g = locked();
    telemetry::set_enabled(true);
    telemetry::reset();
    telemetry::set_stderr_echo(false);
    let path = std::env::temp_dir().join("fyro_test_telemetry_events.jsonl");
    let path = path.to_str().unwrap().to_string();
    export::set_jsonl_path(&path).expect("sink");

    let gnarly = "shape [2, 3] != [3]\n\t\"guide\" mismatch \\ tab";
    telemetry::warn(telemetry::WarnKind::GraphFallback, gnarly);
    telemetry::warn(telemetry::WarnKind::DataParallelGraphDisabled, "plain reason");
    telemetry::record(telemetry::Hist::StepNs, 1234);
    export::emit_snapshot("after-warns");
    export::clear_jsonl();
    telemetry::set_stderr_echo(true);
    telemetry::set_enabled(false);

    let text = std::fs::read_to_string(&path).expect("read events");
    std::fs::remove_file(&path).ok();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "expected 3 events:\n{text}");

    let ev0 = export::parse_jsonl_line(lines[0]).expect("line 0 parses");
    let get = |fields: &[(String, String)], k: &str| -> String {
        fields
            .iter()
            .find(|(n, _)| n == k)
            .map(|(_, v)| v.clone())
            .unwrap_or_else(|| panic!("missing field {k}"))
    };
    assert_eq!(get(&ev0, "seq"), "0");
    assert_eq!(get(&ev0, "event"), "warn");
    assert_eq!(get(&ev0, "kind"), "graph_fallback");
    assert_eq!(get(&ev0, "message"), gnarly, "escape round trip failed");

    let ev1 = export::parse_jsonl_line(lines[1]).expect("line 1 parses");
    assert_eq!(get(&ev1, "seq"), "1");
    assert_eq!(get(&ev1, "kind"), "dp_graph_disabled");

    let ev2 = export::parse_jsonl_line(lines[2]).expect("line 2 parses");
    assert_eq!(get(&ev2, "seq"), "2");
    assert_eq!(get(&ev2, "event"), "snapshot");
    assert_eq!(get(&ev2, "label"), "after-warns");
    let snap = get(&ev2, "telemetry");
    assert!(snap.starts_with('{') && snap.contains("\"hists\""), "snapshot body: {snap}");

    // warn events counted while enabled
    let s = telemetry::snapshot();
    assert_eq!(s.counter("warn_events"), 2);
    telemetry::reset();
}

#[test]
fn warn_events_flow_without_sink_and_count_only_when_enabled() {
    let _g = locked();
    telemetry::set_enabled(false);
    telemetry::reset();
    telemetry::set_stderr_echo(false);
    // disabled: no counter bump, no panic without a sink
    telemetry::warn(telemetry::WarnKind::GraphDisabled, "quiet");
    assert_eq!(telemetry::snapshot().counter("warn_events"), 0);
    telemetry::set_enabled(true);
    telemetry::warn(telemetry::WarnKind::GraphDisabled, "counted");
    telemetry::set_enabled(false);
    telemetry::set_stderr_echo(true);
    assert_eq!(telemetry::snapshot().counter("warn_events"), 1);
    telemetry::reset();
}

// -------------------------------------------------- parameter server

#[test]
fn param_server_staleness_histogram_and_push_counters() {
    let _g = locked();
    telemetry::set_enabled(true);
    telemetry::reset();

    let mut store = ParamStore::new();
    store.get_or_init("w", || Tensor::scalar(0.0), Constraint::Real);
    let mut grads = std::collections::HashMap::new();
    grads.insert("w".to_string(), Tensor::scalar(1.0));

    let server = ParamServer::new(store, Adam::new(0.1), 0);
    let (v0, local) = server.pull();
    assert!(matches!(server.push(v0, &local, &grads), PushOutcome::Applied { .. }));
    // now one version stale: rejected at k = 0
    assert!(matches!(server.push(v0, &local, &grads), PushOutcome::Stale { .. }));
    let (v1, local1) = server.pull();
    assert!(matches!(server.push(v1, &local1, &grads), PushOutcome::Applied { .. }));
    telemetry::set_enabled(false);

    let s = telemetry::snapshot();
    assert_eq!(s.counter("ps_push_applied"), 2);
    assert_eq!(s.counter("ps_push_rejected"), 1);
    let h = s.hist("ps_staleness").unwrap();
    assert_eq!(h.count, 3, "every push lands in the staleness histogram");
    assert_eq!(h.min, 0);
    assert_eq!(h.max, 1);
    telemetry::reset();
}

// ------------------------------------------------- snapshot plumbing

#[test]
fn snapshot_json_is_parseable_and_diagnostics_embed() {
    let _g = locked();
    telemetry::set_enabled(true);
    telemetry::reset();
    telemetry::count(telemetry::Counter::Steps);
    telemetry::gauge(telemetry::Gauge::Loss, -2.5);
    telemetry::record(telemetry::Hist::StepNs, 512);
    telemetry::set_enabled(false);

    let s = telemetry::snapshot();
    let json = s.to_json().render();
    let fields = export::parse_jsonl_line(&json).expect("snapshot JSON parses");
    let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
    assert_eq!(keys, ["counters", "gauges", "hists", "sites"]);

    // GraphDiagnostics folds into the same JSON vocabulary
    let d = fyro::infer::GraphDiagnostics {
        active: true,
        compiles: 1,
        compiled_steps: 9,
        ..Default::default()
    };
    let dj = d.to_json().render();
    let df = export::parse_jsonl_line(&dj).expect("diagnostics JSON parses");
    assert!(df.iter().any(|(k, v)| k == "compiled_steps" && v == "9"));
    telemetry::reset();
}
